//! Tensor operations: GEMM, conv2d (direct + im2col), pooling.
//!
//! Integer variants accumulate in `i64` and narrow with a checked cast —
//! the deployment pipeline's range analysis (transform/deploy.rs) proves
//! narrowing is safe for deployed graphs, and the debug assertion catches
//! violations in tests.
//!
//! Two call styles coexist:
//!
//! * tensor-in/tensor-out convenience functions (`matmul_i32`,
//!   `conv2d_f32`, ...) used by the unfused interpreter paths; and
//! * arena-aware `_into` variants operating on raw slices
//!   (`im2col_into`, `matmul_i32_fused_into`, `maxpool_into`, ...) used
//!   by the compiled execution plans (engine/plan.rs) — no allocation,
//!   caller-provided scratch, optional fused per-channel epilogues
//!   applied while the GEMM output is narrowed.

use super::{get_packed, get_packed_raw, packed_byte_len, set_packed, Tensor, TensorF, TensorI};
use crate::quant::Precision;

/// Checked i64 -> i32 narrowing for integer images. The deployment
/// pipeline's range analysis proves every narrowed value fits; debug
/// builds verify that proof at every narrowing site.
#[inline]
pub fn narrow(v: i64) -> i32 {
    debug_assert!(
        v >= i32::MIN as i64 && v <= i32::MAX as i64,
        "integer image overflowed i32: {v}"
    );
    v as i32
}

// ---------------------------------------------------------------------------
// Packed integer elements (DESIGN.md §Precision propagation)
// ---------------------------------------------------------------------------

/// An integer-image storage element the packed kernels are generic over:
/// `u8` (unsigned sub-word), `i8` (signed sub-word) and `i32` (the
/// full-width fallback). Widening is lossless; narrowing carries the same
/// debug-checked contract as [`narrow`] — the deployment pipeline's range
/// proof guarantees the value fits its stamped precision.
pub trait PackedElem: Copy + Default + Send + Sync + 'static {
    const PRECISION: Precision;

    /// Lossless widening to the arithmetic width.
    fn to_i32(self) -> i32;

    /// Range-proved narrowing from the arithmetic width (debug-checked,
    /// exactly like [`narrow`]).
    fn from_i32(v: i32) -> Self;
}

impl PackedElem for u8 {
    const PRECISION: Precision = Precision::U8;

    #[inline]
    fn to_i32(self) -> i32 {
        self as i32
    }

    #[inline]
    fn from_i32(v: i32) -> Self {
        debug_assert!(
            (0..=u8::MAX as i32).contains(&v),
            "integer image overflowed u8: {v}"
        );
        v as u8
    }
}

impl PackedElem for i8 {
    const PRECISION: Precision = Precision::I8;

    #[inline]
    fn to_i32(self) -> i32 {
        self as i32
    }

    #[inline]
    fn from_i32(v: i32) -> Self {
        debug_assert!(
            (i8::MIN as i32..=i8::MAX as i32).contains(&v),
            "integer image overflowed i8: {v}"
        );
        v as i8
    }
}

impl PackedElem for i32 {
    const PRECISION: Precision = Precision::I32;

    #[inline]
    fn to_i32(self) -> i32 {
        self
    }

    #[inline]
    fn from_i32(v: i32) -> Self {
        v
    }
}

// ---------------------------------------------------------------------------
// GEMM
// ---------------------------------------------------------------------------

/// C[M,N] = A[M,K] @ B[K,N] over f32.
pub fn matmul_f32(a: &TensorF, b: &TensorF) -> TensorF {
    assert_eq!(a.ndim(), 2);
    assert_eq!(b.ndim(), 2);
    let (m, k) = (a.shape()[0], a.shape()[1]);
    let (k2, n) = (b.shape()[0], b.shape()[1]);
    assert_eq!(k, k2, "matmul inner dims");
    let mut out = vec![0f32; m * n];
    matmul_f32_fused_into(a.data(), b.data(), m, k, n, &|_, v| v, &mut out);
    Tensor::from_vec(&[m, n], out)
}

/// f32 GEMM into a caller-provided buffer with a fused per-element
/// epilogue: `out[i*n + j] = epi(j, sum_k a[i,k]*b[k,j])`. The column
/// index `j` is the output-channel index for conv/linear layers, so
/// per-channel bias/BN/activation epilogues fuse here. ikj loop order,
/// unit-stride inner loops, zero-`a` rows skipped — identical arithmetic
/// (and identical f32 summation order) to [`matmul_f32`].
pub fn matmul_f32_fused_into<F>(
    ad: &[f32],
    bd: &[f32],
    m: usize,
    k: usize,
    n: usize,
    epi: &F,
    out: &mut [f32],
) where
    F: Fn(usize, f32) -> f32,
{
    assert!(ad.len() >= m * k && bd.len() >= k * n);
    let out = &mut out[..m * n];
    for i in 0..m {
        let crow = &mut out[i * n..(i + 1) * n];
        crow.fill(0.0);
        let arow = &ad[i * k..(i + 1) * k];
        for (kk, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let brow = &bd[kk * n..(kk + 1) * n];
            for j in 0..n {
                crow[j] += av * brow[j];
            }
        }
        for (j, v) in crow.iter_mut().enumerate() {
            *v = epi(j, *v);
        }
    }
}

/// Integer-image GEMM (Eq. 16): C = A @ B with i64 accumulation,
/// checked-narrowed to i32. Reference implementation (unfused paths and
/// tests); the plan hot path uses [`matmul_i32_fused_into`].
pub fn matmul_i32(a: &TensorI, b: &TensorI) -> TensorI {
    assert_eq!(a.ndim(), 2);
    assert_eq!(b.ndim(), 2);
    let (m, k) = (a.shape()[0], a.shape()[1]);
    let (k2, n) = (b.shape()[0], b.shape()[1]);
    assert_eq!(k, k2, "matmul inner dims");
    let mut out = vec![0i64; m * n];
    let ad = a.data();
    let bd = b.data();
    for i in 0..m {
        let crow = &mut out[i * n..(i + 1) * n];
        for kk in 0..k {
            let av = ad[i * k + kk] as i64;
            if av == 0 {
                continue;
            }
            let brow = &bd[kk * n..(kk + 1) * n];
            for j in 0..n {
                crow[j] += av * brow[j] as i64;
            }
        }
    }
    Tensor::from_vec(&[m, n], out.into_iter().map(narrow).collect())
}

/// Fast integer GEMM accumulating directly in i32 (engine hot path).
///
/// PRECONDITION: the caller proved — via the deployment pipeline's range
/// analysis (transform/deploy.rs) — that every partial sum fits i32.
/// Per-product safety holds whenever |a| < 2^15 and |b| < 2^16 (true for
/// all <=8-bit integer images). i32 accumulation lets LLVM autovectorize
/// the inner loop (the i64-widening variant cannot), ~4x on this testbed.
/// Large workloads additionally split across row-block worker threads
/// (bit-identical: integer addition order per output element is
/// unchanged; each row is computed by exactly one thread).
pub fn matmul_i32_fast(a: &TensorI, b: &TensorI) -> TensorI {
    assert_eq!(a.ndim(), 2);
    assert_eq!(b.ndim(), 2);
    let (m, k) = (a.shape()[0], a.shape()[1]);
    let (k2, n) = (b.shape()[0], b.shape()[1]);
    assert_eq!(k, k2, "matmul inner dims");
    let mut out = vec![0i32; m * n];
    matmul_i32_into(a.data(), b.data(), m, k, n, &mut out);
    Tensor::from_vec(&[m, n], out)
}

/// [`matmul_i32_fast`] into a caller-provided buffer (no allocation).
pub fn matmul_i32_into(
    ad: &[i32],
    bd: &[i32],
    m: usize,
    k: usize,
    n: usize,
    out: &mut [i32],
) {
    matmul_i32_fused_into(ad, bd, m, k, n, &|_, v| v, out)
}

/// Integer GEMM with a fused per-element epilogue applied as each output
/// element is finalized: `out[i*n + j] = epi(j, acc_i32)`. This is where
/// the plan layer's ConvInt/LinearInt → IntBn → RequantAct/ThreshAct
/// chains collapse: the epilogue widens the i32 accumulator to i64, runs
/// the per-channel integer epilogue (bias, Eq. 22 BN, Eq. 11 requant or
/// Eq. 19-20 thresholds) and narrows back — no intermediate tensors.
///
/// One-line delegate to the precision-generic [`matmul_q_fused_into`] at
/// its i32 instantiation (`i32` is a [`PackedElem`]): one threading
/// scaffold and one MAC loop serve every storage width, so the packed
/// and full-width paths cannot diverge. Same range-analysis precondition
/// as [`matmul_i32_fast`].
pub fn matmul_i32_fused_into<F>(
    ad: &[i32],
    bd: &[i32],
    m: usize,
    k: usize,
    n: usize,
    epi: &F,
    out: &mut [i32],
) where
    F: Fn(usize, i32) -> i32 + Sync,
{
    matmul_q_fused_into(ad, bd, m, k, n, epi, out)
}

/// Worker-thread count for an m*k*n MAC GEMM; 1 below the spawn-amortization
/// threshold.
fn gemm_threads(m: usize, k: usize, n: usize) -> usize {
    // ~0.5M MACs per thread: at the ~1 Gmac/s scalar baseline that is
    // ~0.5 ms of work against a ~20 µs spawn.
    const MACS_PER_THREAD: usize = 1 << 19;
    let work = m.saturating_mul(k).saturating_mul(n);
    if work < 2 * MACS_PER_THREAD {
        return 1;
    }
    let hw = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
    (work / MACS_PER_THREAD).min(hw).min(m).max(1)
}

/// Precision-generic integer GEMM with a fused per-element epilogue —
/// THE integer MAC kernel (all fused integer GEMM entry points delegate
/// here). `A` streams at its packed width (u8 im2col patches for
/// <=8-bit activations), `B` at its packed width (i8 weights for <=8-bit
/// grids) and the epilogue's result narrows *directly into the packed
/// output buffer* — no i32 intermediate tensor is ever materialized.
///
/// Arithmetic is storage-width-invariant: every element widens to i32,
/// products/sums use wrapping i32 accumulation in a fixed order (a
/// dedicated accumulator row, since `out` may be sub-word), zero-`a`
/// rows are skipped, and row blocks are distributed over scoped worker
/// threads when the MAC count amortizes the spawns — the per-element
/// arithmetic (and therefore the result) is identical at any thread
/// count and any element width. Same range-analysis precondition as
/// [`matmul_i32_fast`].
pub fn matmul_q_fused_into<A, B, O, F>(
    ad: &[A],
    bd: &[B],
    m: usize,
    k: usize,
    n: usize,
    epi: &F,
    out: &mut [O],
) where
    A: PackedElem,
    B: PackedElem,
    O: PackedElem,
    F: Fn(usize, i32) -> i32 + Sync,
{
    assert!(ad.len() >= m * k && bd.len() >= k * n);
    let out = &mut out[..m * n];
    let threads = gemm_threads(m, k, n);
    if threads <= 1 {
        matmul_q_block(ad, bd, 0, m, k, n, epi, out);
        return;
    }
    let rows_per = m.div_ceil(threads);
    std::thread::scope(|s| {
        let mut blocks: Vec<(usize, &mut [O])> = Vec::new();
        let mut rest = out;
        let mut row0 = 0usize;
        while row0 < m {
            let take = rows_per.min(m - row0);
            let (chunk, tail) = std::mem::take(&mut rest).split_at_mut(take * n);
            rest = tail;
            blocks.push((row0, chunk));
            row0 += take;
        }
        let mut blocks = blocks.into_iter();
        let (lo0, chunk0) = blocks.next().expect("at least one row block");
        for (lo, chunk) in blocks {
            let rows = chunk.len() / n;
            s.spawn(move || matmul_q_block(ad, bd, lo, lo + rows, k, n, epi, chunk));
        }
        matmul_q_block(ad, bd, lo0, lo0 + chunk0.len() / n, k, n, epi, chunk0);
    });
}

#[allow(clippy::too_many_arguments)]
fn matmul_q_block<A, B, O, F>(
    ad: &[A],
    bd: &[B],
    row_lo: usize,
    row_hi: usize,
    k: usize,
    n: usize,
    epi: &F,
    out: &mut [O],
) where
    A: PackedElem,
    B: PackedElem,
    O: PackedElem,
    F: Fn(usize, i32) -> i32,
{
    debug_assert_eq!(out.len(), (row_hi - row_lo) * n);
    // One accumulator row per block (the output buffer may be sub-word);
    // arena output buffers are reused, so every element is written fresh
    // from the accumulator.
    let mut acc = vec![0i32; n];
    for i in row_lo..row_hi {
        acc.fill(0);
        let arow = &ad[i * k..(i + 1) * k];
        for (kk, &av) in arow.iter().enumerate() {
            let a = av.to_i32();
            if a == 0 {
                continue;
            }
            let brow = &bd[kk * n..(kk + 1) * n];
            for j in 0..n {
                acc[j] = acc[j].wrapping_add(a.wrapping_mul(brow[j].to_i32()));
            }
        }
        let crow = &mut out[(i - row_lo) * n..(i - row_lo + 1) * n];
        for (j, o) in crow.iter_mut().enumerate() {
            *o = O::from_i32(epi(j, acc[j]));
        }
    }
}

// ---------------------------------------------------------------------------
// im2col (shared by both engines; layout matches python kernels/ref.py)
// ---------------------------------------------------------------------------

/// NCHW -> [B*OH*OW, C*KH*KW] patches; column index = c*(kh*kw) + ki*kw + kj.
///
/// Loop order (bi, ci, ki, kj) outer / (oy, ox) inner with the valid
/// output ranges computed once per (ki, kj): the inner loops are
/// branch-free induction (the #Perf pass measured ~2x over the naive
/// per-pixel bounds-checked form).
pub fn im2col<T: Copy + Default>(
    x: &Tensor<T>,
    kh: usize,
    kw: usize,
    stride: usize,
    pad: usize,
) -> (Tensor<T>, (usize, usize, usize)) {
    assert_eq!(x.ndim(), 4);
    let (b, c, h, w) = (x.shape()[0], x.shape()[1], x.shape()[2], x.shape()[3]);
    let oh = (h + 2 * pad - kh) / stride + 1;
    let ow = (w + 2 * pad - kw) / stride + 1;
    let cols = c * kh * kw;
    let mut out = vec![T::default(); b * oh * ow * cols];
    im2col_into(x.data(), b, c, h, w, kh, kw, stride, pad, &mut out);
    (Tensor::from_vec(&[b * oh * ow, cols], out), (b, oh, ow))
}

/// Arena-aware [`im2col`]: writes the patch matrix into a caller-provided
/// buffer. The used prefix is zero-filled first (arena buffers are reused
/// across requests and carry stale data where padding expects zeros).
/// Returns (rows = B*OH*OW, cols = C*KH*KW, OH, OW).
#[allow(clippy::too_many_arguments)]
pub fn im2col_into<T: Copy + Default>(
    xd: &[T],
    b: usize,
    c: usize,
    h: usize,
    w: usize,
    kh: usize,
    kw: usize,
    stride: usize,
    pad: usize,
    out: &mut [T],
) -> (usize, usize, usize, usize) {
    assert!(xd.len() >= b * c * h * w);
    let oh = (h + 2 * pad - kh) / stride + 1;
    let ow = (w + 2 * pad - kw) / stride + 1;
    let cols = c * kh * kw;
    let rows = b * oh * ow;
    let out = &mut out[..rows * cols];
    out.fill(T::default());
    // valid output index range for a kernel offset k: iy = o*stride+k-pad
    // must lie in [0, dim): o >= ceil((pad-k)/stride), o < ...
    let valid = |k: usize, dim: usize, omax: usize| -> (usize, usize) {
        let lo = pad.saturating_sub(k).div_ceil(stride);
        let hi_excl = if dim + pad > k {
            ((dim + pad - k - 1) / stride + 1).min(omax)
        } else {
            0
        };
        (lo.min(omax), hi_excl)
    };
    for bi in 0..b {
        for ci in 0..c {
            let xbase = (bi * c + ci) * h * w;
            for ki in 0..kh {
                let (oy_lo, oy_hi) = valid(ki, h, oh);
                for kj in 0..kw {
                    let (ox_lo, ox_hi) = valid(kj, w, ow);
                    let col = ci * kh * kw + ki * kw + kj;
                    for oy in oy_lo..oy_hi {
                        let iy = oy * stride + ki - pad;
                        let xrow = xbase + iy * w;
                        let orow = ((bi * oh + oy) * ow) * cols + col;
                        let mut ix = ox_lo * stride + kj - pad;
                        for ox in ox_lo..ox_hi {
                            out[orow + ox * cols] = xd[xrow + ix];
                            ix += stride;
                        }
                    }
                }
            }
        }
    }
    (rows, cols, oh, ow)
}

/// [B*OH*OW, C_out] rows -> NCHW.
pub fn rows_to_nchw<T: Copy + Default>(
    rows: &Tensor<T>,
    b: usize,
    oh: usize,
    ow: usize,
) -> Tensor<T> {
    assert_eq!(rows.ndim(), 2);
    assert_eq!(rows.shape()[0], b * oh * ow);
    let c = rows.shape()[1];
    let mut out = Tensor::zeros(&[b, c, oh, ow]);
    rows_to_nchw_into(rows.data(), b, c, oh, ow, out.data_mut());
    out
}

/// Scatter a [B*OH*OW, C] GEMM-row buffer into an NCHW buffer.
pub fn rows_to_nchw_into<T: Copy>(
    rows: &[T],
    b: usize,
    c: usize,
    oh: usize,
    ow: usize,
    out: &mut [T],
) {
    assert!(rows.len() >= b * oh * ow * c);
    let hw = oh * ow;
    let out = &mut out[..b * c * hw];
    for bi in 0..b {
        for pix in 0..hw {
            let row = (bi * hw + pix) * c;
            for ci in 0..c {
                out[(bi * c + ci) * hw + pix] = rows[row + ci];
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Convolution
// ---------------------------------------------------------------------------

/// f32 conv2d, weights OIHW, input NCHW, zero padding.
pub fn conv2d_f32(
    x: &TensorF,
    w: &TensorF,
    stride: usize,
    pad: usize,
) -> TensorF {
    let (cols, (b, oh, ow)) = im2col(x, w.shape()[2], w.shape()[3], stride, pad);
    let wt = oihw_to_wmat(w);
    rows_to_nchw(&matmul_f32(&cols, &wt), b, oh, ow)
}

/// OIHW float weights -> the [C_in*KH*KW, C_out] matrix layout matching
/// the im2col column order (the ID artifact layout).
pub fn oihw_to_wmat(w: &TensorF) -> TensorF {
    assert_eq!(w.ndim(), 4);
    let (co, ci, kh, kw) = (w.shape()[0], w.shape()[1], w.shape()[2], w.shape()[3]);
    let mut wmat = vec![0f32; ci * kh * kw * co];
    for o in 0..co {
        for i in 0..ci {
            for y in 0..kh {
                for z in 0..kw {
                    wmat[(i * kh * kw + y * kw + z) * co + o] =
                        w.data()[((o * ci + i) * kh + y) * kw + z];
                }
            }
        }
    }
    Tensor::from_vec(&[ci * kh * kw, co], wmat)
}

/// Integer conv2d with weights already in matrix layout
/// [C_in*KH*KW, C_out] (the ID artifact layout).
pub fn conv2d_i32_wmat(
    x: &TensorI,
    wmat: &TensorI,
    kh: usize,
    kw: usize,
    stride: usize,
    pad: usize,
) -> TensorI {
    let (cols, (b, oh, ow)) = im2col(x, kh, kw, stride, pad);
    rows_to_nchw(&matmul_i32(&cols, wmat), b, oh, ow)
}

/// Fast variant of [`conv2d_i32_wmat`] using the i32-accumulating GEMM.
/// Same range-analysis precondition as [`matmul_i32_fast`].
pub fn conv2d_i32_wmat_fast(
    x: &TensorI,
    wmat: &TensorI,
    kh: usize,
    kw: usize,
    stride: usize,
    pad: usize,
) -> TensorI {
    let (cols, (b, oh, ow)) = im2col(x, kh, kw, stride, pad);
    rows_to_nchw(&matmul_i32_fast(&cols, wmat), b, oh, ow)
}

// ---------------------------------------------------------------------------
// Pooling
// ---------------------------------------------------------------------------

/// Max pool, window = stride (sec. 3.6: untouched by quantization).
pub fn maxpool<T: Copy + Default + PartialOrd>(x: &Tensor<T>, k: usize) -> Tensor<T> {
    let (b, c, h, w) = (x.shape()[0], x.shape()[1], x.shape()[2], x.shape()[3]);
    assert!(h % k == 0 && w % k == 0);
    let mut out = Tensor::zeros(&[b, c, h / k, w / k]);
    maxpool_into(x.data(), b, c, h, w, k, out.data_mut());
    out
}

/// [`maxpool`] into a caller-provided buffer.
pub fn maxpool_into<T: Copy + PartialOrd>(
    xd: &[T],
    b: usize,
    c: usize,
    h: usize,
    w: usize,
    k: usize,
    out: &mut [T],
) {
    assert!(h % k == 0 && w % k == 0);
    let (oh, ow) = (h / k, w / k);
    let out = &mut out[..b * c * oh * ow];
    for bc in 0..b * c {
        let xbase = bc * h * w;
        let obase = bc * oh * ow;
        for oy in 0..oh {
            for ox in 0..ow {
                let mut best = xd[xbase + (oy * k) * w + ox * k];
                for dy in 0..k {
                    let xrow = xbase + (oy * k + dy) * w + ox * k;
                    for dx in 0..k {
                        let v = xd[xrow + dx];
                        if v > best {
                            best = v;
                        }
                    }
                }
                out[obase + oy * ow + ox] = best;
            }
        }
    }
}

/// f32 average pool, window = stride.
pub fn avgpool_f32(x: &TensorF, k: usize) -> TensorF {
    let (b, c, h, w) = (x.shape()[0], x.shape()[1], x.shape()[2], x.shape()[3]);
    assert!(h % k == 0 && w % k == 0);
    let mut out = Tensor::zeros(&[b, c, h / k, w / k]);
    avgpool_f32_into(x.data(), b, c, h, w, k, out.data_mut());
    out
}

/// [`avgpool_f32`] into a caller-provided buffer.
pub fn avgpool_f32_into(
    xd: &[f32],
    b: usize,
    c: usize,
    h: usize,
    w: usize,
    k: usize,
    out: &mut [f32],
) {
    assert!(h % k == 0 && w % k == 0);
    let (oh, ow) = (h / k, w / k);
    let inv = 1.0 / (k * k) as f32;
    let out = &mut out[..b * c * oh * ow];
    for bc in 0..b * c {
        let xbase = bc * h * w;
        let obase = bc * oh * ow;
        for oy in 0..oh {
            for ox in 0..ow {
                let mut acc = 0f32;
                for dy in 0..k {
                    let xrow = xbase + (oy * k + dy) * w + ox * k;
                    for dx in 0..k {
                        acc += xd[xrow + dx];
                    }
                }
                out[obase + oy * ow + ox] = acc * inv;
            }
        }
    }
}

/// Integer average pool (Eq. 25): (floor(2^d/(K*K)) * sum) >> d.
pub fn avgpool_i32(x: &TensorI, k: usize, d: u32) -> TensorI {
    let (b, c, h, w) = (x.shape()[0], x.shape()[1], x.shape()[2], x.shape()[3]);
    assert!(h % k == 0 && w % k == 0);
    let mut out = Tensor::zeros(&[b, c, h / k, w / k]);
    avgpool_i32_into(x.data(), b, c, h, w, k, d, out.data_mut());
    out
}

/// [`avgpool_i32`] into a caller-provided buffer — the i32 instantiation
/// of [`avgpool_q_into`] (one copy of the Eq. 25 scaling).
#[allow(clippy::too_many_arguments)]
pub fn avgpool_i32_into(
    xd: &[i32],
    b: usize,
    c: usize,
    h: usize,
    w: usize,
    k: usize,
    d: u32,
    out: &mut [i32],
) {
    avgpool_q_into(xd, b, c, h, w, k, d, out)
}

/// Precision-generic twin of [`avgpool_i32_into`] (Eq. 25): widens each
/// packed element to the i64 accumulator, applies the identical
/// `(floor(2^d/(K*K)) * sum) >> d` scaling, and narrows the result back
/// into the packed output. Average pooling never widens the value range,
/// so the input's precision is always a sound output assignment.
#[allow(clippy::too_many_arguments)]
pub fn avgpool_q_into<T: PackedElem>(
    xd: &[T],
    b: usize,
    c: usize,
    h: usize,
    w: usize,
    k: usize,
    d: u32,
    out: &mut [T],
) {
    assert!(h % k == 0 && w % k == 0);
    let (oh, ow) = (h / k, w / k);
    let m = (1i64 << d) / (k * k) as i64;
    let out = &mut out[..b * c * oh * ow];
    for bc in 0..b * c {
        let xbase = bc * h * w;
        let obase = bc * oh * ow;
        for oy in 0..oh {
            for ox in 0..ow {
                let mut acc = 0i64;
                for dy in 0..k {
                    let xrow = xbase + (oy * k + dy) * w + ox * k;
                    for dx in 0..k {
                        acc += xd[xrow + dx].to_i32() as i64;
                    }
                }
                out[obase + oy * ow + ox] = T::from_i32(narrow((acc * m) >> d));
            }
        }
    }
}

/// Global mean over H,W: [B,C,H,W] f32 -> [B,C].
pub fn global_mean_f32(x: &TensorF) -> TensorF {
    let (b, c, h, w) = (x.shape()[0], x.shape()[1], x.shape()[2], x.shape()[3]);
    let mut out = Tensor::zeros(&[b, c]);
    global_mean_f32_into(x.data(), b, c, h, w, out.data_mut());
    out
}

/// [`global_mean_f32`] into a caller-provided buffer.
pub fn global_mean_f32_into(
    xd: &[f32],
    b: usize,
    c: usize,
    h: usize,
    w: usize,
    out: &mut [f32],
) {
    let inv = 1.0 / (h * w) as f32;
    let hw = h * w;
    let out = &mut out[..b * c];
    for (bc, o) in out.iter_mut().enumerate() {
        let mut acc = 0f32;
        for v in &xd[bc * hw..(bc + 1) * hw] {
            acc += *v;
        }
        *o = acc * inv;
    }
}

// ---------------------------------------------------------------------------
// Sub-byte (bit-packed) kernels (DESIGN.md §Sub-byte packing)
// ---------------------------------------------------------------------------
//
// Few-bit integer images (U1/U2/U4/I4) are stored 2-8 elements per byte,
// LSB-first (tensor/mod.rs::get_packed/set_packed). The kernels below are
// bit-exact twins of the byte-width kernels above: every element widens
// to the same i32 value the wide interpreter sees, and every accumulation
// uses the same wrapping-i32 order, so fused sub-byte execution is
// bit-identical to the full-width path node for node.

/// Distribute the rows of an `m x n` row-major output over scoped worker
/// threads — the same row-block split (and therefore the same per-element
/// arithmetic) as [`matmul_q_fused_into`]. `body(row_lo, row_hi, chunk)`
/// must be a pure function of its row range; the first block runs on the
/// calling thread.
fn run_row_blocks<O, F>(m: usize, n: usize, threads: usize, out: &mut [O], body: F)
where
    O: Send,
    F: Fn(usize, usize, &mut [O]) + Sync,
{
    let out = &mut out[..m * n];
    if threads <= 1 {
        body(0, m, out);
        return;
    }
    let rows_per = m.div_ceil(threads);
    std::thread::scope(|s| {
        let body = &body;
        let mut rest = out;
        let mut first: Option<(usize, &mut [O])> = None;
        let mut row0 = 0usize;
        while row0 < m {
            let take = rows_per.min(m - row0);
            let (chunk, tail) = std::mem::take(&mut rest).split_at_mut(take * n);
            rest = tail;
            if first.is_none() {
                first = Some((row0, chunk));
            } else {
                s.spawn(move || body(row0, row0 + take, chunk));
            }
            row0 += take;
        }
        let (lo, chunk) = first.expect("at least one row block");
        body(lo, lo + chunk.len() / n, chunk);
    });
}

/// Weight matrix [K, N] decomposed into two's-complement bit-planes for
/// the bit-serial GEMM: plane `p` of column `j` is a K-bit bitmap packed
/// into `ceil(K/64)` u64 words at `planes[(p*n + j)*words ..]`. `bits` is
/// the minimal signed width covering the actual weight range, so a
/// ternary grid costs 2 planes and a binary [-1, 0] grid costs 1. The
/// value decomposition is
///
///   w = -2^(B-1) * b_{B-1} + sum_{p < B-1} 2^p * b_p
///
/// (the top plane is the sign plane).
pub struct BitPlanes {
    k: usize,
    n: usize,
    bits: u32,
    words: usize,
    planes: Vec<u64>,
}

impl BitPlanes {
    /// Decompose a [K, N] weight matrix; `None` when the weights do not
    /// fit an 8-bit signed grid (bit-serial would cost more planes than
    /// the MAC kernel is worth).
    pub fn build(wq: &TensorI) -> Option<BitPlanes> {
        assert_eq!(wq.ndim(), 2);
        let (k, n) = (wq.shape()[0], wq.shape()[1]);
        let d = wq.data();
        let (mut lo, mut hi) = (0i64, 0i64);
        for &v in d {
            lo = lo.min(v as i64);
            hi = hi.max(v as i64);
        }
        let bits = (1u32..=8).find(|&b| {
            lo >= -(1i64 << (b - 1)) && hi <= (1i64 << (b - 1)) - 1
        })?;
        let words = k.div_ceil(64);
        let mask = (1u32 << bits) - 1;
        let mut planes = vec![0u64; bits as usize * n * words];
        for row in 0..k {
            let (wi, bit) = (row / 64, 1u64 << (row % 64));
            for col in 0..n {
                let raw = (d[row * n + col] as u32) & mask;
                for p in 0..bits {
                    if (raw >> p) & 1 != 0 {
                        planes[(p as usize * n + col) * words + wi] |= bit;
                    }
                }
            }
        }
        Some(BitPlanes { k, n, bits, words, planes })
    }

    pub fn k(&self) -> usize {
        self.k
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// Planes actually stored (the minimal signed width of the grid).
    pub fn bits(&self) -> u32 {
        self.bits
    }

    /// Bitmap storage footprint in bytes.
    pub fn bytes(&self) -> usize {
        self.planes.len() * 8
    }
}

/// Bit-serial AND+popcount GEMM over bit-packed unsigned activations and
/// [`BitPlanes`] weights, with the same fused epilogue contract as
/// [`matmul_q_fused_into`]. For Q-bit activations and B-bit weights each
/// output element costs Q*B AND+popcount passes over K-bit bitmaps
/// instead of K multiply-accumulates:
///
///   dot = sum_{p, q} c_p * 2^q * popcount(a_plane_q AND w_plane_p)
///
/// with c_p the two's-complement plane coefficient. Every term and every
/// sum uses wrapping i32 arithmetic, which is exact mod 2^32 — i.e.
/// bit-identical to the wide interpreter's wrapping-i32 MAC loop, even on
/// graphs whose accumulators exceed i32 (both paths agree mod 2^32).
pub fn matmul_bitserial_fused_into<O, F>(
    a_packed: &[u8],
    a_prec: Precision,
    m: usize,
    planes: &BitPlanes,
    epi: &F,
    out: &mut [O],
) where
    O: PackedElem,
    F: Fn(usize, i32) -> i32 + Sync,
{
    assert!(
        matches!(a_prec, Precision::U1 | Precision::U2 | Precision::U4),
        "bit-serial GEMM needs an unsigned sub-byte activation grid, got {}",
        a_prec.name()
    );
    let (k, n, words) = (planes.k, planes.n, planes.words);
    let abits = a_prec.bits();
    assert!(a_packed.len() >= packed_byte_len(m * k, abits));
    let threads = gemm_threads(m, k, n);
    run_row_blocks(m, n, threads, out, |row_lo, row_hi, chunk| {
        let mut aplanes = vec![0u64; abits as usize * words];
        let mut acc = vec![0i32; n];
        for i in row_lo..row_hi {
            aplanes.fill(0);
            let base = i * k;
            // Branchless scatter: a data-dependent skip on random few-bit
            // values mispredicts ~half the time, which costs far more
            // than unconditionally OR-ing zero bits.
            for e in 0..k {
                let v = get_packed_raw(a_packed, base + e, abits);
                let (wi, sh) = (e / 64, e % 64);
                for q in 0..abits {
                    aplanes[q as usize * words + wi] |= (((v >> q) & 1) as u64) << sh;
                }
            }
            for (j, a) in acc.iter_mut().enumerate() {
                let mut sum = 0i32;
                for p in 0..planes.bits {
                    let wplane = &planes.planes[(p as usize * n + j) * words..][..words];
                    let c = if p + 1 == planes.bits { -(1i32 << p) } else { 1i32 << p };
                    for q in 0..abits {
                        let ap = &aplanes[q as usize * words..][..words];
                        let mut pc = 0u32;
                        for (aw, ww) in ap.iter().zip(wplane) {
                            pc += (aw & ww).count_ones();
                        }
                        sum = sum.wrapping_add((c << q).wrapping_mul(pc as i32));
                    }
                }
                *a = sum;
            }
            let crow = &mut chunk[(i - row_lo) * n..(i - row_lo + 1) * n];
            for (j, o) in crow.iter_mut().enumerate() {
                *o = O::from_i32(epi(j, acc[j]));
            }
        }
    });
}

/// Row-block GEMM over bit-packed sub-byte activations: each row block
/// unpacks its activation rows into an i8 scratch row (every sub-byte
/// value fits i8, sign-extended for I4) and runs the identical
/// wrapping-i32 MAC loop as [`matmul_q_fused_into`] — the unpack feeds
/// the autovectorized kernel unit-stride data, so U4/I4 grids trade an
/// O(K) unpack for 2x less GEMM input traffic. Bit-identical to the wide
/// path by the same argument as the byte kernels.
#[allow(clippy::too_many_arguments)]
pub fn matmul_subbyte_fused_into<B, O, F>(
    a_packed: &[u8],
    a_prec: Precision,
    bd: &[B],
    m: usize,
    k: usize,
    n: usize,
    epi: &F,
    out: &mut [O],
) where
    B: PackedElem,
    O: PackedElem,
    F: Fn(usize, i32) -> i32 + Sync,
{
    assert!(a_prec.is_sub_byte(), "got {}", a_prec.name());
    assert!(a_packed.len() >= packed_byte_len(m * k, a_prec.bits()));
    assert!(bd.len() >= k * n);
    let threads = gemm_threads(m, k, n);
    run_row_blocks(m, n, threads, out, |row_lo, row_hi, chunk| {
        let mut arow = vec![0i8; k];
        let mut acc = vec![0i32; n];
        for i in row_lo..row_hi {
            for (e, a) in arow.iter_mut().enumerate() {
                *a = get_packed(a_packed, i * k + e, a_prec) as i8;
            }
            acc.fill(0);
            for (kk, &av) in arow.iter().enumerate() {
                let a = av as i32;
                if a == 0 {
                    continue;
                }
                let brow = &bd[kk * n..(kk + 1) * n];
                for j in 0..n {
                    acc[j] = acc[j].wrapping_add(a.wrapping_mul(brow[j].to_i32()));
                }
            }
            let crow = &mut chunk[(i - row_lo) * n..(i - row_lo + 1) * n];
            for (j, o) in crow.iter_mut().enumerate() {
                *o = O::from_i32(epi(j, acc[j]));
            }
        }
    });
}

/// Bit-packed twin of [`im2col_into`]: reads and writes sub-byte packed
/// payloads element-for-element in the identical patch layout. The used
/// prefix (including trailing pad bits) is zero-filled first, so padded
/// halo regions and canonical-payload invariants both hold on reused
/// arena buffers.
#[allow(clippy::too_many_arguments)]
pub fn im2col_packed_into(
    xd: &[u8],
    p: Precision,
    b: usize,
    c: usize,
    h: usize,
    w: usize,
    kh: usize,
    kw: usize,
    stride: usize,
    pad: usize,
    out: &mut [u8],
) -> (usize, usize, usize, usize) {
    let bits = p.bits();
    assert!(p.is_sub_byte());
    assert!(xd.len() >= packed_byte_len(b * c * h * w, bits));
    let oh = (h + 2 * pad - kh) / stride + 1;
    let ow = (w + 2 * pad - kw) / stride + 1;
    let cols = c * kh * kw;
    let rows = b * oh * ow;
    out[..packed_byte_len(rows * cols, bits)].fill(0);
    let valid = |k: usize, dim: usize, omax: usize| -> (usize, usize) {
        let lo = pad.saturating_sub(k).div_ceil(stride);
        let hi_excl = if dim + pad > k {
            ((dim + pad - k - 1) / stride + 1).min(omax)
        } else {
            0
        };
        (lo.min(omax), hi_excl)
    };
    for bi in 0..b {
        for ci in 0..c {
            let xbase = (bi * c + ci) * h * w;
            for ki in 0..kh {
                let (oy_lo, oy_hi) = valid(ki, h, oh);
                for kj in 0..kw {
                    let (ox_lo, ox_hi) = valid(kj, w, ow);
                    let col = ci * kh * kw + ki * kw + kj;
                    for oy in oy_lo..oy_hi {
                        let iy = oy * stride + ki - pad;
                        let xrow = xbase + iy * w;
                        let orow = ((bi * oh + oy) * ow) * cols + col;
                        let mut ix = ox_lo * stride + kj - pad;
                        for ox in ox_lo..ox_hi {
                            let v = get_packed(xd, xrow + ix, p);
                            set_packed(out, orow + ox * cols, p, v);
                            ix += stride;
                        }
                    }
                }
            }
        }
    }
    (rows, cols, oh, ow)
}

/// Bit-packed twin of [`rows_to_nchw_into`].
pub fn rows_to_nchw_packed_into(
    rows: &[u8],
    p: Precision,
    b: usize,
    c: usize,
    oh: usize,
    ow: usize,
    out: &mut [u8],
) {
    let bits = p.bits();
    assert!(rows.len() >= packed_byte_len(b * oh * ow * c, bits));
    let hw = oh * ow;
    out[..packed_byte_len(b * c * hw, bits)].fill(0);
    for bi in 0..b {
        for pix in 0..hw {
            let row = (bi * hw + pix) * c;
            for ci in 0..c {
                let v = get_packed(rows, row + ci, p);
                set_packed(out, (bi * c + ci) * hw + pix, p, v);
            }
        }
    }
}

/// Bit-packed twin of [`maxpool_into`]: compares the widened (sign-
/// extended) values, so signed grids order correctly.
#[allow(clippy::too_many_arguments)]
pub fn maxpool_packed_into(
    xd: &[u8],
    p: Precision,
    b: usize,
    c: usize,
    h: usize,
    w: usize,
    k: usize,
    out: &mut [u8],
) {
    assert!(h % k == 0 && w % k == 0);
    let (oh, ow) = (h / k, w / k);
    out[..packed_byte_len(b * c * oh * ow, p.bits())].fill(0);
    for bc in 0..b * c {
        let xbase = bc * h * w;
        let obase = bc * oh * ow;
        for oy in 0..oh {
            for ox in 0..ow {
                let mut best = get_packed(xd, xbase + (oy * k) * w + ox * k, p);
                for dy in 0..k {
                    let xrow = xbase + (oy * k + dy) * w + ox * k;
                    for dx in 0..k {
                        let v = get_packed(xd, xrow + dx, p);
                        if v > best {
                            best = v;
                        }
                    }
                }
                set_packed(out, obase + oy * ow + ox, p, best);
            }
        }
    }
}

/// Bit-packed twin of [`avgpool_q_into`] (Eq. 25): identical i64
/// accumulation and `(floor(2^d/(K*K)) * sum) >> d` scaling; the result
/// never widens past the input grid, so packing back is always sound.
#[allow(clippy::too_many_arguments)]
pub fn avgpool_packed_into(
    xd: &[u8],
    p: Precision,
    b: usize,
    c: usize,
    h: usize,
    w: usize,
    k: usize,
    d: u32,
    out: &mut [u8],
) {
    assert!(h % k == 0 && w % k == 0);
    let (oh, ow) = (h / k, w / k);
    let m = (1i64 << d) / (k * k) as i64;
    out[..packed_byte_len(b * c * oh * ow, p.bits())].fill(0);
    for bc in 0..b * c {
        let xbase = bc * h * w;
        let obase = bc * oh * ow;
        for oy in 0..oh {
            for ox in 0..ow {
                let mut acc = 0i64;
                for dy in 0..k {
                    let xrow = xbase + (oy * k + dy) * w + ox * k;
                    for dx in 0..k {
                        acc += get_packed(xd, xrow + dx, p) as i64;
                    }
                }
                set_packed(out, obase + oy * ow + ox, p, narrow((acc * m) >> d));
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Backward kernels (native training; DESIGN.md §Training)
// ---------------------------------------------------------------------------
//
// Adjoints of the float forward kernels above. Threaded GEMMs reuse the
// same row-block split as the forward path (`run_row_blocks` /
// `gemm_threads`): each output row is computed by exactly one thread with
// a fixed per-element accumulation order, so gradients are identical at
// any thread count. The `_acc_into` kernels *accumulate* (`+=`) into the
// output buffer — the backward plan zeroes a gradient slot once and lets
// every consumer's contribution add in place.

/// out[K,N] = Aᵀ B with A [M,K], B [M,N]: out[k,j] = Σ_i a[i,k]·b[i,j].
/// The weight-gradient GEMM (linear gW = xᵀ·dY; conv gWmat = colsᵀ·dRows).
/// Overwrites `out`.
pub fn matmul_f32_atb_into(
    ad: &[f32],
    bd: &[f32],
    m: usize,
    k: usize,
    n: usize,
    out: &mut [f32],
) {
    assert!(ad.len() >= m * k && bd.len() >= m * n);
    let out = &mut out[..k * n];
    let threads = gemm_threads(k, m, n);
    run_row_blocks(k, n, threads, out, |lo, hi, chunk| {
        chunk.fill(0.0);
        for i in 0..m {
            let arow = &ad[i * k..(i + 1) * k];
            let brow = &bd[i * n..(i + 1) * n];
            for kk in lo..hi {
                let av = arow[kk];
                if av == 0.0 {
                    continue;
                }
                let crow = &mut chunk[(kk - lo) * n..(kk - lo + 1) * n];
                for (cv, &bv) in crow.iter_mut().zip(brow) {
                    *cv += av * bv;
                }
            }
        }
    });
}

/// out[M,N] += A Bᵀ with A [M,K], B [N,K]: out[i,j] += Σ_k a[i,k]·b[j,k].
/// The input-gradient GEMM (linear dX += dY·wᵀ; conv gCols = dRows·wmatᵀ).
pub fn matmul_f32_abt_acc_into(
    ad: &[f32],
    bd: &[f32],
    m: usize,
    k: usize,
    n: usize,
    out: &mut [f32],
) {
    assert!(ad.len() >= m * k && bd.len() >= n * k);
    let out = &mut out[..m * n];
    let threads = gemm_threads(m, k, n);
    run_row_blocks(m, n, threads, out, |lo, hi, chunk| {
        for i in lo..hi {
            let arow = &ad[i * k..(i + 1) * k];
            let crow = &mut chunk[(i - lo) * n..(i - lo + 1) * n];
            for (j, cv) in crow.iter_mut().enumerate() {
                let brow = &bd[j * k..(j + 1) * k];
                let mut acc = 0f32;
                for (&av, &bv) in arow.iter().zip(brow) {
                    acc += av * bv;
                }
                *cv += acc;
            }
        }
    });
}

/// Gather an NCHW tensor into the [B*OH*OW, C] GEMM-row layout — the
/// exact inverse permutation of [`rows_to_nchw_into`].
pub fn nchw_to_rows_into<T: Copy>(
    xd: &[T],
    b: usize,
    c: usize,
    oh: usize,
    ow: usize,
    rows: &mut [T],
) {
    let hw = oh * ow;
    assert!(xd.len() >= b * c * hw);
    let rows = &mut rows[..b * hw * c];
    for bi in 0..b {
        for pix in 0..hw {
            let row = (bi * hw + pix) * c;
            for ci in 0..c {
                rows[row + ci] = xd[(bi * c + ci) * hw + pix];
            }
        }
    }
}

/// Scatter-add a [B*OH*OW, C*KH*KW] patch-gradient matrix back onto the
/// NCHW input gradient — the adjoint of [`im2col_into`] (contributions to
/// padding locations are dropped). Accumulates into `gx`; iteration
/// mirrors im2col's precomputed valid ranges so the scatter needs no
/// bounds checks.
#[allow(clippy::too_many_arguments)]
pub fn col2im_acc_into(
    gcols: &[f32],
    b: usize,
    c: usize,
    h: usize,
    w: usize,
    kh: usize,
    kw: usize,
    stride: usize,
    pad: usize,
    gx: &mut [f32],
) {
    let oh = (h + 2 * pad - kh) / stride + 1;
    let ow = (w + 2 * pad - kw) / stride + 1;
    let cols = c * kh * kw;
    assert!(gcols.len() >= b * oh * ow * cols);
    let gx = &mut gx[..b * c * h * w];
    let valid = |kq: usize, dim: usize, omax: usize| -> (usize, usize) {
        let lo = pad.saturating_sub(kq).div_ceil(stride);
        let hi_excl = if dim + pad > kq {
            ((dim + pad - kq - 1) / stride + 1).min(omax)
        } else {
            0
        };
        (lo.min(omax), hi_excl)
    };
    for bi in 0..b {
        for ci in 0..c {
            let xbase = (bi * c + ci) * h * w;
            for ki in 0..kh {
                let (oy_lo, oy_hi) = valid(ki, h, oh);
                for kj in 0..kw {
                    let (ox_lo, ox_hi) = valid(kj, w, ow);
                    let col = ci * kh * kw + ki * kw + kj;
                    for oy in oy_lo..oy_hi {
                        let iy = oy * stride + ki - pad;
                        let xrow = xbase + iy * w;
                        let grow = ((bi * oh + oy) * ow) * cols + col;
                        let mut ix = ox_lo * stride + kj - pad;
                        for ox in ox_lo..ox_hi {
                            gx[xrow + ix] += gcols[grow + ox * cols];
                            ix += stride;
                        }
                    }
                }
            }
        }
    }
}

/// Route a pooled gradient back to each window's argmax — the adjoint of
/// [`maxpool_into`]. The first maximum wins on ties, matching the forward
/// kernel's strict `>` scan. Accumulates into `gx`.
#[allow(clippy::too_many_arguments)]
pub fn maxpool_backward_acc_into(
    xd: &[f32],
    gy: &[f32],
    b: usize,
    c: usize,
    h: usize,
    w: usize,
    k: usize,
    gx: &mut [f32],
) {
    assert!(h % k == 0 && w % k == 0);
    let (oh, ow) = (h / k, w / k);
    assert!(xd.len() >= b * c * h * w && gy.len() >= b * c * oh * ow);
    let gx = &mut gx[..b * c * h * w];
    for bc in 0..b * c {
        let xbase = bc * h * w;
        let obase = bc * oh * ow;
        for oy in 0..oh {
            for ox in 0..ow {
                let mut arg = xbase + (oy * k) * w + ox * k;
                let mut best = xd[arg];
                for dy in 0..k {
                    let xrow = xbase + (oy * k + dy) * w + ox * k;
                    for dx in 0..k {
                        let v = xd[xrow + dx];
                        if v > best {
                            best = v;
                            arg = xrow + dx;
                        }
                    }
                }
                gx[arg] += gy[obase + oy * ow + ox];
            }
        }
    }
}

/// Adjoint of [`avgpool_f32_into`]: spread each pooled gradient uniformly
/// over its K×K window. Accumulates into `gx`.
pub fn avgpool_backward_acc_into(
    gy: &[f32],
    b: usize,
    c: usize,
    h: usize,
    w: usize,
    k: usize,
    gx: &mut [f32],
) {
    assert!(h % k == 0 && w % k == 0);
    let (oh, ow) = (h / k, w / k);
    assert!(gy.len() >= b * c * oh * ow);
    let inv = 1.0 / (k * k) as f32;
    let gx = &mut gx[..b * c * h * w];
    for bc in 0..b * c {
        let xbase = bc * h * w;
        let obase = bc * oh * ow;
        for oy in 0..oh {
            for ox in 0..ow {
                let g = gy[obase + oy * ow + ox] * inv;
                for dy in 0..k {
                    let xrow = xbase + (oy * k + dy) * w + ox * k;
                    for gv in &mut gx[xrow..xrow + k] {
                        *gv += g;
                    }
                }
            }
        }
    }
}

/// Adjoint of [`global_mean_f32_into`]: gx[b,c,:,:] += gy[b,c] / (H·W).
pub fn global_mean_backward_acc_into(
    gy: &[f32],
    b: usize,
    c: usize,
    h: usize,
    w: usize,
    gx: &mut [f32],
) {
    let hw = h * w;
    assert!(gy.len() >= b * c);
    let inv = 1.0 / hw as f32;
    let gx = &mut gx[..b * c * hw];
    for bc in 0..b * c {
        let g = gy[bc] * inv;
        for gv in &mut gx[bc * hw..(bc + 1) * hw] {
            *gv += g;
        }
    }
}

/// Inverse layout transform of [`oihw_to_wmat`] for weight gradients: a
/// [C_in*KH*KW, C_out] gradient matrix back to OIHW order.
pub fn wmat_to_oihw(gw: &[f32], co: usize, ci: usize, kh: usize, kw: usize) -> Vec<f32> {
    assert!(gw.len() >= ci * kh * kw * co);
    let mut out = vec![0f32; co * ci * kh * kw];
    for o in 0..co {
        for i in 0..ci {
            for y in 0..kh {
                for z in 0..kw {
                    out[((o * ci + i) * kh + y) * kw + z] =
                        gw[(i * kh * kw + y * kw + z) * co + o];
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_i(rng: &mut Rng, shape: &[usize], lo: i64, hi: i64) -> TensorI {
        let n: usize = shape.iter().product();
        Tensor::from_vec(shape, (0..n).map(|_| rng.int(lo, hi) as i32).collect())
    }

    fn rand_f(rng: &mut Rng, shape: &[usize]) -> TensorF {
        let n: usize = shape.iter().product();
        Tensor::from_vec(shape, (0..n).map(|_| rng.normal(0.0, 1.0) as f32).collect())
    }

    #[test]
    fn matmul_fast_matches_checked() {
        let mut rng = Rng::new(11);
        for _ in 0..20 {
            let m = rng.int(1, 40) as usize;
            let k = rng.int(1, 60) as usize;
            let n = rng.int(1, 40) as usize;
            let a = rand_i(&mut rng, &[m, k], -255, 256);
            let b = rand_i(&mut rng, &[k, n], -128, 128);
            assert_eq!(matmul_i32(&a, &b), matmul_i32_fast(&a, &b));
        }
    }

    #[test]
    fn matmul_threaded_path_matches_checked() {
        // Big enough to cross the row-block threading threshold.
        let mut rng = Rng::new(12);
        let (m, k, n) = (160, 96, 80);
        let a = rand_i(&mut rng, &[m, k], -200, 200);
        let b = rand_i(&mut rng, &[k, n], -100, 100);
        assert!(gemm_threads(m, k, n) >= 1); // smoke the picker
        assert_eq!(matmul_i32(&a, &b), matmul_i32_fast(&a, &b));
    }

    #[test]
    fn matmul_into_reuses_dirty_buffers() {
        let mut rng = Rng::new(13);
        let a = rand_i(&mut rng, &[7, 9], -50, 50);
        let b = rand_i(&mut rng, &[9, 5], -50, 50);
        let want = matmul_i32(&a, &b);
        let mut buf = vec![i32::MAX; 7 * 5 + 3]; // stale + oversized
        matmul_i32_into(a.data(), b.data(), 7, 9, 5, &mut buf);
        assert_eq!(&buf[..35], want.data());
    }

    #[test]
    fn matmul_fused_epilogue_applies_per_column() {
        let mut rng = Rng::new(14);
        let a = rand_i(&mut rng, &[6, 8], -40, 40);
        let b = rand_i(&mut rng, &[8, 4], -40, 40);
        let plain = matmul_i32(&a, &b);
        let mut out = vec![0i32; 6 * 4];
        matmul_i32_fused_into(
            a.data(),
            b.data(),
            6,
            8,
            4,
            &|j, v| narrow(v as i64 * 2 + j as i64),
            &mut out,
        );
        for i in 0..6 {
            for j in 0..4 {
                assert_eq!(out[i * 4 + j], plain.at2(i, j) * 2 + j as i32);
            }
        }
    }

    #[test]
    fn packed_matmul_matches_i32_reference() {
        // u8 x i8 -> i32 accumulate must equal the i32 x i32 reference on
        // the same values, at sizes below and above the threading cutoff.
        let mut rng = Rng::new(21);
        for (m, k, n) in [(5usize, 7usize, 3usize), (160, 96, 80)] {
            let a32 = rand_i(&mut rng, &[m, k], 0, 256);
            let b32 = rand_i(&mut rng, &[k, n], -128, 128);
            let want = matmul_i32(&a32, &b32);
            let a8: Vec<u8> = a32.data().iter().map(|v| *v as u8).collect();
            let b8: Vec<i8> = b32.data().iter().map(|v| *v as i8).collect();
            let mut out = vec![0i32; m * n];
            matmul_q_fused_into(&a8, &b8, m, k, n, &|_, v| v, &mut out);
            assert_eq!(&out[..], want.data(), "{m}x{k}x{n}");
        }
    }

    #[test]
    fn packed_matmul_narrows_into_packed_output() {
        // Epilogue clamps into [0, 255]; the GEMM writes u8 directly.
        let mut rng = Rng::new(22);
        let (m, k, n) = (6usize, 9usize, 4usize);
        let a32 = rand_i(&mut rng, &[m, k], 0, 256);
        let b32 = rand_i(&mut rng, &[k, n], -128, 128);
        let epi = |_: usize, v: i32| (v as i64).clamp(0, 255) as i32;
        let mut want = vec![0i32; m * n];
        matmul_i32_fused_into(a32.data(), b32.data(), m, k, n, &epi, &mut want);
        let a8: Vec<u8> = a32.data().iter().map(|v| *v as u8).collect();
        let b8: Vec<i8> = b32.data().iter().map(|v| *v as i8).collect();
        let mut out = vec![0u8; m * n];
        matmul_q_fused_into(&a8, &b8, m, k, n, &epi, &mut out);
        for (o, w) in out.iter().zip(&want) {
            assert_eq!(*o as i32, *w);
        }
    }

    #[test]
    fn packed_avgpool_matches_i32_reference() {
        let mut rng = Rng::new(23);
        let x = rand_i(&mut rng, &[2, 3, 4, 4], 0, 256);
        let want = avgpool_i32(&x, 2, 12);
        let x8: Vec<u8> = x.data().iter().map(|v| *v as u8).collect();
        let mut out = vec![0u8; 2 * 3 * 2 * 2];
        avgpool_q_into(&x8, 2, 3, 4, 4, 2, 12, &mut out);
        for (o, w) in out.iter().zip(want.data()) {
            assert_eq!(*o as i32, *w);
        }
    }

    #[test]
    fn packed_im2col_matches_i32_layout() {
        // im2col is already generic; pin the u8 instantiation against the
        // i32 one (same values, zero padding included).
        let x = Tensor::from_vec(&[1, 1, 2, 2], vec![1, 2, 3, 4]);
        let (want, _) = im2col(&x, 3, 3, 1, 1);
        let x8 = Tensor::from_vec(&[1, 1, 2, 2], vec![1u8, 2, 3, 4]);
        let mut out = vec![9u8; 4 * 9];
        im2col_into(x8.data(), 1, 1, 2, 2, 3, 3, 1, 1, &mut out);
        for (o, w) in out.iter().zip(want.data()) {
            assert_eq!(*o as i32, *w);
        }
    }

    fn pack_vals(vals: &[i32], p: Precision) -> Vec<u8> {
        let mut out = vec![0u8; packed_byte_len(vals.len(), p.bits())];
        for (i, &v) in vals.iter().enumerate() {
            set_packed(&mut out, i, p, v);
        }
        out
    }

    #[test]
    fn bit_planes_use_the_minimal_signed_width() {
        let w = Tensor::from_vec(&[2, 2], vec![-1, 0, -1, 0]);
        assert_eq!(BitPlanes::build(&w).unwrap().bits(), 1);
        let w = Tensor::from_vec(&[2, 2], vec![-1, 0, 1, 0]);
        assert_eq!(BitPlanes::build(&w).unwrap().bits(), 2);
        let w = Tensor::from_vec(&[2, 2], vec![-8, 7, 0, 1]);
        assert_eq!(BitPlanes::build(&w).unwrap().bits(), 4);
        let w = Tensor::from_vec(&[2, 2], vec![-128, 127, 0, 1]);
        assert_eq!(BitPlanes::build(&w).unwrap().bits(), 8);
        let w = Tensor::from_vec(&[2, 2], vec![300, 0, 0, 0]);
        assert!(BitPlanes::build(&w).is_none());
    }

    #[test]
    fn bitserial_matmul_matches_i32_reference() {
        // Q-bit activations x few-bit signed weights, at sizes below and
        // above the threading cutoff and with K spanning >1 bitmap word.
        let mut rng = Rng::new(31);
        let grids = [
            (Precision::U1, -1i64, 0i64),
            (Precision::U1, -2, 1),
            (Precision::U2, -1, 1),
            (Precision::U2, -8, 7),
            (Precision::U4, -8, 7),
        ];
        for (p, wlo, whi) in grids {
            for (m, k, n) in [(5usize, 7usize, 3usize), (9, 130, 8), (160, 96, 80)] {
                let a32 = rand_i(&mut rng, &[m, k], 0, p.max_val() as i64 + 1);
                let b32 = rand_i(&mut rng, &[k, n], wlo, whi + 1);
                let want = matmul_i32(&a32, &b32);
                let ap = pack_vals(a32.data(), p);
                let planes = BitPlanes::build(&b32).unwrap();
                let mut out = vec![0i32; m * n];
                matmul_bitserial_fused_into(&ap, p, m, &planes, &|_, v| v, &mut out);
                assert_eq!(&out[..], want.data(), "{} {m}x{k}x{n}", p.name());
            }
        }
    }

    #[test]
    fn bitserial_epilogue_narrows_into_packed_output() {
        let mut rng = Rng::new(32);
        let (m, k, n) = (6usize, 70usize, 5usize);
        let a32 = rand_i(&mut rng, &[m, k], 0, 4);
        let b32 = rand_i(&mut rng, &[k, n], -2, 3);
        let epi = |j: usize, v: i32| (v as i64 + j as i64).clamp(0, 255) as i32;
        let mut want = vec![0i32; m * n];
        matmul_i32_fused_into(a32.data(), b32.data(), m, k, n, &epi, &mut want);
        let ap = pack_vals(a32.data(), Precision::U2);
        let planes = BitPlanes::build(&b32).unwrap();
        let mut out = vec![0u8; m * n];
        matmul_bitserial_fused_into(&ap, Precision::U2, m, &planes, &epi, &mut out);
        for (o, w) in out.iter().zip(&want) {
            assert_eq!(*o as i32, *w);
        }
    }

    #[test]
    fn subbyte_unpack_matmul_matches_i32_reference() {
        // U4 and I4 activations x i8 weights through the nibble-unpack
        // row-block kernel, below and above the threading cutoff.
        let mut rng = Rng::new(33);
        for p in [Precision::U4, Precision::I4, Precision::U2, Precision::U1] {
            for (m, k, n) in [(5usize, 7usize, 3usize), (160, 96, 80)] {
                let a32 =
                    rand_i(&mut rng, &[m, k], p.min_val() as i64, p.max_val() as i64 + 1);
                let b32 = rand_i(&mut rng, &[k, n], -128, 128);
                let want = matmul_i32(&a32, &b32);
                let ap = pack_vals(a32.data(), p);
                let b8: Vec<i8> = b32.data().iter().map(|v| *v as i8).collect();
                let mut out = vec![0i32; m * n];
                matmul_subbyte_fused_into(&ap, p, &b8, m, k, n, &|_, v| v, &mut out);
                assert_eq!(&out[..], want.data(), "{} {m}x{k}x{n}", p.name());
            }
        }
    }

    #[test]
    fn subbyte_matmul_narrows_into_packed_output() {
        // Sub-byte in, sub-byte out: the caller packs the epilogue result.
        let mut rng = Rng::new(34);
        let (m, k, n) = (6usize, 9usize, 4usize);
        let a32 = rand_i(&mut rng, &[m, k], 0, 16);
        let b32 = rand_i(&mut rng, &[k, n], -8, 8);
        let epi = |_: usize, v: i32| (v as i64).clamp(0, 15) as i32;
        let mut want = vec![0i32; m * n];
        matmul_i32_fused_into(a32.data(), b32.data(), m, k, n, &epi, &mut want);
        let ap = pack_vals(a32.data(), Precision::U4);
        let b8: Vec<i8> = b32.data().iter().map(|v| *v as i8).collect();
        let mut wide = vec![0i32; m * n];
        matmul_subbyte_fused_into(&ap, Precision::U4, &b8, m, k, n, &epi, &mut wide);
        assert_eq!(&wide[..], &want[..]);
        let repacked = pack_vals(&wide, Precision::U4);
        for (i, w) in want.iter().enumerate() {
            assert_eq!(get_packed(&repacked, i, Precision::U4), *w);
        }
    }

    #[test]
    fn packed_subbyte_im2col_and_pools_match_wide_twins() {
        let mut rng = Rng::new(35);
        for p in [Precision::U1, Precision::U2, Precision::U4, Precision::I4] {
            let x = rand_i(
                &mut rng,
                &[2, 3, 4, 4],
                p.min_val() as i64,
                p.max_val() as i64 + 1,
            );
            let xp = pack_vals(x.data(), p);

            let (want, _) = im2col(&x, 3, 3, 1, 1);
            let rows = want.shape()[0] * want.shape()[1];
            let mut got = vec![0xffu8; packed_byte_len(rows, p.bits())];
            im2col_packed_into(&xp, p, 2, 3, 4, 4, 3, 3, 1, 1, &mut got);
            for (i, w) in want.data().iter().enumerate() {
                assert_eq!(get_packed(&got, i, p), *w, "{} im2col", p.name());
            }

            let mut wide = vec![0i32; 2 * 3 * 2 * 2];
            maxpool_into(x.data(), 2, 3, 4, 4, 2, &mut wide);
            let mut got = vec![0xffu8; packed_byte_len(wide.len(), p.bits())];
            maxpool_packed_into(&xp, p, 2, 3, 4, 4, 2, &mut got);
            for (i, w) in wide.iter().enumerate() {
                assert_eq!(get_packed(&got, i, p), *w, "{} maxpool", p.name());
            }

            if p != Precision::I4 {
                // Eq. 25 avgpool on unsigned grids (the deployed case).
                avgpool_i32_into(x.data(), 2, 3, 4, 4, 2, 12, &mut wide);
                let mut got = vec![0xffu8; packed_byte_len(wide.len(), p.bits())];
                avgpool_packed_into(&xp, p, 2, 3, 4, 4, 2, 12, &mut got);
                for (i, w) in wide.iter().enumerate() {
                    assert_eq!(get_packed(&got, i, p), *w, "{} avgpool", p.name());
                }
            }

            let r = rand_i(
                &mut rng,
                &[2 * 3 * 3, 4],
                p.min_val() as i64,
                p.max_val() as i64 + 1,
            );
            let wantr = rows_to_nchw(&r, 2, 3, 3);
            let rp = pack_vals(r.data(), p);
            let mut got = vec![0xffu8; packed_byte_len(2 * 4 * 9, p.bits())];
            rows_to_nchw_packed_into(&rp, p, 2, 4, 3, 3, &mut got);
            for (i, w) in wantr.data().iter().enumerate() {
                assert_eq!(get_packed(&got, i, p), *w, "{} scatter", p.name());
            }
        }
    }

    #[test]
    fn matmul_i32_small() {
        let a = Tensor::from_vec(&[2, 3], vec![1, 2, 3, 4, 5, 6]);
        let b = Tensor::from_vec(&[3, 2], vec![7, 8, 9, 10, 11, 12]);
        let c = matmul_i32(&a, &b);
        assert_eq!(c.data(), &[58, 64, 139, 154]);
    }

    #[test]
    fn matmul_f32_matches_naive() {
        let mut rng = Rng::new(1);
        let a = rand_f(&mut rng, &[17, 23]);
        let b = rand_f(&mut rng, &[23, 9]);
        let c = matmul_f32(&a, &b);
        for i in 0..17 {
            for j in 0..9 {
                let mut acc = 0f32;
                for k in 0..23 {
                    acc += a.at2(i, k) * b.at2(k, j);
                }
                assert!((c.at2(i, j) - acc).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn conv_via_im2col_matches_direct() {
        // 1x1 kernel conv == per-pixel matmul; sanity for layout.
        let mut rng = Rng::new(2);
        let x = rand_i(&mut rng, &[2, 3, 4, 4], -100, 100);
        let w = rand_i(&mut rng, &[3, 5], -50, 50); // [cin*1*1, cout]
        let y = conv2d_i32_wmat(&x, &w, 1, 1, 1, 0);
        assert_eq!(y.shape(), &[2, 5, 4, 4]);
        // check one output element by hand
        let mut acc = 0i64;
        for ci in 0..3 {
            acc += x.at4(1, ci, 2, 3) as i64 * w.at2(ci, 4) as i64;
        }
        assert_eq!(y.at4(1, 4, 2, 3) as i64, acc);
    }

    #[test]
    fn conv_stride_padding_shapes() {
        let x = Tensor::<i32>::zeros(&[1, 1, 16, 16]);
        let w = Tensor::<i32>::zeros(&[9, 8]);
        let y = conv2d_i32_wmat(&x, &w, 3, 3, 2, 1);
        assert_eq!(y.shape(), &[1, 8, 8, 8]);
    }

    #[test]
    fn conv_f32_identity_kernel() {
        let mut rng = Rng::new(3);
        let x = rand_f(&mut rng, &[1, 1, 5, 5]);
        // 3x3 identity kernel (center 1)
        let mut wd = vec![0f32; 9];
        wd[4] = 1.0;
        let w = Tensor::from_vec(&[1, 1, 3, 3], wd);
        let y = conv2d_f32(&x, &w, 1, 1);
        assert!(y.allclose(&x, 1e-6, 0.0));
    }

    #[test]
    fn im2col_into_zeroes_stale_padding() {
        // padded conv over a dirty arena buffer must still read zeros in
        // the halo region.
        let x = Tensor::from_vec(&[1, 1, 2, 2], vec![1, 2, 3, 4]);
        let mut dirty = vec![77i32; 2 * 2 * 9 + 5];
        let (rows, cols, oh, ow) =
            im2col_into(x.data(), 1, 1, 2, 2, 3, 3, 1, 1, &mut dirty);
        assert_eq!((rows, cols, oh, ow), (4, 9, 2, 2));
        let (want, _) = im2col(&x, 3, 3, 1, 1);
        assert_eq!(&dirty[..36], want.data());
        assert_eq!(dirty[36..], [77; 5]); // untouched tail
    }

    #[test]
    fn maxpool_and_avgpool() {
        let x = Tensor::from_vec(&[1, 1, 2, 2], vec![1, 5, 3, 4]);
        assert_eq!(maxpool(&x, 2).data(), &[5]);
        // avgpool_i32: sum=13, m=floor(2^12/4)=1024, (13*1024)>>12 = 3
        assert_eq!(avgpool_i32(&x, 2, 12).data(), &[3]);
        let xf = x.map(|v| v as f32);
        assert!((avgpool_f32(&xf, 2).data()[0] - 3.25).abs() < 1e-6);
    }

    #[test]
    fn pool_into_variants_match_tensor_api() {
        let mut rng = Rng::new(4);
        let x = rand_i(&mut rng, &[2, 3, 4, 4], -100, 100);
        let mut out = vec![0i32; 2 * 3 * 2 * 2];
        maxpool_into(x.data(), 2, 3, 4, 4, 2, &mut out);
        assert_eq!(&out[..], maxpool(&x, 2).data());
        avgpool_i32_into(x.data(), 2, 3, 4, 4, 2, 12, &mut out);
        assert_eq!(&out[..], avgpool_i32(&x, 2, 12).data());
        let xf = rand_f(&mut rng, &[2, 3, 4, 4]);
        let mut outf = vec![0f32; 2 * 3 * 2 * 2];
        avgpool_f32_into(xf.data(), 2, 3, 4, 4, 2, &mut outf);
        assert_eq!(&outf[..], avgpool_f32(&xf, 2).data());
        let mut gm = vec![0f32; 6];
        global_mean_f32_into(xf.data(), 2, 3, 4, 4, &mut gm);
        assert_eq!(&gm[..], global_mean_f32(&xf).data());
    }

    #[test]
    fn global_mean() {
        let x = Tensor::from_vec(&[1, 2, 1, 2], vec![1.0f32, 3.0, 10.0, 20.0]);
        let y = global_mean_f32(&x);
        assert_eq!(y.data(), &[2.0, 15.0]);
    }

    #[test]
    fn rows_to_nchw_into_matches_tensor_api() {
        let mut rng = Rng::new(5);
        let rows = rand_i(&mut rng, &[2 * 3 * 3, 4], -10, 10);
        let want = rows_to_nchw(&rows, 2, 3, 3);
        let mut out = vec![0i32; 2 * 4 * 9];
        rows_to_nchw_into(rows.data(), 2, 4, 3, 3, &mut out);
        assert_eq!(&out[..], want.data());
    }

    #[test]
    fn im2col_matches_python_layout() {
        // mirrors python test: column index = c*(kh*kw) + ki*kw + kj
        let x = Tensor::from_vec(&[1, 2, 2, 2], vec![1, 2, 3, 4, 5, 6, 7, 8]);
        let (cols, (b, oh, ow)) = im2col(&x, 2, 2, 1, 0);
        assert_eq!((b, oh, ow), (1, 1, 1));
        assert_eq!(cols.data(), &[1, 2, 3, 4, 5, 6, 7, 8]);
    }

    fn transpose(t: &TensorF) -> TensorF {
        let (m, n) = (t.shape()[0], t.shape()[1]);
        let mut out = vec![0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                out[j * m + i] = t.data()[i * n + j];
            }
        }
        Tensor::from_vec(&[n, m], out)
    }

    #[test]
    fn transposed_gemms_match_explicit_transpose() {
        let mut rng = Rng::new(21);
        for &(m, k, n) in &[(3usize, 5usize, 4usize), (17, 9, 13), (160, 96, 80)] {
            let a = rand_f(&mut rng, &[m, k]);
            let b = rand_f(&mut rng, &[m, n]);
            // AᵀB vs matmul(transpose(a), b)
            let want = matmul_f32(&transpose(&a), &b);
            let mut got = vec![0f32; k * n];
            matmul_f32_atb_into(a.data(), b.data(), m, k, n, &mut got);
            for (g, w) in got.iter().zip(want.data()) {
                assert!((g - w).abs() <= 1e-4 * w.abs().max(1.0), "{g} vs {w}");
            }
            // ABᵀ (accumulating) vs matmul(a2, transpose(b2))
            let a2 = rand_f(&mut rng, &[m, k]);
            let b2 = rand_f(&mut rng, &[n, k]);
            let want2 = matmul_f32(&a2, &transpose(&b2));
            let mut got2 = vec![1.0f32; m * n]; // nonzero: verifies +=
            matmul_f32_abt_acc_into(a2.data(), b2.data(), m, k, n, &mut got2);
            for (g, w) in got2.iter().zip(want2.data()) {
                let w = w + 1.0;
                assert!((g - w).abs() <= 1e-4 * w.abs().max(1.0), "{g} vs {w}");
            }
        }
    }

    #[test]
    fn nchw_rows_roundtrip() {
        let mut rng = Rng::new(22);
        let x = rand_f(&mut rng, &[2, 5, 3, 4]);
        let mut rows = vec![0f32; x.len()];
        nchw_to_rows_into(x.data(), 2, 5, 3, 4, &mut rows);
        let mut back = vec![0f32; x.len()];
        rows_to_nchw_into(&rows, 2, 5, 3, 4, &mut back);
        assert_eq!(&back[..], x.data());
    }

    #[test]
    fn col2im_is_the_adjoint_of_im2col() {
        // <im2col(x), g> == <x, col2im(g)> for random x, g — the defining
        // property of the adjoint, covering stride/pad combinations.
        let mut rng = Rng::new(23);
        for &(h, w, kh, kw, stride, pad) in
            &[(6usize, 6usize, 3usize, 3usize, 1usize, 1usize), (7, 5, 3, 3, 2, 1), (4, 4, 2, 2, 2, 0)]
        {
            let (b, c) = (2, 3);
            let x = rand_f(&mut rng, &[b, c, h, w]);
            let oh = (h + 2 * pad - kh) / stride + 1;
            let ow = (w + 2 * pad - kw) / stride + 1;
            let rows = b * oh * ow;
            let cols = c * kh * kw;
            let mut xc = vec![0f32; rows * cols];
            im2col_into(x.data(), b, c, h, w, kh, kw, stride, pad, &mut xc);
            let g = rand_f(&mut rng, &[rows, cols]);
            let lhs: f64 = xc.iter().zip(g.data()).map(|(a, b)| (*a as f64) * (*b as f64)).sum();
            let mut gx = vec![0f32; b * c * h * w];
            col2im_acc_into(g.data(), b, c, h, w, kh, kw, stride, pad, &mut gx);
            let rhs: f64 =
                x.data().iter().zip(&gx).map(|(a, b)| (*a as f64) * (*b as f64)).sum();
            assert!((lhs - rhs).abs() <= 1e-3 * lhs.abs().max(1.0), "{lhs} vs {rhs}");
        }
    }

    #[test]
    fn pool_backward_kernels() {
        // maxpool: gradient lands exactly on each window's (first) argmax
        let x = Tensor::from_vec(
            &[1, 1, 2, 4],
            vec![1.0f32, 5.0, 2.0, 2.0, 3.0, 0.0, 2.0, 2.0],
        );
        let gy = [10.0f32, 100.0];
        let mut gx = vec![0f32; 8];
        maxpool_backward_acc_into(x.data(), &gy, 1, 1, 2, 4, 2, &mut gx);
        // left window: max 5.0 at idx 1; right window: tie at 2.0, first
        // scan position (idx 2) wins
        assert_eq!(gx, vec![0.0, 10.0, 100.0, 0.0, 0.0, 0.0, 0.0, 0.0]);
        // avgpool: uniform spread of gy/k^2
        let mut gx = vec![0f32; 8];
        avgpool_backward_acc_into(&gy, 1, 1, 2, 4, 2, &mut gx);
        assert_eq!(gx, vec![2.5, 2.5, 25.0, 25.0, 2.5, 2.5, 25.0, 25.0]);
        // global mean: gy/(H*W) everywhere
        let mut gx = vec![0f32; 8];
        global_mean_backward_acc_into(&[8.0, 16.0], 1, 2, 2, 2, &mut gx);
        assert_eq!(gx, vec![2.0, 2.0, 2.0, 2.0, 4.0, 4.0, 4.0, 4.0]);
    }

    #[test]
    fn wmat_grad_layout_roundtrip() {
        let mut rng = Rng::new(24);
        let w = rand_f(&mut rng, &[4, 3, 3, 3]);
        let wmat = oihw_to_wmat(&w);
        let back = wmat_to_oihw(wmat.data(), 4, 3, 3, 3);
        assert_eq!(&back[..], w.data());
    }
}
