//! Integer-only executor for IntegerDeployable graphs — the MCU-datapath
//! simulator (DESIGN.md §Hardware-Adaptation).
//!
//! Invariant: no floating-point arithmetic touches the value path. All
//! tensors are i32 integer images; products and accumulations widen to
//! i64 exactly like the Pallas kernels and narrow back behind checked
//! casts (the transform pipeline's range analysis proves they fit).
//!
//! Two execution paths, bit-identical by construction (and by the
//! property tests in tests/plan.rs):
//!
//! * [`IntegerEngine::run`] compiles a fused [`IntPlan`] and executes it
//!   (serving precompiles the plan once in
//!   [`crate::exec::NativeIntExecutor`] instead of per call);
//! * [`IntegerEngine::run_interpreted`] / [`IntegerEngine::run_traced`]
//!   walk the graph node by node with one tensor per node — the unfused
//!   diagnostic path the plan is verified against.

use crate::engine::plan::{IntArena, IntPlan, PackedArena};
use crate::graph::int::{IntGraph, IntOp};
use crate::tensor::ops;
use crate::tensor::{Tensor, TensorI};

#[derive(Default)]
pub struct IntegerEngine;

impl IntegerEngine {
    pub fn new() -> Self {
        IntegerEngine
    }

    /// Run the integer graph on an integer-image batch ([B,C,H,W] or
    /// [B,F]) through a freshly compiled fused plan.
    pub fn run(&self, g: &IntGraph, qx: &TensorI) -> TensorI {
        let plan = IntPlan::compile(g).expect("integer graph failed to plan");
        let layout = plan
            .layout(qx.shape().first().copied().unwrap_or(0))
            .expect("integer plan layout");
        let mut arena = IntArena::new();
        plan.execute(&layout, &mut arena, qx)
    }

    /// Run through the precision-packed plan path: sub-word nodes stream
    /// u8/i8 storage (DESIGN.md §Precision propagation). Bit-identical to
    /// [`Self::run`] for inputs inside the deployed input spec; inputs
    /// outside the stamped input precision panic loudly here (release
    /// builds would otherwise wrap them while narrowing). Serving
    /// precompiles this path in [`crate::exec::NativeIntExecutor`], which
    /// rejects out-of-range requests with an error instead.
    pub fn run_packed(&self, g: &IntGraph, qx: &TensorI) -> TensorI {
        let plan = IntPlan::compile(g).expect("integer graph failed to plan");
        let p = plan.input_precision();
        if let Some(v) = p.find_out_of_range(qx.data()) {
            panic!(
                "run_packed: input value {v} outside the deployed input \
                 precision {} range [{}, {}]",
                p.name(),
                p.min_val(),
                p.max_val()
            );
        }
        let layout = plan
            .packed_layout(qx.shape().first().copied().unwrap_or(0))
            .expect("integer packed layout");
        let mut arena = PackedArena::new();
        plan.execute_packed(&layout, &mut arena, qx)
    }

    /// Unfused reference interpreter: one tensor per node, no fusion, no
    /// arena. The plan path is property-tested bit-identical to this.
    pub fn run_interpreted(&self, g: &IntGraph, qx: &TensorI) -> TensorI {
        self.run_inner(g, qx, None)
    }

    /// Run the unfused interpreter and record every node's output
    /// (deployment diagnostics; the trace indexes by graph node id).
    pub fn run_traced(&self, g: &IntGraph, qx: &TensorI) -> Vec<TensorI> {
        let mut trace = Vec::with_capacity(g.nodes.len());
        self.run_inner(g, qx, Some(&mut trace));
        trace
    }

    fn run_inner(
        &self,
        g: &IntGraph,
        qx: &TensorI,
        mut trace: Option<&mut Vec<TensorI>>,
    ) -> TensorI {
        let mut outs: Vec<Option<TensorI>> = vec![None; g.nodes.len()];
        for n in &g.nodes {
            let out = match &n.op {
                IntOp::Input { .. } => qx.clone(),
                IntOp::ConvInt { wq, bias_q, kh, kw, stride, pad, .. } => {
                    // Fast i32-accumulating path: IntGraphs only come from
                    // transform::deploy, whose range analysis proved every
                    // accumulator fits i32 (overflow would have aborted
                    // the transform). Debug builds double-check via the
                    // engine's checked per-op arithmetic elsewhere.
                    // Weights live at their packed precision; this
                    // diagnostic path widens them per run (the serving
                    // path — engine/plan — consumes them packed).
                    let mut y = ops::conv2d_i32_wmat_fast(
                        outs[n.inputs[0]].as_ref().unwrap(),
                        &wq.widen(),
                        *kh,
                        *kw,
                        *stride,
                        *pad,
                    );
                    if let Some(b) = bias_q {
                        add_channel_bias_i32(&mut y, b);
                    }
                    y
                }
                IntOp::LinearInt { wq, bias_q } => {
                    let mut y =
                        ops::matmul_i32_fast(outs[n.inputs[0]].as_ref().unwrap(), &wq.widen());
                    if let Some(b) = bias_q {
                        let c = y.shape()[1];
                        for (i, v) in y.data_mut().iter_mut().enumerate() {
                            *v = ops::narrow(*v as i64 + b[i % c]);
                        }
                    }
                    y
                }
                IntOp::IntBn { bn } => {
                    let t = outs[n.inputs[0]].as_ref().unwrap();
                    apply_per_channel(t, |c, q| ops::narrow(bn.apply(c, q)))
                }
                IntOp::RequantAct { rq } => outs[n.inputs[0]]
                    .as_ref()
                    .unwrap()
                    .map(|q| rq.apply(q as i64) as i32),
                IntOp::ThreshAct { th } => {
                    let t = outs[n.inputs[0]].as_ref().unwrap();
                    apply_per_channel(t, |c, q| th.apply(c, q) as i32)
                }
                IntOp::AvgPoolInt { k, d } => {
                    ops::avgpool_i32(outs[n.inputs[0]].as_ref().unwrap(), *k, *d)
                }
                IntOp::MaxPoolInt { k } => {
                    ops::maxpool(outs[n.inputs[0]].as_ref().unwrap(), *k)
                }
                IntOp::Flatten => {
                    let t = outs[n.inputs[0]].as_ref().unwrap();
                    let b = t.shape()[0];
                    let f: usize = t.shape()[1..].iter().product();
                    t.reshape(&[b, f])
                }
                IntOp::AddRequant { rqs } => {
                    // Branch 0 is the reference space (Eq. 24).
                    let mut acc = outs[n.inputs[0]].as_ref().unwrap().clone();
                    assert_eq!(rqs.len(), n.inputs.len() - 1);
                    for (bi, &i) in n.inputs[1..].iter().enumerate() {
                        let t = outs[i].as_ref().unwrap();
                        assert_eq!(t.shape(), acc.shape(), "Add shape mismatch");
                        let rq = &rqs[bi];
                        for (a, b) in acc.data_mut().iter_mut().zip(t.data()) {
                            *a = ops::narrow(*a as i64 + rq.apply(*b as i64));
                        }
                    }
                    acc
                }
            };
            if let Some(tr) = trace.as_deref_mut() {
                tr.push(out.clone());
            }
            outs[n.id] = Some(out);
        }
        outs[g.output].take().unwrap()
    }
}

/// Apply f(channel, value) over NCHW or [B, C] integer tensors.
fn apply_per_channel(t: &TensorI, f: impl Fn(usize, i64) -> i32) -> TensorI {
    match t.ndim() {
        4 => {
            let (b, c, h, w) =
                (t.shape()[0], t.shape()[1], t.shape()[2], t.shape()[3]);
            let hw = h * w;
            let mut out = TensorI::zeros(t.shape());
            let src = t.data();
            let dst = out.data_mut();
            for bi in 0..b {
                for ci in 0..c {
                    let base = (bi * c + ci) * hw;
                    for k in 0..hw {
                        dst[base + k] = f(ci, src[base + k] as i64);
                    }
                }
            }
            out
        }
        2 => {
            let c = t.shape()[1];
            let data = t
                .data()
                .iter()
                .enumerate()
                .map(|(i, s)| f(i % c, *s as i64))
                .collect();
            Tensor::from_vec(t.shape(), data)
        }
        d => panic!("per-channel op on rank-{d} tensor"),
    }
}

fn add_channel_bias_i32(y: &mut TensorI, bias: &[i64]) {
    let (b, c, h, w) = (y.shape()[0], y.shape()[1], y.shape()[2], y.shape()[3]);
    let hw = h * w;
    let data = y.data_mut();
    for bi in 0..b {
        for ci in 0..c {
            let base = (bi * c + ci) * hw;
            for v in &mut data[base..base + hw] {
                *v = ops::narrow(*v as i64 + bias[ci]);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::int::IntGraph;
    use crate::quant::bn::{BnQuant, Thresholds};
    use crate::quant::requant::Requant;
    use crate::quant::QuantSpec;

    #[test]
    fn conv_bn_requant_pipeline() {
        let mut g = IntGraph::default();
        let spec = QuantSpec { eps: 1.0 / 255.0, lo: 0, hi: 255 };
        let x = g.push("in", IntOp::Input { shape: vec![1, 2, 2], spec }, &[]);
        // 1x1 conv, 1 -> 1 channel... use 2 channels to exercise layout
        let wq = Tensor::from_vec(&[1, 2], vec![2, -1]).into();
        let c = g.push(
            "conv",
            IntOp::ConvInt { wq, bias_q: None, cin: 1, kh: 1, kw: 1, stride: 1, pad: 0 },
            &[x],
        );
        let bn = BnQuant {
            kappa_q: vec![3, 1],
            lambda_q: vec![10, -10],
            eps_kappa: 0.01,
            eps_phi_out: 0.0001,
        };
        let b = g.push("bn", IntOp::IntBn { bn }, &[c]);
        let rq = Requant { m: 1, d: 1, lo: 0, hi: 255 };
        g.push("act", IntOp::RequantAct { rq }, &[b]);

        let qx = Tensor::from_vec(&[1, 1, 2, 2], vec![10, 20, 30, 40]);
        let out = IntegerEngine::new().run(&g, &qx);
        assert_eq!(out.shape(), &[1, 2, 2, 2]);
        // channel 0: (10*2*3 + 10) >> 1 = 35 ; channel 1: (10*-1 -10)>>1 -> clip 0
        assert_eq!(out.at4(0, 0, 0, 0), 35);
        assert_eq!(out.at4(0, 1, 0, 0), 0);
        // fused plan path == unfused interpreter == packed path
        let interp = IntegerEngine::new().run_interpreted(&g, &qx);
        assert_eq!(out, interp);
        assert_eq!(IntegerEngine::new().run_packed(&g, &qx), interp);
    }

    #[test]
    #[should_panic(expected = "outside the deployed input precision")]
    fn run_packed_rejects_out_of_range_inputs() {
        let mut g = IntGraph::default();
        let spec = QuantSpec { eps: 1.0, lo: 0, hi: 255 };
        let x = g.push("in", IntOp::Input { shape: vec![2], spec }, &[]);
        let wq = Tensor::from_vec(&[2, 2], vec![1, 0, 0, 1]).into();
        g.push("fc", IntOp::LinearInt { wq, bias_q: None }, &[x]);
        let qx = Tensor::from_vec(&[1, 2], vec![0, 300]); // 300 > spec hi
        let _ = IntegerEngine::new().run_packed(&g, &qx);
    }

    #[test]
    fn thresh_act_in_graph() {
        let mut g = IntGraph::default();
        let spec = QuantSpec { eps: 1.0, lo: 0, hi: 255 };
        let x = g.push("in", IntOp::Input { shape: vec![1, 1, 2], spec }, &[]);
        let th = Thresholds { th: vec![vec![5, 10, 20]], n_levels: 3 };
        g.push("act", IntOp::ThreshAct { th }, &[x]);
        let qx = Tensor::from_vec(&[1, 1, 1, 2], vec![7, 25]);
        let out = IntegerEngine::new().run(&g, &qx);
        assert_eq!(out.data(), &[1, 3]);
    }

    #[test]
    fn add_requant_combines_branches() {
        let mut g = IntGraph::default();
        let spec = QuantSpec { eps: 0.5, lo: 0, hi: 255 };
        let x = g.push("in", IntOp::Input { shape: vec![2], spec }, &[]);
        // branch 1 lives at eps=0.25 -> requant by ~1/2 into eps=0.5 space
        let rq = Requant { m: 128, d: 8, lo: i64::MIN, hi: i64::MAX };
        g.push("add", IntOp::AddRequant { rqs: vec![rq] }, &[x, x]);
        let qx = Tensor::from_vec(&[1, 2], vec![100, 7]);
        let out = IntegerEngine::new().run(&g, &qx);
        assert_eq!(out.data(), &[150, 10]); // 100 + 50, 7 + 3
    }

    #[test]
    fn flatten_and_linear() {
        let mut g = IntGraph::default();
        let spec = QuantSpec { eps: 1.0, lo: 0, hi: 255 };
        let x = g.push("in", IntOp::Input { shape: vec![2, 1, 1], spec }, &[]);
        let f = g.push("fl", IntOp::Flatten, &[x]);
        let wq = Tensor::from_vec(&[2, 2], vec![1, 2, 3, 4]).into();
        g.push("fc", IntOp::LinearInt { wq, bias_q: Some(vec![5, -5]) }, &[f]);
        let qx = Tensor::from_vec(&[1, 2, 1, 1], vec![10, 20]);
        let out = IntegerEngine::new().run(&g, &qx);
        assert_eq!(out.data(), &[75, 95]); // [10*1+20*3+5, 10*2+20*4-5]
    }
}
