//! Self-contained utilities: PRNG, JSON, statistics, timing, mini property
//! testing.
//!
//! The offline vendor set has no `rand`, `serde` (facade), `criterion`,
//! `clap` or `proptest`, so this crate carries small, well-tested
//! replacements for exactly the slices of those it needs.

pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod timer;
