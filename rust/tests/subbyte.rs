//! Sub-byte storage end-to-end: bit-packed payload round-trips on
//! randomized shapes (every sub-byte precision, boundary values),
//! packed-payload validation, and artifact save -> load bit-identity
//! for few-bit deployments (Q in {1, 2, 4}) whose weight sections ship
//! bit-packed at 2-8 values per byte (DESIGN.md §Sub-byte packing).

use nemo::engine::{IntPlan, IntegerEngine, PackedArena};
use nemo::io::artifact::DeployedArtifact;
use nemo::model::mlp;
use nemo::network::{Network, StageMeta};
use nemo::quant::{quantize_input, Precision};
use nemo::tensor::{packed_byte_len, PackedTensor, QTensor, Tensor, TensorF};
use nemo::transform::{Deployed, DeployOptions};
use nemo::util::prop::prop_check;
use nemo::util::rng::Rng;

const SUB_BYTE: [Precision; 4] =
    [Precision::U1, Precision::U2, Precision::U4, Precision::I4];

#[test]
fn packed_payloads_roundtrip_on_random_shapes() {
    prop_check(60, |rng| {
        let p = SUB_BYTE[rng.int(0, 4) as usize];
        let rank = rng.int(1, 5) as usize;
        let shape: Vec<usize> = (0..rank).map(|_| rng.int(1, 8) as usize).collect();
        let n: usize = shape.iter().product();
        let vals: Vec<i32> = (0..n)
            .map(|_| rng.int(p.min_val(), p.max_val() + 1) as i32)
            .collect();
        let t = Tensor::from_vec(&shape, vals);
        let q = QTensor::narrow_from(&t, p).map_err(|e| e.to_string())?;
        if q.storage_bytes() != packed_byte_len(n, p.bits()) {
            return Err(format!(
                "{}: {} storage bytes for {n} elements",
                p.name(),
                q.storage_bytes()
            ));
        }
        if q.widen() != t {
            return Err(format!("{}: widen() lost values, shape {shape:?}", p.name()));
        }
        Ok(())
    });
}

#[test]
fn packed_boundary_values_roundtrip() {
    for p in SUB_BYTE {
        let vals = vec![
            p.min_val() as i32,
            p.max_val() as i32,
            0,
            p.max_val() as i32,
            p.min_val() as i32,
        ];
        let t = Tensor::from_vec(&[5], vals);
        let q = QTensor::narrow_from(&t, p).unwrap();
        assert_eq!(q.widen(), t, "{} boundary values", p.name());
        // One past either end is rejected, not wrapped.
        for bad in [p.min_val() - 1, p.max_val() + 1] {
            let t = Tensor::from_vec(&[1], vec![bad as i32]);
            assert!(
                QTensor::narrow_from(&t, p).is_err(),
                "{}: {bad} must not narrow",
                p.name()
            );
        }
    }
}

#[test]
fn packed_payload_validation_is_loud() {
    // Wrong byte count for the shape.
    assert!(PackedTensor::from_bytes(&[5], Precision::U2, vec![0; 3]).is_err());
    // Dirty trailing pad bits (3 x 2 bits used, bit 6 set).
    assert!(
        PackedTensor::from_bytes(&[3], Precision::U2, vec![0b0100_0000]).is_err()
    );
    // Byte-and-wider precisions never build packed payloads.
    assert!(PackedTensor::from_bytes(&[3], Precision::U8, vec![0; 3]).is_err());
    // A canonical payload decodes LSB-first.
    let t = PackedTensor::from_bytes(&[3], Precision::U2, vec![0b00_10_01]).unwrap();
    assert_eq!((t.get(0), t.get(1), t.get(2)), (1, 2, 0));
}

/// Deploy the MLP with 4-bit weights and a Q-bit activation grid: every
/// weight section lands on a sub-byte class and every activation stamp
/// on U{Q}.
fn deployed_mlp(q: u32, seed: u64) -> (Deployed, StageMeta, TensorF) {
    let mut rng = Rng::new(seed);
    let g = mlp(&mut rng, 12, 10, 4, 1.0 / 255.0);
    let x = TensorF::from_vec(
        &[3, 12],
        (0..36).map(|_| rng.uniform(0.0, 1.0) as f32).collect(),
    );
    let fp = Network::from_graph(g).unwrap();
    let betas = fp.calibrate(&[x.clone()]);
    let nid = fp
        .quantize_pact(4, q, &betas)
        .unwrap()
        .deploy(DeployOptions { wbits: 4, abits: q, ..DeployOptions::default() })
        .unwrap()
        .integerize();
    let meta = nid.meta().clone();
    (nid.into_deployed(), meta, x)
}

#[test]
fn artifact_roundtrip_is_bit_identical_at_subbyte_q() {
    for (q, want_act) in
        [(1u32, Precision::U1), (2, Precision::U2), (4, Precision::U4)]
    {
        let (dep, meta, x) = deployed_mlp(q, 40 + q as u64);
        assert!(
            dep.id.precisions().contains(&want_act),
            "Q={q}: deployment carries no {} stamp",
            want_act.name()
        );
        let art = DeployedArtifact::from_deployed(&dep, &meta);

        // 4-bit weight grids ship bit-packed: every weight section in
        // the JSON is a sub-byte dtype with a hex payload, never a wide
        // int array.
        let doc = art.to_json();
        let nodes = doc
            .get("model")
            .unwrap()
            .get("graph")
            .unwrap()
            .get("nodes")
            .unwrap()
            .as_arr()
            .unwrap();
        let mut saw_weight = false;
        for n in nodes {
            if let Some(w) = n.get("params").unwrap().get_opt("w") {
                saw_weight = true;
                let dtype = w.get("dtype").unwrap().as_str().unwrap();
                let p = Precision::from_name(dtype).unwrap();
                assert!(p.is_sub_byte(), "Q={q}: weight dtype '{dtype}' stored wide");
                assert!(w.get_opt("packed").is_some(), "Q={q}: no packed payload");
                assert!(
                    w.get_opt("data").is_none(),
                    "Q={q}: wide array beside packed payload"
                );
            }
        }
        assert!(saw_weight, "mlp must contain weight payloads");

        let path = std::env::temp_dir().join(format!(
            "nemo_subbyte_artifact_{}_{q}.nemo.json",
            std::process::id()
        ));
        art.save(&path).unwrap();
        let back = DeployedArtifact::load(&path).unwrap();
        let _ = std::fs::remove_file(&path);

        // Bit-identity of the frozen program, wide and packed.
        let qx = quantize_input(&x, 1.0 / 255.0);
        let want = IntegerEngine::new().run(&dep.id, &qx);
        assert_eq!(
            want,
            IntegerEngine::new().run(&back.graph, &qx),
            "Q={q}: wide execution diverged after reload"
        );
        let plan = IntPlan::compile(&back.graph).unwrap();
        let layout = plan.packed_layout(qx.shape()[0]).unwrap();
        let mut arena = PackedArena::new();
        assert_eq!(
            want,
            plan.execute_packed(&layout, &mut arena, &qx),
            "Q={q}: packed execution diverged after reload"
        );
    }
}
