//! Synthetic dataset: "synthdigits" (S9 in DESIGN.md).
//!
//! Substitution note (DESIGN.md sec. 5): the paper's target inputs are
//! 8-bit camera-style images. No image dataset ships in this environment,
//! so we generate a deterministic 10-class pattern-classification set
//! whose inputs are naturally 8-bit (eps_in = 1/255, alpha = 0, sec. 3.7):
//! each class is a fixed smoothed random glyph; samples apply a random
//! translation, contrast jitter, and Gaussian pixel noise. The quantity
//! the paper cares about — accuracy *deltas across representations* — is
//! preserved by any separable-but-nontrivial classification task.

use crate::tensor::{Tensor, TensorF};
use crate::util::rng::Rng;

pub const IMG: usize = 16;
pub const N_CLASSES: usize = 10;

/// Deterministic dataset generator.
pub struct SynthDigits {
    /// base glyph per class, IMG x IMG in [0, 1]
    glyphs: Vec<Vec<f64>>,
    rng: Rng,
    /// pixel noise sigma
    pub noise: f64,
    /// max |translation| in pixels
    pub max_shift: i64,
}

impl SynthDigits {
    pub fn new(seed: u64) -> Self {
        // Class glyphs are UNIVERSAL (fixed seed): every generator, train
        // or eval, sees the same 10 classes; `seed` only drives the
        // per-sample jitter/noise stream.
        let mut grng = Rng::new(0xD1617);
        let glyphs = (0..N_CLASSES)
            .map(|c| Self::make_glyph(&mut grng, c))
            .collect();
        SynthDigits {
            glyphs,
            rng: Rng::new(seed),
            noise: 0.08,
            max_shift: 2,
        }
    }

    /// Class glyph: sparse random seeds smoothed by a box blur — blobby,
    /// class-distinctive patterns with full [0,1] dynamic range.
    fn make_glyph(rng: &mut Rng, _class: usize) -> Vec<f64> {
        let mut img = vec![0f64; IMG * IMG];
        // 6 random bright seeds
        for _ in 0..6 {
            let y = rng.int(2, (IMG - 2) as i64) as usize;
            let x = rng.int(2, (IMG - 2) as i64) as usize;
            img[y * IMG + x] = 1.0;
        }
        // two box-blur passes (3x3)
        for _ in 0..2 {
            let mut out = vec![0f64; IMG * IMG];
            for y in 0..IMG {
                for x in 0..IMG {
                    let mut acc = 0f64;
                    let mut n = 0f64;
                    for dy in -1i64..=1 {
                        for dx in -1i64..=1 {
                            let yy = y as i64 + dy;
                            let xx = x as i64 + dx;
                            if (0..IMG as i64).contains(&yy) && (0..IMG as i64).contains(&xx) {
                                acc += img[yy as usize * IMG + xx as usize];
                                n += 1.0;
                            }
                        }
                    }
                    out[y * IMG + x] = acc / n * 3.0;
                }
            }
            img = out;
        }
        let m = img.iter().cloned().fold(0.0f64, f64::max).max(1e-9);
        img.iter().map(|v| (v / m).min(1.0)).collect()
    }

    /// One sample of class `label`: translated, contrast-jittered, noisy,
    /// clamped to [0, 1). Values land on the 8-bit grid when quantized.
    pub fn sample(&mut self, label: usize) -> Vec<f32> {
        let dy = self.rng.int(-self.max_shift, self.max_shift + 1);
        let dx = self.rng.int(-self.max_shift, self.max_shift + 1);
        let contrast = self.rng.uniform(0.7, 1.0);
        let glyph = &self.glyphs[label];
        let mut out = vec![0f32; IMG * IMG];
        for y in 0..IMG as i64 {
            for x in 0..IMG as i64 {
                let sy = y - dy;
                let sx = x - dx;
                let base = if (0..IMG as i64).contains(&sy) && (0..IMG as i64).contains(&sx) {
                    glyph[(sy * IMG as i64 + sx) as usize]
                } else {
                    0.0
                };
                let v = base * contrast + self.rng.normal(0.0, self.noise);
                out[(y * IMG as i64 + x) as usize] = v.clamp(0.0, 0.999) as f32;
            }
        }
        out
    }

    /// A batch: ([B,1,16,16] images in [0,1), labels).
    pub fn batch(&mut self, b: usize) -> (TensorF, Vec<usize>) {
        let mut data = Vec::with_capacity(b * IMG * IMG);
        let mut labels = Vec::with_capacity(b);
        for _ in 0..b {
            let label = self.rng.int(0, N_CLASSES as i64) as usize;
            data.extend_from_slice(&self.sample(label));
            labels.push(label);
        }
        (Tensor::from_vec(&[b, 1, IMG, IMG], data), labels)
    }

    /// A fixed evaluation set (fresh generator, disjoint seed).
    pub fn eval_set(seed: u64, n: usize) -> (TensorF, Vec<usize>) {
        let mut gen = SynthDigits::new(seed ^ 0xE7A1_5EED);
        gen.batch(n)
    }
}

/// Classification accuracy from logits argmax vs labels.
pub fn accuracy(pred: &[usize], labels: &[usize]) -> f64 {
    assert_eq!(pred.len(), labels.len());
    let correct = pred.iter().zip(labels).filter(|(p, l)| p == l).count();
    correct as f64 / labels.len().max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_batches() {
        let mut a = SynthDigits::new(1);
        let mut b = SynthDigits::new(1);
        let (xa, la) = a.batch(8);
        let (xb, lb) = b.batch(8);
        assert_eq!(xa.data(), xb.data());
        assert_eq!(la, lb);
    }

    #[test]
    fn values_in_unit_range() {
        let mut g = SynthDigits::new(2);
        let (x, _) = g.batch(16);
        assert!(x.data().iter().all(|v| (0.0..1.0).contains(v)));
    }

    #[test]
    fn classes_are_distinguishable_by_nearest_glyph() {
        // A trivial nearest-template classifier should beat chance by a
        // lot — otherwise the dataset carries no signal.
        let mut g = SynthDigits::new(3);
        let glyphs = g.glyphs.clone();
        let (x, labels) = g.batch(200);
        let mut correct = 0;
        for (i, &label) in labels.iter().enumerate() {
            let img = &x.data()[i * IMG * IMG..(i + 1) * IMG * IMG];
            let mut best = (f64::INFINITY, 0usize);
            for (c, glyph) in glyphs.iter().enumerate() {
                let d: f64 = img
                    .iter()
                    .zip(glyph)
                    .map(|(a, b)| (*a as f64 - b) * (*a as f64 - b))
                    .sum();
                if d < best.0 {
                    best = (d, c);
                }
            }
            if best.1 == label {
                correct += 1;
            }
        }
        let acc = correct as f64 / 200.0;
        assert!(acc > 0.4, "nearest-glyph accuracy only {acc}"); // >4x chance
    }

    #[test]
    fn accuracy_helper() {
        assert_eq!(accuracy(&[1, 2, 3], &[1, 0, 3]), 2.0 / 3.0);
    }
}
