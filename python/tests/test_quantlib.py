"""Formal-claim tests for the quantization math (paper sec. 2-3)."""

import math

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import quantlib as ql

SETTINGS = dict(max_examples=50, deadline=None)
eps_strategy = st.floats(1e-7, 1e-1, allow_nan=False, allow_infinity=False)


@given(eps_a=eps_strategy, eps_b=eps_strategy,
       factor=st.sampled_from([16, 64, 256]))
@settings(**SETTINGS)
def test_choose_d_satisfies_eq14(eps_a, eps_b, factor):
    """d >= log2(eps_b / (eps_a * eta)), eta = 1/factor (Eq. 14)."""
    d = ql.choose_d(eps_a, eps_b, factor)
    if d < 40:  # not saturated at d_max
        assert eps_a * (2.0 ** d) >= factor * eps_b
        if d > 0:  # minimality: d-1 must violate the bound
            assert eps_a * (2.0 ** (d - 1)) < factor * eps_b


def test_choose_d_saturation_raises():
    """Mirror of Rust's RequantSaturation: an unreachable Eq. 14 bound is
    an error, not a silently wrong d = 40."""
    import pytest
    with pytest.raises(ValueError, match="saturated"):
        ql.choose_d(1e-300, 1.0, 16)


@given(eps_a=eps_strategy, eps_b=eps_strategy,
       factor=st.sampled_from([16, 256]), seed=st.integers(0, 2**31))
@settings(**SETTINGS)
def test_requant_relative_error_bound(eps_a, eps_b, factor, seed):
    """|eps_a/eps_b - m/2^d| * 2^d/m <= ... the paper's bound: the ratio
    error is < 1/D relative to eps_a/eps_b scaled by eta (sec. 3.2)."""
    d = ql.choose_d(eps_a, eps_b, factor)
    if d >= 40:
        return
    m = ql.requant_multiplier(eps_a, eps_b, d)
    ratio = eps_a / eps_b
    approx = m / (2.0 ** d)
    # error bound: |ratio - approx| < 1/2^d, and relative error <= 1/factor
    assert abs(ratio - approx) < 1.0 / (2.0 ** d) * (1 + 1e-12)
    assert abs(ratio - approx) / ratio <= 1.0 / factor + 1e-12


@given(seed=st.integers(0, 2**31), bits=st.sampled_from([2, 4, 8]))
@settings(**SETTINGS)
def test_pact_act_on_grid(seed, bits):
    """FakeQuantized activations take values on the eps_y grid in [0, beta]."""
    r = np.random.default_rng(seed)
    beta = float(r.uniform(0.5, 6.0))
    eps = beta / ((1 << bits) - 1)
    x = jnp.asarray(r.normal(0, 2, (500,)), jnp.float32)
    y = np.asarray(ql.pact_act(x, jnp.float32(beta), jnp.float32(eps)))
    q = y / eps
    assert np.allclose(q, np.round(q), atol=1e-3)
    assert (y >= 0).all() and (y <= beta + 1e-6).all()


def test_pact_act_ste_gradients():
    """STE: dL/dx = chi_[0,beta)(x); dL/dbeta = sum over clipped-high."""
    x = jnp.asarray([-1.0, 0.5, 1.5, 3.0], jnp.float32)
    beta = jnp.float32(2.0)
    eps = beta / 15.0

    gx, gb = jax.grad(lambda x_, b_: jnp.sum(ql.pact_act(x_, b_, eps)),
                      argnums=(0, 1))(x, beta)
    assert np.array_equal(np.asarray(gx), [0.0, 1.0, 1.0, 0.0])
    assert float(gb) == 1.0  # only x=3.0 is clipped at the top


def test_pact_weight_ste_gradients():
    w = jnp.asarray([-3.0, -0.5, 0.5, 3.0], jnp.float32)
    beta = jnp.float32(1.0)
    gw = jax.grad(lambda w_: jnp.sum(ql.pact_weight(w_, beta, 4)))(w)
    assert np.array_equal(np.asarray(gw), [0.0, 1.0, 1.0, 0.0])


@given(seed=st.integers(0, 2**31), bits=st.sampled_from([2, 4, 8]))
@settings(**SETTINGS)
def test_weight_quantization_error_bound(seed, bits):
    """|w - w_hat| <= eps_w inside the clipping range."""
    r = np.random.default_rng(seed)
    w = r.normal(0, 1, (200,))
    beta = float(np.max(np.abs(w)))
    spec = ql.QuantSpec.weight(beta, bits)
    q = np.clip(np.floor(w / spec.eps), spec.lo, spec.hi)
    w_hat = q * spec.eps
    inside = np.abs(w) < beta - spec.eps
    assert np.all(np.abs(w - w_hat)[inside] <= spec.eps * (1 + 1e-9))


@given(seed=st.integers(0, 2**31), nlev=st.sampled_from([3, 15, 255]))
@settings(max_examples=25, deadline=None)
def test_threshold_merge_exact(seed, nlev):
    """Eq. 19-20: integer thresholds reproduce BN + linear quantization
    EXACTLY over the full integer input range (the paper's proof)."""
    r = np.random.default_rng(seed)
    c = 4
    gamma = np.abs(r.normal(1, 0.3, c)) + 0.05
    sigma = np.abs(r.normal(1, 0.3, c)) + 0.05
    beta = r.normal(0, 0.5, c)
    mu = r.normal(0, 0.5, c)
    eps_phi = float(r.uniform(1e-5, 1e-3))
    eps_y = float(r.uniform(5e-3, 5e-2))

    th = ql.bn_thresholds(gamma, sigma, beta, mu, eps_phi, eps_y, nlev + 1)
    q_phi = r.integers(-2**18, 2**18, (300, c))

    # Reference: float BN then Eq. 10 linear quantization.
    phi_hat = eps_phi * q_phi
    bn = (gamma / sigma)[None, :] * (phi_hat - mu[None, :]) + beta[None, :]
    want = np.clip(np.floor(bn / eps_y), 0, nlev).astype(np.int64)

    got = np.clip(np.sum(q_phi[:, :, None] >= th.T[None, :, :].transpose(0, 2, 1),
                         axis=-1), 0, nlev)
    assert np.array_equal(got, want)


def test_fold_bn_exact():
    """Eq. 18: folded conv == conv + BN in full precision."""
    import jax

    r = np.random.default_rng(3)
    w = jnp.asarray(r.normal(0, 0.5, (4, 3, 3, 3)), jnp.float64)
    x = jnp.asarray(r.normal(0, 1, (2, 3, 8, 8)), jnp.float64)
    gamma = np.abs(r.normal(1, 0.2, 4)) + 0.05
    sigma = np.abs(r.normal(1, 0.2, 4)) + 0.05
    beta = r.normal(0, 0.3, 4)
    mu = r.normal(0, 0.3, 4)

    conv = lambda x_, w_: jax.lax.conv_general_dilated(
        x_, w_, (1, 1), ((1, 1), (1, 1)),
        dimension_numbers=("NCHW", "OIHW", "NCHW"))
    phi = np.asarray(conv(x, w))
    want = (gamma / sigma)[None, :, None, None] * (phi - mu[None, :, None, None]) \
        + beta[None, :, None, None]

    wf, bf = ql.fold_bn(np.asarray(w), None, gamma, sigma, beta, mu)
    got = np.asarray(conv(x, jnp.asarray(wf))) + bf[None, :, None, None]
    assert np.allclose(got, want, rtol=1e-12, atol=1e-12)


@given(seed=st.integers(0, 2**31))
@settings(**SETTINGS)
def test_maxpool_order_preservation(seed):
    """Sec. 3.6: quantization preserves relative order, so MaxPool commutes
    with the integer image."""
    r = np.random.default_rng(seed)
    t = r.normal(0, 1, (100,))
    eps = 0.03
    q = np.floor(np.clip(t, 0, 2.0) / eps)
    i, j = r.integers(0, 100, 2)
    if q[i] > q[j]:
        assert np.clip(t, 0, 2.0)[i] >= np.clip(t, 0, 2.0)[j] - eps


@given(k=st.sampled_from([2, 3, 4, 7]), d=st.integers(8, 24),
       seed=st.integers(0, 2**31))
@settings(**SETTINGS)
def test_avgpool_scaling_error(k, d, seed):
    """Eq. 25: the 2^d/(K1K2) approximation error is bounded by
    sum * (1/(K1K2) - floor(2^d/(K1K2))/2^d) < sum * K1K2 / 2^d."""
    r = np.random.default_rng(seed)
    acc = int(r.integers(0, 255 * k * k))
    m = (1 << d) // (k * k)
    got = (acc * m) >> d
    exact = acc / (k * k)
    assert got <= exact + 1e-9
    assert exact - got <= acc * (k * k) / (1 << d) / (k * k) + 1.0
