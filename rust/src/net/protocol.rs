//! The NEMO wire protocol: length-prefixed, checksummed frames over a
//! byte stream (DESIGN.md §Network-protocol).
//!
//! Frame layout (all integers little-endian):
//!
//! ```text
//! +---------+---------+--------+-------+----------+---------+
//! | magic   | version | opcode | flags | req_id   | len     |  20-byte header
//! | u32     | u16     | u8     | u8    | u64      | u32     |
//! +---------+---------+--------+-------+----------+---------+
//! | payload: len bytes                                      |
//! +---------------------------------------------------------+
//! | checksum: u64 = FNV-1a64(payload)                       |  8-byte trailer
//! +---------------------------------------------------------+
//! ```
//!
//! `magic` is `b"NEMO"`; `version` is [`WIRE_VERSION`]; `flags` is
//! reserved (must be 0). `req_id` is chosen by the client and echoed on
//! the reply, which is what makes request pipelining possible — replies
//! are matched by id, not by arrival order (the server answers in order,
//! but the client does not have to rely on it). The checksum reuses the
//! artifact format's [`fnv1a64`], so one hash guards both the at-rest
//! and in-flight model representations.
//!
//! Integer tensors cross the wire as dtype-tagged payloads at packed
//! precision — the same `u8`/`i8`/`i32` storage classes the artifact
//! format and [`QTensor`] use — and widen losslessly on the far side.
//! Because IntegerDeployable inference is bit-reproducible, a remote
//! reply is verifiable: the same artifact must produce the same bytes on
//! any machine.
//!
//! Error taxonomy: every failure a server can detect is answered with a
//! typed [`WireError`] reply frame ([`Opcode::ReplyErr`]), never a
//! silently dropped connection. Errors that leave the byte stream
//! desynchronized (malformed header, truncated frame, version mismatch,
//! oversized frame) are *fatal*: the server replies, then closes.
//! Payload-level errors (checksum mismatch, bad request, unknown model,
//! deadline exceeded) keep the connection usable.

use std::io::{self, Read, Write};

use crate::coordinator::{InferError, RegistryError};
use crate::io::fnv1a64;
use crate::quant::Precision;
use crate::tensor::{PackedTensor, QTensor, Tensor, TensorI};

/// `b"NEMO"` interpreted little-endian.
pub const MAGIC: u32 = u32::from_le_bytes(*b"NEMO");

/// Protocol version carried in every frame header. The header layout is
/// frozen across versions (compat policy: a v1 server can always *parse*
/// the header of any future frame and answer `VersionMismatch`).
pub const WIRE_VERSION: u16 = 1;

/// Frame header byte length (magic + version + opcode + flags + req_id
/// + payload len).
pub const HEADER_LEN: usize = 4 + 2 + 1 + 1 + 8 + 4;

/// Checksum trailer byte length.
pub const TRAILER_LEN: usize = 8;

/// Default cap on payload size — a declared length above this is a typed
/// `FrameTooLarge` error, not an allocation.
pub const MAX_PAYLOAD: u32 = 64 << 20;

/// Frame opcodes. Requests are < 0x80; replies have the top bit set.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum Opcode {
    /// Liveness/RTT probe. Empty payload both ways.
    Ping = 0x01,
    /// `infer(model, qtensor)` -> logits qtensor.
    Infer = 0x02,
    /// `infer_deadline(model, deadline_us, qtensor)` -> logits qtensor.
    InferDeadline = 0x03,
    /// `load_model(name, artifact_path)` -> version (1).
    LoadModel = 0x10,
    /// `swap_model(name, artifact_path)` -> new version.
    SwapModel = 0x11,
    /// `unload_model(name)` -> empty.
    UnloadModel = 0x12,
    /// `list_models()` -> sorted model table.
    ListModels = 0x13,
    /// `model_metrics(name)` -> counters + latency summaries.
    ModelMetrics = 0x14,
    /// Successful reply; payload is op-specific.
    ReplyOk = 0x80,
    /// Typed failure reply; payload is `u16 code + string message`.
    ReplyErr = 0x81,
}

impl Opcode {
    pub fn from_u8(b: u8) -> Option<Opcode> {
        Some(match b {
            0x01 => Opcode::Ping,
            0x02 => Opcode::Infer,
            0x03 => Opcode::InferDeadline,
            0x10 => Opcode::LoadModel,
            0x11 => Opcode::SwapModel,
            0x12 => Opcode::UnloadModel,
            0x13 => Opcode::ListModels,
            0x14 => Opcode::ModelMetrics,
            0x80 => Opcode::ReplyOk,
            0x81 => Opcode::ReplyErr,
            _ => return None,
        })
    }
}

/// Typed wire failure codes (stable numeric values — the compat surface
/// a newer client must keep decoding).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u16)]
pub enum WireCode {
    /// Model name not registered (or already unloaded).
    UnknownModel = 1,
    /// The request's deadline expired before a reply was produced.
    DeadlineExceeded = 2,
    /// Header/payload bytes that cannot be parsed (bad magic, truncated
    /// frame, short payload). Fatal: the stream is desynchronized.
    MalformedFrame = 3,
    /// Frame carried a protocol version this peer does not speak. Fatal.
    VersionMismatch = 4,
    /// FNV-1a64 trailer does not match the payload. Recoverable — the
    /// framing itself was intact.
    ChecksumMismatch = 5,
    /// Structurally valid frame with a semantically bad request (unknown
    /// opcode, bad tensor dims, duplicate name, ...). Recoverable.
    BadRequest = 6,
    /// The serving registry/coordinator is shutting down.
    ServerShutdown = 7,
    /// Declared payload length above the server's cap. Fatal (the
    /// payload is never read).
    FrameTooLarge = 8,
    /// Any other server-side failure, with the message carrying context.
    Internal = 9,
}

impl WireCode {
    pub fn from_u16(v: u16) -> Option<WireCode> {
        Some(match v {
            1 => WireCode::UnknownModel,
            2 => WireCode::DeadlineExceeded,
            3 => WireCode::MalformedFrame,
            4 => WireCode::VersionMismatch,
            5 => WireCode::ChecksumMismatch,
            6 => WireCode::BadRequest,
            7 => WireCode::ServerShutdown,
            8 => WireCode::FrameTooLarge,
            9 => WireCode::Internal,
            _ => return None,
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            WireCode::UnknownModel => "unknown-model",
            WireCode::DeadlineExceeded => "deadline-exceeded",
            WireCode::MalformedFrame => "malformed-frame",
            WireCode::VersionMismatch => "version-mismatch",
            WireCode::ChecksumMismatch => "checksum-mismatch",
            WireCode::BadRequest => "bad-request",
            WireCode::ServerShutdown => "server-shutdown",
            WireCode::FrameTooLarge => "frame-too-large",
            WireCode::Internal => "internal",
        }
    }
}

/// A typed protocol-level failure: what a `ReplyErr` frame carries, and
/// what [`crate::net::NemoClient`] surfaces (recover with
/// `err.downcast_ref::<WireError>()`).
#[derive(Clone, Debug, thiserror::Error)]
#[error("wire error [{}]: {message}", self.code.name())]
pub struct WireError {
    pub code: WireCode,
    pub message: String,
}

impl WireError {
    pub fn new(code: WireCode, message: impl Into<String>) -> Self {
        WireError { code, message: message.into() }
    }

    /// Whether the byte stream is desynchronized after this error — the
    /// server replies and then must close the connection.
    pub fn fatal(&self) -> bool {
        matches!(
            self.code,
            WireCode::MalformedFrame
                | WireCode::VersionMismatch
                | WireCode::FrameTooLarge
        )
    }

    /// Map a serving-side failure to its wire representation, preserving
    /// the typed registry/inference errors the coordinator produces.
    pub fn from_serving(err: &anyhow::Error) -> WireError {
        if let Some(r) = err.downcast_ref::<RegistryError>() {
            let code = match r {
                RegistryError::UnknownModel(_) => WireCode::UnknownModel,
                RegistryError::DuplicateName(_) => WireCode::BadRequest,
            };
            return WireError::new(code, r.to_string());
        }
        if let Some(i) = err.downcast_ref::<InferError>() {
            let code = match i {
                InferError::DeadlineExceeded(_) => WireCode::DeadlineExceeded,
                InferError::ServerStopped => WireCode::ServerShutdown,
            };
            return WireError::new(code, i.to_string());
        }
        WireError::new(WireCode::Internal, format!("{err:#}"))
    }
}

fn malformed(msg: impl Into<String>) -> WireError {
    WireError::new(WireCode::MalformedFrame, msg)
}

/// One protocol frame (header fields + payload; the checksum trailer is
/// computed on encode and verified on decode).
#[derive(Clone, Debug, PartialEq)]
pub struct Frame {
    pub opcode: Opcode,
    pub req_id: u64,
    pub payload: Vec<u8>,
}

impl Frame {
    pub fn new(opcode: Opcode, req_id: u64, payload: Vec<u8>) -> Self {
        Frame { opcode, req_id, payload }
    }

    /// Serialize header + payload + checksum trailer.
    pub fn encode(&self) -> Vec<u8> {
        let mut out =
            Vec::with_capacity(HEADER_LEN + self.payload.len() + TRAILER_LEN);
        out.extend_from_slice(&MAGIC.to_le_bytes());
        out.extend_from_slice(&WIRE_VERSION.to_le_bytes());
        out.push(self.opcode as u8);
        out.push(0); // flags (reserved)
        out.extend_from_slice(&self.req_id.to_le_bytes());
        out.extend_from_slice(&(self.payload.len() as u32).to_le_bytes());
        out.extend_from_slice(&self.payload);
        out.extend_from_slice(&fnv1a64(&self.payload).to_le_bytes());
        out
    }

    /// Write the encoded frame to a stream.
    pub fn write_to(&self, w: &mut impl Write) -> io::Result<()> {
        w.write_all(&self.encode())?;
        w.flush()
    }
}

/// A parsed frame header (the fixed 20 bytes), before the payload is
/// read. Kept separate so servers can reject oversized/mismatched frames
/// without touching the payload.
#[derive(Clone, Copy, Debug)]
pub struct Header {
    pub version: u16,
    pub opcode_raw: u8,
    pub req_id: u64,
    pub payload_len: u32,
}

impl Header {
    /// Parse the fixed-size header. `max_payload` caps the declared
    /// length. Magic/version/flags violations come back as typed, fatal
    /// [`WireError`]s.
    pub fn parse(buf: &[u8; HEADER_LEN], max_payload: u32) -> Result<Header, WireError> {
        let magic = u32::from_le_bytes(buf[0..4].try_into().unwrap());
        if magic != MAGIC {
            return Err(malformed(format!(
                "bad magic {magic:#010x} (expected {MAGIC:#010x} = \"NEMO\")"
            )));
        }
        let version = u16::from_le_bytes(buf[4..6].try_into().unwrap());
        let opcode_raw = buf[6];
        let flags = buf[7];
        let req_id = u64::from_le_bytes(buf[8..16].try_into().unwrap());
        let payload_len = u32::from_le_bytes(buf[16..20].try_into().unwrap());
        if version != WIRE_VERSION {
            return Err(WireError::new(
                WireCode::VersionMismatch,
                format!(
                    "frame speaks protocol v{version}, this peer speaks v{WIRE_VERSION}"
                ),
            ));
        }
        if flags != 0 {
            return Err(malformed(format!("reserved flags byte is {flags:#04x}")));
        }
        if payload_len > max_payload {
            return Err(WireError::new(
                WireCode::FrameTooLarge,
                format!(
                    "declared payload of {payload_len} bytes exceeds the \
                     {max_payload}-byte cap"
                ),
            ));
        }
        Ok(Header { version, opcode_raw, req_id, payload_len })
    }
}

/// Read one frame from a blocking stream (client side — the server uses
/// its own poll-aware loop). Verifies magic, version, size cap and
/// checksum; unknown opcodes are malformed here because a client only
/// ever expects replies.
pub fn read_frame(r: &mut impl Read, max_payload: u32) -> Result<Frame, WireError> {
    let mut hdr = [0u8; HEADER_LEN];
    r.read_exact(&mut hdr)
        .map_err(|e| malformed(format!("reading frame header: {e}")))?;
    let h = Header::parse(&hdr, max_payload)?;
    let mut payload = vec![0u8; h.payload_len as usize];
    r.read_exact(&mut payload)
        .map_err(|e| malformed(format!("reading {}-byte payload: {e}", h.payload_len)))?;
    let mut trailer = [0u8; TRAILER_LEN];
    r.read_exact(&mut trailer)
        .map_err(|e| malformed(format!("reading checksum trailer: {e}")))?;
    let want = u64::from_le_bytes(trailer);
    let got = fnv1a64(&payload);
    if want != got {
        return Err(WireError::new(
            WireCode::ChecksumMismatch,
            format!("payload checksum {got:#018x} != trailer {want:#018x}"),
        ));
    }
    let opcode = Opcode::from_u8(h.opcode_raw)
        .ok_or_else(|| malformed(format!("unknown opcode {:#04x}", h.opcode_raw)))?;
    Ok(Frame { opcode, req_id: h.req_id, payload })
}

// ---------------------------------------------------------------------------
// Payload codec
// ---------------------------------------------------------------------------

/// Append-only payload writer with the protocol's primitive encodings.
#[derive(Default)]
pub struct PayloadWriter(Vec<u8>);

impl PayloadWriter {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn finish(self) -> Vec<u8> {
        self.0
    }

    pub fn put_u8(&mut self, v: u8) {
        self.0.push(v);
    }

    pub fn put_u16(&mut self, v: u16) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_u32(&mut self, v: u32) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_u64(&mut self, v: u64) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_f64(&mut self, v: f64) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }

    /// Length-prefixed UTF-8 string (u32 length).
    pub fn put_str(&mut self, s: &str) {
        self.put_u32(s.len() as u32);
        self.0.extend_from_slice(s.as_bytes());
    }

    /// Dtype-tagged integer tensor at packed precision: `dtype u8, ndim
    /// u8, dims u32×ndim, data` where data is 1 byte/element for
    /// `u8`/`i8`, 4 LE bytes for `i32`, and the raw LSB-first bit-packed
    /// payload (`Precision::storage_bytes`, 2–8 elements per byte) for
    /// the sub-byte dtypes — the wire twin of the artifact format's
    /// dtype-tagged weight payloads.
    pub fn put_qtensor(&mut self, t: &QTensor) {
        self.put_u8(dtype_tag(t.precision()));
        let shape = t.shape();
        self.put_u8(shape.len() as u8);
        for d in shape {
            self.put_u32(*d as u32);
        }
        match t {
            QTensor::U8(t) => self.0.extend_from_slice(t.data()),
            QTensor::I8(t) => {
                self.0.extend(t.data().iter().map(|v| *v as u8));
            }
            QTensor::I32(t) => {
                for v in t.data() {
                    self.0.extend_from_slice(&v.to_le_bytes());
                }
            }
            QTensor::Packed(t) => self.0.extend_from_slice(t.bytes()),
        }
    }
}

/// Sequential payload reader; every getter fails typed (malformed frame)
/// on truncation instead of panicking.
pub struct PayloadReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> PayloadReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        PayloadReader { buf, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// The payload must be fully consumed — trailing bytes mean the
    /// peer and we disagree about the encoding.
    pub fn expect_end(&self) -> Result<(), WireError> {
        if self.remaining() != 0 {
            return Err(malformed(format!(
                "{} unexpected trailing payload bytes",
                self.remaining()
            )));
        }
        Ok(())
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], WireError> {
        if self.remaining() < n {
            return Err(malformed(format!(
                "payload truncated: wanted {n} more bytes, have {}",
                self.remaining()
            )));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn get_u8(&mut self) -> Result<u8, WireError> {
        Ok(self.take(1)?[0])
    }

    pub fn get_u16(&mut self) -> Result<u16, WireError> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    pub fn get_u32(&mut self) -> Result<u32, WireError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn get_u64(&mut self) -> Result<u64, WireError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn get_f64(&mut self) -> Result<f64, WireError> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn get_str(&mut self) -> Result<String, WireError> {
        let n = self.get_u32()? as usize;
        let bytes = self.take(n)?;
        String::from_utf8(bytes.to_vec())
            .map_err(|e| malformed(format!("string payload is not UTF-8: {e}")))
    }

    /// Decode a dtype-tagged tensor (see [`PayloadWriter::put_qtensor`]).
    pub fn get_qtensor(&mut self) -> Result<QTensor, WireError> {
        let tag = self.get_u8()?;
        let p = precision_of_tag(tag)
            .ok_or_else(|| malformed(format!("unknown tensor dtype tag {tag}")))?;
        let ndim = self.get_u8()? as usize;
        if ndim > 8 {
            return Err(malformed(format!("tensor rank {ndim} exceeds the cap of 8")));
        }
        let mut shape = Vec::with_capacity(ndim);
        let mut len: usize = 1;
        for _ in 0..ndim {
            let d = self.get_u32()? as usize;
            len = len.checked_mul(d).ok_or_else(|| {
                malformed("tensor element count overflows usize".to_string())
            })?;
            shape.push(d);
        }
        if len > MAX_PAYLOAD as usize {
            return Err(malformed(format!(
                "tensor with {len} elements exceeds the payload cap"
            )));
        }
        Ok(match p {
            Precision::U8 => {
                let data = self.take(len)?.to_vec();
                QTensor::U8(Tensor::from_vec(&shape, data))
            }
            Precision::I8 => {
                let data = self.take(len)?.iter().map(|b| *b as i8).collect();
                QTensor::I8(Tensor::from_vec(&shape, data))
            }
            Precision::I32 => {
                let bytes = self.take(len * 4)?;
                let data = bytes
                    .chunks_exact(4)
                    .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
                    .collect();
                QTensor::I32(Tensor::from_vec(&shape, data))
            }
            Precision::U1 | Precision::U2 | Precision::U4 | Precision::I4 => {
                let data = self.take(p.storage_bytes(len))?.to_vec();
                let t = PackedTensor::from_bytes(&shape, p, data)
                    .map_err(|e| malformed(format!("packed tensor payload: {e}")))?;
                QTensor::Packed(t)
            }
        })
    }
}

/// Wire dtype tag for a storage precision (0=u8, 1=i8, 2=i32, 3=u4,
/// 4=u2, 5=u1, 6=i4; the numeric twin of the artifact format's
/// `Precision::name()` strings). Sub-byte tags extend the v1 table —
/// old peers reject them as unknown dtypes, which is the correct typed
/// failure for a frame they cannot decode.
pub fn dtype_tag(p: Precision) -> u8 {
    match p {
        Precision::U8 => 0,
        Precision::I8 => 1,
        Precision::I32 => 2,
        Precision::U4 => 3,
        Precision::U2 => 4,
        Precision::U1 => 5,
        Precision::I4 => 6,
    }
}

pub fn precision_of_tag(tag: u8) -> Option<Precision> {
    Some(match tag {
        0 => Precision::U8,
        1 => Precision::I8,
        2 => Precision::I32,
        3 => Precision::U4,
        4 => Precision::U2,
        5 => Precision::U1,
        6 => Precision::I4,
        _ => return None,
    })
}

/// Narrow an i32 integer image to the tightest lossless wire precision
/// (the value-range twin of the deploy-time precision proof): few-bit
/// images bit-pack down to `u1`/`u2`/`u4`/`i4` (2–8 elements per
/// byte), byte-range images cross at 1 byte/element, everything else
/// stays wide. Always lossless — `widen()` on the far side restores the
/// exact i32 image.
pub fn pack_lossless(t: &TensorI) -> QTensor {
    let (mut lo, mut hi) = (i64::MAX, i64::MIN);
    for &v in t.data() {
        lo = lo.min(v as i64);
        hi = hi.max(v as i64);
    }
    if t.is_empty() {
        return QTensor::I32(t.clone());
    }
    let p = Precision::for_range(lo, hi);
    // In-range by construction, but route the error anyway: a silent
    // unwrap here would turn a future range bug into a panic on the
    // serving path.
    QTensor::narrow_from(t, p).unwrap_or_else(|_| QTensor::I32(t.clone()))
}

// ---------------------------------------------------------------------------
// Op payload schemas (shared by server and client)
// ---------------------------------------------------------------------------

/// `ListModels` reply row — the wire twin of
/// [`crate::coordinator::ModelInfo`] (provenance flattened to a string).
/// Rows are sorted by name; the registry guarantees it and the protocol
/// documents it, so CLI output and tests are stable across runs.
#[derive(Clone, Debug, PartialEq)]
pub struct WireModelInfo {
    pub name: String,
    pub version: u64,
    pub backend: String,
    pub input_shape: Vec<usize>,
    pub max_batch: u32,
    pub provenance: String,
}

pub fn encode_model_infos(infos: &[WireModelInfo]) -> Vec<u8> {
    let mut w = PayloadWriter::new();
    w.put_u32(infos.len() as u32);
    for i in infos {
        w.put_str(&i.name);
        w.put_u64(i.version);
        w.put_str(&i.backend);
        w.put_u8(i.input_shape.len() as u8);
        for d in &i.input_shape {
            w.put_u32(*d as u32);
        }
        w.put_u32(i.max_batch);
        w.put_str(&i.provenance);
    }
    w.finish()
}

pub fn decode_model_infos(payload: &[u8]) -> Result<Vec<WireModelInfo>, WireError> {
    let mut r = PayloadReader::new(payload);
    let n = r.get_u32()? as usize;
    let mut out = Vec::with_capacity(n.min(1024));
    for _ in 0..n {
        let name = r.get_str()?;
        let version = r.get_u64()?;
        let backend = r.get_str()?;
        let ndim = r.get_u8()? as usize;
        let mut input_shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            input_shape.push(r.get_u32()? as usize);
        }
        let max_batch = r.get_u32()?;
        let provenance = r.get_str()?;
        out.push(WireModelInfo {
            name,
            version,
            backend,
            input_shape,
            max_batch,
            provenance,
        });
    }
    r.expect_end()?;
    Ok(out)
}

/// Five-number summary of one latency/size distribution as it crosses
/// the wire (full sample vectors stay server-side).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct WireStat {
    pub count: u64,
    pub mean: f64,
    pub p50: f64,
    pub p99: f64,
    pub max: f64,
}

impl WireStat {
    fn of(s: &mut crate::util::stats::Samples) -> WireStat {
        if s.is_empty() {
            // Samples reports NaN for empty distributions; on the wire
            // that would break bit-determinism (NaN != NaN), so an empty
            // summary is all-zeros with count = 0.
            return WireStat::default();
        }
        WireStat {
            count: s.len() as u64,
            mean: s.mean(),
            p50: s.percentile(0.5),
            p99: s.percentile(0.99),
            max: s.max(),
        }
    }

    fn put(&self, w: &mut PayloadWriter) {
        w.put_u64(self.count);
        w.put_f64(self.mean);
        w.put_f64(self.p50);
        w.put_f64(self.p99);
        w.put_f64(self.max);
    }

    fn get(r: &mut PayloadReader) -> Result<WireStat, WireError> {
        Ok(WireStat {
            count: r.get_u64()?,
            mean: r.get_f64()?,
            p50: r.get_f64()?,
            p99: r.get_f64()?,
            max: r.get_f64()?,
        })
    }

    pub fn summary(&self) -> String {
        if self.count == 0 {
            return "n=0".to_string();
        }
        format!(
            "n={} mean={:.6} p50={:.6} p99={:.6} max={:.6}",
            self.count, self.mean, self.p50, self.p99, self.max
        )
    }
}

/// `ModelMetrics` reply — counters plus summarized distributions of one
/// model's [`crate::coordinator::Metrics`] ledger.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct WireMetrics {
    pub completed: u64,
    pub failed: u64,
    pub padded: u64,
    pub e2e_latency: WireStat,
    pub exec_time: WireStat,
    pub queue_wait: WireStat,
    pub batch_sizes: WireStat,
}

impl WireMetrics {
    pub fn from_metrics(m: &mut crate::coordinator::Metrics) -> WireMetrics {
        WireMetrics {
            completed: m.completed,
            failed: m.failed,
            padded: m.padded,
            e2e_latency: WireStat::of(&mut m.e2e_latency),
            exec_time: WireStat::of(&mut m.exec_time),
            queue_wait: WireStat::of(&mut m.queue_wait),
            batch_sizes: WireStat::of(&mut m.batch_sizes),
        }
    }

    pub fn encode(&self) -> Vec<u8> {
        let mut w = PayloadWriter::new();
        w.put_u64(self.completed);
        w.put_u64(self.failed);
        w.put_u64(self.padded);
        self.e2e_latency.put(&mut w);
        self.exec_time.put(&mut w);
        self.queue_wait.put(&mut w);
        self.batch_sizes.put(&mut w);
        w.finish()
    }

    pub fn decode(payload: &[u8]) -> Result<WireMetrics, WireError> {
        let mut r = PayloadReader::new(payload);
        let m = WireMetrics {
            completed: r.get_u64()?,
            failed: r.get_u64()?,
            padded: r.get_u64()?,
            e2e_latency: WireStat::get(&mut r)?,
            exec_time: WireStat::get(&mut r)?,
            queue_wait: WireStat::get(&mut r)?,
            batch_sizes: WireStat::get(&mut r)?,
        };
        r.expect_end()?;
        Ok(m)
    }

    pub fn report(&self) -> String {
        format!(
            "completed={} failed={} padded={}\n\
             e2e_latency (s): {}\nexec_time   (s): {}\n\
             queue_wait  (s): {}\nbatch size     : {}",
            self.completed,
            self.failed,
            self.padded,
            self.e2e_latency.summary(),
            self.exec_time.summary(),
            self.queue_wait.summary(),
            self.batch_sizes.summary()
        )
    }
}

/// Encode a `ReplyErr` payload.
pub fn encode_error(e: &WireError) -> Vec<u8> {
    let mut w = PayloadWriter::new();
    w.put_u16(e.code as u16);
    w.put_str(&e.message);
    w.finish()
}

/// Decode a `ReplyErr` payload.
pub fn decode_error(payload: &[u8]) -> WireError {
    fn parse(r: &mut PayloadReader) -> Result<WireError, WireError> {
        let raw = r.get_u16()?;
        let code = WireCode::from_u16(raw)
            .ok_or_else(|| malformed(format!("unknown error code {raw}")))?;
        let message = r.get_str()?;
        Ok(WireError { code, message })
    }
    let mut r = PayloadReader::new(payload);
    parse(&mut r).unwrap_or_else(|e| e)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frame_round_trips_through_encode_and_read() {
        let f = Frame::new(Opcode::Infer, 42, vec![1, 2, 3, 4, 5]);
        let bytes = f.encode();
        assert_eq!(bytes.len(), HEADER_LEN + 5 + TRAILER_LEN);
        let got = read_frame(&mut bytes.as_slice(), MAX_PAYLOAD).unwrap();
        assert_eq!(got, f);
    }

    #[test]
    fn corrupted_checksum_is_typed() {
        let f = Frame::new(Opcode::Ping, 7, vec![9, 9]);
        let mut bytes = f.encode();
        let last = bytes.len() - 1;
        bytes[last] ^= 0xff;
        let err = read_frame(&mut bytes.as_slice(), MAX_PAYLOAD).unwrap_err();
        assert_eq!(err.code, WireCode::ChecksumMismatch);
        assert!(!err.fatal());
    }

    #[test]
    fn bad_magic_and_version_are_typed_and_fatal() {
        let f = Frame::new(Opcode::Ping, 1, vec![]);
        let mut bytes = f.encode();
        bytes[0] = b'X';
        let err = read_frame(&mut bytes.as_slice(), MAX_PAYLOAD).unwrap_err();
        assert_eq!(err.code, WireCode::MalformedFrame);
        assert!(err.fatal());

        let mut bytes = f.encode();
        bytes[4] = 99; // version LE low byte
        let err = read_frame(&mut bytes.as_slice(), MAX_PAYLOAD).unwrap_err();
        assert_eq!(err.code, WireCode::VersionMismatch);
        assert!(err.fatal());
    }

    #[test]
    fn oversized_declared_payload_is_typed() {
        let f = Frame::new(Opcode::Ping, 1, vec![0; 100]);
        let bytes = f.encode();
        let err = read_frame(&mut bytes.as_slice(), 10).unwrap_err();
        assert_eq!(err.code, WireCode::FrameTooLarge);
        assert!(err.fatal());
    }

    #[test]
    fn qtensor_round_trips_at_every_precision() {
        let sub = |p, shape: &[usize], vals: &[i32]| {
            QTensor::narrow_from(&Tensor::from_vec(shape, vals.to_vec()), p).unwrap()
        };
        let cases = [
            QTensor::U8(Tensor::from_vec(&[2, 2], vec![0u8, 1, 254, 255])),
            QTensor::I8(Tensor::from_vec(&[3], vec![-128i8, 0, 127])),
            QTensor::I32(Tensor::from_vec(&[2], vec![i32::MIN, i32::MAX])),
            sub(Precision::U1, &[9], &[1, 0, 1, 1, 0, 0, 1, 0, 1]),
            sub(Precision::U2, &[2, 3], &[0, 3, 1, 2, 3, 0]),
            sub(Precision::U4, &[5], &[0, 15, 7, 8, 1]),
            sub(Precision::I4, &[4], &[-8, 7, -1, 0]),
        ];
        for t in cases {
            let mut w = PayloadWriter::new();
            w.put_qtensor(&t);
            let bytes = w.finish();
            let mut r = PayloadReader::new(&bytes);
            let got = r.get_qtensor().unwrap();
            r.expect_end().unwrap();
            assert_eq!(got, t);
        }
    }

    #[test]
    fn pack_lossless_picks_the_tightest_precision() {
        use crate::quant::Precision;
        let t = Tensor::from_vec(&[2], vec![0, 1]);
        assert_eq!(pack_lossless(&t).precision(), Precision::U1);
        let t = Tensor::from_vec(&[2], vec![0, 3]);
        assert_eq!(pack_lossless(&t).precision(), Precision::U2);
        let t = Tensor::from_vec(&[2], vec![0, 15]);
        assert_eq!(pack_lossless(&t).precision(), Precision::U4);
        let t = Tensor::from_vec(&[2], vec![-8, 7]);
        assert_eq!(pack_lossless(&t).precision(), Precision::I4);
        let t = Tensor::from_vec(&[2], vec![0, 255]);
        assert_eq!(pack_lossless(&t).precision(), Precision::U8);
        let t = Tensor::from_vec(&[2], vec![-1, 127]);
        assert_eq!(pack_lossless(&t).precision(), Precision::I8);
        let t = Tensor::from_vec(&[2], vec![-1, 128]);
        assert_eq!(pack_lossless(&t).precision(), Precision::I32);
        // and is always lossless
        for t in [
            Tensor::from_vec(&[3], vec![-70000, 0, 70000]),
            Tensor::from_vec(&[2], vec![12, 200]),
        ] {
            assert_eq!(pack_lossless(&t).widen(), t);
        }
    }

    #[test]
    fn truncated_payload_reader_is_typed() {
        let mut r = PayloadReader::new(&[1, 0]);
        assert!(r.get_u32().is_err());
        let mut w = PayloadWriter::new();
        w.put_str("hello");
        let bytes = w.finish();
        let mut r = PayloadReader::new(&bytes[..bytes.len() - 1]);
        let err = r.get_str().unwrap_err();
        assert_eq!(err.code, WireCode::MalformedFrame);
    }

    #[test]
    fn model_infos_and_metrics_round_trip() {
        let infos = vec![
            WireModelInfo {
                name: "alpha".into(),
                version: 3,
                backend: "native-int".into(),
                input_shape: vec![1, 12, 12],
                max_batch: 16,
                provenance: "in-memory".into(),
            },
            WireModelInfo {
                name: "zeta".into(),
                version: 1,
                backend: "native-int".into(),
                input_shape: vec![12],
                max_batch: 8,
                provenance: "artifact x.nemo.json".into(),
            },
        ];
        let got = decode_model_infos(&encode_model_infos(&infos)).unwrap();
        assert_eq!(got, infos);

        let mut m = crate::coordinator::Metrics::new();
        m.completed = 11;
        m.failed = 2;
        m.e2e_latency.push(0.5);
        m.e2e_latency.push(1.5);
        let wm = WireMetrics::from_metrics(&mut m);
        let got = WireMetrics::decode(&wm.encode()).unwrap();
        assert_eq!(got, wm);
        assert_eq!(got.completed, 11);
        assert_eq!(got.e2e_latency.count, 2);
        assert!((got.e2e_latency.mean - 1.0).abs() < 1e-12);
    }

    #[test]
    fn error_payload_round_trips() {
        let e = WireError::new(WireCode::UnknownModel, "model 'x' not found");
        let got = decode_error(&encode_error(&e));
        assert_eq!(got.code, WireCode::UnknownModel);
        assert_eq!(got.message, "model 'x' not found");
    }
}
