//! Remote serving demo: the two-terminal `serve --listen` / `client`
//! flow collapsed into one process over a loopback socket.
//!
//!     cargo run --release --example remote_serving
//!
//! The flow mirrors a networked deployment of the paper's
//! IntegerDeployable artifacts: deploy a net to `*.nemo.json`, serve it
//! through the coordinator, expose the coordinator on a TCP port with
//! [`NetServer`], and drive it with [`NemoClient`] — ping, list,
//! single and pipelined inference, a zero-downtime remote hot swap,
//! and metrics. Because integer inference is bit-reproducible, the
//! demo *asserts* that remote logits equal the in-process engine's,
//! byte for byte, before and after the swap.

use std::time::Instant;

use nemo::coordinator::{Server, ServerConfig};
use nemo::data::SynthDigits;
use nemo::model::synthnet::{SynthNet, EPS_IN};
use nemo::net::{NemoClient, NetConfig, NetServer};
use nemo::network::{IntegerDeployable, Network};
use nemo::quant::quantize_input;
use nemo::transform::DeployOptions;
use nemo::util::rng::Rng;

fn deploy_to(
    seed: u64,
    path: &std::path::Path,
) -> anyhow::Result<Network<IntegerDeployable>> {
    let mut rng = Rng::new(seed);
    let net = SynthNet::init(&mut rng);
    let nid = net.to_network(8)?.deploy(DeployOptions::default())?.integerize();
    nid.save_deployed(path)?;
    Ok(nid)
}

fn main() -> anyhow::Result<()> {
    let dir = std::env::temp_dir();
    let pid = std::process::id();
    let path_a = dir.join(format!("remote_serving_a_{pid}.nemo.json"));
    let path_b = dir.join(format!("remote_serving_b_{pid}.nemo.json"));
    let nid_a = deploy_to(31, &path_a)?;
    let nid_b = deploy_to(32, &path_b)?;

    // "Terminal 1": serve artifact A over a loopback socket.
    let server = Server::builder()
        .default_config(ServerConfig { max_batch: 8, ..ServerConfig::default() })
        .model_from_artifact("digits", &path_a)
        .start()?;
    let ns = NetServer::bind("127.0.0.1:0", server.handle(), NetConfig::default())?;
    println!("serving on {}", ns.local_addr());

    // "Terminal 2": a remote client.
    let mut client = NemoClient::connect(ns.local_addr())?;
    let t = Instant::now();
    client.ping()?;
    println!("ping: {:.3} ms round-trip", t.elapsed().as_secs_f64() * 1e3);
    for m in client.list_models()? {
        println!("  '{}' v{} backend={} input={:?}", m.name, m.version, m.backend, m.input_shape);
    }

    // Remote inference is bit-identical to the in-process engine.
    let mut data = SynthDigits::new(7000);
    let (x, _) = data.batch(1);
    let qx = quantize_input(&x, EPS_IN);
    let remote = client.infer("digits", &qx)?;
    anyhow::ensure!(
        remote.data() == nid_a.run(&qx).data(),
        "remote logits must be bit-identical to the engine"
    );
    println!("remote logits == in-process engine: bit-exact");

    // Pipelined inference: one connection, n requests in flight.
    let inputs: Vec<_> = (0..16)
        .map(|_| {
            let (x, _) = data.batch(1);
            quantize_input(&x, EPS_IN)
        })
        .collect();
    let t = Instant::now();
    let outs = client.infer_pipelined("digits", &inputs)?;
    println!(
        "pipelined {} requests in {:.2} ms",
        outs.len(),
        t.elapsed().as_secs_f64() * 1e3
    );

    // Zero-downtime remote hot swap to artifact B, then re-verify.
    let version = client.swap_model("digits", path_b.to_str().unwrap())?;
    println!("remote hot swap -> artifact B (now v{version})");
    let remote = client.infer("digits", &qx)?;
    anyhow::ensure!(
        remote.data() == nid_b.run(&qx).data(),
        "post-swap remote logits must match artifact B"
    );
    println!("post-swap remote logits == artifact B engine: bit-exact");

    println!("\nremote metrics for 'digits':\n{}", client.model_metrics("digits")?.report());

    // Drain: socket layer first (in-flight replies go out), then the
    // coordinator (in-flight batches finish and are accounted).
    ns.stop();
    let m = server.stop();
    println!("drained: completed={} failed={}", m.completed, m.failed);

    let _ = std::fs::remove_file(&path_a);
    let _ = std::fs::remove_file(&path_b);
    Ok(())
}
