/* Dependency-free C mirror of the NEMO integer inference kernels, used to
 * produce the committed BENCH_plan.json / BENCH_packed.json /
 * BENCH_subbyte.json baselines on build hosts that have a C compiler but
 * no Rust toolchain. The loop structure mirrors rust/src/tensor/ops.rs:
 *
 *   - gemm_i32 / gemm_u8i8 : matmul_q_fused_into's MAC loop (accumulator
 *     row, zero-activation skip, wrapping i32 adds);
 *   - gemm_bitserial       : matmul_bitserial_fused_into (LSB-first packed
 *     activations, two's-complement weight bit-planes, AND+popcount);
 *   - gemm_nibble          : matmul_subbyte_fused_into (unpack a nibble row,
 *     then the byte MAC loop);
 *   - the e2e section      : the deployed synthnet shapes (conv1 1->8 s1,
 *     conv2 8->16 s2, conv3 16->32 s2 on 16x16 inputs, avgpool k4, fc
 *     32->10) run three ways: per-node interpreted (fresh buffers, unfused
 *     BN/requant passes), planned wide (reused i32 arena, fused epilogue)
 *     and planned packed (reused u8 arena, u8 x i8 GEMM).
 *
 * Build and run:   cc -O3 -march=native -o subbyte_mirror tools/subbyte_mirror.c && ./subbyte_mirror
 *
 * Each timing is a warmup + min-time loop (util::timer::bench's protocol).
 * The program asserts that every kernel variant produces bit-identical
 * outputs before timing it, then prints one JSON object per bench section.
 */
#include <stdint.h>
#include <stdio.h>
#include <stdlib.h>
#include <string.h>
#include <time.h>

static double now_s(void) {
    struct timespec ts;
    clock_gettime(CLOCK_MONOTONIC, &ts);
    return (double)ts.tv_sec + (double)ts.tv_nsec * 1e-9;
}

/* xorshift64* — any deterministic stream works; values only need to cover
 * the quantized ranges. */
static uint64_t rng_state = 0x9E3779B97F4A7C15ull;
static uint64_t rng_next(void) {
    uint64_t x = rng_state;
    x ^= x >> 12;
    x ^= x << 25;
    x ^= x >> 27;
    rng_state = x;
    return x * 0x2545F4914F6CDD1Dull;
}
/* uniform in [lo, hi) like util::rng::Rng::int */
static int64_t rng_int(int64_t lo, int64_t hi) {
    return lo + (int64_t)(rng_next() % (uint64_t)(hi - lo));
}

/* warmup twice, then loop until min_time has elapsed */
#define BENCH(t_out, min_time, stmt)                                         \
    do {                                                                     \
        stmt;                                                                \
        stmt;                                                                \
        double _t0 = now_s();                                                \
        long _iters = 0;                                                     \
        double _el;                                                          \
        do {                                                                 \
            stmt;                                                            \
            _iters++;                                                        \
            _el = now_s() - _t0;                                             \
        } while (_el < (min_time));                                          \
        (t_out) = _el / (double)_iters;                                      \
    } while (0)

/* ------------------------------------------------------------------ */
/* kernels (mirrors of rust/src/tensor/ops.rs)                         */
/* ------------------------------------------------------------------ */

static void gemm_i32(const int32_t *a, const int32_t *b, int m, int k, int n,
                     int32_t *out) {
    int32_t *acc = malloc(sizeof(int32_t) * (size_t)n);
    for (int i = 0; i < m; i++) {
        memset(acc, 0, sizeof(int32_t) * (size_t)n);
        const int32_t *ar = a + (size_t)i * k;
        for (int kk = 0; kk < k; kk++) {
            int32_t av = ar[kk];
            if (av == 0)
                continue;
            const int32_t *br = b + (size_t)kk * n;
            for (int j = 0; j < n; j++)
                acc[j] += av * br[j];
        }
        memcpy(out + (size_t)i * n, acc, sizeof(int32_t) * (size_t)n);
    }
    free(acc);
}

static void gemm_u8i8(const uint8_t *a, const int8_t *b, int m, int k, int n,
                      int32_t *out) {
    int32_t *acc = malloc(sizeof(int32_t) * (size_t)n);
    for (int i = 0; i < m; i++) {
        memset(acc, 0, sizeof(int32_t) * (size_t)n);
        const uint8_t *ar = a + (size_t)i * k;
        for (int kk = 0; kk < k; kk++) {
            int32_t av = ar[kk];
            if (av == 0)
                continue;
            const int8_t *br = b + (size_t)kk * n;
            for (int j = 0; j < n; j++)
                acc[j] += av * (int32_t)br[j];
        }
        memcpy(out + (size_t)i * n, acc, sizeof(int32_t) * (size_t)n);
    }
    free(acc);
}

/* LSB-first sub-byte read; fields of 1/2/4 bits never straddle a byte */
static inline unsigned get_packed(const uint8_t *d, size_t idx, int bits) {
    size_t bit = idx * (size_t)bits;
    return (d[bit / 8] >> (bit % 8)) & ((1u << bits) - 1);
}
static inline void set_packed(uint8_t *d, size_t idx, int bits, unsigned v) {
    size_t bit = idx * (size_t)bits;
    unsigned mask = (1u << bits) - 1;
    d[bit / 8] = (uint8_t)((d[bit / 8] & ~(mask << (bit % 8))) |
                           ((v & mask) << (bit % 8)));
}

/* two's-complement weight bit-planes, layout planes[(p*n + j)*words + wi] */
static uint64_t *build_planes(const int32_t *w, int k, int n, int wbits,
                              int words) {
    uint64_t *planes = calloc((size_t)wbits * n * words, 8);
    unsigned mask = (1u << wbits) - 1;
    for (int row = 0; row < k; row++) {
        int wi = row / 64;
        uint64_t bit = 1ull << (row % 64);
        for (int col = 0; col < n; col++) {
            unsigned raw = (unsigned)w[(size_t)row * n + col] & mask;
            for (int p = 0; p < wbits; p++)
                if ((raw >> p) & 1)
                    planes[((size_t)p * n + col) * words + wi] |= bit;
        }
    }
    return planes;
}

static void gemm_bitserial(const uint8_t *ap, int abits, int m, int k, int n,
                           const uint64_t *planes, int wbits, int words,
                           int32_t *out) {
    uint64_t *apl = calloc((size_t)abits * words, 8);
    int32_t *acc = malloc(sizeof(int32_t) * (size_t)n);
    for (int i = 0; i < m; i++) {
        memset(apl, 0, (size_t)abits * words * 8);
        size_t base = (size_t)i * k;
        /* branchless scatter, matching the Rust kernel */
        for (int e = 0; e < k; e++) {
            unsigned v = get_packed(ap, base + e, abits);
            int wi = e / 64, sh = e % 64;
            for (int q = 0; q < abits; q++)
                apl[(size_t)q * words + wi] |= (uint64_t)((v >> q) & 1) << sh;
        }
        for (int j = 0; j < n; j++) {
            int32_t sum = 0;
            for (int p = 0; p < wbits; p++) {
                const uint64_t *wp = planes + ((size_t)p * n + j) * words;
                int32_t c = (p + 1 == wbits) ? -(1 << p) : (1 << p);
                for (int q = 0; q < abits; q++) {
                    const uint64_t *aq = apl + (size_t)q * words;
                    uint32_t pc = 0;
                    for (int w = 0; w < words; w++)
                        pc += (uint32_t)__builtin_popcountll(aq[w] & wp[w]);
                    sum += (c << q) * (int32_t)pc;
                }
            }
            acc[j] = sum;
        }
        memcpy(out + (size_t)i * n, acc, sizeof(int32_t) * (size_t)n);
    }
    free(apl);
    free(acc);
}

static void gemm_nibble(const uint8_t *ap, int m, int k, int n,
                        const int8_t *b, int32_t *out) {
    int8_t *arow = malloc((size_t)k);
    int32_t *acc = malloc(sizeof(int32_t) * (size_t)n);
    for (int i = 0; i < m; i++) {
        for (int e = 0; e < k; e++)
            arow[e] = (int8_t)get_packed(ap, (size_t)i * k + e, 4);
        memset(acc, 0, sizeof(int32_t) * (size_t)n);
        for (int kk = 0; kk < k; kk++) {
            int32_t av = arow[kk];
            if (av == 0)
                continue;
            const int8_t *br = b + (size_t)kk * n;
            for (int j = 0; j < n; j++)
                acc[j] += av * (int32_t)br[j];
        }
        memcpy(out + (size_t)i * n, acc, sizeof(int32_t) * (size_t)n);
    }
    free(arow);
    free(acc);
}

/* ------------------------------------------------------------------ */
/* section 1: sub-byte GEMM kernels vs the byte kernel                 */
/* ------------------------------------------------------------------ */

static void section_subbyte_gemm(void) {
    const int m = 256, k = 1024, n = 128;
    const int words = (k + 63) / 64;
    printf("  \"subbyte_gemm\": [\n");
    int abits_list[3] = {1, 2, 4};
    for (int qi = 0; qi < 3; qi++) {
        int q = abits_list[qi];
        int hi = (1 << q) - 1;
        int32_t *a32 = malloc(sizeof(int32_t) * (size_t)m * k);
        int32_t *w32 = malloc(sizeof(int32_t) * (size_t)k * n);
        uint8_t *a8 = malloc((size_t)m * k);
        int8_t *w8 = malloc((size_t)k * n);
        for (size_t i = 0; i < (size_t)m * k; i++) {
            a32[i] = (int32_t)rng_int(0, hi + 1);
            a8[i] = (uint8_t)a32[i];
        }
        for (size_t i = 0; i < (size_t)k * n; i++) {
            w32[i] = (int32_t)rng_int(-2, 2); /* 2-bit signed grid */
            w8[i] = (int8_t)w32[i];
        }
        size_t packed_len = ((size_t)m * k * q + 7) / 8;
        uint8_t *ap = calloc(packed_len, 1);
        for (size_t i = 0; i < (size_t)m * k; i++)
            set_packed(ap, i, q, (unsigned)a32[i]);

        int32_t *out_byte = malloc(sizeof(int32_t) * (size_t)m * n);
        int32_t *out_sub = malloc(sizeof(int32_t) * (size_t)m * n);
        double t_byte, t_sub;
        const char *kernel;
        size_t w_bytes;
        BENCH(t_byte, 0.5, gemm_u8i8(a8, w8, m, k, n, out_byte));
        if (q <= 2) {
            uint64_t *planes = build_planes(w32, k, n, 2, words);
            gemm_bitserial(ap, q, m, k, n, planes, 2, words, out_sub);
            if (memcmp(out_byte, out_sub, sizeof(int32_t) * (size_t)m * n)) {
                fprintf(stderr, "bitserial mismatch at q=%d\n", q);
                exit(1);
            }
            BENCH(t_sub, 0.5,
                  gemm_bitserial(ap, q, m, k, n, planes, 2, words, out_sub));
            kernel = "bitserial";
            w_bytes = (size_t)2 * n * words * 8;
            free(planes);
        } else {
            gemm_nibble(ap, m, k, n, w8, out_sub);
            if (memcmp(out_byte, out_sub, sizeof(int32_t) * (size_t)m * n)) {
                fprintf(stderr, "nibble mismatch at q=%d\n", q);
                exit(1);
            }
            BENCH(t_sub, 0.5, gemm_nibble(ap, m, k, n, w8, out_sub));
            kernel = "nibble";
            w_bytes = (size_t)k * n;
        }
        printf("    {\"abits\": %d, \"kernel\": \"%s\", \"byte_s\": %.6e, "
               "\"sub_s\": %.6e, \"speedup\": %.3f, \"act_bytes_byte\": %zu, "
               "\"act_bytes_packed\": %zu, \"weight_bytes_byte\": %zu, "
               "\"weight_bytes_packed\": %zu}%s\n",
               q, kernel, t_byte, t_sub, t_byte / t_sub, (size_t)m * k,
               packed_len, (size_t)k * n, w_bytes, qi + 1 < 3 ? "," : "");
        free(a32);
        free(w32);
        free(a8);
        free(w8);
        free(ap);
        free(out_byte);
        free(out_sub);
    }
    printf("  ],\n");
}

/* ------------------------------------------------------------------ */
/* section 2: u8 x i8 packed GEMM vs the i32 baseline                  */
/* ------------------------------------------------------------------ */

static void section_packed_gemm(void) {
    int shapes[2][3] = {{2048, 144, 32}, {256, 256, 256}};
    printf("  \"packed_gemm\": [\n");
    for (int si = 0; si < 2; si++) {
        int m = shapes[si][0], k = shapes[si][1], n = shapes[si][2];
        int32_t *a32 = malloc(sizeof(int32_t) * (size_t)m * k);
        int32_t *b32 = malloc(sizeof(int32_t) * (size_t)k * n);
        uint8_t *a8 = malloc((size_t)m * k);
        int8_t *b8 = malloc((size_t)k * n);
        for (size_t i = 0; i < (size_t)m * k; i++) {
            a32[i] = (int32_t)rng_int(0, 256);
            a8[i] = (uint8_t)a32[i];
        }
        for (size_t i = 0; i < (size_t)k * n; i++) {
            b32[i] = (int32_t)rng_int(-128, 128);
            b8[i] = (int8_t)b32[i];
        }
        int32_t *out_i = malloc(sizeof(int32_t) * (size_t)m * n);
        int32_t *out_q = malloc(sizeof(int32_t) * (size_t)m * n);
        gemm_i32(a32, b32, m, k, n, out_i);
        gemm_u8i8(a8, b8, m, k, n, out_q);
        if (memcmp(out_i, out_q, sizeof(int32_t) * (size_t)m * n)) {
            fprintf(stderr, "packed gemm mismatch\n");
            exit(1);
        }
        double t_i32, t_q;
        BENCH(t_i32, 0.5, gemm_i32(a32, b32, m, k, n, out_i));
        BENCH(t_q, 0.5, gemm_u8i8(a8, b8, m, k, n, out_q));
        printf("    {\"workload\": \"gemm_%dx%dx%d\", \"i32_s\": %.6e, "
               "\"packed_s\": %.6e, \"speedup\": %.3f}%s\n",
               m, k, n, t_i32, t_q, t_i32 / t_q, si == 0 ? "," : "");
        free(a32);
        free(b32);
        free(a8);
        free(b8);
        free(out_i);
        free(out_q);
    }
    printf("  ],\n");
}

/* ------------------------------------------------------------------ */
/* section 3: synthnet-shaped e2e — interpreted / planned / packed     */
/* ------------------------------------------------------------------ */

typedef struct {
    int cin, cout, h, w, k, stride, pad, oh, ow;
    int8_t *w8;   /* [cin*k*k, cout] */
    int32_t *w32; /* same values wide */
    int32_t *bias;
    int32_t rq_m; /* requant multiply */
    int rq_d;     /* requant shift */
} Layer;

/* NHWC im2col: rows = B*OH*OW, cols = cin*k*k (template over elem width) */
#define DEF_IM2COL(NAME, T)                                                  \
    static void NAME(const T *x, int b, int c, int h, int w, int kk, int s,  \
                     int p, int oh, int ow, T *out) {                        \
        int cols = c * kk * kk;                                              \
        for (int bi = 0; bi < b; bi++)                                       \
            for (int oy = 0; oy < oh; oy++)                                  \
                for (int ox = 0; ox < ow; ox++) {                            \
                    T *row =                                                 \
                        out + ((size_t)(bi * oh + oy) * ow + ox) * cols;     \
                    for (int ci = 0; ci < c; ci++)                           \
                        for (int ki = 0; ki < kk; ki++)                      \
                            for (int kj = 0; kj < kk; kj++) {                \
                                int iy = oy * s + ki - p;                    \
                                int ix = ox * s + kj - p;                    \
                                T v = 0;                                     \
                                if (iy >= 0 && iy < h && ix >= 0 && ix < w)  \
                                    v = x[((size_t)(bi * h + iy) * w + ix) * \
                                              c +                            \
                                          ci];                               \
                                row[ci * kk * kk + ki * kk + kj] = v;        \
                            }                                                \
                }                                                            \
    }
DEF_IM2COL(im2col_i32, int32_t)
DEF_IM2COL(im2col_u8, uint8_t)

static inline int32_t requant(int64_t acc, int32_t m, int d, int32_t hi) {
    int64_t v = (acc * m) >> d;
    if (v < 0)
        v = 0;
    if (v > hi)
        v = hi;
    return (int32_t)v;
}

/* interpreted: fresh buffers per node, conv -> separate bias pass ->
 * separate requant pass (run_interpreted's per-node tensors) */
static void run_interpreted(const Layer *ls, int nl, const int32_t *x, int b,
                            const int32_t *fc_w, const int32_t *fc_b,
                            int32_t *logits) {
    int32_t *cur = malloc(sizeof(int32_t) * (size_t)b * ls[0].cin * ls[0].h *
                          ls[0].w);
    memcpy(cur, x,
           sizeof(int32_t) * (size_t)b * ls[0].cin * ls[0].h * ls[0].w);
    for (int li = 0; li < nl; li++) {
        const Layer *l = &ls[li];
        int rows = b * l->oh * l->ow, cols = l->cin * l->k * l->k;
        int32_t *patches = malloc(sizeof(int32_t) * (size_t)rows * cols);
        im2col_i32(cur, b, l->cin, l->h, l->w, l->k, l->stride, l->pad, l->oh,
                   l->ow, patches);
        int32_t *conv = malloc(sizeof(int32_t) * (size_t)rows * l->cout);
        gemm_i32(patches, l->w32, rows, cols, l->cout, conv);
        /* separate bias node */
        for (int r = 0; r < rows; r++)
            for (int j = 0; j < l->cout; j++)
                conv[(size_t)r * l->cout + j] += l->bias[j];
        /* separate requant node */
        int32_t *act = malloc(sizeof(int32_t) * (size_t)rows * l->cout);
        for (size_t i = 0; i < (size_t)rows * l->cout; i++)
            act[i] = requant(conv[i], l->rq_m, l->rq_d, 255);
        free(patches);
        free(conv);
        free(cur);
        cur = act;
    }
    /* avgpool k4 (exact at d=12: 4096/16) then fc */
    const Layer *last = &ls[nl - 1];
    int c = last->cout, hw = last->oh * last->ow;
    int32_t *pooled = malloc(sizeof(int32_t) * (size_t)b * c);
    for (int bi = 0; bi < b; bi++)
        for (int ci = 0; ci < c; ci++) {
            int64_t s = 0;
            for (int i = 0; i < hw; i++)
                s += cur[((size_t)bi * hw + i) * c + ci];
            pooled[(size_t)bi * c + ci] = (int32_t)((s * 256) >> 12);
        }
    gemm_i32(pooled, fc_w, b, c, 10, logits);
    for (int bi = 0; bi < b; bi++)
        for (int j = 0; j < 10; j++)
            logits[(size_t)bi * 10 + j] += fc_b[j];
    free(cur);
    free(pooled);
}

/* fused GEMM + bias + requant epilogue, i32 operands (the wide plan) */
static void gemm_i32_fused(const int32_t *restrict a,
                           const int32_t *restrict b, int m, int k, int n,
                           const int32_t *restrict bias, int32_t rq_m,
                           int rq_d, int32_t *restrict acc,
                           int32_t *restrict out) {
    for (int i = 0; i < m; i++) {
        memset(acc, 0, sizeof(int32_t) * (size_t)n);
        const int32_t *ar = a + (size_t)i * k;
        for (int kk = 0; kk < k; kk++) {
            int32_t av = ar[kk];
            if (av == 0)
                continue;
            const int32_t *br = b + (size_t)kk * n;
            for (int j = 0; j < n; j++)
                acc[j] += av * br[j];
        }
        for (int j = 0; j < n; j++)
            out[(size_t)i * n + j] = requant(acc[j] + bias[j], rq_m, rq_d, 255);
    }
}

/* fused GEMM + bias + requant epilogue, u8 x i8 operands and u8 output
 * (the packed plan) */
static void gemm_u8i8_fused(const uint8_t *restrict a,
                            const int8_t *restrict b, int m, int k, int n,
                            const int32_t *restrict bias, int32_t rq_m,
                            int rq_d, int32_t *restrict acc,
                            uint8_t *restrict out) {
    for (int i = 0; i < m; i++) {
        memset(acc, 0, sizeof(int32_t) * (size_t)n);
        const uint8_t *ar = a + (size_t)i * k;
        for (int kk = 0; kk < k; kk++) {
            int32_t av = ar[kk];
            if (av == 0)
                continue;
            const int8_t *br = b + (size_t)kk * n;
            for (int j = 0; j < n; j++)
                acc[j] += av * (int32_t)br[j];
        }
        for (int j = 0; j < n; j++)
            out[(size_t)i * n + j] =
                (uint8_t)requant(acc[j] + bias[j], rq_m, rq_d, 255);
    }
}

/* planned: preallocated arena, bias+requant fused into the GEMM epilogue.
 * elem = 0 -> i32 activations (wide plan), elem = 1 -> u8 (packed plan). */
static void run_planned(const Layer *ls, int nl, const void *x, int b,
                        const int32_t *fc_w, const int8_t *fc_w8,
                        const int32_t *fc_b, int elem, void **arena,
                        int32_t *logits) {
    /* arena: [0] activations a, [1] patches, [2] activations b, [3] pooled */
    const void *cur = x;
    int32_t *acc = arena[4];
    for (int li = 0; li < nl; li++) {
        const Layer *l = &ls[li];
        int rows = b * l->oh * l->ow, cols = l->cin * l->k * l->k;
        void *patches = arena[1];
        void *next = arena[li % 2 ? 0 : 2];
        if (elem == 0) {
            im2col_i32((const int32_t *)cur, b, l->cin, l->h, l->w, l->k,
                       l->stride, l->pad, l->oh, l->ow, (int32_t *)patches);
            gemm_i32_fused((const int32_t *)patches, l->w32, rows, cols,
                           l->cout, l->bias, l->rq_m, l->rq_d, acc,
                           (int32_t *)next);
        } else {
            im2col_u8((const uint8_t *)cur, b, l->cin, l->h, l->w, l->k,
                      l->stride, l->pad, l->oh, l->ow, (uint8_t *)patches);
            gemm_u8i8_fused((const uint8_t *)patches, l->w8, rows, cols,
                            l->cout, l->bias, l->rq_m, l->rq_d, acc,
                            (uint8_t *)next);
        }
        cur = next;
    }
    const Layer *last = &ls[nl - 1];
    int c = last->cout, hw = last->oh * last->ow;
    int32_t *pooled = arena[3];
    for (int bi = 0; bi < b; bi++)
        for (int ci = 0; ci < c; ci++) {
            int64_t s = 0;
            for (int i = 0; i < hw; i++)
                s += elem == 0
                         ? ((const int32_t *)cur)[((size_t)bi * hw + i) * c +
                                                  ci]
                         : ((const uint8_t *)cur)[((size_t)bi * hw + i) * c +
                                                  ci];
            pooled[(size_t)bi * c + ci] = (int32_t)((s * 256) >> 12);
        }
    for (int bi = 0; bi < b; bi++) {
        memset(acc, 0, sizeof(int32_t) * 10);
        for (int kk = 0; kk < c; kk++) {
            int32_t av = pooled[(size_t)bi * c + kk];
            if (av == 0)
                continue;
            for (int j = 0; j < 10; j++)
                acc[j] += av * (elem == 0 ? fc_w[(size_t)kk * 10 + j]
                                          : (int32_t)fc_w8[(size_t)kk * 10 + j]);
        }
        for (int j = 0; j < 10; j++)
            logits[(size_t)bi * 10 + j] = acc[j] + fc_b[j];
    }
}

static void section_e2e(void) {
    Layer ls[3] = {
        {1, 8, 16, 16, 3, 1, 1, 16, 16, 0, 0, 0, 29, 13},
        {8, 16, 16, 16, 3, 2, 1, 8, 8, 0, 0, 0, 29, 17},
        {16, 32, 8, 8, 3, 2, 1, 4, 4, 0, 0, 0, 29, 18},
    };
    for (int li = 0; li < 3; li++) {
        Layer *l = &ls[li];
        size_t wn = (size_t)l->cin * l->k * l->k * l->cout;
        l->w32 = malloc(sizeof(int32_t) * wn);
        l->w8 = malloc(wn);
        l->bias = malloc(sizeof(int32_t) * (size_t)l->cout);
        for (size_t i = 0; i < wn; i++) {
            l->w32[i] = (int32_t)rng_int(-128, 128);
            l->w8[i] = (int8_t)l->w32[i];
        }
        for (int j = 0; j < l->cout; j++)
            l->bias[j] = (int32_t)rng_int(-1000, 1000);
    }
    int32_t fc_w[32 * 10], fc_b[10];
    int8_t fc_w8[32 * 10];
    for (int i = 0; i < 32 * 10; i++) {
        fc_w[i] = (int32_t)rng_int(-128, 128);
        fc_w8[i] = (int8_t)fc_w[i];
    }
    for (int j = 0; j < 10; j++)
        fc_b[j] = (int32_t)rng_int(-1000, 1000);

    printf("  \"e2e_synthnet\": [\n");
    int batches[2] = {1, 16};
    for (int bi = 0; bi < 2; bi++) {
        int b = batches[bi];
        size_t in_n = (size_t)b * 256; /* 1x16x16 */
        int32_t *x32 = malloc(sizeof(int32_t) * in_n);
        uint8_t *x8 = malloc(in_n);
        for (size_t i = 0; i < in_n; i++) {
            x32[i] = (int32_t)rng_int(0, 256);
            x8[i] = (uint8_t)x32[i];
        }
        /* arena slots sized for the largest per-slot use across layers */
        size_t max_act = (size_t)b * 8 * 16 * 16;
        size_t max_patch = (size_t)b * 16 * 16 * 72;
        void *arena_wide[5] = {malloc(4 * max_act), malloc(4 * max_patch),
                               malloc(4 * max_act), malloc(4 * (size_t)b * 32),
                               malloc(4 * 64)};
        void *arena_packed[5] = {malloc(max_act), malloc(max_patch),
                                 malloc(max_act), malloc(4 * (size_t)b * 32),
                                 malloc(4 * 64)};
        int32_t *lg_i = malloc(sizeof(int32_t) * (size_t)b * 10);
        int32_t *lg_w = malloc(sizeof(int32_t) * (size_t)b * 10);
        int32_t *lg_p = malloc(sizeof(int32_t) * (size_t)b * 10);
        run_interpreted(ls, 3, x32, b, fc_w, fc_b, lg_i);
        run_planned(ls, 3, x32, b, fc_w, NULL, fc_b, 0, arena_wide, lg_w);
        run_planned(ls, 3, x8, b, NULL, fc_w8, fc_b, 1, arena_packed, lg_p);
        if (memcmp(lg_i, lg_w, sizeof(int32_t) * (size_t)b * 10) ||
            memcmp(lg_i, lg_p, sizeof(int32_t) * (size_t)b * 10)) {
            fprintf(stderr, "e2e mismatch at b=%d\n", b);
            exit(1);
        }
        double t_interp, t_wide, t_packed;
        BENCH(t_interp, 0.7, run_interpreted(ls, 3, x32, b, fc_w, fc_b, lg_i));
        BENCH(t_wide, 0.7,
              run_planned(ls, 3, x32, b, fc_w, NULL, fc_b, 0, arena_wide,
                          lg_w));
        BENCH(t_packed, 0.7,
              run_planned(ls, 3, x8, b, NULL, fc_w8, fc_b, 1, arena_packed,
                          lg_p));
        printf("    {\"batch\": %d, \"interpreted_s\": %.6e, \"planned_s\": "
               "%.6e, \"plan_speedup\": %.3f, \"packed_s\": %.6e, "
               "\"packed_speedup\": %.3f}%s\n",
               b, t_interp, t_wide, t_interp / t_wide, t_packed,
               t_wide / t_packed, bi == 0 ? "," : "");
        free(x32);
        free(x8);
        for (int i = 0; i < 5; i++) {
            free(arena_wide[i]);
            free(arena_packed[i]);
        }
        free(lg_i);
        free(lg_w);
        free(lg_p);
    }
    printf("  ]\n");
    for (int li = 0; li < 3; li++) {
        free(ls[li].w32);
        free(ls[li].w8);
        free(ls[li].bias);
    }
}

int main(void) {
    printf("{\n");
    section_subbyte_gemm();
    section_packed_gemm();
    section_e2e();
    printf("}\n");
    return 0;
}
