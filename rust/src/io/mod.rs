//! Artifact manifest, golden vectors, and checkpoint I/O (S11).
//!
//! The build-time Python side (`python/compile/aot.py`) writes
//! `artifacts/manifest.json` (per-artifact argument lists) and
//! `artifacts/goldens.json` (cross-language validation vectors); this
//! module loads both. Checkpoints (trained parameters) are stored as JSON
//! with full-precision f64 values — small models, exact round-trips.
//!
//! [`artifact`] is the *native* deployment format: a versioned,
//! checksummed `model.nemo.json` — or its v3 binary container twin
//! `model.nemob`, whose 64-byte-aligned weight sections the loader
//! `mmap`s into zero-copy tensor views — holding a complete
//! IntegerDeployable program: no Python, no PJRT manifest, no training
//! step needed to serve it (DESIGN.md §Artifact-format).

pub mod artifact;
pub mod mmap;

pub use artifact::{
    binary_info, fnv1a64, ArtifactError, ArtifactProvenance, BinInfo, BinLoadStats,
    BinSection, DeployedArtifact,
};
pub use mmap::{AlignedBytes, BinLoadMode, MappedFile};

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::tensor::{Tensor, TensorF, TensorI};
use crate::util::json::{self, Value};

/// One argument of an AOT artifact.
#[derive(Clone, Debug)]
pub struct ArgSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

/// One AOT-compiled artifact (an HLO text module).
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: PathBuf,
    pub args: Vec<ArgSpec>,
    pub n_outputs: usize,
    pub kind: String,
    pub batch: Option<usize>,
    pub wbits: Option<u32>,
    pub abits: Option<u32>,
}

/// Parsed artifacts/manifest.json.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub artifacts: Vec<ArtifactSpec>,
    pub arch: Value,
    pub dir: PathBuf,
}

impl ArtifactSpec {
    /// Per-sample input shape of the artifact: the trailing argument is
    /// the batched input image, so strip its batch dimension. Artifacts
    /// with no arguments (or a scalar trailing argument) are malformed
    /// manifests and yield a contextful error instead of a panic.
    pub fn sample_input_shape(&self) -> Result<Vec<usize>> {
        let last = self.args.last().with_context(|| {
            format!(
                "artifact '{}' has no arguments (expected the batched input \
                 image as the last argument)",
                self.name
            )
        })?;
        if last.shape.is_empty() {
            anyhow::bail!(
                "artifact '{}': trailing argument '{}' is a scalar, not a \
                 batched input image",
                self.name,
                last.name
            );
        }
        Ok(last.shape[1..].to_vec())
    }
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("reading {}/manifest.json (run `make artifacts`)", dir.display()))?;
        let v = json::parse(&text).context("parsing manifest.json")?;
        let mut artifacts = Vec::new();
        for a in v.get("artifacts")?.as_arr()? {
            let args = a
                .get("args")?
                .as_arr()?
                .iter()
                .map(|e| {
                    Ok(ArgSpec {
                        name: e.get("name")?.as_str()?.to_string(),
                        shape: e
                            .get("shape")?
                            .as_arr()?
                            .iter()
                            .map(|s| Ok(s.as_i64()? as usize))
                            .collect::<Result<Vec<_>>>()?,
                        dtype: e.get("dtype")?.as_str()?.to_string(),
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            artifacts.push(ArtifactSpec {
                name: a.get("name")?.as_str()?.to_string(),
                file: dir.join(a.get("file")?.as_str()?),
                args,
                n_outputs: a.get("n_outputs")?.as_i64()? as usize,
                kind: a
                    .get_opt("kind")
                    .and_then(|k| k.as_str().ok())
                    .unwrap_or("")
                    .to_string(),
                batch: a.get_opt("batch").and_then(|b| b.as_i64().ok()).map(|b| b as usize),
                wbits: a.get_opt("wbits").and_then(|b| b.as_i64().ok()).map(|b| b as u32),
                abits: a.get_opt("abits").and_then(|b| b.as_i64().ok()).map(|b| b as u32),
            });
        }
        let arch = v.get("arch")?.clone();
        Ok(Manifest { artifacts, arch, dir })
    }

    pub fn find(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .iter()
            .find(|a| a.name == name)
            .with_context(|| format!("artifact '{name}' not in manifest"))
    }

    /// All artifacts of a kind, sorted by batch size.
    pub fn by_kind(&self, kind: &str) -> Vec<&ArtifactSpec> {
        let mut v: Vec<&ArtifactSpec> =
            self.artifacts.iter().filter(|a| a.kind == kind).collect();
        v.sort_by_key(|a| a.batch.unwrap_or(0));
        v
    }
}

/// Parsed artifacts/goldens.json (kept as raw JSON; tests pull what they
/// need via the tensor helpers).
pub struct Goldens(pub Value);

impl Goldens {
    pub fn load(dir: impl AsRef<Path>) -> Result<Goldens> {
        let text = std::fs::read_to_string(dir.as_ref().join("goldens.json"))
            .context("reading goldens.json (run `make artifacts`)")?;
        Ok(Goldens(json::parse(&text).context("parsing goldens.json")?))
    }

    pub fn tensor_f32(&self, path: &[&str]) -> Result<TensorF> {
        let v = self.walk(path)?;
        let (data, shape) = v.as_f64_tensor()?;
        Ok(TensorF::from_f64(&shape, &data))
    }

    pub fn tensor_i32(&self, path: &[&str]) -> Result<TensorI> {
        let v = self.walk(path)?;
        let (data, shape) = v.as_i32_tensor()?;
        Ok(Tensor::from_vec(&shape, data))
    }

    pub fn f64(&self, path: &[&str]) -> Result<f64> {
        Ok(self.walk(path)?.as_f64()?)
    }

    pub fn i64(&self, path: &[&str]) -> Result<i64> {
        Ok(self.walk(path)?.as_i64()?)
    }

    pub fn walk(&self, path: &[&str]) -> Result<&Value> {
        let mut v = &self.0;
        for p in path {
            v = v.get(p)?;
        }
        Ok(v)
    }
}

// ---------------------------------------------------------------------------
// Checkpoints: named f64 tensors, exact JSON round-trip
// ---------------------------------------------------------------------------

/// A named-tensor checkpoint (trained parameters + BN state + act betas).
#[derive(Clone, Debug, Default)]
pub struct Checkpoint {
    pub tensors: BTreeMap<String, (Vec<usize>, Vec<f64>)>,
    pub meta: BTreeMap<String, f64>,
}

impl Checkpoint {
    pub fn insert_f32(&mut self, name: &str, t: &TensorF) {
        self.tensors.insert(
            name.to_string(),
            (t.shape().to_vec(), t.data().iter().map(|v| *v as f64).collect()),
        );
    }

    pub fn insert_f64(&mut self, name: &str, shape: &[usize], data: Vec<f64>) {
        self.tensors.insert(name.to_string(), (shape.to_vec(), data));
    }

    pub fn get_f32(&self, name: &str) -> Result<TensorF> {
        let (shape, data) = self
            .tensors
            .get(name)
            .with_context(|| format!("checkpoint missing tensor '{name}'"))?;
        Ok(TensorF::from_f64(shape, data))
    }

    pub fn get_f64(&self, name: &str) -> Result<(&[usize], &[f64])> {
        let (shape, data) = self
            .tensors
            .get(name)
            .with_context(|| format!("checkpoint missing tensor '{name}'"))?;
        Ok((shape, data))
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let mut tensors = BTreeMap::new();
        for (name, (shape, data)) in &self.tensors {
            tensors.insert(
                name.clone(),
                json::obj(vec![
                    ("shape", json::arr_i64(&shape.iter().map(|s| *s as i64).collect::<Vec<_>>())),
                    ("data", json::arr_f64(data)),
                ]),
            );
        }
        let mut meta = BTreeMap::new();
        for (k, v) in &self.meta {
            meta.insert(k.clone(), Value::Num(*v));
        }
        let root = json::obj(vec![
            ("tensors", Value::Obj(tensors)),
            ("meta", Value::Obj(meta)),
        ]);
        std::fs::write(path, json::write(&root))?;
        Ok(())
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Checkpoint> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading checkpoint {}", path.as_ref().display()))?;
        let v = json::parse(&text)?;
        let mut ck = Checkpoint::default();
        for (name, t) in v.get("tensors")?.as_obj()? {
            let shape: Vec<usize> = t
                .get("shape")?
                .as_arr()?
                .iter()
                .map(|s| Ok(s.as_i64()? as usize))
                .collect::<Result<Vec<_>>>()?;
            let data: Vec<f64> = t
                .get("data")?
                .as_arr()?
                .iter()
                .map(|d| Ok(d.as_f64()?))
                .collect::<Result<Vec<_>>>()?;
            ck.tensors.insert(name.clone(), (shape, data));
        }
        if let Some(meta) = v.get_opt("meta") {
            for (k, mv) in meta.as_obj()? {
                ck.meta.insert(k.clone(), mv.as_f64()?);
            }
        }
        Ok(ck)
    }
}

/// Default artifacts directory: $NEMO_ARTIFACTS or ./artifacts.
pub fn artifacts_dir() -> PathBuf {
    std::env::var("NEMO_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sample_input_shape_strips_batch_dim() {
        let spec = ArtifactSpec {
            name: "m_b4".into(),
            file: PathBuf::from("m_b4.hlo.txt"),
            args: vec![
                ArgSpec { name: "w".into(), shape: vec![8, 8], dtype: "int32".into() },
                ArgSpec { name: "qx".into(), shape: vec![4, 1, 16, 16], dtype: "int32".into() },
            ],
            n_outputs: 1,
            kind: "id_fwd".into(),
            batch: Some(4),
            wbits: None,
            abits: None,
        };
        assert_eq!(spec.sample_input_shape().unwrap(), vec![1, 16, 16]);
    }

    #[test]
    fn sample_input_shape_errors_on_empty_args() {
        // Previously this panicked via args.last().unwrap(); a malformed
        // manifest must produce a contextful error naming the artifact.
        let spec = ArtifactSpec {
            name: "broken".into(),
            file: PathBuf::from("broken.hlo.txt"),
            args: vec![],
            n_outputs: 1,
            kind: "id_fwd".into(),
            batch: Some(1),
            wbits: None,
            abits: None,
        };
        let err = spec.sample_input_shape().unwrap_err();
        assert!(err.to_string().contains("broken"), "{err}");
        assert!(err.to_string().contains("no arguments"), "{err}");
    }

    #[test]
    fn sample_input_shape_errors_on_scalar_input() {
        let spec = ArtifactSpec {
            name: "scalar_in".into(),
            file: PathBuf::from("s.hlo.txt"),
            args: vec![ArgSpec { name: "lr".into(), shape: vec![], dtype: "float32".into() }],
            n_outputs: 1,
            kind: "id_fwd".into(),
            batch: Some(1),
            wbits: None,
            abits: None,
        };
        let err = spec.sample_input_shape().unwrap_err();
        assert!(err.to_string().contains("scalar"), "{err}");
    }

    #[test]
    fn checkpoint_roundtrip_is_exact() {
        let mut ck = Checkpoint::default();
        ck.insert_f64("w", &[2, 2], vec![1.0 / 3.0, -2.5e-7, 0.0, 1e300]);
        ck.meta.insert("loss".into(), 0.125);
        let dir = std::env::temp_dir().join("nemo_ck_test.json");
        ck.save(&dir).unwrap();
        let back = Checkpoint::load(&dir).unwrap();
        let (shape, data) = back.get_f64("w").unwrap();
        assert_eq!(shape, &[2, 2]);
        assert_eq!(data, &[1.0 / 3.0, -2.5e-7, 0.0, 1e300]);
        assert_eq!(back.meta["loss"], 0.125);
        let _ = std::fs::remove_file(dir);
    }

    #[test]
    fn manifest_loads_if_artifacts_built() {
        let dir = artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        assert!(!m.artifacts.is_empty());
        let id = m.find("synthnet_id_fwd_b1").unwrap();
        assert_eq!(id.kind, "id_fwd");
        assert_eq!(id.args.last().unwrap().name, "qx");
        assert!(!m.by_kind("id_fwd").is_empty());
    }

    #[test]
    fn goldens_load_if_built() {
        let dir = artifacts_dir();
        if !dir.join("goldens.json").exists() {
            return;
        }
        let g = Goldens::load(&dir).unwrap();
        let qx = g.tensor_i32(&["model_case", "qx"]).unwrap();
        assert_eq!(qx.shape()[0], 2);
        assert!(g.f64(&["model_case", "eps_out"]).unwrap() > 0.0);
    }
}
