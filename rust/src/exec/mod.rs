//! Unified executor backend abstraction (S5/S6 in DESIGN.md).
//!
//! Every way of running a network — the float engine (FP/FQ/QD), the
//! integer engine (ID, the MCU-datapath simulator) and the PJRT-compiled
//! artifacts — sits behind one [`Executor`] trait, so the serving
//! coordinator, benchmarks and tools can drive any backend through the
//! same `run_batch` call:
//!
//! * [`NativeIntExecutor`] — the in-process integer engine over an
//!   [`IntGraph`]; no artifacts, no FFI, always available.
//! * [`NativeFloatExecutor`] — the float engine over a FP/FQ/QD [`Graph`].
//! * `PjrtExecutor` (feature `pjrt`) — AOT-compiled HLO artifacts on the
//!   PJRT CPU client, with per-batch-size compiled variants and
//!   transparent zero-padding.
//!
//! [`Arg`] is the host-side tensor value crossing any executor boundary
//! (it also crosses the PJRT FFI boundary when the `pjrt` feature is on).

#[cfg(feature = "pjrt")]
pub mod pjrt;

#[cfg(feature = "pjrt")]
pub use pjrt::PjrtExecutor;

use std::sync::{Mutex, OnceLock};

use anyhow::{bail, ensure, Context as _, Result};

use crate::engine::plan::{Arena, FloatPlan, IntArena, IntPlan, PackedArena, PlanLayout};
use crate::engine::PlanError;
use crate::graph::int::IntGraph;
use crate::graph::Graph;
use crate::tensor::{TensorF, TensorI};

/// A host-side tensor value crossing an executor boundary.
#[derive(Clone, Debug)]
pub enum Arg {
    F32(TensorF),
    I32(TensorI),
}

impl Arg {
    pub fn shape(&self) -> &[usize] {
        match self {
            Arg::F32(t) => t.shape(),
            Arg::I32(t) => t.shape(),
        }
    }

    pub fn as_f32(&self) -> Result<&TensorF> {
        match self {
            Arg::F32(t) => Ok(t),
            Arg::I32(_) => bail!("expected f32 tensor, got i32"),
        }
    }

    pub fn as_i32(&self) -> Result<&TensorI> {
        match self {
            Arg::I32(t) => Ok(t),
            Arg::F32(_) => bail!("expected i32 tensor, got f32"),
        }
    }
}

impl From<TensorF> for Arg {
    fn from(t: TensorF) -> Self {
        Arg::F32(t)
    }
}

impl From<TensorI> for Arg {
    fn from(t: TensorI) -> Self {
        Arg::I32(t)
    }
}

/// One gathered batch of inputs for an executor. The leading dimension of
/// `batch` is the batch size.
#[derive(Clone, Debug)]
pub struct ExecInput {
    pub batch: Arg,
}

impl ExecInput {
    pub fn i32(t: TensorI) -> Self {
        ExecInput { batch: Arg::I32(t) }
    }

    pub fn f32(t: TensorF) -> Self {
        ExecInput { batch: Arg::F32(t) }
    }

    pub fn batch_size(&self) -> usize {
        self.batch.shape().first().copied().unwrap_or(0)
    }
}

/// Result of one `run_batch`: the per-sample logits batch, with the same
/// batch size as the input (executors strip any internal padding).
#[derive(Clone, Debug)]
pub struct ExecOutput {
    pub logits: Arg,
}

impl ExecOutput {
    pub fn int_logits(&self) -> Result<&TensorI> {
        self.logits.as_i32()
    }
}

/// A batched inference backend. Implementations must be shareable across
/// the coordinator's worker threads.
pub trait Executor: Send + Sync {
    /// Short backend name for logs/metrics ("native-int", "pjrt", ...).
    fn name(&self) -> &str;

    /// Per-sample input shape (without the batch dimension).
    fn input_shape(&self) -> &[usize];

    /// Largest batch accepted by a single `run_batch` call.
    fn max_batch(&self) -> usize;

    /// Batch size actually executed for `n` gathered samples (backends
    /// with compiled batch variants round up and zero-pad internally).
    fn effective_batch(&self, n: usize) -> usize {
        n
    }

    /// Execute one gathered batch and return per-sample outputs.
    fn run_batch(&self, input: &ExecInput) -> Result<ExecOutput>;
}

fn check_batch_shape(
    name: &str,
    got: &[usize],
    want_sample: &[usize],
    max_batch: usize,
) -> Result<usize> {
    ensure!(
        got.len() == want_sample.len() + 1 && &got[1..] == want_sample,
        "{name}: input shape {got:?} does not match per-sample shape {want_sample:?} (plus batch dim)",
    );
    let n = got[0];
    ensure!(n >= 1, "{name}: empty batch");
    ensure!(
        n <= max_batch,
        "{name}: batch {n} exceeds max_batch {max_batch}",
    );
    Ok(n)
}

/// Shared plumbing of the native executors: per-batch-variant layouts
/// compiled *lazily* — slot `b-1` fills on the first request with batch
/// `b` and is cached for the executor's lifetime — plus a pool of
/// scratch arenas recycled across requests, so the steady-state request
/// path performs no graph walking and no per-node allocation. Only the
/// batch-1 layout is compiled eagerly, so construction surfaces layout
/// errors without paying for `max_batch` variants that a serving mix may
/// never touch (ROADMAP "Batch-variant plan sharing"). Generic over the
/// arena flavour ([`Arena<T>`] for the full-width/float paths,
/// [`PackedArena`] for precision-packed serving).
struct PlanSet<A> {
    layouts: Vec<OnceLock<PlanLayout>>,
    arenas: Mutex<Vec<A>>,
}

impl<A: Default> PlanSet<A> {
    fn new(
        layout_of: impl Fn(usize) -> std::result::Result<PlanLayout, PlanError>,
        max_batch: usize,
    ) -> Result<Self> {
        let layouts: Vec<OnceLock<PlanLayout>> =
            (0..max_batch).map(|_| OnceLock::new()).collect();
        // Batch 1 eagerly: any per-batch layout error is structural (the
        // batch dimension only scales buffer sizes), so this validates
        // the whole family at construction time.
        let first = layout_of(1)?;
        let _ = layouts[0].set(first);
        Ok(PlanSet { layouts, arenas: Mutex::new(Vec::new()) })
    }

    /// Number of batch variants compiled so far (diagnostics/benches).
    fn compiled_layouts(&self) -> usize {
        self.layouts.iter().filter(|c| c.get().is_some()).count()
    }

    /// Run `f` with the layout for batch `n` (compiling and caching it on
    /// first use) and a pooled arena.
    fn with_arena<R>(
        &self,
        n: usize,
        layout_of: impl Fn(usize) -> std::result::Result<PlanLayout, PlanError>,
        f: impl FnOnce(&PlanLayout, &mut A) -> R,
    ) -> Result<R> {
        let cell = &self.layouts[n - 1];
        let layout = match cell.get() {
            Some(l) => l,
            None => {
                // Racing threads may compile the same variant; the first
                // `set` wins and the duplicate is dropped — layouts are
                // deterministic, so either copy is correct.
                let l = layout_of(n)?;
                cell.get_or_init(|| l)
            }
        };
        let mut arena = self
            .arenas
            .lock()
            .expect("arena pool poisoned")
            .pop()
            .unwrap_or_default();
        let out = f(layout, &mut arena);
        self.arenas.lock().expect("arena pool poisoned").push(arena);
        Ok(out)
    }
}

/// Which execution flavour a [`NativeIntExecutor`] compiled: packed
/// (sub-word steps stream u8/i8 storage) whenever the plan has any, the
/// classic i32 path when the whole graph is wide and packing would only
/// add copies.
enum IntPlanSet {
    Packed(PlanSet<PackedArena>),
    Wide(PlanSet<IntArena>),
}

/// The in-process integer engine behind the [`Executor`] trait: runs an
/// IntegerDeployable graph with no artifacts and no FFI. This is the
/// `serve --backend native` path. The graph is compiled once into a
/// fused [`IntPlan`] with per-batch-variant layouts; requests execute
/// the plan over pooled arenas (see DESIGN.md §Plan-compilation). When
/// the deployed graph carries sub-word precision stamps the executor
/// serves the packed path end-to-end — same bits, 1 byte/element on the
/// GEMM-dominant activation traffic (DESIGN.md §Precision propagation).
pub struct NativeIntExecutor {
    plan: IntPlan,
    plans: IntPlanSet,
    input_shape: Vec<usize>,
    max_batch: usize,
    eps_out: f64,
}

impl NativeIntExecutor {
    pub fn new(graph: IntGraph, max_batch: usize) -> Result<Self> {
        ensure!(max_batch >= 1, "max_batch must be >= 1");
        let eps_out = graph.eps_out;
        let plan = IntPlan::compile(&graph)?;
        let plans = if plan.has_packed_steps() {
            IntPlanSet::Packed(PlanSet::new(|b| plan.packed_layout(b), max_batch)?)
        } else {
            IntPlanSet::Wide(PlanSet::new(|b| plan.layout(b), max_batch)?)
        };
        let input_shape = plan.input_shape().to_vec();
        Ok(NativeIntExecutor { plan, plans, input_shape, max_batch, eps_out })
    }

    /// Build the executor straight from a saved native deployment
    /// artifact: load + checksum validation + precision re-proof + plan
    /// compilation — serving with zero training or transform work. This
    /// is the `nemo serve --model` cold-start path. Both on-disk forms
    /// work (the loader sniffs the leading bytes): the JSON document
    /// (`model.nemo.json`) decodes weight payloads into owned tensors,
    /// the v3 binary container (`model.nemob`) is mmapped and its
    /// weight sections become zero-copy views that the plan compiler's
    /// `pack_weights` carries through to the GEMM kernels.
    pub fn from_artifact(
        path: impl AsRef<std::path::Path>,
        max_batch: usize,
    ) -> Result<Self> {
        Self::from_artifact_with_provenance(path, max_batch).map(|(exec, _)| exec)
    }

    /// Like [`Self::from_artifact`], but also surfaces the artifact's
    /// provenance (path, checksum, format version, byte size) — what the
    /// serving registry records so `list_models` can say exactly which
    /// bytes a name is serving.
    pub fn from_artifact_with_provenance(
        path: impl AsRef<std::path::Path>,
        max_batch: usize,
    ) -> Result<(Self, crate::io::artifact::ArtifactProvenance)> {
        let path = path.as_ref();
        // Warn-mode static check: serving keeps loading (the decode
        // layer already rejected malformed files) but any soundness
        // finding lands on stderr for the operator. `nemo check
        // --strict` / `load_checked(.., Strict)` is the hard gate.
        let (art, prov) = crate::io::DeployedArtifact::load_with_provenance_checked(
            path,
            crate::analysis::CheckMode::Warn,
        )
        .with_context(|| {
            format!("loading deployed model artifact {}", path.display())
        })?;
        Ok((Self::new(art.into_int_graph(), max_batch)?, prov))
    }

    /// Quantum of the output integer image (real logits ~ eps_out * Q).
    pub fn eps_out(&self) -> f64 {
        self.eps_out
    }

    /// Graph nodes eliminated by epilogue fusion (diagnostics).
    pub fn fused_nodes(&self) -> usize {
        self.plan.fused_nodes()
    }

    /// Whether requests run the precision-packed plan path.
    pub fn packed(&self) -> bool {
        matches!(self.plans, IntPlanSet::Packed(_))
    }

    /// How many per-batch [`PlanLayout`] variants have been compiled so
    /// far. Construction compiles exactly one (the batch-1 validator);
    /// the rest fill lazily on first use, so this stays small for
    /// serving mixes that only ever see a few batch sizes.
    pub fn compiled_layouts(&self) -> usize {
        match &self.plans {
            IntPlanSet::Packed(ps) => ps.compiled_layouts(),
            IntPlanSet::Wide(ps) => ps.compiled_layouts(),
        }
    }

    /// Loud range check for untrusted request images entering the packed
    /// path: a value outside the input spec's stamped precision would
    /// violate the deploy-time range proof (and, in release builds, wrap
    /// silently), so it is rejected here instead.
    fn check_packed_input(&self, qx: &TensorI) -> Result<()> {
        let p = self.plan.input_precision();
        if let Some(v) = p.find_out_of_range(qx.data()) {
            bail!(
                "native-int: input value {v} outside the deployed input precision \
                 {} range [{}, {}]",
                p.name(),
                p.min_val(),
                p.max_val()
            );
        }
        Ok(())
    }
}

impl Executor for NativeIntExecutor {
    fn name(&self) -> &str {
        "native-int"
    }

    fn input_shape(&self) -> &[usize] {
        &self.input_shape
    }

    fn max_batch(&self) -> usize {
        self.max_batch
    }

    fn run_batch(&self, input: &ExecInput) -> Result<ExecOutput> {
        let qx = input.batch.as_i32()?;
        let n =
            check_batch_shape("native-int", qx.shape(), &self.input_shape, self.max_batch)?;
        let out = match &self.plans {
            IntPlanSet::Packed(ps) => {
                self.check_packed_input(qx)?;
                ps.with_arena(
                    n,
                    |b| self.plan.packed_layout(b),
                    |layout, arena| self.plan.execute_packed(layout, arena, qx),
                )?
            }
            IntPlanSet::Wide(ps) => ps.with_arena(
                n,
                |b| self.plan.layout(b),
                |layout, arena| self.plan.execute(layout, arena, qx),
            )?,
        };
        Ok(ExecOutput { logits: Arg::I32(out) })
    }
}

/// The float engine behind the [`Executor`] trait: runs FP / FQ / QD
/// graphs on f32 batches. Note the serving coordinator's request
/// protocol carries integer images only, so this backend is for direct
/// `run_batch` callers (tools, benches, comparisons), not for the
/// serving registry. Compiled exactly like the integer executor: one
/// fused plan, lazy per-batch layouts, pooled arenas.
pub struct NativeFloatExecutor {
    plan: FloatPlan,
    plans: PlanSet<Arena<f32>>,
    input_shape: Vec<usize>,
    max_batch: usize,
}

impl NativeFloatExecutor {
    pub fn new(graph: Graph, max_batch: usize) -> Result<Self> {
        ensure!(max_batch >= 1, "max_batch must be >= 1");
        let plan = FloatPlan::compile(&graph)?;
        let plans = PlanSet::new(|b| plan.layout(b), max_batch)?;
        let input_shape = plan.input_shape().to_vec();
        Ok(NativeFloatExecutor { plan, plans, input_shape, max_batch })
    }

    /// Compiled per-batch layout variants so far (lazy; see
    /// [`NativeIntExecutor::compiled_layouts`]).
    pub fn compiled_layouts(&self) -> usize {
        self.plans.compiled_layouts()
    }
}

impl Executor for NativeFloatExecutor {
    fn name(&self) -> &str {
        "native-float"
    }

    fn input_shape(&self) -> &[usize] {
        &self.input_shape
    }

    fn max_batch(&self) -> usize {
        self.max_batch
    }

    fn run_batch(&self, input: &ExecInput) -> Result<ExecOutput> {
        let x = input.batch.as_f32()?;
        let n = check_batch_shape(
            "native-float",
            x.shape(),
            &self.input_shape,
            self.max_batch,
        )?;
        let out = self.plans.with_arena(
            n,
            |b| self.plan.layout(b),
            |layout, arena| self.plan.execute(layout, arena, x),
        )?;
        Ok(ExecOutput { logits: Arg::F32(out) })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::int::IntOp;
    use crate::graph::Op;
    use crate::quant::QuantSpec;
    use crate::tensor::Tensor;

    fn identity_int_graph() -> IntGraph {
        let mut g = IntGraph::default();
        let spec = QuantSpec { eps: 1.0, lo: 0, hi: 255 };
        let x = g.push("in", IntOp::Input { shape: vec![2], spec }, &[]);
        let wq = Tensor::from_vec(&[2, 2], vec![1, 0, 0, 1]).into();
        g.push("fc", IntOp::LinearInt { wq, bias_q: None }, &[x]);
        g.eps_out = 1.0;
        g
    }

    #[test]
    fn native_int_executor_runs_a_batch() {
        let exec = NativeIntExecutor::new(identity_int_graph(), 8).unwrap();
        assert_eq!(exec.input_shape(), &[2]);
        assert_eq!(exec.max_batch(), 8);
        assert_eq!(exec.effective_batch(3), 3);
        let qx = Tensor::from_vec(&[2, 2], vec![1, 2, 3, 4]);
        let out = exec.run_batch(&ExecInput::i32(qx)).unwrap();
        assert_eq!(out.int_logits().unwrap().data(), &[1, 2, 3, 4]);
    }

    #[test]
    fn native_int_executor_rejects_bad_shapes() {
        let exec = NativeIntExecutor::new(identity_int_graph(), 2).unwrap();
        // wrong sample shape
        let qx = Tensor::from_vec(&[1, 3], vec![1, 2, 3]);
        assert!(exec.run_batch(&ExecInput::i32(qx)).is_err());
        // over max batch
        let qx = Tensor::from_vec(&[3, 2], vec![0; 6]);
        assert!(exec.run_batch(&ExecInput::i32(qx)).is_err());
        // wrong dtype
        let x = TensorF::from_vec(&[1, 2], vec![0.0, 1.0]);
        assert!(exec.run_batch(&ExecInput::f32(x)).is_err());
    }

    #[test]
    fn packed_executor_rejects_out_of_range_inputs() {
        // The identity graph's input spec is [0, 255] -> U8 packed path.
        let exec = NativeIntExecutor::new(identity_int_graph(), 4).unwrap();
        assert!(exec.packed());
        let qx = Tensor::from_vec(&[1, 2], vec![0, 300]);
        let err = exec.run_batch(&ExecInput::i32(qx)).unwrap_err();
        assert!(err.to_string().contains("outside"), "{err}");
        // In-range requests still serve, bit-identical to the engine.
        let qx = Tensor::from_vec(&[2, 2], vec![255, 0, 7, 19]);
        let out = exec.run_batch(&ExecInput::i32(qx.clone())).unwrap();
        let want = crate::engine::IntegerEngine::new()
            .run_interpreted(&identity_int_graph(), &qx);
        assert_eq!(out.int_logits().unwrap(), &want);
    }

    #[test]
    fn wide_graph_uses_the_i32_plan_set() {
        let mut g = IntGraph::default();
        let spec = QuantSpec { eps: 1.0, lo: 0, hi: 1 << 16 };
        let x = g.push("in", IntOp::Input { shape: vec![2], spec }, &[]);
        let wq = Tensor::from_vec(&[2, 2], vec![1, 0, 0, 1]).into();
        g.push("fc", IntOp::LinearInt { wq, bias_q: None }, &[x]);
        g.eps_out = 1.0;
        let exec = NativeIntExecutor::new(g, 2).unwrap();
        assert!(!exec.packed());
        let qx = Tensor::from_vec(&[1, 2], vec![40000, 2]);
        let out = exec.run_batch(&ExecInput::i32(qx)).unwrap();
        assert_eq!(out.int_logits().unwrap().data(), &[40000, 2]);
    }

    #[test]
    fn from_artifact_builds_a_bit_identical_executor() {
        let g = identity_int_graph();
        let art = crate::io::DeployedArtifact {
            graph: g.clone(),
            layers: vec![],
            node_eps: vec![1.0; g.nodes.len()],
            worst_case: vec![255, 510],
            meta: Default::default(),
        };
        let path = std::env::temp_dir()
            .join(format!("nemo_exec_artifact_{}.nemo.json", std::process::id()));
        art.save(&path).unwrap();
        let exec = NativeIntExecutor::from_artifact(&path, 4).unwrap();
        assert_eq!(exec.input_shape(), &[2]);
        let qx = Tensor::from_vec(&[2, 2], vec![9, 0, 255, 3]);
        let out = exec.run_batch(&ExecInput::i32(qx.clone())).unwrap();
        let want = NativeIntExecutor::new(g, 4)
            .unwrap()
            .run_batch(&ExecInput::i32(qx))
            .unwrap();
        assert_eq!(
            out.int_logits().unwrap().data(),
            want.int_logits().unwrap().data()
        );
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn per_batch_layouts_compile_lazily_and_cache() {
        // Construction compiles exactly one layout (the batch-1
        // validator) even for a large max_batch; variants fill on first
        // use and are cached, not recompiled.
        let exec = NativeIntExecutor::new(identity_int_graph(), 64).unwrap();
        assert_eq!(exec.compiled_layouts(), 1);
        let qx = Tensor::from_vec(&[3, 2], vec![1, 2, 3, 4, 5, 6]);
        let out = exec.run_batch(&ExecInput::i32(qx.clone())).unwrap();
        assert_eq!(out.int_logits().unwrap().data(), &[1, 2, 3, 4, 5, 6]);
        assert_eq!(exec.compiled_layouts(), 2, "batch-3 variant compiled on demand");
        exec.run_batch(&ExecInput::i32(qx)).unwrap();
        assert_eq!(exec.compiled_layouts(), 2, "second batch-3 request reuses the cache");
    }

    #[test]
    fn from_artifact_with_provenance_reports_the_file() {
        let g = identity_int_graph();
        let art = crate::io::DeployedArtifact {
            graph: g,
            layers: vec![],
            node_eps: vec![1.0; 2],
            worst_case: vec![255, 510],
            meta: Default::default(),
        };
        let path = std::env::temp_dir()
            .join(format!("nemo_exec_prov_{}.nemo.json", std::process::id()));
        art.save(&path).unwrap();
        let (exec, prov) =
            NativeIntExecutor::from_artifact_with_provenance(&path, 2).unwrap();
        assert_eq!(exec.input_shape(), &[2]);
        assert!(prov.path.contains("nemo_exec_prov_"), "{}", prov.path);
        assert!(prov.checksum.starts_with("fnv1a64:"), "{}", prov.checksum);
        assert_eq!(prov.format_version, crate::io::artifact::VERSION);
        assert_eq!(prov.bytes, std::fs::metadata(&path).unwrap().len());
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn native_int_executor_requires_input_node() {
        let mut g = IntGraph::default();
        let wq = Tensor::from_vec(&[1, 1], vec![1]).into();
        g.push("fc", IntOp::LinearInt { wq, bias_q: None }, &[]);
        assert!(NativeIntExecutor::new(g, 4).is_err());
    }

    #[test]
    fn native_float_executor_runs_a_batch() {
        let mut g = Graph::new(1.0 / 255.0);
        let x = g.push("in", Op::Input { shape: vec![2] }, &[]);
        g.push("act", Op::ReLU, &[x]);
        let exec = NativeFloatExecutor::new(g, 4).unwrap();
        let x = TensorF::from_vec(&[1, 2], vec![-1.0, 2.0]);
        let out = exec.run_batch(&ExecInput::f32(x)).unwrap();
        assert_eq!(out.logits.as_f32().unwrap().data(), &[0.0, 2.0]);
    }
}
