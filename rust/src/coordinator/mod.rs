//! Serving coordinator (S7): request router + dynamic batcher + worker
//! pool over any [`Executor`] backend.
//!
//! Deployment shape (vLLM-router-like, scaled to this paper): callers
//! submit single-sample integer images; the batcher coalesces them up to
//! `max_batch` or `batch_timeout`, gathers one batch tensor, executes it
//! on a worker thread through `Executor::run_batch`, and scatters the
//! per-sample results. The backend is interchangeable: the native
//! integer engine (`serve --backend native`, no artifacts needed) and
//! the AOT-compiled PJRT executables (`--backend pjrt`) serve through
//! the identical path — batch-variant selection and padding are the
//! executor's business, not the coordinator's.

pub mod metrics;

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{self, Receiver, Sender, SyncSender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::exec::{Arg, ExecInput, Executor};
use crate::tensor::{Tensor, TensorF, TensorI};

pub use metrics::Metrics;

/// A servable model: a name bound to an [`Executor`] backend.
pub struct ModelVariant {
    pub name: String,
    pub exec: Arc<dyn Executor>,
}

impl ModelVariant {
    /// Serve any executor speaking the integer request protocol: inputs
    /// are integer image batches and logits are integer-valued (the
    /// native integer engine, the PJRT ID executables, or any future ID
    /// backend). An f32 logits tensor is tolerated only when its values
    /// are already integers (some XLA lowerings emit integer math as
    /// f32) — the worker truncates it; genuinely fractional-logit float
    /// backends do not fit this protocol.
    pub fn new(name: &str, exec: Arc<dyn Executor>) -> Self {
        ModelVariant { name: name.to_string(), exec }
    }

    /// Load every `kind` artifact (e.g. "id_fwd") from the PJRT runtime.
    #[cfg(feature = "pjrt")]
    pub fn load(
        rt: &crate::runtime::Runtime,
        name: &str,
        kind: &str,
        base_args: Vec<Arg>,
    ) -> Result<Self> {
        let exec = crate::exec::PjrtExecutor::load(rt, kind, base_args)?;
        Ok(Self::new(name, Arc::new(exec)))
    }

    /// Per-sample input shape expected by the backend.
    pub fn input_shape(&self) -> &[usize] {
        self.exec.input_shape()
    }

    pub fn max_batch(&self) -> usize {
        self.exec.max_batch()
    }
}

struct Request {
    model: String,
    qx: TensorI, // [1, ...]
    reply: SyncSender<Result<TensorI>>,
    enqueued: Instant,
}

/// Coordinator configuration.
#[derive(Clone, Copy, Debug)]
pub struct ServerConfig {
    pub max_batch: usize,
    pub batch_timeout: Duration,
    pub n_workers: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            max_batch: 16,
            batch_timeout: Duration::from_micros(500),
            n_workers: 2,
        }
    }
}

/// Clonable client handle.
#[derive(Clone)]
pub struct ServerHandle {
    tx: Sender<Request>,
}

impl ServerHandle {
    /// Blocking single-sample inference; returns the [1, C_out] integer
    /// logits image.
    pub fn infer(&self, model: &str, qx: TensorI) -> Result<TensorI> {
        let (rtx, rrx) = mpsc::sync_channel(1);
        self.tx
            .send(Request {
                model: model.to_string(),
                qx,
                reply: rtx,
                enqueued: Instant::now(),
            })
            .map_err(|_| anyhow!("server stopped"))?;
        rrx.recv().map_err(|_| anyhow!("server dropped request"))?
    }
}

/// The running server; dropping it (after all handles) stops the threads.
pub struct Server {
    handle: ServerHandle,
    stop: Arc<AtomicBool>,
    pub metrics: Arc<Mutex<Metrics>>,
    threads: Vec<std::thread::JoinHandle<()>>,
}

struct Job {
    exec: Arc<dyn Executor>,
    input: ExecInput,
    waiters: Vec<(SyncSender<Result<TensorI>>, Instant)>,
    n_real: usize,
    /// Batch size the executor will actually run (>= n_real when the
    /// backend pads to a compiled variant).
    batch: usize,
}

impl Server {
    pub fn start(models: Vec<ModelVariant>, cfg: ServerConfig) -> Server {
        let (tx, rx) = mpsc::channel::<Request>();
        let (jtx, jrx) = mpsc::channel::<Job>();
        let jrx = Arc::new(Mutex::new(jrx));
        let metrics = Arc::new(Mutex::new(Metrics::new()));
        let stop = Arc::new(AtomicBool::new(false));
        let registry: Arc<HashMap<String, ModelVariant>> = Arc::new(
            models.into_iter().map(|m| (m.name.clone(), m)).collect(),
        );

        let mut threads = Vec::new();
        // Batcher thread
        {
            let registry = registry.clone();
            let metrics = metrics.clone();
            let stop = stop.clone();
            threads.push(std::thread::spawn(move || {
                batcher_loop(rx, jtx, registry, metrics, stop, cfg);
            }));
        }
        // Worker pool
        for wid in 0..cfg.n_workers {
            let jrx = jrx.clone();
            let metrics = metrics.clone();
            threads.push(std::thread::spawn(move || {
                worker_loop(wid, jrx, metrics);
            }));
        }
        Server { handle: ServerHandle { tx }, stop, metrics, threads }
    }

    pub fn handle(&self) -> ServerHandle {
        self.handle.clone()
    }

    pub fn stop(self) -> Metrics {
        self.stop.store(true, Ordering::SeqCst);
        let Server { handle, metrics, threads, .. } = self;
        drop(handle); // close the request channel so the batcher exits
        for t in threads {
            let _ = t.join();
        }
        let m = metrics.lock().unwrap().clone();
        m
    }
}

fn batcher_loop(
    rx: Receiver<Request>,
    jtx: Sender<Job>,
    registry: Arc<HashMap<String, ModelVariant>>,
    metrics: Arc<Mutex<Metrics>>,
    stop: Arc<AtomicBool>,
    cfg: ServerConfig,
) {
    loop {
        // Block for the first request (or exit when all senders dropped).
        let first = match rx.recv_timeout(Duration::from_millis(50)) {
            Ok(r) => r,
            Err(mpsc::RecvTimeoutError::Timeout) => {
                if stop.load(Ordering::SeqCst) {
                    return;
                }
                continue;
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => return,
        };
        let deadline = Instant::now() + cfg.batch_timeout;
        let mut bucket: HashMap<String, Vec<Request>> = HashMap::new();
        let cap = cfg.max_batch;
        bucket.entry(first.model.clone()).or_default().push(first);
        // Coalesce until the timeout or the cap for some model.
        loop {
            let full = bucket.values().any(|v| v.len() >= cap);
            let now = Instant::now();
            if full || now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(r) => bucket.entry(r.model.clone()).or_default().push(r),
                Err(mpsc::RecvTimeoutError::Timeout) => break,
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
        }
        for (model, reqs) in bucket {
            let Some(mv) = registry.get(&model) else {
                for r in reqs {
                    let _ = r
                        .reply
                        .send(Err(anyhow!("unknown model '{model}'")));
                }
                continue;
            };
            // Split into chunks of at most what the backend can run
            // (floored at 1: chunks(0) panics and a misconfigured
            // max_batch must not take down the batcher thread).
            for chunk in reqs.chunks(mv.max_batch().min(cap).max(1)) {
                dispatch(mv, chunk, &jtx, &metrics);
            }
        }
    }
}

fn dispatch(
    mv: &ModelVariant,
    reqs: &[Request],
    jtx: &Sender<Job>,
    metrics: &Arc<Mutex<Metrics>>,
) {
    // Shape guard: a wrong-shaped request must fail loudly (in release
    // builds too) instead of silently corrupting the gathered batch.
    let expected = mv.input_shape();
    let mut valid: Vec<&Request> = Vec::with_capacity(reqs.len());
    let mut rejected = 0u64;
    for r in reqs {
        let shape = r.qx.shape();
        let ok = shape.first() == Some(&1)
            && shape.len() == expected.len() + 1
            && shape[1..] == *expected;
        if ok {
            valid.push(r);
        } else {
            rejected += 1;
            let _ = r.reply.send(Err(anyhow!(
                "model '{}': input shape {:?} does not match per-sample shape \
                 {:?} (expected a [1, ...] single-sample image)",
                mv.name,
                shape,
                expected
            )));
        }
    }
    if rejected > 0 {
        metrics.lock().unwrap().failed += rejected;
    }
    if valid.is_empty() {
        return;
    }
    let n = valid.len();
    // Gather: [n, ...]; the executor pads to a compiled variant if needed.
    let sample_len: usize = expected.iter().product();
    let mut data = Vec::with_capacity(n * sample_len);
    for r in &valid {
        data.extend_from_slice(r.qx.data());
    }
    let mut shape = vec![n];
    shape.extend_from_slice(expected);
    let qx = Tensor::from_vec(&shape, data);

    {
        let mut m = metrics.lock().unwrap();
        m.batch_sizes.push(n as f64);
        let now = Instant::now();
        for r in &valid {
            m.queue_wait
                .push(now.duration_since(r.enqueued).as_secs_f64());
        }
    }
    let job = Job {
        exec: mv.exec.clone(),
        input: ExecInput::i32(qx),
        waiters: valid.iter().map(|r| (r.reply.clone(), r.enqueued)).collect(),
        n_real: n,
        batch: mv.exec.effective_batch(n),
    };
    if let Err(mpsc::SendError(job)) = jtx.send(job) {
        // The worker pool is gone (server shutting down). Dropping the
        // job here used to drop the reply senders silently, so clients
        // saw a misleading "server dropped request" with no failure
        // recorded — answer with the real cause and count the failures.
        fail_job(
            &job,
            metrics,
            "server is shutting down: worker pool stopped before the batch ran",
        );
    }
}

fn worker_loop(
    _wid: usize,
    jrx: Arc<Mutex<Receiver<Job>>>,
    metrics: Arc<Mutex<Metrics>>,
) {
    loop {
        let job = {
            let guard = jrx.lock().unwrap();
            match guard.recv() {
                Ok(j) => j,
                Err(_) => return,
            }
        };
        let t0 = Instant::now();
        let result = job.exec.run_batch(&job.input);
        let exec_s = t0.elapsed().as_secs_f64();
        match result {
            Ok(out) => {
                let t = match out.logits {
                    Arg::I32(t) => t,
                    Arg::F32(t) => match integral_logits(&t) {
                        Ok(t) => t,
                        Err(msg) => {
                            let msg = format!(
                                "executor '{}' broke the integer logits protocol: {msg}",
                                job.exec.name()
                            );
                            fail_job(&job, &metrics, &msg);
                            continue;
                        }
                    },
                };
                if t.shape().first().copied().unwrap_or(0) < job.n_real {
                    let msg = format!(
                        "executor '{}' returned {} rows for {} samples",
                        job.exec.name(),
                        t.shape().first().copied().unwrap_or(0),
                        job.n_real
                    );
                    fail_job(&job, &metrics, &msg);
                    continue;
                }
                // Scatter replies first, then record everything under a
                // single metrics acquisition per job (the e2e latencies
                // are batched instead of locking once per waiter).
                let done = Instant::now();
                let mut e2e = Vec::with_capacity(job.waiters.len());
                for (i, (reply, enq)) in job.waiters.iter().enumerate() {
                    let row = t.slice_batch(i, i + 1);
                    let _ = reply.send(Ok(row));
                    e2e.push(done.duration_since(*enq).as_secs_f64());
                }
                let mut m = metrics.lock().unwrap();
                m.exec_time.push(exec_s);
                m.completed += job.n_real as u64;
                m.padded += job.batch.saturating_sub(job.n_real) as u64;
                for l in e2e {
                    m.e2e_latency.push(l);
                }
            }
            Err(e) => {
                let msg = format!("execution failed: {e:#}");
                fail_job(&job, &metrics, &msg);
            }
        }
    }
}

/// Convert an f32 logits batch to the integer image the request protocol
/// carries. Per the [`ModelVariant::new`] contract, f32 logits are
/// tolerated only when their values are already integers (some XLA
/// lowerings emit integer math as f32): each value is rounded to the
/// nearest integer, and anything more than 1e-6 from an integer is a
/// protocol violation reported loudly — never truncated silently.
fn integral_logits(t: &TensorF) -> Result<TensorI, String> {
    let mut data = Vec::with_capacity(t.len());
    for &v in t.data() {
        let r = v.round();
        if !v.is_finite() || (v - r).abs() > 1e-6 {
            return Err(format!(
                "f32 logit {v} is not integer-valued (>1e-6 from an integer); \
                 fractional-logit float backends do not fit the integer \
                 request protocol"
            ));
        }
        // Integer-valued but outside i32: `as i32` would saturate — the
        // same silent corruption this function exists to prevent.
        let ri = r as i64;
        if !(i32::MIN as i64..=i32::MAX as i64).contains(&ri) {
            return Err(format!(
                "f32 logit {v} overflows the i32 integer-image range"
            ));
        }
        data.push(ri as i32);
    }
    Ok(Tensor::from_vec(t.shape(), data))
}

fn fail_job(job: &Job, metrics: &Arc<Mutex<Metrics>>, msg: &str) {
    {
        let mut m = metrics.lock().unwrap();
        m.failed += job.n_real as u64;
    }
    for (reply, _) in &job.waiters {
        let _ = reply.send(Err(anyhow!(msg.to_string())));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_defaults_are_sane() {
        let cfg = ServerConfig::default();
        assert!(cfg.max_batch >= 1);
        assert!(cfg.n_workers >= 1);
    }

    #[test]
    fn integral_logits_rounds_to_nearest() {
        // v as i32 used to truncate: 2.9999997 -> 2. Round instead.
        let t = TensorF::from_vec(&[1, 4], vec![2.9999997, -1.0000001, 0.0, 41.0]);
        let q = integral_logits(&t).unwrap();
        assert_eq!(q.data(), &[3, -1, 0, 41]);
    }

    #[test]
    fn integral_logits_rejects_fractional_values() {
        let t = TensorF::from_vec(&[1, 2], vec![1.0, 1.5]);
        let err = integral_logits(&t).unwrap_err();
        assert!(err.contains("not integer-valued"), "{err}");
        let t = TensorF::from_vec(&[1, 1], vec![f32::NAN]);
        assert!(integral_logits(&t).is_err());
        let t = TensorF::from_vec(&[1, 1], vec![1.0 + 2e-6]);
        assert!(integral_logits(&t).is_err());
    }

    struct IdentityExec;
    impl Executor for IdentityExec {
        fn name(&self) -> &str {
            "stub"
        }
        fn input_shape(&self) -> &[usize] {
            &[2]
        }
        fn max_batch(&self) -> usize {
            4
        }
        fn run_batch(&self, input: &ExecInput) -> Result<crate::exec::ExecOutput> {
            Ok(crate::exec::ExecOutput { logits: input.batch.clone() })
        }
    }

    #[test]
    fn dispatch_to_stopped_worker_pool_replies_with_shutdown_error() {
        // Regression: a failed jtx.send(job) dropped the waiters' reply
        // senders, so clients saw "server dropped request" and no failed
        // metric was recorded.
        let mv = ModelVariant::new("m", Arc::new(IdentityExec));
        let (reply, rrx) = mpsc::sync_channel(1);
        let req = Request {
            model: "m".into(),
            qx: Tensor::from_vec(&[1, 2], vec![1, 2]),
            reply,
            enqueued: Instant::now(),
        };
        let (jtx, jrx) = mpsc::channel::<Job>();
        drop(jrx); // worker pool already gone
        let metrics = Arc::new(Mutex::new(Metrics::new()));
        dispatch(&mv, std::slice::from_ref(&req), &jtx, &metrics);
        let err = rrx.recv().expect("a reply must arrive").unwrap_err();
        assert!(err.to_string().contains("shutting down"), "{err}");
        assert_eq!(metrics.lock().unwrap().failed, 1);
    }

    #[test]
    fn integral_logits_rejects_i32_overflow() {
        // 3e9 is exactly integral in f32 but outside i32; `as i32` would
        // silently saturate to i32::MAX.
        let t = TensorF::from_vec(&[1, 1], vec![3e9]);
        let err = integral_logits(&t).unwrap_err();
        assert!(err.contains("overflows"), "{err}");
        let t = TensorF::from_vec(&[1, 1], vec![-3e9]);
        assert!(integral_logits(&t).is_err());
    }
}
