//! # NEMO-rs: integer-only DNN quantization for deployment
//!
//! A Rust + JAX + Pallas reproduction of Conti, *"Technical Report: NEMO
//! Quantization for Deployment Model"* (2020).
//!
//! The paper defines four DNN representations — FullPrecision,
//! FakeQuantized, QuantizedDeployable, IntegerDeployable — and the
//! transforms between them; the last one runs inference using *only*
//! integers. This crate implements:
//!
//! * the full representation pipeline over a graph IR
//!   ([`graph`], [`transform`]);
//! * the quantization/requantization math of paper secs. 2-3 ([`quant`]);
//! * two executors ([`engine`]): a float engine for FP/FQ/QD and an
//!   integer-only engine for ID (the MCU-datapath simulator);
//! * a PJRT runtime ([`runtime`]) that loads the AOT-compiled JAX/Pallas
//!   artifacts (`artifacts/*.hlo.txt`) produced by `python/compile/`;
//! * a serving coordinator ([`coordinator`]) with dynamic batching over
//!   the compiled IntegerDeployable executables;
//! * a QAT training driver ([`train`]) that runs the compiled
//!   FakeQuantized train step — Python is never on the request path;
//! * model zoo, synthetic dataset, checkpoint/manifest I/O
//!   ([`model`], [`data`], [`io`]).
//!
//! See DESIGN.md for the paper-to-module map and EXPERIMENTS.md for the
//! reproduced experiment suite.

pub mod cli;
pub mod coordinator;
pub mod data;
pub mod engine;
pub mod graph;
pub mod io;
pub mod model;
pub mod quant;
pub mod runtime;
pub mod tensor;
pub mod train;
pub mod transform;
pub mod util;
