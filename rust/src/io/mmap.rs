//! Read-only file mapping for zero-copy artifact cold load
//! (DESIGN.md §Artifact-format v3).
//!
//! The offline vendor set has no `libc`/`memmap` crate, so — like the
//! `signal(2)` shim in `net` — the two POSIX symbols are declared
//! directly against the libc that `std` already links. Both sources
//! implement [`ByteSource`], so tensor views borrow from either:
//!
//! * [`MappedFile`] — `mmap(2)` of the whole file, page-aligned (>= the
//!   64-byte section alignment the v3 writer guarantees); weight bytes
//!   are never copied, the kernel pages them in on demand.
//! * [`AlignedBytes`] — the fallback when mapping is unavailable (or
//!   forced by [`BinLoadMode::Read`]): one `read_exact` into a
//!   `Vec<u64>`-backed buffer, so the 8-byte base alignment still
//!   satisfies every element type a section can hold.

use std::fs::File;
use std::io::{self, Read};
use std::path::Path;

use crate::tensor::ByteSource;

/// How the binary-artifact loader acquires the file's bytes.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum BinLoadMode {
    /// `mmap` when the platform supports it, aligned read otherwise.
    #[default]
    Auto,
    /// `mmap` or fail — tests assert the zero-copy path this way.
    Mmap,
    /// Force the aligned `File::read` fallback.
    Read,
}

// Under Miri there is no real syscall layer: the raw mmap/munmap
// declarations are compiled out and `MappedFile::map` reports
// Unsupported, so the loader exercises the aligned-read fallback —
// exactly the path whose pointer arithmetic Miri can verify.
#[cfg(all(unix, not(miri)))]
mod sys {
    // Raw POSIX mmap/munmap against the libc std links (no-libc-crate
    // policy; see `net::shutdown_flag` for the precedent).
    extern "C" {
        pub fn mmap(
            addr: *mut u8,
            len: usize,
            prot: i32,
            flags: i32,
            fd: i32,
            offset: i64,
        ) -> *mut u8;
        pub fn munmap(addr: *mut u8, len: usize) -> i32;
    }

    pub const PROT_READ: i32 = 1;
    pub const MAP_PRIVATE: i32 = 2;
}

/// A read-only `mmap(2)` of a whole file. The mapping outlives every
/// tensor view into it because views hold the owning `Arc`.
pub struct MappedFile {
    ptr: *const u8,
    len: usize,
}

// SAFETY: the mapping is immutable (PROT_READ, MAP_PRIVATE) for its
// whole lifetime and unmapped only in Drop, so moving the owner across
// threads cannot invalidate it.
unsafe impl Send for MappedFile {}
// SAFETY: same invariant — the bytes behind `ptr` never change, so
// concurrent shared reads from multiple threads are sound.
unsafe impl Sync for MappedFile {}

impl MappedFile {
    /// Map `path` read-only. Fails with a plain `io::Error` on
    /// platforms without `mmap` or when the syscall is refused — the
    /// loader then falls back to [`AlignedBytes`].
    #[cfg(all(unix, not(miri)))]
    pub fn map(path: impl AsRef<Path>) -> io::Result<MappedFile> {
        use std::os::unix::io::AsRawFd;
        let file = File::open(path)?;
        let len = file.metadata()?.len();
        if len == 0 {
            // mmap(2) rejects zero-length mappings; an empty artifact
            // is invalid anyway, so surface it as such.
            return Err(io::Error::new(io::ErrorKind::UnexpectedEof, "empty file"));
        }
        let len = usize::try_from(len)
            .map_err(|_| io::Error::new(io::ErrorKind::InvalidData, "file too large"))?;
        // SAFETY: a fresh read-only private mapping of a file we hold
        // open; the fd may close after mmap returns (POSIX keeps the
        // mapping valid).
        let ptr = unsafe {
            sys::mmap(
                std::ptr::null_mut(),
                len,
                sys::PROT_READ,
                sys::MAP_PRIVATE,
                file.as_raw_fd(),
                0,
            )
        };
        if ptr as isize == -1 {
            return Err(io::Error::last_os_error());
        }
        Ok(MappedFile { ptr, len })
    }

    #[cfg(any(not(unix), miri))]
    pub fn map(_path: impl AsRef<Path>) -> io::Result<MappedFile> {
        Err(io::Error::new(
            io::ErrorKind::Unsupported,
            "mmap is unavailable on this platform",
        ))
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }
}

impl ByteSource for MappedFile {
    fn bytes(&self) -> &[u8] {
        // SAFETY: ptr/len describe a live PROT_READ mapping owned by
        // self; it is unmapped only in Drop.
        unsafe { std::slice::from_raw_parts(self.ptr, self.len) }
    }
}

impl Drop for MappedFile {
    fn drop(&mut self) {
        #[cfg(all(unix, not(miri)))]
        // SAFETY: exactly the region mmap returned; no views can
        // outlive self (they hold the Arc that runs this Drop).
        unsafe {
            sys::munmap(self.ptr as *mut u8, self.len);
        }
    }
}

/// The read fallback: the whole file in a `Vec<u64>`-backed buffer, so
/// the base address is 8-aligned and the v3 container's 64-byte
/// section offsets stay aligned for every section element type.
pub struct AlignedBytes {
    buf: Vec<u64>,
    len: usize,
}

impl AlignedBytes {
    pub fn read_file(path: impl AsRef<Path>) -> io::Result<AlignedBytes> {
        let mut file = File::open(path)?;
        let len = usize::try_from(file.metadata()?.len())
            .map_err(|_| io::Error::new(io::ErrorKind::InvalidData, "file too large"))?;
        let mut buf = vec![0u64; len.div_ceil(8)];
        // SAFETY: a u64 buffer viewed as initialized bytes; len <= the
        // allocation's byte size by construction.
        let dst = unsafe {
            std::slice::from_raw_parts_mut(buf.as_mut_ptr().cast::<u8>(), len)
        };
        file.read_exact(dst)?;
        Ok(AlignedBytes { buf, len })
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }
}

impl ByteSource for AlignedBytes {
    fn bytes(&self) -> &[u8] {
        // SAFETY: same region as in read_file; the trailing pad bytes
        // of the last u64 word are excluded by len.
        unsafe { std::slice::from_raw_parts(self.buf.as_ptr().cast::<u8>(), self.len) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(tag: &str, bytes: &[u8]) -> std::path::PathBuf {
        let p = std::env::temp_dir()
            .join(format!("nemo_mmap_{tag}_{}.bin", std::process::id()));
        std::fs::write(&p, bytes).unwrap();
        p
    }

    #[test]
    fn mapped_file_exposes_the_file_bytes() {
        let p = tmp("map", b"hello mapping");
        match MappedFile::map(&p) {
            Ok(m) => {
                assert_eq!(m.bytes(), b"hello mapping");
                assert_eq!(m.len(), 13);
                // Page alignment covers the container's 64-byte rule.
                assert_eq!(m.bytes().as_ptr() as usize % 64, 0);
            }
            Err(e) => {
                // Expected where the syscall shim is compiled out
                // (non-unix, or the Miri lane).
                assert!(cfg!(any(not(unix), miri)), "mmap failed on unix: {e}");
            }
        }
        let _ = std::fs::remove_file(p);
    }

    #[test]
    fn aligned_read_matches_and_is_8_aligned() {
        let data: Vec<u8> = (0..100).collect();
        let p = tmp("read", &data);
        let a = AlignedBytes::read_file(&p).unwrap();
        assert_eq!(a.bytes(), &data[..]);
        assert_eq!(a.len(), 100);
        assert_eq!(a.bytes().as_ptr() as usize % 8, 0);
        let _ = std::fs::remove_file(p);
    }

    #[test]
    fn missing_and_empty_files_error() {
        assert!(AlignedBytes::read_file("/nonexistent/nemo.nemob").is_err());
        assert!(MappedFile::map("/nonexistent/nemo.nemob").is_err());
        let p = tmp("empty", b"");
        assert!(MappedFile::map(&p).is_err());
        let a = AlignedBytes::read_file(&p).unwrap();
        assert!(a.is_empty());
        let _ = std::fs::remove_file(p);
    }
}
