//! Tensor operations: GEMM, conv2d (direct + im2col), pooling.
//!
//! Integer variants accumulate in `i64` and narrow with a checked cast —
//! the deployment pipeline's range analysis (transform/range.rs) proves
//! narrowing is safe for deployed graphs, and the debug assertion catches
//! violations in tests.

use super::{Tensor, TensorF, TensorI};

#[inline]
fn narrow(v: i64) -> i32 {
    debug_assert!(
        v >= i32::MIN as i64 && v <= i32::MAX as i64,
        "integer image overflowed i32: {v}"
    );
    v as i32
}

// ---------------------------------------------------------------------------
// GEMM
// ---------------------------------------------------------------------------

/// C[M,N] = A[M,K] @ B[K,N] over f32.
pub fn matmul_f32(a: &TensorF, b: &TensorF) -> TensorF {
    assert_eq!(a.ndim(), 2);
    assert_eq!(b.ndim(), 2);
    let (m, k) = (a.shape()[0], a.shape()[1]);
    let (k2, n) = (b.shape()[0], b.shape()[1]);
    assert_eq!(k, k2, "matmul inner dims");
    let mut out = vec![0f32; m * n];
    let ad = a.data();
    let bd = b.data();
    // ikj loop order: unit-stride inner loop over both B and C rows.
    for i in 0..m {
        let crow = &mut out[i * n..(i + 1) * n];
        for kk in 0..k {
            let av = ad[i * k + kk];
            if av == 0.0 {
                continue;
            }
            let brow = &bd[kk * n..(kk + 1) * n];
            for j in 0..n {
                crow[j] += av * brow[j];
            }
        }
    }
    Tensor::from_vec(&[m, n], out)
}

/// Integer-image GEMM (Eq. 16): C = A @ B with i64 accumulation,
/// checked-narrowed to i32.
pub fn matmul_i32(a: &TensorI, b: &TensorI) -> TensorI {
    assert_eq!(a.ndim(), 2);
    assert_eq!(b.ndim(), 2);
    let (m, k) = (a.shape()[0], a.shape()[1]);
    let (k2, n) = (b.shape()[0], b.shape()[1]);
    assert_eq!(k, k2, "matmul inner dims");
    let mut out = vec![0i64; m * n];
    let ad = a.data();
    let bd = b.data();
    for i in 0..m {
        let crow = &mut out[i * n..(i + 1) * n];
        for kk in 0..k {
            let av = ad[i * k + kk] as i64;
            if av == 0 {
                continue;
            }
            let brow = &bd[kk * n..(kk + 1) * n];
            for j in 0..n {
                crow[j] += av * brow[j] as i64;
            }
        }
    }
    Tensor::from_vec(&[m, n], out.into_iter().map(narrow).collect())
}

/// Fast integer GEMM accumulating directly in i32 (engine hot path).
///
/// PRECONDITION: the caller proved — via the deployment pipeline's range
/// analysis (transform/deploy.rs) — that every partial sum fits i32.
/// Per-product safety holds whenever |a| < 2^15 and |b| < 2^16 (true for
/// all <=8-bit integer images). i32 accumulation lets LLVM autovectorize
/// the inner loop (the i64-widening variant cannot), ~4x on this testbed.
pub fn matmul_i32_fast(a: &TensorI, b: &TensorI) -> TensorI {
    assert_eq!(a.ndim(), 2);
    assert_eq!(b.ndim(), 2);
    let (m, k) = (a.shape()[0], a.shape()[1]);
    let (k2, n) = (b.shape()[0], b.shape()[1]);
    assert_eq!(k, k2, "matmul inner dims");
    let mut out = vec![0i32; m * n];
    let ad = a.data();
    let bd = b.data();
    for i in 0..m {
        let crow = &mut out[i * n..(i + 1) * n];
        let arow = &ad[i * k..(i + 1) * k];
        for (kk, &av) in arow.iter().enumerate() {
            if av == 0 {
                continue;
            }
            let brow = &bd[kk * n..(kk + 1) * n];
            for j in 0..n {
                crow[j] = crow[j].wrapping_add(av.wrapping_mul(brow[j]));
            }
        }
    }
    Tensor::from_vec(&[m, n], out)
}

// ---------------------------------------------------------------------------
// im2col (shared by both engines; layout matches python kernels/ref.py)
// ---------------------------------------------------------------------------

/// NCHW -> [B*OH*OW, C*KH*KW] patches; column index = c*(kh*kw) + ki*kw + kj.
///
/// Loop order (bi, ci, ki, kj) outer / (oy, ox) inner with the valid
/// output ranges computed once per (ki, kj): the inner loops are
/// branch-free induction (the #Perf pass measured ~2x over the naive
/// per-pixel bounds-checked form).
pub fn im2col<T: Copy + Default>(
    x: &Tensor<T>,
    kh: usize,
    kw: usize,
    stride: usize,
    pad: usize,
) -> (Tensor<T>, (usize, usize, usize)) {
    assert_eq!(x.ndim(), 4);
    let (b, c, h, w) = (x.shape()[0], x.shape()[1], x.shape()[2], x.shape()[3]);
    let oh = (h + 2 * pad - kh) / stride + 1;
    let ow = (w + 2 * pad - kw) / stride + 1;
    let cols = c * kh * kw;
    let mut out = vec![T::default(); b * oh * ow * cols];
    let xd = x.data();
    // valid output index range for a kernel offset k: iy = o*stride+k-pad
    // must lie in [0, dim): o >= ceil((pad-k)/stride), o < ...
    let valid = |k: usize, dim: usize, omax: usize| -> (usize, usize) {
        let lo = pad.saturating_sub(k).div_ceil(stride);
        let hi_excl = if dim + pad > k {
            ((dim + pad - k - 1) / stride + 1).min(omax)
        } else {
            0
        };
        (lo.min(omax), hi_excl)
    };
    for bi in 0..b {
        for ci in 0..c {
            let xbase = (bi * c + ci) * h * w;
            for ki in 0..kh {
                let (oy_lo, oy_hi) = valid(ki, h, oh);
                for kj in 0..kw {
                    let (ox_lo, ox_hi) = valid(kj, w, ow);
                    let col = ci * kh * kw + ki * kw + kj;
                    for oy in oy_lo..oy_hi {
                        let iy = oy * stride + ki - pad;
                        let xrow = xbase + iy * w;
                        let orow = ((bi * oh + oy) * ow) * cols + col;
                        let mut ix = ox_lo * stride + kj - pad;
                        for ox in ox_lo..ox_hi {
                            out[orow + ox * cols] = xd[xrow + ix];
                            ix += stride;
                        }
                    }
                }
            }
        }
    }
    (Tensor::from_vec(&[b * oh * ow, cols], out), (b, oh, ow))
}

/// [B*OH*OW, C_out] rows -> NCHW.
pub fn rows_to_nchw<T: Copy + Default>(
    rows: &Tensor<T>,
    b: usize,
    oh: usize,
    ow: usize,
) -> Tensor<T> {
    assert_eq!(rows.ndim(), 2);
    assert_eq!(rows.shape()[0], b * oh * ow);
    let c = rows.shape()[1];
    let mut out = Tensor::zeros(&[b, c, oh, ow]);
    for bi in 0..b {
        for oy in 0..oh {
            for ox in 0..ow {
                let row = (bi * oh + oy) * ow + ox;
                for ci in 0..c {
                    out.set4(bi, ci, oy, ox, rows.at2(row, ci));
                }
            }
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Convolution
// ---------------------------------------------------------------------------

/// f32 conv2d, weights OIHW, input NCHW, zero padding.
pub fn conv2d_f32(
    x: &TensorF,
    w: &TensorF,
    stride: usize,
    pad: usize,
) -> TensorF {
    let (cols, (b, oh, ow)) = im2col(x, w.shape()[2], w.shape()[3], stride, pad);
    let (co, ci, kh, kw) = (w.shape()[0], w.shape()[1], w.shape()[2], w.shape()[3]);
    // OIHW -> [C_in*KH*KW, C_out] matching im2col column order.
    let mut wmat = vec![0f32; ci * kh * kw * co];
    for o in 0..co {
        for i in 0..ci {
            for y in 0..kh {
                for z in 0..kw {
                    wmat[(i * kh * kw + y * kw + z) * co + o] =
                        w.data()[((o * ci + i) * kh + y) * kw + z];
                }
            }
        }
    }
    let wt = Tensor::from_vec(&[ci * kh * kw, co], wmat);
    rows_to_nchw(&matmul_f32(&cols, &wt), b, oh, ow)
}

/// Integer conv2d with weights already in matrix layout
/// [C_in*KH*KW, C_out] (the ID artifact layout).
pub fn conv2d_i32_wmat(
    x: &TensorI,
    wmat: &TensorI,
    kh: usize,
    kw: usize,
    stride: usize,
    pad: usize,
) -> TensorI {
    let (cols, (b, oh, ow)) = im2col(x, kh, kw, stride, pad);
    rows_to_nchw(&matmul_i32(&cols, wmat), b, oh, ow)
}

/// Fast variant of [`conv2d_i32_wmat`] using the i32-accumulating GEMM.
/// Same range-analysis precondition as [`matmul_i32_fast`].
pub fn conv2d_i32_wmat_fast(
    x: &TensorI,
    wmat: &TensorI,
    kh: usize,
    kw: usize,
    stride: usize,
    pad: usize,
) -> TensorI {
    let (cols, (b, oh, ow)) = im2col(x, kh, kw, stride, pad);
    rows_to_nchw(&matmul_i32_fast(&cols, wmat), b, oh, ow)
}

// ---------------------------------------------------------------------------
// Pooling
// ---------------------------------------------------------------------------

/// Max pool, window = stride (sec. 3.6: untouched by quantization).
pub fn maxpool<T: Copy + Default + PartialOrd>(x: &Tensor<T>, k: usize) -> Tensor<T> {
    let (b, c, h, w) = (x.shape()[0], x.shape()[1], x.shape()[2], x.shape()[3]);
    assert!(h % k == 0 && w % k == 0);
    let (oh, ow) = (h / k, w / k);
    let mut out = Tensor::zeros(&[b, c, oh, ow]);
    for bi in 0..b {
        for ci in 0..c {
            for oy in 0..oh {
                for ox in 0..ow {
                    let mut best = x.at4(bi, ci, oy * k, ox * k);
                    for dy in 0..k {
                        for dx in 0..k {
                            let v = x.at4(bi, ci, oy * k + dy, ox * k + dx);
                            if v > best {
                                best = v;
                            }
                        }
                    }
                    out.set4(bi, ci, oy, ox, best);
                }
            }
        }
    }
    out
}

/// f32 average pool, window = stride.
pub fn avgpool_f32(x: &TensorF, k: usize) -> TensorF {
    let (b, c, h, w) = (x.shape()[0], x.shape()[1], x.shape()[2], x.shape()[3]);
    assert!(h % k == 0 && w % k == 0);
    let (oh, ow) = (h / k, w / k);
    let mut out = Tensor::zeros(&[b, c, oh, ow]);
    let inv = 1.0 / (k * k) as f32;
    for bi in 0..b {
        for ci in 0..c {
            for oy in 0..oh {
                for ox in 0..ow {
                    let mut acc = 0f32;
                    for dy in 0..k {
                        for dx in 0..k {
                            acc += x.at4(bi, ci, oy * k + dy, ox * k + dx);
                        }
                    }
                    out.set4(bi, ci, oy, ox, acc * inv);
                }
            }
        }
    }
    out
}

/// Integer average pool (Eq. 25): (floor(2^d/(K*K)) * sum) >> d.
pub fn avgpool_i32(x: &TensorI, k: usize, d: u32) -> TensorI {
    let (b, c, h, w) = (x.shape()[0], x.shape()[1], x.shape()[2], x.shape()[3]);
    assert!(h % k == 0 && w % k == 0);
    let (oh, ow) = (h / k, w / k);
    let m = ((1i64 << d) / (k * k) as i64) as i64;
    let mut out = Tensor::zeros(&[b, c, oh, ow]);
    for bi in 0..b {
        for ci in 0..c {
            for oy in 0..oh {
                for ox in 0..ow {
                    let mut acc = 0i64;
                    for dy in 0..k {
                        for dx in 0..k {
                            acc += x.at4(bi, ci, oy * k + dy, ox * k + dx) as i64;
                        }
                    }
                    out.set4(bi, ci, oy, ox, narrow((acc * m) >> d));
                }
            }
        }
    }
    out
}

/// Global mean over H,W: [B,C,H,W] f32 -> [B,C].
pub fn global_mean_f32(x: &TensorF) -> TensorF {
    let (b, c, h, w) = (x.shape()[0], x.shape()[1], x.shape()[2], x.shape()[3]);
    let inv = 1.0 / (h * w) as f32;
    let mut out = Tensor::zeros(&[b, c]);
    for bi in 0..b {
        for ci in 0..c {
            let mut acc = 0f32;
            for y in 0..h {
                for z in 0..w {
                    acc += x.at4(bi, ci, y, z);
                }
            }
            out.data_mut()[bi * c + ci] = acc * inv;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_i(rng: &mut Rng, shape: &[usize], lo: i64, hi: i64) -> TensorI {
        let n: usize = shape.iter().product();
        Tensor::from_vec(shape, (0..n).map(|_| rng.int(lo, hi) as i32).collect())
    }

    fn rand_f(rng: &mut Rng, shape: &[usize]) -> TensorF {
        let n: usize = shape.iter().product();
        Tensor::from_vec(shape, (0..n).map(|_| rng.normal(0.0, 1.0) as f32).collect())
    }

    #[test]
    fn matmul_fast_matches_checked() {
        let mut rng = Rng::new(11);
        for _ in 0..20 {
            let m = rng.int(1, 40) as usize;
            let k = rng.int(1, 60) as usize;
            let n = rng.int(1, 40) as usize;
            let a = rand_i(&mut rng, &[m, k], -255, 256);
            let b = rand_i(&mut rng, &[k, n], -128, 128);
            assert_eq!(matmul_i32(&a, &b), matmul_i32_fast(&a, &b));
        }
    }

    #[test]
    fn matmul_i32_small() {
        let a = Tensor::from_vec(&[2, 3], vec![1, 2, 3, 4, 5, 6]);
        let b = Tensor::from_vec(&[3, 2], vec![7, 8, 9, 10, 11, 12]);
        let c = matmul_i32(&a, &b);
        assert_eq!(c.data(), &[58, 64, 139, 154]);
    }

    #[test]
    fn matmul_f32_matches_naive() {
        let mut rng = Rng::new(1);
        let a = rand_f(&mut rng, &[17, 23]);
        let b = rand_f(&mut rng, &[23, 9]);
        let c = matmul_f32(&a, &b);
        for i in 0..17 {
            for j in 0..9 {
                let mut acc = 0f32;
                for k in 0..23 {
                    acc += a.at2(i, k) * b.at2(k, j);
                }
                assert!((c.at2(i, j) - acc).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn conv_via_im2col_matches_direct() {
        // 1x1 kernel conv == per-pixel matmul; sanity for layout.
        let mut rng = Rng::new(2);
        let x = rand_i(&mut rng, &[2, 3, 4, 4], -100, 100);
        let w = rand_i(&mut rng, &[3, 5], -50, 50); // [cin*1*1, cout]
        let y = conv2d_i32_wmat(&x, &w, 1, 1, 1, 0);
        assert_eq!(y.shape(), &[2, 5, 4, 4]);
        // check one output element by hand
        let mut acc = 0i64;
        for ci in 0..3 {
            acc += x.at4(1, ci, 2, 3) as i64 * w.at2(ci, 4) as i64;
        }
        assert_eq!(y.at4(1, 4, 2, 3) as i64, acc);
    }

    #[test]
    fn conv_stride_padding_shapes() {
        let x = Tensor::<i32>::zeros(&[1, 1, 16, 16]);
        let w = Tensor::<i32>::zeros(&[9, 8]);
        let y = conv2d_i32_wmat(&x, &w, 3, 3, 2, 1);
        assert_eq!(y.shape(), &[1, 8, 8, 8]);
    }

    #[test]
    fn conv_f32_identity_kernel() {
        let mut rng = Rng::new(3);
        let x = rand_f(&mut rng, &[1, 1, 5, 5]);
        // 3x3 identity kernel (center 1)
        let mut wd = vec![0f32; 9];
        wd[4] = 1.0;
        let w = Tensor::from_vec(&[1, 1, 3, 3], wd);
        let y = conv2d_f32(&x, &w, 1, 1);
        assert!(y.allclose(&x, 1e-6, 0.0));
    }

    #[test]
    fn maxpool_and_avgpool() {
        let x = Tensor::from_vec(&[1, 1, 2, 2], vec![1, 5, 3, 4]);
        assert_eq!(maxpool(&x, 2).data(), &[5]);
        // avgpool_i32: sum=13, m=floor(2^12/4)=1024, (13*1024)>>12 = 3
        assert_eq!(avgpool_i32(&x, 2, 12).data(), &[3]);
        let xf = x.map(|v| v as f32);
        assert!((avgpool_f32(&xf, 2).data()[0] - 3.25).abs() < 1e-6);
    }

    #[test]
    fn global_mean() {
        let x = Tensor::from_vec(&[1, 2, 1, 2], vec![1.0f32, 3.0, 10.0, 20.0]);
        let y = global_mean_f32(&x);
        assert_eq!(y.data(), &[2.0, 15.0]);
    }

    #[test]
    fn im2col_matches_python_layout() {
        // mirrors python test: column index = c*(kh*kw) + ki*kw + kj
        let x = Tensor::from_vec(&[1, 2, 2, 2], vec![1, 2, 3, 4, 5, 6, 7, 8]);
        let (cols, (b, oh, ow)) = im2col(&x, 2, 2, 1, 0);
        assert_eq!((b, oh, ow), (1, 1, 1));
        assert_eq!(cols.data(), &[1, 2, 3, 4, 5, 6, 7, 8]);
    }
}
