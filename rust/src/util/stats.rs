//! Latency/throughput statistics for the coordinator and the bench harness.

/// Reservoir-free percentile tracker: stores all samples (benches and
/// serving runs here are small enough), computes p50/p95/p99/mean.
#[derive(Clone, Debug, Default)]
pub struct Samples {
    xs: Vec<f64>,
    sorted: bool,
}

impl Samples {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: f64) {
        self.xs.push(x);
        self.sorted = false;
    }

    /// Append every sample from `other` (per-model metrics folding into
    /// an aggregate view).
    pub fn extend_from(&mut self, other: &Samples) {
        self.xs.extend_from_slice(&other.xs);
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.xs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.xs.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.xs.is_empty() {
            return f64::NAN;
        }
        self.xs.iter().sum::<f64>() / self.xs.len() as f64
    }

    /// Smallest sample; NaN for an empty set (an empty metric must read
    /// as "no data", not as a real +infinity observation).
    pub fn min(&self) -> f64 {
        if self.xs.is_empty() {
            return f64::NAN;
        }
        self.xs.iter().cloned().fold(f64::INFINITY, f64::min)
    }

    /// Largest sample; NaN for an empty set.
    pub fn max(&self) -> f64 {
        if self.xs.is_empty() {
            return f64::NAN;
        }
        self.xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
    }

    pub fn std(&self) -> f64 {
        let m = self.mean();
        if self.xs.len() < 2 {
            return 0.0;
        }
        (self.xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>()
            / (self.xs.len() - 1) as f64)
            .sqrt()
    }

    /// q in [0, 1]; nearest-rank on the sorted samples. NaN samples sort
    /// last under `total_cmp` instead of panicking the metrics thread (a
    /// NaN duration ratio pushed by a metrics path must not take down
    /// the summary).
    pub fn percentile(&mut self, q: f64) -> f64 {
        if self.xs.is_empty() {
            return f64::NAN;
        }
        if !self.sorted {
            self.xs.sort_by(f64::total_cmp);
            self.sorted = true;
        }
        let idx = ((self.xs.len() as f64 - 1.0) * q).round() as usize;
        self.xs[idx.min(self.xs.len() - 1)]
    }

    pub fn summary(&mut self) -> String {
        format!(
            "n={} mean={:.3} p50={:.3} p95={:.3} p99={:.3} max={:.3}",
            self.len(),
            self.mean(),
            self.percentile(0.50),
            self.percentile(0.95),
            self.percentile(0.99),
            self.max()
        )
    }
}

/// Fixed-bucket histogram (for metric export without storing samples).
#[derive(Clone, Debug)]
pub struct Histogram {
    bounds: Vec<f64>,
    counts: Vec<u64>,
    total: u64,
    sum: f64,
}

impl Histogram {
    /// Exponential buckets: start, start*factor, ...
    pub fn exponential(start: f64, factor: f64, n: usize) -> Self {
        let mut bounds = Vec::with_capacity(n);
        let mut b = start;
        for _ in 0..n {
            bounds.push(b);
            b *= factor;
        }
        Histogram { counts: vec![0; n + 1], bounds, total: 0, sum: 0.0 }
    }

    pub fn observe(&mut self, x: f64) {
        let idx = self.bounds.partition_point(|b| *b <= x);
        self.counts[idx] += 1;
        self.total += 1;
        self.sum += x;
    }

    pub fn total(&self) -> u64 {
        self.total
    }

    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            f64::NAN
        } else {
            self.sum / self.total as f64
        }
    }

    /// Approximate quantile from the histogram buckets.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.total == 0 {
            return f64::NAN;
        }
        let target = (q * self.total as f64).ceil() as u64;
        let mut acc = 0;
        for (i, c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= target {
                return if i == 0 {
                    self.bounds.first().copied().unwrap_or(0.0)
                } else {
                    self.bounds[(i - 1).min(self.bounds.len() - 1)]
                };
            }
        }
        *self.bounds.last().unwrap()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles() {
        let mut s = Samples::new();
        for i in 1..=100 {
            s.push(i as f64);
        }
        assert_eq!(s.percentile(0.0), 1.0);
        assert_eq!(s.percentile(1.0), 100.0);
        let p50 = s.percentile(0.5);
        assert!((49.0..=51.0).contains(&p50));
        assert!((s.mean() - 50.5).abs() < 1e-9);
    }

    #[test]
    fn nan_samples_do_not_panic_percentile() {
        // Regression: partial_cmp().unwrap() panicked on any NaN sample.
        let mut s = Samples::new();
        s.push(3.0);
        s.push(f64::NAN);
        s.push(1.0);
        // NaN sorts last under total_cmp; the low percentiles stay real.
        assert_eq!(s.percentile(0.0), 1.0);
        assert_eq!(s.percentile(0.5), 3.0);
        assert!(s.percentile(1.0).is_nan());
        // summary() touches every percentile and must not panic either.
        let _ = s.summary();
    }

    #[test]
    fn empty_set_min_max_are_nan_not_infinite() {
        let s = Samples::new();
        assert!(s.min().is_nan());
        assert!(s.max().is_nan());
        let mut one = Samples::new();
        one.push(2.5);
        assert_eq!(one.min(), 2.5);
        assert_eq!(one.max(), 2.5);
    }

    #[test]
    fn histogram_buckets() {
        let mut h = Histogram::exponential(1.0, 2.0, 10);
        for x in [0.5, 1.5, 3.0, 100.0] {
            h.observe(x);
        }
        assert_eq!(h.total(), 4);
        assert!(h.mean() > 0.0);
        assert!(h.quantile(0.99) >= 32.0);
    }
}
