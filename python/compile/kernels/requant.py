"""Requantization Pallas kernel (Def. 3.1, Eq. 13).

    RQ(q) = clip((floor(eps_a * 2^d / eps_b) * q) >> d, lo, hi)

m = floor(eps_a*2^d/eps_b), d are derived at deployment time by the Rust
pipeline (quant/requant.rs mirrors quantlib.choose_d). The multiply is
widened to int64 in-kernel: with the Eq. 14 minimal d, m is in
[factor, 2*factor) and q after integer BN can reach ~2^28, so m*q can
exceed int32. The arithmetic right shift implements floor toward -inf.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import INT, WIDE, INTERPRET, cdiv, pad_to


def _requant_kernel(q_ref, mdlh_ref, o_ref):
    q = q_ref[...].astype(WIDE)
    m = mdlh_ref[0].astype(WIDE)
    d = mdlh_ref[1].astype(WIDE)
    lo = mdlh_ref[2].astype(WIDE)
    hi = mdlh_ref[3].astype(WIDE)
    o_ref[...] = jnp.clip(jnp.right_shift(q * m, d), lo, hi).astype(INT)


def requant(q: jnp.ndarray, m: jnp.ndarray, d: jnp.ndarray, lo: jnp.ndarray,
            hi: jnp.ndarray, *, block: int = 4096) -> jnp.ndarray:
    """Elementwise requantization over a flattened int32 tensor."""
    shape = q.shape
    flat = q.reshape(-1)
    n = flat.shape[0]
    fp = pad_to(flat, 0, block)
    mdlh = jnp.stack([m, d, lo, hi]).astype(INT)
    out = pl.pallas_call(
        _requant_kernel,
        grid=(cdiv(n, block),),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((4,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct(fp.shape, INT),
        interpret=INTERPRET,
    )(fp, mdlh)
    return out[:n].reshape(shape)
