//! Compiled execution plans (DESIGN.md §Plan-compilation).
//!
//! The interpreters in [`super::integer`]/[`super::float`] walk the graph
//! per call and allocate a fresh tensor per node. A [`IntPlan`] /
//! [`FloatPlan`] instead compiles a graph **once**:
//!
//! 1. **Shape inference** ([`crate::graph::shape`]) — every node's output
//!    shape is a static function of the graph (only the batch dimension
//!    varies), so it is computed at compile time, not per request.
//! 2. **Fusion** — the deployment pipeline guarantees that
//!    `ConvInt/LinearInt → IntBn → RequantAct/ThreshAct` chains (and the
//!    residual `AddRequant` equivalents) are pointwise per-channel
//!    epilogues of the producing GEMM/Add. The planner collapses each
//!    chain into a single step whose epilogue runs while the GEMM output
//!    is narrowed i64→i32 — no intermediate tensors, bit-identical
//!    results (the float pipeline fuses `Conv2d/Linear/Add → BatchNorm/
//!    QuantBn → ReLU/PactAct` the same way).
//! 3. **Liveness + arena planning** — a topological liveness pass assigns
//!    every step output (and conv im2col/GEMM scratch) to a slot in a
//!    reusable buffer arena; slots are recycled the moment their last
//!    reader retires. Executing a plan performs zero graph walking and —
//!    with a pooled [`Arena`] — zero steady-state allocation beyond the
//!    returned output tensor.
//!
//! [`PlanLayout`] carries the per-batch-size slot assignment so executors
//! can compile one layout per batch variant up front and share the plan
//! (weights are held once, in the plan's steps).

use crate::graph::int::{IntGraph, IntOp};
use crate::graph::shape::{self, ShapeError};
use crate::graph::{Graph, NodeId, Op};
use crate::quant::bn::{BnQuant, Thresholds};
use crate::quant::requant::Requant;
use crate::quant::QuantSpec;
use crate::tensor::{ops, Tensor, TensorF, TensorI};

pub type StepId = usize;

/// Sentinel slot meaning "this step's output is the request input".
const INPUT_SLOT: usize = usize::MAX;

#[derive(Debug, thiserror::Error)]
pub enum PlanError {
    #[error("shape inference: {0}")]
    Shape(#[from] ShapeError),
    #[error("plan: {0}")]
    Invalid(String),
}

// ---------------------------------------------------------------------------
// Arena + per-batch layout (shared by the int and float plans)
// ---------------------------------------------------------------------------

/// A pool of reusable buffers addressed by slot id. Arenas only ever
/// grow; an arena prepared for batch 16 serves batch 1 without resizing.
pub struct Arena<T> {
    bufs: Vec<Vec<T>>,
}

pub type IntArena = Arena<i32>;
pub type FloatArena = Arena<f32>;

impl<T> Default for Arena<T> {
    fn default() -> Self {
        Arena { bufs: Vec::new() }
    }
}

impl<T: Copy + Default> Arena<T> {
    pub fn new() -> Self {
        Self::default()
    }

    /// Grow buffers to satisfy `layout`'s slot lengths.
    fn prepare(&mut self, layout: &PlanLayout) {
        if self.bufs.len() < layout.slot_lens.len() {
            self.bufs.resize_with(layout.slot_lens.len(), Vec::new);
        }
        for (i, &len) in layout.slot_lens.iter().enumerate() {
            if self.bufs[i].len() < len {
                self.bufs[i].resize(len, T::default());
            }
        }
    }

    /// Total elements currently held (diagnostics).
    pub fn len(&self) -> usize {
        self.bufs.iter().map(|b| b.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Per-batch-size execution layout: full shapes, arena slot of every step
/// output, conv scratch slots, and the required slot lengths.
#[derive(Clone, Debug)]
pub struct PlanLayout {
    pub batch: usize,
    shapes: Vec<Vec<usize>>,
    out_slot: Vec<usize>,
    scratch: Vec<Vec<usize>>,
    /// Required length of each arena slot.
    pub slot_lens: Vec<usize>,
}

impl PlanLayout {
    /// Total arena elements this layout requires (perf introspection).
    pub fn arena_len(&self) -> usize {
        self.slot_lens.iter().sum()
    }

    /// Number of distinct arena slots (vs. one buffer per node in the
    /// interpreter).
    pub fn arena_slots(&self) -> usize {
        self.slot_lens.len()
    }
}

/// What the slot allocator needs to know about one step.
struct StepSpec {
    inputs: Vec<StepId>,
    out_len: usize,
    scratch: Vec<usize>,
    is_input: bool,
}

/// Liveness-driven slot assignment: walk the schedule once, allocating
/// output/scratch slots from a free list and recycling a slot as soon as
/// its last reader has executed. Returns (out_slot, scratch_slots,
/// slot_lens).
fn assign_slots(
    specs: &[StepSpec],
    output: StepId,
) -> (Vec<usize>, Vec<Vec<usize>>, Vec<usize>) {
    let n = specs.len();
    let mut last_use: Vec<usize> = (0..n).collect();
    for (s, spec) in specs.iter().enumerate() {
        for &i in &spec.inputs {
            last_use[i] = last_use[i].max(s);
        }
    }
    last_use[output] = usize::MAX; // the network output is read after the loop

    fn alloc(len: usize, slot_lens: &mut Vec<usize>, free: &mut Vec<usize>) -> usize {
        // Best fit: the smallest free slot already >= len; otherwise the
        // largest free slot (least growth); otherwise a fresh slot.
        let mut best: Option<usize> = None;
        for (fi, &slot) in free.iter().enumerate() {
            let better = match best {
                None => true,
                Some(b) => {
                    let (cap, bcap) = (slot_lens[slot], slot_lens[free[b]]);
                    match (cap >= len, bcap >= len) {
                        (true, true) => cap < bcap,
                        (true, false) => true,
                        (false, true) => false,
                        (false, false) => cap > bcap,
                    }
                }
            };
            if better {
                best = Some(fi);
            }
        }
        match best {
            Some(fi) => {
                let slot = free.swap_remove(fi);
                if slot_lens[slot] < len {
                    slot_lens[slot] = len;
                }
                slot
            }
            None => {
                slot_lens.push(len);
                slot_lens.len() - 1
            }
        }
    }

    let mut out_slot = vec![INPUT_SLOT; n];
    let mut scratch_slots: Vec<Vec<usize>> = vec![Vec::new(); n];
    let mut slot_lens: Vec<usize> = Vec::new();
    let mut free: Vec<usize> = Vec::new();
    for (s, spec) in specs.iter().enumerate() {
        if !spec.is_input {
            // Scratch and output are allocated while every input is still
            // live, so a step can never alias a buffer it reads.
            for &sl in &spec.scratch {
                let slot = alloc(sl, &mut slot_lens, &mut free);
                scratch_slots[s].push(slot);
            }
            out_slot[s] = alloc(spec.out_len, &mut slot_lens, &mut free);
            // Scratch dies with the step.
            for &slot in &scratch_slots[s] {
                free.push(slot);
            }
        }
        // Inputs whose last reader is this step are dead now.
        let mut freed: Vec<StepId> = Vec::new();
        for &i in &spec.inputs {
            if last_use[i] == s && !specs[i].is_input && !freed.contains(&i) {
                freed.push(i);
                free.push(out_slot[i]);
            }
        }
    }
    (out_slot, scratch_slots, slot_lens)
}

/// Read a step's output: the request input for Input steps, its arena
/// slot otherwise.
fn slot_data<'a, T: Copy + Default>(
    arena: &'a Arena<T>,
    layout: &PlanLayout,
    sid: StepId,
    qx: &'a Tensor<T>,
) -> &'a [T] {
    let slot = layout.out_slot[sid];
    if slot == INPUT_SLOT {
        qx.data()
    } else {
        &arena.bufs[slot]
    }
}

/// channel-of-flat-index helper: NCHW -> (i / (H*W)) % C, [B, C] -> i % C.
fn channel_stride(shape: &[usize]) -> (usize, usize) {
    match shape.len() {
        4 => (shape[1], shape[2] * shape[3]),
        2 => (shape[1], 1),
        d => panic!("per-channel op on rank-{d} tensor"),
    }
}

// ---------------------------------------------------------------------------
// Integer plan
// ---------------------------------------------------------------------------

/// Fused per-channel integer epilogue, applied while a GEMM/Add output is
/// narrowed i64 → i32: Eq. 22 integer BN, then Eq. 11 requantization or
/// the Eq. 19-20 threshold activation. Each stage narrows through the
/// shared checked [`ops::narrow`], exactly like the standalone ops, so
/// fused execution is bit-identical to the interpreter.
#[derive(Clone, Debug, Default)]
pub struct IntEpilogue {
    bn: Option<BnQuant>,
    act: Option<IntAct>,
}

#[derive(Clone, Debug)]
enum IntAct {
    Requant(Requant),
    Thresh(Thresholds),
}

impl IntEpilogue {
    fn is_empty(&self) -> bool {
        self.bn.is_none() && self.act.is_none()
    }

    /// Stages fused into this epilogue (diagnostics).
    pub fn depth(&self) -> usize {
        self.bn.is_some() as usize + self.act.is_some() as usize
    }

    #[inline]
    fn apply(&self, c: usize, v: i64) -> i32 {
        let v = match &self.bn {
            Some(bn) => ops::narrow(bn.apply(c, v)) as i64,
            None => v,
        };
        match &self.act {
            Some(IntAct::Requant(rq)) => ops::narrow(rq.apply(v)),
            Some(IntAct::Thresh(th)) => ops::narrow(th.apply(c, v)),
            None => ops::narrow(v),
        }
    }
}

/// Per-channel bias + epilogue over a raw GEMM accumulator (the closure
/// handed to [`ops::matmul_i32_fused_into`]; column index = channel).
fn int_epi_fn<'a>(
    bias: Option<&'a [i64]>,
    epi: &'a IntEpilogue,
) -> impl Fn(usize, i32) -> i32 + Sync + 'a {
    move |c, acc| {
        let mut v = acc as i64;
        if let Some(b) = bias {
            v = ops::narrow(v + b[c]) as i64;
        }
        epi.apply(c, v)
    }
}

enum IntStepOp {
    Input,
    Conv {
        wq: TensorI,
        bias_q: Option<Vec<i64>>,
        kh: usize,
        kw: usize,
        stride: usize,
        pad: usize,
        epi: IntEpilogue,
    },
    Linear {
        wq: TensorI,
        bias_q: Option<Vec<i64>>,
        epi: IntEpilogue,
    },
    Bn { bn: BnQuant },
    Requant { rq: Requant },
    Thresh { th: Thresholds },
    AvgPool { k: usize, d: u32 },
    MaxPool { k: usize },
    Flatten,
    Add { rqs: Vec<Requant>, epi: IntEpilogue },
}

/// One compiled step. `node` is the *last* graph node fused into the
/// step — its output is bit-identical to that node's interpreter output,
/// which is what `execute_traced` reports and the plan property tests
/// check against `run_traced`.
pub struct IntStep {
    op: IntStepOp,
    inputs: Vec<StepId>,
    pub node: NodeId,
    pub name: String,
}

impl IntStep {
    /// Number of graph nodes fused into this step beyond the base op.
    pub fn fused_depth(&self) -> usize {
        match &self.op {
            IntStepOp::Conv { epi, .. }
            | IntStepOp::Linear { epi, .. }
            | IntStepOp::Add { epi, .. } => epi.depth(),
            _ => 0,
        }
    }
}

/// A compiled integer-graph execution plan. Compile once per graph;
/// derive a [`PlanLayout`] per batch size; execute with a (pooled)
/// [`IntArena`].
pub struct IntPlan {
    steps: Vec<IntStep>,
    output: StepId,
    /// Per-step output shape without the batch dimension.
    sample_shapes: Vec<Vec<usize>>,
    input_shape: Vec<usize>,
    fused_away: usize,
}

impl IntPlan {
    pub fn compile(g: &IntGraph) -> Result<IntPlan, PlanError> {
        let input_shape = match g.nodes.first().map(|nd| &nd.op) {
            Some(IntOp::Input { shape, .. }) => shape.clone(),
            _ => {
                return Err(PlanError::Invalid(
                    "integer graph has no leading Input node".into(),
                ))
            }
        };
        let shapes1 = shape::infer_int(g, 1)?;
        let n = g.nodes.len();
        let mut fanout = vec![0usize; n];
        let mut consumers: Vec<Vec<NodeId>> = vec![Vec::new(); n];
        for nd in &g.nodes {
            for &i in &nd.inputs {
                fanout[i] += 1;
                consumers[i].push(nd.id);
            }
        }

        // Epilogue absorption: from `start`, keep absorbing the unique
        // consumer while it is a pointwise per-channel op that extends
        // the (bn? act?) epilogue. Stops at the graph output — an
        // absorbed output would never be materialized.
        let absorb = |absorbed: &mut Vec<bool>,
                      chain: &mut Vec<NodeId>,
                      start: NodeId|
         -> (IntEpilogue, NodeId) {
            let mut epi = IntEpilogue::default();
            let mut cur = start;
            loop {
                if fanout[cur] != 1 || cur == g.output {
                    break;
                }
                let c = consumers[cur][0];
                match &g.nodes[c].op {
                    IntOp::IntBn { bn } if epi.is_empty() => {
                        epi.bn = Some(bn.clone());
                    }
                    IntOp::RequantAct { rq } if epi.act.is_none() => {
                        epi.act = Some(IntAct::Requant(*rq));
                    }
                    IntOp::ThreshAct { th } if epi.act.is_none() => {
                        epi.act = Some(IntAct::Thresh(th.clone()));
                    }
                    _ => break,
                }
                absorbed[c] = true;
                chain.push(c);
                cur = c;
            }
            (epi, cur)
        };

        let mut absorbed = vec![false; n];
        let mut node_step: Vec<Option<StepId>> = vec![None; n];
        let mut steps: Vec<IntStep> = Vec::new();
        let mut sample_shapes: Vec<Vec<usize>> = Vec::new();
        let mut fused_away = 0usize;
        for nd in &g.nodes {
            if absorbed[nd.id] {
                continue;
            }
            let mut chain: Vec<NodeId> = Vec::new();
            let op = match &nd.op {
                IntOp::Input { .. } => IntStepOp::Input,
                IntOp::ConvInt { wq, bias_q, kh, kw, stride, pad, .. } => {
                    let (epi, _) = absorb(&mut absorbed, &mut chain, nd.id);
                    IntStepOp::Conv {
                        wq: wq.clone(),
                        bias_q: bias_q.clone(),
                        kh: *kh,
                        kw: *kw,
                        stride: *stride,
                        pad: *pad,
                        epi,
                    }
                }
                IntOp::LinearInt { wq, bias_q } => {
                    let (epi, _) = absorb(&mut absorbed, &mut chain, nd.id);
                    IntStepOp::Linear {
                        wq: wq.clone(),
                        bias_q: bias_q.clone(),
                        epi,
                    }
                }
                IntOp::AddRequant { rqs } => {
                    let (epi, _) = absorb(&mut absorbed, &mut chain, nd.id);
                    IntStepOp::Add { rqs: rqs.clone(), epi }
                }
                IntOp::IntBn { bn } => IntStepOp::Bn { bn: bn.clone() },
                IntOp::RequantAct { rq } => IntStepOp::Requant { rq: *rq },
                IntOp::ThreshAct { th } => IntStepOp::Thresh { th: th.clone() },
                IntOp::AvgPoolInt { k, d } => IntStepOp::AvgPool { k: *k, d: *d },
                IntOp::MaxPoolInt { k } => IntStepOp::MaxPool { k: *k },
                IntOp::Flatten => IntStepOp::Flatten,
            };
            let anchor = chain.last().copied().unwrap_or(nd.id);
            let sid = steps.len();
            node_step[nd.id] = Some(sid);
            for &cid in &chain {
                node_step[cid] = Some(sid);
            }
            fused_away += chain.len();
            let inputs: Vec<StepId> = nd
                .inputs
                .iter()
                .map(|&i| node_step[i].expect("graph is topological"))
                .collect();
            sample_shapes.push(shapes1[anchor][1..].to_vec());
            steps.push(IntStep {
                op,
                inputs,
                node: anchor,
                name: g.nodes[anchor].name.clone(),
            });
        }
        let output = node_step[g.output]
            .ok_or_else(|| PlanError::Invalid("output node unmapped".into()))?;
        Ok(IntPlan {
            steps,
            output,
            sample_shapes,
            input_shape,
            fused_away,
        })
    }

    pub fn input_shape(&self) -> &[usize] {
        &self.input_shape
    }

    pub fn steps(&self) -> &[IntStep] {
        &self.steps
    }

    /// Graph nodes eliminated by epilogue fusion.
    pub fn fused_nodes(&self) -> usize {
        self.fused_away
    }

    /// Derive the per-batch-size buffer layout.
    pub fn layout(&self, batch: usize) -> Result<PlanLayout, PlanError> {
        if batch == 0 {
            return Err(PlanError::Invalid("batch size must be >= 1".into()));
        }
        let shapes: Vec<Vec<usize>> = self
            .sample_shapes
            .iter()
            .map(|ss| {
                let mut s = Vec::with_capacity(ss.len() + 1);
                s.push(batch);
                s.extend_from_slice(ss);
                s
            })
            .collect();
        let specs: Vec<StepSpec> = self
            .steps
            .iter()
            .enumerate()
            .map(|(i, st)| {
                let out_len: usize = shapes[i].iter().product();
                let scratch = match &st.op {
                    IntStepOp::Conv { wq, .. } => {
                        let rows = out_len / wq.shape()[1];
                        // im2col patches + GEMM row output
                        vec![rows * wq.shape()[0], out_len]
                    }
                    _ => Vec::new(),
                };
                StepSpec {
                    inputs: st.inputs.clone(),
                    out_len,
                    scratch,
                    is_input: matches!(st.op, IntStepOp::Input),
                }
            })
            .collect();
        let (out_slot, scratch, slot_lens) = assign_slots(&specs, self.output);
        Ok(PlanLayout { batch, shapes, out_slot, scratch, slot_lens })
    }

    /// Execute the plan on a batch. `layout.batch` must match `qx`.
    pub fn execute(
        &self,
        layout: &PlanLayout,
        arena: &mut IntArena,
        qx: &TensorI,
    ) -> TensorI {
        self.execute_inner(layout, arena, qx, None)
    }

    /// Execute and clone out every step's output, tagged with the graph
    /// node it is bit-identical to (diagnostics / the fusion property
    /// tests — pairs with the interpreter's `run_traced`).
    pub fn execute_traced(
        &self,
        layout: &PlanLayout,
        arena: &mut IntArena,
        qx: &TensorI,
    ) -> Vec<(NodeId, TensorI)> {
        let mut trace = Vec::with_capacity(self.steps.len());
        self.execute_inner(layout, arena, qx, Some(&mut trace));
        trace
    }

    fn execute_inner(
        &self,
        layout: &PlanLayout,
        arena: &mut IntArena,
        qx: &TensorI,
        mut trace: Option<&mut Vec<(NodeId, TensorI)>>,
    ) -> TensorI {
        assert_eq!(layout.batch, qx.shape()[0], "layout batch != input batch");
        assert_eq!(
            &qx.shape()[1..],
            &self.input_shape[..],
            "input sample shape mismatch"
        );
        arena.prepare(layout);
        for (sid, st) in self.steps.iter().enumerate() {
            let out_shape = &layout.shapes[sid];
            let out_len: usize = out_shape.iter().product();
            match &st.op {
                IntStepOp::Input => {}
                IntStepOp::Conv { wq, bias_q, kh, kw, stride, pad, epi } => {
                    let (b, c, h, w) = {
                        let s = &layout.shapes[st.inputs[0]];
                        (s[0], s[1], s[2], s[3])
                    };
                    let co = wq.shape()[1];
                    let kdim = wq.shape()[0];
                    let m = out_len / co;
                    let cols_slot = layout.scratch[sid][0];
                    let rows_slot = layout.scratch[sid][1];
                    let out_slot = layout.out_slot[sid];
                    let mut cols = std::mem::take(&mut arena.bufs[cols_slot]);
                    {
                        let xin = slot_data(arena, layout, st.inputs[0], qx);
                        ops::im2col_into(
                            xin, b, c, h, w, *kh, *kw, *stride, *pad, &mut cols,
                        );
                    }
                    let mut rows = std::mem::take(&mut arena.bufs[rows_slot]);
                    let epi_fn = int_epi_fn(bias_q.as_deref(), epi);
                    ops::matmul_i32_fused_into(
                        &cols[..m * kdim],
                        wq.data(),
                        m,
                        kdim,
                        co,
                        &epi_fn,
                        &mut rows,
                    );
                    let mut out = std::mem::take(&mut arena.bufs[out_slot]);
                    ops::rows_to_nchw_into(
                        &rows[..m * co],
                        b,
                        co,
                        out_shape[2],
                        out_shape[3],
                        &mut out,
                    );
                    arena.bufs[cols_slot] = cols;
                    arena.bufs[rows_slot] = rows;
                    arena.bufs[out_slot] = out;
                }
                IntStepOp::Linear { wq, bias_q, epi } => {
                    let in_shape = &layout.shapes[st.inputs[0]];
                    let (bsz, fi) = (in_shape[0], in_shape[1]);
                    let fo = wq.shape()[1];
                    let out_slot = layout.out_slot[sid];
                    let mut out = std::mem::take(&mut arena.bufs[out_slot]);
                    {
                        let xin = slot_data(arena, layout, st.inputs[0], qx);
                        let epi_fn = int_epi_fn(bias_q.as_deref(), epi);
                        ops::matmul_i32_fused_into(
                            &xin[..bsz * fi],
                            wq.data(),
                            bsz,
                            fi,
                            fo,
                            &epi_fn,
                            &mut out,
                        );
                    }
                    arena.bufs[out_slot] = out;
                }
                IntStepOp::Bn { bn } => {
                    self.unary(layout, arena, qx, sid, |in_shape, xin, out| {
                        let (c, hw) = channel_stride(in_shape);
                        for (i, o) in out.iter_mut().enumerate() {
                            *o = ops::narrow(bn.apply((i / hw) % c, xin[i] as i64));
                        }
                    });
                }
                IntStepOp::Requant { rq } => {
                    self.unary(layout, arena, qx, sid, |_, xin, out| {
                        for (o, &x) in out.iter_mut().zip(xin) {
                            *o = ops::narrow(rq.apply(x as i64));
                        }
                    });
                }
                IntStepOp::Thresh { th } => {
                    self.unary(layout, arena, qx, sid, |in_shape, xin, out| {
                        let (c, hw) = channel_stride(in_shape);
                        for (i, o) in out.iter_mut().enumerate() {
                            *o = ops::narrow(th.apply((i / hw) % c, xin[i] as i64));
                        }
                    });
                }
                IntStepOp::AvgPool { k, d } => {
                    self.unary(layout, arena, qx, sid, |in_shape, xin, out| {
                        let (b, c, h, w) =
                            (in_shape[0], in_shape[1], in_shape[2], in_shape[3]);
                        ops::avgpool_i32_into(xin, b, c, h, w, *k, *d, out);
                    });
                }
                IntStepOp::MaxPool { k } => {
                    self.unary(layout, arena, qx, sid, |in_shape, xin, out| {
                        let (b, c, h, w) =
                            (in_shape[0], in_shape[1], in_shape[2], in_shape[3]);
                        ops::maxpool_into(xin, b, c, h, w, *k, out);
                    });
                }
                IntStepOp::Flatten => {
                    self.unary(layout, arena, qx, sid, |_, xin, out| {
                        out.copy_from_slice(&xin[..out.len()]);
                    });
                }
                IntStepOp::Add { rqs, epi } => {
                    let out_slot = layout.out_slot[sid];
                    let mut out = std::mem::take(&mut arena.bufs[out_slot]);
                    {
                        let out = &mut out[..out_len];
                        // Branch 0 is the reference space (Eq. 24).
                        let r0 = slot_data(arena, layout, st.inputs[0], qx);
                        out.copy_from_slice(&r0[..out_len]);
                        for (bi, &inp) in st.inputs.iter().skip(1).enumerate() {
                            let bx = slot_data(arena, layout, inp, qx);
                            let rq = &rqs[bi];
                            for (a, &bv) in out.iter_mut().zip(&bx[..out_len]) {
                                *a = ops::narrow(*a as i64 + rq.apply(bv as i64));
                            }
                        }
                        if !epi.is_empty() {
                            let (c, hw) = channel_stride(out_shape);
                            for (i, v) in out.iter_mut().enumerate() {
                                *v = epi.apply((i / hw) % c, *v as i64);
                            }
                        }
                    }
                    arena.bufs[out_slot] = out;
                }
            }
            if let Some(tr) = trace.as_deref_mut() {
                let data = slot_data(arena, layout, sid, qx)[..out_len].to_vec();
                tr.push((st.node, Tensor::from_vec(out_shape, data)));
            }
        }
        let shape = &layout.shapes[self.output];
        let len: usize = shape.iter().product();
        Tensor::from_vec(shape, slot_data(arena, layout, self.output, qx)[..len].to_vec())
    }

    /// Run a single-input step: take the output buffer, hand (input
    /// shape, input data, output prefix) to `f`, put the buffer back.
    fn unary(
        &self,
        layout: &PlanLayout,
        arena: &mut IntArena,
        qx: &TensorI,
        sid: StepId,
        f: impl FnOnce(&[usize], &[i32], &mut [i32]),
    ) {
        let st = &self.steps[sid];
        let out_len: usize = layout.shapes[sid].iter().product();
        let out_slot = layout.out_slot[sid];
        let mut out = std::mem::take(&mut arena.bufs[out_slot]);
        {
            let in_shape = &layout.shapes[st.inputs[0]];
            let xin = slot_data(arena, layout, st.inputs[0], qx);
            f(in_shape, xin, &mut out[..out_len]);
        }
        arena.bufs[out_slot] = out;
    }
}

// ---------------------------------------------------------------------------
// Float plan
// ---------------------------------------------------------------------------

/// Fused float epilogue: per-channel affine (BatchNorm/QuantBn — the
/// kappa/lambda are kept in f64 and cast per element exactly like the
/// interpreter's `apply_channel_affine`) followed by ReLU or the Eq. 10
/// PACT quantization/activation.
#[derive(Clone, Debug, Default)]
pub struct FloatEpilogue {
    affine: Option<(Vec<f64>, Vec<f64>)>,
    act: Option<FloatAct>,
}

#[derive(Clone, Debug)]
enum FloatAct {
    Relu,
    Pact(QuantSpec),
}

impl FloatEpilogue {
    fn is_empty(&self) -> bool {
        self.affine.is_none() && self.act.is_none()
    }

    pub fn depth(&self) -> usize {
        self.affine.is_some() as usize + self.act.is_some() as usize
    }

    #[inline]
    fn apply(&self, c: usize, mut v: f32) -> f32 {
        if let Some((kappa, lambda)) = &self.affine {
            v = kappa[c] as f32 * v + lambda[c] as f32;
        }
        match &self.act {
            Some(FloatAct::Relu) => v.max(0.0),
            Some(FloatAct::Pact(spec)) => spec.fake_quantize(v as f64) as f32,
            None => v,
        }
    }
}

/// Bias + epilogue over a float GEMM output column (channel). `v + bias`
/// is bit-identical to the interpreter's `1.0 * v + bias` affine form.
fn float_epi_fn<'a>(
    bias: Option<&'a [f64]>,
    epi: &'a FloatEpilogue,
) -> impl Fn(usize, f32) -> f32 + 'a {
    move |c, acc| {
        let mut v = acc;
        if let Some(b) = bias {
            v += b[c] as f32;
        }
        epi.apply(c, v)
    }
}

enum FloatStepOp {
    Input,
    Conv {
        /// Weights pre-transposed to the [C_in*KH*KW, C_out] im2col
        /// layout at compile time (the interpreter re-derives this every
        /// call — same values, same GEMM).
        wmat: TensorF,
        bias: Option<Vec<f64>>,
        kh: usize,
        kw: usize,
        stride: usize,
        pad: usize,
        epi: FloatEpilogue,
    },
    Linear {
        w: TensorF,
        bias: Option<Vec<f64>>,
        epi: FloatEpilogue,
    },
    Affine { kappa: Vec<f64>, lambda: Vec<f64> },
    Relu,
    Pact { spec: QuantSpec },
    MaxPool { k: usize },
    AvgPool { k: usize },
    GlobalAvgPool,
    Flatten,
    Add { epi: FloatEpilogue },
}

pub struct FloatStep {
    op: FloatStepOp,
    inputs: Vec<StepId>,
    pub node: NodeId,
    pub name: String,
}

impl FloatStep {
    pub fn fused_depth(&self) -> usize {
        match &self.op {
            FloatStepOp::Conv { epi, .. }
            | FloatStepOp::Linear { epi, .. }
            | FloatStepOp::Add { epi, .. } => epi.depth(),
            _ => 0,
        }
    }
}

/// A compiled float-graph execution plan (FP / FQ / QD representations).
pub struct FloatPlan {
    steps: Vec<FloatStep>,
    output: StepId,
    sample_shapes: Vec<Vec<usize>>,
    input_shape: Vec<usize>,
    fused_away: usize,
}

impl FloatPlan {
    pub fn compile(g: &Graph) -> Result<FloatPlan, PlanError> {
        let input_shape = match g
            .nodes
            .iter()
            .find_map(|nd| match &nd.op {
                Op::Input { shape } => Some(shape.clone()),
                _ => None,
            }) {
            Some(s) => s,
            None => {
                return Err(PlanError::Invalid("float graph has no Input node".into()))
            }
        };
        let shapes1 = shape::infer_float(g, 1)?;
        let n = g.nodes.len();
        let mut fanout = vec![0usize; n];
        let mut consumers: Vec<Vec<NodeId>> = vec![Vec::new(); n];
        for nd in &g.nodes {
            for &i in &nd.inputs {
                fanout[i] += 1;
                consumers[i].push(nd.id);
            }
        }

        let absorb = |absorbed: &mut Vec<bool>,
                      chain: &mut Vec<NodeId>,
                      start: NodeId|
         -> (FloatEpilogue, NodeId) {
            let mut epi = FloatEpilogue::default();
            let mut cur = start;
            loop {
                if fanout[cur] != 1 || cur == g.output {
                    break;
                }
                let c = consumers[cur][0];
                match &g.nodes[c].op {
                    Op::BatchNorm { bn } if epi.is_empty() => {
                        epi.affine = Some(bn.affine());
                    }
                    Op::QuantBn { kappa_hat, lambda_hat } if epi.is_empty() => {
                        epi.affine = Some((kappa_hat.clone(), lambda_hat.clone()));
                    }
                    Op::ReLU if epi.act.is_none() => {
                        epi.act = Some(FloatAct::Relu);
                    }
                    Op::PactAct { beta, bits } if epi.act.is_none() => {
                        epi.act =
                            Some(FloatAct::Pact(QuantSpec::activation(*beta, *bits)));
                    }
                    _ => break,
                }
                absorbed[c] = true;
                chain.push(c);
                cur = c;
            }
            (epi, cur)
        };

        let mut absorbed = vec![false; n];
        let mut node_step: Vec<Option<StepId>> = vec![None; n];
        let mut steps: Vec<FloatStep> = Vec::new();
        let mut sample_shapes: Vec<Vec<usize>> = Vec::new();
        let mut fused_away = 0usize;
        for nd in &g.nodes {
            if absorbed[nd.id] {
                continue;
            }
            let mut chain: Vec<NodeId> = Vec::new();
            let op = match &nd.op {
                Op::Input { .. } => FloatStepOp::Input,
                Op::Conv2d { w, bias, stride, pad } => {
                    let (epi, _) = absorb(&mut absorbed, &mut chain, nd.id);
                    FloatStepOp::Conv {
                        wmat: ops::oihw_to_wmat(w),
                        bias: bias.clone(),
                        kh: w.shape()[2],
                        kw: w.shape()[3],
                        stride: *stride,
                        pad: *pad,
                        epi,
                    }
                }
                Op::Linear { w, bias } => {
                    let (epi, _) = absorb(&mut absorbed, &mut chain, nd.id);
                    FloatStepOp::Linear { w: w.clone(), bias: bias.clone(), epi }
                }
                Op::Add => {
                    let (epi, _) = absorb(&mut absorbed, &mut chain, nd.id);
                    FloatStepOp::Add { epi }
                }
                Op::BatchNorm { bn } => {
                    let (kappa, lambda) = bn.affine();
                    FloatStepOp::Affine { kappa, lambda }
                }
                Op::QuantBn { kappa_hat, lambda_hat } => FloatStepOp::Affine {
                    kappa: kappa_hat.clone(),
                    lambda: lambda_hat.clone(),
                },
                Op::ReLU => FloatStepOp::Relu,
                Op::PactAct { beta, bits } => FloatStepOp::Pact {
                    spec: QuantSpec::activation(*beta, *bits),
                },
                Op::MaxPool { k } => FloatStepOp::MaxPool { k: *k },
                Op::AvgPool { k } => FloatStepOp::AvgPool { k: *k },
                Op::GlobalAvgPool => FloatStepOp::GlobalAvgPool,
                Op::Flatten => FloatStepOp::Flatten,
            };
            let anchor = chain.last().copied().unwrap_or(nd.id);
            let sid = steps.len();
            node_step[nd.id] = Some(sid);
            for &cid in &chain {
                node_step[cid] = Some(sid);
            }
            fused_away += chain.len();
            let inputs: Vec<StepId> = nd
                .inputs
                .iter()
                .map(|&i| node_step[i].expect("graph is topological"))
                .collect();
            sample_shapes.push(shapes1[anchor][1..].to_vec());
            steps.push(FloatStep {
                op,
                inputs,
                node: anchor,
                name: g.nodes[anchor].name.clone(),
            });
        }
        let output = node_step[g.output]
            .ok_or_else(|| PlanError::Invalid("output node unmapped".into()))?;
        Ok(FloatPlan {
            steps,
            output,
            sample_shapes,
            input_shape,
            fused_away,
        })
    }

    pub fn input_shape(&self) -> &[usize] {
        &self.input_shape
    }

    pub fn steps(&self) -> &[FloatStep] {
        &self.steps
    }

    pub fn fused_nodes(&self) -> usize {
        self.fused_away
    }

    pub fn layout(&self, batch: usize) -> Result<PlanLayout, PlanError> {
        if batch == 0 {
            return Err(PlanError::Invalid("batch size must be >= 1".into()));
        }
        let shapes: Vec<Vec<usize>> = self
            .sample_shapes
            .iter()
            .map(|ss| {
                let mut s = Vec::with_capacity(ss.len() + 1);
                s.push(batch);
                s.extend_from_slice(ss);
                s
            })
            .collect();
        let specs: Vec<StepSpec> = self
            .steps
            .iter()
            .enumerate()
            .map(|(i, st)| {
                let out_len: usize = shapes[i].iter().product();
                let scratch = match &st.op {
                    FloatStepOp::Conv { wmat, .. } => {
                        let rows = out_len / wmat.shape()[1];
                        vec![rows * wmat.shape()[0], out_len]
                    }
                    _ => Vec::new(),
                };
                StepSpec {
                    inputs: st.inputs.clone(),
                    out_len,
                    scratch,
                    is_input: matches!(st.op, FloatStepOp::Input),
                }
            })
            .collect();
        let (out_slot, scratch, slot_lens) = assign_slots(&specs, self.output);
        Ok(PlanLayout { batch, shapes, out_slot, scratch, slot_lens })
    }

    pub fn execute(
        &self,
        layout: &PlanLayout,
        arena: &mut FloatArena,
        x: &TensorF,
    ) -> TensorF {
        self.execute_inner(layout, arena, x, None)
    }

    pub fn execute_traced(
        &self,
        layout: &PlanLayout,
        arena: &mut FloatArena,
        x: &TensorF,
    ) -> Vec<(NodeId, TensorF)> {
        let mut trace = Vec::with_capacity(self.steps.len());
        self.execute_inner(layout, arena, x, Some(&mut trace));
        trace
    }

    fn execute_inner(
        &self,
        layout: &PlanLayout,
        arena: &mut FloatArena,
        x: &TensorF,
        mut trace: Option<&mut Vec<(NodeId, TensorF)>>,
    ) -> TensorF {
        assert_eq!(layout.batch, x.shape()[0], "layout batch != input batch");
        assert_eq!(
            &x.shape()[1..],
            &self.input_shape[..],
            "input sample shape mismatch"
        );
        arena.prepare(layout);
        for (sid, st) in self.steps.iter().enumerate() {
            let out_shape = &layout.shapes[sid];
            let out_len: usize = out_shape.iter().product();
            match &st.op {
                FloatStepOp::Input => {}
                FloatStepOp::Conv { wmat, bias, kh, kw, stride, pad, epi } => {
                    let (b, c, h, w) = {
                        let s = &layout.shapes[st.inputs[0]];
                        (s[0], s[1], s[2], s[3])
                    };
                    let co = wmat.shape()[1];
                    let kdim = wmat.shape()[0];
                    let m = out_len / co;
                    let cols_slot = layout.scratch[sid][0];
                    let rows_slot = layout.scratch[sid][1];
                    let out_slot = layout.out_slot[sid];
                    let mut cols = std::mem::take(&mut arena.bufs[cols_slot]);
                    {
                        let xin = slot_data(arena, layout, st.inputs[0], x);
                        ops::im2col_into(
                            xin, b, c, h, w, *kh, *kw, *stride, *pad, &mut cols,
                        );
                    }
                    let mut rows = std::mem::take(&mut arena.bufs[rows_slot]);
                    let epi_fn = float_epi_fn(bias.as_deref(), epi);
                    ops::matmul_f32_fused_into(
                        &cols[..m * kdim],
                        wmat.data(),
                        m,
                        kdim,
                        co,
                        &epi_fn,
                        &mut rows,
                    );
                    let mut out = std::mem::take(&mut arena.bufs[out_slot]);
                    ops::rows_to_nchw_into(
                        &rows[..m * co],
                        b,
                        co,
                        out_shape[2],
                        out_shape[3],
                        &mut out,
                    );
                    arena.bufs[cols_slot] = cols;
                    arena.bufs[rows_slot] = rows;
                    arena.bufs[out_slot] = out;
                }
                FloatStepOp::Linear { w, bias, epi } => {
                    let in_shape = &layout.shapes[st.inputs[0]];
                    let (bsz, fi) = (in_shape[0], in_shape[1]);
                    let fo = w.shape()[1];
                    let out_slot = layout.out_slot[sid];
                    let mut out = std::mem::take(&mut arena.bufs[out_slot]);
                    {
                        let xin = slot_data(arena, layout, st.inputs[0], x);
                        let epi_fn = float_epi_fn(bias.as_deref(), epi);
                        ops::matmul_f32_fused_into(
                            &xin[..bsz * fi],
                            w.data(),
                            bsz,
                            fi,
                            fo,
                            &epi_fn,
                            &mut out,
                        );
                    }
                    arena.bufs[out_slot] = out;
                }
                FloatStepOp::Affine { kappa, lambda } => {
                    self.unary(layout, arena, x, sid, |in_shape, xin, out| {
                        let (c, hw) = channel_stride(in_shape);
                        for (i, o) in out.iter_mut().enumerate() {
                            let ch = (i / hw) % c;
                            *o = kappa[ch] as f32 * xin[i] + lambda[ch] as f32;
                        }
                    });
                }
                FloatStepOp::Relu => {
                    self.unary(layout, arena, x, sid, |_, xin, out| {
                        for (o, &v) in out.iter_mut().zip(xin) {
                            *o = v.max(0.0);
                        }
                    });
                }
                FloatStepOp::Pact { spec } => {
                    self.unary(layout, arena, x, sid, |_, xin, out| {
                        for (o, &v) in out.iter_mut().zip(xin) {
                            *o = spec.fake_quantize(v as f64) as f32;
                        }
                    });
                }
                FloatStepOp::MaxPool { k } => {
                    self.unary(layout, arena, x, sid, |in_shape, xin, out| {
                        let (b, c, h, w) =
                            (in_shape[0], in_shape[1], in_shape[2], in_shape[3]);
                        ops::maxpool_into(xin, b, c, h, w, *k, out);
                    });
                }
                FloatStepOp::AvgPool { k } => {
                    self.unary(layout, arena, x, sid, |in_shape, xin, out| {
                        let (b, c, h, w) =
                            (in_shape[0], in_shape[1], in_shape[2], in_shape[3]);
                        ops::avgpool_f32_into(xin, b, c, h, w, *k, out);
                    });
                }
                FloatStepOp::GlobalAvgPool => {
                    self.unary(layout, arena, x, sid, |in_shape, xin, out| {
                        let (b, c, h, w) =
                            (in_shape[0], in_shape[1], in_shape[2], in_shape[3]);
                        ops::global_mean_f32_into(xin, b, c, h, w, out);
                    });
                }
                FloatStepOp::Flatten => {
                    self.unary(layout, arena, x, sid, |_, xin, out| {
                        out.copy_from_slice(&xin[..out.len()]);
                    });
                }
                FloatStepOp::Add { epi } => {
                    let out_slot = layout.out_slot[sid];
                    let mut out = std::mem::take(&mut arena.bufs[out_slot]);
                    {
                        let out = &mut out[..out_len];
                        let r0 = slot_data(arena, layout, st.inputs[0], x);
                        out.copy_from_slice(&r0[..out_len]);
                        for &inp in st.inputs.iter().skip(1) {
                            let bx = slot_data(arena, layout, inp, x);
                            for (a, &bv) in out.iter_mut().zip(&bx[..out_len]) {
                                *a += bv;
                            }
                        }
                        if !epi.is_empty() {
                            let (c, hw) = channel_stride(out_shape);
                            for (i, v) in out.iter_mut().enumerate() {
                                *v = epi.apply((i / hw) % c, *v);
                            }
                        }
                    }
                    arena.bufs[out_slot] = out;
                }
            }
            if let Some(tr) = trace.as_deref_mut() {
                let data = slot_data(arena, layout, sid, x)[..out_len].to_vec();
                tr.push((st.node, Tensor::from_vec(out_shape, data)));
            }
        }
        let shape = &layout.shapes[self.output];
        let len: usize = shape.iter().product();
        Tensor::from_vec(shape, slot_data(arena, layout, self.output, x)[..len].to_vec())
    }

    fn unary(
        &self,
        layout: &PlanLayout,
        arena: &mut FloatArena,
        x: &TensorF,
        sid: StepId,
        f: impl FnOnce(&[usize], &[f32], &mut [f32]),
    ) {
        let st = &self.steps[sid];
        let out_len: usize = layout.shapes[sid].iter().product();
        let out_slot = layout.out_slot[sid];
        let mut out = std::mem::take(&mut arena.bufs[out_slot]);
        {
            let in_shape = &layout.shapes[st.inputs[0]];
            let xin = slot_data(arena, layout, st.inputs[0], x);
            f(in_shape, xin, &mut out[..out_len]);
        }
        arena.bufs[out_slot] = out;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::bn::BnParams;

    fn conv_bn_act_graph() -> IntGraph {
        let mut g = IntGraph::default();
        let spec = QuantSpec { eps: 1.0 / 255.0, lo: 0, hi: 255 };
        let x = g.push("in", IntOp::Input { shape: vec![1, 4, 4], spec }, &[]);
        let wq = Tensor::from_vec(&[9, 2], (0..18).map(|i| (i % 5) as i32 - 2).collect());
        let c = g.push(
            "conv",
            IntOp::ConvInt {
                wq,
                bias_q: Some(vec![3, -3]),
                cin: 1,
                kh: 3,
                kw: 3,
                stride: 1,
                pad: 1,
            },
            &[x],
        );
        let bn = BnQuant {
            kappa_q: vec![2, 3],
            lambda_q: vec![5, -5],
            eps_kappa: 0.01,
            eps_phi_out: 0.001,
        };
        let b = g.push("bn", IntOp::IntBn { bn }, &[c]);
        let rq = Requant { m: 3, d: 2, lo: 0, hi: 255 };
        g.push("act", IntOp::RequantAct { rq }, &[b]);
        g
    }

    #[test]
    fn conv_chain_fuses_into_one_step() {
        let g = conv_bn_act_graph();
        let plan = IntPlan::compile(&g).unwrap();
        // Input + fused conv = 2 steps; bn + act absorbed.
        assert_eq!(plan.steps().len(), 2);
        assert_eq!(plan.fused_nodes(), 2);
        assert_eq!(plan.steps()[1].fused_depth(), 2);
        assert_eq!(plan.steps()[1].node, g.output);
    }

    #[test]
    fn fused_execution_matches_interpreter() {
        let g = conv_bn_act_graph();
        let plan = IntPlan::compile(&g).unwrap();
        let layout = plan.layout(2).unwrap();
        let mut arena = IntArena::new();
        let qx = Tensor::from_vec(&[2, 1, 4, 4], (0..32).map(|i| i * 7 % 256).collect());
        let got = plan.execute(&layout, &mut arena, &qx);
        let want = crate::engine::IntegerEngine::new().run_interpreted(&g, &qx);
        assert_eq!(got, want);
        // and again with the now-dirty arena (buffer reuse must not leak)
        let got2 = plan.execute(&layout, &mut arena, &qx);
        assert_eq!(got2, want);
    }

    #[test]
    fn traced_execution_anchors_match_interpreter_nodes() {
        let g = conv_bn_act_graph();
        let plan = IntPlan::compile(&g).unwrap();
        let layout = plan.layout(1).unwrap();
        let mut arena = IntArena::new();
        let qx = Tensor::from_vec(&[1, 1, 4, 4], (0..16).map(|i| i * 11 % 256).collect());
        let interp = crate::engine::IntegerEngine::new().run_traced(&g, &qx);
        for (node, t) in plan.execute_traced(&layout, &mut arena, &qx) {
            assert_eq!(t, interp[node], "step anchored at node {node}");
        }
    }

    #[test]
    fn output_slot_is_never_recycled() {
        // Chain long enough for slot reuse to kick in.
        let g = conv_bn_act_graph();
        let plan = IntPlan::compile(&g).unwrap();
        let layout = plan.layout(1).unwrap();
        // Arena is bounded: at most cols + rows + two live activations.
        assert!(layout.arena_slots() <= 4, "slots = {}", layout.arena_slots());
    }

    #[test]
    fn float_plan_matches_interpreter() {
        let mut g = Graph::new(1.0 / 255.0);
        let x = g.push("in", Op::Input { shape: vec![1, 4, 4] }, &[]);
        let w = Tensor::from_vec(
            &[2, 1, 3, 3],
            (0..18).map(|i| (i as f32 - 9.0) * 0.1).collect(),
        );
        let c = g.push("c", Op::Conv2d { w, bias: Some(vec![0.1, -0.1]), stride: 1, pad: 1 }, &[x]);
        let b = g.push("bn", Op::BatchNorm { bn: BnParams::identity(2) }, &[c]);
        g.push("a", Op::ReLU, &[b]);
        let plan = FloatPlan::compile(&g).unwrap();
        assert_eq!(plan.steps().len(), 2);
        let layout = plan.layout(3).unwrap();
        let mut arena = FloatArena::new();
        let xin = Tensor::from_vec(
            &[3, 1, 4, 4],
            (0..48).map(|i| (i as f32) * 0.02 - 0.4).collect(),
        );
        let got = plan.execute(&layout, &mut arena, &xin);
        let want = crate::engine::FloatEngine::new().run_interpreted(&g, &xin);
        assert_eq!(got.data(), want.data());
    }

    #[test]
    fn compile_rejects_missing_input() {
        let mut g = IntGraph::default();
        let wq = Tensor::from_vec(&[1, 1], vec![1]);
        g.push("fc", IntOp::LinearInt { wq, bias_q: None }, &[]);
        assert!(IntPlan::compile(&g).is_err());
    }
}
