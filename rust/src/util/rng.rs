//! Deterministic PRNG (xoshiro256**) with the distribution helpers the
//! library needs: uniform ints/floats, normal (Box-Muller), shuffles.
//!
//! Determinism matters more than statistical strength here: dataset
//! generation, weight init and property tests must reproduce exactly
//! across runs and machines.

/// xoshiro256** by Blackman & Vigna (public domain reference algorithm).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second Box-Muller sample
    spare_normal: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare_normal: None }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [lo, hi) (hi > lo).
    pub fn int(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(hi > lo);
        let span = (hi - lo) as u64;
        // Lemire-style rejection-free-enough modulo; bias is negligible for
        // span << 2^64 and irrelevant for our test/data generation use.
        lo + (self.next_u64() % span) as i64
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn normal(&mut self, mean: f64, std: f64) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return mean + std * z;
        }
        // u1 in (0,1] to avoid ln(0)
        let u1 = 1.0 - self.f64();
        let u2 = self.f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.spare_normal = Some(r * theta.sin());
        mean + std * r * theta.cos()
    }

    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.int(0, (i + 1) as i64) as usize;
            v.swap(i, j);
        }
    }

    /// A fresh child generator (for parallel workers / sub-tasks).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn int_bounds() {
        let mut r = Rng::new(2);
        for _ in 0..10_000 {
            let x = r.int(-5, 17);
            assert!((-5..17).contains(&x));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal(2.0, 3.0)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!((mean - 2.0).abs() < 0.05, "mean {mean}");
        assert!((var - 9.0).abs() < 0.2, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(4);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
