//! Serving coordinator (S7): request router + dynamic batcher + worker
//! pool over a runtime [`ModelRegistry`] of [`Executor`] backends.
//!
//! Deployment shape (vLLM-router-like, scaled to this paper): callers
//! submit single-sample integer images against a model *name*; the
//! batcher coalesces per name up to that model's `max_batch` or batch
//! timeout, gathers one batch tensor, executes it on a worker thread
//! through `Executor::run_batch`, and scatters the per-sample results.
//!
//! The model set is *not* frozen at construction: a [`ServerBuilder`]
//! seeds the registry (`.model(..)`, `.model_from_artifact(..)`), and the
//! [`ServerHandle`] is the single public serving surface afterwards —
//! request ops (`infer`, `infer_deadline`, `try_infer`) plus admin ops
//! (`load_model*`, `swap_model*`, `unload_model`, `list_models`,
//! `model_metrics`) that take effect at runtime without a restart.
//! Swap/unload atomicity with respect to in-flight batches is the
//! registry's contract (see [`registry`]): a gathered batch never mixes
//! executor versions and no reply is dropped by a lifecycle operation.
//!
//! Backends stay interchangeable: the native integer engine (`serve
//! --backend native`, no artifacts needed), executors rehydrated from
//! `model.nemo.json` deployment artifacts (`serve --model a.nemo.json
//! --model b.nemo.json`), and the AOT-compiled PJRT executables serve
//! through the identical path — batch-variant selection and padding are
//! the executor's business, not the coordinator's.

pub mod metrics;
pub mod registry;

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{self, Receiver, Sender, SyncSender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context as _, Result};

use crate::exec::{Arg, ExecInput, Executor};
use crate::tensor::{Tensor, TensorF, TensorI};

pub use metrics::Metrics;
pub use registry::{ModelEntry, ModelInfo, ModelRegistry, Provenance, RegistryError};

// A servable model is just a name bound to an [`Executor`] backend:
// `ServerBuilder::model(name, exec)` / `ServerHandle::load_model`. Any
// executor speaking the integer request protocol fits — inputs are
// integer image batches and logits are integer-valued (the native
// integer engine, the PJRT ID executables, or any future ID backend).
// An f32 logits tensor is tolerated only when its values are already
// integers (some XLA lowerings emit integer math as f32) — the worker
// truncates it; genuinely fractional-logit float backends do not fit
// this protocol.

struct Request {
    model: String,
    qx: TensorI, // [1, ...]
    reply: SyncSender<Result<TensorI>>,
    enqueued: Instant,
}

/// Coordinator configuration. Used twice: as the server-wide defaults
/// (`ServerBuilder::default_config`; `n_workers` sizes the shared worker
/// pool) and as per-model overrides (`config_for`), where `max_batch` and
/// `batch_timeout` shape that model's batching — `n_workers` has no
/// per-model meaning because the pool is shared.
#[derive(Clone, Copy, Debug)]
pub struct ServerConfig {
    pub max_batch: usize,
    pub batch_timeout: Duration,
    pub n_workers: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            max_batch: 16,
            batch_timeout: Duration::from_micros(500),
            n_workers: 2,
        }
    }
}

/// Typed inference-path failures beyond [`RegistryError`]. Carried
/// inside `anyhow::Error`; recover with `err.downcast_ref::<InferError>()`.
#[derive(Debug, thiserror::Error)]
pub enum InferError {
    #[error(
        "inference deadline of {0:?} exceeded before a reply arrived \
         (the request may still complete server-side)"
    )]
    DeadlineExceeded(Duration),
    #[error("server stopped before replying")]
    ServerStopped,
}

/// A submitted request whose reply has not been claimed yet — the
/// non-blocking half of [`ServerHandle::try_infer`].
pub struct PendingInference {
    rx: mpsc::Receiver<Result<TensorI>>,
}

impl PendingInference {
    /// Non-blocking poll: `None` while the reply is still in flight.
    pub fn try_poll(&self) -> Option<Result<TensorI>> {
        match self.rx.try_recv() {
            Ok(r) => Some(r),
            Err(mpsc::TryRecvError::Empty) => None,
            Err(mpsc::TryRecvError::Disconnected) => {
                Some(Err(InferError::ServerStopped.into()))
            }
        }
    }

    /// Block until the reply arrives.
    pub fn wait(self) -> Result<TensorI> {
        match self.rx.recv() {
            Ok(r) => r,
            Err(_) => Err(InferError::ServerStopped.into()),
        }
    }

    /// Block at most `timeout`; a late reply is abandoned (the server
    /// still executes and accounts the request).
    pub fn wait_deadline(self, timeout: Duration) -> Result<TensorI> {
        match self.rx.recv_timeout(timeout) {
            Ok(r) => r,
            Err(mpsc::RecvTimeoutError::Timeout) => {
                Err(InferError::DeadlineExceeded(timeout).into())
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                Err(InferError::ServerStopped.into())
            }
        }
    }
}

/// Clonable client + admin handle: the single public serving surface.
#[derive(Clone)]
pub struct ServerHandle {
    tx: Sender<Request>,
    registry: Arc<ModelRegistry>,
    default_cfg: ServerConfig,
}

impl ServerHandle {
    /// Blocking single-sample inference; returns the [1, C_out] integer
    /// logits image.
    pub fn infer(&self, model: &str, qx: TensorI) -> Result<TensorI> {
        self.try_infer(model, qx)?.wait()
    }

    /// Blocking inference with a reply deadline. On timeout the caller
    /// gets a typed [`InferError::DeadlineExceeded`]; the request itself
    /// still runs to completion server-side.
    pub fn infer_deadline(
        &self,
        model: &str,
        qx: TensorI,
        timeout: Duration,
    ) -> Result<TensorI> {
        self.try_infer(model, qx)?.wait_deadline(timeout)
    }

    /// Non-blocking submit: queues the request and returns immediately
    /// with a [`PendingInference`] to poll or wait on. Unknown model
    /// names fail here, before anything is queued.
    pub fn try_infer(&self, model: &str, qx: TensorI) -> Result<PendingInference> {
        if !self.registry.contains(model) {
            return Err(RegistryError::UnknownModel(model.to_string()).into());
        }
        let (rtx, rrx) = mpsc::sync_channel(1);
        self.tx
            .send(Request {
                model: model.to_string(),
                qx,
                reply: rtx,
                enqueued: Instant::now(),
            })
            .map_err(|_| anyhow::Error::from(InferError::ServerStopped))?;
        Ok(PendingInference { rx: rrx })
    }

    // -- admin ops ---------------------------------------------------

    /// Register a new model under `name` at runtime, serving with the
    /// server's default config. Duplicate names are a typed error.
    pub fn load_model(&self, name: &str, exec: Arc<dyn Executor>) -> Result<()> {
        self.registry
            .register(ModelEntry::new(name, exec, self.default_cfg, Provenance::InMemory))
            .map_err(anyhow::Error::from)
    }

    /// Register a new model from a `model.nemo.json` deployment artifact
    /// (cold load: checksum + precision re-proof + plan compile).
    pub fn load_model_from_artifact(
        &self,
        name: &str,
        path: impl AsRef<std::path::Path>,
    ) -> Result<()> {
        let (exec, prov) = artifact_exec(path.as_ref(), self.default_cfg.max_batch)?;
        self.registry
            .register(ModelEntry::new(name, exec, self.default_cfg, prov))
            .map_err(anyhow::Error::from)
    }

    /// Hot-swap the executor serving `name`; returns the new version.
    /// Batches already dispatched to the old executor complete on it;
    /// requests submitted after this returns run on `exec`.
    pub fn swap_model(&self, name: &str, exec: Arc<dyn Executor>) -> Result<u64> {
        self.registry
            .swap(name, exec, Provenance::InMemory)
            .map_err(anyhow::Error::from)
    }

    /// Hot-swap `name` to a freshly loaded deployment artifact — the
    /// zero-downtime re-deploy path: the old version keeps serving until
    /// the new executor is fully built and validated.
    pub fn swap_model_from_artifact(
        &self,
        name: &str,
        path: impl AsRef<std::path::Path>,
    ) -> Result<u64> {
        let entry_cfg = self
            .registry
            .config_of(name)
            .ok_or_else(|| RegistryError::UnknownModel(name.to_string()))?;
        let (exec, prov) = artifact_exec(path.as_ref(), entry_cfg.max_batch)?;
        self.registry.swap(name, exec, prov).map_err(anyhow::Error::from)
    }

    /// Remove `name` from routing. In-flight batches still complete and
    /// reply; subsequent `infer(name, ..)` is a typed unknown-model error.
    pub fn unload_model(&self, name: &str) -> Result<()> {
        self.registry.unload(name).map(|_| ()).map_err(anyhow::Error::from)
    }

    /// Snapshot of every registered model, sorted by name.
    pub fn list_models(&self) -> Vec<ModelInfo> {
        self.registry.list()
    }

    /// Snapshot of one model's metrics ledger (spans swap versions).
    pub fn model_metrics(&self, name: &str) -> Result<Metrics> {
        self.registry.metrics_of(name).map_err(anyhow::Error::from)
    }
}

/// Build an executor (plus provenance) from a deployment artifact.
fn artifact_exec(
    path: &std::path::Path,
    max_batch: usize,
) -> Result<(Arc<dyn Executor>, Provenance)> {
    let (exec, prov) =
        crate::exec::NativeIntExecutor::from_artifact_with_provenance(path, max_batch)
            .with_context(|| {
                format!("building executor from artifact {}", path.display())
            })?;
    Ok((Arc::new(exec), Provenance::Artifact(prov)))
}

enum ModelSource {
    Exec(Arc<dyn Executor>),
    Artifact(PathBuf),
}

/// Builder for a [`Server`]: seed models (by executor or by artifact
/// path), set the default config and per-model overrides, then `start()`.
/// Duplicate names are a typed [`RegistryError::DuplicateName`].
#[derive(Default)]
pub struct ServerBuilder {
    default_cfg: Option<ServerConfig>,
    models: Vec<(String, ModelSource)>,
    configs: HashMap<String, ServerConfig>,
}

impl ServerBuilder {
    pub fn new() -> Self {
        Self::default()
    }

    /// Server-wide defaults: worker-pool size, and the batching config
    /// for models without a `config_for` override.
    pub fn default_config(mut self, cfg: ServerConfig) -> Self {
        self.default_cfg = Some(cfg);
        self
    }

    /// Serve `exec` under `name`.
    pub fn model(mut self, name: &str, exec: Arc<dyn Executor>) -> Self {
        self.models.push((name.to_string(), ModelSource::Exec(exec)));
        self
    }

    /// Serve the deployment artifact at `path` under `name`; the
    /// executor is built at `start()` with the model's resolved config.
    pub fn model_from_artifact(
        mut self,
        name: &str,
        path: impl AsRef<std::path::Path>,
    ) -> Self {
        self.models
            .push((name.to_string(), ModelSource::Artifact(path.as_ref().to_path_buf())));
        self
    }

    /// Per-model batching override (`max_batch`, `batch_timeout`).
    pub fn config_for(mut self, name: &str, cfg: ServerConfig) -> Self {
        self.configs.insert(name.to_string(), cfg);
        self
    }

    /// Build the registry and start the batcher + worker threads.
    pub fn start(self) -> Result<Server> {
        let default_cfg = self.default_cfg.unwrap_or_default();
        let registry = Arc::new(ModelRegistry::new());
        for (name, source) in self.models {
            let cfg = self.configs.get(&name).copied().unwrap_or(default_cfg);
            let (exec, prov) = match source {
                ModelSource::Exec(exec) => (exec, Provenance::InMemory),
                ModelSource::Artifact(path) => artifact_exec(&path, cfg.max_batch)?,
            };
            registry.register(ModelEntry::new(&name, exec, cfg, prov))?;
        }
        Ok(Server::spawn(registry, default_cfg))
    }
}

/// The running server; stop it (or drop it after all handles) to join
/// the threads. Constructed via [`Server::builder`].
pub struct Server {
    handle: ServerHandle,
    registry: Arc<ModelRegistry>,
    stop: Arc<AtomicBool>,
    threads: Vec<std::thread::JoinHandle<()>>,
}

struct Job {
    model: String,
    exec: Arc<dyn Executor>,
    metrics: Arc<Mutex<Metrics>>,
    input: ExecInput,
    waiters: Vec<(SyncSender<Result<TensorI>>, Instant)>,
    n_real: usize,
    /// Batch size the executor will actually run (>= n_real when the
    /// backend pads to a compiled variant).
    batch: usize,
}

impl Server {
    pub fn builder() -> ServerBuilder {
        ServerBuilder::new()
    }

    fn spawn(registry: Arc<ModelRegistry>, cfg: ServerConfig) -> Server {
        let (tx, rx) = mpsc::channel::<Request>();
        let (jtx, jrx) = mpsc::channel::<Job>();
        let jrx = Arc::new(Mutex::new(jrx));
        let stop = Arc::new(AtomicBool::new(false));

        let mut threads = Vec::new();
        // Batcher thread
        {
            let registry = registry.clone();
            let stop = stop.clone();
            threads.push(std::thread::spawn(move || {
                batcher_loop(rx, jtx, registry, stop, cfg);
            }));
        }
        // Worker pool (shared across models)
        for wid in 0..cfg.n_workers {
            let jrx = jrx.clone();
            threads.push(std::thread::spawn(move || {
                worker_loop(wid, jrx);
            }));
        }
        let handle = ServerHandle { tx, registry: registry.clone(), default_cfg: cfg };
        Server { handle, registry, stop, threads }
    }

    pub fn handle(&self) -> ServerHandle {
        self.handle.clone()
    }

    /// The registry backing this server (shared with every handle).
    pub fn registry(&self) -> &Arc<ModelRegistry> {
        &self.registry
    }

    /// Stop the threads and return the metrics aggregated across every
    /// model still registered (per-model ledgers: `model_metrics`).
    pub fn stop(self) -> Metrics {
        self.stop.store(true, Ordering::SeqCst);
        let Server { handle, registry, threads, .. } = self;
        drop(handle); // close the request channel so the batcher exits
        for t in threads {
            let _ = t.join();
        }
        registry.aggregate_metrics()
    }
}

fn batcher_loop(
    rx: Receiver<Request>,
    jtx: Sender<Job>,
    registry: Arc<ModelRegistry>,
    stop: Arc<AtomicBool>,
    cfg: ServerConfig,
) {
    loop {
        // Block for the first request (or exit when all senders dropped).
        let first = match rx.recv_timeout(Duration::from_millis(50)) {
            Ok(r) => r,
            Err(mpsc::RecvTimeoutError::Timeout) => {
                if stop.load(Ordering::SeqCst) {
                    return;
                }
                continue;
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => return,
        };
        // The coalescing window is set by the first request's model (its
        // per-model batch_timeout override, else the server default);
        // each model's bucket caps at that model's max_batch.
        let cap_of = |model: &str| -> usize {
            registry
                .config_of(model)
                .map(|c| c.max_batch)
                .unwrap_or(cfg.max_batch)
                .max(1)
        };
        let window = registry
            .config_of(&first.model)
            .map(|c| c.batch_timeout)
            .unwrap_or(cfg.batch_timeout);
        let deadline = Instant::now() + window;
        let mut bucket: HashMap<String, Vec<Request>> = HashMap::new();
        let mut caps: HashMap<String, usize> = HashMap::new();
        caps.insert(first.model.clone(), cap_of(&first.model));
        bucket.entry(first.model.clone()).or_default().push(first);
        // Coalesce until the timeout or the cap for some model.
        loop {
            let full = bucket
                .iter()
                .any(|(m, v)| v.len() >= caps.get(m).copied().unwrap_or(1));
            let now = Instant::now();
            if full || now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(r) => {
                    caps.entry(r.model.clone())
                        .or_insert_with(|| cap_of(&r.model));
                    bucket.entry(r.model.clone()).or_default().push(r);
                }
                Err(mpsc::RecvTimeoutError::Timeout) => break,
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
        }
        for (model, reqs) in bucket {
            // Resolve the name once per gathered bucket: every chunk of
            // this bucket runs on the same executor version, and a swap
            // or unload landing mid-coalesce takes effect at exactly
            // this boundary.
            let Some(entry) = registry.get(&model) else {
                // Unloaded between submit and dispatch.
                for r in reqs {
                    let _ = r.reply.send(Err(RegistryError::UnknownModel(
                        model.clone(),
                    )
                    .into()));
                }
                continue;
            };
            // Split into chunks of at most what the backend can run
            // (floored at 1: chunks(0) panics and a misconfigured
            // max_batch must not take down the batcher thread).
            let chunk_cap = entry.exec.max_batch().min(entry.cfg.max_batch).max(1);
            for chunk in reqs.chunks(chunk_cap) {
                dispatch(&entry, chunk, &jtx);
            }
        }
    }
}

fn dispatch(entry: &ModelEntry, reqs: &[Request], jtx: &Sender<Job>) {
    // Shape guard: a wrong-shaped request must fail loudly (in release
    // builds too) instead of silently corrupting the gathered batch.
    let expected = entry.exec.input_shape();
    let mut valid: Vec<&Request> = Vec::with_capacity(reqs.len());
    let mut rejected = 0u64;
    for r in reqs {
        let shape = r.qx.shape();
        let ok = shape.first() == Some(&1)
            && shape.len() == expected.len() + 1
            && shape[1..] == *expected;
        if ok {
            valid.push(r);
        } else {
            rejected += 1;
            let _ = r.reply.send(Err(anyhow!(
                "model '{}': input shape {:?} does not match per-sample shape \
                 {:?} (expected a [1, ...] single-sample image)",
                entry.name,
                shape,
                expected
            )));
        }
    }
    if rejected > 0 {
        entry.metrics.lock().unwrap().failed += rejected;
    }
    if valid.is_empty() {
        return;
    }
    let n = valid.len();
    // Gather: [n, ...]; the executor pads to a compiled variant if needed.
    let sample_len: usize = expected.iter().product();
    let mut data = Vec::with_capacity(n * sample_len);
    for r in &valid {
        data.extend_from_slice(r.qx.data());
    }
    let mut shape = vec![n];
    shape.extend_from_slice(expected);
    let qx = Tensor::from_vec(&shape, data);

    {
        let mut m = entry.metrics.lock().unwrap();
        m.batch_sizes.push(n as f64);
        let now = Instant::now();
        for r in &valid {
            m.queue_wait
                .push(now.duration_since(r.enqueued).as_secs_f64());
        }
    }
    let job = Job {
        model: entry.name.clone(),
        exec: entry.exec.clone(),
        metrics: entry.metrics.clone(),
        input: ExecInput::i32(qx),
        waiters: valid.iter().map(|r| (r.reply.clone(), r.enqueued)).collect(),
        n_real: n,
        batch: entry.exec.effective_batch(n),
    };
    if let Err(mpsc::SendError(job)) = jtx.send(job) {
        // The worker pool is gone (server shutting down). Dropping the
        // job here used to drop the reply senders silently, so clients
        // saw a misleading "server dropped request" with no failure
        // recorded — answer with the real cause and count the failures.
        fail_job(
            &job,
            "server is shutting down: worker pool stopped before the batch ran",
        );
    }
}

fn worker_loop(_wid: usize, jrx: Arc<Mutex<Receiver<Job>>>) {
    loop {
        let job = {
            let guard = jrx.lock().unwrap();
            match guard.recv() {
                Ok(j) => j,
                Err(_) => return,
            }
        };
        let t0 = Instant::now();
        let result = job.exec.run_batch(&job.input);
        let exec_s = t0.elapsed().as_secs_f64();
        match result {
            Ok(out) => {
                let t = match out.logits {
                    Arg::I32(t) => t,
                    Arg::F32(t) => match integral_logits(&t) {
                        Ok(t) => t,
                        Err(msg) => {
                            let msg = format!(
                                "model '{}': executor '{}' broke the integer logits \
                                 protocol: {msg}",
                                job.model,
                                job.exec.name()
                            );
                            fail_job(&job, &msg);
                            continue;
                        }
                    },
                };
                if t.shape().first().copied().unwrap_or(0) < job.n_real {
                    let msg = format!(
                        "model '{}': executor '{}' returned {} rows for {} samples",
                        job.model,
                        job.exec.name(),
                        t.shape().first().copied().unwrap_or(0),
                        job.n_real
                    );
                    fail_job(&job, &msg);
                    continue;
                }
                // Scatter replies first, then record everything under a
                // single metrics acquisition per job (the e2e latencies
                // are batched instead of locking once per waiter).
                let done = Instant::now();
                let mut e2e = Vec::with_capacity(job.waiters.len());
                for (i, (reply, enq)) in job.waiters.iter().enumerate() {
                    let row = t.slice_batch(i, i + 1);
                    let _ = reply.send(Ok(row));
                    e2e.push(done.duration_since(*enq).as_secs_f64());
                }
                let mut m = job.metrics.lock().unwrap();
                m.exec_time.push(exec_s);
                m.completed += job.n_real as u64;
                m.padded += job.batch.saturating_sub(job.n_real) as u64;
                for l in e2e {
                    m.e2e_latency.push(l);
                }
            }
            Err(e) => {
                let msg = format!("model '{}': execution failed: {e:#}", job.model);
                fail_job(&job, &msg);
            }
        }
    }
}

/// Convert an f32 logits batch to the integer image the request protocol
/// carries. Per the coordinator's backend contract, f32 logits are
/// tolerated only when their values are already integers (some XLA
/// lowerings emit integer math as f32): each value is rounded to the
/// nearest integer, and anything more than 1e-6 from an integer is a
/// protocol violation reported loudly — never truncated silently.
fn integral_logits(t: &TensorF) -> Result<TensorI, String> {
    let mut data = Vec::with_capacity(t.len());
    for &v in t.data() {
        let r = v.round();
        if !v.is_finite() || (v - r).abs() > 1e-6 {
            return Err(format!(
                "f32 logit {v} is not integer-valued (>1e-6 from an integer); \
                 fractional-logit float backends do not fit the integer \
                 request protocol"
            ));
        }
        // Integer-valued but outside i32: `as i32` would saturate — the
        // same silent corruption this function exists to prevent.
        let ri = r as i64;
        if !(i32::MIN as i64..=i32::MAX as i64).contains(&ri) {
            return Err(format!(
                "f32 logit {v} overflows the i32 integer-image range"
            ));
        }
        data.push(ri as i32);
    }
    Ok(Tensor::from_vec(t.shape(), data))
}

fn fail_job(job: &Job, msg: &str) {
    {
        let mut m = job.metrics.lock().unwrap();
        m.failed += job.n_real as u64;
    }
    for (reply, _) in &job.waiters {
        let _ = reply.send(Err(anyhow!(msg.to_string())));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_defaults_are_sane() {
        let cfg = ServerConfig::default();
        assert!(cfg.max_batch >= 1);
        assert!(cfg.n_workers >= 1);
    }

    #[test]
    fn integral_logits_rounds_to_nearest() {
        // v as i32 used to truncate: 2.9999997 -> 2. Round instead.
        let t = TensorF::from_vec(&[1, 4], vec![2.9999997, -1.0000001, 0.0, 41.0]);
        let q = integral_logits(&t).unwrap();
        assert_eq!(q.data(), &[3, -1, 0, 41]);
    }

    #[test]
    fn integral_logits_rejects_fractional_values() {
        let t = TensorF::from_vec(&[1, 2], vec![1.0, 1.5]);
        let err = integral_logits(&t).unwrap_err();
        assert!(err.contains("not integer-valued"), "{err}");
        let t = TensorF::from_vec(&[1, 1], vec![f32::NAN]);
        assert!(integral_logits(&t).is_err());
        let t = TensorF::from_vec(&[1, 1], vec![1.0 + 2e-6]);
        assert!(integral_logits(&t).is_err());
    }

    struct IdentityExec;
    impl Executor for IdentityExec {
        fn name(&self) -> &str {
            "stub"
        }
        fn input_shape(&self) -> &[usize] {
            &[2]
        }
        fn max_batch(&self) -> usize {
            4
        }
        fn run_batch(&self, input: &ExecInput) -> Result<crate::exec::ExecOutput> {
            Ok(crate::exec::ExecOutput { logits: input.batch.clone() })
        }
    }

    #[test]
    fn dispatch_to_stopped_worker_pool_replies_with_shutdown_error() {
        // Regression: a failed jtx.send(job) dropped the waiters' reply
        // senders, so clients saw "server dropped request" and no failed
        // metric was recorded.
        let entry = ModelEntry::new(
            "m",
            Arc::new(IdentityExec),
            ServerConfig::default(),
            Provenance::InMemory,
        );
        let (reply, rrx) = mpsc::sync_channel(1);
        let req = Request {
            model: "m".into(),
            qx: Tensor::from_vec(&[1, 2], vec![1, 2]),
            reply,
            enqueued: Instant::now(),
        };
        let (jtx, jrx) = mpsc::channel::<Job>();
        drop(jrx); // worker pool already gone
        dispatch(&entry, std::slice::from_ref(&req), &jtx);
        let err = rrx.recv().expect("a reply must arrive").unwrap_err();
        assert!(err.to_string().contains("shutting down"), "{err}");
        assert_eq!(entry.metrics.lock().unwrap().failed, 1);
    }

    #[test]
    fn integral_logits_rejects_i32_overflow() {
        // 3e9 is exactly integral in f32 but outside i32; `as i32` would
        // silently saturate to i32::MAX.
        let t = TensorF::from_vec(&[1, 1], vec![3e9]);
        let err = integral_logits(&t).unwrap_err();
        assert!(err.contains("overflows"), "{err}");
        let t = TensorF::from_vec(&[1, 1], vec![-3e9]);
        assert!(integral_logits(&t).is_err());
    }

    #[test]
    fn builder_takes_name_and_executor_directly() {
        // The (name, exec) pair goes straight into the builder — this is
        // the migration target of the removed ModelVariant wrapper.
        let exec: Arc<dyn Executor> = Arc::new(IdentityExec);
        assert_eq!(exec.input_shape(), &[2]);
        assert_eq!(exec.max_batch(), 4);
        let server = Server::builder().model("m", exec).start().unwrap();
        let h = server.handle();
        let out = h.infer("m", Tensor::from_vec(&[1, 2], vec![4, 5])).unwrap();
        assert_eq!(out.data(), &[4, 5]);
        server.stop();
    }
}
