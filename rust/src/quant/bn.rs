//! Batch-normalization quantization (paper sec. 3.4): the three
//! strategies — folding (Eq. 18), integer BN (Eq. 21-22), and exact
//! threshold merging (Eq. 19-20).

use super::QuantSpec;

/// Full-precision BN parameters for one channel group (all length C).
#[derive(Clone, Debug)]
pub struct BnParams {
    pub gamma: Vec<f64>,
    pub sigma: Vec<f64>,
    pub beta: Vec<f64>,
    pub mu: Vec<f64>,
}

impl BnParams {
    pub fn identity(c: usize) -> Self {
        BnParams {
            gamma: vec![1.0; c],
            sigma: vec![1.0; c],
            beta: vec![0.0; c],
            mu: vec![0.0; c],
        }
    }

    pub fn channels(&self) -> usize {
        self.gamma.len()
    }

    /// kappa = gamma/sigma, lambda = beta - kappa*mu (Eq. 21).
    pub fn affine(&self) -> (Vec<f64>, Vec<f64>) {
        let kappa: Vec<f64> = self
            .gamma
            .iter()
            .zip(&self.sigma)
            .map(|(g, s)| g / s)
            .collect();
        let lambda: Vec<f64> = self
            .beta
            .iter()
            .zip(kappa.iter().zip(&self.mu))
            .map(|(b, (k, m))| b - k * m)
            .collect();
        (kappa, lambda)
    }

    /// BN folding (Eq. 18): returns per-channel (w_scale, bias_add) to
    /// apply to the preceding Linear operator:
    ///   w <- gamma/sigma * w ;  b <- b + beta - gamma/sigma * mu.
    pub fn fold(&self) -> (Vec<f64>, Vec<f64>) {
        self.affine() // identical algebra; named separately for intent
    }
}

/// Quantized integer BN (Eq. 22): Q(phi) = Q(kappa)*Q(varphi) + Q(lambda).
#[derive(Clone, Debug)]
pub struct BnQuant {
    pub kappa_q: Vec<i32>,
    pub lambda_q: Vec<i32>,
    pub eps_kappa: f64,
    /// eps of the BN output: eps_kappa * eps_phi
    pub eps_phi_out: f64,
}

impl BnQuant {
    /// Mirror of quantlib.quantize_bn: symmetric kappa quantizer
    /// (kappa_bits, default 8); lambda stored directly in the target
    /// format eps_kappa*eps_phi (the D=1 wiring of sec. 3.4 "In NEMO").
    pub fn derive(bn: &BnParams, eps_phi: f64, kappa_bits: u32) -> Self {
        let (kappa, lambda) = bn.affine();
        let mut bmax = kappa.iter().fold(0f64, |m, k| m.max(k.abs()));
        if bmax == 0.0 {
            bmax = 1.0;
        }
        let spec = QuantSpec::symmetric(bmax, kappa_bits);
        let kappa_q: Vec<i32> = kappa
            .iter()
            .map(|k| ((k / spec.eps).floor() as i64).clamp(spec.lo, spec.hi) as i32)
            .collect();
        let eps_phi_out = spec.eps * eps_phi;
        let lambda_q: Vec<i32> = lambda
            .iter()
            .map(|l| (l / eps_phi_out).floor() as i32)
            .collect();
        BnQuant { kappa_q, lambda_q, eps_kappa: spec.eps, eps_phi_out }
    }

    /// Apply to one integer value of channel c (engine hot path uses the
    /// fused version in engine/integer.rs; this is the reference).
    #[inline]
    pub fn apply(&self, c: usize, q: i64) -> i64 {
        self.kappa_q[c] as i64 * q + self.lambda_q[c] as i64
    }
}

/// Exact BN+activation merge (Eq. 19-20): per-channel integer thresholds
///   TH_i = ceil((sigma/gamma * i * eps_y - beta*sigma/gamma + mu)/eps_phi)
/// for i = 1..n_levels; output integer = #{i : Q(varphi) >= TH_i}.
#[derive(Clone, Debug)]
pub struct Thresholds {
    /// [C][N] ascending per channel
    pub th: Vec<Vec<i64>>,
    pub n_levels: i64,
}

impl Thresholds {
    /// Requires gamma, sigma > 0 (paper assumption "by construction or
    /// simple transformations").
    pub fn derive(bn: &BnParams, eps_phi: f64, eps_y: f64, n_levels: i64) -> Self {
        assert!(
            bn.gamma.iter().all(|g| *g > 0.0) && bn.sigma.iter().all(|s| *s > 0.0),
            "threshold merge requires gamma, sigma > 0 (sec. 3.4)"
        );
        let th = (0..bn.channels())
            .map(|c| {
                let inv = bn.sigma[c] / bn.gamma[c];
                (1..=n_levels)
                    .map(|i| {
                        ((inv * i as f64 * eps_y - bn.beta[c] * inv + bn.mu[c]) / eps_phi)
                            .ceil() as i64
                    })
                    .collect()
            })
            .collect();
        Thresholds { th, n_levels }
    }

    /// Q_y(varphi) for channel c — counts satisfied thresholds. The
    /// thresholds are ascending so a binary search gives O(log N); N is
    /// small (paper: "especially effective when the cardinality of Z_y is
    /// small") so linear scan wins for N <= 15 and we pick by size.
    #[inline]
    pub fn apply(&self, c: usize, q: i64) -> i64 {
        let t = &self.th[c];
        if t.len() <= 16 {
            t.iter().take_while(|th| q >= **th).count() as i64
        } else {
            t.partition_point(|th| q >= *th) as i64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::prop_check;

    fn random_bn(rng: &mut crate::util::rng::Rng, c: usize) -> BnParams {
        BnParams {
            gamma: (0..c).map(|_| rng.uniform(0.05, 2.0)).collect(),
            sigma: (0..c).map(|_| rng.uniform(0.05, 2.0)).collect(),
            beta: (0..c).map(|_| rng.normal(0.0, 0.5)).collect(),
            mu: (0..c).map(|_| rng.normal(0.0, 0.5)).collect(),
        }
    }

    #[test]
    fn identity_bn_is_identity() {
        let bn = BnParams::identity(4);
        let (k, l) = bn.affine();
        assert_eq!(k, vec![1.0; 4]);
        assert_eq!(l, vec![0.0; 4]);
    }

    #[test]
    fn integer_bn_approximates_float_bn() {
        // |eps_out * Q(phi) - (kappa*phi_hat + lambda)| bounded by the
        // kappa quantization step and one lambda ulp (Eq. 21 approx).
        prop_check(200, |rng| {
            let c = rng.int(1, 8) as usize;
            let bn = random_bn(rng, c);
            let eps_phi = rng.uniform(1e-6, 1e-3);
            let bq = BnQuant::derive(&bn, eps_phi, 8);
            let (kappa, lambda) = bn.affine();
            let ch = rng.int(0, c as i64) as usize;
            let q = rng.int(-(1 << 20), 1 << 20);
            let phi_hat = eps_phi * q as f64;
            let want = kappa[ch] * phi_hat + lambda[ch];
            let got = bq.eps_phi_out * bq.apply(ch, q) as f64;
            // kappa error <= eps_kappa => output error <= eps_kappa*|phi| +
            // one lambda quantum (eps_phi_out)
            let bound = bq.eps_kappa * phi_hat.abs() + bq.eps_phi_out * (1.0 + 1e-9);
            if (got - want).abs() > bound {
                return Err(format!("|{got} - {want}| > {bound}"));
            }
            Ok(())
        });
    }

    #[test]
    fn thresholds_exactly_match_float_path() {
        // The Eq. 19-20 proof: thresholding Q(varphi) == quantizing the
        // float BN output with Eq. 10, exactly, for every integer input.
        prop_check(100, |rng| {
            let c = rng.int(1, 6) as usize;
            let bn = random_bn(rng, c);
            let eps_phi = rng.uniform(1e-5, 1e-3);
            let eps_y = rng.uniform(5e-3, 5e-2);
            let n = [3i64, 15, 255][rng.int(0, 3) as usize];
            let th = Thresholds::derive(&bn, eps_phi, eps_y, n);
            for _ in 0..50 {
                let ch = rng.int(0, c as i64) as usize;
                let q = rng.int(-(1 << 18), 1 << 18);
                let phi_hat = eps_phi * q as f64;
                let bnv = bn.gamma[ch] / bn.sigma[ch] * (phi_hat - bn.mu[ch]) + bn.beta[ch];
                let want = ((bnv / eps_y).floor() as i64).clamp(0, n);
                let got = th.apply(ch, q);
                if got != want {
                    return Err(format!(
                        "ch {ch} q {q}: thresholds {got} != float path {want}"
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn threshold_apply_linear_equals_binary_search() {
        prop_check(100, |rng| {
            let mut t: Vec<i64> = (0..300).map(|_| rng.int(-1000, 1000)).collect();
            t.sort();
            let th = Thresholds { th: vec![t.clone()], n_levels: 300 };
            let q = rng.int(-1200, 1200);
            let lin = t.iter().take_while(|v| q >= **v).count() as i64;
            if th.apply(0, q) != lin {
                return Err("binary search mismatch".into());
            }
            Ok(())
        });
    }
}
