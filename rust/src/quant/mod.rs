//! Quantization math (paper sec. 2-3), the Rust mirror of
//! `python/compile/quantlib.py`.
//!
//! Numerical contract: every function here performs the *same f64
//! operations in the same order* as its Python counterpart, so both sides
//! derive identical integer parameters from identical float inputs (IEEE
//! 754 f64 arithmetic is deterministic; we avoid libm-dependent functions
//! like log2 on the shared paths). Cross-language goldens in
//! `artifacts/goldens.json` pin this contract down in tests.

pub mod bn;
pub mod requant;

use crate::tensor::{TensorF, TensorI};
#[cfg(test)]
use crate::tensor::Tensor;

/// Element storage width of an integer image (DESIGN.md §Precision
/// propagation and §Sub-byte-packing). Derived from a node's provable
/// value range: the packed execution path streams `U8`/`I8` tensors at
/// 1 byte/element instead of the 4 bytes an `i32` image costs, and the
/// sub-byte classes (`U1`/`U2`/`U4`/`I4`) pack 8/4/2 elements per byte —
/// the dominant bandwidth of the fused GEMM hot path shrinks with the
/// deployment bit width Q. `I32` is always a sound (if wasteful)
/// assignment and remains the fallback for wide nodes.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Precision {
    /// Single-bit unsigned image: values provably in [0, 1] (a `bits = 1`
    /// activation space); 8 elements per byte.
    U1,
    /// 2-bit unsigned image: values provably in [0, 3]; 4 elements/byte.
    U2,
    /// 4-bit unsigned image (nibble): values provably in [0, 15]; 2
    /// elements per byte.
    U4,
    /// Signed nibble image: values provably in [-8, 7] (a `bits <= 4`
    /// symmetric weight grid); 2 elements per byte, two's complement.
    I4,
    /// Unsigned sub-word image: values provably in [0, 255] (e.g. a
    /// `bits <= 8` activation space).
    U8,
    /// Signed sub-word image: values provably in [-128, 127] (e.g. a
    /// `bits <= 8` symmetric weight grid).
    I8,
    /// Full-width image — the universal fallback.
    I32,
}

impl Precision {
    /// Tightest storage class whose range contains [lo, hi] (inclusive).
    /// Unsigned wins over signed when both fit (activations at Q bits are
    /// exactly [0, 2^Q - 1]).
    pub fn for_range(lo: i64, hi: i64) -> Self {
        debug_assert!(lo <= hi, "empty range [{lo}, {hi}]");
        for p in [
            Precision::U1,
            Precision::U2,
            Precision::U4,
            Precision::I4,
            Precision::U8,
            Precision::I8,
        ] {
            if p.contains(lo, hi) {
                return p;
            }
        }
        Precision::I32
    }

    /// Precision implied by a quantized space: `bits <= 8` activation
    /// specs ([0, 2^Q-1]) map to the tightest unsigned class, `bits <= 8`
    /// symmetric weight specs ([-2^(Q-1), 2^(Q-1)-1]) to `I4`/`I8`,
    /// anything wider to `I32`.
    pub fn of_spec(spec: &QuantSpec) -> Self {
        Self::for_range(spec.lo, spec.hi)
    }

    /// Bits per element — the arena bit-sizing rule (`I4` stores two's
    /// complement nibbles, so it costs the same 4 bits as `U4`).
    pub fn bits(self) -> u32 {
        match self {
            Precision::U1 => 1,
            Precision::U2 => 2,
            Precision::U4 | Precision::I4 => 4,
            Precision::U8 | Precision::I8 => 8,
            Precision::I32 => 32,
        }
    }

    /// Whether elements of this class pack several to a byte.
    pub fn is_sub_byte(self) -> bool {
        self.bits() < 8
    }

    /// Bytes needed to store `len` elements at this precision —
    /// `ceil(len * bits / 8)`, the arena/payload byte-sizing rule. All
    /// sub-byte widths divide 8, so no element ever straddles a byte.
    pub fn storage_bytes(self, len: usize) -> usize {
        (len * self.bits() as usize).div_ceil(8)
    }

    /// Smallest representable value.
    pub fn min_val(self) -> i64 {
        match self {
            Precision::U1 | Precision::U2 | Precision::U4 | Precision::U8 => 0,
            Precision::I4 => -8,
            Precision::I8 => i8::MIN as i64,
            Precision::I32 => i32::MIN as i64,
        }
    }

    /// Largest representable value.
    pub fn max_val(self) -> i64 {
        match self {
            Precision::U1 => 1,
            Precision::U2 => 3,
            Precision::U4 => 15,
            Precision::I4 => 7,
            Precision::U8 => u8::MAX as i64,
            Precision::I8 => i8::MAX as i64,
            Precision::I32 => i32::MAX as i64,
        }
    }

    /// Whether every value of [lo, hi] is representable — the deploy-time
    /// range proof for a precision assignment.
    pub fn contains(self, lo: i64, hi: i64) -> bool {
        self.min_val() <= lo && hi <= self.max_val()
    }

    /// First value of an i32 image that does not fit this precision, if
    /// any — the shared scan behind the executors' loud input-range
    /// checks (a value outside the stamped range would violate the
    /// deploy-time range proof and wrap silently in release builds).
    pub fn find_out_of_range(self, data: &[i32]) -> Option<i32> {
        if self == Precision::I32 {
            return None;
        }
        data.iter()
            .find(|v| !(self.min_val()..=self.max_val()).contains(&(**v as i64)))
            .copied()
    }

    pub fn name(self) -> &'static str {
        match self {
            Precision::U1 => "u1",
            Precision::U2 => "u2",
            Precision::U4 => "u4",
            Precision::I4 => "i4",
            Precision::U8 => "u8",
            Precision::I8 => "i8",
            Precision::I32 => "i32",
        }
    }

    /// Inverse of [`Self::name`] — used by the deployment-artifact loader
    /// to decode stored precision stamps and weight payload dtypes.
    pub fn from_name(s: &str) -> Option<Self> {
        match s {
            "u1" => Some(Precision::U1),
            "u2" => Some(Precision::U2),
            "u4" => Some(Precision::U4),
            "i4" => Some(Precision::I4),
            "u8" => Some(Precision::U8),
            "i8" => Some(Precision::I8),
            "i32" => Some(Precision::I32),
            _ => None,
        }
    }
}

/// A quantized space Z_t with its quantum epsilon_t (Def. 2.1).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct QuantSpec {
    /// the quantum epsilon_t
    pub eps: f64,
    /// inclusive integer bounds of Z_t
    pub lo: i64,
    pub hi: i64,
}

/// Guard for the `bits` parameter of the spec constructors. `bits = 0`
/// would build an empty/degenerate grid silently (the weight constructor
/// would shift by `bits - 1` and underflow); anything above 31 overflows
/// the i32 integer-image contract the engines rely on.
fn check_bits(who: &str, bits: u32) {
    assert!(
        (1..=31).contains(&bits),
        "QuantSpec::{who}: bits must be in 1..=31, got {bits} \
         (0 yields an empty grid; >31 cannot fit an i32 integer image)"
    );
}

impl QuantSpec {
    /// alpha=0 activation space: eps = beta/(2^Q - 1), Z = [0, 2^Q - 1].
    ///
    /// `bits` must be in 1..=31; `bits = 1` gives the binary grid {0, 1}
    /// with eps = beta.
    pub fn activation(beta: f64, bits: u32) -> Self {
        check_bits("activation", bits);
        let n = (1i64 << bits) - 1;
        QuantSpec { eps: beta / n as f64, lo: 0, hi: n }
    }

    /// Symmetric weight space: eps = 2*beta/(2^Q - 1),
    /// Z = [-2^(Q-1), 2^(Q-1) - 1]. The offset alpha_w is a multiple of
    /// eps_w so Eq. 15's correction term folds into one integer image.
    ///
    /// `bits` must be in 1..=31. Note the degenerate `bits = 1` case: the
    /// grid is [-1, 0] (i.e. {-2*beta, 0} in the real domain), *not* the
    /// BinaryConnect-style {-beta, +beta} — Eq. 15's symmetric grid always
    /// includes 0 and drops the +2^(Q-1) point. Callers wanting binary
    /// weights should handle that representation themselves.
    pub fn weight(beta: f64, bits: u32) -> Self {
        check_bits("weight", bits);
        let n = (1i64 << bits) - 1;
        QuantSpec {
            eps: 2.0 * beta / n as f64,
            lo: -(1i64 << (bits - 1)),
            hi: (1i64 << (bits - 1)) - 1,
        }
    }

    /// Symmetric space for BN kappa (sec. 3.4) — same grid as weights.
    pub fn symmetric(beta: f64, bits: u32) -> Self {
        Self::weight(beta, bits)
    }

    pub fn levels(&self) -> i64 {
        self.hi - self.lo + 1
    }

    /// Integer image of a scalar: clip(floor(t/eps), lo, hi) (Eq. 10).
    #[inline]
    pub fn quantize(&self, t: f64) -> i64 {
        let q = (t / self.eps).floor();
        (q as i64).clamp(self.lo, self.hi)
    }

    /// Quantized version t_hat = eps * Q(t) (Def. 2.2, alpha = 0).
    #[inline]
    pub fn dequantize(&self, q: i64) -> f64 {
        self.eps * q as f64
    }

    /// Fake-quantize: t -> eps*Q(t) in one step (FakeQuantized fwd path).
    #[inline]
    pub fn fake_quantize(&self, t: f64) -> f64 {
        self.dequantize(self.quantize(t))
    }
}

/// Quantize an f32 tensor to its integer image under `spec`.
pub fn quantize_tensor(t: &TensorF, spec: &QuantSpec) -> TensorI {
    t.map(|x| spec.quantize(x as f64) as i32)
}

/// Replace every value by its quantized version (harden_weights).
pub fn harden_tensor(t: &TensorF, spec: &QuantSpec) -> TensorF {
    t.map(|x| spec.fake_quantize(x as f64) as f32)
}

/// Dequantize an integer image back to the real domain.
pub fn dequantize_tensor(q: &TensorI, spec: &QuantSpec) -> TensorF {
    q.map(|v| spec.dequantize(v as i64) as f32)
}

/// max|t| — the statistic NEMO's reset_alpha_weights uses for beta_w.
pub fn max_abs(t: &TensorF) -> f64 {
    let m = t.data().iter().fold(0f32, |m, x| m.max(x.abs()));
    if m == 0.0 {
        1.0
    } else {
        m as f64
    }
}

/// max(t) — calibration statistic for activation beta_y (sec. 2).
pub fn max_val(t: &TensorF) -> f64 {
    let m = t.data().iter().fold(f32::NEG_INFINITY, |m, x| m.max(*x));
    if m <= 0.0 {
        1.0
    } else {
        m as f64
    }
}

/// Integer image of an input in [0,1) at eps_in = 1/255 (sec. 3.7).
pub fn quantize_input(x: &TensorF, eps_in: f64) -> TensorI {
    let hi = (1.0 / eps_in).round() as i64;
    x.map(|v| ((v as f64 / eps_in).floor() as i64).clamp(0, hi) as i32)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::prop_check;

    #[test]
    fn activation_spec_8bit() {
        let s = QuantSpec::activation(2.55, 8);
        assert!((s.eps - 0.01).abs() < 1e-12);
        assert_eq!((s.lo, s.hi), (0, 255));
        assert_eq!(s.quantize(1.004), 100);
        assert_eq!(s.quantize(-3.0), 0);
        assert_eq!(s.quantize(99.0), 255);
    }

    #[test]
    fn weight_spec_symmetric() {
        let s = QuantSpec::weight(1.0, 8);
        assert_eq!((s.lo, s.hi), (-128, 127));
        assert_eq!(s.quantize(-1.0), -128);
        assert_eq!(s.quantize(0.999), 127);
        assert_eq!(s.quantize(0.0), 0);
    }

    #[test]
    fn quantization_function_is_monotonic_pointwise_piecewise_constant() {
        // Def. 2.2's requirements, checked as properties.
        prop_check(200, |rng| {
            let bits = [2u32, 4, 8][rng.int(0, 3) as usize];
            let beta = rng.uniform(0.1, 10.0);
            let s = QuantSpec::activation(beta, bits);
            let a = rng.uniform(-2.0 * beta, 2.0 * beta);
            let b = rng.uniform(-2.0 * beta, 2.0 * beta);
            let (qa, qb) = (s.quantize(a), s.quantize(b));
            if a <= b && qa > qb {
                return Err(format!("not monotonic: Q({a})={qa} > Q({b})={qb}"));
            }
            // quantized version error bound inside the clip range
            if a >= 0.0 && a < beta - s.eps {
                let err = (a - s.fake_quantize(a)).abs();
                if err > s.eps * (1.0 + 1e-12) {
                    return Err(format!("error {err} > eps {}", s.eps));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn fake_quantize_is_stable_within_one_quantum() {
        // floor-based quantization is idempotent only up to one ulp of
        // the division (q*eps)/eps; re-quantizing may drop at most one
        // grid step (same behaviour as NEMO's floor-based PACT_QuantFunc).
        prop_check(200, |rng| {
            let s = QuantSpec::weight(rng.uniform(0.1, 5.0), 4);
            let t = rng.normal(0.0, 2.0);
            let once = s.fake_quantize(t);
            let twice = s.fake_quantize(once);
            if (once - twice).abs() > s.eps * (1.0 + 1e-12) {
                return Err(format!("moved more than eps: {once} vs {twice}"));
            }
            Ok(())
        });
    }

    #[test]
    fn input_quantization() {
        let x = Tensor::from_vec(&[3], vec![0.0f32, 0.5, 1.5]);
        let q = quantize_input(&x, 1.0 / 255.0);
        assert_eq!(q.data(), &[0, 127, 255]);
    }

    #[test]
    fn precision_for_range_picks_the_tightest_class() {
        assert_eq!(Precision::for_range(0, 1), Precision::U1);
        assert_eq!(Precision::for_range(0, 3), Precision::U2);
        assert_eq!(Precision::for_range(0, 2), Precision::U2);
        assert_eq!(Precision::for_range(0, 15), Precision::U4);
        assert_eq!(Precision::for_range(-8, 7), Precision::I4);
        assert_eq!(Precision::for_range(-1, 0), Precision::I4); // 1-bit weight grid
        assert_eq!(Precision::for_range(0, 255), Precision::U8);
        assert_eq!(Precision::for_range(0, 127), Precision::U8); // unsigned wins
        assert_eq!(Precision::for_range(-1, 8), Precision::I8); // 8 > I4 max
        assert_eq!(Precision::for_range(-128, 127), Precision::I8);
        assert_eq!(Precision::for_range(0, 256), Precision::I32);
        assert_eq!(Precision::for_range(-129, 0), Precision::I32);
        assert_eq!(Precision::for_range(0, 511), Precision::I32); // 9-bit act
    }

    #[test]
    fn precision_of_spec_follows_the_bits_map() {
        // bits <= 8 activations -> tightest unsigned class, weights ->
        // I4/I8, else I32.
        let acts = [
            Precision::U1,
            Precision::U2,
            Precision::U4,
            Precision::U4,
            Precision::U8,
            Precision::U8,
            Precision::U8,
            Precision::U8,
        ];
        for bits in 1..=8u32 {
            assert_eq!(
                Precision::of_spec(&QuantSpec::activation(1.0, bits)),
                acts[bits as usize - 1],
                "activation bits={bits}"
            );
            let want_w = if bits <= 4 { Precision::I4 } else { Precision::I8 };
            assert_eq!(
                Precision::of_spec(&QuantSpec::weight(1.0, bits)),
                want_w,
                "weight bits={bits}"
            );
        }
        assert_eq!(Precision::of_spec(&QuantSpec::activation(1.0, 9)), Precision::I32);
        assert_eq!(Precision::of_spec(&QuantSpec::weight(1.0, 9)), Precision::I32);
    }

    #[test]
    fn precision_contains_is_the_range_proof() {
        assert!(Precision::U1.contains(0, 1));
        assert!(!Precision::U1.contains(0, 2));
        assert!(Precision::U2.contains(0, 3));
        assert!(!Precision::U2.contains(-1, 3));
        assert!(Precision::U4.contains(0, 15));
        assert!(!Precision::U4.contains(0, 16));
        assert!(Precision::I4.contains(-8, 7));
        assert!(!Precision::I4.contains(-9, 0));
        assert!(Precision::U8.contains(0, 255));
        assert!(!Precision::U8.contains(-1, 255));
        assert!(Precision::I8.contains(-1, 0));
        assert!(!Precision::I8.contains(0, 128));
        assert!(Precision::I32.contains(i32::MIN as i64, i32::MAX as i64));
    }

    #[test]
    fn precision_storage_is_bit_sized() {
        // ceil(len * bits / 8): sub-byte classes pack 8/4/2 per byte.
        assert_eq!(Precision::U1.storage_bytes(8), 1);
        assert_eq!(Precision::U1.storage_bytes(9), 2);
        assert_eq!(Precision::U2.storage_bytes(4), 1);
        assert_eq!(Precision::U2.storage_bytes(5), 2);
        assert_eq!(Precision::U4.storage_bytes(2), 1);
        assert_eq!(Precision::I4.storage_bytes(3), 2);
        assert_eq!(Precision::U8.storage_bytes(7), 7);
        assert_eq!(Precision::I8.storage_bytes(7), 7);
        assert_eq!(Precision::I32.storage_bytes(7), 28);
        assert_eq!(Precision::U1.storage_bytes(0), 0);
        for p in [
            Precision::U1,
            Precision::U2,
            Precision::U4,
            Precision::I4,
        ] {
            assert!(p.is_sub_byte(), "{}", p.name());
        }
        for p in [Precision::U8, Precision::I8, Precision::I32] {
            assert!(!p.is_sub_byte(), "{}", p.name());
        }
    }

    #[test]
    fn precision_names_round_trip() {
        for p in [
            Precision::U1,
            Precision::U2,
            Precision::U4,
            Precision::I4,
            Precision::U8,
            Precision::I8,
            Precision::I32,
        ] {
            assert_eq!(Precision::from_name(p.name()), Some(p));
        }
        assert_eq!(Precision::from_name("u3"), None);
    }

    #[test]
    fn one_bit_weight_grid_is_documented_binary() {
        // bits = 1 is legal but degenerate: grid [-1, 0], eps = 2*beta.
        let s = QuantSpec::weight(0.5, 1);
        assert_eq!((s.lo, s.hi), (-1, 0));
        assert!((s.eps - 1.0).abs() < 1e-12);
        assert_eq!(s.levels(), 2);
    }

    #[test]
    #[should_panic(expected = "bits must be in 1..=31")]
    fn zero_bit_weight_spec_is_rejected() {
        let _ = QuantSpec::weight(1.0, 0);
    }

    #[test]
    #[should_panic(expected = "bits must be in 1..=31")]
    fn zero_bit_activation_spec_is_rejected() {
        let _ = QuantSpec::activation(1.0, 0);
    }

    #[test]
    #[should_panic(expected = "bits must be in 1..=31")]
    fn over_wide_activation_spec_is_rejected() {
        let _ = QuantSpec::activation(1.0, 32);
    }
}
