//! Float-domain executor for FP / FQ / QD graphs.
//!
//! [`FloatEngine::run`] executes a freshly compiled fused [`FloatPlan`];
//! [`FloatEngine::run_interpreted`] / [`FloatEngine::run_traced`] keep
//! the unfused node-per-tensor interpreter (calibration captures
//! per-node activations through the trace, and the plan property tests
//! verify the two paths bit-identical).

use crate::engine::plan::{FloatArena, FloatPlan};
use crate::graph::{Graph, Op};
use crate::quant::QuantSpec;
use crate::tensor::ops;
use crate::tensor::TensorF;
#[cfg(test)]
use crate::tensor::Tensor;

/// Executes a float [`Graph`] on NCHW batches. Also exposes activation
/// capture for calibration (transform::calibrate).
#[derive(Default)]
pub struct FloatEngine;

impl FloatEngine {
    pub fn new() -> Self {
        FloatEngine
    }

    /// Run the graph; `x` is [B, C, H, W] (or [B, F] for MLP graphs).
    pub fn run(&self, g: &Graph, x: &TensorF) -> TensorF {
        let plan = FloatPlan::compile(g).expect("float graph failed to plan");
        let layout = plan
            .layout(x.shape().first().copied().unwrap_or(0))
            .expect("float plan layout");
        let mut arena = FloatArena::new();
        plan.execute(&layout, &mut arena, x)
    }

    /// Unfused reference interpreter (one tensor per node).
    pub fn run_interpreted(&self, g: &Graph, x: &TensorF) -> TensorF {
        self.run_inner(g, x, None)
    }

    /// Run the unfused interpreter and record the output tensor of every
    /// node (used by calibration and by debugging tools).
    pub fn run_traced(&self, g: &Graph, x: &TensorF) -> Vec<TensorF> {
        let mut trace: Vec<TensorF> = Vec::with_capacity(g.nodes.len());
        self.run_inner(g, x, Some(&mut trace));
        trace
    }

    fn run_inner(
        &self,
        g: &Graph,
        x: &TensorF,
        mut trace: Option<&mut Vec<TensorF>>,
    ) -> TensorF {
        let mut outs: Vec<Option<TensorF>> = vec![None; g.nodes.len()];
        for n in &g.nodes {
            let out = match &n.op {
                Op::Input { .. } => x.clone(),
                Op::Conv2d { w, bias, stride, pad } => {
                    let mut y = ops::conv2d_f32(
                        outs[n.inputs[0]].as_ref().unwrap(),
                        w,
                        *stride,
                        *pad,
                    );
                    if let Some(b) = bias {
                        add_channel_bias(&mut y, b);
                    }
                    y
                }
                Op::Linear { w, bias } => {
                    let mut y =
                        ops::matmul_f32(outs[n.inputs[0]].as_ref().unwrap(), w);
                    if let Some(b) = bias {
                        let c = y.shape()[1];
                        for (i, v) in y.data_mut().iter_mut().enumerate() {
                            *v += b[i % c] as f32;
                        }
                    }
                    y
                }
                Op::BatchNorm { bn } => {
                    let mut y = outs[n.inputs[0]].as_ref().unwrap().clone();
                    let (kappa, lambda) = bn.affine();
                    apply_channel_affine(&mut y, &kappa, &lambda);
                    y
                }
                Op::QuantBn { kappa_hat, lambda_hat } => {
                    let mut y = outs[n.inputs[0]].as_ref().unwrap().clone();
                    apply_channel_affine(&mut y, kappa_hat, lambda_hat);
                    y
                }
                Op::ReLU => outs[n.inputs[0]]
                    .as_ref()
                    .unwrap()
                    .map(|v| v.max(0.0)),
                Op::PactAct { beta, bits } => {
                    let spec = QuantSpec::activation(*beta, *bits);
                    outs[n.inputs[0]]
                        .as_ref()
                        .unwrap()
                        .map(|v| spec.fake_quantize(v as f64) as f32)
                }
                Op::MaxPool { k } => {
                    ops::maxpool(outs[n.inputs[0]].as_ref().unwrap(), *k)
                }
                Op::AvgPool { k } => {
                    ops::avgpool_f32(outs[n.inputs[0]].as_ref().unwrap(), *k)
                }
                Op::GlobalAvgPool => {
                    ops::global_mean_f32(outs[n.inputs[0]].as_ref().unwrap())
                }
                Op::Flatten => {
                    let t = outs[n.inputs[0]].as_ref().unwrap();
                    let b = t.shape()[0];
                    let f: usize = t.shape()[1..].iter().product();
                    t.reshape(&[b, f])
                }
                Op::Add => {
                    let first = outs[n.inputs[0]].as_ref().unwrap();
                    let mut acc = first.clone();
                    for &i in &n.inputs[1..] {
                        let t = outs[i].as_ref().unwrap();
                        assert_eq!(t.shape(), acc.shape(), "Add shape mismatch");
                        for (a, b) in acc.data_mut().iter_mut().zip(t.data()) {
                            *a += *b;
                        }
                    }
                    acc
                }
            };
            if let Some(tr) = trace.as_deref_mut() {
                tr.push(out.clone());
            }
            outs[n.id] = Some(out);
        }
        outs[g.output].take().unwrap()
    }
}

/// y[:, c, ...] = kappa[c] * y[:, c, ...] + lambda[c] for NCHW or [B, C].
fn apply_channel_affine(y: &mut TensorF, kappa: &[f64], lambda: &[f64]) {
    match y.ndim() {
        4 => {
            let (b, c, h, w) =
                (y.shape()[0], y.shape()[1], y.shape()[2], y.shape()[3]);
            let hw = h * w;
            let data = y.data_mut();
            for bi in 0..b {
                for ci in 0..c {
                    let base = (bi * c + ci) * hw;
                    let k = kappa[ci] as f32;
                    let l = lambda[ci] as f32;
                    for v in &mut data[base..base + hw] {
                        *v = k * *v + l;
                    }
                }
            }
        }
        2 => {
            let c = y.shape()[1];
            for (i, v) in y.data_mut().iter_mut().enumerate() {
                *v = kappa[i % c] as f32 * *v + lambda[i % c] as f32;
            }
        }
        d => panic!("channel affine on rank-{d} tensor"),
    }
}

fn add_channel_bias(y: &mut TensorF, bias: &[f64]) {
    let ones = vec![1.0f64; bias.len()];
    // reuse affine with kappa = 1
    apply_channel_affine(y, &ones, bias);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Graph;
    use crate::quant::bn::BnParams;

    #[test]
    fn identity_conv_bn_relu() {
        let mut g = Graph::new(1.0 / 255.0);
        let x = g.push("in", Op::Input { shape: vec![1, 3, 3] }, &[]);
        let mut wd = vec![0f32; 9];
        wd[4] = 1.0; // identity 3x3
        let w = Tensor::from_vec(&[1, 1, 3, 3], wd);
        let c = g.push("conv", Op::Conv2d { w, bias: None, stride: 1, pad: 1 }, &[x]);
        let b = g.push("bn", Op::BatchNorm { bn: BnParams::identity(1) }, &[c]);
        g.push("act", Op::ReLU, &[b]);

        let input = Tensor::from_vec(&[1, 1, 3, 3],
            vec![-1.0f32, 2.0, -3.0, 4.0, -5.0, 6.0, -7.0, 8.0, 0.0]);
        let out = FloatEngine::new().run(&g, &input);
        assert_eq!(
            out.data(),
            &[0.0, 2.0, 0.0, 4.0, 0.0, 6.0, 0.0, 8.0, 0.0]
        );
        // fused plan path == unfused interpreter, bit-exactly
        let interp = FloatEngine::new().run_interpreted(&g, &input);
        assert_eq!(out.data(), interp.data());
    }

    #[test]
    fn pact_act_quantizes_to_grid() {
        let mut g = Graph::new(1.0 / 255.0);
        let x = g.push("in", Op::Input { shape: vec![4] }, &[]);
        g.push("act", Op::PactAct { beta: 1.5, bits: 4 }, &[x]);
        let input = Tensor::from_vec(&[1, 4], vec![-0.3f32, 0.49, 1.0, 7.0]);
        let out = FloatEngine::new().run(&g, &input);
        let eps = 1.5 / 15.0;
        assert_eq!(out.data()[0], 0.0);
        assert!((out.data()[1] - (0.49f32 / eps).floor() * eps).abs() < 1e-6);
        assert_eq!(out.data()[3], 15.0 * eps); // clipped to beta
    }

    #[test]
    fn add_and_trace() {
        let mut g = Graph::new(1.0);
        let x = g.push("in", Op::Input { shape: vec![2] }, &[]);
        let r = g.push("relu", Op::ReLU, &[x]);
        g.push("add", Op::Add, &[r, r]);
        let out = FloatEngine::new().run(&g, &Tensor::from_vec(&[1, 2], vec![1.0f32, -2.0]));
        assert_eq!(out.data(), &[2.0, 0.0]);
        let trace = FloatEngine::new().run_traced(&g, &Tensor::from_vec(&[1, 2], vec![1.0f32, -2.0]));
        assert_eq!(trace.len(), 3);
    }
}
