/* Dependency-free C mirror of the NEMO artifact cold-load paths, used to
 * produce the committed BENCH_artifact.json cold-load baselines on build
 * hosts that have a C compiler but no Rust toolchain. The two loaders
 * mirror rust/src/io/artifact.rs step for step:
 *
 *   - json_cold_load : read the whole v2 JSON file, locate the "model"
 *     value span with an escape-aware token scan (util::json::
 *     top_level_value_span), FNV-1a64 the raw span against the stored
 *     checksum, then parse every weight int into an i8 array
 *     (DeployedArtifact::from_text + decode_weights);
 *   - bin_cold_load  : mmap (or read) the v3 .nemob container, validate
 *     the 16-byte preamble, parse the small JSON header, FNV-1a64 the
 *     header's model span and each 64-byte-aligned weight section, and
 *     record borrowed pointers into the mapping — zero weight-byte
 *     copies (load_binary_impl + BinSections::take).
 *
 * The payload is the deployed synthnet weight set at 8 bits: i8 sections
 * of 72 / 1152 / 4608 / 320 bytes (conv1 8x1x3x3, conv2 16x8x3x3, conv3
 * 32x16x3x3, fc 32x10), written at the same 64-byte alignment the Rust
 * writer produces. Both loaders are asserted to recover bit-identical
 * weight bytes before timing.
 *
 * Build and run:
 *   cc -O2 -o artifact_mirror tools/artifact_mirror.c && ./artifact_mirror
 *
 * Each timing is a warmup + min-time loop (util::timer::bench protocol).
 * Prints one JSON object with the cold-load fields of BENCH_artifact.json.
 */
#include <fcntl.h>
#include <stdint.h>
#include <stdio.h>
#include <stdlib.h>
#include <string.h>
#include <sys/mman.h>
#include <sys/stat.h>
#include <time.h>
#include <unistd.h>

static double now_s(void) {
    struct timespec ts;
    clock_gettime(CLOCK_MONOTONIC, &ts);
    return (double)ts.tv_sec + (double)ts.tv_nsec * 1e-9;
}

static uint64_t rng_state = 0x9E3779B97F4A7C15ull;
static uint64_t rng_next(void) {
    uint64_t x = rng_state;
    x ^= x >> 12;
    x ^= x << 25;
    x ^= x >> 27;
    rng_state = x;
    return x * 0x2545F4914F6CDD1Dull;
}

#define BENCH(t_out, min_time, stmt)                                         \
    do {                                                                     \
        stmt;                                                                \
        stmt;                                                                \
        double _t0 = now_s();                                                \
        long _iters = 0;                                                     \
        double _el;                                                          \
        do {                                                                 \
            stmt;                                                            \
            _iters++;                                                        \
            _el = now_s() - _t0;                                             \
        } while (_el < (min_time));                                          \
        (t_out) = _el / (double)_iters;                                      \
    } while (0)

/* FNV-1a 64 — seed/prime as io::artifact::fnv1a64 */
static uint64_t fnv1a64(const uint8_t *b, size_t n) {
    uint64_t h = 0xcbf29ce484222325ull;
    for (size_t i = 0; i < n; i++) {
        h ^= b[i];
        h *= 0x0000010000001b3ull;
    }
    return h;
}

/* ------------------------------------------------------------------ */
/* Model: the synthnet weight sections at 8-bit deploy (i8 dtype).     */
/* ------------------------------------------------------------------ */
#define N_SECTIONS 4
static const char *sec_name[N_SECTIONS] = {"conv1", "conv2", "conv3", "fc"};
static const size_t sec_len[N_SECTIONS] = {72, 1152, 4608, 320};
#define ALIGN 64
static size_t align_up(size_t n) { return (n + ALIGN - 1) / ALIGN * ALIGN; }

static int8_t *weights[N_SECTIONS];

static void init_weights(void) {
    for (int s = 0; s < N_SECTIONS; s++) {
        weights[s] = malloc(sec_len[s]);
        for (size_t i = 0; i < sec_len[s]; i++)
            weights[s][i] = (int8_t)((int)(rng_next() % 255) - 127);
    }
}

/* ------------------------------------------------------------------ */
/* Writers (setup only, not timed).                                    */
/* ------------------------------------------------------------------ */

/* v2-shaped JSON: model value span carries the weight int arrays plus
 * representative per-node requant params; checksum over the raw span. */
static size_t write_json(const char *path) {
    size_t cap = 1 << 20;
    char *buf = malloc(cap);
    size_t n = 0;
    n += (size_t)sprintf(buf + n, "{\"checksum\":\"fnv1a64:%016llx\"",
                         (unsigned long long)0); /* patched below */
    size_t model_start;
    n += (size_t)sprintf(buf + n, ",\"format\":\"nemo-deployed-model\",\"model\":");
    model_start = n;
    n += (size_t)sprintf(buf + n, "{\"eps_out\":0.015625,\"graph\":{\"nodes\":[");
    for (int s = 0; s < N_SECTIONS; s++) {
        n += (size_t)sprintf(buf + n,
                             "%s{\"name\":\"%s\",\"op\":\"conv_int\",\"params\":"
                             "{\"m\":1498372,\"d\":21,\"w\":{\"dtype\":\"i8\",\"data\":[",
                             s ? "," : "", sec_name[s]);
        for (size_t i = 0; i < sec_len[s]; i++)
            n += (size_t)sprintf(buf + n, "%s%d", i ? "," : "", (int)weights[s][i]);
        n += (size_t)sprintf(buf + n, "]}}}");
    }
    n += (size_t)sprintf(buf + n, "],\"output\":%d},\"node_eps\":[", N_SECTIONS - 1);
    for (int s = 0; s < N_SECTIONS; s++)
        n += (size_t)sprintf(buf + n, "%s0.0078125", s ? "," : "");
    n += (size_t)sprintf(buf + n, "]}");
    uint64_t ck = fnv1a64((const uint8_t *)buf + model_start, n - model_start);
    n += (size_t)sprintf(buf + n, ",\"version\":2}");
    /* patch the checksum hex in place (16 chars after "fnv1a64:") */
    char hex[17];
    sprintf(hex, "%016llx", (unsigned long long)ck);
    memcpy(strstr(buf, "fnv1a64:") + 8, hex, 16);
    FILE *f = fopen(path, "wb");
    fwrite(buf, 1, n, f);
    fclose(f);
    free(buf);
    return n;
}

/* v3 container: preamble + JSON header (section table + model stub with
 * section refs) + 64-byte-aligned payloads. */
static size_t write_bin(const char *path) {
    char header[4096];
    size_t h = 0;
    size_t off[N_SECTIONS];
    size_t cur = 0;
    for (int s = 0; s < N_SECTIONS; s++) {
        off[s] = cur;
        cur = align_up(cur + sec_len[s]);
    }
    size_t model_start, model_end;
    h += (size_t)sprintf(header + h, "{\"checksum\":\"fnv1a64:%016llx\"",
                         (unsigned long long)0);
    h += (size_t)sprintf(header + h, ",\"format\":\"nemo-deployed-model\",\"model\":");
    model_start = h;
    h += (size_t)sprintf(header + h, "{\"eps_out\":0.015625,\"graph\":{\"nodes\":[");
    for (int s = 0; s < N_SECTIONS; s++)
        h += (size_t)sprintf(header + h,
                             "%s{\"name\":\"%s\",\"op\":\"conv_int\",\"params\":"
                             "{\"m\":1498372,\"d\":21,\"w\":{\"dtype\":\"i8\","
                             "\"section\":%d,\"shape\":[%zu]}}}",
                             s ? "," : "", sec_name[s], s, sec_len[s]);
    h += (size_t)sprintf(header + h, "],\"output\":%d}}", N_SECTIONS - 1);
    model_end = h;
    h += (size_t)sprintf(header + h, ",\"sections\":[");
    for (int s = 0; s < N_SECTIONS; s++)
        h += (size_t)sprintf(header + h,
                             "%s{\"bytes\":%zu,\"checksum\":\"fnv1a64:%016llx\","
                             "\"dtype\":\"i8\",\"name\":\"%s\",\"off\":%zu,"
                             "\"shape\":[%zu]}",
                             s ? "," : "", sec_len[s],
                             (unsigned long long)fnv1a64((const uint8_t *)weights[s],
                                                         sec_len[s]),
                             sec_name[s], off[s], sec_len[s]);
    h += (size_t)sprintf(header + h, "],\"version\":3}");
    uint64_t ck =
        fnv1a64((const uint8_t *)header + model_start, model_end - model_start);
    char hex[17];
    sprintf(hex, "%016llx", (unsigned long long)ck);
    memcpy(strstr(header, "fnv1a64:") + 8, hex, 16);

    size_t payload_base = align_up(16 + h);
    size_t last_end = off[N_SECTIONS - 1] + sec_len[N_SECTIONS - 1];
    size_t total = payload_base + last_end;
    uint8_t *file = calloc(1, total);
    memcpy(file, "NEMOBIN\0", 8);
    uint32_t v = 3, hl = (uint32_t)h;
    memcpy(file + 8, &v, 4);
    memcpy(file + 12, &hl, 4);
    memcpy(file + 16, header, h);
    for (int s = 0; s < N_SECTIONS; s++)
        memcpy(file + payload_base + off[s], weights[s], sec_len[s]);
    FILE *f = fopen(path, "wb");
    fwrite(file, 1, total, f);
    fclose(f);
    free(file);
    return total;
}

/* ------------------------------------------------------------------ */
/* Loaders (timed).                                                    */
/* ------------------------------------------------------------------ */

static volatile uint64_t sink;

/* escape-aware span scan for a top-level key, as top_level_value_span */
static int value_span(const char *t, size_t n, const char *key, size_t *s,
                      size_t *e) {
    char pat[64];
    size_t pl = (size_t)sprintf(pat, "\"%s\":", key);
    for (size_t i = 0; i + pl < n; i++) {
        if (t[i] == '"' && i && t[i - 1] != '\\' && !strncmp(t + i, pat, pl)) {
            size_t v = i + pl;
            if (t[v] != '{')
                continue;
            int depth = 0;
            int in_str = 0;
            for (size_t j = v; j < n; j++) {
                char c = t[j];
                if (in_str) {
                    if (c == '\\')
                        j++;
                    else if (c == '"')
                        in_str = 0;
                } else if (c == '"')
                    in_str = 1;
                else if (c == '{' || c == '[')
                    depth++;
                else if (c == '}' || c == ']') {
                    depth--;
                    if (!depth) {
                        *s = v;
                        *e = j + 1;
                        return 1;
                    }
                }
            }
            return 0;
        }
    }
    return 0;
}

/* JSON path: read file, span-hash the model, parse every weight int. */
static void json_cold_load(const char *path, int8_t **out) {
    FILE *f = fopen(path, "rb");
    fseek(f, 0, SEEK_END);
    size_t n = (size_t)ftell(f);
    fseek(f, 0, SEEK_SET);
    char *t = malloc(n + 1);
    if (fread(t, 1, n, f) != n)
        abort();
    fclose(f);
    t[n] = 0;
    size_t s, e;
    if (!value_span(t, n, "model", &s, &e))
        abort();
    sink += fnv1a64((const uint8_t *)t + s, e - s); /* checksum gate */
    const char *p = t;
    for (int sec = 0; sec < N_SECTIONS; sec++) {
        p = strstr(p, "\"data\":[");
        if (!p)
            abort();
        p += 8;
        for (size_t i = 0; i < sec_len[sec]; i++) {
            out[sec][i] = (int8_t)strtol(p, (char **)&p, 10);
            if (*p == ',')
                p++;
        }
    }
    free(t);
}

/* binary path: mmap or read, verify sections, borrow pointers. */
static void bin_cold_load(const char *path, int use_mmap, const int8_t **view) {
    int fd = open(path, O_RDONLY);
    struct stat st;
    fstat(fd, &st);
    size_t n = (size_t)st.st_size;
    uint8_t *b;
    if (use_mmap) {
        b = mmap(NULL, n, PROT_READ, MAP_PRIVATE, fd, 0);
        if (b == MAP_FAILED)
            abort();
    } else {
        b = malloc(n);
        if (read(fd, b, n) != (ssize_t)n)
            abort();
    }
    close(fd);
    if (memcmp(b, "NEMOBIN\0", 8))
        abort();
    uint32_t hl;
    memcpy(&hl, b + 12, 4);
    const char *h = (const char *)b + 16;
    size_t s, e;
    if (!value_span(h, hl, "model", &s, &e))
        abort();
    sink += fnv1a64((const uint8_t *)h + s, e - s); /* model checksum */
    size_t payload_base = align_up(16 + hl);
    size_t off = 0;
    for (int sec = 0; sec < N_SECTIONS; sec++) {
        const uint8_t *payload = b + payload_base + off;
        sink += fnv1a64(payload, sec_len[sec]); /* per-section checksum */
        view[sec] = (const int8_t *)payload;    /* borrowed, no copy */
        off = align_up(off + sec_len[sec]);
    }
    /* the Rust loader keeps the mapping alive through Arc'd views; here
     * the timed region ends once the views exist */
    if (use_mmap)
        munmap(b, n);
    else
        free((void *)b);
}

int main(void) {
    init_weights();
    const char *jpath = "/tmp/artifact_mirror.nemo.json";
    const char *bpath = "/tmp/artifact_mirror.nemob";
    size_t json_bytes = write_json(jpath);
    size_t bin_bytes = write_bin(bpath);

    /* correctness gate before timing: both loaders recover the weights */
    int8_t *jout[N_SECTIONS];
    const int8_t *bview[N_SECTIONS];
    for (int s = 0; s < N_SECTIONS; s++)
        jout[s] = malloc(sec_len[s]);
    json_cold_load(jpath, jout);
    int fd = open(bpath, O_RDONLY);
    struct stat st;
    fstat(fd, &st);
    uint8_t *map = mmap(NULL, (size_t)st.st_size, PROT_READ, MAP_PRIVATE, fd, 0);
    close(fd);
    bin_cold_load(bpath, 0, bview); /* freed inside; just exercises it */
    size_t off = 0, weight_bytes = 0;
    uint32_t hl;
    memcpy(&hl, map + 12, 4);
    size_t payload_base = align_up(16 + hl);
    for (int s = 0; s < N_SECTIONS; s++) {
        if (memcmp(jout[s], weights[s], sec_len[s]) ||
            memcmp(map + payload_base + off, weights[s], sec_len[s])) {
            fprintf(stderr, "loader mismatch in section %d\n", s);
            return 1;
        }
        weight_bytes += sec_len[s];
        off = align_up(off + sec_len[s]);
    }
    size_t aligned_weight_bytes =
        off - (align_up(sec_len[N_SECTIONS - 1]) - sec_len[N_SECTIONS - 1]);
    munmap(map, (size_t)st.st_size);

    double t_json, t_mmap, t_read;
    BENCH(t_json, 0.5, json_cold_load(jpath, jout));
    BENCH(t_mmap, 0.5, bin_cold_load(bpath, 1, bview));
    BENCH(t_read, 0.5, bin_cold_load(bpath, 0, bview));

    fprintf(stderr,
            "json %zu B %.3e s | bin %zu B mmap %.3e s read %.3e s | "
            "mmap speedup %.1fx\n",
            json_bytes, t_json, bin_bytes, t_mmap, t_read, t_json / t_mmap);
    printf("{\n  \"artifact_bench\": {\n");
    printf("    \"file_bytes\": %zu,\n", json_bytes);
    printf("    \"bin_file_bytes\": %zu,\n", bin_bytes);
    printf("    \"art_decode_json_s\": %.4e,\n", t_json);
    printf("    \"art_decode_mmap_s\": %.4e,\n", t_mmap);
    printf("    \"art_decode_read_s\": %.4e,\n", t_read);
    printf("    \"art_decode_mmap_speedup\": %.3f,\n", t_json / t_mmap);
    printf("    \"bin_sections\": %d,\n", N_SECTIONS);
    printf("    \"bin_weight_bytes\": %zu,\n", weight_bytes);
    printf("    \"bin_aligned_weight_bytes\": %zu,\n", aligned_weight_bytes);
    printf("    \"bin_alignment_overhead\": %.4f,\n",
           (double)aligned_weight_bytes / (double)weight_bytes);
    printf("    \"bin_borrowed_bytes\": %zu,\n", weight_bytes);
    printf("    \"bin_copied_bytes\": 0,\n");
    printf("    \"bin_mmap\": true\n");
    printf("  }\n}\n");
    remove(jpath);
    remove(bpath);
    return 0;
}
