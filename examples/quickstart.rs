//! Quickstart: the four NEMO representations in ~60 lines.
//!
//!     cargo run --release --example quickstart
//!
//! Builds a small MLP, walks it FullPrecision -> FakeQuantized ->
//! QuantizedDeployable -> IntegerDeployable, and shows that the final
//! integer-only network (no floats anywhere on the value path) agrees
//! with the float pipeline. No AOT artifacts required.

use nemo::engine::{FloatEngine, IntegerEngine};
use nemo::model::mlp;
use nemo::quant::quantize_input;
use nemo::tensor::Tensor;
use nemo::transform::{calibrate, deploy, quantize_pact, DeployOptions};
use nemo::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let mut rng = Rng::new(42);
    let eps_in = 1.0 / 255.0;

    // 1. FullPrecision: an ordinary float network (sec. 1).
    let fp = mlp(&mut rng, 64, 48, 10, eps_in);
    let x = Tensor::from_vec(
        &[4, 64],
        (0..256).map(|_| rng.uniform(0.0, 1.0) as f32).collect(),
    );
    let fp_out = FloatEngine::new().run(&fp, &x);

    // 2. FakeQuantized: PACT clipping bounds from FP calibration (sec. 2).
    let betas = calibrate(&fp, &[x.clone()]);
    println!("calibrated PACT betas: {betas:?}");
    let fq = quantize_pact(&fp, 8, 8, &betas);
    let fq_out = FloatEngine::new().run(&fq, &x);

    // 3+4. QuantizedDeployable + IntegerDeployable in one transform
    //      (harden_weights + bn_quantizer + set_deployment + integerize).
    let dep = deploy(&fq, DeployOptions::default())?;
    let qd_out = FloatEngine::new().run(&dep.qd, &x);

    // Integer-only inference: quantize the input image (eps_in = 1/255,
    // sec. 3.7) and run on integer images end to end.
    let qx = quantize_input(&x, eps_in);
    let id_out = IntegerEngine::new().run(&dep.id, &qx);

    println!("\nlogits for sample 0:");
    println!("  FP : {:?}", &fp_out.data()[..10]);
    println!("  FQ : {:?}", &fq_out.data()[..10]);
    println!("  QD : {:?}", &qd_out.data()[..10]);
    let id_real: Vec<f32> = id_out.data()[..10]
        .iter()
        .map(|q| (*q as f64 * dep.eps_out) as f32)
        .collect();
    println!("  ID : {id_real:?}  (eps_out * integer image)");
    println!("  ID integer image: {:?}", &id_out.data()[..10]);

    assert_eq!(
        fp_out.argmax_rows(),
        id_out.argmax_rows(),
        "integer-only deployment changed the predictions!"
    );
    println!("\nargmax agreement FP == ID on all {} samples ✓", x.shape()[0]);
    println!("max |QD - eps*ID| = {:.2e}", {
        let mut m = 0f64;
        for (a, b) in qd_out.data().iter().zip(id_out.data()) {
            m = m.max((*a as f64 - *b as f64 * dep.eps_out).abs());
        }
        m
    });
    Ok(())
}
