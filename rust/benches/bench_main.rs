//! Experiment/bench harness: one sub-bench per experiment in DESIGN.md §6
//! (the tables/figures the tech report implies). Run all:
//!
//!     cargo bench
//!
//! or a subset: `cargo bench -- E1 E5 plan`. Results are recorded in
//! EXPERIMENTS.md; the `plan` bench additionally writes BENCH_plan.json
//! (planned-vs-interpreted integer inference throughput) so CI and the
//! perf log can track the compiled-plan speedup. criterion is not in the
//! offline vendor set; timing uses util::timer::bench (warmup + min-time
//! loop). The default build needs no artifacts and no `pjrt` feature —
//! PJRT-dependent benches compile out (and print a skip note) without it.

use std::sync::Arc;
use std::time::Duration;

use nemo::coordinator::{Server, ServerConfig};
use nemo::data::SynthDigits;
use nemo::engine::plan::{IntArena, PackedArena};
use nemo::engine::{FloatEngine, IntPlan, IntegerEngine};
use nemo::exec::{ExecInput, Executor, NativeIntExecutor};
use nemo::graph::int::{IntGraph, IntOp};
use nemo::graph::Graph;
use nemo::io::artifact::{binary_info, DeployedArtifact};
use nemo::io::BinLoadMode;
use nemo::model::residual_net;
use nemo::model::synthnet::{SynthNet, EPS_IN};
use nemo::network::{FakeQuantized, Network};
use nemo::quant::bn::{BnParams, BnQuant, Thresholds};
use nemo::quant::requant::{choose_d, multiplier, Requant};
use nemo::quant::{quantize_input, Precision};
use nemo::tensor::{ops, set_packed, Tensor, TensorI};
use nemo::transform::{calibrate_percentile, DeployOptions, Deployed};
use nemo::util::json::{self, Value};
use nemo::util::rng::Rng;
use nemo::util::timer::{bench, fmt_time};

#[cfg(feature = "pjrt")]
use nemo::io::artifacts_dir;
#[cfg(feature = "pjrt")]
use nemo::runtime::Runtime;

/// PACT graph -> deployment record via the typed pipeline (the untyped
/// `transform::deploy` shim is gone).
fn deploy_pact(g: Graph, opts: DeployOptions) -> Deployed {
    Network::<FakeQuantized>::from_pact_graph(g)
        .expect("pact graph")
        .deploy(opts)
        .expect("deploy")
        .integerize()
        .into_deployed()
}

fn main() {
    let filters: Vec<String> = std::env::args()
        .skip(1)
        .filter(|a| {
            a.starts_with('E')
                || a.starts_with("perf")
                || a.starts_with("plan")
                || a.starts_with("packed")
                || a.starts_with("subbyte")
                || a.starts_with("artifact")
                || a.starts_with("registry")
                || a.starts_with("net")
                || a.starts_with("train")
        })
        .collect();
    let run = |tag: &str| {
        filters.is_empty() || filters.iter().any(|f| tag.starts_with(f.as_str()))
    };

    #[cfg(feature = "pjrt")]
    let rt = Runtime::new(artifacts_dir()).ok();
    #[cfg(feature = "pjrt")]
    if rt.is_none() {
        eprintln!("NOTE: artifacts not built; PJRT-dependent benches are skipped");
    }
    #[cfg(not(feature = "pjrt"))]
    eprintln!("NOTE: built without `pjrt`; PJRT-dependent benches are skipped");

    if run("E1") {
        e1_requant_error();
    }
    if run("E2") {
        e2_threshold_exactness();
    }
    #[cfg(feature = "pjrt")]
    if run("E3") || run("E4") {
        e3_e4_representations_and_qat(rt.as_ref());
    }
    if run("E5") {
        e5_avgpool_error();
    }
    if run("E6") {
        e6_add_requant();
    }
    if run("E7") {
        e7_bn_folding();
    }
    if run("E8") {
        e8_engine_and_serving();
    }
    #[cfg(feature = "pjrt")]
    if run("E9") {
        e9_float_hardware(rt.as_ref());
    }
    if run("plan") {
        plan_vs_interpreted();
    }
    if run("packed") {
        packed_vs_i32();
    }
    if run("subbyte") {
        subbyte_bench();
    }
    if run("artifact") {
        artifact_cold_load_and_serve();
    }
    if run("registry") {
        registry_multi_model_and_swap();
    }
    if run("net") {
        net_loopback();
    }
    if run("train") {
        train_native_bench();
    }
    if run("perf") {
        perf_microbench();
        #[cfg(feature = "pjrt")]
        perf_pjrt_kernels(rt.as_ref());
    }
}

// ---------------------------------------------------------------------------
// E1: requantization relative error vs d (Eq. 12-14)
// ---------------------------------------------------------------------------

fn e1_requant_error() {
    println!("\n=== E1: requantization error vs d (Eq. 13-14 bound) ===");
    println!(
        "{:>4} {:>14} {:>14} {:>10}",
        "d", "max rel err", "bound 1/2^d*r", "ok"
    );
    let mut rng = Rng::new(1);
    for d in [2u32, 4, 8, 12, 16, 20, 24] {
        let mut max_rel = 0f64;
        let mut bound = 0f64;
        for _ in 0..2000 {
            let eps_a = rng.uniform(1e-6, 1e-2);
            let eps_b = rng.uniform(1e-4, 1e-1);
            let ratio = eps_a / eps_b;
            let m = multiplier(eps_a, eps_b, d);
            if m == 0 {
                continue; // d too small for this ratio: out of Eq. 14 regime
            }
            let approx = m as f64 / (1u64 << d) as f64;
            max_rel = max_rel.max((ratio - approx).abs() / ratio);
            bound = bound.max(1.0 / ((1u64 << d) as f64 * ratio));
        }
        println!(
            "{:>4} {:>14.3e} {:>14.3e} {:>10}",
            d,
            max_rel,
            bound,
            if max_rel <= bound * (1.0 + 1e-9) { "within" } else { "VIOLATED" }
        );
    }
    // The Eq. 14 d-selection hits the eta target:
    let mut worst = 0f64;
    for _ in 0..5000 {
        let eps_a = rng.uniform(1e-7, 1e-1);
        let eps_b = rng.uniform(1e-7, 1e-1);
        let Ok(d) = choose_d(eps_a, eps_b, 16) else {
            continue; // saturation is a typed error now
        };
        let m = multiplier(eps_a, eps_b, d);
        let rel = (eps_a / eps_b - m as f64 / (1u64 << d) as f64).abs() / (eps_a / eps_b);
        worst = worst.max(rel);
    }
    println!("choose_d(factor=16): worst rel err {worst:.4} (target <= 0.0625)");
}

// ---------------------------------------------------------------------------
// E2: threshold merge exactness + cost (Eq. 19-20)
// ---------------------------------------------------------------------------

fn e2_threshold_exactness() {
    println!("\n=== E2: threshold BN+act merge — exactness & cost (Eq. 19-20) ===");
    let mut rng = Rng::new(2);
    let c = 32;
    let bn = BnParams {
        gamma: (0..c).map(|_| rng.uniform(0.05, 2.0)).collect(),
        sigma: (0..c).map(|_| rng.uniform(0.05, 2.0)).collect(),
        beta: (0..c).map(|_| rng.normal(0.0, 0.5)).collect(),
        mu: (0..c).map(|_| rng.normal(0.0, 0.5)).collect(),
    };
    let eps_phi = 1e-4;
    println!(
        "{:>6} {:>12} {:>14} {:>14}",
        "bits", "mismatches", "thresh t/el", "intbn+rq t/el"
    );
    for bits in [2u32, 4, 8] {
        let n = (1i64 << bits) - 1;
        let eps_y = 2.0 / n as f64;
        let th = Thresholds::derive(&bn, eps_phi, eps_y, n);
        let bq = BnQuant::derive(&bn, eps_phi, 8);
        let rq = Requant::derive(bq.eps_phi_out, eps_y, 16, 0, n).expect("bound reachable");
        // exactness vs the float BN + Eq. 10 path
        let mut mismatches = 0u64;
        let mut qs = Vec::new();
        for _ in 0..100_000 {
            let ch = rng.int(0, c as i64) as usize;
            let q = rng.int(-(1 << 20), 1 << 20);
            qs.push((ch, q));
            let float_bn = bn.gamma[ch] / bn.sigma[ch] * (eps_phi * q as f64 - bn.mu[ch])
                + bn.beta[ch];
            let want = ((float_bn / eps_y).floor() as i64).clamp(0, n);
            if th.apply(ch, q) != want {
                mismatches += 1;
            }
        }
        // cost per element
        let (t_th, _) = bench(1, 0.2, || {
            let mut acc = 0i64;
            for (ch, q) in &qs {
                acc = acc.wrapping_add(th.apply(*ch, *q));
            }
            std::hint::black_box(acc);
        });
        let (t_rq, _) = bench(1, 0.2, || {
            let mut acc = 0i64;
            for (ch, q) in &qs {
                acc = acc.wrapping_add(rq.apply(bq.apply(*ch, *q)));
            }
            std::hint::black_box(acc);
        });
        println!(
            "{:>6} {:>12} {:>14} {:>14}",
            bits,
            mismatches,
            fmt_time(t_th / qs.len() as f64),
            fmt_time(t_rq / qs.len() as f64)
        );
    }
    println!("(threshold path is exact by construction; mismatches must be 0)");
}

// ---------------------------------------------------------------------------
// E3+E4: representation accuracy table + QAT recovery (needs pjrt)
// ---------------------------------------------------------------------------

#[cfg(feature = "pjrt")]
fn e3_e4_representations_and_qat(rt: Option<&Runtime>) {
    use nemo::train::{eval_float, eval_integer, train_fp, train_fq, TrainConfig};

    println!("\n=== E3: accuracy across representations / E4: QAT recovery ===");
    let Some(rt) = rt else {
        println!("skipped (no artifacts)");
        return;
    };
    let seed = 3u64;
    let mut rng = Rng::new(seed);
    let mut net = SynthNet::init(&mut rng);
    let mut data = SynthDigits::new(seed);
    let cfg = TrainConfig {
        steps: 500,
        lr: 0.3,
        lr_decay: true,
        seed,
        log_every: 0,
        ..TrainConfig::default()
    };
    train_fp(rt, &mut net, &mut data, &cfg).expect("fp train");
    let (cal_x, _) = data.batch(128);
    net.act_betas = calibrate_percentile(&net.to_fp_graph(), &[cal_x], 0.995);
    let (eval_x, eval_l) = SynthDigits::eval_set(seed, 1024);
    let fp_acc = eval_float(&net.to_fp_graph(), &eval_x, &eval_l);

    println!(
        "{:<8} {:>8} {:>10} {:>10} {:>12} {:>12}",
        "bits", "FP", "QD preQAT", "ID preQAT", "QD postQAT", "ID postQAT"
    );
    for bits in [8u32, 4, 2] {
        let dep0 = deploy_pact(
            net.to_pact_graph(bits),
            DeployOptions { wbits: bits, abits: bits, ..DeployOptions::default() },
        );
        let qd0 = eval_float(&dep0.qd, &eval_x, &eval_l);
        let id0 = eval_integer(&dep0.id, &eval_x, &eval_l, EPS_IN);

        // E4: QAT fine-tune at this bit width (fresh copy of the FP net)
        let mut qat_net = net.clone();
        let mut qat_data = SynthDigits::new(seed + 100);
        let qcfg = TrainConfig {
            steps: 200,
            lr: 0.06,
            lr_decay: true,
            seed,
            log_every: 0,
            ..TrainConfig::default()
        };
        train_fq(rt, &mut qat_net, &mut qat_data, bits, bits, &qcfg).expect("fq");
        let dep1 = deploy_pact(
            qat_net.to_pact_graph(bits),
            DeployOptions { wbits: bits, abits: bits, ..DeployOptions::default() },
        );
        let qd1 = eval_float(&dep1.qd, &eval_x, &eval_l);
        let id1 = eval_integer(&dep1.id, &eval_x, &eval_l, EPS_IN);
        println!(
            "{:<8} {:>7.1}% {:>9.1}% {:>9.1}% {:>11.1}% {:>11.1}%",
            format!("{bits}/{bits}"),
            fp_acc * 100.0,
            qd0 * 100.0,
            id0 * 100.0,
            qd1 * 100.0,
            id1 * 100.0
        );
    }
}

// ---------------------------------------------------------------------------
// E5: integer AvgPool error vs d (Eq. 25)
// ---------------------------------------------------------------------------

fn e5_avgpool_error() {
    println!("\n=== E5: integer AvgPool scaling error vs d (Eq. 25) ===");
    println!("{:>4} {:>4} {:>14} {:>14}", "K", "d", "max abs err", "mean abs err");
    let mut rng = Rng::new(5);
    for k in [2usize, 3, 4, 7] {
        for d in [8u32, 12, 16, 20] {
            let m = (1i64 << d) / (k * k) as i64;
            let mut max_err = 0f64;
            let mut sum_err = 0f64;
            let trials = 20_000;
            for _ in 0..trials {
                let acc: i64 = (0..k * k).map(|_| rng.int(0, 256)).sum();
                let got = ((acc * m) >> d) as f64;
                let exact = acc as f64 / (k * k) as f64;
                let e = (exact - got).abs();
                max_err = max_err.max(e);
                sum_err += e;
            }
            println!(
                "{:>4} {:>4} {:>14.4} {:>14.4}",
                k,
                d,
                max_err,
                sum_err / trials as f64
            );
        }
    }
    println!("(error -> floor-only (<1) as d grows; K=4 with d>=4 is exact scaling)");
}

// ---------------------------------------------------------------------------
// E6: Add requantization (Eq. 24) on the residual net
// ---------------------------------------------------------------------------

fn e6_add_requant() {
    println!("\n=== E6: integer Add with per-branch requantization (Eq. 24) ===");
    let mut rng = Rng::new(6);
    let g = residual_net(&mut rng, EPS_IN);
    let mut cal = SynthDigits::new(60);
    let (cal_x, _) = cal.batch(32);
    let betas = calibrate_percentile(&g, &[cal_x.clone()], 0.999);
    let fq = Network::from_graph(g)
        .expect("fp")
        .quantize_pact(8, 8, &betas)
        .expect("fq");
    let fq_graph = fq.graph().clone();
    println!("{:>8} {:>16} {:>16}", "factor", "max |QD-ID| out", "argmax agree");
    for factor in [16u32, 64, 256, 1024] {
        let dep = deploy_pact(
            fq_graph.clone(),
            DeployOptions { add_requant_factor: factor, ..DeployOptions::default() },
        );
        let (x, _) = SynthDigits::eval_set(61, 128);
        let qx = quantize_input(&x, EPS_IN);
        let x_grid = qx.map(|q| q as f32 / 255.0);
        let qd = FloatEngine::new().run(&dep.qd, &x_grid);
        let id = IntegerEngine::new().run(&dep.id, &qx);
        let mut max_diff = 0f64;
        for (a, b) in qd.data().iter().zip(id.data()) {
            max_diff = max_diff.max((*a as f64 - *b as f64 * dep.eps_out).abs());
        }
        let agree = qd
            .argmax_rows()
            .iter()
            .zip(id.argmax_rows())
            .filter(|(a, b)| **a == *b)
            .count();
        println!(
            "{:>8} {:>16.4e} {:>13}/128",
            factor, max_diff, agree
        );
    }
    println!("(NEMO default factor = 256)");
}

// ---------------------------------------------------------------------------
// E7: BN folding (Eq. 18)
// ---------------------------------------------------------------------------

fn e7_bn_folding() {
    println!("\n=== E7: BN folding exactness + inference cost (Eq. 18) ===");
    let mut rng = Rng::new(7);
    let net = SynthNet::init(&mut rng);
    let g = net.to_fp_graph();
    let folded_net = Network::from_graph(g.clone())
        .expect("fp")
        .fold_bn(None)
        .expect("fold");
    let folded = folded_net.graph();
    let (x, _) = SynthDigits::eval_set(70, 64);
    let e = FloatEngine::new();
    let a = e.run(&g, &x);
    let b = e.run(folded, &x);
    println!("max |unfolded - folded| = {:.3e} (float assoc. error only)", a.max_abs_diff(&b));
    let (t_bn, _) = bench(1, 0.5, || {
        std::hint::black_box(e.run(&g, &x));
    });
    let (t_fold, _) = bench(1, 0.5, || {
        std::hint::black_box(e.run(folded, &x));
    });
    println!(
        "inference: with BN {}  folded {}  ({:.1}% faster, {} fewer nodes)",
        fmt_time(t_bn),
        fmt_time(t_fold),
        100.0 * (t_bn - t_fold) / t_bn,
        g.nodes.len() - folded.nodes.len()
    );
}

// ---------------------------------------------------------------------------
// E8: engine throughput + native serving sweep
// ---------------------------------------------------------------------------

fn e8_engine_and_serving() {
    println!("\n=== E8: deployment throughput (engines + native serving) ===");
    let mut rng = Rng::new(8);
    let net = SynthNet::init(&mut rng);
    let dep = deploy_pact(net.to_pact_graph(8), DeployOptions::default());
    let (x, _) = SynthDigits::eval_set(80, 16);
    let qx = quantize_input(&x, EPS_IN);
    let fe = FloatEngine::new();
    let ie = IntegerEngine::new();
    let fp_g = net.to_fp_graph();

    let (t_fp, _) = bench(2, 1.0, || {
        std::hint::black_box(fe.run(&fp_g, &x));
    });
    let (t_qd, _) = bench(2, 1.0, || {
        std::hint::black_box(fe.run(&dep.qd, &x));
    });
    let (t_id, _) = bench(2, 1.0, || {
        std::hint::black_box(ie.run(&dep.id, &qx));
    });
    println!("batch=16 inference:");
    println!("  FloatEngine FP   : {} / batch ({:.0} img/s)", fmt_time(t_fp), 16.0 / t_fp);
    println!("  FloatEngine QD   : {} / batch ({:.0} img/s)", fmt_time(t_qd), 16.0 / t_qd);
    println!("  IntegerEngine ID : {} / batch ({:.0} img/s)", fmt_time(t_id), 16.0 / t_id);

    // Serving sweep over the planned native executor: no artifacts, no
    // FFI — the coordinator's hot path does zero graph walking.
    println!("serving over native-int (512 req, 2 workers):");
    println!(
        "  {:>9} {:>8} {:>10} {:>10} {:>12}",
        "max_batch", "clients", "p50 (ms)", "p99 (ms)", "thruput r/s"
    );
    for (max_batch, clients) in [(1usize, 8usize), (16, 8), (16, 32)] {
        let exec = NativeIntExecutor::new(dep.id.clone(), max_batch).expect("executor");
        let server = Server::builder()
            .default_config(ServerConfig {
                max_batch,
                batch_timeout: Duration::from_micros(300),
                n_workers: 2,
            })
            .model("synthnet", Arc::new(exec))
            .start()
            .expect("server");
        let t0 = std::time::Instant::now();
        let mut joins = Vec::new();
        for c in 0..clients {
            let h = server.handle();
            joins.push(std::thread::spawn(move || {
                let mut d = SynthDigits::new(800 + c as u64);
                for _ in 0..512 / clients {
                    let (x, _) = d.batch(1);
                    h.infer("synthnet", quantize_input(&x, EPS_IN)).unwrap();
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        let wall = t0.elapsed().as_secs_f64();
        let mut m = server.stop();
        println!(
            "  {:>9} {:>8} {:>10.3} {:>10.3} {:>12.0}",
            max_batch,
            clients,
            m.e2e_latency.percentile(0.5) * 1e3,
            m.e2e_latency.percentile(0.99) * 1e3,
            m.throughput(wall)
        );
    }
}

// ---------------------------------------------------------------------------
// E9: ID on float hardware (PJRT) — exactness + overhead (needs pjrt)
// ---------------------------------------------------------------------------

#[cfg(feature = "pjrt")]
fn e9_float_hardware(rt: Option<&Runtime>) {
    use nemo::graph::Op;
    use nemo::model::artifact_args::synthnet_id_args;

    println!("\n=== E9: IntegerDeployable on general-purpose hardware (sec. 3 note) ===");
    let Some(rt) = rt else {
        println!("skipped (no artifacts)");
        return;
    };
    let mut rng = Rng::new(9);
    let net = SynthNet::init(&mut rng);
    let dep = deploy_pact(net.to_pact_graph(8), DeployOptions::default());
    let (x, _) = SynthDigits::eval_set(90, 8);
    let qx = quantize_input(&x, EPS_IN);
    let x_grid = qx.map(|q| q as f32 / 255.0);

    // exactness: integer engine vs BOTH PJRT integer graphs
    let engine_out = IntegerEngine::new().run(&dep.id, &qx);
    let id_exe = rt.load("synthnet_id_fwd_b8").expect("load id");
    let mut id_args = synthnet_id_args(&dep).expect("args");
    id_args.push(qx.clone().into());
    let pjrt_out = id_exe.run(&id_args).expect("run");
    let exact = pjrt_out[0].as_i32().unwrap().data() == engine_out.data();
    println!("bit-exactness IntegerEngine vs PJRT(Pallas): {}", if exact { "EXACT ✓" } else { "MISMATCH ✗" });
    let id_xla = rt.load("synthnet_id_xla_b8").ok();
    if let Some(x_exe) = &id_xla {
        let o = x_exe.run(&id_args).expect("run xla");
        let exact2 = o[0].as_i32().unwrap().data() == engine_out.data();
        println!(
            "bit-exactness IntegerEngine vs PJRT(XLA-native): {}",
            if exact2 { "EXACT ✓" } else { "MISMATCH ✗" }
        );
    }

    // overhead: integer graph vs float QD graph on the same PJRT backend
    let (t_id, _) = bench(2, 1.0, || {
        std::hint::black_box(id_exe.run(&id_args).expect("run"));
    });
    let t_id_xla = id_xla.as_ref().map(|x_exe| {
        bench(2, 1.0, || {
            std::hint::black_box(x_exe.run(&id_args).expect("run"));
        })
        .0
    });
    let qd_exe = rt.load("synthnet_qd_fwd_b8").expect("load qd");
    // qd args: w_hat/kappa_hat/lambda_hat/beta/eps per conv + fc + x
    let mut qd_args: Vec<nemo::runtime::Arg> = Vec::new();
    {
        let mut per_conv: Vec<(Tensor<f32>, Vec<f64>, Vec<f64>)> = Vec::new();
        let mut fc: Option<(Tensor<f32>, Vec<f64>)> = None;
        for n in &dep.qd.nodes {
            match &n.op {
                Op::Conv2d { w, .. } => per_conv.push((w.clone(), vec![], vec![])),
                Op::QuantBn { kappa_hat, lambda_hat } => {
                    let last = per_conv.last_mut().unwrap();
                    last.1 = kappa_hat.clone();
                    last.2 = lambda_hat.clone();
                }
                Op::Linear { w, bias } => {
                    fc = Some((w.clone(), bias.clone().unwrap_or_default()))
                }
                _ => {}
            }
        }
        for (i, (w, k, l)) in per_conv.into_iter().enumerate() {
            qd_args.push(w.into());
            qd_args.push(Tensor::from_f64(&[k.len()], &k).into());
            qd_args.push(Tensor::from_f64(&[l.len()], &l).into());
            let lay = &dep.layers[i];
            qd_args.push(Tensor::scalar(lay.beta_y as f32).into());
            qd_args.push(Tensor::scalar(lay.eps_y as f32).into());
        }
        let (w, b) = fc.unwrap();
        qd_args.push(w.into());
        qd_args.push(Tensor::from_f64(&[b.len()], &b).into());
        qd_args.push(x_grid.clone().into());
    }
    let (t_qd, _) = bench(2, 1.0, || {
        std::hint::black_box(qd_exe.run(&qd_args).expect("run qd"));
    });
    println!(
        "PJRT b=8: Pallas-interpret integer graph {}  float (QD) graph {}  -> {:.2}x",
        fmt_time(t_id),
        fmt_time(t_qd),
        t_id / t_qd
    );
    if let Some(t_x) = t_id_xla {
        println!(
            "PJRT b=8: XLA-native integer graph {}  float (QD) graph {}  -> {:.2}x",
            fmt_time(t_x),
            fmt_time(t_qd),
            t_x / t_qd
        );
    }
    println!("(the paper predicts a small penalty for running ID on non-integer hardware;\n the XLA-native build is the faithful comparison — interpret-mode Pallas adds loop overhead)");
}

// ---------------------------------------------------------------------------
// plan: compiled execution plans vs interpreted graph walking
// ---------------------------------------------------------------------------

fn plan_vs_interpreted() {
    println!("\n=== plan: compiled plans vs per-request graph interpretation ===");
    let mut rng = Rng::new(42);
    let net = SynthNet::init(&mut rng);
    let dep = deploy_pact(net.to_pact_graph(8), DeployOptions::default());
    let ie = IntegerEngine::new();
    let plan = IntPlan::compile(&dep.id).expect("plan");
    println!(
        "  synthnet ID graph: {} nodes -> {} plan steps ({} fused into GEMM epilogues)",
        dep.id.nodes.len(),
        plan.steps().len(),
        plan.fused_nodes()
    );

    let mut results: Vec<Value> = Vec::new();
    for batch in [1usize, 16] {
        let (x, _) = SynthDigits::eval_set(800 + batch as u64, batch);
        let qx = quantize_input(&x, EPS_IN);
        let (t_interp, _) = bench(2, 0.7, || {
            std::hint::black_box(ie.run_interpreted(&dep.id, &qx));
        });
        let layout = plan.layout(batch).expect("layout");
        let mut arena = IntArena::new();
        let (t_plan, _) = bench(2, 0.7, || {
            std::hint::black_box(plan.execute(&layout, &mut arena, &qx));
        });
        // exactness sanity while we are here
        assert_eq!(
            plan.execute(&layout, &mut arena, &qx),
            ie.run_interpreted(&dep.id, &qx),
            "plan diverged from interpreter"
        );
        let speedup = t_interp / t_plan;
        println!(
            "  batch {batch:>2}: interpreted {} ({:>7.0} img/s)  planned {} ({:>7.0} img/s)  -> {speedup:.2}x  [arena {} KiB in {} slots]",
            fmt_time(t_interp),
            batch as f64 / t_interp,
            fmt_time(t_plan),
            batch as f64 / t_plan,
            layout.arena_len() * 4 / 1024,
            layout.arena_slots(),
        );
        results.push(json::obj(vec![
            ("workload", Value::Str("synthnet_id".into())),
            ("batch", Value::Int(batch as i64)),
            ("interpreted_s", Value::Num(t_interp)),
            ("planned_s", Value::Num(t_plan)),
            ("speedup", Value::Num(speedup)),
            ("planned_imgs_per_s", Value::Num(batch as f64 / t_plan)),
            ("arena_slots", Value::Int(layout.arena_slots() as i64)),
            ("arena_bytes", Value::Int((layout.arena_len() * 4) as i64)),
        ]));
    }

    // Steady-state serving path: precompiled executor + pooled arenas.
    let exec = NativeIntExecutor::new(dep.id.clone(), 16).expect("executor");
    let (x, _) = SynthDigits::eval_set(900, 16);
    let input = ExecInput::i32(quantize_input(&x, EPS_IN));
    let (t_exec, _) = bench(2, 0.7, || {
        std::hint::black_box(exec.run_batch(&input).expect("run"));
    });
    println!(
        "  NativeIntExecutor b=16 (precompiled, pooled arenas): {} ({:.0} img/s)",
        fmt_time(t_exec),
        16.0 / t_exec
    );
    results.push(json::obj(vec![
        ("workload", Value::Str("synthnet_id_executor".into())),
        ("batch", Value::Int(16)),
        ("planned_s", Value::Num(t_exec)),
        ("planned_imgs_per_s", Value::Num(16.0 / t_exec)),
    ]));

    // Lazy per-batch layouts: executor construction compiles exactly one
    // variant regardless of max_batch (the rest fill on first use), so
    // construction cost must not scale with max_batch.
    let (t_ctor_1, _) = bench(1, 0.3, || {
        std::hint::black_box(NativeIntExecutor::new(dep.id.clone(), 1).expect("executor"));
    });
    let (t_ctor_256, _) = bench(1, 0.3, || {
        std::hint::black_box(NativeIntExecutor::new(dep.id.clone(), 256).expect("executor"));
    });
    let lazy = NativeIntExecutor::new(dep.id.clone(), 256).expect("executor");
    assert_eq!(
        lazy.compiled_layouts(),
        1,
        "construction compiled more than the batch-1 validator layout"
    );
    assert!(
        t_ctor_256 < t_ctor_1 * 8.0,
        "construction time scales with max_batch again: b=1 {} vs b=256 {}",
        fmt_time(t_ctor_1),
        fmt_time(t_ctor_256)
    );
    println!(
        "  construction (lazy layouts): max_batch=1 {}  max_batch=256 {}  ({:.2}x)",
        fmt_time(t_ctor_1),
        fmt_time(t_ctor_256),
        t_ctor_256 / t_ctor_1
    );
    results.push(json::obj(vec![
        ("workload", Value::Str("executor_construction_lazy_layouts".into())),
        ("ctor_max_batch_1_s", Value::Num(t_ctor_1)),
        ("ctor_max_batch_256_s", Value::Num(t_ctor_256)),
        ("ratio", Value::Num(t_ctor_256 / t_ctor_1)),
    ]));

    let doc = json::obj(vec![("plan_bench", Value::Arr(results))]);
    std::fs::write("BENCH_plan.json", json::write(&doc)).expect("write BENCH_plan.json");
    println!("  wrote BENCH_plan.json");
}

// ---------------------------------------------------------------------------
// packed: precision-packed storage vs full-width i32 (DESIGN.md
// §Precision propagation) — writes BENCH_packed.json
// ---------------------------------------------------------------------------

fn packed_vs_i32() {
    println!("\n=== packed: u8/i8 packed storage vs i32 full width ===");
    let mut rng = Rng::new(77);
    let mut results: Vec<Value> = Vec::new();

    // GEMM hot path: u8 activations x i8 weights -> i32 accumulate vs the
    // i32 x i32 baseline on identical values (bit-identical outputs; the
    // packed A/B operands stream at 1/4 the bytes).
    for (m, k, n) in [(2048usize, 144usize, 32usize), (256, 256, 256)] {
        let a32: Vec<i32> = (0..m * k).map(|_| rng.int(0, 256) as i32).collect();
        let b32: Vec<i32> = (0..k * n).map(|_| rng.int(-128, 128) as i32).collect();
        let a8: Vec<u8> = a32.iter().map(|v| *v as u8).collect();
        let b8: Vec<i8> = b32.iter().map(|v| *v as i8).collect();
        let mut out_i = vec![0i32; m * n];
        let mut out_q = vec![0i32; m * n];
        let (t_i32, _) = bench(2, 0.5, || {
            ops::matmul_i32_into(&a32, &b32, m, k, n, &mut out_i);
            std::hint::black_box(&out_i);
        });
        let (t_q, _) = bench(2, 0.5, || {
            ops::matmul_q_fused_into(&a8, &b8, m, k, n, &|_, v| v, &mut out_q);
            std::hint::black_box(&out_q);
        });
        assert_eq!(out_i, out_q, "packed GEMM diverged from i32 baseline");
        let flops = 2.0 * (m * k * n) as f64;
        println!(
            "  gemm {m}x{k}x{n}: i32 {} ({:.2} Gop/s)  u8xi8 {} ({:.2} Gop/s)  -> {:.2}x",
            fmt_time(t_i32),
            flops / t_i32 / 1e9,
            fmt_time(t_q),
            flops / t_q / 1e9,
            t_i32 / t_q
        );
        results.push(json::obj(vec![
            ("workload", Value::Str(format!("gemm_{m}x{k}x{n}"))),
            ("i32_s", Value::Num(t_i32)),
            ("packed_s", Value::Num(t_q)),
            ("speedup", Value::Num(t_i32 / t_q)),
        ]));
    }

    // End-to-end: deployed synthnet, i32 plan vs packed plan, plus the
    // packed serving executor.
    let net = SynthNet::init(&mut rng);
    let dep = deploy_pact(net.to_pact_graph(8), DeployOptions::default());
    let plan = IntPlan::compile(&dep.id).expect("plan");
    println!(
        "  synthnet ID: packed steps over {} plan steps (input {})",
        plan.steps().len(),
        plan.input_precision().name()
    );
    for batch in [1usize, 16] {
        let (x, _) = SynthDigits::eval_set(770 + batch as u64, batch);
        let qx = quantize_input(&x, EPS_IN);
        let wide = plan.layout(batch).expect("layout");
        let packed = plan.packed_layout(batch).expect("packed layout");
        let mut arena = IntArena::new();
        let mut parena = PackedArena::new();
        let (t_wide, _) = bench(2, 0.7, || {
            std::hint::black_box(plan.execute(&wide, &mut arena, &qx));
        });
        let (t_packed, _) = bench(2, 0.7, || {
            std::hint::black_box(plan.execute_packed(&packed, &mut parena, &qx));
        });
        assert_eq!(
            plan.execute(&wide, &mut arena, &qx),
            plan.execute_packed(&packed, &mut parena, &qx),
            "packed plan diverged"
        );
        let speedup = t_wide / t_packed;
        println!(
            "  batch {batch:>2}: i32 {} ({:>7.0} img/s)  packed {} ({:>7.0} img/s)  -> {speedup:.2}x  [arena {} KiB -> {} KiB]",
            fmt_time(t_wide),
            batch as f64 / t_wide,
            fmt_time(t_packed),
            batch as f64 / t_packed,
            wide.arena_bytes() / 1024,
            packed.arena_bytes() / 1024,
        );
        results.push(json::obj(vec![
            ("workload", Value::Str("synthnet_id_e2e".into())),
            ("batch", Value::Int(batch as i64)),
            ("i32_s", Value::Num(t_wide)),
            ("packed_s", Value::Num(t_packed)),
            ("speedup", Value::Num(speedup)),
            ("packed_imgs_per_s", Value::Num(batch as f64 / t_packed)),
            ("i32_arena_bytes", Value::Int(wide.arena_bytes() as i64)),
            ("packed_arena_bytes", Value::Int(packed.arena_bytes() as i64)),
        ]));
    }

    // Packed serving: the executor compiles the packed path end-to-end.
    let exec = NativeIntExecutor::new(dep.id.clone(), 16).expect("executor");
    assert!(exec.packed(), "deployed synthnet must serve packed");
    let (x, _) = SynthDigits::eval_set(771, 16);
    let input = ExecInput::i32(quantize_input(&x, EPS_IN));
    let (t_exec, _) = bench(2, 0.7, || {
        std::hint::black_box(exec.run_batch(&input).expect("run"));
    });
    println!(
        "  NativeIntExecutor b=16 (packed serving): {} ({:.0} img/s)",
        fmt_time(t_exec),
        16.0 / t_exec
    );
    results.push(json::obj(vec![
        ("workload", Value::Str("synthnet_id_executor_packed".into())),
        ("batch", Value::Int(16)),
        ("packed_s", Value::Num(t_exec)),
        ("packed_imgs_per_s", Value::Num(16.0 / t_exec)),
    ]));

    let doc = json::obj(vec![("packed_bench", Value::Arr(results))]);
    std::fs::write("BENCH_packed.json", json::write(&doc))
        .expect("write BENCH_packed.json");
    println!("  wrote BENCH_packed.json");
}

// ---------------------------------------------------------------------------
// subbyte: bit-packed few-bit grids — bit-serial / nibble GEMM vs the byte
// kernel, plus e2e packed plans at Q in {1, 2, 4, 8} (DESIGN.md §Sub-byte
// packing) — writes BENCH_subbyte.json
// ---------------------------------------------------------------------------

/// Bit-packed vs one-byte-per-element footprint of every Conv/Linear
/// weight section in the graph (what the artifact ships under §Sub-byte
/// packing vs the byte-class baseline).
fn weight_section_bytes(g: &IntGraph) -> (usize, usize) {
    let (mut packed, mut byte) = (0usize, 0usize);
    for node in &g.nodes {
        let wq = match &node.op {
            IntOp::ConvInt { wq, .. } | IntOp::LinearInt { wq, .. } => wq,
            _ => continue,
        };
        let (lo, hi) = wq.min_max();
        let len = wq.len();
        packed += Precision::for_range(lo, hi).storage_bytes(len);
        byte += len;
    }
    (packed, byte)
}

fn subbyte_bench() {
    println!("\n=== subbyte: bit-packed grids — bit-serial/nibble GEMM vs byte kernels ===");
    let mut rng = Rng::new(4242);
    let mut results: Vec<Value> = Vec::new();

    // GEMM hot path: Q-bit activations x 2-bit weights. The baseline is
    // the byte kernel (u8 x i8 -> i32) on identical values; at Q <= 2 the
    // same GEMM runs bit-serial over AND+popcount bit-planes, at Q = 4 it
    // runs the nibble-unpacking row-block kernel. Outputs must match the
    // byte kernel bit for bit.
    let (m, k, n) = (256usize, 1024usize, 128usize);
    for q in [1u32, 2, 4, 8] {
        let hi = (1i64 << q) - 1;
        let prec = Precision::for_range(0, hi);
        let a32: Vec<i32> = (0..m * k).map(|_| rng.int(0, hi + 1) as i32).collect();
        let w32: Vec<i32> = (0..k * n).map(|_| rng.int(-2, 2) as i32).collect();
        let a8: Vec<u8> = a32.iter().map(|v| *v as u8).collect();
        let w8: Vec<i8> = w32.iter().map(|v| *v as i8).collect();
        let mut out = vec![0i32; m * n];
        let (t_byte, _) = bench(2, 0.5, || {
            ops::matmul_q_fused_into(&a8, &w8, m, k, n, &|_, v| v, &mut out);
            std::hint::black_box(&out);
        });

        let mut out_sub = vec![0i32; m * n];
        let (kernel, t_sub, act_bytes, w_bytes) = if prec.is_sub_byte() {
            let mut ap = vec![0u8; prec.storage_bytes(m * k)];
            for (i, &v) in a32.iter().enumerate() {
                set_packed(&mut ap, i, prec, v);
            }
            if q <= 2 {
                let planes = ops::BitPlanes::build(&Tensor::from_vec(&[k, n], w32))
                    .expect("2-bit weights fit bit planes");
                let (t, _) = bench(2, 0.5, || {
                    ops::matmul_bitserial_fused_into(
                        &ap,
                        prec,
                        m,
                        &planes,
                        &|_, v| v,
                        &mut out_sub,
                    );
                    std::hint::black_box(&out_sub);
                });
                ("bitserial", t, ap.len(), planes.bytes())
            } else {
                let (t, _) = bench(2, 0.5, || {
                    ops::matmul_subbyte_fused_into(
                        &ap,
                        prec,
                        &w8,
                        m,
                        k,
                        n,
                        &|_, v| v,
                        &mut out_sub,
                    );
                    std::hint::black_box(&out_sub);
                });
                ("nibble", t, ap.len(), w8.len())
            }
        } else {
            out_sub.copy_from_slice(&out);
            ("byte", t_byte, a8.len(), w8.len())
        };
        assert_eq!(out, out_sub, "sub-byte GEMM diverged from the byte kernel at Q={q}");
        let flops = 2.0 * (m * k * n) as f64;
        println!(
            "  gemm {m}x{k}x{n} Q={q}: byte {} ({:.2} Gop/s)  {kernel} {} ({:.2} Gop/s)  -> {:.2}x  [A {} B -> {} B]",
            fmt_time(t_byte),
            flops / t_byte / 1e9,
            fmt_time(t_sub),
            flops / t_sub / 1e9,
            t_byte / t_sub,
            m * k,
            act_bytes,
        );
        results.push(json::obj(vec![
            ("workload", Value::Str(format!("gemm_{m}x{k}x{n}"))),
            ("abits", Value::Int(q as i64)),
            ("kernel", Value::Str(kernel.into())),
            ("byte_s", Value::Num(t_byte)),
            ("sub_s", Value::Num(t_sub)),
            ("speedup", Value::Num(t_byte / t_sub)),
            ("act_bytes_byte", Value::Int((m * k) as i64)),
            ("act_bytes_packed", Value::Int(act_bytes as i64)),
            ("act_reduction", Value::Num((m * k) as f64 / act_bytes as f64)),
            ("weight_bytes_byte", Value::Int((k * n) as i64)),
            ("weight_bytes_packed", Value::Int(w_bytes as i64)),
        ]));
    }

    // Deterministic storage ledger: packed bytes per 4096 weights at
    // each sub-byte class vs the byte classes' 1 B/elem.
    for p in [Precision::U1, Precision::U2, Precision::U4, Precision::I4] {
        let elems = 4096usize;
        let packed = p.storage_bytes(elems);
        println!(
            "  storage {}: {packed} B per {elems} elems ({}x vs 1 B/elem)",
            p.name(),
            elems / packed
        );
        results.push(json::obj(vec![
            ("workload", Value::Str("weight_storage".into())),
            ("dtype", Value::Str(p.name().into())),
            ("elems", Value::Int(elems as i64)),
            ("bytes_packed", Value::Int(packed as i64)),
            ("bytes_byte", Value::Int(elems as i64)),
            ("reduction", Value::Num(elems as f64 / packed as f64)),
        ]));
    }

    // End-to-end: synthnet deployed at a Q-bit activation grid (4-bit
    // weights below Q=8 so the few-bit kernels engage), wide i32 plan vs
    // the sub-byte packed plan, bit-identical by assertion.
    let net = SynthNet::init(&mut rng);
    let batch = 16usize;
    for q in [1u32, 2, 4, 8] {
        let wbits = if q < 8 { 4 } else { 8 };
        let opts = DeployOptions { wbits, abits: q, ..DeployOptions::default() };
        let dep = match Network::<FakeQuantized>::from_pact_graph(net.to_pact_graph(q))
            .expect("pact graph")
            .deploy(opts)
        {
            Ok(d) => d.integerize().into_deployed(),
            Err(e) => {
                println!("  e2e Q={q}: deploy skipped ({e})");
                continue;
            }
        };
        let plan = IntPlan::compile(&dep.id).expect("plan");
        let (x, _) = SynthDigits::eval_set(4200 + q as u64, batch);
        let qx = quantize_input(&x, EPS_IN);
        let wide = plan.layout(batch).expect("layout");
        let packed = plan.packed_layout(batch).expect("packed layout");
        let mut arena = IntArena::new();
        let mut parena = PackedArena::new();
        let (t_wide, _) = bench(2, 0.7, || {
            std::hint::black_box(plan.execute(&wide, &mut arena, &qx));
        });
        let (t_packed, _) = bench(2, 0.7, || {
            std::hint::black_box(plan.execute_packed(&packed, &mut parena, &qx));
        });
        assert_eq!(
            plan.execute(&wide, &mut arena, &qx),
            plan.execute_packed(&packed, &mut parena, &qx),
            "sub-byte packed plan diverged at Q={q}"
        );
        let (w_sub, w_byte) = weight_section_bytes(&dep.id);
        println!(
            "  e2e Q={q} (w{wbits}): i32 {} ({:>6.0} img/s)  packed {} ({:>6.0} img/s)  -> {:.2}x  [{} bit-serial steps, arena {} -> {} B, weights {} -> {} B]",
            fmt_time(t_wide),
            batch as f64 / t_wide,
            fmt_time(t_packed),
            batch as f64 / t_packed,
            t_wide / t_packed,
            plan.bitserial_steps(),
            wide.arena_bytes(),
            packed.arena_bytes(),
            w_byte,
            w_sub,
        );
        results.push(json::obj(vec![
            ("workload", Value::Str("synthnet_id_e2e".into())),
            ("batch", Value::Int(batch as i64)),
            ("abits", Value::Int(q as i64)),
            ("wbits", Value::Int(wbits as i64)),
            ("bitserial_steps", Value::Int(plan.bitserial_steps() as i64)),
            ("i32_s", Value::Num(t_wide)),
            ("packed_s", Value::Num(t_packed)),
            ("speedup", Value::Num(t_wide / t_packed)),
            ("i32_arena_bytes", Value::Int(wide.arena_bytes() as i64)),
            ("packed_arena_bytes", Value::Int(packed.arena_bytes() as i64)),
            ("weight_bytes_byte", Value::Int(w_byte as i64)),
            ("weight_bytes_packed", Value::Int(w_sub as i64)),
            ("weight_reduction", Value::Num(w_byte as f64 / w_sub as f64)),
        ]));
    }

    let doc = json::obj(vec![("subbyte_bench", Value::Arr(results))]);
    std::fs::write("BENCH_subbyte.json", json::write(&doc))
        .expect("write BENCH_subbyte.json");
    println!("  wrote BENCH_subbyte.json");
}

// ---------------------------------------------------------------------------
// artifact: native deployment artifacts — cold-load latency and
// serve-from-artifact throughput (DESIGN.md §Artifact-format) — writes
// BENCH_artifact.json
// ---------------------------------------------------------------------------

fn artifact_cold_load_and_serve() {
    println!("\n=== artifact: deploy-once/serve-anywhere cold start & throughput ===");
    let mut rng = Rng::new(88);
    let net = SynthNet::init(&mut rng);
    let nid = Network::<FakeQuantized>::from_pact_graph(net.to_pact_graph(8))
        .expect("pact graph")
        .deploy(DeployOptions::default())
        .expect("deploy")
        .integerize();
    let path = std::env::temp_dir()
        .join(format!("bench_artifact_{}.nemo.json", std::process::id()));

    let (t_save, _) = bench(1, 0.3, || {
        nid.save_deployed(&path).expect("save");
    });
    let bytes = std::fs::metadata(&path).map(|m| m.len()).unwrap_or(0);

    // Cold load: file -> checksum -> precision re-proof -> compiled
    // packed plans, i.e. the full `nemo serve --model` startup cost.
    let max_batch = 16usize;
    let (t_load, _) = bench(1, 0.5, || {
        std::hint::black_box(
            NativeIntExecutor::from_artifact(&path, max_batch).expect("from_artifact"),
        );
    });
    println!(
        "  synthnet artifact: {bytes} bytes  save {}  cold load->executor {}",
        fmt_time(t_save),
        fmt_time(t_load)
    );

    // Binary v3 container: same model, 64-byte-aligned sections, weights
    // mapped as zero-copy views (DESIGN.md §Artifact-format).
    let bin_path = std::env::temp_dir()
        .join(format!("bench_artifact_{}.nemob", std::process::id()));
    let (t_save_bin, _) = bench(1, 0.3, || {
        nid.save_deployed_bin(&bin_path).expect("save bin");
    });
    let bin_bytes = std::fs::metadata(&bin_path).map(|m| m.len()).unwrap_or(0);
    let (t_load_bin, _) = bench(1, 0.5, || {
        std::hint::black_box(
            NativeIntExecutor::from_artifact(&bin_path, max_batch)
                .expect("bin from_artifact"),
        );
    });
    // Artifact decode alone (no plan compilation), per load path: the
    // JSON parse/narrow pipeline vs the mmap view construction vs the
    // aligned-read fallback.
    let (t_art_json, _) = bench(1, 0.5, || {
        std::hint::black_box(DeployedArtifact::load(&path).expect("json load"));
    });
    let (t_art_mmap, _) = bench(1, 0.5, || {
        std::hint::black_box(
            DeployedArtifact::load_binary(&bin_path, BinLoadMode::Auto)
                .expect("mmap load"),
        );
    });
    let (t_art_read, _) = bench(1, 0.5, || {
        std::hint::black_box(
            DeployedArtifact::load_binary(&bin_path, BinLoadMode::Read)
                .expect("read load"),
        );
    });
    let (_, _, stats) = DeployedArtifact::load_binary(&bin_path, BinLoadMode::Auto)
        .expect("stats load");
    let binfo = binary_info(&bin_path).expect("binary info");
    println!(
        "  binary artifact: {bin_bytes} bytes ({:.2}x smaller)  save {}  \
         cold load->executor {} ({:.1}x vs JSON)",
        bytes as f64 / bin_bytes as f64,
        fmt_time(t_save_bin),
        fmt_time(t_load_bin),
        t_load / t_load_bin,
    );
    println!(
        "  artifact decode: json {}  mmap {} ({:.1}x)  read {} ({:.1}x)  \
         [{} sections, {} B weights ({} B aligned), borrowed {} B, copied {} B, mmap = {}]",
        fmt_time(t_art_json),
        fmt_time(t_art_mmap),
        t_art_json / t_art_mmap,
        fmt_time(t_art_read),
        t_art_json / t_art_read,
        binfo.sections.len(),
        binfo.weight_bytes,
        binfo.aligned_weight_bytes,
        stats.borrowed_bytes,
        stats.copied_bytes,
        stats.mmap,
    );

    // Serve-from-artifact throughput, direct executor path.
    let exec = NativeIntExecutor::from_artifact(&path, max_batch).expect("from_artifact");
    let (x, _) = SynthDigits::eval_set(880, max_batch);
    let input = ExecInput::i32(quantize_input(&x, EPS_IN));
    let (t_exec, _) = bench(2, 0.7, || {
        std::hint::black_box(exec.run_batch(&input).expect("run"));
    });
    println!(
        "  serve-from-artifact b={max_batch}: {} ({:.0} img/s, packed = {})",
        fmt_time(t_exec),
        max_batch as f64 / t_exec,
        exec.packed()
    );

    // Coordinator throughput over the artifact-backed executor (routed
    // through the registry's own artifact loader, as `serve --model` is).
    let server = Server::builder()
        .default_config(ServerConfig {
            max_batch,
            batch_timeout: Duration::from_micros(300),
            n_workers: 2,
        })
        .model("synthnet", Arc::new(exec))
        .start()
        .expect("server");
    let n_requests = 2048usize;
    let clients = 8usize;
    let t0 = std::time::Instant::now();
    let mut joins = Vec::new();
    for c in 0..clients {
        let h = server.handle();
        let per = n_requests / clients;
        joins.push(std::thread::spawn(move || {
            let mut data = SynthDigits::new(881 + c as u64);
            for _ in 0..per {
                let (x, _) = data.batch(1);
                let qx = quantize_input(&x, EPS_IN);
                h.infer("synthnet", qx).expect("infer");
            }
        }));
    }
    for j in joins {
        j.join().unwrap();
    }
    let wall = t0.elapsed().as_secs_f64();
    let mut m = server.stop();
    println!(
        "  coordinator ({clients} clients): {:.0} req/s  p50 {:.3} ms  p99 {:.3} ms",
        m.throughput(wall),
        m.e2e_latency.percentile(0.50) * 1e3,
        m.e2e_latency.percentile(0.99) * 1e3,
    );

    let doc = json::obj(vec![(
        "artifact_bench",
        json::obj(vec![
            ("file_bytes", Value::Int(bytes as i64)),
            ("save_s", Value::Num(t_save)),
            ("cold_load_s", Value::Num(t_load)),
            ("exec_batch_s", Value::Num(t_exec)),
            ("exec_imgs_per_s", Value::Num(max_batch as f64 / t_exec)),
            ("serve_req_per_s", Value::Num(m.throughput(wall))),
            ("serve_p99_ms", Value::Num(m.e2e_latency.percentile(0.99) * 1e3)),
            ("bin_file_bytes", Value::Int(bin_bytes as i64)),
            ("bin_save_s", Value::Num(t_save_bin)),
            ("bin_cold_load_s", Value::Num(t_load_bin)),
            ("bin_cold_load_speedup", Value::Num(t_load / t_load_bin)),
            ("art_decode_json_s", Value::Num(t_art_json)),
            ("art_decode_mmap_s", Value::Num(t_art_mmap)),
            ("art_decode_read_s", Value::Num(t_art_read)),
            ("art_decode_mmap_speedup", Value::Num(t_art_json / t_art_mmap)),
            ("bin_sections", Value::Int(binfo.sections.len() as i64)),
            ("bin_weight_bytes", Value::Int(binfo.weight_bytes as i64)),
            (
                "bin_aligned_weight_bytes",
                Value::Int(binfo.aligned_weight_bytes as i64),
            ),
            (
                "bin_alignment_overhead",
                Value::Num(binfo.aligned_weight_bytes as f64 / binfo.weight_bytes as f64),
            ),
            ("bin_borrowed_bytes", Value::Int(stats.borrowed_bytes as i64)),
            ("bin_copied_bytes", Value::Int(stats.copied_bytes as i64)),
            ("bin_mmap", Value::Bool(stats.mmap)),
        ]),
    )]);
    std::fs::write("BENCH_artifact.json", json::write(&doc))
        .expect("write BENCH_artifact.json");
    println!("  wrote BENCH_artifact.json");
    let _ = std::fs::remove_file(&path);
    let _ = std::fs::remove_file(&bin_path);
}

// ---------------------------------------------------------------------------
// registry: multi-model serving throughput + hot-swap latency — writes
// BENCH_registry.json
// ---------------------------------------------------------------------------

fn registry_multi_model_and_swap() {
    println!("\n=== registry: two models by name + hot swaps under load ===");
    let mut rng = Rng::new(123);
    let net_a = SynthNet::init(&mut rng);
    let net_b = SynthNet::init(&mut rng);
    let dep_a = deploy_pact(net_a.to_pact_graph(8), DeployOptions::default());
    let dep_b = deploy_pact(net_b.to_pact_graph(8), DeployOptions::default());
    let max_batch = 16usize;

    let server = Server::builder()
        .default_config(ServerConfig {
            max_batch,
            batch_timeout: Duration::from_micros(300),
            n_workers: 2,
        })
        .model(
            "a",
            Arc::new(NativeIntExecutor::new(dep_a.id.clone(), max_batch).expect("exec a")),
        )
        .model(
            "b",
            Arc::new(NativeIntExecutor::new(dep_b.id.clone(), max_batch).expect("exec b")),
        )
        .start()
        .expect("server");
    let h = server.handle();

    // Prebuilt swap targets so the measured latency is the registry's
    // swap operation, not executor construction.
    let swap_targets: [Arc<dyn Executor>; 2] = [
        Arc::new(NativeIntExecutor::new(dep_b.id.clone(), max_batch).expect("swap b")),
        Arc::new(NativeIntExecutor::new(dep_a.id.clone(), max_batch).expect("swap a")),
    ];

    let n_requests = 2048usize;
    let clients = 8usize;
    let t0 = std::time::Instant::now();
    let mut joins = Vec::new();
    for c in 0..clients {
        let h = server.handle();
        let model = if c % 2 == 0 { "a" } else { "b" };
        let per = n_requests / clients;
        joins.push(std::thread::spawn(move || {
            let mut data = SynthDigits::new(4500 + c as u64);
            for _ in 0..per {
                let (x, _) = data.batch(1);
                h.infer(model, quantize_input(&x, EPS_IN)).expect("infer");
            }
        }));
    }

    // Hot-swap "a" back and forth while the load test runs.
    let n_swaps = 8usize;
    let mut swap_lat = Vec::with_capacity(n_swaps);
    for i in 0..n_swaps {
        std::thread::sleep(Duration::from_millis(3));
        let t = std::time::Instant::now();
        h.swap_model("a", swap_targets[i % 2].clone()).expect("swap");
        swap_lat.push(t.elapsed().as_secs_f64());
    }

    for j in joins {
        j.join().unwrap();
    }
    let wall = t0.elapsed().as_secs_f64();
    // stop() joins the workers, making the per-model ledgers final —
    // workers record metrics after scattering replies, so reading the
    // exact counts before the join would race the last batch.
    let total = server.stop();
    let ma = h.model_metrics("a").expect("metrics a");
    let mb = h.model_metrics("b").expect("metrics b");
    assert_eq!(total.failed, 0, "hot swaps must not fail any request");
    assert_eq!(
        ma.completed + mb.completed,
        n_requests as u64,
        "per-model ledgers must account for every request across swaps"
    );

    let swap_mean = swap_lat.iter().sum::<f64>() / swap_lat.len() as f64;
    let swap_max = swap_lat.iter().cloned().fold(0.0f64, f64::max);
    println!(
        "  {n_requests} req over 2 models, {clients} clients, {n_swaps} hot swaps: \
         {:.0} req/s  (a: {}, b: {})",
        total.throughput(wall),
        ma.completed,
        mb.completed
    );
    println!(
        "  swap latency: mean {}  max {}  (version v{} after {n_swaps} swaps)",
        fmt_time(swap_mean),
        fmt_time(swap_max),
        1 + n_swaps
    );

    let doc = json::obj(vec![(
        "registry_bench",
        json::obj(vec![
            ("n_requests", Value::Int(n_requests as i64)),
            ("n_models", Value::Int(2)),
            ("n_swaps", Value::Int(n_swaps as i64)),
            ("two_model_req_per_s", Value::Num(total.throughput(wall))),
            ("model_a_completed", Value::Int(ma.completed as i64)),
            ("model_b_completed", Value::Int(mb.completed as i64)),
            ("swap_latency_mean_s", Value::Num(swap_mean)),
            ("swap_latency_max_s", Value::Num(swap_max)),
        ]),
    )]);
    std::fs::write("BENCH_registry.json", json::write(&doc))
        .expect("write BENCH_registry.json");
    println!("  wrote BENCH_registry.json");
}

/// Wire-protocol overhead: loopback request latency (p50/p99) and
/// throughput vs in-process `ServerHandle::infer` against the same
/// coordinator, on a single connection and a pipelined one.
fn net_loopback() {
    use nemo::net::{NemoClient, NetConfig, NetServer};
    use nemo::util::stats::Samples;

    println!("\n=== net: loopback wire protocol vs in-process infer ===");
    let mut rng = Rng::new(321);
    let net = SynthNet::init(&mut rng);
    let dep = deploy_pact(net.to_pact_graph(8), DeployOptions::default());
    let max_batch = 16usize;
    let server = Server::builder()
        .default_config(ServerConfig {
            max_batch,
            batch_timeout: Duration::from_micros(300),
            n_workers: 2,
        })
        .model(
            "m",
            Arc::new(NativeIntExecutor::new(dep.id.clone(), max_batch).expect("exec")),
        )
        .start()
        .expect("server");
    let h = server.handle();
    let ns = NetServer::bind("127.0.0.1:0", server.handle(), NetConfig::default())
        .expect("bind");
    let mut client = NemoClient::connect(ns.local_addr()).expect("connect");

    let mut data = SynthDigits::new(6100);
    let inputs: Vec<TensorI> = (0..256)
        .map(|_| {
            let (x, _) = data.batch(1);
            quantize_input(&x, EPS_IN)
        })
        .collect();

    // In-process baseline on the same coordinator.
    let n = inputs.len();
    let t0 = std::time::Instant::now();
    let mut local_lat = Samples::new();
    for qx in &inputs {
        let t = std::time::Instant::now();
        h.infer("m", qx.clone()).expect("local infer");
        local_lat.push(t.elapsed().as_secs_f64());
    }
    let local_wall = t0.elapsed().as_secs_f64();

    // Remote, one request per round-trip.
    let t0 = std::time::Instant::now();
    let mut remote_lat = Samples::new();
    for qx in &inputs {
        let t = std::time::Instant::now();
        client.infer("m", qx).expect("remote infer");
        remote_lat.push(t.elapsed().as_secs_f64());
    }
    let remote_wall = t0.elapsed().as_secs_f64();

    // Remote, pipelined in windows of 32 frames per flush.
    let window = 32usize;
    let t0 = std::time::Instant::now();
    for chunk in inputs.chunks(window) {
        let outs = client.infer_pipelined("m", chunk).expect("pipelined infer");
        assert_eq!(outs.len(), chunk.len());
    }
    let pipelined_wall = t0.elapsed().as_secs_f64();

    let local_p50 = local_lat.percentile(0.5);
    let remote_p50 = remote_lat.percentile(0.5);
    let remote_p99 = remote_lat.percentile(0.99);
    println!(
        "  in-process : {:>8.0} req/s  p50 {}  p99 {}",
        n as f64 / local_wall,
        fmt_time(local_p50),
        fmt_time(local_lat.percentile(0.99))
    );
    println!(
        "  remote     : {:>8.0} req/s  p50 {}  p99 {}  (wire overhead p50 {})",
        n as f64 / remote_wall,
        fmt_time(remote_p50),
        fmt_time(remote_p99),
        fmt_time((remote_p50 - local_p50).max(0.0))
    );
    println!(
        "  pipelined  : {:>8.0} req/s  ({} frames per flush)",
        n as f64 / pipelined_wall,
        window
    );

    ns.stop();
    let total = server.stop();
    assert_eq!(total.failed, 0, "the bench must not fail any request");

    let doc = json::obj(vec![(
        "net_bench",
        json::obj(vec![
            ("n_requests", Value::Int(n as i64)),
            ("pipeline_window", Value::Int(window as i64)),
            ("inprocess_req_per_s", Value::Num(n as f64 / local_wall)),
            ("inprocess_p50_s", Value::Num(local_p50)),
            ("remote_req_per_s", Value::Num(n as f64 / remote_wall)),
            ("remote_p50_s", Value::Num(remote_p50)),
            ("remote_p99_s", Value::Num(remote_p99)),
            ("pipelined_req_per_s", Value::Num(n as f64 / pipelined_wall)),
            ("wire_overhead_p50_s", Value::Num((remote_p50 - local_p50).max(0.0))),
        ]),
    )]);
    std::fs::write("BENCH_net.json", json::write(&doc)).expect("write BENCH_net.json");
    println!("  wrote BENCH_net.json");
}

// ---------------------------------------------------------------------------
// train: native backward-plan training (DESIGN.md §Training) — writes
// BENCH_train.json (steps/sec + peak shared-arena bytes)
// ---------------------------------------------------------------------------

fn train_native_bench() {
    use nemo::engine::{BackwardPlan, FloatPlan};
    use nemo::train::native::{train_fp, train_fq, OptState};
    use nemo::train::TrainConfig;

    println!("\n=== train: native backward-plan training ===");
    let mut results = Vec::new();
    for (tag, fq) in [("fp", false), ("fq_w8a8", true)] {
        let mut rng = Rng::new(70);
        let mut net = SynthNet::init(&mut rng);
        let mut data = SynthDigits::new(70);
        let mut opt = OptState::default();
        let steps = 40usize;
        let cfg = TrainConfig {
            steps,
            lr: 0.05,
            lr_decay: false,
            seed: 70,
            log_every: 0,
            batch: 32,
            ..TrainConfig::default()
        };
        let t0 = std::time::Instant::now();
        let rep = if fq {
            net.act_betas = vec![4.0, 4.0, 4.0];
            train_fq(&mut net, &mut data, 8, 8, &cfg, &mut opt).expect("fq train")
        } else {
            train_fp(&mut net, &mut data, &cfg, &mut opt).expect("fp train")
        };
        let secs = t0.elapsed().as_secs_f64();
        let sps = steps as f64 / secs;

        // Peak shared-arena footprint: forward and backward layouts run
        // over one FloatArena whose slots grow to the per-slot max.
        let g = if fq { net.to_pact_graph(8) } else { net.to_fp_graph() };
        let flayout =
            FloatPlan::compile_unfused(&g).unwrap().layout(cfg.batch).unwrap();
        let bwd = BackwardPlan::compile(&g).unwrap();
        let blayout = bwd.layout(&g, cfg.batch).unwrap();
        let n_slots = flayout.slot_lens.len().max(blayout.slot_lens.len());
        let peak_bytes: usize = (0..n_slots)
            .map(|i| {
                let f = flayout.slot_lens.get(i).copied().unwrap_or(0);
                let b = blayout.slot_lens.get(i).copied().unwrap_or(0);
                f.max(b) * 4
            })
            .sum();
        println!(
            "  {tag}: {steps} steps x b{} in {}  ({sps:.1} steps/s, {:.0} img/s)  [fwd arena {} KiB, bwd {} KiB, shared peak {} KiB]",
            cfg.batch,
            fmt_time(secs),
            sps * cfg.batch as f64,
            flayout.arena_bytes() / 1024,
            blayout.arena_bytes() / 1024,
            peak_bytes / 1024,
        );
        results.push(json::obj(vec![
            ("workload", Value::Str(format!("synthnet_train_{tag}"))),
            ("batch", Value::Int(cfg.batch as i64)),
            ("steps", Value::Int(steps as i64)),
            ("steps_per_s", Value::Num(sps)),
            ("imgs_per_s", Value::Num(sps * cfg.batch as f64)),
            ("final_loss", Value::Num(rep.final_loss())),
            ("fwd_arena_bytes", Value::Int(flayout.arena_bytes() as i64)),
            ("bwd_arena_bytes", Value::Int(blayout.arena_bytes() as i64)),
            ("peak_arena_bytes", Value::Int(peak_bytes as i64)),
        ]));
    }
    let doc = json::obj(vec![("train_bench", Value::Arr(results))]);
    std::fs::write("BENCH_train.json", json::write(&doc)).expect("write BENCH_train.json");
    println!("  wrote BENCH_train.json");
}

// ---------------------------------------------------------------------------
// perf: micro-benchmarks for the optimization pass (§Perf)
// ---------------------------------------------------------------------------

fn perf_microbench() {
    println!("\n=== perf: hot-path micro-benchmarks ===");
    let mut rng = Rng::new(99);
    // integer GEMM (the engine hot path)
    for (m, k, n) in [(256usize, 72usize, 16usize), (2048, 144, 32), (256, 256, 256)] {
        let a = Tensor::from_vec(&[m, k], (0..m * k).map(|_| rng.int(0, 256) as i32).collect());
        let b = Tensor::from_vec(&[k, n], (0..k * n).map(|_| rng.int(-128, 128) as i32).collect());
        let (t, _) = bench(2, 0.5, || {
            std::hint::black_box(ops::matmul_i32(&a, &b));
        });
        let (tf, _) = bench(2, 0.5, || {
            std::hint::black_box(ops::matmul_i32_fast(&a, &b));
        });
        let flops = 2.0 * (m * k * n) as f64;
        println!(
            "  matmul_i32 {m}x{k}x{n}: checked {} ({:.2} Gop/s)  fast/threaded {} ({:.2} Gop/s)",
            fmt_time(t),
            flops / t / 1e9,
            fmt_time(tf),
            flops / tf / 1e9
        );
    }
    // im2col
    let x: TensorI = Tensor::from_vec(
        &[16, 8, 16, 16],
        (0..16 * 8 * 256).map(|_| rng.int(0, 256) as i32).collect(),
    );
    let (t, _) = bench(2, 0.5, || {
        std::hint::black_box(ops::im2col(&x, 3, 3, 1, 1));
    });
    println!("  im2col 16x8x16x16 k3: {}", fmt_time(t));
    // im2col into a reused arena buffer (the plan path)
    let mut buf = vec![0i32; 16 * 16 * 16 * 8 * 9];
    let (t, _) = bench(2, 0.5, || {
        std::hint::black_box(ops::im2col_into(
            x.data(),
            16,
            8,
            16,
            16,
            3,
            3,
            1,
            1,
            &mut buf,
        ));
    });
    println!("  im2col_into (arena reuse): {}", fmt_time(t));
    // requant
    let q: TensorI = Tensor::from_vec(&[1 << 16], (0..1 << 16).map(|_| rng.int(-(1 << 24), 1 << 24) as i32).collect());
    let rq = Requant { m: 29, d: 21, lo: 0, hi: 255 };
    let (t, _) = bench(2, 0.5, || {
        std::hint::black_box(rq.apply_tensor(&q));
    });
    println!("  requant 64k: {}  ({:.0} Mel/s)", fmt_time(t), (1 << 16) as f64 / t / 1e6);
}

#[cfg(feature = "pjrt")]
fn perf_pjrt_kernels(rt: Option<&Runtime>) {
    let Some(rt) = rt else { return };
    for name in ["kernel_qgemm_256", "kernel_requant_64k", "kernel_intbn_4096x64",
                 "kernel_thresh_4096x32", "kernel_avgpool_8x32"] {
        let exe = rt.load(name).expect("load");
        let args: Vec<nemo::runtime::Arg> = exe
            .spec
            .args
            .iter()
            .map(|a| {
                if a.dtype == "int32" {
                    nemo::runtime::Arg::I32(Tensor::full(&a.shape, 3))
                } else {
                    nemo::runtime::Arg::F32(Tensor::full(&a.shape, 1.0))
                }
            })
            .collect();
        let (t, _) = bench(2, 0.5, || {
            std::hint::black_box(exe.run(&args).expect("run"));
        });
        println!("  PJRT {name}: {}", fmt_time(t));
    }
}
