//! Native deployment artifact round-trip: a saved `model.nemo.json` must
//! reload into a bit-identical integer program — on randomized graphs,
//! through both the packed and the wide execution paths — and corrupted
//! or version-mismatched files must be rejected loudly. Serving from an
//! artifact (the `nemo serve --model` path) is held to the same
//! bit-identity standard with zero training/transform work at load time.

use std::time::Duration;

use nemo::coordinator::{Server, ServerConfig};
use nemo::data::SynthDigits;
use nemo::engine::IntegerEngine;
use nemo::exec::{ExecInput, Executor, NativeIntExecutor};
use nemo::graph::{Graph, Op};
use nemo::io::artifact::{ArtifactError, DeployedArtifact, FORMAT, VERSION};
use nemo::model::synthnet::{SynthNet, EPS_IN};
use nemo::network::{IntegerDeployable, Network};
use nemo::quant::bn::BnParams;
use nemo::quant::quantize_input;
use nemo::tensor::{Tensor, TensorF};
use nemo::transform::DeployOptions;
use nemo::util::prop::prop_check;
use nemo::util::rng::Rng;

fn rand_w(rng: &mut Rng, shape: &[usize], std: f64) -> TensorF {
    let n: usize = shape.iter().product();
    Tensor::from_vec(shape, (0..n).map(|_| rng.normal(0.0, std) as f32).collect())
}

fn rand_bn(rng: &mut Rng, c: usize) -> BnParams {
    BnParams {
        gamma: (0..c).map(|_| rng.uniform(0.3, 1.6)).collect(),
        sigma: (0..c).map(|_| rng.uniform(0.3, 1.6)).collect(),
        beta: (0..c).map(|_| rng.normal(0.0, 0.2)).collect(),
        mu: (0..c).map(|_| rng.normal(0.0, 0.2)).collect(),
    }
}

/// A random conv/bn/act/pool net (subset of the tests/plan.rs generator:
/// enough variety to cover every IntOp the artifact format serializes).
fn random_net(rng: &mut Rng) -> (Graph, usize) {
    let mut g = Graph::new(1.0 / 255.0);
    let mut c = rng.int(1, 3) as usize;
    let mut h = 8usize;
    let mut prev = g.push("in", Op::Input { shape: vec![c, h, h] }, &[]);
    let blocks = rng.int(1, 3) as usize;
    for b in 0..blocks {
        let cout = rng.int(2, 5) as usize;
        let k = if rng.int(0, 2) == 0 { 1 } else { 3 };
        let std = (0.8 / (c * k * k) as f64).sqrt();
        let bias = if rng.int(0, 2) == 0 {
            Some((0..cout).map(|_| rng.normal(0.0, 0.1)).collect())
        } else {
            None
        };
        let w = rand_w(rng, &[cout, c, k, k], std);
        prev = g.push(
            &format!("c{b}"),
            Op::Conv2d { w, bias, stride: 1, pad: k / 2 },
            &[prev],
        );
        c = cout;
        if rng.int(0, 2) == 0 {
            prev = g.push(&format!("bn{b}"), Op::BatchNorm { bn: rand_bn(rng, c) }, &[prev]);
        }
        prev = g.push(&format!("a{b}"), Op::ReLU, &[prev]);
        // residual: conv-bn-act branch + requantizing Add
        if rng.int(0, 3) == 0 {
            let w2 = rand_w(rng, &[c, c, 3, 3], (0.8 / (c * 9) as f64).sqrt());
            let cb = g.push(
                &format!("rc{b}"),
                Op::Conv2d { w: w2, bias: None, stride: 1, pad: 1 },
                &[prev],
            );
            let bb = g.push(&format!("rbn{b}"), Op::BatchNorm { bn: rand_bn(rng, c) }, &[cb]);
            let ab = g.push(&format!("ra{b}"), Op::ReLU, &[bb]);
            let add = g.push(&format!("radd{b}"), Op::Add, &[prev, ab]);
            prev = g.push(&format!("rpa{b}"), Op::ReLU, &[add]);
        }
        if h % 2 == 0 && h > 2 && rng.int(0, 2) == 0 {
            let pool = if rng.int(0, 2) == 0 {
                Op::MaxPool { k: 2 }
            } else {
                Op::AvgPool { k: 2 }
            };
            prev = g.push(&format!("p{b}"), pool, &[prev]);
            h /= 2;
        }
    }
    let classes = rng.int(2, 6) as usize;
    let (head_in, head) = if rng.int(0, 2) == 0 {
        (c, g.push("gap", Op::GlobalAvgPool, &[prev]))
    } else {
        (c * h * h, g.push("fl", Op::Flatten, &[prev]))
    };
    let wf = rand_w(rng, &[head_in, classes], (1.0 / head_in as f64).sqrt());
    g.push("fc", Op::Linear { w: wf, bias: None }, &[head]);
    let in_c = match &g.nodes[0].op {
        Op::Input { shape } => shape[0],
        _ => unreachable!(),
    };
    (g, in_c)
}

fn rand_input(rng: &mut Rng, b: usize, c: usize) -> TensorF {
    Tensor::from_vec(
        &[b, c, 8, 8],
        (0..b * c * 64).map(|_| rng.uniform(0.0, 1.0) as f32).collect(),
    )
}

fn tmp_path(tag: &str) -> std::path::PathBuf {
    // pid-unique: concurrent test runs on one host must not share files.
    std::env::temp_dir().join(format!(
        "nemo_artifact_{tag}_{}.nemo.json",
        std::process::id()
    ))
}

#[test]
fn randomized_roundtrip_is_bit_identical_packed_and_wide() {
    prop_check(15, |rng| {
        let (g, in_c) = random_net(rng);
        let b = rng.int(1, 4) as usize;
        let x = rand_input(rng, b, in_c);
        let fp = Network::from_graph(g).map_err(|e| e.to_string())?;
        let betas = fp.calibrate(&[x.clone()]);
        // abits 9 forces the wide (i32) executor path; <=8 allows packed.
        let abits = [2u32, 4, 8, 9][rng.int(0, 4) as usize];
        let opts = DeployOptions {
            abits,
            use_thresholds: rng.int(0, 2) == 0,
            ..DeployOptions::default()
        };
        let nid = fp
            .quantize_pact(8, abits, &betas)
            .map_err(|e| e.to_string())?
            .deploy(opts)
            .map_err(|e| e.to_string())?
            .integerize();

        let path = tmp_path("prop");
        nid.save_deployed(&path).map_err(|e| e.to_string())?;
        let loaded =
            Network::<IntegerDeployable>::load_deployed(&path).map_err(|e| e.to_string())?;
        let _ = std::fs::remove_file(&path);

        let qx = quantize_input(&x, 1.0 / 255.0);
        // Interpreter bit-identity on the loaded graph.
        let want = IntegerEngine::new().run(nid.int_graph(), &qx);
        let got = IntegerEngine::new().run(loaded.int_graph(), &qx);
        if want != got {
            return Err("loaded interpreter logits diverged".into());
        }
        if loaded.int_graph().precisions() != nid.int_graph().precisions() {
            return Err("precision stamps changed across the round-trip".into());
        }
        if loaded.eps_out().to_bits() != nid.eps_out().to_bits() {
            return Err("eps_out changed across the round-trip".into());
        }
        // Executor bit-identity: compiled plans (packed when the stamps
        // allow, wide otherwise) from original vs loaded graph.
        let e0 = nid.to_executor(b).map_err(|e| e.to_string())?;
        let e1 = loaded.to_executor(b).map_err(|e| e.to_string())?;
        if e0.packed() != e1.packed() {
            return Err("packed-vs-wide plan choice changed across the round-trip".into());
        }
        let o0 = e0.run_batch(&ExecInput::i32(qx.clone())).map_err(|e| e.to_string())?;
        let o1 = e1.run_batch(&ExecInput::i32(qx)).map_err(|e| e.to_string())?;
        if o0.int_logits().unwrap() != o1.int_logits().unwrap() {
            return Err(format!(
                "executor logits diverged (packed = {})",
                e0.packed()
            ));
        }
        Ok(())
    });
}

#[test]
fn corrupted_and_mismatched_files_are_rejected_loudly() {
    let mut rng = Rng::new(42);
    let net = SynthNet::init(&mut rng);
    let nid = net
        .to_network(8)
        .unwrap()
        .deploy(DeployOptions::default())
        .unwrap()
        .integerize();
    let path = tmp_path("reject");
    nid.save_deployed(&path).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();

    // Baseline: the file loads.
    assert!(DeployedArtifact::load(&path).is_ok());

    // Bit-flip inside the model payload -> checksum error.
    let marker = "\"eps_out\":";
    let pos = text.find(marker).unwrap() + marker.len();
    let mut corrupted = text.clone();
    let orig = corrupted.as_bytes()[pos] as char;
    let repl = if orig == '1' { '2' } else { '1' };
    corrupted.replace_range(pos..pos + 1, &repl.to_string());
    std::fs::write(&path, &corrupted).unwrap();
    match DeployedArtifact::load(&path) {
        Err(ArtifactError::Checksum { .. }) => {}
        other => panic!("expected Checksum error, got {:?}", other.err()),
    }

    // Version bump -> version error (before any model decoding).
    let versioned = text.replace(
        &format!("\"version\":{VERSION}"),
        &format!("\"version\":{}", VERSION + 1),
    );
    assert_ne!(versioned, text, "version field must be present to rewrite");
    std::fs::write(&path, &versioned).unwrap();
    match DeployedArtifact::load(&path) {
        Err(ArtifactError::Version { found }) => assert_eq!(found, VERSION + 1),
        other => panic!("expected Version error, got {:?}", other.err()),
    }

    // Foreign format tag -> format error.
    let foreign = text.replace(FORMAT, "some-other-format");
    std::fs::write(&path, &foreign).unwrap();
    assert!(matches!(
        DeployedArtifact::load(&path),
        Err(ArtifactError::Format { .. })
    ));

    // Truncated file -> JSON parse error, not a panic.
    std::fs::write(&path, &text[..text.len() / 2]).unwrap();
    assert!(matches!(
        DeployedArtifact::load(&path),
        Err(ArtifactError::Json(_))
    ));

    // Missing file -> IO error naming the path.
    let _ = std::fs::remove_file(&path);
    match DeployedArtifact::load(&path) {
        Err(ArtifactError::Io { path: p, .. }) => {
            assert!(p.contains("nemo_artifact_reject_"), "{p}");
        }
        other => panic!("expected Io error, got {:?}", other.err()),
    }
}

#[test]
fn serve_from_artifact_without_training_matches_local_engine() {
    // The `nemo serve --model m.nemo.json` path: the only model-building
    // step is NativeIntExecutor::from_artifact — no checkpoint, no
    // transform pipeline, no Python artifacts.
    let path = tmp_path("serve");
    {
        let mut rng = Rng::new(21);
        let net = SynthNet::init(&mut rng);
        let nid = net
            .to_network(8)
            .unwrap()
            .deploy(DeployOptions::default())
            .unwrap()
            .integerize();
        nid.save_deployed(&path).unwrap();
    } // in-memory network dropped: serving below sees only the file

    let exec = NativeIntExecutor::from_artifact(&path, 8).unwrap();
    assert!(exec.packed(), "synthnet at 8 bits must serve packed");
    let reference = Network::<IntegerDeployable>::load_deployed(&path).unwrap();

    // Build through the registry's own artifact path (the `nemo serve
    // --model` route), then verify against the direct-executor load.
    let server = Server::builder()
        .default_config(ServerConfig {
            max_batch: 8,
            batch_timeout: Duration::from_micros(300),
            n_workers: 2,
        })
        .model_from_artifact("synthnet", &path)
        .start()
        .unwrap();
    let _ = std::fs::remove_file(&path); // server loaded fully into memory
    let models = server.handle().list_models();
    assert_eq!(models.len(), 1);
    assert!(
        models[0].provenance.to_string().contains("nemo_artifact"),
        "provenance must name the artifact file: {}",
        models[0].provenance
    );
    let h = server.handle();
    let mut data = SynthDigits::new(7);
    for _ in 0..24 {
        let (x, _) = data.batch(1);
        let qx = quantize_input(&x, EPS_IN);
        let served = h.infer("synthnet", qx.clone()).unwrap();
        assert_eq!(
            served.data(),
            reference.run(&qx).data(),
            "artifact-served logits must be bit-identical"
        );
    }
    let m = server.stop();
    assert_eq!(m.completed, 24);
    assert_eq!(m.failed, 0);
}

#[test]
fn loaded_network_keeps_stage_metadata_and_layers() {
    let mut rng = Rng::new(9);
    let net = SynthNet::init(&mut rng);
    let nid = net
        .to_network(6)
        .unwrap()
        .deploy(DeployOptions { wbits: 6, abits: 6, ..DeployOptions::default() })
        .unwrap()
        .integerize();
    let path = tmp_path("meta");
    nid.save_deployed(&path).unwrap();
    let loaded = Network::<IntegerDeployable>::load_deployed(&path).unwrap();
    let _ = std::fs::remove_file(&path);
    assert_eq!(loaded.stage_name(), "IntegerDeployable");
    assert_eq!(loaded.meta().wbits, 6);
    assert_eq!(loaded.meta().abits, 6);
    assert_eq!(loaded.layers().len(), nid.layers().len());
    for (a, b) in loaded.layers().iter().zip(nid.layers()) {
        assert_eq!(a.name, b.name);
        assert_eq!(a.m, b.m);
        assert_eq!(a.d, b.d);
        assert_eq!(a.eps_y.to_bits(), b.eps_y.to_bits());
    }
    assert_eq!(
        loaded.deployed().worst_case,
        nid.deployed().worst_case,
        "range-analysis diagnostics must survive the round-trip"
    );
}
