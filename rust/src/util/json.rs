//! Minimal JSON: parser + writer.
//!
//! The offline vendor set lacks the `serde` facade crate, so artifact
//! manifests, goldens and checkpoints go through this hand-rolled module.
//! Scope: full JSON spec minus exotic escapes (\u surrogate pairs are
//! supported); numbers parse to f64 (with exact i64 fast path) which is
//! what the interchange files contain.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    /// Integers are kept exact when the token has no '.', 'e' or 'E'.
    Int(i64),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

#[derive(Debug, thiserror::Error)]
pub enum JsonError {
    #[error("json parse error at byte {0}: {1}")]
    Parse(usize, String),
    #[error("json type error: expected {expected}, found {found}")]
    Type { expected: &'static str, found: &'static str },
    #[error("json missing key: {0}")]
    MissingKey(String),
}

type Result<T> = std::result::Result<T, JsonError>;

impl Value {
    fn kind(&self) -> &'static str {
        match self {
            Value::Null => "null",
            Value::Bool(_) => "bool",
            Value::Int(_) => "int",
            Value::Num(_) => "number",
            Value::Str(_) => "string",
            Value::Arr(_) => "array",
            Value::Obj(_) => "object",
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Value::Int(i) => Ok(*i as f64),
            Value::Num(n) => Ok(*n),
            v => Err(JsonError::Type { expected: "number", found: v.kind() }),
        }
    }

    pub fn as_i64(&self) -> Result<i64> {
        match self {
            Value::Int(i) => Ok(*i),
            Value::Num(n) if n.fract() == 0.0 => Ok(*n as i64),
            v => Err(JsonError::Type { expected: "int", found: v.kind() }),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Value::Str(s) => Ok(s),
            v => Err(JsonError::Type { expected: "string", found: v.kind() }),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Value::Bool(b) => Ok(*b),
            v => Err(JsonError::Type { expected: "bool", found: v.kind() }),
        }
    }

    pub fn as_arr(&self) -> Result<&[Value]> {
        match self {
            Value::Arr(a) => Ok(a),
            v => Err(JsonError::Type { expected: "array", found: v.kind() }),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(o) => Ok(o),
            v => Err(JsonError::Type { expected: "object", found: v.kind() }),
        }
    }

    pub fn get(&self, key: &str) -> Result<&Value> {
        self.as_obj()?
            .get(key)
            .ok_or_else(|| JsonError::MissingKey(key.to_string()))
    }

    pub fn get_opt(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(o) => o.get(key),
            _ => None,
        }
    }

    /// Flatten an arbitrarily nested numeric array into (data, shape).
    pub fn as_f64_tensor(&self) -> Result<(Vec<f64>, Vec<usize>)> {
        let mut shape = Vec::new();
        let mut node = self;
        loop {
            match node {
                Value::Arr(a) => {
                    shape.push(a.len());
                    if a.is_empty() {
                        return Ok((vec![], shape));
                    }
                    node = &a[0];
                }
                _ => break,
            }
        }
        let mut data = Vec::new();
        fn walk(v: &Value, depth: usize, shape: &[usize], out: &mut Vec<f64>) -> Result<()> {
            if depth == shape.len() {
                out.push(v.as_f64()?);
                return Ok(());
            }
            let a = v.as_arr()?;
            if a.len() != shape[depth] {
                return Err(JsonError::Parse(0, "ragged tensor".into()));
            }
            for e in a {
                walk(e, depth + 1, shape, out)?;
            }
            Ok(())
        }
        walk(self, 0, &shape, &mut data)?;
        Ok((data, shape))
    }

    pub fn as_i32_tensor(&self) -> Result<(Vec<i32>, Vec<usize>)> {
        let (data, shape) = self.as_f64_tensor()?;
        Ok((data.into_iter().map(|x| x as i32).collect(), shape))
    }
}

// ---------------------------------------------------------------------------
// Parser
// ---------------------------------------------------------------------------

pub fn parse(input: &str) -> Result<Value> {
    let mut p = Parser { b: input.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.b.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError::Parse(self.pos, msg.to_string())
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Value> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.lit("true", Value::Bool(true)),
            Some(b'f') => self.lit("false", Value::Bool(false)),
            Some(b'n') => self.lit("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(b'N') => self.lit("NaN", Value::Num(f64::NAN)),
            Some(b'I') => self.lit("Infinity", Value::Num(f64::INFINITY)),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, s: &str, v: Value) -> Result<Value> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err("bad literal"))
        }
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
            // python json may emit -Infinity
            if self.peek() == Some(b'I') {
                return self.lit("Infinity", Value::Num(f64::NEG_INFINITY));
            }
        }
        let mut is_float = false;
        while let Some(c) = self.peek() {
            match c {
                b'0'..=b'9' => self.pos += 1,
                b'.' | b'e' | b'E' | b'+' | b'-' => {
                    is_float = true;
                    self.pos += 1;
                }
                _ => break,
            }
        }
        let tok = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        if is_float {
            tok.parse::<f64>().map(Value::Num).map_err(|_| self.err("bad number"))
        } else {
            // exact integer if it fits, f64 otherwise
            match tok.parse::<i64>() {
                Ok(i) => Ok(Value::Int(i)),
                Err(_) => tok.parse::<f64>().map(Value::Num).map_err(|_| self.err("bad number")),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            self.pos += 1;
                            let cp = self.hex4()?;
                            // surrogate pair
                            if (0xD800..0xDC00).contains(&cp) {
                                self.expect(b'\\')?;
                                self.expect(b'u')?;
                                let lo = self.hex4()?;
                                let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                out.push(char::from_u32(c).ok_or_else(|| self.err("bad codepoint"))?);
                            } else {
                                out.push(char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?);
                            }
                            continue; // pos already advanced by hex4
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let s = std::str::from_utf8(&self.b[self.pos..])
                        .map_err(|_| self.err("bad utf8"))?;
                    let ch = s.chars().next().unwrap();
                    out.push(ch);
                    self.pos += ch.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32> {
        if self.pos + 4 > self.b.len() {
            return Err(self.err("short \\u escape"));
        }
        let s = std::str::from_utf8(&self.b[self.pos..self.pos + 4])
            .map_err(|_| self.err("bad utf8"))?;
        let v = u32::from_str_radix(s, 16).map_err(|_| self.err("bad hex"))?;
        self.pos += 4;
        Ok(v)
    }

    fn array(&mut self) -> Result<Value> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Value::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Value::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Writer
// ---------------------------------------------------------------------------

pub fn write(v: &Value) -> String {
    let mut s = String::new();
    write_into(v, &mut s);
    s
}

fn write_into(v: &Value, out: &mut String) {
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(true) => out.push_str("true"),
        Value::Bool(false) => out.push_str("false"),
        Value::Int(i) => {
            let _ = write!(out, "{i}");
        }
        Value::Num(n) => {
            if n.is_nan() {
                out.push_str("NaN");
            } else if n.is_infinite() {
                out.push_str(if *n > 0.0 { "Infinity" } else { "-Infinity" });
            } else if n.fract() == 0.0 && n.abs() < 1e15 {
                let _ = write!(out, "{:.1}", n);
            } else {
                // Rust's shortest round-trip float formatting
                let _ = write!(out, "{n}");
            }
        }
        Value::Str(s) => write_str(s, out),
        Value::Arr(a) => {
            out.push('[');
            for (i, e) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_into(e, out);
            }
            out.push(']');
        }
        Value::Obj(o) => {
            out.push('{');
            for (i, (k, e)) in o.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_str(k, out);
                out.push(':');
                write_into(e, out);
            }
            out.push('}');
        }
    }
}

fn write_str(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience builders used by checkpoint/metrics writers.
pub fn arr_f64(v: &[f64]) -> Value {
    Value::Arr(v.iter().map(|x| Value::Num(*x)).collect())
}

pub fn arr_i64(v: &[i64]) -> Value {
    Value::Arr(v.iter().map(|x| Value::Int(*x)).collect())
}

pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
    Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

/// Byte span `(start, end)` of the value for `key` in the top-level
/// object of `text`, found by a token-level scan (string-escape-aware,
/// depth-tracking) without building a tree. The artifact loader hashes
/// the raw span of the `model` subtree while the document is parsed
/// once — the checksum no longer needs a second, re-serialized copy of
/// the model text. Returns `None` when `text` is not an object or the
/// key is absent at depth 1; escaped keys are not matched (the caller
/// falls back to the canonical re-serialize).
pub fn top_level_value_span(text: &str, key: &str) -> Option<(usize, usize)> {
    let b = text.as_bytes();
    let skip_ws = |mut i: usize| {
        while i < b.len() && b[i].is_ascii_whitespace() {
            i += 1;
        }
        i
    };
    // End index (exclusive) of the string starting at the quote `b[i]`.
    let scan_string = |i: usize| {
        debug_assert_eq!(b[i], b'"');
        let mut j = i + 1;
        while j < b.len() {
            match b[j] {
                b'\\' => j += 2,
                b'"' => return Some(j + 1),
                _ => j += 1,
            }
        }
        None
    };
    // End index (exclusive) of the value starting at `b[i]`.
    let scan_value = |i: usize| match b.get(i)? {
        b'"' => scan_string(i),
        b'{' | b'[' => {
            let mut depth = 0usize;
            let mut j = i;
            while j < b.len() {
                match b[j] {
                    b'"' => j = scan_string(j)?,
                    b'{' | b'[' => {
                        depth += 1;
                        j += 1;
                    }
                    b'}' | b']' => {
                        depth -= 1;
                        j += 1;
                        if depth == 0 {
                            return Some(j);
                        }
                    }
                    _ => j += 1,
                }
            }
            None
        }
        _ => {
            // scalar: runs until a structural delimiter or whitespace
            let mut j = i;
            while j < b.len() && !matches!(b[j], b',' | b'}' | b']') && !b[j].is_ascii_whitespace() {
                j += 1;
            }
            Some(j)
        }
    };

    let mut i = skip_ws(0);
    if *b.get(i)? != b'{' {
        return None;
    }
    i = skip_ws(i + 1);
    loop {
        match *b.get(i)? {
            b'}' => return None,
            b',' => {
                i = skip_ws(i + 1);
                continue;
            }
            b'"' => {}
            _ => return None,
        }
        let kend = scan_string(i)?;
        let k = &text[i + 1..kend - 1];
        i = skip_ws(kend);
        if *b.get(i)? != b':' {
            return None;
        }
        i = skip_ws(i + 1);
        let vend = scan_value(i)?;
        if k == key && !k.contains('\\') {
            return Some((i, vend));
        }
        i = skip_ws(vend);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": [1, 2.5, -3], "b": {"c": "x\ny", "d": null, "e": true}}"#;
        let v = parse(src).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[0].as_i64().unwrap(), 1);
        assert_eq!(v.get("b").unwrap().get("c").unwrap().as_str().unwrap(), "x\ny");
        let again = parse(&write(&v)).unwrap();
        assert_eq!(v, again);
    }

    #[test]
    fn float_roundtrip_is_exact() {
        for x in [1.0 / 255.0, 3.1e-5, std::f64::consts::PI, 1e-300, -0.0] {
            let s = write(&Value::Num(x));
            let v = parse(&s).unwrap();
            assert_eq!(v.as_f64().unwrap().to_bits(), x.to_bits(), "{s}");
        }
    }

    #[test]
    fn big_ints_are_exact() {
        let v = parse("[9007199254740993, -9007199254740993]").unwrap();
        assert_eq!(v.as_arr().unwrap()[0].as_i64().unwrap(), 9007199254740993);
    }

    #[test]
    fn tensor_flatten() {
        let v = parse("[[1,2,3],[4,5,6]]").unwrap();
        let (data, shape) = v.as_f64_tensor().unwrap();
        assert_eq!(shape, vec![2, 3]);
        assert_eq!(data, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
    }

    #[test]
    fn unicode_escape() {
        let v = parse(r#""é😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "é😀");
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("tru").is_err());
        assert!(parse("1 2").is_err());
    }

    #[test]
    fn top_level_spans_match_the_canonical_writer() {
        // Canonical output: the raw span IS the canonical serialization
        // of the subtree, so hashing it equals hashing write(subtree).
        let doc = obj(vec![
            ("alpha", Value::Int(7)),
            ("model", obj(vec![("w", arr_i64(&[1, -2, 3])), ("s", Value::Str("a\"b".into()))])),
            ("tail", Value::Bool(true)),
        ]);
        let text = write(&doc);
        let (s, e) = top_level_value_span(&text, "model").unwrap();
        assert_eq!(&text[s..e], write(doc.get("model").unwrap()));
        let (s, e) = top_level_value_span(&text, "alpha").unwrap();
        assert_eq!(&text[s..e], "7");
        let (s, e) = top_level_value_span(&text, "tail").unwrap();
        assert_eq!(&text[s..e], "true");
        assert!(top_level_value_span(&text, "absent").is_none());
    }

    #[test]
    fn spans_survive_whitespace_and_tricky_strings() {
        let text = r#" { "a" : [ {"}]": "\\\"{" } , 2 ] , "b" : { "x" : -1.5e3 } } "#;
        let (s, e) = top_level_value_span(text, "b").unwrap();
        assert_eq!(&text[s..e], r#"{ "x" : -1.5e3 }"#);
        let (s, e) = top_level_value_span(text, "a").unwrap();
        assert_eq!(&text[s..e], r#"[ {"}]": "\\\"{" } , 2 ]"#);
        // nested key "x" is not at the top level
        assert!(top_level_value_span(text, "x").is_none());
        // non-objects and truncated docs yield None, never panic
        assert!(top_level_value_span("[1,2]", "a").is_none());
        assert!(top_level_value_span(r#"{"a": [1, 2"#, "a").is_none());
        assert!(top_level_value_span(r#"{"a": "unterminated"#, "a").is_none());
    }

    #[test]
    fn parses_special_floats() {
        // python json.dump emits these for nan/inf
        let v = parse("[NaN, Infinity, -Infinity]").unwrap();
        let a = v.as_arr().unwrap();
        assert!(a[0].as_f64().unwrap().is_nan());
        assert_eq!(a[1].as_f64().unwrap(), f64::INFINITY);
        assert_eq!(a[2].as_f64().unwrap(), f64::NEG_INFINITY);
    }
}
