//! Compiled execution plans (DESIGN.md §Plan-compilation).
//!
//! The interpreters in [`super::integer`]/[`super::float`] walk the graph
//! per call and allocate a fresh tensor per node. A [`IntPlan`] /
//! [`FloatPlan`] instead compiles a graph **once**:
//!
//! 1. **Shape inference** ([`crate::graph::shape`]) — every node's output
//!    shape is a static function of the graph (only the batch dimension
//!    varies), so it is computed at compile time, not per request.
//! 2. **Fusion** — the deployment pipeline guarantees that
//!    `ConvInt/LinearInt → IntBn → RequantAct/ThreshAct` chains (and the
//!    residual `AddRequant` equivalents) are pointwise per-channel
//!    epilogues of the producing GEMM/Add. The planner collapses each
//!    chain into a single step whose epilogue runs while the GEMM output
//!    is narrowed i64→i32 — no intermediate tensors, bit-identical
//!    results (the float pipeline fuses `Conv2d/Linear/Add → BatchNorm/
//!    QuantBn → ReLU/PactAct` the same way).
//! 3. **Liveness + arena planning** — a topological liveness pass assigns
//!    every step output (and conv im2col/GEMM scratch) to a slot in a
//!    reusable buffer arena; slots are recycled the moment their last
//!    reader retires. Executing a plan performs zero graph walking and —
//!    with a pooled [`Arena`] — zero steady-state allocation beyond the
//!    returned output tensor.
//!
//! [`PlanLayout`] carries the per-batch-size slot assignment so executors
//! can compile one layout per batch variant up front and share the plan
//! (weights are held once, in the plan's steps).

use crate::graph::int::{IntGraph, IntOp};
use crate::graph::shape::{self, ShapeError};
use crate::graph::{Graph, NodeId, Op};
use crate::quant::bn::{BnQuant, Thresholds};
use crate::quant::requant::Requant;
use crate::quant::{Precision, QuantSpec};
use crate::tensor::ops::PackedElem;
use crate::tensor::{get_packed, ops, set_packed, QTensor, Tensor, TensorF, TensorI};

pub type StepId = usize;

/// Sentinel slot meaning "this step's output is the request input".
pub(crate) const INPUT_SLOT: usize = usize::MAX;

#[derive(Debug, thiserror::Error)]
pub enum PlanError {
    #[error("shape inference: {0}")]
    Shape(#[from] ShapeError),
    #[error("plan: {0}")]
    Invalid(String),
}

// ---------------------------------------------------------------------------
// Arena + per-batch layout (shared by the int and float plans)
// ---------------------------------------------------------------------------

/// A pool of reusable buffers addressed by slot id. Arenas only ever
/// grow; an arena prepared for batch 16 serves batch 1 without resizing.
pub struct Arena<T> {
    pub(crate) bufs: Vec<Vec<T>>,
}

pub type IntArena = Arena<i32>;
pub type FloatArena = Arena<f32>;

impl<T> Default for Arena<T> {
    fn default() -> Self {
        Arena { bufs: Vec::new() }
    }
}

impl<T: Copy + Default> Arena<T> {
    pub fn new() -> Self {
        Self::default()
    }

    /// Grow buffers to satisfy `layout`'s slot lengths.
    fn prepare(&mut self, layout: &PlanLayout) {
        self.prepare_lens(&layout.slot_lens);
    }

    /// Grow buffers to satisfy explicit slot lengths (the backward plan
    /// carries its own layout type).
    pub(crate) fn prepare_lens(&mut self, slot_lens: &[usize]) {
        if self.bufs.len() < slot_lens.len() {
            self.bufs.resize_with(slot_lens.len(), Vec::new);
        }
        for (i, &len) in slot_lens.iter().enumerate() {
            if self.bufs[i].len() < len {
                self.bufs[i].resize(len, T::default());
            }
        }
    }

    /// Total elements currently held (diagnostics).
    pub fn len(&self) -> usize {
        self.bufs.iter().map(|b| b.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// One precision-tagged buffer of a [`PackedArena`] slot. The layout
/// fixes each slot's precision; `prepare` re-types a slot only when the
/// layout demands it (first use / plan change), so the steady state is
/// allocation-free exactly like [`Arena`].
#[derive(Debug)]
pub enum PackedBuf {
    U8(Vec<u8>),
    I8(Vec<i8>),
    I32(Vec<i32>),
    /// Bit-packed sub-byte storage: `len` logical elements at `prec`
    /// (2-8 per byte, LSB-first — see tensor/mod.rs `get_packed`).
    Sub {
        prec: Precision,
        len: usize,
        data: Vec<u8>,
    },
}

impl Default for PackedBuf {
    fn default() -> Self {
        PackedBuf::I32(Vec::new())
    }
}

impl PackedBuf {
    fn new(p: Precision, len: usize) -> Self {
        match p {
            Precision::U8 => PackedBuf::U8(vec![0; len]),
            Precision::I8 => PackedBuf::I8(vec![0; len]),
            Precision::I32 => PackedBuf::I32(vec![0; len]),
            sub => PackedBuf::Sub {
                prec: sub,
                len,
                data: vec![0; sub.storage_bytes(len)],
            },
        }
    }

    pub fn precision(&self) -> Precision {
        match self {
            PackedBuf::U8(_) => Precision::U8,
            PackedBuf::I8(_) => Precision::I8,
            PackedBuf::I32(_) => Precision::I32,
            PackedBuf::Sub { prec, .. } => *prec,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            PackedBuf::U8(v) => v.len(),
            PackedBuf::I8(v) => v.len(),
            PackedBuf::I32(v) => v.len(),
            PackedBuf::Sub { len, .. } => *len,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Read element `i` widened to i32 (the sub-byte dispatch slow path).
    fn get(&self, i: usize) -> i32 {
        match self {
            PackedBuf::U8(v) => v[i] as i32,
            PackedBuf::I8(v) => v[i] as i32,
            PackedBuf::I32(v) => v[i],
            PackedBuf::Sub { prec, data, .. } => get_packed(data, i, *prec),
        }
    }

    /// Write element `i`, narrowing into the stored precision (debug-
    /// checked, like [`PackedElem::from_i32`]).
    fn set(&mut self, i: usize, v: i32) {
        match self {
            PackedBuf::U8(b) => b[i] = u8::from_i32(v),
            PackedBuf::I8(b) => b[i] = i8::from_i32(v),
            PackedBuf::I32(b) => b[i] = v,
            PackedBuf::Sub { prec, data, .. } => set_packed(data, i, *prec, v),
        }
    }

    /// Widen the first `n` elements to i32 (traces, final output).
    fn widen_prefix(&self, n: usize) -> Vec<i32> {
        match self {
            PackedBuf::U8(v) => v[..n].iter().map(|x| *x as i32).collect(),
            PackedBuf::I8(v) => v[..n].iter().map(|x| *x as i32).collect(),
            PackedBuf::I32(v) => v[..n].to_vec(),
            PackedBuf::Sub { prec, data, .. } => {
                (0..n).map(|i| get_packed(data, i, *prec)).collect()
            }
        }
    }

    /// Grow to at least `len` elements (the single grow policy).
    fn grow_to(&mut self, len: usize) {
        match self {
            PackedBuf::U8(v) => {
                if v.len() < len {
                    v.resize(len, 0);
                }
            }
            PackedBuf::I8(v) => {
                if v.len() < len {
                    v.resize(len, 0);
                }
            }
            PackedBuf::I32(v) => {
                if v.len() < len {
                    v.resize(len, 0);
                }
            }
            PackedBuf::Sub { prec, len: cur, data } => {
                if *cur < len {
                    *cur = len;
                    data.resize(prec.storage_bytes(len), 0);
                }
            }
        }
    }
}

/// The packed counterpart of [`IntArena`]: slots are byte-sized to their
/// stamped precision (a u8 activation slot costs 1 byte/element, not 4).
/// Only grows, like [`Arena`]; serves any batch of its plan.
#[derive(Default)]
pub struct PackedArena {
    bufs: Vec<PackedBuf>,
}

impl PackedArena {
    pub fn new() -> Self {
        Self::default()
    }

    /// Grow (and, on first use, type) buffers to satisfy `layout`.
    fn prepare(&mut self, layout: &PlanLayout) {
        if self.bufs.len() < layout.slot_lens.len() {
            self.bufs.resize_with(layout.slot_lens.len(), PackedBuf::default);
        }
        for (i, (&len, &p)) in
            layout.slot_lens.iter().zip(&layout.slot_prec).enumerate()
        {
            let buf = &mut self.bufs[i];
            if buf.precision() != p {
                *buf = PackedBuf::new(p, len);
            } else {
                buf.grow_to(len);
            }
        }
    }

    /// Total bytes currently held (diagnostics). Sub-byte slots count
    /// their bit-packed size: `ceil(len * bits / 8)`.
    pub fn bytes(&self) -> usize {
        self.bufs
            .iter()
            .map(|b| b.precision().storage_bytes(b.len()))
            .sum()
    }
}

/// Per-batch-size execution layout: full shapes, arena slot of every step
/// output, conv scratch slots, and the required slot lengths.
#[derive(Clone, Debug)]
pub struct PlanLayout {
    pub batch: usize,
    shapes: Vec<Vec<usize>>,
    out_slot: Vec<usize>,
    scratch: Vec<Vec<usize>>,
    /// Required length of each arena slot (elements, not bytes).
    pub slot_lens: Vec<usize>,
    /// Storage precision of each arena slot (always `I32` for layouts of
    /// the full-width/float paths; mixed for packed layouts).
    slot_prec: Vec<Precision>,
    /// Whether this layout was built by `packed_layout` (the input gets a
    /// real slot and slots carry mixed precisions).
    packed: bool,
}

impl PlanLayout {
    /// Total arena elements this layout requires (perf introspection).
    pub fn arena_len(&self) -> usize {
        self.slot_lens.iter().sum()
    }

    /// Total arena bytes under the precision bit-sizing rule — the
    /// number the packed path shrinks (sub-byte slots store 2-8
    /// elements per byte).
    pub fn arena_bytes(&self) -> usize {
        self.slot_lens
            .iter()
            .zip(&self.slot_prec)
            .map(|(&l, p)| p.storage_bytes(l))
            .sum()
    }

    /// Number of distinct arena slots (vs. one buffer per node in the
    /// interpreter).
    pub fn arena_slots(&self) -> usize {
        self.slot_lens.len()
    }

    pub fn is_packed(&self) -> bool {
        self.packed
    }
}

/// What the slot allocator needs to know about one step.
pub(crate) struct StepSpec {
    pub(crate) inputs: Vec<StepId>,
    pub(crate) out_len: usize,
    pub(crate) out_prec: Precision,
    pub(crate) scratch: Vec<(usize, Precision)>,
    pub(crate) is_input: bool,
}

/// Liveness-driven slot assignment: walk the schedule once, allocating
/// output/scratch slots from a free list and recycling a slot as soon as
/// its last reader has executed. A slot only ever serves one storage
/// precision (free-list reuse is per precision class), so packed arenas
/// can fix each slot's element type up front. Returns (out_slot,
/// scratch_slots, slot_lens, slot_prec).
pub(crate) fn assign_slots(
    specs: &[StepSpec],
    output: StepId,
) -> (Vec<usize>, Vec<Vec<usize>>, Vec<usize>, Vec<Precision>) {
    let n = specs.len();
    let mut last_use: Vec<usize> = (0..n).collect();
    for (s, spec) in specs.iter().enumerate() {
        for &i in &spec.inputs {
            last_use[i] = last_use[i].max(s);
        }
    }
    last_use[output] = usize::MAX; // the network output is read after the loop

    fn alloc(
        len: usize,
        prec: Precision,
        slot_lens: &mut Vec<usize>,
        slot_prec: &mut Vec<Precision>,
        free: &mut Vec<usize>,
    ) -> usize {
        // Best fit among free slots of the same precision: the smallest
        // free slot already >= len; otherwise the largest (least growth);
        // otherwise a fresh slot.
        let mut best: Option<usize> = None;
        for (fi, &slot) in free.iter().enumerate() {
            if slot_prec[slot] != prec {
                continue;
            }
            let better = match best {
                None => true,
                Some(b) => {
                    let (cap, bcap) = (slot_lens[slot], slot_lens[free[b]]);
                    match (cap >= len, bcap >= len) {
                        (true, true) => cap < bcap,
                        (true, false) => true,
                        (false, true) => false,
                        (false, false) => cap > bcap,
                    }
                }
            };
            if better {
                best = Some(fi);
            }
        }
        match best {
            Some(fi) => {
                let slot = free.swap_remove(fi);
                if slot_lens[slot] < len {
                    slot_lens[slot] = len;
                }
                slot
            }
            None => {
                slot_lens.push(len);
                slot_prec.push(prec);
                slot_lens.len() - 1
            }
        }
    }

    let mut out_slot = vec![INPUT_SLOT; n];
    let mut scratch_slots: Vec<Vec<usize>> = vec![Vec::new(); n];
    let mut slot_lens: Vec<usize> = Vec::new();
    let mut slot_prec: Vec<Precision> = Vec::new();
    let mut free: Vec<usize> = Vec::new();
    for (s, spec) in specs.iter().enumerate() {
        if !spec.is_input {
            // Scratch and output are allocated while every input is still
            // live, so a step can never alias a buffer it reads.
            for &(sl, sp) in &spec.scratch {
                let slot = alloc(sl, sp, &mut slot_lens, &mut slot_prec, &mut free);
                scratch_slots[s].push(slot);
            }
            out_slot[s] =
                alloc(spec.out_len, spec.out_prec, &mut slot_lens, &mut slot_prec, &mut free);
            // Scratch dies with the step.
            for &slot in &scratch_slots[s] {
                free.push(slot);
            }
        }
        // Inputs whose last reader is this step are dead now.
        let mut freed: Vec<StepId> = Vec::new();
        for &i in &spec.inputs {
            if last_use[i] == s && !specs[i].is_input && !freed.contains(&i) {
                freed.push(i);
                free.push(out_slot[i]);
            }
        }
    }
    (out_slot, scratch_slots, slot_lens, slot_prec)
}

/// Read a step's output: the request input for Input steps, its arena
/// slot otherwise.
fn slot_data<'a, T: Copy + Default>(
    arena: &'a Arena<T>,
    layout: &PlanLayout,
    sid: StepId,
    qx: &'a Tensor<T>,
) -> &'a [T] {
    let slot = layout.out_slot[sid];
    if slot == INPUT_SLOT {
        qx.data()
    } else {
        &arena.bufs[slot]
    }
}

/// channel-of-flat-index helper: NCHW -> (i / (H*W)) % C, [B, C] -> i % C.
pub(crate) fn channel_stride(shape: &[usize]) -> (usize, usize) {
    match shape.len() {
        4 => (shape[1], shape[2] * shape[3]),
        2 => (shape[1], 1),
        d => panic!("per-channel op on rank-{d} tensor"),
    }
}

// ---------------------------------------------------------------------------
// Integer plan
// ---------------------------------------------------------------------------

/// Fused per-channel integer epilogue, applied while a GEMM/Add output is
/// narrowed i64 → i32: Eq. 22 integer BN, then Eq. 11 requantization or
/// the Eq. 19-20 threshold activation. Each stage narrows through the
/// shared checked [`ops::narrow`], exactly like the standalone ops, so
/// fused execution is bit-identical to the interpreter.
#[derive(Clone, Debug, Default)]
pub struct IntEpilogue {
    bn: Option<BnQuant>,
    act: Option<IntAct>,
}

#[derive(Clone, Debug)]
enum IntAct {
    Requant(Requant),
    Thresh(Thresholds),
}

impl IntEpilogue {
    fn is_empty(&self) -> bool {
        self.bn.is_none() && self.act.is_none()
    }

    /// Stages fused into this epilogue (diagnostics).
    pub fn depth(&self) -> usize {
        self.bn.is_some() as usize + self.act.is_some() as usize
    }

    #[inline]
    fn apply(&self, c: usize, v: i64) -> i32 {
        let v = match &self.bn {
            Some(bn) => ops::narrow(bn.apply(c, v)) as i64,
            None => v,
        };
        match &self.act {
            Some(IntAct::Requant(rq)) => ops::narrow(rq.apply(v)),
            Some(IntAct::Thresh(th)) => ops::narrow(th.apply(c, v)),
            None => ops::narrow(v),
        }
    }
}

/// Per-channel bias + epilogue over a raw GEMM accumulator (the closure
/// handed to [`ops::matmul_i32_fused_into`]; column index = channel).
fn int_epi_fn<'a>(
    bias: Option<&'a [i64]>,
    epi: &'a IntEpilogue,
) -> impl Fn(usize, i32) -> i32 + Sync + 'a {
    move |c, acc| {
        let mut v = acc as i64;
        if let Some(b) = bias {
            v = ops::narrow(v + b[c]) as i64;
        }
        epi.apply(c, v)
    }
}

/// Weight storage for a compiled GEMM step: the single held copy is
/// i8-packed whenever every value fits (true for any `wbits <= 8`
/// symmetric grid — 1 byte/element on BOTH execution paths), and stays
/// i32 otherwise (the wide-node fallback). Never `U8`: symmetric weight
/// grids that fit a byte always fit i8.
///
/// Graph weights already arrive precision-tagged (`IntOp.wq` is a
/// [`QTensor`]). `I8` weights are reused as-is — a cheap clone that
/// *preserves borrowed storage*, so a plan compiled from an mmap'ed
/// binary artifact keeps serving GEMM weights straight out of the
/// mapping with zero weight-byte copies. Sub-byte weights expand to
/// owned i8 here (2-8x, at plan-compile time only): the GEMM kernels
/// stream one weight byte per element, and the bit-serial path
/// re-slices its own bit planes below either way.
fn pack_weights(wq: &QTensor) -> QTensor {
    match wq {
        QTensor::I8(_) => wq.clone(),
        QTensor::U8(t) if t.data().iter().all(|v| *v <= i8::MAX as u8) => {
            QTensor::I8(t.map(|v| v as i8))
        }
        QTensor::U8(t) => QTensor::I32(t.map(|v| v as i32)),
        QTensor::I32(t) => {
            let fits = t
                .data()
                .iter()
                .all(|v| (i8::MIN as i32..=i8::MAX as i32).contains(v));
            if fits {
                QTensor::I8(t.map(|v| v as i8))
            } else {
                wq.clone()
            }
        }
        QTensor::Packed(t) => QTensor::I8(Tensor::from_vec(
            t.shape(),
            (0..t.len()).map(|i| t.get(i) as i8).collect(),
        )),
    }
}

enum IntStepOp {
    Input,
    Conv {
        /// Weight matrix in its packed storage (see [`pack_weights`]).
        wq: QTensor,
        bias_q: Option<Vec<i64>>,
        kh: usize,
        kw: usize,
        stride: usize,
        pad: usize,
        epi: IntEpilogue,
    },
    Linear {
        wq: QTensor,
        bias_q: Option<Vec<i64>>,
        epi: IntEpilogue,
    },
    Bn { bn: BnQuant },
    Requant { rq: Requant },
    Thresh { th: Thresholds },
    AvgPool { k: usize, d: u32 },
    MaxPool { k: usize },
    Flatten,
    Add { rqs: Vec<Requant>, epi: IntEpilogue },
}

/// One compiled step. `node` is the *last* graph node fused into the
/// step — its output is bit-identical to that node's interpreter output,
/// which is what `execute_traced` reports and the plan property tests
/// check against `run_traced`.
pub struct IntStep {
    op: IntStepOp,
    inputs: Vec<StepId>,
    pub node: NodeId,
    /// The *first* graph node of the step — the base op the epilogue
    /// chain was fused onto (equals `node` for unfused steps). This is
    /// the id the static checker attributes routing facts to.
    pub base: NodeId,
    pub name: String,
}

impl IntStep {
    /// Number of graph nodes fused into this step beyond the base op.
    pub fn fused_depth(&self) -> usize {
        match &self.op {
            IntStepOp::Conv { epi, .. }
            | IntStepOp::Linear { epi, .. }
            | IntStepOp::Add { epi, .. } => epi.depth(),
            _ => 0,
        }
    }
}

/// Routing facts for one GEMM step, exposed for the static checker
/// (`nemo check`): graph-node attribution plus the kernel-path decision
/// [`IntPlan::compile`] made for it.
#[derive(Clone, Debug)]
pub struct GemmRouting {
    /// Graph node id of the conv/linear itself (the step's base node).
    pub node: NodeId,
    /// Graph node id whose output feeds the GEMM (anchor of the
    /// producing step).
    pub input_node: NodeId,
    /// Storage precision stamped on that producer.
    pub input_precision: Precision,
    /// Bit width of the weight grid if it decomposes into bit-planes
    /// (`None` when the weights do not fit the bit-plane builder).
    pub weight_bits: Option<u32>,
    /// Whether the bit-serial AND+popcount kernel was selected.
    pub bitserial: bool,
}

/// A compiled integer-graph execution plan. Compile once per graph;
/// derive a [`PlanLayout`] per batch size; execute with a (pooled)
/// [`IntArena`] — or, when the graph carries sub-word precision stamps,
/// derive a [`Self::packed_layout`] and execute with a [`PackedArena`]
/// via [`Self::execute_packed`] (bit-identical, 1 byte/element on packed
/// steps).
pub struct IntPlan {
    steps: Vec<IntStep>,
    output: StepId,
    /// Per-step output shape without the batch dimension.
    sample_shapes: Vec<Vec<usize>>,
    /// Per-step output storage precision (the anchor node's stamp).
    step_prec: Vec<Precision>,
    /// Per-step weight bit-plane decomposition for the bit-serial
    /// AND+popcount GEMM — `Some` only for GEMM steps whose packed
    /// activations are 1- or 2-bit and whose weights fit a few-bit
    /// signed grid (pure kernel policy; bit-identity never depends on
    /// which GEMM path runs).
    bit_planes: Vec<Option<ops::BitPlanes>>,
    input_shape: Vec<usize>,
    input_prec: Precision,
    fused_away: usize,
}

impl IntPlan {
    pub fn compile(g: &IntGraph) -> Result<IntPlan, PlanError> {
        let input_shape = match g.nodes.first().map(|nd| &nd.op) {
            Some(IntOp::Input { shape, .. }) => shape.clone(),
            _ => {
                return Err(PlanError::Invalid(
                    "integer graph has no leading Input node".into(),
                ))
            }
        };
        let shapes1 = shape::infer_int(g, 1)?;
        let node_prec = shape::infer_precision(g)?;
        let n = g.nodes.len();
        let mut fanout = vec![0usize; n];
        let mut consumers: Vec<Vec<NodeId>> = vec![Vec::new(); n];
        for nd in &g.nodes {
            for &i in &nd.inputs {
                fanout[i] += 1;
                consumers[i].push(nd.id);
            }
        }

        // Epilogue absorption: from `start`, keep absorbing the unique
        // consumer while it is a pointwise per-channel op that extends
        // the (bn? act?) epilogue. Stops at the graph output — an
        // absorbed output would never be materialized.
        let absorb = |absorbed: &mut Vec<bool>,
                      chain: &mut Vec<NodeId>,
                      start: NodeId|
         -> (IntEpilogue, NodeId) {
            let mut epi = IntEpilogue::default();
            let mut cur = start;
            loop {
                if fanout[cur] != 1 || cur == g.output {
                    break;
                }
                let c = consumers[cur][0];
                match &g.nodes[c].op {
                    IntOp::IntBn { bn } if epi.is_empty() => {
                        epi.bn = Some(bn.clone());
                    }
                    IntOp::RequantAct { rq } if epi.act.is_none() => {
                        epi.act = Some(IntAct::Requant(*rq));
                    }
                    IntOp::ThreshAct { th } if epi.act.is_none() => {
                        epi.act = Some(IntAct::Thresh(th.clone()));
                    }
                    _ => break,
                }
                absorbed[c] = true;
                chain.push(c);
                cur = c;
            }
            (epi, cur)
        };

        let mut absorbed = vec![false; n];
        let mut node_step: Vec<Option<StepId>> = vec![None; n];
        let mut steps: Vec<IntStep> = Vec::new();
        let mut sample_shapes: Vec<Vec<usize>> = Vec::new();
        let mut step_prec: Vec<Precision> = Vec::new();
        let mut fused_away = 0usize;
        for nd in &g.nodes {
            if absorbed[nd.id] {
                continue;
            }
            let mut chain: Vec<NodeId> = Vec::new();
            let op = match &nd.op {
                IntOp::Input { .. } => IntStepOp::Input,
                IntOp::ConvInt { wq, bias_q, kh, kw, stride, pad, .. } => {
                    let (epi, _) = absorb(&mut absorbed, &mut chain, nd.id);
                    IntStepOp::Conv {
                        wq: pack_weights(wq),
                        bias_q: bias_q.clone(),
                        kh: *kh,
                        kw: *kw,
                        stride: *stride,
                        pad: *pad,
                        epi,
                    }
                }
                IntOp::LinearInt { wq, bias_q } => {
                    let (epi, _) = absorb(&mut absorbed, &mut chain, nd.id);
                    IntStepOp::Linear {
                        wq: pack_weights(wq),
                        bias_q: bias_q.clone(),
                        epi,
                    }
                }
                IntOp::AddRequant { rqs } => {
                    let (epi, _) = absorb(&mut absorbed, &mut chain, nd.id);
                    IntStepOp::Add { rqs: rqs.clone(), epi }
                }
                IntOp::IntBn { bn } => IntStepOp::Bn { bn: bn.clone() },
                IntOp::RequantAct { rq } => IntStepOp::Requant { rq: *rq },
                IntOp::ThreshAct { th } => IntStepOp::Thresh { th: th.clone() },
                IntOp::AvgPoolInt { k, d } => IntStepOp::AvgPool { k: *k, d: *d },
                IntOp::MaxPoolInt { k } => IntStepOp::MaxPool { k: *k },
                IntOp::Flatten => IntStepOp::Flatten,
            };
            let anchor = chain.last().copied().unwrap_or(nd.id);
            let sid = steps.len();
            node_step[nd.id] = Some(sid);
            for &cid in &chain {
                node_step[cid] = Some(sid);
            }
            fused_away += chain.len();
            let inputs: Vec<StepId> = nd
                .inputs
                .iter()
                .map(|&i| node_step[i].expect("graph is topological"))
                .collect();
            sample_shapes.push(shapes1[anchor][1..].to_vec());
            step_prec.push(node_prec[anchor]);
            steps.push(IntStep {
                op,
                inputs,
                node: anchor,
                base: nd.id,
                name: g.nodes[anchor].name.clone(),
            });
        }
        let output = node_step[g.output]
            .ok_or_else(|| PlanError::Invalid("output node unmapped".into()))?;
        // Pre-decompose weights into bit-planes where the bit-serial
        // GEMM applies: 1-/2-bit activations (so at most 2 activation
        // planes) against weights on a <= 4-bit signed grid. Everything
        // else keeps the MAC kernels.
        let bit_planes: Vec<Option<ops::BitPlanes>> = steps
            .iter()
            .map(|st| {
                let wq = match &st.op {
                    IntStepOp::Conv { wq, .. } | IntStepOp::Linear { wq, .. } => wq,
                    _ => return None,
                };
                if !matches!(step_prec[st.inputs[0]], Precision::U1 | Precision::U2) {
                    return None;
                }
                let wide = match wq {
                    QTensor::I8(w) => w.map(|v| v as i32),
                    QTensor::I32(w) => w.clone(),
                    _ => return None,
                };
                ops::BitPlanes::build(&wide).filter(|p| p.bits() <= 4)
            })
            .collect();
        Ok(IntPlan {
            steps,
            output,
            sample_shapes,
            step_prec,
            bit_planes,
            input_shape,
            input_prec: node_prec[0],
            fused_away,
        })
    }

    pub fn input_shape(&self) -> &[usize] {
        &self.input_shape
    }

    /// Storage precision of the request input image.
    pub fn input_precision(&self) -> Precision {
        self.input_prec
    }

    /// Per-step output storage precision (anchor node stamps).
    pub fn step_precisions(&self) -> &[Precision] {
        &self.step_prec
    }

    /// GEMM steps routed to the bit-serial AND+popcount kernel on the
    /// packed path (diagnostics / bench).
    pub fn bitserial_steps(&self) -> usize {
        self.bit_planes.iter().filter(|p| p.is_some()).count()
    }

    /// Kernel-routing facts for every GEMM step — which graph node it
    /// is, what feeds it, and whether the bit-serial path took it. The
    /// static checker (`analysis::check_graph`) consumes these to flag
    /// bit-serial-eligible GEMMs left on the MAC kernels; the routing
    /// policy itself lives in [`Self::compile`] and is not duplicated
    /// here.
    pub fn gemm_routing(&self) -> Vec<GemmRouting> {
        self.steps
            .iter()
            .enumerate()
            .filter_map(|(i, st)| {
                let wq = match &st.op {
                    IntStepOp::Conv { wq, .. } | IntStepOp::Linear { wq, .. } => wq,
                    _ => return None,
                };
                let wide = match wq {
                    QTensor::I8(w) => w.map(|v| v as i32),
                    QTensor::I32(w) => w.clone(),
                    packed => packed.widen(),
                };
                Some(GemmRouting {
                    node: st.base,
                    input_node: self.steps[st.inputs[0]].node,
                    input_precision: self.step_prec[st.inputs[0]],
                    weight_bits: ops::BitPlanes::build(&wide).map(|p| p.bits()),
                    bitserial: self.bit_planes[i].is_some(),
                })
            })
            .collect()
    }

    /// Whether any step (or the input) packs below full i32 width — if
    /// not, the packed path degenerates to the i32 path plus two copies
    /// and executors should prefer [`Self::layout`]/[`Self::execute`].
    pub fn has_packed_steps(&self) -> bool {
        self.input_prec != Precision::I32
            || self.step_prec.iter().any(|p| *p != Precision::I32)
    }

    pub fn steps(&self) -> &[IntStep] {
        &self.steps
    }

    /// Graph nodes eliminated by epilogue fusion.
    pub fn fused_nodes(&self) -> usize {
        self.fused_away
    }

    /// Batch-expanded shapes shared by both layout flavours.
    fn batch_shapes(&self, batch: usize) -> Result<Vec<Vec<usize>>, PlanError> {
        if batch == 0 {
            return Err(PlanError::Invalid("batch size must be >= 1".into()));
        }
        Ok(self
            .sample_shapes
            .iter()
            .map(|ss| {
                let mut s = Vec::with_capacity(ss.len() + 1);
                s.push(batch);
                s.extend_from_slice(ss);
                s
            })
            .collect())
    }

    /// Derive the per-batch-size buffer layout for the full-width i32
    /// execution path ([`Self::execute`]).
    pub fn layout(&self, batch: usize) -> Result<PlanLayout, PlanError> {
        let shapes = self.batch_shapes(batch)?;
        let specs: Vec<StepSpec> = self
            .steps
            .iter()
            .enumerate()
            .map(|(i, st)| {
                let out_len: usize = shapes[i].iter().product();
                let scratch = match &st.op {
                    IntStepOp::Conv { wq, .. } => {
                        let rows = out_len / wq.shape()[1];
                        // im2col patches + GEMM row output
                        vec![
                            (rows * wq.shape()[0], Precision::I32),
                            (out_len, Precision::I32),
                        ]
                    }
                    _ => Vec::new(),
                };
                StepSpec {
                    inputs: st.inputs.clone(),
                    out_len,
                    out_prec: Precision::I32,
                    scratch,
                    is_input: matches!(st.op, IntStepOp::Input),
                }
            })
            .collect();
        let (out_slot, scratch, slot_lens, slot_prec) =
            assign_slots(&specs, self.output);
        Ok(PlanLayout {
            batch,
            shapes,
            out_slot,
            scratch,
            slot_lens,
            slot_prec,
            packed: false,
        })
    }

    /// Derive the per-batch-size buffer layout for the packed execution
    /// path ([`Self::execute_packed`]): every step output slot is
    /// byte-sized to its stamped precision, conv scratch follows its
    /// operands (u8 im2col patches for a u8 input), and the Input step
    /// gets a real slot holding the narrowed request image (Add needs no
    /// extra scratch — its output slot is always full-width I32 and
    /// doubles as the Eq. 24 accumulator).
    pub fn packed_layout(&self, batch: usize) -> Result<PlanLayout, PlanError> {
        let shapes = self.batch_shapes(batch)?;
        let specs: Vec<StepSpec> = self
            .steps
            .iter()
            .enumerate()
            .map(|(i, st)| {
                let out_len: usize = shapes[i].iter().product();
                let scratch = match &st.op {
                    IntStepOp::Conv { wq, .. } => {
                        let rows = out_len / wq.shape()[1];
                        // im2col patches in the input's precision, GEMM
                        // rows in the output's.
                        vec![
                            (rows * wq.shape()[0], self.step_prec[st.inputs[0]]),
                            (out_len, self.step_prec[i]),
                        ]
                    }
                    _ => Vec::new(),
                };
                StepSpec {
                    inputs: st.inputs.clone(),
                    out_len,
                    out_prec: self.step_prec[i],
                    scratch,
                    // The packed path materializes the narrowed input in
                    // its own slot instead of reading the i32 request.
                    is_input: false,
                }
            })
            .collect();
        let (out_slot, scratch, slot_lens, slot_prec) =
            assign_slots(&specs, self.output);
        Ok(PlanLayout {
            batch,
            shapes,
            out_slot,
            scratch,
            slot_lens,
            slot_prec,
            packed: true,
        })
    }

    /// Execute the plan on a batch. `layout.batch` must match `qx`.
    pub fn execute(
        &self,
        layout: &PlanLayout,
        arena: &mut IntArena,
        qx: &TensorI,
    ) -> TensorI {
        self.execute_inner(layout, arena, qx, None)
    }

    /// Execute and clone out every step's output, tagged with the graph
    /// node it is bit-identical to (diagnostics / the fusion property
    /// tests — pairs with the interpreter's `run_traced`).
    pub fn execute_traced(
        &self,
        layout: &PlanLayout,
        arena: &mut IntArena,
        qx: &TensorI,
    ) -> Vec<(NodeId, TensorI)> {
        let mut trace = Vec::with_capacity(self.steps.len());
        self.execute_inner(layout, arena, qx, Some(&mut trace));
        trace
    }

    fn execute_inner(
        &self,
        layout: &PlanLayout,
        arena: &mut IntArena,
        qx: &TensorI,
        mut trace: Option<&mut Vec<(NodeId, TensorI)>>,
    ) -> TensorI {
        assert!(!layout.packed, "i32 execute needs a layout(), not packed_layout()");
        assert_eq!(layout.batch, qx.shape()[0], "layout batch != input batch");
        assert_eq!(
            &qx.shape()[1..],
            &self.input_shape[..],
            "input sample shape mismatch"
        );
        arena.prepare(layout);
        for (sid, st) in self.steps.iter().enumerate() {
            let out_shape = &layout.shapes[sid];
            let out_len: usize = out_shape.iter().product();
            match &st.op {
                IntStepOp::Input => {}
                IntStepOp::Conv { wq, bias_q, kh, kw, stride, pad, epi, .. } => {
                    let (b, c, h, w) = {
                        let s = &layout.shapes[st.inputs[0]];
                        (s[0], s[1], s[2], s[3])
                    };
                    let co = wq.shape()[1];
                    let kdim = wq.shape()[0];
                    let m = out_len / co;
                    let cols_slot = layout.scratch[sid][0];
                    let rows_slot = layout.scratch[sid][1];
                    let out_slot = layout.out_slot[sid];
                    let mut cols = std::mem::take(&mut arena.bufs[cols_slot]);
                    {
                        let xin = slot_data(arena, layout, st.inputs[0], qx);
                        ops::im2col_into(
                            xin, b, c, h, w, *kh, *kw, *stride, *pad, &mut cols,
                        );
                    }
                    let mut rows = std::mem::take(&mut arena.bufs[rows_slot]);
                    let epi_fn = int_epi_fn(bias_q.as_deref(), epi);
                    gemm_wide(&cols[..m * kdim], wq, m, kdim, co, &epi_fn, &mut rows);
                    let mut out = std::mem::take(&mut arena.bufs[out_slot]);
                    ops::rows_to_nchw_into(
                        &rows[..m * co],
                        b,
                        co,
                        out_shape[2],
                        out_shape[3],
                        &mut out,
                    );
                    arena.bufs[cols_slot] = cols;
                    arena.bufs[rows_slot] = rows;
                    arena.bufs[out_slot] = out;
                }
                IntStepOp::Linear { wq, bias_q, epi, .. } => {
                    let in_shape = &layout.shapes[st.inputs[0]];
                    let (bsz, fi) = (in_shape[0], in_shape[1]);
                    let fo = wq.shape()[1];
                    let out_slot = layout.out_slot[sid];
                    let mut out = std::mem::take(&mut arena.bufs[out_slot]);
                    {
                        let xin = slot_data(arena, layout, st.inputs[0], qx);
                        let epi_fn = int_epi_fn(bias_q.as_deref(), epi);
                        gemm_wide(&xin[..bsz * fi], wq, bsz, fi, fo, &epi_fn, &mut out);
                    }
                    arena.bufs[out_slot] = out;
                }
                IntStepOp::Bn { bn } => {
                    self.unary(layout, arena, qx, sid, |in_shape, xin, out| {
                        let (c, hw) = channel_stride(in_shape);
                        for (i, o) in out.iter_mut().enumerate() {
                            *o = ops::narrow(bn.apply((i / hw) % c, xin[i] as i64));
                        }
                    });
                }
                IntStepOp::Requant { rq } => {
                    self.unary(layout, arena, qx, sid, |_, xin, out| {
                        for (o, &x) in out.iter_mut().zip(xin) {
                            *o = ops::narrow(rq.apply(x as i64));
                        }
                    });
                }
                IntStepOp::Thresh { th } => {
                    self.unary(layout, arena, qx, sid, |in_shape, xin, out| {
                        let (c, hw) = channel_stride(in_shape);
                        for (i, o) in out.iter_mut().enumerate() {
                            *o = ops::narrow(th.apply((i / hw) % c, xin[i] as i64));
                        }
                    });
                }
                IntStepOp::AvgPool { k, d } => {
                    self.unary(layout, arena, qx, sid, |in_shape, xin, out| {
                        let (b, c, h, w) =
                            (in_shape[0], in_shape[1], in_shape[2], in_shape[3]);
                        ops::avgpool_i32_into(xin, b, c, h, w, *k, *d, out);
                    });
                }
                IntStepOp::MaxPool { k } => {
                    self.unary(layout, arena, qx, sid, |in_shape, xin, out| {
                        let (b, c, h, w) =
                            (in_shape[0], in_shape[1], in_shape[2], in_shape[3]);
                        ops::maxpool_into(xin, b, c, h, w, *k, out);
                    });
                }
                IntStepOp::Flatten => {
                    self.unary(layout, arena, qx, sid, |_, xin, out| {
                        out.copy_from_slice(&xin[..out.len()]);
                    });
                }
                IntStepOp::Add { rqs, epi } => {
                    let out_slot = layout.out_slot[sid];
                    let mut out = std::mem::take(&mut arena.bufs[out_slot]);
                    {
                        let out = &mut out[..out_len];
                        // Branch 0 is the reference space (Eq. 24).
                        let r0 = slot_data(arena, layout, st.inputs[0], qx);
                        out.copy_from_slice(&r0[..out_len]);
                        for (bi, &inp) in st.inputs.iter().skip(1).enumerate() {
                            let bx = slot_data(arena, layout, inp, qx);
                            let rq = &rqs[bi];
                            for (a, &bv) in out.iter_mut().zip(&bx[..out_len]) {
                                *a = ops::narrow(*a as i64 + rq.apply(bv as i64));
                            }
                        }
                        if !epi.is_empty() {
                            let (c, hw) = channel_stride(out_shape);
                            for (i, v) in out.iter_mut().enumerate() {
                                *v = epi.apply((i / hw) % c, *v as i64);
                            }
                        }
                    }
                    arena.bufs[out_slot] = out;
                }
            }
            if let Some(tr) = trace.as_deref_mut() {
                let data = slot_data(arena, layout, sid, qx)[..out_len].to_vec();
                tr.push((st.node, Tensor::from_vec(out_shape, data)));
            }
        }
        let shape = &layout.shapes[self.output];
        let len: usize = shape.iter().product();
        Tensor::from_vec(shape, slot_data(arena, layout, self.output, qx)[..len].to_vec())
    }

    /// Run a single-input step: take the output buffer, hand (input
    /// shape, input data, output prefix) to `f`, put the buffer back.
    fn unary(
        &self,
        layout: &PlanLayout,
        arena: &mut IntArena,
        qx: &TensorI,
        sid: StepId,
        f: impl FnOnce(&[usize], &[i32], &mut [i32]),
    ) {
        let st = &self.steps[sid];
        let out_len: usize = layout.shapes[sid].iter().product();
        let out_slot = layout.out_slot[sid];
        let mut out = std::mem::take(&mut arena.bufs[out_slot]);
        {
            let in_shape = &layout.shapes[st.inputs[0]];
            let xin = slot_data(arena, layout, st.inputs[0], qx);
            f(in_shape, xin, &mut out[..out_len]);
        }
        arena.bufs[out_slot] = out;
    }

    // -- packed execution ---------------------------------------------------

    /// Execute the plan with precision-packed buffers: sub-word steps
    /// stream u8/i8 images (1 byte/element) and the fused GEMM epilogue
    /// narrows directly into the packed output; wide (i32) steps run
    /// exactly as in [`Self::execute`]. Bit-identical to the i32 path and
    /// the interpreter (tests/plan.rs property tests). `layout` must come
    /// from [`Self::packed_layout`].
    pub fn execute_packed(
        &self,
        layout: &PlanLayout,
        arena: &mut PackedArena,
        qx: &TensorI,
    ) -> TensorI {
        self.execute_packed_inner(layout, arena, qx, None)
    }

    /// Packed execution with every step output widened into the trace
    /// (pairs with the interpreter's `run_traced`, like
    /// [`Self::execute_traced`]).
    pub fn execute_packed_traced(
        &self,
        layout: &PlanLayout,
        arena: &mut PackedArena,
        qx: &TensorI,
    ) -> Vec<(NodeId, TensorI)> {
        let mut trace = Vec::with_capacity(self.steps.len());
        self.execute_packed_inner(layout, arena, qx, Some(&mut trace));
        trace
    }

    fn execute_packed_inner(
        &self,
        layout: &PlanLayout,
        arena: &mut PackedArena,
        qx: &TensorI,
        mut trace: Option<&mut Vec<(NodeId, TensorI)>>,
    ) -> TensorI {
        assert!(layout.packed, "packed execute needs a packed_layout()");
        assert_eq!(layout.batch, qx.shape()[0], "layout batch != input batch");
        assert_eq!(
            &qx.shape()[1..],
            &self.input_shape[..],
            "input sample shape mismatch"
        );
        arena.prepare(layout);
        for (sid, st) in self.steps.iter().enumerate() {
            let out_shape = &layout.shapes[sid];
            let out_len: usize = out_shape.iter().product();
            match &st.op {
                IntStepOp::Input => {
                    // Narrow the i32 request image into the packed input
                    // slot. The input spec's range proof covers this;
                    // executors validate untrusted values up front.
                    let out_slot = layout.out_slot[sid];
                    let mut out = std::mem::take(&mut arena.bufs[out_slot]);
                    narrow_q(qx.data(), &mut out, out_len);
                    arena.bufs[out_slot] = out;
                }
                IntStepOp::Conv { wq, bias_q, kh, kw, stride, pad, epi } => {
                    let (b, c, h, w) = {
                        let s = &layout.shapes[st.inputs[0]];
                        (s[0], s[1], s[2], s[3])
                    };
                    let co = wq.shape()[1];
                    let kdim = wq.shape()[0];
                    let m = out_len / co;
                    let cols_slot = layout.scratch[sid][0];
                    let rows_slot = layout.scratch[sid][1];
                    let out_slot = layout.out_slot[sid];
                    let mut cols = std::mem::take(&mut arena.bufs[cols_slot]);
                    {
                        let xin = &arena.bufs[layout.out_slot[st.inputs[0]]];
                        im2col_q(xin, &mut cols, b, c, h, w, *kh, *kw, *stride, *pad);
                    }
                    let mut rows = std::mem::take(&mut arena.bufs[rows_slot]);
                    {
                        let epi_fn = int_epi_fn(bias_q.as_deref(), epi);
                        let bp = self.bit_planes[sid].as_ref();
                        gemm_q(&cols, wq, bp, m, kdim, co, &epi_fn, &mut rows);
                    }
                    let mut out = std::mem::take(&mut arena.bufs[out_slot]);
                    scatter_q(&rows, &mut out, b, co, out_shape[2], out_shape[3]);
                    arena.bufs[cols_slot] = cols;
                    arena.bufs[rows_slot] = rows;
                    arena.bufs[out_slot] = out;
                }
                IntStepOp::Linear { wq, bias_q, epi } => {
                    let in_shape = &layout.shapes[st.inputs[0]];
                    let (bsz, fi) = (in_shape[0], in_shape[1]);
                    let fo = wq.shape()[1];
                    let out_slot = layout.out_slot[sid];
                    let mut out = std::mem::take(&mut arena.bufs[out_slot]);
                    {
                        let xin = &arena.bufs[layout.out_slot[st.inputs[0]]];
                        let epi_fn = int_epi_fn(bias_q.as_deref(), epi);
                        let bp = self.bit_planes[sid].as_ref();
                        gemm_q(xin, wq, bp, bsz, fi, fo, &epi_fn, &mut out);
                    }
                    arena.bufs[out_slot] = out;
                }
                IntStepOp::Bn { bn } => {
                    self.unary_q(layout, arena, sid, |in_shape, xin, out| {
                        let (c, hw) = channel_stride(in_shape);
                        map_q(xin, out, out_len, |i, v| {
                            ops::narrow(bn.apply((i / hw) % c, v as i64))
                        });
                    });
                }
                IntStepOp::Requant { rq } => {
                    self.unary_q(layout, arena, sid, |_, xin, out| {
                        map_q(xin, out, out_len, |_, v| ops::narrow(rq.apply(v as i64)));
                    });
                }
                IntStepOp::Thresh { th } => {
                    self.unary_q(layout, arena, sid, |in_shape, xin, out| {
                        let (c, hw) = channel_stride(in_shape);
                        map_q(xin, out, out_len, |i, v| {
                            ops::narrow(th.apply((i / hw) % c, v as i64))
                        });
                    });
                }
                IntStepOp::AvgPool { k, d } => {
                    self.unary_q(layout, arena, sid, |in_shape, xin, out| {
                        let (b, c, h, w) =
                            (in_shape[0], in_shape[1], in_shape[2], in_shape[3]);
                        avgpool_q(xin, out, b, c, h, w, *k, *d);
                    });
                }
                IntStepOp::MaxPool { k } => {
                    self.unary_q(layout, arena, sid, |in_shape, xin, out| {
                        let (b, c, h, w) =
                            (in_shape[0], in_shape[1], in_shape[2], in_shape[3]);
                        maxpool_q(xin, out, b, c, h, w, *k);
                    });
                }
                IntStepOp::Flatten => {
                    self.unary_q(layout, arena, sid, |_, xin, out| {
                        copy_q(xin, out, out_len);
                    });
                }
                IntStepOp::Add { rqs, epi } => {
                    // AddRequant nodes are always stamped I32 (only the
                    // range analysis bounds them), so the packed output
                    // slot IS the full-width accumulator — same in-place
                    // Eq. 24 accumulation as the wide path.
                    let out_slot = layout.out_slot[sid];
                    let mut out = std::mem::take(&mut arena.bufs[out_slot]);
                    {
                        let PackedBuf::I32(acc) = &mut out else {
                            unreachable!("Add output slot is I32 (infer_precision)")
                        };
                        let acc = &mut acc[..out_len];
                        // Branch 0 is the reference space (Eq. 24).
                        let r0 = &arena.bufs[layout.out_slot[st.inputs[0]]];
                        for_each_q(r0, out_len, |i, v| acc[i] = v);
                        for (bi, &inp) in st.inputs.iter().skip(1).enumerate() {
                            let bx = &arena.bufs[layout.out_slot[inp]];
                            let rq = &rqs[bi];
                            for_each_q(bx, out_len, |i, v| {
                                acc[i] =
                                    ops::narrow(acc[i] as i64 + rq.apply(v as i64));
                            });
                        }
                        if !epi.is_empty() {
                            let (c, hw) = channel_stride(out_shape);
                            for (i, v) in acc.iter_mut().enumerate() {
                                *v = epi.apply((i / hw) % c, *v as i64);
                            }
                        }
                    }
                    arena.bufs[out_slot] = out;
                }
            }
            if let Some(tr) = trace.as_deref_mut() {
                let buf = &arena.bufs[layout.out_slot[sid]];
                tr.push((st.node, Tensor::from_vec(out_shape, buf.widen_prefix(out_len))));
            }
        }
        let shape = &layout.shapes[self.output];
        let len: usize = shape.iter().product();
        let buf = &arena.bufs[layout.out_slot[self.output]];
        Tensor::from_vec(shape, buf.widen_prefix(len))
    }

    /// Packed twin of [`Self::unary`]: take the output buffer, hand
    /// (input shape, input buffer, output buffer) to `f`, put it back.
    fn unary_q(
        &self,
        layout: &PlanLayout,
        arena: &mut PackedArena,
        sid: StepId,
        f: impl FnOnce(&[usize], &PackedBuf, &mut PackedBuf),
    ) {
        let st = &self.steps[sid];
        let out_slot = layout.out_slot[sid];
        let mut out = std::mem::take(&mut arena.bufs[out_slot]);
        {
            let in_shape = &layout.shapes[st.inputs[0]];
            let xin = &arena.bufs[layout.out_slot[st.inputs[0]]];
            f(in_shape, xin, &mut out);
        }
        arena.bufs[out_slot] = out;
    }
}

// ---------------------------------------------------------------------------
// Packed kernel dispatch (precision -> monomorphized kernel)
// ---------------------------------------------------------------------------

/// Narrow an i32 slice into a packed buffer prefix (debug-checked like
/// `ops::narrow`; callers validate untrusted inputs up front).
fn narrow_q(src: &[i32], dst: &mut PackedBuf, n: usize) {
    match dst {
        PackedBuf::U8(v) => {
            for (o, &x) in v[..n].iter_mut().zip(src) {
                *o = u8::from_i32(x);
            }
        }
        PackedBuf::I8(v) => {
            for (o, &x) in v[..n].iter_mut().zip(src) {
                *o = i8::from_i32(x);
            }
        }
        PackedBuf::I32(v) => v[..n].copy_from_slice(&src[..n]),
        PackedBuf::Sub { prec, data, .. } => {
            for (i, &x) in src[..n].iter().enumerate() {
                set_packed(data, i, *prec, x);
            }
        }
    }
}

/// Pointwise `out[i] = f(i, widen(x[i]))`, narrowing into `out`'s
/// precision — the shared loop behind the standalone Bn/Requant/Thresh/
/// Flatten packed steps and the Add narrowing stage.
fn map_q(xin: &PackedBuf, out: &mut PackedBuf, n: usize, f: impl Fn(usize, i32) -> i32) {
    fn inner<I: PackedElem, O: PackedElem>(
        x: &[I],
        o: &mut [O],
        n: usize,
        f: impl Fn(usize, i32) -> i32,
    ) {
        for (i, (o, &x)) in o[..n].iter_mut().zip(&x[..n]).enumerate() {
            *o = O::from_i32(f(i, x.to_i32()));
        }
    }
    if matches!(xin, PackedBuf::Sub { .. }) || matches!(out, PackedBuf::Sub { .. }) {
        // Sub-byte on either side: element-at-a-time through the bit
        // accessors (same widen-apply-narrow arithmetic).
        for i in 0..n {
            out.set(i, f(i, xin.get(i)));
        }
        return;
    }
    match (xin, out) {
        (PackedBuf::U8(x), PackedBuf::U8(o)) => inner(x, o, n, f),
        (PackedBuf::U8(x), PackedBuf::I8(o)) => inner(x, o, n, f),
        (PackedBuf::U8(x), PackedBuf::I32(o)) => inner(x, o, n, f),
        (PackedBuf::I8(x), PackedBuf::U8(o)) => inner(x, o, n, f),
        (PackedBuf::I8(x), PackedBuf::I8(o)) => inner(x, o, n, f),
        (PackedBuf::I8(x), PackedBuf::I32(o)) => inner(x, o, n, f),
        (PackedBuf::I32(x), PackedBuf::U8(o)) => inner(x, o, n, f),
        (PackedBuf::I32(x), PackedBuf::I8(o)) => inner(x, o, n, f),
        (PackedBuf::I32(x), PackedBuf::I32(o)) => inner(x, o, n, f),
        (PackedBuf::Sub { .. }, _) | (_, PackedBuf::Sub { .. }) => {
            unreachable!("sub-byte map handled above")
        }
    }
}

/// Bulk copy between same-precision packed buffers (Flatten — the
/// stamps inherit, so the variants always match; no per-element widen/
/// narrow round-trip).
fn copy_q(xin: &PackedBuf, out: &mut PackedBuf, n: usize) {
    match (xin, out) {
        (PackedBuf::U8(x), PackedBuf::U8(o)) => o[..n].copy_from_slice(&x[..n]),
        (PackedBuf::I8(x), PackedBuf::I8(o)) => o[..n].copy_from_slice(&x[..n]),
        (PackedBuf::I32(x), PackedBuf::I32(o)) => o[..n].copy_from_slice(&x[..n]),
        (
            PackedBuf::Sub { prec: px, data: x, .. },
            PackedBuf::Sub { prec: po, data: o, .. },
        ) if px == po => {
            let nb = px.storage_bytes(n);
            o[..nb].copy_from_slice(&x[..nb]);
        }
        _ => unreachable!("flatten precision mismatch (inferred stamps inherit)"),
    }
}

/// Visit the first `n` elements of a packed buffer, widened to i32.
fn for_each_q(x: &PackedBuf, n: usize, mut f: impl FnMut(usize, i32)) {
    match x {
        PackedBuf::U8(v) => {
            for (i, &x) in v[..n].iter().enumerate() {
                f(i, x as i32);
            }
        }
        PackedBuf::I8(v) => {
            for (i, &x) in v[..n].iter().enumerate() {
                f(i, x as i32);
            }
        }
        PackedBuf::I32(v) => {
            for (i, &x) in v[..n].iter().enumerate() {
                f(i, x);
            }
        }
        PackedBuf::Sub { prec, data, .. } => {
            for i in 0..n {
                f(i, get_packed(data, i, *prec));
            }
        }
    }
}

/// im2col into a same-precision patch buffer (the layout gives the cols
/// scratch the input's precision).
#[allow(clippy::too_many_arguments)]
fn im2col_q(
    xin: &PackedBuf,
    cols: &mut PackedBuf,
    b: usize,
    c: usize,
    h: usize,
    w: usize,
    kh: usize,
    kw: usize,
    stride: usize,
    pad: usize,
) {
    match (xin, cols) {
        (PackedBuf::U8(x), PackedBuf::U8(o)) => {
            ops::im2col_into(x, b, c, h, w, kh, kw, stride, pad, o);
        }
        (PackedBuf::I8(x), PackedBuf::I8(o)) => {
            ops::im2col_into(x, b, c, h, w, kh, kw, stride, pad, o);
        }
        (PackedBuf::I32(x), PackedBuf::I32(o)) => {
            ops::im2col_into(x, b, c, h, w, kh, kw, stride, pad, o);
        }
        (
            PackedBuf::Sub { prec: px, data: x, .. },
            PackedBuf::Sub { prec: po, data: o, .. },
        ) if px == po => {
            ops::im2col_packed_into(x, *px, b, c, h, w, kh, kw, stride, pad, o);
        }
        _ => unreachable!("im2col precision mismatch (layout gives cols the input precision)"),
    }
}

/// Scatter same-precision GEMM rows into the NCHW output buffer.
fn scatter_q(rows: &PackedBuf, out: &mut PackedBuf, b: usize, c: usize, oh: usize, ow: usize) {
    match (rows, out) {
        (PackedBuf::U8(r), PackedBuf::U8(o)) => ops::rows_to_nchw_into(r, b, c, oh, ow, o),
        (PackedBuf::I8(r), PackedBuf::I8(o)) => ops::rows_to_nchw_into(r, b, c, oh, ow, o),
        (PackedBuf::I32(r), PackedBuf::I32(o)) => ops::rows_to_nchw_into(r, b, c, oh, ow, o),
        (
            PackedBuf::Sub { prec: pr, data: r, .. },
            PackedBuf::Sub { prec: po, data: o, .. },
        ) if pr == po => ops::rows_to_nchw_packed_into(r, *pr, b, c, oh, ow, o),
        _ => unreachable!("scatter precision mismatch (layout gives rows the output precision)"),
    }
}

/// Same-precision packed max pool.
#[allow(clippy::too_many_arguments)]
fn maxpool_q(
    xin: &PackedBuf,
    out: &mut PackedBuf,
    b: usize,
    c: usize,
    h: usize,
    w: usize,
    k: usize,
) {
    match (xin, out) {
        (PackedBuf::U8(x), PackedBuf::U8(o)) => ops::maxpool_into(x, b, c, h, w, k, o),
        (PackedBuf::I8(x), PackedBuf::I8(o)) => ops::maxpool_into(x, b, c, h, w, k, o),
        (PackedBuf::I32(x), PackedBuf::I32(o)) => ops::maxpool_into(x, b, c, h, w, k, o),
        (
            PackedBuf::Sub { prec: px, data: x, .. },
            PackedBuf::Sub { prec: po, data: o, .. },
        ) if px == po => ops::maxpool_packed_into(x, *px, b, c, h, w, k, o),
        _ => unreachable!("maxpool precision mismatch (inferred stamps inherit)"),
    }
}

/// Same-precision packed average pool (Eq. 25).
#[allow(clippy::too_many_arguments)]
fn avgpool_q(
    xin: &PackedBuf,
    out: &mut PackedBuf,
    b: usize,
    c: usize,
    h: usize,
    w: usize,
    k: usize,
    d: u32,
) {
    match (xin, out) {
        (PackedBuf::U8(x), PackedBuf::U8(o)) => ops::avgpool_q_into(x, b, c, h, w, k, d, o),
        (PackedBuf::I8(x), PackedBuf::I8(o)) => ops::avgpool_q_into(x, b, c, h, w, k, d, o),
        (PackedBuf::I32(x), PackedBuf::I32(o)) => ops::avgpool_q_into(x, b, c, h, w, k, d, o),
        (
            PackedBuf::Sub { prec: px, data: x, .. },
            PackedBuf::Sub { prec: po, data: o, .. },
        ) if px == po => ops::avgpool_packed_into(x, *px, b, c, h, w, k, d, o),
        _ => unreachable!("avgpool precision mismatch (inferred stamps inherit)"),
    }
}

/// Full-width GEMM over the single stored weight variant (the i32
/// execution path): i8-packed weights still stream at 1 byte/element —
/// [`ops::matmul_q_fused_into`] with i32 A/out is bit-identical to
/// [`ops::matmul_i32_fused_into`] on the same values.
fn gemm_wide<F>(
    ad: &[i32],
    wq: &QTensor,
    m: usize,
    k: usize,
    n: usize,
    epi: &F,
    out: &mut [i32],
) where
    F: Fn(usize, i32) -> i32 + Sync,
{
    match wq {
        QTensor::I8(w) => ops::matmul_q_fused_into(ad, w.data(), m, k, n, epi, out),
        QTensor::I32(w) => ops::matmul_i32_fused_into(ad, w.data(), m, k, n, epi, out),
        QTensor::U8(_) | QTensor::Packed(_) => {
            unreachable!("weights pack to i8 or stay i32")
        }
    }
}

/// Packed GEMM dispatch: input buffer precision x weight storage (i8 or
/// i32, see [`pack_weights`]) x output precision, all routed to the
/// generic MAC kernel [`ops::matmul_q_fused_into`] — except sub-byte
/// activations, which take the bit-serial AND+popcount kernel when the
/// plan pre-built weight [`ops::BitPlanes`] and the nibble-unpack
/// row-block kernel otherwise. Sub-byte *outputs* go through a transient
/// i32 row buffer and pack afterwards (packed rows share bytes across
/// row boundaries, so threaded row blocks cannot write bytes
/// independently).
#[allow(clippy::too_many_arguments)]
fn gemm_q<F>(
    xin: &PackedBuf,
    wq: &QTensor,
    bp: Option<&ops::BitPlanes>,
    m: usize,
    k: usize,
    n: usize,
    epi: &F,
    out: &mut PackedBuf,
) where
    F: Fn(usize, i32) -> i32 + Sync,
{
    match out {
        PackedBuf::U8(o) => gemm_q_in(xin, wq, bp, m, k, n, epi, o),
        PackedBuf::I8(o) => gemm_q_in(xin, wq, bp, m, k, n, epi, o),
        PackedBuf::I32(o) => gemm_q_in(xin, wq, bp, m, k, n, epi, o),
        PackedBuf::Sub { prec, data, .. } => {
            let mut wide = vec![0i32; m * n];
            gemm_q_in(xin, wq, bp, m, k, n, epi, &mut wide);
            for (i, &v) in wide.iter().enumerate() {
                set_packed(data, i, *prec, v);
            }
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn gemm_q_in<O, F>(
    xin: &PackedBuf,
    wq: &QTensor,
    bp: Option<&ops::BitPlanes>,
    m: usize,
    k: usize,
    n: usize,
    epi: &F,
    out: &mut [O],
) where
    O: PackedElem,
    F: Fn(usize, i32) -> i32 + Sync,
{
    match (xin, wq) {
        (PackedBuf::U8(x), QTensor::I8(w)) => {
            ops::matmul_q_fused_into(&x[..m * k], w.data(), m, k, n, epi, out)
        }
        (PackedBuf::U8(x), QTensor::I32(w)) => {
            ops::matmul_q_fused_into(&x[..m * k], w.data(), m, k, n, epi, out)
        }
        (PackedBuf::I8(x), QTensor::I8(w)) => {
            ops::matmul_q_fused_into(&x[..m * k], w.data(), m, k, n, epi, out)
        }
        (PackedBuf::I8(x), QTensor::I32(w)) => {
            ops::matmul_q_fused_into(&x[..m * k], w.data(), m, k, n, epi, out)
        }
        (PackedBuf::I32(x), QTensor::I8(w)) => {
            ops::matmul_q_fused_into(&x[..m * k], w.data(), m, k, n, epi, out)
        }
        (PackedBuf::I32(x), QTensor::I32(w)) => {
            ops::matmul_q_fused_into(&x[..m * k], w.data(), m, k, n, epi, out)
        }
        (PackedBuf::Sub { prec, data, .. }, _) => {
            if let Some(planes) = bp {
                debug_assert_eq!((planes.k(), planes.n()), (k, n));
                ops::matmul_bitserial_fused_into(data, *prec, m, planes, epi, out);
                return;
            }
            match wq {
                QTensor::I8(w) => ops::matmul_subbyte_fused_into(
                    data,
                    *prec,
                    w.data(),
                    m,
                    k,
                    n,
                    epi,
                    out,
                ),
                QTensor::I32(w) => ops::matmul_subbyte_fused_into(
                    data,
                    *prec,
                    w.data(),
                    m,
                    k,
                    n,
                    epi,
                    out,
                ),
                QTensor::U8(_) | QTensor::Packed(_) => {
                    unreachable!("weights pack to i8 or stay i32")
                }
            }
        }
        (_, QTensor::U8(_) | QTensor::Packed(_)) => {
            unreachable!("weights pack to i8 or stay i32")
        }
    }
}

// ---------------------------------------------------------------------------
// Float plan
// ---------------------------------------------------------------------------

/// Fused float epilogue: per-channel affine (BatchNorm/QuantBn — the
/// kappa/lambda are kept in f64 and cast per element exactly like the
/// interpreter's `apply_channel_affine`) followed by ReLU or the Eq. 10
/// PACT quantization/activation.
#[derive(Clone, Debug, Default)]
pub struct FloatEpilogue {
    affine: Option<(Vec<f64>, Vec<f64>)>,
    act: Option<FloatAct>,
}

#[derive(Clone, Debug)]
enum FloatAct {
    Relu,
    Pact(QuantSpec),
}

impl FloatEpilogue {
    fn is_empty(&self) -> bool {
        self.affine.is_none() && self.act.is_none()
    }

    pub fn depth(&self) -> usize {
        self.affine.is_some() as usize + self.act.is_some() as usize
    }

    #[inline]
    fn apply(&self, c: usize, mut v: f32) -> f32 {
        if let Some((kappa, lambda)) = &self.affine {
            v = kappa[c] as f32 * v + lambda[c] as f32;
        }
        match &self.act {
            Some(FloatAct::Relu) => v.max(0.0),
            Some(FloatAct::Pact(spec)) => spec.fake_quantize(v as f64) as f32,
            None => v,
        }
    }
}

/// Bias + epilogue over a float GEMM output column (channel). `v + bias`
/// is bit-identical to the interpreter's `1.0 * v + bias` affine form.
fn float_epi_fn<'a>(
    bias: Option<&'a [f64]>,
    epi: &'a FloatEpilogue,
) -> impl Fn(usize, f32) -> f32 + 'a {
    move |c, acc| {
        let mut v = acc;
        if let Some(b) = bias {
            v += b[c] as f32;
        }
        epi.apply(c, v)
    }
}

enum FloatStepOp {
    Input,
    Conv {
        /// Weights pre-transposed to the [C_in*KH*KW, C_out] im2col
        /// layout at compile time (the interpreter re-derives this every
        /// call — same values, same GEMM).
        wmat: TensorF,
        bias: Option<Vec<f64>>,
        kh: usize,
        kw: usize,
        stride: usize,
        pad: usize,
        epi: FloatEpilogue,
    },
    Linear {
        w: TensorF,
        bias: Option<Vec<f64>>,
        epi: FloatEpilogue,
    },
    Affine { kappa: Vec<f64>, lambda: Vec<f64> },
    Relu,
    Pact { spec: QuantSpec },
    MaxPool { k: usize },
    AvgPool { k: usize },
    GlobalAvgPool,
    Flatten,
    Add { epi: FloatEpilogue },
}

pub struct FloatStep {
    op: FloatStepOp,
    inputs: Vec<StepId>,
    pub node: NodeId,
    pub name: String,
}

impl FloatStep {
    pub fn fused_depth(&self) -> usize {
        match &self.op {
            FloatStepOp::Conv { epi, .. }
            | FloatStepOp::Linear { epi, .. }
            | FloatStepOp::Add { epi, .. } => epi.depth(),
            _ => 0,
        }
    }
}

/// A compiled float-graph execution plan (FP / FQ / QD representations).
pub struct FloatPlan {
    steps: Vec<FloatStep>,
    output: StepId,
    sample_shapes: Vec<Vec<usize>>,
    input_shape: Vec<usize>,
    fused_away: usize,
}

impl FloatPlan {
    pub fn compile(g: &Graph) -> Result<FloatPlan, PlanError> {
        Self::compile_inner(g, true)
    }

    /// Compile WITHOUT epilogue fusion: every graph node becomes its own
    /// step (step id == node id), so every node's activation is
    /// materialized in the arena. This is the training-forward mode — the
    /// backward plan checkpoints the subset of activations its gradient
    /// kernels read (see [`super::backward::BackwardPlan`]); fused plans
    /// stay the inference hot path.
    pub fn compile_unfused(g: &Graph) -> Result<FloatPlan, PlanError> {
        let plan = Self::compile_inner(g, false)?;
        debug_assert!(plan.steps.iter().enumerate().all(|(s, st)| s == st.node));
        Ok(plan)
    }

    fn compile_inner(g: &Graph, fuse: bool) -> Result<FloatPlan, PlanError> {
        let input_shape = match g
            .nodes
            .iter()
            .find_map(|nd| match &nd.op {
                Op::Input { shape } => Some(shape.clone()),
                _ => None,
            }) {
            Some(s) => s,
            None => {
                return Err(PlanError::Invalid("float graph has no Input node".into()))
            }
        };
        let shapes1 = shape::infer_float(g, 1)?;
        let n = g.nodes.len();
        let mut fanout = vec![0usize; n];
        let mut consumers: Vec<Vec<NodeId>> = vec![Vec::new(); n];
        for nd in &g.nodes {
            for &i in &nd.inputs {
                fanout[i] += 1;
                consumers[i].push(nd.id);
            }
        }

        let absorb = |absorbed: &mut Vec<bool>,
                      chain: &mut Vec<NodeId>,
                      start: NodeId|
         -> (FloatEpilogue, NodeId) {
            let mut epi = FloatEpilogue::default();
            let mut cur = start;
            loop {
                if fanout[cur] != 1 || cur == g.output {
                    break;
                }
                let c = consumers[cur][0];
                match &g.nodes[c].op {
                    Op::BatchNorm { bn } if epi.is_empty() => {
                        epi.affine = Some(bn.affine());
                    }
                    Op::QuantBn { kappa_hat, lambda_hat } if epi.is_empty() => {
                        epi.affine = Some((kappa_hat.clone(), lambda_hat.clone()));
                    }
                    Op::ReLU if epi.act.is_none() => {
                        epi.act = Some(FloatAct::Relu);
                    }
                    Op::PactAct { beta, bits } if epi.act.is_none() => {
                        epi.act =
                            Some(FloatAct::Pact(QuantSpec::activation(*beta, *bits)));
                    }
                    _ => break,
                }
                absorbed[c] = true;
                chain.push(c);
                cur = c;
            }
            (epi, cur)
        };

        let mut absorbed = vec![false; n];
        let mut node_step: Vec<Option<StepId>> = vec![None; n];
        let mut steps: Vec<FloatStep> = Vec::new();
        let mut sample_shapes: Vec<Vec<usize>> = Vec::new();
        let mut fused_away = 0usize;
        for nd in &g.nodes {
            if absorbed[nd.id] {
                continue;
            }
            let mut chain: Vec<NodeId> = Vec::new();
            let op = match &nd.op {
                Op::Input { .. } => FloatStepOp::Input,
                Op::Conv2d { w, bias, stride, pad } => {
                    let (epi, _) = if fuse {
                        absorb(&mut absorbed, &mut chain, nd.id)
                    } else {
                        (FloatEpilogue::default(), nd.id)
                    };
                    FloatStepOp::Conv {
                        wmat: ops::oihw_to_wmat(w),
                        bias: bias.clone(),
                        kh: w.shape()[2],
                        kw: w.shape()[3],
                        stride: *stride,
                        pad: *pad,
                        epi,
                    }
                }
                Op::Linear { w, bias } => {
                    let (epi, _) = if fuse {
                        absorb(&mut absorbed, &mut chain, nd.id)
                    } else {
                        (FloatEpilogue::default(), nd.id)
                    };
                    FloatStepOp::Linear { w: w.clone(), bias: bias.clone(), epi }
                }
                Op::Add => {
                    let (epi, _) = if fuse {
                        absorb(&mut absorbed, &mut chain, nd.id)
                    } else {
                        (FloatEpilogue::default(), nd.id)
                    };
                    FloatStepOp::Add { epi }
                }
                Op::BatchNorm { bn } => {
                    let (kappa, lambda) = bn.affine();
                    FloatStepOp::Affine { kappa, lambda }
                }
                Op::QuantBn { kappa_hat, lambda_hat } => FloatStepOp::Affine {
                    kappa: kappa_hat.clone(),
                    lambda: lambda_hat.clone(),
                },
                Op::ReLU => FloatStepOp::Relu,
                Op::PactAct { beta, bits } => FloatStepOp::Pact {
                    spec: QuantSpec::activation(*beta, *bits),
                },
                Op::MaxPool { k } => FloatStepOp::MaxPool { k: *k },
                Op::AvgPool { k } => FloatStepOp::AvgPool { k: *k },
                Op::GlobalAvgPool => FloatStepOp::GlobalAvgPool,
                Op::Flatten => FloatStepOp::Flatten,
            };
            let anchor = chain.last().copied().unwrap_or(nd.id);
            let sid = steps.len();
            node_step[nd.id] = Some(sid);
            for &cid in &chain {
                node_step[cid] = Some(sid);
            }
            fused_away += chain.len();
            let inputs: Vec<StepId> = nd
                .inputs
                .iter()
                .map(|&i| node_step[i].expect("graph is topological"))
                .collect();
            sample_shapes.push(shapes1[anchor][1..].to_vec());
            steps.push(FloatStep {
                op,
                inputs,
                node: anchor,
                name: g.nodes[anchor].name.clone(),
            });
        }
        let output = node_step[g.output]
            .ok_or_else(|| PlanError::Invalid("output node unmapped".into()))?;
        Ok(FloatPlan {
            steps,
            output,
            sample_shapes,
            input_shape,
            fused_away,
        })
    }

    pub fn input_shape(&self) -> &[usize] {
        &self.input_shape
    }

    pub fn steps(&self) -> &[FloatStep] {
        &self.steps
    }

    pub fn fused_nodes(&self) -> usize {
        self.fused_away
    }

    pub fn layout(&self, batch: usize) -> Result<PlanLayout, PlanError> {
        if batch == 0 {
            return Err(PlanError::Invalid("batch size must be >= 1".into()));
        }
        let shapes: Vec<Vec<usize>> = self
            .sample_shapes
            .iter()
            .map(|ss| {
                let mut s = Vec::with_capacity(ss.len() + 1);
                s.push(batch);
                s.extend_from_slice(ss);
                s
            })
            .collect();
        let specs: Vec<StepSpec> = self
            .steps
            .iter()
            .enumerate()
            .map(|(i, st)| {
                let out_len: usize = shapes[i].iter().product();
                let scratch = match &st.op {
                    FloatStepOp::Conv { wmat, .. } => {
                        let rows = out_len / wmat.shape()[1];
                        vec![
                            (rows * wmat.shape()[0], Precision::I32),
                            (out_len, Precision::I32),
                        ]
                    }
                    _ => Vec::new(),
                };
                StepSpec {
                    inputs: st.inputs.clone(),
                    out_len,
                    // Float buffers have one width; precision tags are
                    // only meaningful for integer packed layouts.
                    out_prec: Precision::I32,
                    scratch,
                    is_input: matches!(st.op, FloatStepOp::Input),
                }
            })
            .collect();
        let (out_slot, scratch, slot_lens, slot_prec) =
            assign_slots(&specs, self.output);
        Ok(PlanLayout {
            batch,
            shapes,
            out_slot,
            scratch,
            slot_lens,
            slot_prec,
            packed: false,
        })
    }

    pub fn execute(
        &self,
        layout: &PlanLayout,
        arena: &mut FloatArena,
        x: &TensorF,
    ) -> TensorF {
        self.execute_inner(layout, arena, x, None)
    }

    pub fn execute_traced(
        &self,
        layout: &PlanLayout,
        arena: &mut FloatArena,
        x: &TensorF,
    ) -> Vec<(NodeId, TensorF)> {
        let mut trace = Vec::with_capacity(self.steps.len());
        let mut sink = |_sid: StepId, node: NodeId, shape: &[usize], data: &[f32]| {
            trace.push((node, Tensor::from_vec(shape, data.to_vec())));
        };
        self.execute_inner(layout, arena, x, Some(&mut sink));
        trace
    }

    /// Execute while checkpointing the step outputs selected by `keep`
    /// (indexed by step id) — the training-forward tape. For an unfused
    /// plan (step id == node id) the mask addresses graph nodes directly;
    /// unselected activations are never cloned out of the arena.
    pub fn execute_checkpointed(
        &self,
        layout: &PlanLayout,
        arena: &mut FloatArena,
        x: &TensorF,
        keep: &[bool],
    ) -> (TensorF, Vec<Option<TensorF>>) {
        let mut tape: Vec<Option<TensorF>> = Vec::new();
        tape.resize_with(self.steps.len(), || None);
        let mut sink = |sid: StepId, _node: NodeId, shape: &[usize], data: &[f32]| {
            if keep.get(sid).copied().unwrap_or(false) {
                tape[sid] = Some(Tensor::from_vec(shape, data.to_vec()));
            }
        };
        let out = self.execute_inner(layout, arena, x, Some(&mut sink));
        (out, tape)
    }

    fn execute_inner(
        &self,
        layout: &PlanLayout,
        arena: &mut FloatArena,
        x: &TensorF,
        mut sink: Option<&mut dyn FnMut(StepId, NodeId, &[usize], &[f32])>,
    ) -> TensorF {
        assert_eq!(layout.batch, x.shape()[0], "layout batch != input batch");
        assert_eq!(
            &x.shape()[1..],
            &self.input_shape[..],
            "input sample shape mismatch"
        );
        arena.prepare(layout);
        for (sid, st) in self.steps.iter().enumerate() {
            let out_shape = &layout.shapes[sid];
            let out_len: usize = out_shape.iter().product();
            match &st.op {
                FloatStepOp::Input => {}
                FloatStepOp::Conv { wmat, bias, kh, kw, stride, pad, epi } => {
                    let (b, c, h, w) = {
                        let s = &layout.shapes[st.inputs[0]];
                        (s[0], s[1], s[2], s[3])
                    };
                    let co = wmat.shape()[1];
                    let kdim = wmat.shape()[0];
                    let m = out_len / co;
                    let cols_slot = layout.scratch[sid][0];
                    let rows_slot = layout.scratch[sid][1];
                    let out_slot = layout.out_slot[sid];
                    let mut cols = std::mem::take(&mut arena.bufs[cols_slot]);
                    {
                        let xin = slot_data(arena, layout, st.inputs[0], x);
                        ops::im2col_into(
                            xin, b, c, h, w, *kh, *kw, *stride, *pad, &mut cols,
                        );
                    }
                    let mut rows = std::mem::take(&mut arena.bufs[rows_slot]);
                    let epi_fn = float_epi_fn(bias.as_deref(), epi);
                    ops::matmul_f32_fused_into(
                        &cols[..m * kdim],
                        wmat.data(),
                        m,
                        kdim,
                        co,
                        &epi_fn,
                        &mut rows,
                    );
                    let mut out = std::mem::take(&mut arena.bufs[out_slot]);
                    ops::rows_to_nchw_into(
                        &rows[..m * co],
                        b,
                        co,
                        out_shape[2],
                        out_shape[3],
                        &mut out,
                    );
                    arena.bufs[cols_slot] = cols;
                    arena.bufs[rows_slot] = rows;
                    arena.bufs[out_slot] = out;
                }
                FloatStepOp::Linear { w, bias, epi } => {
                    let in_shape = &layout.shapes[st.inputs[0]];
                    let (bsz, fi) = (in_shape[0], in_shape[1]);
                    let fo = w.shape()[1];
                    let out_slot = layout.out_slot[sid];
                    let mut out = std::mem::take(&mut arena.bufs[out_slot]);
                    {
                        let xin = slot_data(arena, layout, st.inputs[0], x);
                        let epi_fn = float_epi_fn(bias.as_deref(), epi);
                        ops::matmul_f32_fused_into(
                            &xin[..bsz * fi],
                            w.data(),
                            bsz,
                            fi,
                            fo,
                            &epi_fn,
                            &mut out,
                        );
                    }
                    arena.bufs[out_slot] = out;
                }
                FloatStepOp::Affine { kappa, lambda } => {
                    self.unary(layout, arena, x, sid, |in_shape, xin, out| {
                        let (c, hw) = channel_stride(in_shape);
                        for (i, o) in out.iter_mut().enumerate() {
                            let ch = (i / hw) % c;
                            *o = kappa[ch] as f32 * xin[i] + lambda[ch] as f32;
                        }
                    });
                }
                FloatStepOp::Relu => {
                    self.unary(layout, arena, x, sid, |_, xin, out| {
                        for (o, &v) in out.iter_mut().zip(xin) {
                            *o = v.max(0.0);
                        }
                    });
                }
                FloatStepOp::Pact { spec } => {
                    self.unary(layout, arena, x, sid, |_, xin, out| {
                        for (o, &v) in out.iter_mut().zip(xin) {
                            *o = spec.fake_quantize(v as f64) as f32;
                        }
                    });
                }
                FloatStepOp::MaxPool { k } => {
                    self.unary(layout, arena, x, sid, |in_shape, xin, out| {
                        let (b, c, h, w) =
                            (in_shape[0], in_shape[1], in_shape[2], in_shape[3]);
                        ops::maxpool_into(xin, b, c, h, w, *k, out);
                    });
                }
                FloatStepOp::AvgPool { k } => {
                    self.unary(layout, arena, x, sid, |in_shape, xin, out| {
                        let (b, c, h, w) =
                            (in_shape[0], in_shape[1], in_shape[2], in_shape[3]);
                        ops::avgpool_f32_into(xin, b, c, h, w, *k, out);
                    });
                }
                FloatStepOp::GlobalAvgPool => {
                    self.unary(layout, arena, x, sid, |in_shape, xin, out| {
                        let (b, c, h, w) =
                            (in_shape[0], in_shape[1], in_shape[2], in_shape[3]);
                        ops::global_mean_f32_into(xin, b, c, h, w, out);
                    });
                }
                FloatStepOp::Flatten => {
                    self.unary(layout, arena, x, sid, |_, xin, out| {
                        out.copy_from_slice(&xin[..out.len()]);
                    });
                }
                FloatStepOp::Add { epi } => {
                    let out_slot = layout.out_slot[sid];
                    let mut out = std::mem::take(&mut arena.bufs[out_slot]);
                    {
                        let out = &mut out[..out_len];
                        let r0 = slot_data(arena, layout, st.inputs[0], x);
                        out.copy_from_slice(&r0[..out_len]);
                        for &inp in st.inputs.iter().skip(1) {
                            let bx = slot_data(arena, layout, inp, x);
                            for (a, &bv) in out.iter_mut().zip(&bx[..out_len]) {
                                *a += bv;
                            }
                        }
                        if !epi.is_empty() {
                            let (c, hw) = channel_stride(out_shape);
                            for (i, v) in out.iter_mut().enumerate() {
                                *v = epi.apply((i / hw) % c, *v);
                            }
                        }
                    }
                    arena.bufs[out_slot] = out;
                }
            }
            if let Some(sink) = sink.as_mut() {
                let data = slot_data(arena, layout, sid, x);
                sink(sid, st.node, out_shape, &data[..out_len]);
            }
        }
        let shape = &layout.shapes[self.output];
        let len: usize = shape.iter().product();
        Tensor::from_vec(shape, slot_data(arena, layout, self.output, x)[..len].to_vec())
    }

    fn unary(
        &self,
        layout: &PlanLayout,
        arena: &mut FloatArena,
        x: &TensorF,
        sid: StepId,
        f: impl FnOnce(&[usize], &[f32], &mut [f32]),
    ) {
        let st = &self.steps[sid];
        let out_len: usize = layout.shapes[sid].iter().product();
        let out_slot = layout.out_slot[sid];
        let mut out = std::mem::take(&mut arena.bufs[out_slot]);
        {
            let in_shape = &layout.shapes[st.inputs[0]];
            let xin = slot_data(arena, layout, st.inputs[0], x);
            f(in_shape, xin, &mut out[..out_len]);
        }
        arena.bufs[out_slot] = out;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::bn::BnParams;

    fn conv_bn_act_graph() -> IntGraph {
        let mut g = IntGraph::default();
        let spec = QuantSpec { eps: 1.0 / 255.0, lo: 0, hi: 255 };
        let x = g.push("in", IntOp::Input { shape: vec![1, 4, 4], spec }, &[]);
        let wq =
            Tensor::from_vec(&[9, 2], (0..18).map(|i| (i % 5) as i32 - 2).collect()).into();
        let c = g.push(
            "conv",
            IntOp::ConvInt {
                wq,
                bias_q: Some(vec![3, -3]),
                cin: 1,
                kh: 3,
                kw: 3,
                stride: 1,
                pad: 1,
            },
            &[x],
        );
        let bn = BnQuant {
            kappa_q: vec![2, 3],
            lambda_q: vec![5, -5],
            eps_kappa: 0.01,
            eps_phi_out: 0.001,
        };
        let b = g.push("bn", IntOp::IntBn { bn }, &[c]);
        let rq = Requant { m: 3, d: 2, lo: 0, hi: 255 };
        g.push("act", IntOp::RequantAct { rq }, &[b]);
        g
    }

    #[test]
    fn conv_chain_fuses_into_one_step() {
        let g = conv_bn_act_graph();
        let plan = IntPlan::compile(&g).unwrap();
        // Input + fused conv = 2 steps; bn + act absorbed.
        assert_eq!(plan.steps().len(), 2);
        assert_eq!(plan.fused_nodes(), 2);
        assert_eq!(plan.steps()[1].fused_depth(), 2);
        assert_eq!(plan.steps()[1].node, g.output);
    }

    #[test]
    fn fused_execution_matches_interpreter() {
        let g = conv_bn_act_graph();
        let plan = IntPlan::compile(&g).unwrap();
        let layout = plan.layout(2).unwrap();
        let mut arena = IntArena::new();
        let qx = Tensor::from_vec(&[2, 1, 4, 4], (0..32).map(|i| i * 7 % 256).collect());
        let got = plan.execute(&layout, &mut arena, &qx);
        let want = crate::engine::IntegerEngine::new().run_interpreted(&g, &qx);
        assert_eq!(got, want);
        // and again with the now-dirty arena (buffer reuse must not leak)
        let got2 = plan.execute(&layout, &mut arena, &qx);
        assert_eq!(got2, want);
    }

    #[test]
    fn traced_execution_anchors_match_interpreter_nodes() {
        let g = conv_bn_act_graph();
        let plan = IntPlan::compile(&g).unwrap();
        let layout = plan.layout(1).unwrap();
        let mut arena = IntArena::new();
        let qx = Tensor::from_vec(&[1, 1, 4, 4], (0..16).map(|i| i * 11 % 256).collect());
        let interp = crate::engine::IntegerEngine::new().run_traced(&g, &qx);
        for (node, t) in plan.execute_traced(&layout, &mut arena, &qx) {
            assert_eq!(t, interp[node], "step anchored at node {node}");
        }
    }

    #[test]
    fn packed_execution_matches_i32_and_interpreter() {
        let g = conv_bn_act_graph();
        let plan = IntPlan::compile(&g).unwrap();
        assert!(plan.has_packed_steps());
        // input U8, fused conv chain ends at a [0,255] requant -> U8.
        assert_eq!(plan.input_precision(), Precision::U8);
        assert_eq!(plan.step_precisions(), &[Precision::U8, Precision::U8]);
        let layout = plan.packed_layout(2).unwrap();
        assert!(layout.is_packed());
        let mut arena = PackedArena::new();
        let qx = Tensor::from_vec(&[2, 1, 4, 4], (0..32).map(|i| i * 7 % 256).collect());
        let want = crate::engine::IntegerEngine::new().run_interpreted(&g, &qx);
        for round in 0..2 {
            let got = plan.execute_packed(&layout, &mut arena, &qx);
            assert_eq!(got, want, "round {round}");
        }
        // Packed arena is byte-sized: strictly smaller than the i32 one.
        let wide = plan.layout(2).unwrap();
        assert!(
            layout.arena_bytes() < wide.arena_bytes(),
            "packed {} B vs i32 {} B",
            layout.arena_bytes(),
            wide.arena_bytes()
        );
    }

    #[test]
    fn packed_traced_matches_interpreter_nodes() {
        let g = conv_bn_act_graph();
        let plan = IntPlan::compile(&g).unwrap();
        let layout = plan.packed_layout(1).unwrap();
        let mut arena = PackedArena::new();
        let qx = Tensor::from_vec(&[1, 1, 4, 4], (0..16).map(|i| i * 11 % 256).collect());
        let interp = crate::engine::IntegerEngine::new().run_traced(&g, &qx);
        for (node, t) in plan.execute_packed_traced(&layout, &mut arena, &qx) {
            assert_eq!(t, interp[node], "packed step anchored at node {node}");
        }
    }

    fn subbyte_conv_graph() -> IntGraph {
        // 2-bit input grid, ternary weights, 2-bit requant output: both
        // steps stamp U2 and the conv GEMM takes the bit-serial path.
        let mut g = IntGraph::default();
        let spec = QuantSpec { eps: 1.0 / 3.0, lo: 0, hi: 3 };
        let x = g.push("in", IntOp::Input { shape: vec![1, 4, 4], spec }, &[]);
        let wq =
            Tensor::from_vec(&[9, 2], (0..18).map(|i| (i % 3) as i32 - 1).collect()).into();
        let c = g.push(
            "conv",
            IntOp::ConvInt {
                wq,
                bias_q: Some(vec![1, -1]),
                cin: 1,
                kh: 3,
                kw: 3,
                stride: 1,
                pad: 1,
            },
            &[x],
        );
        let rq = Requant { m: 3, d: 4, lo: 0, hi: 3 };
        g.push("act", IntOp::RequantAct { rq }, &[c]);
        g
    }

    #[test]
    fn subbyte_packed_execution_matches_interpreter() {
        let g = subbyte_conv_graph();
        let plan = IntPlan::compile(&g).unwrap();
        assert_eq!(plan.input_precision(), Precision::U2);
        assert_eq!(plan.step_precisions(), &[Precision::U2, Precision::U2]);
        assert_eq!(plan.bitserial_steps(), 1);
        let layout = plan.packed_layout(2).unwrap();
        let mut arena = PackedArena::new();
        let qx = Tensor::from_vec(&[2, 1, 4, 4], (0..32).map(|i| i % 4).collect());
        let want = crate::engine::IntegerEngine::new().run_interpreted(&g, &qx);
        for round in 0..2 {
            let got = plan.execute_packed(&layout, &mut arena, &qx);
            assert_eq!(got, want, "round {round}");
        }
        // Every slot is 2-bit: the packed arena is >= 4x smaller than the
        // full-width one.
        let wide = plan.layout(2).unwrap();
        assert!(
            layout.arena_bytes() * 4 <= wide.arena_bytes(),
            "packed {} B vs i32 {} B",
            layout.arena_bytes(),
            wide.arena_bytes()
        );
    }

    #[test]
    fn subbyte_traced_matches_interpreter_nodes() {
        let g = subbyte_conv_graph();
        let plan = IntPlan::compile(&g).unwrap();
        let layout = plan.packed_layout(1).unwrap();
        let mut arena = PackedArena::new();
        let qx = Tensor::from_vec(&[1, 1, 4, 4], (0..16).map(|i| i * 3 % 4).collect());
        let interp = crate::engine::IntegerEngine::new().run_traced(&g, &qx);
        for (node, t) in plan.execute_packed_traced(&layout, &mut arena, &qx) {
            assert_eq!(t, interp[node], "sub-byte step anchored at node {node}");
        }
    }

    #[test]
    fn nibble_linear_matches_interpreter() {
        // 4-bit activations keep the MAC path (no bit planes by policy)
        // but stream nibble-packed buffers end to end.
        let mut g = IntGraph::default();
        let spec = QuantSpec { eps: 1.0 / 15.0, lo: 0, hi: 15 };
        let x = g.push("in", IntOp::Input { shape: vec![6], spec }, &[]);
        let wq =
            Tensor::from_vec(&[6, 3], (0..18).map(|i| (i % 11) as i32 - 5).collect()).into();
        let fc = g.push(
            "fc",
            IntOp::LinearInt { wq, bias_q: Some(vec![4, 0, -4]) },
            &[x],
        );
        let rq = Requant { m: 5, d: 6, lo: 0, hi: 15 };
        g.push("act", IntOp::RequantAct { rq }, &[fc]);
        let plan = IntPlan::compile(&g).unwrap();
        assert_eq!(plan.step_precisions(), &[Precision::U4, Precision::U4]);
        assert_eq!(plan.bitserial_steps(), 0);
        let layout = plan.packed_layout(3).unwrap();
        let mut arena = PackedArena::new();
        let qx = Tensor::from_vec(&[3, 6], (0..18).map(|i| i % 16).collect());
        let want = crate::engine::IntegerEngine::new().run_interpreted(&g, &qx);
        for round in 0..2 {
            let got = plan.execute_packed(&layout, &mut arena, &qx);
            assert_eq!(got, want, "round {round}");
        }
    }

    #[test]
    fn packed_and_wide_layouts_reject_wrong_execute() {
        let g = conv_bn_act_graph();
        let plan = IntPlan::compile(&g).unwrap();
        let qx = Tensor::from_vec(&[1, 1, 4, 4], vec![0; 16]);
        let packed = plan.packed_layout(1).unwrap();
        let wide = plan.layout(1).unwrap();
        assert!(std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            plan.execute(&packed, &mut IntArena::new(), &qx)
        }))
        .is_err());
        assert!(std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            plan.execute_packed(&wide, &mut PackedArena::new(), &qx)
        }))
        .is_err());
    }

    #[test]
    fn fully_wide_graph_has_no_packed_steps() {
        // 9-bit-style input and unclipped linear output: nothing packs.
        let mut g = IntGraph::default();
        let spec = QuantSpec { eps: 1.0, lo: 0, hi: 511 };
        let x = g.push("in", IntOp::Input { shape: vec![2], spec }, &[]);
        let wq = Tensor::from_vec(&[2, 2], vec![300, 0, 0, 300]).into();
        g.push("fc", IntOp::LinearInt { wq, bias_q: None }, &[x]);
        let plan = IntPlan::compile(&g).unwrap();
        assert!(!plan.has_packed_steps());
        // The packed path still runs wide graphs correctly (fallback).
        let qx = Tensor::from_vec(&[1, 2], vec![500, 17]);
        let layout = plan.packed_layout(1).unwrap();
        let mut arena = PackedArena::new();
        let got = plan.execute_packed(&layout, &mut arena, &qx);
        let want = crate::engine::IntegerEngine::new().run_interpreted(&g, &qx);
        assert_eq!(got, want);
    }

    #[test]
    fn output_slot_is_never_recycled() {
        // Chain long enough for slot reuse to kick in.
        let g = conv_bn_act_graph();
        let plan = IntPlan::compile(&g).unwrap();
        let layout = plan.layout(1).unwrap();
        // Arena is bounded: at most cols + rows + two live activations.
        assert!(layout.arena_slots() <= 4, "slots = {}", layout.arena_slots());
    }

    #[test]
    fn float_plan_matches_interpreter() {
        let mut g = Graph::new(1.0 / 255.0);
        let x = g.push("in", Op::Input { shape: vec![1, 4, 4] }, &[]);
        let w = Tensor::from_vec(
            &[2, 1, 3, 3],
            (0..18).map(|i| (i as f32 - 9.0) * 0.1).collect(),
        );
        let c = g.push("c", Op::Conv2d { w, bias: Some(vec![0.1, -0.1]), stride: 1, pad: 1 }, &[x]);
        let b = g.push("bn", Op::BatchNorm { bn: BnParams::identity(2) }, &[c]);
        g.push("a", Op::ReLU, &[b]);
        let plan = FloatPlan::compile(&g).unwrap();
        assert_eq!(plan.steps().len(), 2);
        let layout = plan.layout(3).unwrap();
        let mut arena = FloatArena::new();
        let xin = Tensor::from_vec(
            &[3, 1, 4, 4],
            (0..48).map(|i| (i as f32) * 0.02 - 0.4).collect(),
        );
        let got = plan.execute(&layout, &mut arena, &xin);
        let want = crate::engine::FloatEngine::new().run_interpreted(&g, &xin);
        assert_eq!(got.data(), want.data());
    }

    #[test]
    fn compile_rejects_missing_input() {
        let mut g = IntGraph::default();
        let wq = Tensor::from_vec(&[1, 1], vec![1]).into();
        g.push("fc", IntOp::LinearInt { wq, bias_q: None }, &[]);
        assert!(IntPlan::compile(&g).is_err());
    }
}
