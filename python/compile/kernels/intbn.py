"""Integer batch-normalization Pallas kernel (Eq. 22).

    Q(phi) = Q(kappa) * Q(varphi) + Q(lambda)       per output channel

Operates on a [rows, C] view (NCHW tensors are transposed/reshaped by the
caller so channels are the minor axis — the TPU lane axis, letting the
per-channel kappa/lambda broadcast across sublanes). The product is
computed in int64 and narrowed back after a range check: with the default
kappa_bits = 8 the result fits int32 (|kappa| < 2^7, |varphi| < 2^24 by the
pipeline's range analysis).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import INT, WIDE, INTERPRET, cdiv, pad_to


def _intbn_kernel(q_ref, kappa_ref, lambda_ref, o_ref):
    q = q_ref[...].astype(WIDE)
    kq = kappa_ref[...].astype(WIDE)[None, :]
    lq = lambda_ref[...].astype(WIDE)[None, :]
    o_ref[...] = (q * kq + lq).astype(INT)


def intbn(q: jnp.ndarray, kappa_q: jnp.ndarray, lambda_q: jnp.ndarray, *,
          br: int = 256, bc: int = 64) -> jnp.ndarray:
    """q: [R, C] int32; kappa_q, lambda_q: [C] int32."""
    r, c = q.shape
    qp = pad_to(pad_to(q, 0, br), 1, bc)
    kp = pad_to(kappa_q, 0, bc)
    lp = pad_to(lambda_q, 0, bc)
    out = pl.pallas_call(
        _intbn_kernel,
        grid=(cdiv(r, br), cdiv(c, bc)),
        in_specs=[
            pl.BlockSpec((br, bc), lambda i, j: (i, j)),
            pl.BlockSpec((bc,), lambda i, j: (j,)),
            pl.BlockSpec((bc,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((br, bc), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct(qp.shape, INT),
        interpret=INTERPRET,
    )(qp, kp, lp)
    return out[:r, :c]
