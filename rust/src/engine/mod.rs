//! Graph executors (S5 in DESIGN.md).
//!
//! * [`FloatEngine`] runs FP / FQ / QD graphs on f32 tensors.
//! * [`IntegerEngine`] runs IntegerDeployable graphs using i32 integer
//!   images with i64 widening — no floating point on the value path. It
//!   is the simulator standing in for the paper's MCU integer datapath
//!   (DESIGN.md §Hardware-Adaptation).
//! * [`plan`] is the compile layer between graphs and engines: static
//!   shape inference, liveness-planned buffer arenas, and fused
//!   GEMM-epilogue kernels. `run` on either engine executes a compiled
//!   plan; the unfused interpreters remain as `run_interpreted` /
//!   `run_traced` diagnostic paths and as the bit-exactness reference.
//!
//! These are the raw single-call engines; for batched serving and
//! backend-interchangeable execution they are wrapped by the
//! [`crate::exec::Executor`] implementations, which compile one plan (and
//! one layout per batch variant) up front and pool arenas across
//! requests.
//!
//! [`backward`] extends the plan layer with reverse-mode gradients: a
//! [`BackwardPlan`] compiled from the same graph drives native training
//! (DESIGN.md §Training) without any external autodiff dependency.

pub mod backward;
pub mod float;
pub mod integer;
pub mod plan;

pub use backward::{BackwardPlan, BwdLayout};
pub use float::FloatEngine;
pub use integer::IntegerEngine;
pub use plan::{FloatArena, FloatPlan, GemmRouting, IntPlan, PackedArena, PlanError, PlanLayout};
