//! Activation calibration: set each PACT clipping bound beta_y to the max
//! value of y observed in the FullPrecision stage (paper sec. 2,
//! "In NEMO": beta "can be set to the maximum value of y in the
//! FullPrecision stage").

use crate::engine::FloatEngine;
use crate::graph::Graph;
use crate::tensor::TensorF;

/// Run the float graph over calibration batches and return, for each
/// activation node (in [`Graph::activations`] order), the maximum output
/// value observed (floored at a tiny positive value so eps_y > 0).
pub fn calibrate(g: &Graph, batches: &[TensorF]) -> Vec<f64> {
    let engine = FloatEngine::new();
    let acts = g.activations();
    let mut betas = vec![1e-6f64; acts.len()];
    for x in batches {
        let trace = engine.run_traced(g, x);
        for (ai, &node) in acts.iter().enumerate() {
            let m = trace[node]
                .data()
                .iter()
                .fold(f32::NEG_INFINITY, |m, v| m.max(*v)) as f64;
            if m > betas[ai] {
                betas[ai] = m;
            }
        }
    }
    betas
}

/// Percentile calibration: beta_y = the q-quantile of each activation's
/// outputs over the calibration batches. NEMO's policy is max (q = 1.0);
/// percentiles are far more robust to the single-outlier-channel problem
/// on trained networks (documented deviation, DESIGN.md sec. 5) — the
/// clipped tail is exactly what PACT's trainable beta would learn to cut.
pub fn calibrate_percentile(g: &Graph, batches: &[TensorF], q: f64) -> Vec<f64> {
    if q >= 1.0 {
        return calibrate(g, batches);
    }
    let engine = FloatEngine::new();
    let acts = g.activations();
    let mut collected: Vec<Vec<f32>> = vec![Vec::new(); acts.len()];
    for x in batches {
        let trace = engine.run_traced(g, x);
        for (ai, &node) in acts.iter().enumerate() {
            collected[ai].extend_from_slice(trace[node].data());
        }
    }
    collected
        .into_iter()
        .map(|mut vals| {
            vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
            if vals.is_empty() {
                return 1.0;
            }
            let idx = ((vals.len() - 1) as f64 * q).round() as usize;
            (vals[idx] as f64).max(1e-6)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Op;
    use crate::tensor::Tensor;

    #[test]
    fn calibration_tracks_max() {
        let mut g = Graph::new(1.0);
        let x = g.push("in", Op::Input { shape: vec![2] }, &[]);
        g.push("act", Op::ReLU, &[x]);
        let b1 = Tensor::from_vec(&[1, 2], vec![0.5f32, -1.0]);
        let b2 = Tensor::from_vec(&[1, 2], vec![3.25f32, 0.0]);
        let betas = calibrate(&g, &[b1, b2]);
        assert_eq!(betas, vec![3.25f64]);
    }

    #[test]
    fn all_negative_gives_positive_floor() {
        let mut g = Graph::new(1.0);
        let x = g.push("in", Op::Input { shape: vec![2] }, &[]);
        g.push("act", Op::ReLU, &[x]);
        let b = Tensor::from_vec(&[1, 2], vec![-1.0f32, -2.0]);
        let betas = calibrate(&g, &[b]);
        assert!(betas[0] > 0.0);
    }
}
