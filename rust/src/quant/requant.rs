//! Requantization (Def. 3.1, Eq. 12-14): moving an integer image from one
//! quantized space to another using only an integer multiply and an
//! arithmetic right shift.

use super::Precision;
use crate::tensor::TensorI;

/// Largest shift `choose_d` will try before declaring the Eq. 14 bound
/// unreachable. Beyond this the multiplier `m = eps_a*2^d/eps_b` no
/// longer buys precision and starts threatening the requant product
/// width, so saturation is an error, not a fallback.
pub const D_MAX: u32 = 40;

/// The Eq. 14 bound `eps_a * 2^d >= factor * eps_b` is unreachable
/// within [`D_MAX`] doublings: the requant ratio the pair of quanta
/// demands cannot be approximated within the paper's 1/eta error
/// guarantee. Deployment must reject the network instead of baking a
/// wrong `(m, d)` into the graph (and into saved artifacts).
#[derive(Clone, Copy, Debug, thiserror::Error)]
#[error(
    "choose_d saturated: eps_a={eps_a:.3e}, eps_b={eps_b:.3e}, \
     factor={factor} needs d > {D_MAX}, violating the 1/{factor} \
     requantization error guarantee (Eq. 14)"
)]
pub struct RequantSaturation {
    pub eps_a: f64,
    pub eps_b: f64,
    pub factor: u32,
}

/// Smallest d with eps_a * 2^d >= factor * eps_b (Eq. 14 with
/// eta = 1/factor). Exact doubling loop — identical to
/// quantlib.choose_d so both languages derive the same d, and both
/// reject saturation the same way (this errors, Python raises) when
/// the bound is unreachable within [`D_MAX`] doublings — the former
/// silent `d = 40` saturation produced requants violating the paper's
/// 1/eta error guarantee.
pub fn choose_d(
    eps_a: f64,
    eps_b: f64,
    requantization_factor: u32,
) -> Result<u32, RequantSaturation> {
    assert!(eps_a > 0.0 && eps_b > 0.0, "quanta must be positive");
    let target = requantization_factor as f64 * eps_b;
    let mut d = 0u32;
    let mut p = eps_a;
    while p < target && d < D_MAX {
        p *= 2.0;
        d += 1;
    }
    if p < target {
        return Err(RequantSaturation {
            eps_a,
            eps_b,
            factor: requantization_factor,
        });
    }
    Ok(d)
}

/// m = floor(eps_a * 2^d / eps_b) (Eq. 13).
pub fn multiplier(eps_a: f64, eps_b: f64, d: u32) -> i64 {
    (eps_a * (1u64 << d) as f64 / eps_b).floor() as i64
}

/// Requantization parameters for one space transition.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Requant {
    pub m: i64,
    pub d: u32,
    pub lo: i64,
    pub hi: i64,
}

impl Requant {
    /// Derive (m, d) from the source/target quanta and clip bounds
    /// (Eq. 13-14). `factor` is NEMO's requantization_factor (1/eta):
    /// 16 for activations, 256 for Adds. Errors when `choose_d`
    /// saturates (the ratio cannot meet the 1/factor error guarantee).
    pub fn derive(
        eps_a: f64,
        eps_b: f64,
        factor: u32,
        lo: i64,
        hi: i64,
    ) -> Result<Self, RequantSaturation> {
        let d = choose_d(eps_a, eps_b, factor)?;
        Ok(Requant { m: multiplier(eps_a, eps_b, d), d, lo, hi })
    }

    /// clip((m * q) >> d, lo, hi). The shift is arithmetic (floor toward
    /// -inf), matching Eq. 13's floor for negative values. The product
    /// is widened to i128: with d near [`D_MAX`], `m` can exceed 2^32
    /// and a legal i32-range accumulator would wrap the i64 product
    /// silently in release builds.
    #[inline]
    pub fn apply(&self, q: i64) -> i64 {
        let shifted = (self.m as i128 * q as i128) >> self.d;
        shifted.clamp(self.lo as i128, self.hi as i128) as i64
    }

    /// Requantize a whole integer tensor.
    pub fn apply_tensor(&self, q: &TensorI) -> TensorI {
        q.map(|v| self.apply(v as i64) as i32)
    }

    /// The real-valued ratio this requant approximates.
    pub fn approx_ratio(&self) -> f64 {
        self.m as f64 / (1u64 << self.d) as f64
    }

    /// Storage precision of the requantized output — the clip bounds
    /// [lo, hi] *are* the output's provable value range, so an 8-bit
    /// activation requant ([0, 255]) packs to `U8` while an unclipped
    /// Add-branch requant stays `I32`.
    pub fn output_precision(&self) -> Precision {
        Precision::for_range(self.lo, self.hi)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::prop_check;

    #[test]
    fn eq14_bound_and_minimality() {
        prop_check(500, |rng| {
            let eps_a = (-rng.uniform(2.0, 14.0)).exp2();
            let eps_b = (-rng.uniform(1.0, 10.0)).exp2();
            let factor = [16u32, 64, 256][rng.int(0, 3) as usize];
            let d = match choose_d(eps_a, eps_b, factor) {
                Ok(d) => d,
                Err(_) => return Ok(()), // saturation is a typed error now
            };
            if eps_a * ((1u64 << d) as f64) < factor as f64 * eps_b {
                return Err(format!("bound violated: d={d}"));
            }
            if d > 0 && eps_a * ((1u64 << (d - 1)) as f64) >= factor as f64 * eps_b {
                return Err(format!("not minimal: d={d}"));
            }
            Ok(())
        });
    }

    #[test]
    fn choose_d_saturation_is_a_typed_error() {
        // eps_a tiny, eps_b huge: the Eq. 14 bound needs d > 40. The old
        // code silently returned d = 40 and a requant whose ratio
        // violated the 1/eta guarantee; now it is a RequantSaturation.
        let err = choose_d(1e-300, 1.0, 16).unwrap_err();
        assert_eq!(err.factor, 16);
        assert!(err.to_string().contains("saturated"), "{err}");
        assert!(Requant::derive(1e-300, 1.0, 16, 0, 255).is_err());
        // A reachable bound still derives fine.
        assert!(choose_d(3.1e-5, 0.02, 16).is_ok());
    }

    #[test]
    fn apply_survives_i64_product_overflow() {
        // Regression: m > 2^32 times a legal i32-range accumulator
        // overflows the old i64 product (2^33 * (2^31-1) > 2^63) and
        // wrapped to a negative value in release builds. The i128
        // widening must give the mathematically exact shifted product.
        let rq = Requant { m: 1i64 << 33, d: 40, lo: i64::MIN, hi: i64::MAX };
        let q = i32::MAX as i64;
        // (2^33 * (2^31 - 1)) >> 40 = (2^64 - 2^33) >> 40 = 2^24 - 1
        assert_eq!(rq.apply(q), (1i64 << 24) - 1);
        // Negative side floors toward -inf.
        assert_eq!(rq.apply(-q), -(1i64 << 24));
        // Clip bounds still apply after the exact shift.
        let clipped = Requant { m: 1i64 << 33, d: 40, lo: 0, hi: 255 };
        assert_eq!(clipped.apply(q), 255);
        assert_eq!(clipped.apply(-q), 0);
    }

    #[test]
    fn relative_error_bounded_by_eta() {
        // |eps_a/eps_b - m/2^d| / (eps_a/eps_b) <= 1/factor (sec. 3.2)
        prop_check(500, |rng| {
            let eps_a = rng.uniform(1e-7, 1e-1);
            let eps_b = rng.uniform(1e-7, 1e-1);
            let factor = 16u32;
            let Ok(d) = choose_d(eps_a, eps_b, factor) else {
                return Ok(());
            };
            let m = multiplier(eps_a, eps_b, d);
            let ratio = eps_a / eps_b;
            let approx = m as f64 / (1u64 << d) as f64;
            let rel = (ratio - approx).abs() / ratio;
            if rel > 1.0 / factor as f64 + 1e-12 {
                return Err(format!("rel err {rel} > 1/{factor}"));
            }
            Ok(())
        });
    }

    #[test]
    fn arithmetic_shift_floors_negatives() {
        let rq = Requant { m: 1, d: 8, lo: -100, hi: 100 };
        assert_eq!(rq.apply(-1), -1);
        assert_eq!(rq.apply(-256), -1);
        assert_eq!(rq.apply(-257), -2);
        assert_eq!(rq.apply(255), 0);
        assert_eq!(rq.apply(256), 1);
    }

    #[test]
    fn requant_approximates_ideal_scaling() {
        // RQ(q) ~ q * eps_a/eps_b within |q|/D + 1 (sec. 3.2 error bound).
        prop_check(300, |rng| {
            let eps_a = rng.uniform(1e-6, 1e-2);
            let eps_b = rng.uniform(1e-4, 1e-1);
            let rq = Requant::derive(eps_a, eps_b, 16, i64::MIN, i64::MAX)
                .expect("bound reachable in this eps range");
            let q = rng.int(-(1 << 24), 1 << 24);
            let got = rq.apply(q) as f64;
            let ideal = q as f64 * eps_a / eps_b;
            let bound = (q.abs() as f64) / (1u64 << rq.d) as f64 + 1.0;
            if (got - ideal).abs() > bound {
                return Err(format!(
                    "ideal {ideal} got {got} bound {bound} (m={} d={})",
                    rq.m, rq.d
                ));
            }
            Ok(())
        });
    }

    #[test]
    fn derive_matches_python_constants() {
        // One pinned case also present in goldens (belt and braces).
        let d = choose_d(3.1e-5, 0.02, 16).unwrap();
        let m = multiplier(3.1e-5, 0.02, d);
        // 0.02*16/3.1e-5 = 10322.6 -> 2^14 = 16384 -> d = 14
        assert_eq!(d, 14);
        assert_eq!(m, (3.1e-5 * 16384.0f64 / 0.02) as i64);
    }
}
