//! Representation transforms (S4 in DESIGN.md) — the math behind the
//! typed pipeline in [`crate::network`]:
//!
//! | NEMO (paper "In NEMO" boxes)          | here                          |
//! |---------------------------------------|-------------------------------|
//! | `nemo.transform.quantize_pact`        | `Network::quantize_pact`      |
//! | `net.fold_bn()` + `reset_alpha...`    | `Network::fold_bn`            |
//! | `nemo.transform.bn_quantizer`         | inside `Network::deploy`      |
//! | `net.harden_weights()`                | inside `Network::deploy`      |
//! | `net.set_deployment(eps_in=...)`      | eps propagation in deploy     |
//! | `nemo.transform.integerize_pact`      | `Network::integerize`         |
//! | `net.add_input_bias()`                | [`fold::add_input_bias`]      |
//!
//! The transform entry points live on [`crate::network::Network`]: the
//! untyped free-function shims (`quantize_pact`, `fold_bn`, `deploy`)
//! that survived one release as deprecated aliases are gone — they let a
//! caller deploy an uncalibrated FP graph or fold BN twice, which the
//! typed pipeline makes unrepresentable. The implementations remain here
//! as crate-private `*_impl` functions behind the typed API.
//!
//! The pipeline's extra safety pass — integer range analysis proving all
//! i32 narrowing is sound — has no NEMO equivalent; it stands in for the
//! "deployment backend" checks the paper delegates to the target.

pub mod calibrate;
pub mod deploy;
pub mod fold;

pub use calibrate::{calibrate, calibrate_percentile};
pub use deploy::{DeployOptions, Deployed, LayerQuant};
pub use fold::add_input_bias;

use crate::graph::{Graph, Op};
use crate::quant::{harden_tensor, max_abs, QuantSpec};

#[derive(Debug, thiserror::Error)]
pub enum TransformError {
    #[error("deployment requires PACT activations; found {0} (run quantize_pact first)")]
    NeedsFakeQuant(&'static str),
    #[error("integer range overflow in {node}: worst-case |acc| = {worst} > 2^31")]
    RangeOverflow { node: String, worst: i64 },
    #[error("requantization at {node}: {source}")]
    RequantSaturated {
        node: String,
        #[source]
        source: crate::quant::requant::RequantSaturation,
    },
    #[error(
        "precision proof failed at {node}: stamped {precision} cannot hold the \
         analyzed range [{qmin}, {qmax}]"
    )]
    PrecisionProof {
        node: String,
        precision: &'static str,
        qmin: i64,
        qmax: i64,
    },
    #[error("statically unsound integer graph at '{node}' [{rule}]: {detail}")]
    Unsound {
        node: String,
        rule: &'static str,
        detail: String,
    },
    #[error("unsupported op in {0} representation: {1}")]
    Unsupported(&'static str, &'static str),
    #[error("graph error: {0}")]
    Graph(#[from] crate::graph::GraphError),
    #[error("add_input_bias: {0}")]
    InputBias(String),
    #[error("batch norm already folded in this network (fold_bn is not idempotent)")]
    AlreadyFolded,
    #[error("stage transition: {0}")]
    Stage(String),
}

/// FullPrecision -> FakeQuantized (sec. 2): replace every ReLU with a
/// PACT quantization/activation at the calibrated clipping bound, and
/// put Linear weights on their symmetric fake-quantization grid.
///
/// `act_betas` must have one entry per activation node (see
/// [`Graph::activations`]), typically from [`calibrate`]. Crate-private:
/// the public entry point is `network::Network::<FullPrecision>::
/// quantize_pact`, which checks the beta count and records stage
/// metadata.
pub(crate) fn quantize_pact_impl(
    g: &Graph,
    wbits: u32,
    abits: u32,
    act_betas: &[f64],
) -> Graph {
    let mut out = g.clone();
    let mut act_i = 0usize;
    for n in &mut out.nodes {
        match &mut n.op {
            Op::Conv2d { w, .. } | Op::Linear { w, .. } => {
                let spec = QuantSpec::weight(max_abs(w), wbits);
                *w = harden_tensor(w, &spec);
            }
            Op::ReLU => {
                n.op = Op::PactAct { beta: act_betas[act_i], bits: abits };
                act_i += 1;
            }
            Op::PactAct { beta, bits } => {
                *beta = act_betas[act_i];
                *bits = abits;
                act_i += 1;
            }
            _ => {}
        }
    }
    assert_eq!(act_i, act_betas.len(), "one beta per activation");
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::FloatEngine;
    use crate::tensor::Tensor;

    #[test]
    fn quantize_pact_replaces_relu_and_hardens() {
        let mut g = Graph::new(1.0 / 255.0);
        let x = g.push("in", Op::Input { shape: vec![2] }, &[]);
        let w = Tensor::from_vec(&[2, 2], vec![0.31f32, -0.77, 0.5, 0.2]);
        let l = g.push("fc", Op::Linear { w, bias: None }, &[x]);
        g.push("act", Op::ReLU, &[l]);

        let fq = quantize_pact_impl(&g, 4, 4, &[2.0]);
        match &fq.nodes[2].op {
            Op::PactAct { beta, bits } => {
                assert_eq!(*beta, 2.0);
                assert_eq!(*bits, 4);
            }
            op => panic!("expected PactAct, got {}", op.name()),
        }
        // hardened weights live on the eps_w grid
        match &fq.nodes[1].op {
            Op::Linear { w, .. } => {
                let spec = QuantSpec::weight(0.77, 4);
                for v in w.data() {
                    let q = (*v as f64) / spec.eps;
                    assert!((q - q.round()).abs() < 1e-6, "{v} not on grid");
                }
            }
            _ => unreachable!(),
        }
        // still runs
        let out = FloatEngine::new().run(&fq, &Tensor::from_vec(&[1, 2], vec![0.5f32, 0.5]));
        assert_eq!(out.shape(), &[1, 2]);
    }
}
