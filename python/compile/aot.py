"""AOT exporter: lower every L2 graph to HLO *text* + write manifest/goldens.

Run once by `make artifacts`:

    cd python && python -m compile.aot --out ../artifacts

Outputs:
  artifacts/<name>.hlo.txt   — HLO text modules (the interchange format:
                               jax >= 0.5 serialized protos use 64-bit ids
                               which xla_extension 0.5.1 rejects; the text
                               parser reassigns ids and round-trips).
  artifacts/manifest.json    — arch config + per-artifact argument list
                               (name/shape/dtype) + output counts, so the
                               Rust runtime assembles buffers by name.
  artifacts/goldens.json     — cross-language golden vectors: derived
                               integer deployment parameters and expected
                               outputs for bit-exact Rust validation.

Python never runs after this; the Rust binary is self-contained.
"""

from __future__ import annotations

import argparse
import functools
import json
import os

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import deploy as dp
from . import model as M
from . import quantlib as ql
from .kernels import ref as kref
from .kernels.avgpool import avgpool as k_avgpool
from .kernels.intbn import intbn as k_intbn
from .kernels.qgemm import qgemm as k_qgemm
from .kernels.requant import requant as k_requant
from .kernels.thresh import thresh as k_thresh

F32 = jnp.float32
I32 = jnp.int32


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def _spec(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _arg_entries(names, specs):
    return [dict(name=n, shape=list(s.shape), dtype=str(np.dtype(s.dtype)))
            for n, s in zip(names, specs)]


class Exporter:
    def __init__(self, out_dir: str):
        self.out_dir = out_dir
        self.manifest = dict(arch=M.ARCH, artifacts=[])

    def export(self, name: str, fn, arg_names, arg_specs, n_outputs: int,
               meta=None):
        lowered = jax.jit(fn).lower(*arg_specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(self.out_dir, fname), "w") as f:
            f.write(text)
        entry = dict(name=name, file=fname,
                     args=_arg_entries(arg_names, arg_specs),
                     n_outputs=n_outputs)
        if meta:
            entry.update(meta)
        self.manifest["artifacts"].append(entry)
        print(f"  {fname:48s} {len(text) // 1024:6d} KiB, "
              f"{len(arg_specs)} args -> {n_outputs} outputs")


# ---------------------------------------------------------------------------
# Model artifact definitions
# ---------------------------------------------------------------------------


def export_models(ex: Exporter):
    pspec = M.param_spec()
    sspec = M.bn_state_spec()
    aspec = M.act_beta_spec()
    np_, ns_, na_ = len(pspec), len(sspec), len(aspec)

    def fp_fwd_flat(*flat):
        x = flat[-1]
        return tuple([M.fp_fwd(flat[:np_], flat[np_:np_ + ns_], x)])

    def fq_fwd_flat(wbits, abits, *flat):
        x = flat[-1]
        return tuple([M.fq_fwd(flat[:np_], flat[np_:np_ + ns_],
                               flat[np_ + ns_:np_ + ns_ + na_], x,
                               wbits=wbits, abits=abits)])

    def qd_fwd_flat(*flat):
        return tuple([M.qd_fwd(flat[:-1], flat[-1])])

    def id_fwd_flat(*flat):
        return tuple([M.id_fwd(flat[:-1], flat[-1])])

    def fp_train_flat(*flat):
        params = flat[:np_]
        state = flat[np_:np_ + ns_]
        x, y, lr = flat[-3], flat[-2], flat[-1]
        p2, s2, loss = M.fp_train_step(params, state, x, y, lr)
        return tuple(list(p2) + list(s2) + [loss])

    def fq_train_flat(wbits, abits, *flat):
        params = flat[:np_]
        state = flat[np_:np_ + ns_]
        betas = flat[np_ + ns_:np_ + ns_ + na_]
        x, y, lr = flat[-3], flat[-2], flat[-1]
        p2, s2, b2, loss = M.fq_train_step(params, state, betas, x, y, lr,
                                           wbits=wbits, abits=abits)
        return tuple(list(p2) + list(s2) + list(b2) + [loss])

    pnames = [n for n, _ in pspec]
    pspecs = [_spec(s, F32) for _, s in pspec]
    snames = [n for n, _ in sspec]
    sspecs = [_spec(s, F32) for _, s in sspec]
    anames = [n for n, _ in aspec]
    aspecs = [_spec(s, F32) for _, s in aspec]

    def xin(b):
        return _spec((b, *M.IN_SHAPE), F32)

    # FullPrecision forward.
    for b in (1, 8, 16):
        ex.export(f"synthnet_fp_fwd_b{b}", fp_fwd_flat,
                  pnames + snames + ["x"], pspecs + sspecs + [xin(b)],
                  n_outputs=1, meta=dict(kind="fp_fwd", batch=b))

    # FullPrecision train step.
    b = 32
    ex.export("synthnet_fp_train_b32", fp_train_flat,
              pnames + snames + ["x", "y", "lr"],
              pspecs + sspecs + [xin(b), _spec((b,), I32), _spec((), F32)],
              n_outputs=np_ + ns_ + 1, meta=dict(kind="fp_train", batch=b))

    # FakeQuantized forward + train, per bit config.
    for wb, ab in ((8, 8), (4, 4), (2, 2)):
        for b in (1, 8):
            ex.export(f"synthnet_fq_fwd_w{wb}a{ab}_b{b}",
                      functools.partial(fq_fwd_flat, wb, ab),
                      pnames + snames + anames + ["x"],
                      pspecs + sspecs + aspecs + [xin(b)],
                      n_outputs=1,
                      meta=dict(kind="fq_fwd", batch=b, wbits=wb, abits=ab))
        b = 32
        ex.export(f"synthnet_fq_train_w{wb}a{ab}_b32",
                  functools.partial(fq_train_flat, wb, ab),
                  pnames + snames + anames + ["x", "y", "lr"],
                  pspecs + sspecs + aspecs + [xin(b), _spec((b,), I32),
                                              _spec((), F32)],
                  n_outputs=np_ + ns_ + na_ + 1,
                  meta=dict(kind="fq_train", batch=b, wbits=wb, abits=ab))

    # QuantizedDeployable forward.
    qspec = M.qd_spec()
    qnames = [n for n, _ in qspec]
    qspecs = [_spec(s, F32) for _, s in qspec]
    for b in (1, 8):
        ex.export(f"synthnet_qd_fwd_b{b}", qd_fwd_flat, qnames + ["x"],
                  qspecs + [xin(b)], n_outputs=1,
                  meta=dict(kind="qd_fwd", batch=b))

    # IntegerDeployable forward: the Pallas-kernel build (kernel-identity
    # and TPU-shaped) and the XLA-native build (CPU serving fast path) —
    # bit-exact same function, same argument spec.
    ispec = M.id_spec()
    inames = [n for n, _ in ispec]
    ispecs = [_spec(s, I32) for _, s in ispec]

    def id_xla_flat(*flat):
        return tuple([M.id_fwd_xla(flat[:-1], flat[-1])])

    for b in (1, 2, 4, 8, 16):
        ex.export(f"synthnet_id_fwd_b{b}", id_fwd_flat, inames + ["qx"],
                  ispecs + [_spec((b, *M.IN_SHAPE), I32)], n_outputs=1,
                  meta=dict(kind="id_fwd", batch=b))
        ex.export(f"synthnet_id_xla_b{b}", id_xla_flat, inames + ["qx"],
                  ispecs + [_spec((b, *M.IN_SHAPE), I32)], n_outputs=1,
                  meta=dict(kind="id_fwd_xla", batch=b))


# ---------------------------------------------------------------------------
# Micro-kernel artifacts (per-kernel benches / tests from rust)
# ---------------------------------------------------------------------------


def export_kernels(ex: Exporter):
    ex.export("kernel_qgemm_256", lambda a, b: (k_qgemm(a, b),),
              ["a", "b"], [_spec((256, 256), I32), _spec((256, 256), I32)],
              n_outputs=1, meta=dict(kind="kernel"))
    ex.export("kernel_requant_64k",
              lambda q, m, d, lo, hi: (k_requant(q, m, d, lo, hi),),
              ["q", "m", "d", "lo", "hi"],
              [_spec((65536,), I32)] + [_spec((), I32)] * 4,
              n_outputs=1, meta=dict(kind="kernel"))
    ex.export("kernel_intbn_4096x64",
              lambda q, k, l: (k_intbn(q, k, l),),
              ["q", "kappa_q", "lambda_q"],
              [_spec((4096, 64), I32), _spec((64,), I32), _spec((64,), I32)],
              n_outputs=1, meta=dict(kind="kernel"))
    ex.export("kernel_thresh_4096x32",
              lambda q, th: (k_thresh(q, th),),
              ["q", "thresholds"],
              [_spec((4096, 32), I32), _spec((32, 15), I32)],
              n_outputs=1, meta=dict(kind="kernel"))
    ex.export("kernel_avgpool_8x32",
              lambda q: (k_avgpool(q, 4, 4, M.POOL_D),),
              ["q"], [_spec((8, 32, 16, 16), I32)],
              n_outputs=1, meta=dict(kind="kernel"))


# ---------------------------------------------------------------------------
# Goldens: cross-language validation vectors
# ---------------------------------------------------------------------------


def init_params(seed: int = 42):
    """He-ish init; goldens carry the actual values, so the cross-language
    match is exact. Every value is rounded through float32 before use:
    NEMO stores everything in float32 (paper sec. 3 note), and the Rust
    side keeps weights in f32 — rounding here makes the f64 transform
    arithmetic bit-identical on both sides."""
    rng = np.random.default_rng(seed)
    params, state = [], []
    for c in M.CONVS:
        fan_in = c["cin"] * c["k"] * c["k"]
        params.append(rng.normal(0, np.sqrt(2.0 / fan_in),
                                 (c["cout"], c["cin"], c["k"], c["k"])))
        params.append(np.abs(rng.normal(1.0, 0.1, (c["cout"],))))  # gamma>0
        params.append(rng.normal(0.0, 0.1, (c["cout"],)))          # beta
        state.append(rng.normal(0.0, 0.2, (c["cout"],)))           # mu
        state.append(np.abs(rng.normal(1.0, 0.2, (c["cout"],))))   # var
    params.append(rng.normal(0, np.sqrt(2.0 / M.FC_IN),
                             (M.FC_IN, M.N_CLASSES)))
    params.append(rng.normal(0, 0.05, (M.N_CLASSES,)))
    return ([a.astype(np.float32).astype(np.float64) for a in params],
            [a.astype(np.float32).astype(np.float64) for a in state])


def _tolist(a):
    return np.asarray(a).tolist()


def make_goldens():
    rng = np.random.default_rng(7)
    g = {}

    # choose_d / multiplier cases (transform determinism cross-check).
    cases = []
    for _ in range(64):
        eps_a = float(np.exp(rng.uniform(-14, -2)))
        eps_b = float(np.exp(rng.uniform(-10, -1)))
        factor = int(rng.choice([16, 64, 256]))
        d = ql.choose_d(eps_a, eps_b, factor)
        m = ql.requant_multiplier(eps_a, eps_b, d)
        cases.append(dict(eps_a=eps_a, eps_b=eps_b, factor=factor, d=d, m=m))
    g["requant_param_cases"] = cases

    # BN quantization + thresholds case.
    cout = 16
    gamma = np.abs(rng.normal(1, 0.3, cout)) + 0.05
    sigma = np.abs(rng.normal(1, 0.3, cout)) + 0.05
    beta = rng.normal(0, 0.4, cout)
    mu = rng.normal(0, 0.4, cout)
    eps_phi = 3.1e-5
    bnq = ql.quantize_bn(gamma, sigma, beta, mu, eps_phi, kappa_bits=8)
    th = ql.bn_thresholds(gamma, sigma, beta, mu, eps_phi, eps_y=0.02,
                          n_levels=16)
    g["bn_quant_case"] = dict(
        gamma=_tolist(gamma), sigma=_tolist(sigma), beta=_tolist(beta),
        mu=_tolist(mu), eps_phi=eps_phi, kappa_bits=8,
        kappa_q=list(bnq.kappa_q), lambda_q=list(bnq.lambda_q),
        eps_kappa=bnq.eps_kappa, eps_phi_out=bnq.eps_phi_out)
    g["thresholds_case"] = dict(
        gamma=_tolist(gamma), sigma=_tolist(sigma), beta=_tolist(beta),
        mu=_tolist(mu), eps_phi=eps_phi, eps_y=0.02, n_levels=16,
        thresholds=_tolist(th))

    # fold_bn case (Eq. 18).
    w = rng.normal(0, 0.5, (4, 3, 3, 3))
    wf, bf = ql.fold_bn(w, None, gamma[:4], sigma[:4], beta[:4], mu[:4])
    g["fold_bn_case"] = dict(w=_tolist(w), gamma=_tolist(gamma[:4]),
                             sigma=_tolist(sigma[:4]), beta=_tolist(beta[:4]),
                             mu=_tolist(mu[:4]), w_folded=_tolist(wf),
                             b_folded=_tolist(bf))

    # Full model: FP params -> deployment -> QD/ID goldens.
    params, state = init_params(42)
    xs = rng.uniform(0, 1, (16, *M.IN_SHAPE))
    act_betas = dp.calibrate_act_betas(
        [jnp.asarray(p, jnp.float32) for p in params],
        [jnp.asarray(s, jnp.float32) for s in state],
        xs.astype(np.float32), M.fp_fwd)
    dep = dp.deploy(params, state, act_betas, wbits=8, abits=8)

    x2 = xs[:2].astype(np.float32)
    qx2 = dp.quantize_input(x2)  # quantize the f32-rounded values (NEMO is float32)
    fp_out = M.fp_fwd([jnp.asarray(p, jnp.float32) for p in params],
                      [jnp.asarray(s, jnp.float32) for s in state],
                      jnp.asarray(x2))
    qd_out = M.qd_fwd([jnp.asarray(a) for a in dep.qd_args],
                      jnp.asarray(qx2.astype(np.float32) * M.EPS_IN))
    id_out = M.id_fwd([jnp.asarray(a) for a in dep.id_args],
                      jnp.asarray(qx2))

    g["model_case"] = dict(
        params={n: _tolist(p) for (n, _), p in zip(M.param_spec(), params)},
        bn_state={n: _tolist(s) for (n, _), s in zip(M.bn_state_spec(), state)},
        act_betas=[float(b) for b in act_betas],
        wbits=8, abits=8,
        layers=[dataclass_dict(l) for l in dep.layers],
        eps_out=dep.eps_out,
        id_args={n: _tolist(a) for (n, _), a in zip(M.id_spec(), dep.id_args)},
        x=_tolist(x2), qx=_tolist(qx2),
        fp_logits=_tolist(fp_out), qd_logits=_tolist(qd_out),
        id_qlogits=_tolist(id_out))

    # Kernel-level integer goldens (small, exact).
    a = rng.integers(0, 256, (7, 18)).astype(np.int32)
    b = rng.integers(-128, 128, (18, 5)).astype(np.int32)
    g["qgemm_case"] = dict(a=_tolist(a), b=_tolist(b),
                           out=_tolist(kref.qgemm_ref(a, b)))
    q = rng.integers(-2**26, 2**26, (64,)).astype(np.int32)
    g["requant_case"] = dict(q=_tolist(q), m=29, d=21, lo=0, hi=255,
                             out=_tolist(kref.requant_ref(q, 29, 21, 0, 255)))
    q2 = rng.integers(-2**20, 2**20, (9, 6)).astype(np.int32)
    kq = rng.integers(-127, 127, (6,)).astype(np.int32)
    lq = rng.integers(-2**24, 2**24, (6,)).astype(np.int32)
    g["intbn_case"] = dict(q=_tolist(q2), kappa_q=_tolist(kq),
                           lambda_q=_tolist(lq),
                           out=_tolist(kref.intbn_ref(q2, kq, lq)))
    th2 = np.sort(rng.integers(-500, 500, (6, 15)), axis=1).astype(np.int32)
    g["thresh_case"] = dict(q=_tolist(q2 % 700 - 350), thresholds=_tolist(th2),
                            out=_tolist(kref.thresh_ref(q2 % 700 - 350, th2)))
    q4 = rng.integers(0, 255, (2, 3, 8, 8)).astype(np.int32)
    g["avgpool_case"] = dict(q=_tolist(q4), k=4, d=M.POOL_D,
                             out=_tolist(kref.avgpool_ref(q4, 4, 4, M.POOL_D)))
    return g


def dataclass_dict(l):
    import dataclasses
    return dataclasses.asdict(l)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--skip-models", action="store_true",
                    help="only kernels+goldens (fast dev cycle)")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    ex = Exporter(args.out)
    print("exporting kernel artifacts:")
    export_kernels(ex)
    if not args.skip_models:
        print("exporting model artifacts:")
        export_models(ex)

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(ex.manifest, f, indent=1)
    print("writing goldens...")
    goldens = make_goldens()
    with open(os.path.join(args.out, "goldens.json"), "w") as f:
        json.dump(goldens, f)
    print(f"manifest: {len(ex.manifest['artifacts'])} artifacts")


if __name__ == "__main__":
    main()
