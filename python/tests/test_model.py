"""Representation-consistency tests over the full SynthNet model
(sec. 3: QD completes FQ; ID is the integer image of QD)."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import deploy as dp
from compile import model as M
from compile.aot import init_params


@pytest.fixture(scope="module")
def deployed():
    params, state = init_params(seed=42)
    rng = np.random.default_rng(11)
    xs = rng.uniform(0, 1, (16, *M.IN_SHAPE)).astype(np.float32)
    betas = dp.calibrate_act_betas(
        [jnp.asarray(p, jnp.float32) for p in params],
        [jnp.asarray(s, jnp.float32) for s in state], xs, M.fp_fwd)
    dep = dp.deploy(params, state, betas, wbits=8, abits=8)
    return params, state, betas, dep, xs


def test_qd_close_to_fp(deployed):
    """QD == FP up to accumulated quantization error (small at 8 bits)."""
    params, state, betas, dep, xs = deployed
    x = xs[:8]
    qx = dp.quantize_input(x)
    fp = np.asarray(M.fp_fwd([jnp.asarray(p, jnp.float32) for p in params],
                             [jnp.asarray(s, jnp.float32) for s in state],
                             jnp.asarray(x)))
    qd = np.asarray(M.qd_fwd([jnp.asarray(a) for a in dep.qd_args],
                             jnp.asarray(qx.astype(np.float32) * M.EPS_IN)))
    # logits live on an O(1) scale; 8-bit pipeline keeps them close.
    assert np.max(np.abs(fp - qd)) < 0.35
    # argmax agreement on a clear majority of samples
    agree = np.mean(np.argmax(fp, -1) == np.argmax(qd, -1))
    assert agree >= 0.75


def test_id_matches_qd_within_requant_error(deployed):
    """eps_out * Q(logits) approximates the QD logits within the
    requantization error bound (eta = 1/16 per stage)."""
    params, state, betas, dep, xs = deployed
    x = xs[:8]
    qx = dp.quantize_input(x)
    qd = np.asarray(M.qd_fwd([jnp.asarray(a) for a in dep.qd_args],
                             jnp.asarray(qx.astype(np.float32) * M.EPS_IN)))
    qlog = np.asarray(M.id_fwd([jnp.asarray(a) for a in dep.id_args],
                               jnp.asarray(qx)))
    id_logits = qlog.astype(np.float64) * dep.eps_out
    # per-stage relative error 1/16, three stages + pooling: be generous
    # on the absolute tolerance but demand argmax agreement.
    assert np.max(np.abs(id_logits - qd)) < 0.5
    agree = np.mean(np.argmax(qd, -1) == np.argmax(id_logits, -1))
    assert agree >= 0.75


def test_id_is_deterministic_integer(deployed):
    params, state, betas, dep, xs = deployed
    qx = dp.quantize_input(xs[:2])
    a = np.asarray(M.id_fwd([jnp.asarray(v) for v in dep.id_args],
                            jnp.asarray(qx)))
    b = np.asarray(M.id_fwd([jnp.asarray(v) for v in dep.id_args],
                            jnp.asarray(qx)))
    assert a.dtype == np.int32
    assert np.array_equal(a, b)


def test_fq_fwd_runs_all_bitwidths(deployed):
    params, state, betas, dep, xs = deployed
    x = jnp.asarray(xs[:4])
    p = [jnp.asarray(v, jnp.float32) for v in params]
    s = [jnp.asarray(v, jnp.float32) for v in state]
    b = [jnp.asarray(v, jnp.float32) for v in betas]
    for wb, ab in ((8, 8), (4, 4), (2, 2)):
        out = M.fq_fwd(p, s, b, x, wbits=wb, abits=ab)
        assert np.isfinite(np.asarray(out)).all()


def test_fq_train_step_reduces_loss():
    """A few QAT steps on a fixed batch must reduce the loss (STE works)."""
    params, state = init_params(seed=1)
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.uniform(0, 1, (32, *M.IN_SHAPE)), jnp.float32)
    y = jnp.asarray(rng.integers(0, M.N_CLASSES, (32,)), jnp.int32)
    p = [jnp.asarray(v, jnp.float32) for v in params]
    s = [jnp.asarray(v, jnp.float32) for v in state]
    b = [jnp.float32(4.0)] * M.N_ACT
    losses = []
    for _ in range(12):
        p, s, b, loss = M.fq_train_step(p, s, b, x, y, jnp.float32(0.05),
                                        wbits=4, abits=4)
        losses.append(float(loss))
    assert losses[-1] < losses[0]


def test_fp_train_step_reduces_loss():
    params, state = init_params(seed=2)
    rng = np.random.default_rng(6)
    x = jnp.asarray(rng.uniform(0, 1, (32, *M.IN_SHAPE)), jnp.float32)
    y = jnp.asarray(rng.integers(0, M.N_CLASSES, (32,)), jnp.int32)
    p = [jnp.asarray(v, jnp.float32) for v in params]
    s = [jnp.asarray(v, jnp.float32) for v in state]
    losses = []
    for _ in range(12):
        p, s, loss = M.fp_train_step(p, s, x, y, jnp.float32(0.05))
        losses.append(float(loss))
    assert losses[-1] < losses[0]


def test_deploy_range_analysis(deployed):
    """Worst-case accumulators stay within int32 (the pipeline's safety
    contract for the Pallas kernels and the MCU-style engine)."""
    _, _, _, dep, _ = deployed
    for lay, c in zip(dep.layers, M.CONVS):
        k_elems = c["cin"] * c["k"] * c["k"]
        acc_max = k_elems * 255 * 128           # |Q_x| <= 255, |Q_w| <= 128
        assert acc_max < 2**31
        bn_max = acc_max * 128 + 2**26          # |kappa_q| < 2^7
        assert bn_max < 2**63
        assert lay.m * bn_max < 2**63           # requant multiply in i64


def test_id_xla_matches_pallas_bit_exactly(deployed):
    """The XLA-native ID build and the Pallas-kernel ID build are the same
    integer function (same args, bit-exact outputs)."""
    import jax.numpy as jnp

    params, state, betas, dep, xs = deployed
    qx = dp.quantize_input(xs[:4])
    a = np.asarray(M.id_fwd([jnp.asarray(v) for v in dep.id_args], jnp.asarray(qx)))
    b = np.asarray(M.id_fwd_xla([jnp.asarray(v) for v in dep.id_args], jnp.asarray(qx)))
    assert np.array_equal(a, b)
