//! Typestate representation pipeline (S4 in DESIGN.md): the paper's four
//! DNN representations as *types*, with the legal transforms between
//! adjacent stages as the only available (self-consuming) transitions:
//!
//! ```text
//!  Network<FullPrecision>
//!      |  calibrate(..) -> quantize_pact(wbits, abits, betas)
//!      v
//!  Network<FakeQuantized>          (fold_bn() allowed here and above,
//!      |  deploy(opts)              tracked so it cannot run twice)
//!      v
//!  Network<QuantizedDeployable>
//!      |  integerize()
//!      v
//!  Network<IntegerDeployable>  --> NativeIntExecutor / PJRT artifacts
//! ```
//!
//! Illegal transitions are compile errors, not runtime checks. A
//! FullPrecision network has no `deploy`:
//!
//! ```compile_fail
//! use nemo::graph::{Graph, Op};
//! use nemo::network::Network;
//! use nemo::transform::DeployOptions;
//!
//! let mut g = Graph::new(1.0 / 255.0);
//! g.push("in", Op::Input { shape: vec![4] }, &[]);
//! let fp = Network::from_graph(g).unwrap();
//! let _ = fp.deploy(DeployOptions::default()); // no such method on FP
//! ```
//!
//! and every transition consumes the network, so a stage cannot be
//! transformed twice:
//!
//! ```compile_fail
//! use nemo::graph::{Graph, Op};
//! use nemo::network::Network;
//!
//! let mut g = Graph::new(1.0 / 255.0);
//! let x = g.push("in", Op::Input { shape: vec![4] }, &[]);
//! g.push("act", Op::ReLU, &[x]);
//! let fp = Network::from_graph(g).unwrap();
//! let fq = fp.quantize_pact(8, 8, &[1.0]).unwrap();
//! let _again = fp.quantize_pact(8, 8, &[1.0]); // error: use of moved `fp`
//! ```
//!
//! The legal chain end to end (runs as a doc-test):
//!
//! ```
//! use nemo::model::mlp;
//! use nemo::network::Network;
//! use nemo::quant::quantize_input;
//! use nemo::tensor::Tensor;
//! use nemo::transform::DeployOptions;
//! use nemo::util::rng::Rng;
//!
//! let mut rng = Rng::new(1);
//! let fp = Network::from_graph(mlp(&mut rng, 8, 6, 4, 1.0 / 255.0)).unwrap();
//! let x = Tensor::from_vec(&[2, 8], vec![0.5f32; 16]);
//! let betas = fp.calibrate(&[x.clone()]);
//! let id = fp
//!     .quantize_pact(8, 8, &betas).unwrap()
//!     .deploy(DeployOptions::default()).unwrap()
//!     .integerize();
//! let q = id.run(&quantize_input(&x, 1.0 / 255.0));
//! assert_eq!(q.shape(), &[2, 4]);
//! ```

use crate::engine::{FloatEngine, IntegerEngine};
use crate::exec::NativeIntExecutor;
use crate::graph::int::IntGraph;
use crate::graph::{Graph, Op};
use crate::io::artifact::{ArtifactError, DeployedArtifact};
use crate::tensor::{TensorF, TensorI};
use crate::transform::{self, DeployOptions, Deployed, LayerQuant, TransformError};

mod sealed {
    pub trait Sealed {}
    impl Sealed for super::FullPrecision {}
    impl Sealed for super::FakeQuantized {}
    impl Sealed for super::QuantizedDeployable {}
    impl Sealed for super::IntegerDeployable {}
}

/// Pipeline stage marker (sealed: the paper defines exactly four).
pub trait Stage: sealed::Sealed {
    /// The representation data carried at this stage.
    type Repr;
    const NAME: &'static str;
}

/// Ordinary float network: BatchNorm + ReLU, float weights (sec. 1).
pub struct FullPrecision;
/// PACT activations at calibrated clipping bounds; weights on (or bound
/// for) their symmetric quantization grid (sec. 2).
pub struct FakeQuantized;
/// Every tensor on its quantized grid, BN parameters quantized — still a
/// float graph, numerically a twin of the integer one (sec. 3).
pub struct QuantizedDeployable;
/// Integer images only; runs with no floating point on the value path.
pub struct IntegerDeployable;

impl Stage for FullPrecision {
    type Repr = Graph;
    const NAME: &'static str = "FullPrecision";
}
impl Stage for FakeQuantized {
    type Repr = Graph;
    const NAME: &'static str = "FakeQuantized";
}
impl Stage for QuantizedDeployable {
    type Repr = Deployed;
    const NAME: &'static str = "QuantizedDeployable";
}
impl Stage for IntegerDeployable {
    type Repr = Deployed;
    const NAME: &'static str = "IntegerDeployable";
}

/// Stage metadata accumulated along the pipeline (what used to live ad
/// hoc in `SynthNet` fields and `Deployed`).
#[derive(Clone, Debug, Default)]
pub struct StageMeta {
    /// PACT clipping bounds recorded when entering FakeQuantized.
    pub act_betas: Vec<f64>,
    /// Weight bits chosen at quantize_pact (0 = not yet hardened).
    pub wbits: u32,
    /// Activation bits chosen at quantize_pact.
    pub abits: u32,
    /// Whether fold_bn already ran — the fold is not idempotent, so a
    /// second application is rejected instead of corrupting weights.
    pub bn_folded: bool,
}

/// A network pinned to one representation stage. See the module docs for
/// the transition diagram.
pub struct Network<S: Stage> {
    repr: S::Repr,
    meta: StageMeta,
}

impl<S: Stage> Network<S> {
    /// Name of the current stage ("FullPrecision", ...).
    pub fn stage_name(&self) -> &'static str {
        S::NAME
    }

    /// Stage metadata accumulated so far.
    pub fn meta(&self) -> &StageMeta {
        &self.meta
    }
}

impl Network<FullPrecision> {
    /// Enter the pipeline with a validated FullPrecision graph. A graph
    /// that already carries PACT activations is *not* FullPrecision — it
    /// must enter via [`Network::<FakeQuantized>::from_pact_graph`], so
    /// that `quantize_pact` can never silently overwrite QAT-trained
    /// clipping bounds.
    pub fn from_graph(graph: Graph) -> Result<Self, TransformError> {
        graph.validate()?;
        if graph.nodes.iter().any(|n| matches!(n.op, Op::PactAct { .. })) {
            return Err(TransformError::Stage(
                "graph already contains PactAct nodes; enter the pipeline \
                 at FakeQuantized via Network::from_pact_graph instead"
                    .into(),
            ));
        }
        Ok(Network { repr: graph, meta: StageMeta::default() })
    }

    pub fn graph(&self) -> &Graph {
        &self.repr
    }

    /// Run the float engine on a batch.
    pub fn run(&self, x: &TensorF) -> TensorF {
        FloatEngine::new().run(&self.repr, x)
    }

    /// Max-observed calibration of the PACT clipping bounds (sec. 2):
    /// one beta per activation, feed them to [`Self::quantize_pact`].
    pub fn calibrate(&self, batches: &[TensorF]) -> Vec<f64> {
        transform::calibrate(&self.repr, batches)
    }

    /// Percentile calibration (robust to outlier channels; DESIGN.md §5).
    pub fn calibrate_percentile(&self, batches: &[TensorF], q: f64) -> Vec<f64> {
        transform::calibrate_percentile(&self.repr, batches, q)
    }

    /// Fold every BatchNorm into its preceding Linear operator (Eq. 18).
    /// Tracked in the metadata: folding twice is an error, not silent
    /// weight corruption.
    pub fn fold_bn(mut self, only: Option<&[&str]>) -> Result<Self, TransformError> {
        if self.meta.bn_folded {
            return Err(TransformError::AlreadyFolded);
        }
        self.repr = transform::fold::fold_bn_impl(&self.repr, only)?;
        self.meta.bn_folded = true;
        Ok(self)
    }

    /// FullPrecision -> FakeQuantized (sec. 2): PACT activations at the
    /// calibrated bounds, weights hardened to their symmetric grid.
    pub fn quantize_pact(
        self,
        wbits: u32,
        abits: u32,
        act_betas: &[f64],
    ) -> Result<Network<FakeQuantized>, TransformError> {
        let n_act = self.repr.activations().len();
        if act_betas.len() != n_act {
            return Err(TransformError::Stage(format!(
                "quantize_pact needs one beta per activation: got {}, graph has {n_act}",
                act_betas.len()
            )));
        }
        let graph = transform::quantize_pact_impl(&self.repr, wbits, abits, act_betas);
        Ok(Network {
            repr: graph,
            meta: StageMeta {
                act_betas: act_betas.to_vec(),
                wbits,
                abits,
                bn_folded: self.meta.bn_folded,
            },
        })
    }
}

impl Network<FakeQuantized> {
    /// Wrap an existing PACT graph (e.g. the output of a QAT training
    /// loop, [`crate::model::SynthNet::to_pact_graph`]) without
    /// re-hardening weights — `deploy` derives the weight grids itself,
    /// which keeps this path bit-exact with the Python reference.
    pub fn from_pact_graph(graph: Graph) -> Result<Self, TransformError> {
        graph.validate()?;
        if graph.nodes.iter().any(|n| matches!(n.op, Op::ReLU)) {
            return Err(TransformError::NeedsFakeQuant("ReLU"));
        }
        let mut meta = StageMeta::default();
        for n in &graph.nodes {
            if let Op::PactAct { beta, bits } = n.op {
                meta.act_betas.push(beta);
                meta.abits = meta.abits.max(bits);
            }
        }
        Ok(Network { repr: graph, meta })
    }

    pub fn graph(&self) -> &Graph {
        &self.repr
    }

    /// PACT clipping bounds carried by this stage.
    pub fn act_betas(&self) -> &[f64] {
        &self.meta.act_betas
    }

    /// Run the float engine (fake-quantized forward pass) on a batch.
    pub fn run(&self, x: &TensorF) -> TensorF {
        FloatEngine::new().run(&self.repr, x)
    }

    /// Fold BatchNorm into the preceding Linear ops (Eq. 18); rejected if
    /// the pipeline already folded.
    pub fn fold_bn(mut self, only: Option<&[&str]>) -> Result<Self, TransformError> {
        if self.meta.bn_folded {
            return Err(TransformError::AlreadyFolded);
        }
        self.repr = transform::fold::fold_bn_impl(&self.repr, only)?;
        self.meta.bn_folded = true;
        Ok(self)
    }

    /// FakeQuantized -> QuantizedDeployable (sec. 3): harden_weights +
    /// bn_quantizer + set_deployment eps propagation + integer range
    /// analysis. The integer twin is derived in the same walk and carried
    /// along for the final `integerize` step.
    pub fn deploy(
        self,
        opts: DeployOptions,
    ) -> Result<Network<QuantizedDeployable>, TransformError> {
        let mut meta = self.meta;
        meta.wbits = opts.wbits;
        meta.abits = opts.abits;
        let dep = transform::deploy::deploy_impl(&self.repr, opts)?;
        Ok(Network { repr: dep, meta })
    }
}

impl Network<QuantizedDeployable> {
    /// The QD float graph: every value on its quantized grid.
    pub fn graph(&self) -> &Graph {
        &self.repr.qd
    }

    /// Per-layer quantization table (eps chain, requant m/d, clip bounds).
    pub fn layers(&self) -> &[LayerQuant] {
        &self.repr.layers
    }

    /// Run the float engine on the QD graph.
    pub fn run(&self, x: &TensorF) -> TensorF {
        FloatEngine::new().run(&self.repr.qd, x)
    }

    /// QuantizedDeployable -> IntegerDeployable: release the integer twin
    /// derived during `deploy` (nemo.transform.integerize_pact).
    pub fn integerize(self) -> Network<IntegerDeployable> {
        Network { repr: self.repr, meta: self.meta }
    }
}

impl Network<IntegerDeployable> {
    /// The integer-image graph executed by the integer engine / Pallas
    /// kernels.
    pub fn int_graph(&self) -> &IntGraph {
        &self.repr.id
    }

    /// Storage precision stamped on every integer node (u8/i8/i32),
    /// range-proved during `deploy` — the per-node map the packed
    /// execution path dispatches on (DESIGN.md §Precision propagation).
    pub fn node_precisions(&self) -> Vec<crate::quant::Precision> {
        self.repr.id.precisions()
    }

    /// Quantum of the output integer image: logits_real ~ eps_out * Q.
    pub fn eps_out(&self) -> f64 {
        self.repr.eps_out
    }

    /// Per-layer quantization table (eps chain, requant m/d, clip bounds).
    pub fn layers(&self) -> &[LayerQuant] {
        &self.repr.layers
    }

    /// Full deployment record (QD twin, range analysis, per-node eps) —
    /// the bridge to artifact-argument assembly and diagnostics.
    pub fn deployed(&self) -> &Deployed {
        &self.repr
    }

    pub fn into_deployed(self) -> Deployed {
        self.repr
    }

    /// Run the integer engine on an integer-image batch.
    pub fn run(&self, qx: &TensorI) -> TensorI {
        IntegerEngine::new().run(&self.repr.id, qx)
    }

    /// Freeze this deployed model into a native artifact file
    /// (`model.nemo.json`): the integer program, precision stamps,
    /// requant parameters, eps metadata and packed weights, versioned
    /// and checksummed. Only an IntegerDeployable network has this
    /// method — the typestate makes saving a half-transformed pipeline
    /// unrepresentable. Logits served from the loaded artifact are
    /// bit-identical to this network's.
    pub fn save_deployed(
        &self,
        path: impl AsRef<std::path::Path>,
    ) -> Result<(), ArtifactError> {
        DeployedArtifact::save_parts(&self.repr, &self.meta, path)
    }

    /// [`Self::save_deployed`] in the v3 binary container form
    /// (`model.nemob`): the same frozen integer program, with weight
    /// payloads in 64-byte-aligned checksummed sections the loader can
    /// `mmap` straight into zero-copy tensor views.
    pub fn save_deployed_bin(
        &self,
        path: impl AsRef<std::path::Path>,
    ) -> Result<(), ArtifactError> {
        DeployedArtifact::save_binary_parts(&self.repr, &self.meta, path)
    }

    /// Rehydrate an IntegerDeployable network from a saved artifact —
    /// the `deploy once, serve anywhere` entry point: no training, no
    /// transform pipeline, no Python-side manifest. Both on-disk forms
    /// load (the JSON document and the `.nemob` binary container; the
    /// first bytes decide). The loader validates format/version, the
    /// model checksum and the precision stamps (re-proved via
    /// `shape::infer_precision`). The QD float twin is not shipped in
    /// the artifact, so [`Self::deployed`] on a loaded network exposes
    /// an empty `qd` graph.
    pub fn load_deployed(
        path: impl AsRef<std::path::Path>,
    ) -> Result<Self, ArtifactError> {
        let (repr, meta) = DeployedArtifact::load(path)?.into_deployed();
        Ok(Network { repr, meta })
    }

    /// A shareable native [`crate::exec::Executor`] over this network
    /// (clones the integer graph; the network stays usable).
    pub fn to_executor(&self, max_batch: usize) -> anyhow::Result<NativeIntExecutor> {
        NativeIntExecutor::new(self.repr.id.clone(), max_batch)
    }

    /// [`Self::to_executor`] pre-wrapped in the `Arc<dyn Executor>` the
    /// serving registry speaks — the one-liner for
    /// `ServerBuilder::model(name, nid.to_shared_executor(b)?)` and
    /// `ServerHandle::{load_model, swap_model}`.
    pub fn to_shared_executor(
        &self,
        max_batch: usize,
    ) -> anyhow::Result<std::sync::Arc<dyn crate::exec::Executor>> {
        Ok(std::sync::Arc::new(self.to_executor(max_batch)?))
    }

    /// Consume the network into a native [`crate::exec::Executor`].
    pub fn into_executor(self, max_batch: usize) -> anyhow::Result<NativeIntExecutor> {
        NativeIntExecutor::new(self.repr.id, max_batch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::mlp;
    use crate::quant::quantize_input;
    use crate::tensor::Tensor;
    use crate::util::rng::Rng;

    fn fp_net(seed: u64) -> (Network<FullPrecision>, TensorF) {
        let mut rng = Rng::new(seed);
        let g = mlp(&mut rng, 16, 12, 5, 1.0 / 255.0);
        let x = Tensor::from_vec(
            &[4, 16],
            (0..64).map(|_| rng.uniform(0.0, 1.0) as f32).collect(),
        );
        (Network::from_graph(g).unwrap(), x)
    }

    #[test]
    fn legal_chain_reaches_integer_deployable() {
        let (fp, x) = fp_net(11);
        let betas = fp.calibrate(&[x.clone()]);
        let id = fp
            .quantize_pact(8, 8, &betas)
            .unwrap()
            .deploy(DeployOptions::default())
            .unwrap()
            .integerize();
        assert_eq!(id.stage_name(), "IntegerDeployable");
        assert_eq!(id.meta().wbits, 8);
        let out = id.run(&quantize_input(&x, 1.0 / 255.0));
        assert_eq!(out.shape(), &[4, 5]);
        assert!(id.eps_out() > 0.0);
        assert!(!id.layers().is_empty());
    }

    #[test]
    fn quantize_pact_rejects_wrong_beta_count() {
        let (fp, _) = fp_net(12);
        match fp.quantize_pact(8, 8, &[1.0, 2.0, 3.0]) {
            Err(TransformError::Stage(msg)) => {
                assert!(msg.contains("one beta per activation"), "{msg}");
            }
            other => panic!("expected Stage error, got {:?}", other.map(|n| n.stage_name())),
        }
    }

    #[test]
    fn fold_bn_twice_is_rejected() {
        let (fp, _) = fp_net(13);
        let folded = fp.fold_bn(None).unwrap();
        assert!(folded.meta().bn_folded);
        match folded.fold_bn(None) {
            Err(TransformError::AlreadyFolded) => {}
            other => panic!("expected AlreadyFolded, got {:?}", other.map(|n| n.stage_name())),
        }
    }

    #[test]
    fn fold_flag_survives_quantize_pact() {
        let (fp, x) = fp_net(14);
        let betas = fp.calibrate(&[x]);
        let fq = fp.fold_bn(None).unwrap().quantize_pact(8, 8, &betas).unwrap();
        assert!(fq.meta().bn_folded);
        match fq.fold_bn(None) {
            Err(TransformError::AlreadyFolded) => {}
            other => panic!("expected AlreadyFolded, got {:?}", other.map(|n| n.stage_name())),
        }
    }

    #[test]
    fn from_graph_rejects_pact_graphs() {
        // A QAT-trained PACT graph must not enter at FullPrecision —
        // quantize_pact would silently overwrite its trained betas.
        let (fp, x) = fp_net(17);
        let betas = fp.calibrate(&[x]);
        let fq = fp.quantize_pact(8, 8, &betas).unwrap();
        match Network::from_graph(fq.graph().clone()) {
            Err(TransformError::Stage(msg)) => {
                assert!(msg.contains("PactAct"), "{msg}");
            }
            other => panic!(
                "expected Stage error, got {:?}",
                other.map(|n| n.stage_name())
            ),
        }
    }

    #[test]
    fn from_pact_graph_rejects_relu() {
        let (fp, _) = fp_net(15);
        let g = fp.graph().clone();
        assert!(matches!(
            Network::<FakeQuantized>::from_pact_graph(g),
            Err(TransformError::NeedsFakeQuant(_))
        ));
    }

    #[test]
    fn from_pact_graph_collects_betas() {
        let (fp, x) = fp_net(16);
        let betas = fp.calibrate(&[x]);
        let fq = fp.quantize_pact(8, 8, &betas).unwrap();
        let rewrapped = Network::<FakeQuantized>::from_pact_graph(fq.graph().clone()).unwrap();
        assert_eq!(rewrapped.act_betas(), &betas[..]);
        assert_eq!(rewrapped.meta().abits, 8);
    }
}
