"""Python mirror of the Rust deployment pipeline (rust/src/transform/).

Turns trained FullPrecision/FakeQuantized parameters into
QuantizedDeployable and IntegerDeployable argument lists for model.qd_fwd /
model.id_fwd. This mirror exists for two reasons:

  1. golden generation: aot.py exports (inputs, derived integer params,
     expected outputs) so the Rust pipeline can be validated bit-exactly;
  2. python-side representation-consistency tests (python/tests/).

Every numeric choice here (floor-based quantization, the exact-doubling
choose_d loop, kappa_bits=8 default, lambda stored directly in the target
format) matches rust/src/quant/ and rust/src/transform/ line for line —
f64 arithmetic with identical operation order, so both sides derive
identical integers from identical floats.
"""

from __future__ import annotations

import dataclasses
import math
from typing import List, Sequence

import numpy as np

from . import quantlib as ql
from .model import ARCH, CONVS, EPS_IN, FC_IN, N_CLASSES, BN_EPS


@dataclasses.dataclass
class LayerQuant:
    """Derived quantization record for one conv+BN+act layer."""

    name: str
    beta_w: float
    eps_w: float
    eps_phi: float          # eps_w * eps_x (Eq. 15)
    eps_kappa: float
    eps_phi_out: float      # eps_kappa * eps_phi (integer BN output)
    beta_y: float
    eps_y: float
    d: int                  # Eq. 14
    m: int                  # Eq. 13
    act_hi: int


@dataclasses.dataclass
class DeployedModel:
    """Everything needed to run QD (float) and ID (integer) inference."""

    layers: List[LayerQuant]
    qd_args: List[np.ndarray]
    id_args: List[np.ndarray]
    eps_out: float          # quantum of the integer logits


def _np(x):
    return np.asarray(x, np.float64)


def calibrate_act_betas(params, bn_state, xs, fp_fwd,
                        percentile: float = 1.0) -> List[float]:
    """Set the PACT clipping bound beta_y of each activation from the
    FullPrecision stage statistics (sec. 2, "In NEMO": "the maximum value
    of y in the FullPrecision stage").

    percentile=1.0 reproduces NEMO's max policy; <1.0 uses a percentile,
    which is more robust to outliers (documented deviation, DESIGN.md).
    xs: calibration batch [B,1,16,16]. Returns one beta per conv layer.
    """
    import jax.numpy as jnp

    betas = []
    h = jnp.asarray(xs, jnp.float32)
    p = list(params)
    s = list(bn_state)
    import jax

    for i, c in enumerate(CONVS):
        w, gamma, beta = p[3 * i], p[3 * i + 1], p[3 * i + 2]
        mu, var = s[2 * i], s[2 * i + 1]
        sigma = jnp.sqrt(var + BN_EPS)
        phi = jax.lax.conv_general_dilated(
            h, w, (c["stride"], c["stride"]),
            ((c["pad"], c["pad"]), (c["pad"], c["pad"])),
            dimension_numbers=("NCHW", "OIHW", "NCHW"))
        shape = (1, -1, 1, 1)
        phi = (gamma / sigma).reshape(shape) * (phi - mu.reshape(shape)) + beta.reshape(shape)
        h = jax.nn.relu(phi)
        if percentile >= 1.0:
            betas.append(float(jnp.max(h)) or 1.0)
        else:
            betas.append(float(jnp.quantile(h.reshape(-1), percentile)) or 1.0)
    return betas


def deploy(params: Sequence[np.ndarray], bn_state: Sequence[np.ndarray],
           act_betas: Sequence[float], *, wbits: int = 8, abits: int = 8,
           kappa_bits: int = 8,
           requantization_factor: int = 16) -> DeployedModel:
    """FP/FQ parameters -> QD + ID argument lists (sec. 3 pipeline).

    Steps (NEMO API): harden_weights -> bn_quantizer -> set_deployment
    (eps propagation) -> integerize.
    """
    p = [_np(a) for a in params]
    s = [_np(a) for a in bn_state]
    n_act = (1 << abits) - 1

    qd_args: List[np.ndarray] = []
    id_args: List[np.ndarray] = []
    layers: List[LayerQuant] = []
    eps_x = EPS_IN
    for i, c in enumerate(CONVS):
        w, gamma, beta = p[3 * i], p[3 * i + 1], p[3 * i + 2]
        mu, var = s[2 * i], s[2 * i + 1]
        sigma = np.sqrt(var + BN_EPS)

        # harden_weights: w <- w_hat on the symmetric eps_w grid.
        beta_w = float(np.max(np.abs(w)))
        if beta_w == 0.0:
            beta_w = 1.0
        wspec = ql.QuantSpec.weight(beta_w, wbits)
        wq = np.clip(np.floor(w / wspec.eps), wspec.lo, wspec.hi)
        w_hat = wq * wspec.eps

        # set_deployment: eps propagation through the Linear op (Eq. 15).
        eps_phi = wspec.eps * eps_x

        # bn_quantizer (Eq. 21-22).
        bnq = ql.quantize_bn(gamma, sigma, beta, mu, eps_phi, kappa_bits)
        kappa_q = np.asarray(bnq.kappa_q, np.int64)
        lambda_q = np.asarray(bnq.lambda_q, np.int64)
        kappa_hat = kappa_q * bnq.eps_kappa
        lambda_hat = lambda_q * bnq.eps_phi_out

        # integer activation (Eq. 11/13/14).
        beta_y = float(act_betas[i])
        eps_y = beta_y / n_act
        d = ql.choose_d(bnq.eps_phi_out, eps_y, requantization_factor)
        m = ql.requant_multiplier(bnq.eps_phi_out, eps_y, d)

        layers.append(LayerQuant(
            name=c["name"], beta_w=beta_w, eps_w=wspec.eps, eps_phi=eps_phi,
            eps_kappa=bnq.eps_kappa, eps_phi_out=bnq.eps_phi_out,
            beta_y=beta_y, eps_y=eps_y, d=d, m=m, act_hi=n_act))

        qd_args += [w_hat.astype(np.float32),
                    kappa_hat.astype(np.float32),
                    lambda_hat.astype(np.float32),
                    np.float32(beta_y), np.float32(eps_y)]

        wq_mat = wq.transpose(1, 2, 3, 0).reshape(c["cin"] * c["k"] * c["k"],
                                                  c["cout"])
        id_args += [wq_mat.astype(np.int32),
                    kappa_q.astype(np.int32),
                    lambda_q.astype(np.int32),
                    np.int32(m), np.int32(d), np.int32(n_act)]
        # Propagate the REALIZED quantum: the requant multiplier encodes
        # m/2^d ~ eps_phi_out/eps_y, so the integer image downstream
        # carries eps_eff = eps_phi_out * 2^d / m (mirrors
        # rust/src/transform/deploy.rs; removes compounding scale error).
        eps_x = bnq.eps_phi_out * float(1 << d) / m

    # fc layer: hardened weights + bias on eps_w*eps_x grid.
    wf, bf = p[-2], p[-1]
    beta_wf = float(np.max(np.abs(wf)))
    if beta_wf == 0.0:
        beta_wf = 1.0
    wfspec = ql.QuantSpec.weight(beta_wf, wbits)
    wfq = np.clip(np.floor(wf / wfspec.eps), wfspec.lo, wfspec.hi)
    eps_out = wfspec.eps * eps_x
    bfq = np.floor(bf / eps_out)

    qd_args += [(wfq * wfspec.eps).astype(np.float32),
                (bfq * eps_out).astype(np.float32)]
    id_args += [wfq.astype(np.int32), bfq.astype(np.int32)]

    return DeployedModel(layers=layers, qd_args=qd_args, id_args=id_args,
                         eps_out=eps_out)


def quantize_input(x: np.ndarray) -> np.ndarray:
    """Input image in [0,1) -> 8-bit integer image (eps_in = 1/255)."""
    return np.clip(np.floor(_np(x) / EPS_IN), 0, 255).astype(np.int32)
