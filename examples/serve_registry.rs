//! Multi-model serving registry demo: two deployment artifacts served by
//! name from one coordinator, a zero-downtime hot swap under live
//! traffic, and per-model metrics.
//!
//!     cargo run --release --example serve_registry
//!
//! The flow mirrors a production rollout on the paper's IntegerDeployable
//! artifacts: deploy two nets to `*.nemo.json` files, serve both
//! (`ServerBuilder::model_from_artifact`), route concurrent traffic at
//! each by name, then re-deploy one name to a different artifact with
//! `swap_model_from_artifact` while its clients keep running — no
//! restart, no dropped replies, and bit-identical logits per version
//! (integer-only inference makes the check exact, PAPER.md §4).

use std::time::Duration;

use nemo::coordinator::{Server, ServerConfig};
use nemo::data::SynthDigits;
use nemo::model::synthnet::{SynthNet, EPS_IN};
use nemo::network::{IntegerDeployable, Network};
use nemo::quant::quantize_input;
use nemo::transform::DeployOptions;
use nemo::util::rng::Rng;

fn deploy_to(
    seed: u64,
    bits: u32,
    path: &std::path::Path,
) -> anyhow::Result<Network<IntegerDeployable>> {
    let mut rng = Rng::new(seed);
    let net = SynthNet::init(&mut rng);
    let nid = net
        .to_network(bits)?
        .deploy(DeployOptions { wbits: bits, abits: bits, ..DeployOptions::default() })?
        .integerize();
    nid.save_deployed(path)?;
    Ok(nid)
}

fn main() -> anyhow::Result<()> {
    let dir = std::env::temp_dir();
    let pid = std::process::id();
    let path_a = dir.join(format!("serve_registry_a_{pid}.nemo.json"));
    let path_b = dir.join(format!("serve_registry_b_{pid}.nemo.json"));
    let nid_a = deploy_to(11, 8, &path_a)?;
    let nid_b = deploy_to(22, 8, &path_b)?;
    println!("deployed artifacts: {} and {}", path_a.display(), path_b.display());

    let server = Server::builder()
        .default_config(ServerConfig {
            max_batch: 8,
            batch_timeout: Duration::from_micros(300),
            n_workers: 2,
        })
        .model_from_artifact("alpha", &path_a)
        .model_from_artifact("beta", &path_b)
        .start()?;
    let h = server.handle();
    for info in h.list_models() {
        println!(
            "  '{}' v{} backend={} input={:?} [{}]",
            info.name, info.version, info.backend, info.input_shape, info.provenance
        );
    }

    // Pre-swap, 'alpha' serves artifact A's program bit-identically.
    {
        let mut data = SynthDigits::new(4000);
        let (x, _) = data.batch(1);
        let qx = quantize_input(&x, EPS_IN);
        anyhow::ensure!(
            h.infer("alpha", qx.clone())?.data() == nid_a.run(&qx).data(),
            "pre-swap 'alpha' must serve artifact A bit-identically"
        );
    }

    // Concurrent traffic: 4 clients per model. "alpha" swaps to artifact
    // B mid-run, so its replies must match one of the two versions — and
    // strictly B once the swap has completed.
    let per_client = 64usize;
    let mut joins = Vec::new();
    for c in 0..8u64 {
        let h = server.handle();
        let model = if c % 2 == 0 { "alpha" } else { "beta" };
        joins.push(std::thread::spawn(move || -> anyhow::Result<usize> {
            let mut data = SynthDigits::new(3000 + c);
            let mut served = 0;
            for _ in 0..per_client {
                let (x, _) = data.batch(1);
                let qx = quantize_input(&x, EPS_IN);
                h.infer(model, qx)?;
                served += 1;
            }
            Ok(served)
        }));
    }

    // Hot swap "alpha" -> artifact B once some traffic has flowed.
    std::thread::sleep(Duration::from_millis(5));
    let version = h.swap_model_from_artifact("alpha", &path_b)?;
    println!("hot-swapped 'alpha' to artifact B (now v{version}) under load");

    let mut total = 0;
    for j in joins {
        total += j.join().unwrap()?;
    }

    // Post-swap, 'alpha' serves artifact B's program bit-identically.
    let mut data = SynthDigits::new(4000);
    let (x, _) = data.batch(1);
    let qx = quantize_input(&x, EPS_IN);
    let post = h.infer("alpha", qx.clone())?;
    anyhow::ensure!(
        post.data() == nid_b.run(&qx).data(),
        "post-swap 'alpha' must serve artifact B bit-identically"
    );

    // Stop first so the ledgers are final (workers account a batch after
    // scattering its replies); registry reads still work via the handle.
    let infos = h.list_models();
    let m = server.stop();
    println!("\nper-model metrics ({total} + 2 probe requests total):");
    for info in infos {
        let mut pm = h.model_metrics(&info.name)?;
        println!("-- '{}' (v{})\n{}", info.name, info.version, pm.report());
    }
    println!("aggregate: completed={} failed={}", m.completed, m.failed);

    let _ = std::fs::remove_file(&path_a);
    let _ = std::fs::remove_file(&path_b);
    Ok(())
}
