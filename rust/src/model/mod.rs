//! Model zoo (S10): SynthNet (mirrors python/compile/model.py exactly),
//! a residual variant (exercises the Add path, sec. 3.5), and an MLP.

pub mod synthnet;

pub use synthnet::{SynthNet, ConvCfg, SYNTHNET_CONVS};

use crate::graph::{Graph, Op};
use crate::quant::bn::BnParams;
use crate::tensor::TensorF;
use crate::util::rng::Rng;

/// A small residual CNN: two conv-bn-act blocks whose outputs are Added
/// (both branches fed from the same activation, per the sec. 1 branch
/// rule), then pooled and classified. Engine-only (no AOT artifact);
/// used by the Add-requantization experiments (E6).
pub fn residual_net(rng: &mut Rng, eps_in: f64) -> Graph {
    let mut g = Graph::new(eps_in);
    let x = g.push("in", Op::Input { shape: vec![1, 16, 16] }, &[]);

    let w0 = rand_w(rng, &[8, 1, 3, 3]);
    let c0 = g.push("c0", Op::Conv2d { w: w0, bias: None, stride: 1, pad: 1 }, &[x]);
    let b0 = g.push("bn0", Op::BatchNorm { bn: rand_bn(rng, 8) }, &[c0]);
    let a0 = g.push("a0", Op::ReLU, &[b0]);

    // branch 1: conv-bn-act; branch 2: identity from a0
    let w1 = rand_w(rng, &[8, 8, 3, 3]);
    let c1 = g.push("c1", Op::Conv2d { w: w1, bias: None, stride: 1, pad: 1 }, &[a0]);
    let b1 = g.push("bn1", Op::BatchNorm { bn: rand_bn(rng, 8) }, &[c1]);
    let a1 = g.push("a1", Op::ReLU, &[b1]);

    let add = g.push("add", Op::Add, &[a0, a1]);
    // post-add activation re-quantizes the sum
    let a2 = g.push("a2", Op::ReLU, &[add]);
    let p = g.push("gap", Op::GlobalAvgPool, &[a2]);
    let wf = rand_w(rng, &[8, 10]);
    g.push("fc", Op::Linear { w: wf, bias: None }, &[p]);
    g
}

/// 2-layer MLP over flat inputs (quickstart-sized).
pub fn mlp(rng: &mut Rng, in_dim: usize, hidden: usize, out_dim: usize, eps_in: f64) -> Graph {
    let mut g = Graph::new(eps_in);
    let x = g.push("in", Op::Input { shape: vec![in_dim] }, &[]);
    let w1 = rand_w(rng, &[in_dim, hidden]);
    let l1 = g.push("fc1", Op::Linear { w: w1, bias: None }, &[x]);
    let bn = g.push("bn1", Op::BatchNorm { bn: rand_bn(rng, hidden) }, &[l1]);
    let a1 = g.push("a1", Op::ReLU, &[bn]);
    let w2 = rand_w(rng, &[hidden, out_dim]);
    g.push("fc2", Op::Linear { w: w2, bias: Some(vec![0.0; out_dim]) }, &[a1]);
    g
}

pub(crate) fn rand_w(rng: &mut Rng, shape: &[usize]) -> TensorF {
    let fan_in: usize = if shape.len() == 4 {
        shape[1] * shape[2] * shape[3]
    } else {
        shape[0]
    };
    let std = (2.0 / fan_in as f64).sqrt();
    let n: usize = shape.iter().product();
    TensorF::from_vec(shape, (0..n).map(|_| rng.normal(0.0, std) as f32).collect())
}

pub(crate) fn rand_bn(rng: &mut Rng, c: usize) -> BnParams {
    BnParams {
        gamma: (0..c).map(|_| rng.uniform(0.5, 1.5)).collect(),
        sigma: (0..c).map(|_| rng.uniform(0.5, 1.5)).collect(),
        beta: (0..c).map(|_| rng.normal(0.0, 0.1)).collect(),
        mu: (0..c).map(|_| rng.normal(0.0, 0.1)).collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::FloatEngine;
    use crate::tensor::Tensor;

    #[test]
    fn residual_net_validates_and_runs() {
        let mut rng = Rng::new(5);
        let g = residual_net(&mut rng, 1.0 / 255.0);
        g.validate().unwrap();
        let x = Tensor::from_vec(
            &[2, 1, 16, 16],
            (0..512).map(|i| (i % 255) as f32 / 255.0).collect(),
        );
        let out = FloatEngine::new().run(&g, &x);
        assert_eq!(out.shape(), &[2, 10]);
    }

    #[test]
    fn mlp_runs() {
        let mut rng = Rng::new(6);
        let g = mlp(&mut rng, 64, 32, 10, 1.0 / 255.0);
        g.validate().unwrap();
        let x = Tensor::from_vec(&[3, 64], vec![0.5f32; 192]);
        assert_eq!(FloatEngine::new().run(&g, &x).shape(), &[3, 10]);
    }
}

pub mod artifact_args;
