//! Model registry: the concurrently readable name → [`ModelEntry`] map
//! behind the serving coordinator, with runtime lifecycle operations —
//! `register` (load), `swap` (hot-reload), `unload` — that are atomic
//! with respect to in-flight batches.
//!
//! Atomicity contract (DESIGN.md §Serving-registry): the batcher resolves
//! a name to an `Arc<ModelEntry>` once per gathered batch, and the
//! dispatched job carries that `Arc`. A `swap` or `unload` only replaces
//! or removes the map entry — batches already bound to the old executor
//! complete on it (the `Arc` keeps it alive), new requests resolve to the
//! replacement, and no gathered batch ever mixes two executor versions.
//! Per-model [`Metrics`] survive a swap (the same model name keeps one
//! ledger across versions), so every request to a name is accounted for
//! no matter which executor version answered it.

use std::collections::HashMap;
use std::sync::{Arc, Mutex, RwLock};

use crate::coordinator::{Metrics, ServerConfig};
use crate::exec::Executor;
use crate::io::artifact::ArtifactProvenance;

/// Typed registry failures. Carried inside `anyhow::Error` on the
/// inference path; `err.downcast_ref::<RegistryError>()` recovers them.
#[derive(Debug, thiserror::Error)]
pub enum RegistryError {
    #[error(
        "model '{0}' is already registered (duplicate name); unload it \
         first, or use swap to replace its executor"
    )]
    DuplicateName(String),
    #[error("unknown model '{0}' (never registered, or already unloaded)")]
    UnknownModel(String),
}

/// Where a model's executor came from — surfaced in `list_models` so an
/// operator can tell which artifact (and which bytes) a name is serving.
#[derive(Clone, Debug)]
pub enum Provenance {
    /// Built in-process (e.g. from a checkpoint or a constructed graph).
    InMemory,
    /// Loaded from a `model.nemo.json` deployment artifact.
    Artifact(ArtifactProvenance),
}

impl std::fmt::Display for Provenance {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Provenance::InMemory => write!(f, "in-memory"),
            Provenance::Artifact(a) => write!(
                f,
                "artifact {} ({} bytes, format v{}, {})",
                a.path, a.bytes, a.format_version, a.checksum
            ),
        }
    }
}

/// One registered model: a shareable executor, the serving configuration
/// resolved for this model, its metrics ledger and its provenance. The
/// `version` counter starts at 1 and bumps on every swap of the name.
pub struct ModelEntry {
    pub name: String,
    pub exec: Arc<dyn Executor>,
    pub cfg: ServerConfig,
    pub metrics: Arc<Mutex<Metrics>>,
    pub provenance: Provenance,
    pub version: u64,
}

impl ModelEntry {
    pub fn new(
        name: &str,
        exec: Arc<dyn Executor>,
        cfg: ServerConfig,
        provenance: Provenance,
    ) -> Self {
        ModelEntry {
            name: name.to_string(),
            exec,
            cfg,
            metrics: Arc::new(Mutex::new(Metrics::new())),
            provenance,
            version: 1,
        }
    }

    /// Snapshot for `list_models`.
    pub fn info(&self) -> ModelInfo {
        ModelInfo {
            name: self.name.clone(),
            version: self.version,
            backend: self.exec.name().to_string(),
            input_shape: self.exec.input_shape().to_vec(),
            max_batch: self.cfg.max_batch.min(self.exec.max_batch()),
            provenance: self.provenance.clone(),
        }
    }
}

/// Public snapshot of one registry entry.
#[derive(Clone, Debug)]
pub struct ModelInfo {
    pub name: String,
    pub version: u64,
    pub backend: String,
    pub input_shape: Vec<usize>,
    pub max_batch: usize,
    pub provenance: Provenance,
}

/// The concurrently readable name → entry map. Reads (request routing)
/// take a short shared lock; lifecycle writes take the exclusive lock
/// only to mutate the map — never while an executor runs.
#[derive(Default)]
pub struct ModelRegistry {
    inner: RwLock<HashMap<String, Arc<ModelEntry>>>,
}

impl ModelRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register a new model name. Duplicate names are a typed error —
    /// never a silent last-wins overwrite.
    pub fn register(&self, entry: ModelEntry) -> Result<(), RegistryError> {
        let mut map = self.inner.write().expect("registry lock poisoned");
        if map.contains_key(&entry.name) {
            return Err(RegistryError::DuplicateName(entry.name));
        }
        map.insert(entry.name.clone(), Arc::new(entry));
        Ok(())
    }

    /// Replace the executor serving `name`, keeping its config and its
    /// metrics ledger (the name's request accounting spans versions).
    /// Returns the new version number. Batches already dispatched against
    /// the old executor complete on it; requests routed after this call
    /// returns run on `exec`.
    pub fn swap(
        &self,
        name: &str,
        exec: Arc<dyn Executor>,
        provenance: Provenance,
    ) -> Result<u64, RegistryError> {
        let mut map = self.inner.write().expect("registry lock poisoned");
        let old = map
            .get(name)
            .ok_or_else(|| RegistryError::UnknownModel(name.to_string()))?;
        let entry = ModelEntry {
            name: old.name.clone(),
            exec,
            cfg: old.cfg,
            metrics: old.metrics.clone(),
            provenance,
            version: old.version + 1,
        };
        let version = entry.version;
        map.insert(name.to_string(), Arc::new(entry));
        Ok(version)
    }

    /// Remove `name` from routing. In-flight batches bound to its
    /// executor still complete (their jobs hold the `Arc`); the removed
    /// entry is returned so callers can read its final metrics.
    pub fn unload(&self, name: &str) -> Result<Arc<ModelEntry>, RegistryError> {
        let mut map = self.inner.write().expect("registry lock poisoned");
        map.remove(name)
            .ok_or_else(|| RegistryError::UnknownModel(name.to_string()))
    }

    /// Resolve a name to its current entry (the routing read).
    pub fn get(&self, name: &str) -> Option<Arc<ModelEntry>> {
        self.inner.read().expect("registry lock poisoned").get(name).cloned()
    }

    pub fn contains(&self, name: &str) -> bool {
        self.inner.read().expect("registry lock poisoned").contains_key(name)
    }

    /// Per-model serving config, if the name is registered.
    pub fn config_of(&self, name: &str) -> Option<ServerConfig> {
        self.get(name).map(|e| e.cfg)
    }

    /// Snapshot of every registered model, sorted by name.
    pub fn list(&self) -> Vec<ModelInfo> {
        let map = self.inner.read().expect("registry lock poisoned");
        let mut infos: Vec<ModelInfo> = map.values().map(|e| e.info()).collect();
        infos.sort_by(|a, b| a.name.cmp(&b.name));
        infos
    }

    /// Snapshot of one model's metrics ledger.
    pub fn metrics_of(&self, name: &str) -> Result<Metrics, RegistryError> {
        let entry = self
            .get(name)
            .ok_or_else(|| RegistryError::UnknownModel(name.to_string()))?;
        let m = entry.metrics.lock().expect("metrics lock poisoned").clone();
        Ok(m)
    }

    /// Aggregate metrics across every *currently registered* model
    /// (metrics of unloaded models leave with their entries).
    pub fn aggregate_metrics(&self) -> Metrics {
        let map = self.inner.read().expect("registry lock poisoned");
        let mut total = Metrics::new();
        for entry in map.values() {
            total.merge(&entry.metrics.lock().expect("metrics lock poisoned"));
        }
        total
    }

    pub fn len(&self) -> usize {
        self.inner.read().expect("registry lock poisoned").len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::{ExecInput, ExecOutput};

    struct Stub;
    impl Executor for Stub {
        fn name(&self) -> &str {
            "stub"
        }
        fn input_shape(&self) -> &[usize] {
            &[2]
        }
        fn max_batch(&self) -> usize {
            4
        }
        fn run_batch(&self, input: &ExecInput) -> anyhow::Result<ExecOutput> {
            Ok(ExecOutput { logits: input.batch.clone() })
        }
    }

    fn entry(name: &str) -> ModelEntry {
        ModelEntry::new(name, Arc::new(Stub), ServerConfig::default(), Provenance::InMemory)
    }

    #[test]
    fn duplicate_register_is_typed() {
        let r = ModelRegistry::new();
        r.register(entry("m")).unwrap();
        match r.register(entry("m")) {
            Err(RegistryError::DuplicateName(n)) => assert_eq!(n, "m"),
            other => panic!("expected DuplicateName, got {other:?}"),
        }
        assert_eq!(r.len(), 1);
    }

    #[test]
    fn swap_bumps_version_and_keeps_metrics() {
        let r = ModelRegistry::new();
        r.register(entry("m")).unwrap();
        r.get("m").unwrap().metrics.lock().unwrap().completed = 5;
        let v2 = r.swap("m", Arc::new(Stub), Provenance::InMemory).unwrap();
        assert_eq!(v2, 2);
        let e = r.get("m").unwrap();
        assert_eq!(e.version, 2);
        assert_eq!(e.metrics.lock().unwrap().completed, 5, "ledger spans versions");
        // swapping an unknown name is typed, not an implicit register
        assert!(matches!(
            r.swap("ghost", Arc::new(Stub), Provenance::InMemory),
            Err(RegistryError::UnknownModel(_))
        ));
    }

    #[test]
    fn unload_removes_from_routing_and_returns_entry() {
        let r = ModelRegistry::new();
        r.register(entry("m")).unwrap();
        let removed = r.unload("m").unwrap();
        assert_eq!(removed.name, "m");
        assert!(r.get("m").is_none());
        assert!(matches!(r.unload("m"), Err(RegistryError::UnknownModel(_))));
        // the name can be re-registered afresh (version restarts at 1)
        r.register(entry("m")).unwrap();
        assert_eq!(r.get("m").unwrap().version, 1);
    }

    #[test]
    fn list_is_sorted_and_aggregate_sums() {
        let r = ModelRegistry::new();
        r.register(entry("zeta")).unwrap();
        r.register(entry("alpha")).unwrap();
        let names: Vec<String> = r.list().into_iter().map(|i| i.name).collect();
        assert_eq!(names, vec!["alpha", "zeta"]);
        r.get("alpha").unwrap().metrics.lock().unwrap().completed = 2;
        r.get("zeta").unwrap().metrics.lock().unwrap().completed = 3;
        assert_eq!(r.aggregate_metrics().completed, 5);
    }
}
