//! QAT training driver (S8).
//!
//! Two interchangeable backends implement the paper's training recipe
//! (FullPrecision, then FakeQuantized/STE fine-tuning, sec. 2.2):
//!
//! * [`native`] — the default: minibatch SGD over the backward-plan
//!   compiler ([`crate::engine::BackwardPlan`]), pure Rust, always
//!   available.
//! * `train_fp`/`train_fq` here — the AOT-compiled PJRT train-step
//!   artifacts (require the `pjrt` feature; Python authored the graph
//!   once at build time and is not in the loop).
//!
//! The evaluation helpers run on the native engines and are always
//! available.

pub mod native;

#[cfg(feature = "pjrt")]
use anyhow::{ensure, Context, Result};

#[cfg(feature = "pjrt")]
use crate::data::SynthDigits;
#[cfg(feature = "pjrt")]
use crate::model::artifact_args::{synthnet_fp_args, synthnet_fq_args};
#[cfg(feature = "pjrt")]
use crate::model::synthnet::SynthNet;
#[cfg(feature = "pjrt")]
use crate::runtime::Runtime;
use crate::tensor::TensorF;
#[cfg(feature = "pjrt")]
use crate::tensor::Tensor;

#[derive(Clone, Copy, Debug)]
pub struct TrainConfig {
    pub steps: usize,
    pub lr: f64,
    /// linear LR decay to lr*0.1 over the run
    pub lr_decay: bool,
    pub seed: u64,
    /// log every n steps (0 = silent)
    pub log_every: usize,
    /// SGD momentum (native backend; the PJRT artifacts bake their own
    /// plain-SGD update and ignore this).
    pub momentum: f64,
    /// L2 weight decay on conv/linear weights (native backend).
    pub weight_decay: f64,
    /// Minibatch size (native backend; the PJRT artifacts are lowered
    /// for [`TRAIN_BATCH`]).
    pub batch: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            steps: 300,
            lr: 0.05,
            lr_decay: true,
            seed: 1,
            log_every: 50,
            momentum: 0.9,
            weight_decay: 1e-4,
            batch: TRAIN_BATCH,
        }
    }
}

#[derive(Clone, Debug, Default)]
pub struct TrainReport {
    pub losses: Vec<f64>,
    pub steps: usize,
}

impl TrainReport {
    pub fn final_loss(&self) -> f64 {
        self.losses.last().copied().unwrap_or(f64::NAN)
    }

    /// Mean loss over the first/last k steps (loss-curve summary).
    pub fn head_tail(&self, k: usize) -> (f64, f64) {
        let k = k.min(self.losses.len());
        let head = self.losses[..k].iter().sum::<f64>() / k as f64;
        let tail = self.losses[self.losses.len() - k..].iter().sum::<f64>() / k as f64;
        (head, tail)
    }
}

/// The batch size all train artifacts were lowered with.
pub const TRAIN_BATCH: usize = 32;

/// Train in FullPrecision via the `synthnet_fp_train_b32` artifact.
/// Mutates `net` in place; returns the loss curve.
#[cfg(feature = "pjrt")]
pub fn train_fp(
    rt: &Runtime,
    net: &mut SynthNet,
    data: &mut SynthDigits,
    cfg: &TrainConfig,
) -> Result<TrainReport> {
    let exe = rt.load("synthnet_fp_train_b32")?;
    let n_params = net.param_list().len();
    let n_state = net.bn_state_list().len();
    let mut report = TrainReport::default();
    for step in 0..cfg.steps {
        let (x, labels) = data.batch(TRAIN_BATCH);
        let y: Vec<i32> = labels.iter().map(|l| *l as i32).collect();
        let lr = effective_lr(cfg, step);
        let mut args = synthnet_fp_args(net);
        args.push(x.into());
        args.push(Tensor::from_vec(&[TRAIN_BATCH], y).into());
        args.push(TensorF::scalar(lr as f32).into());
        let outs = exe.run(&args).context("fp train step")?;
        ensure!(outs.len() == n_params + n_state + 1);
        let params: Vec<TensorF> =
            outs[..n_params].iter().map(|a| a.as_f32().unwrap().clone()).collect();
        let state: Vec<TensorF> = outs[n_params..n_params + n_state]
            .iter()
            .map(|a| a.as_f32().unwrap().clone())
            .collect();
        let loss = outs.last().unwrap().as_f32()?.data()[0] as f64;
        net.update_from_flat(&params, &state, None)?;
        report.losses.push(loss);
        report.steps += 1;
        if cfg.log_every > 0 && step % cfg.log_every == 0 {
            eprintln!("[fp  step {step:4}] loss = {loss:.4} lr = {lr:.4}");
        }
    }
    Ok(report)
}

/// QAT fine-tuning via the `synthnet_fq_train_w{W}a{A}_b32` artifact.
/// Trains weights, BN parameters AND the PACT act betas (STE, sec. 2.2).
#[cfg(feature = "pjrt")]
pub fn train_fq(
    rt: &Runtime,
    net: &mut SynthNet,
    data: &mut SynthDigits,
    wbits: u32,
    abits: u32,
    cfg: &TrainConfig,
) -> Result<TrainReport> {
    let name = format!("synthnet_fq_train_w{wbits}a{abits}_b32");
    let exe = rt.load(&name)?;
    let n_params = net.param_list().len();
    let n_state = net.bn_state_list().len();
    let n_betas = net.act_betas.len();
    let mut report = TrainReport::default();
    for step in 0..cfg.steps {
        let (x, labels) = data.batch(TRAIN_BATCH);
        let y: Vec<i32> = labels.iter().map(|l| *l as i32).collect();
        let lr = effective_lr(cfg, step);
        let mut args = synthnet_fq_args(net);
        args.push(x.into());
        args.push(Tensor::from_vec(&[TRAIN_BATCH], y).into());
        args.push(TensorF::scalar(lr as f32).into());
        let outs = exe.run(&args).with_context(|| name.clone())?;
        ensure!(outs.len() == n_params + n_state + n_betas + 1);
        let params: Vec<TensorF> =
            outs[..n_params].iter().map(|a| a.as_f32().unwrap().clone()).collect();
        let state: Vec<TensorF> = outs[n_params..n_params + n_state]
            .iter()
            .map(|a| a.as_f32().unwrap().clone())
            .collect();
        let betas: Vec<TensorF> = outs[n_params + n_state..n_params + n_state + n_betas]
            .iter()
            .map(|a| a.as_f32().unwrap().clone())
            .collect();
        let loss = outs.last().unwrap().as_f32()?.data()[0] as f64;
        net.update_from_flat(&params, &state, Some(&betas))?;
        report.losses.push(loss);
        report.steps += 1;
        if cfg.log_every > 0 && step % cfg.log_every == 0 {
            eprintln!("[fq{wbits} step {step:4}] loss = {loss:.4} lr = {lr:.4}");
        }
    }
    Ok(report)
}

fn effective_lr(cfg: &TrainConfig, step: usize) -> f64 {
    if cfg.lr_decay && cfg.steps > 1 {
        let f = step as f64 / (cfg.steps - 1) as f64;
        cfg.lr * (1.0 - 0.9 * f)
    } else {
        cfg.lr
    }
}

/// Evaluate classification accuracy of a float graph on (x, labels).
pub fn eval_float(
    g: &crate::graph::Graph,
    x: &TensorF,
    labels: &[usize],
) -> f64 {
    let out = crate::engine::FloatEngine::new().run(g, x);
    crate::data::accuracy(&out.argmax_rows(), labels)
}

/// Evaluate accuracy of an IntegerDeployable graph via the integer engine.
pub fn eval_integer(
    g: &crate::graph::int::IntGraph,
    x: &TensorF,
    labels: &[usize],
    eps_in: f64,
) -> f64 {
    let qx = crate::quant::quantize_input(x, eps_in);
    let out = crate::engine::IntegerEngine::new().run(g, &qx);
    crate::data::accuracy(&out.argmax_rows(), labels)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lr_schedule_decays_linearly() {
        let cfg = TrainConfig { steps: 11, lr: 1.0, lr_decay: true, ..Default::default() };
        assert!((effective_lr(&cfg, 0) - 1.0).abs() < 1e-12);
        assert!((effective_lr(&cfg, 10) - 0.1).abs() < 1e-12);
    }

    #[test]
    fn report_head_tail() {
        let r = TrainReport { losses: vec![4.0, 3.0, 2.0, 1.0], steps: 4 };
        let (h, t) = r.head_tail(2);
        assert_eq!((h, t), (3.5, 1.5));
    }
}
