//! Minimal CLI argument parser (no clap in the offline vendor set).
//!
//! Grammar: `nemo <subcommand> [action] [--key value|--key=value|--switch] ...`
//!
//! At most one positional *action* may follow the subcommand (`nemo
//! client infer --model m`); anything positional after that is an
//! error. Repeated flags accumulate in order (`--model a.json --model
//! b.json`), so multi-model subcommands can take one flag per model;
//! the scalar accessors read the *last* occurrence, which keeps `--foo
//! x --foo y` backward compatible with the old last-wins behaviour.

use std::collections::HashMap;

use anyhow::{bail, Context, Result};

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: String,
    /// Optional positional action word right after the subcommand
    /// (`nemo client <action> ...`). Subcommands that take no action
    /// must reject it at dispatch.
    pub action: Option<String>,
    pub flags: HashMap<String, Vec<String>>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Result<Args> {
        let mut out = Args::default();
        let mut it = argv.iter().peekable();
        if let Some(sub) = it.next() {
            if sub.starts_with("--") {
                bail!("expected a subcommand before flags, got '{sub}'");
            }
            out.subcommand = sub.clone();
        }
        if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
            out.action = Some(it.next().unwrap().clone());
        }
        while let Some(tok) = it.next() {
            let Some(key) = tok.strip_prefix("--") else {
                bail!("unexpected positional argument '{tok}'");
            };
            if let Some((k, v)) = key.split_once('=') {
                out.push_flag(k, v.to_string());
            } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                out.push_flag(key, it.next().unwrap().clone());
            } else {
                out.push_flag(key, "true".to_string());
            }
        }
        Ok(out)
    }

    fn push_flag(&mut self, key: &str, value: String) {
        self.flags.entry(key.to_string()).or_default().push(value);
    }

    pub fn from_env() -> Result<Args> {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        Args::parse(&argv)
    }

    pub fn str_opt(&self, key: &str) -> Option<&str> {
        self.flags.get(key).and_then(|v| v.last()).map(|s| s.as_str())
    }

    /// Every occurrence of a repeatable flag, in command-line order.
    pub fn str_all(&self, key: &str) -> &[String] {
        self.flags.get(key).map(|v| v.as_slice()).unwrap_or(&[])
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.str_opt(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.str_opt(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{key} {v}: not an integer")),
        }
    }

    pub fn u32_or(&self, key: &str, default: u32) -> Result<u32> {
        Ok(self.usize_or(key, default as usize)? as u32)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        match self.str_opt(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{key} {v}: not a number")),
        }
    }

    pub fn bool(&self, key: &str) -> bool {
        matches!(self.str_opt(key), Some("true") | Some("1"))
    }
}

/// A `--model` value: `name=path`, or a bare path whose model name
/// defaults to the file stem with any artifact extension stripped —
/// `models/a.nemo.json`, `models/a.json` and `models/a.nemob` all
/// serve as "a".
pub fn model_spec(spec: &str) -> (String, String) {
    if let Some((name, path)) = spec.split_once('=') {
        if !name.is_empty() && !name.contains('/') {
            return (name.to_string(), path.to_string());
        }
    }
    let stem = std::path::Path::new(spec)
        .file_name()
        .map(|s| s.to_string_lossy().to_string())
        .unwrap_or_else(|| spec.to_string());
    let name = stem
        .strip_suffix(".nemo.json")
        .or_else(|| stem.strip_suffix(".json"))
        .or_else(|| stem.strip_suffix(".nemob"))
        .unwrap_or(stem.as_str())
        .to_string();
    (name, spec.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(&s.iter().map(|x| x.to_string()).collect::<Vec<_>>()).unwrap()
    }

    #[test]
    fn basic_parsing() {
        let a = parse(&["train", "--steps", "100", "--lr=0.1", "--quiet"]);
        assert_eq!(a.subcommand, "train");
        assert_eq!(a.usize_or("steps", 0).unwrap(), 100);
        assert_eq!(a.f64_or("lr", 0.0).unwrap(), 0.1);
        assert!(a.bool("quiet"));
        assert!(!a.bool("verbose"));
        assert_eq!(a.usize_or("missing", 7).unwrap(), 7);
    }

    #[test]
    fn rejects_bad_input() {
        assert!(Args::parse(&["--flag-first".to_string()]).is_err());
        let a = parse(&["x", "--n", "abc"]);
        assert!(a.usize_or("n", 0).is_err());
    }

    #[test]
    fn one_positional_action_after_the_subcommand() {
        let a = parse(&["client", "infer", "--model", "m"]);
        assert_eq!(a.subcommand, "client");
        assert_eq!(a.action.as_deref(), Some("infer"));
        assert_eq!(a.str_opt("model"), Some("m"));
        // no action: flags immediately after the subcommand
        let a = parse(&["serve", "--listen", "127.0.0.1:0"]);
        assert_eq!(a.action, None);
        // a second positional is still an error
        let argv: Vec<String> =
            ["client", "infer", "extra"].iter().map(|s| s.to_string()).collect();
        assert!(Args::parse(&argv).is_err());
    }

    #[test]
    fn model_specs_strip_artifact_extensions() {
        assert_eq!(model_spec("models/a.nemo.json"), ("a".into(), "models/a.nemo.json".into()));
        assert_eq!(model_spec("b.json"), ("b".into(), "b.json".into()));
        assert_eq!(model_spec("models/c.nemob"), ("c".into(), "models/c.nemob".into()));
        assert_eq!(model_spec("named=x.nemob"), ("named".into(), "x.nemob".into()));
        assert_eq!(model_spec("plain"), ("plain".into(), "plain".into()));
    }

    #[test]
    fn repeated_flags_accumulate_in_order() {
        let a = parse(&["serve", "--model", "a.json", "--model=b.json", "--model", "c.json"]);
        assert_eq!(a.str_all("model"), &["a.json", "b.json", "c.json"]);
        // scalar accessors stay last-wins
        assert_eq!(a.str_opt("model"), Some("c.json"));
        assert!(a.str_all("absent").is_empty());
    }
}
