//! IntegerDeployable graph: integer-image operators only (paper sec. 3).
//!
//! Produced by `transform::integerize`; executed by
//! `engine::IntegerEngine` (the MCU-datapath simulator) and — through the
//! equivalent HLO artifact — by the PJRT runtime.

use crate::quant::bn::{BnQuant, Thresholds};
use crate::quant::requant::Requant;
use crate::quant::QuantSpec;
use crate::tensor::TensorI;

pub type NodeId = usize;

/// Integer-domain operator.
#[derive(Clone, Debug)]
pub enum IntOp {
    /// Integer input image, NCHW shape (without batch).
    Input { shape: Vec<usize>, spec: QuantSpec },
    /// Convolution with weights in matrix layout [C_in*KH*KW, C_out]
    /// (Eq. 16). Bias (if any) is already in the eps_phi space.
    ConvInt {
        wq: TensorI,
        bias_q: Option<Vec<i64>>,
        cin: usize,
        kh: usize,
        kw: usize,
        stride: usize,
        pad: usize,
    },
    /// Fully-connected: weights [in, out] (Eq. 16).
    LinearInt { wq: TensorI, bias_q: Option<Vec<i64>> },
    /// Integer batch-norm (Eq. 22).
    IntBn { bn: BnQuant },
    /// Requantizing activation (Eq. 11): clip((m*q) >> d, 0, 2^Q-1).
    RequantAct { rq: Requant },
    /// Threshold activation (Eq. 19-20) — the exact BN+act merge.
    ThreshAct { th: Thresholds },
    /// Integer average pooling (Eq. 25).
    AvgPoolInt { k: usize, d: u32 },
    /// Max pooling (untouched by quantization, sec. 3.6).
    MaxPoolInt { k: usize },
    Flatten,
    /// Add with per-branch requantization (Eq. 24): branch 0 is the
    /// reference space; rqs[i] requantizes branch i+1 into it.
    AddRequant { rqs: Vec<Requant> },
}

impl IntOp {
    pub fn name(&self) -> &'static str {
        match self {
            IntOp::Input { .. } => "Input",
            IntOp::ConvInt { .. } => "ConvInt",
            IntOp::LinearInt { .. } => "LinearInt",
            IntOp::IntBn { .. } => "IntBn",
            IntOp::RequantAct { .. } => "RequantAct",
            IntOp::ThreshAct { .. } => "ThreshAct",
            IntOp::AvgPoolInt { .. } => "AvgPoolInt",
            IntOp::MaxPoolInt { .. } => "MaxPoolInt",
            IntOp::Flatten => "Flatten",
            IntOp::AddRequant { .. } => "AddRequant",
        }
    }
}

#[derive(Clone, Debug)]
pub struct IntNode {
    pub id: NodeId,
    pub op: IntOp,
    pub inputs: Vec<NodeId>,
    pub name: String,
}

/// IntegerDeployable graph plus the eps bookkeeping needed to interpret
/// its (integer) output in the real domain.
#[derive(Clone, Debug, Default)]
pub struct IntGraph {
    pub nodes: Vec<IntNode>,
    pub output: NodeId,
    /// Quantum of the output integer image: logits_real ~ eps_out * Q.
    pub eps_out: f64,
}

impl IntGraph {
    pub fn push(&mut self, name: &str, op: IntOp, inputs: &[NodeId]) -> NodeId {
        let id = self.nodes.len();
        for &i in inputs {
            assert!(i < id, "forward reference");
        }
        self.nodes.push(IntNode { id, op, inputs: inputs.to_vec(), name: name.into() });
        self.output = id;
        id
    }

    pub fn node(&self, id: NodeId) -> &IntNode {
        &self.nodes[id]
    }
}
