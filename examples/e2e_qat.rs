//! END-TO-END driver (the repository's headline validation; experiments
//! E3 + E4): proves all three layers compose on a real workload.
//! Requires the `pjrt` feature (training runs through AOT artifacts):
//!
//!     make artifacts && cargo run --release --features pjrt --example e2e_qat
//!
//! Flow (Python never runs — all compute goes through the AOT artifacts
//! or the Rust engines):
//!   1. train SynthNet in FullPrecision for several hundred steps via the
//!      PJRT-compiled train step, logging the loss curve;
//!   2. calibrate PACT clipping bounds from the FP stage (sec. 2);
//!   3. QAT fine-tune in FakeQuantized at 4 bits (STE + trainable beta);
//!   4. deploy through the typestate pipeline: FakeQuantized ->
//!      QuantizedDeployable -> IntegerDeployable (sec. 3);
//!   5. evaluate all four representations + the PJRT IntegerDeployable
//!      artifact, and check engine-vs-PJRT bit-exactness.
//!
//! Results land in EXPERIMENTS.md (E3/E4).

use nemo::data::SynthDigits;
use nemo::io::artifacts_dir;
use nemo::model::artifact_args::synthnet_id_args;
use nemo::model::synthnet::{SynthNet, EPS_IN};
use nemo::network::Network;
use nemo::quant::quantize_input;
use nemo::runtime::Runtime;
use nemo::train::{eval_float, eval_integer, train_fp, train_fq, TrainConfig};
use nemo::transform::DeployOptions;
use nemo::util::rng::Rng;

fn curve(losses: &[f64], buckets: usize) -> String {
    let chunk = (losses.len() / buckets).max(1);
    losses
        .chunks(chunk)
        .map(|c| format!("{:.3}", c.iter().sum::<f64>() / c.len() as f64))
        .collect::<Vec<_>>()
        .join(" -> ")
}

fn main() -> anyhow::Result<()> {
    let rt = Runtime::new(artifacts_dir())?;
    let seed = 1u64;
    let mut rng = Rng::new(seed);
    let mut net = SynthNet::init(&mut rng);
    let mut data = SynthDigits::new(seed);
    let bits = 4u32;
    let opts = DeployOptions { wbits: bits, abits: bits, ..DeployOptions::default() };

    // -- 1. FullPrecision training ---------------------------------------
    let fp_cfg = TrainConfig { steps: 600, lr: 0.3, lr_decay: true, seed, log_every: 100 };
    println!("== stage 1: FullPrecision training ({} steps, b=32) ==", fp_cfg.steps);
    let t0 = std::time::Instant::now();
    let fp_rep = train_fp(&rt, &mut net, &mut data, &fp_cfg)?;
    println!("loss curve: {}", curve(&fp_rep.losses, 8));
    println!("wall: {:.1}s ({:.1} steps/s)", t0.elapsed().as_secs_f64(),
             fp_cfg.steps as f64 / t0.elapsed().as_secs_f64());

    let (eval_x, eval_l) = SynthDigits::eval_set(seed, 1024);
    let fp_acc = eval_float(&net.to_fp_graph(), &eval_x, &eval_l);
    println!("FP accuracy: {:.1}%", fp_acc * 100.0);

    // -- 2. calibration ----------------------------------------------------
    let (cal_x, _) = data.batch(128);
    net.act_betas = Network::from_graph(net.to_fp_graph())?
        .calibrate_percentile(&[cal_x], 0.995);
    println!("\n== stage 2: calibrated PACT betas {:?}", net.act_betas);

    // Pre-QAT deployment at 4 bits (ablation: what QAT buys us, E4).
    let id0 = net.to_network(bits)?.deploy(opts)?.integerize();
    let id_acc_pre = eval_integer(id0.int_graph(), &eval_x, &eval_l, EPS_IN);

    // -- 3. QAT fine-tune at 4 bits (STE, trainable beta) ------------------
    let fq_cfg = TrainConfig { steps: 300, lr: 0.06, lr_decay: true, seed, log_every: 100 };
    println!("\n== stage 3: FakeQuantized QAT w{bits}a{bits} ({} steps) ==", fq_cfg.steps);
    let fq_rep = train_fq(&rt, &mut net, &mut data, bits, bits, &fq_cfg)?;
    println!("loss curve: {}", curve(&fq_rep.losses, 8));
    println!("betas after QAT: {:?}", net.act_betas);

    // -- 4. deployment (typestate pipeline FQ -> QD -> ID) -----------------
    println!("\n== stage 4: deployment (sec. 3 pipeline) ==");
    let nid = net.to_network(bits)?.deploy(opts)?.integerize();
    for l in nid.layers() {
        println!(
            "  {:<6} eps_w {:.3e}  eps_phi_out {:.3e}  eps_y {:.3e}  m {} d {}",
            l.name, l.eps_w, l.eps_phi_out, l.eps_y, l.m, l.d
        );
    }

    // -- 5. evaluation -------------------------------------------------------
    println!("\n== stage 5: evaluation (1024 samples) ==");
    let fq_acc = eval_float(&nid.deployed().qd, &eval_x, &eval_l); // QD == hardened FQ
    let id_acc = eval_integer(nid.int_graph(), &eval_x, &eval_l, EPS_IN);
    println!("  FP  (float32)           : {:.1}%", fp_acc * 100.0);
    println!("  ID  w{bits}a{bits} pre-QAT      : {:.1}%", id_acc_pre * 100.0);
    println!("  QD  w{bits}a{bits} post-QAT     : {:.1}%", fq_acc * 100.0);
    println!("  ID  w{bits}a{bits} post-QAT     : {:.1}%", id_acc * 100.0);

    // PJRT (Pallas kernels) vs integer engine: bit-exact on a batch.
    let qx = quantize_input(&eval_x.slice_batch(0, 16), EPS_IN);
    let engine_out = nid.run(&qx);
    let exe = rt.load("synthnet_id_fwd_b16")?;
    let mut args = synthnet_id_args(nid.deployed())?;
    args.push(qx.into());
    let pjrt_out = exe.run(&args)?;
    assert_eq!(
        pjrt_out[0].as_i32()?.data(),
        engine_out.data(),
        "PJRT and IntegerEngine must agree bit-exactly"
    );
    println!("  PJRT(Pallas) == IntegerEngine on integer logits: bit-exact ✓");

    println!("\nE2E OK");
    Ok(())
}
