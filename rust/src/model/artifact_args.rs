//! Glue between the Rust-side model/transform state and the flat
//! positional argument lists the AOT artifacts expect (orders defined by
//! python/compile/model.py *_spec functions, recorded in manifest.json).

use anyhow::{bail, ensure, Result};

use crate::graph::int::IntOp;
use crate::exec::Arg;
use crate::tensor::Tensor;
use crate::transform::Deployed;

use super::synthnet::SynthNet;

/// FP/FQ artifact parameter list: params [11] ++ bn_state [6].
pub fn synthnet_fp_args(net: &SynthNet) -> Vec<Arg> {
    let mut args: Vec<Arg> = Vec::new();
    for t in net.param_list() {
        args.push(t.into());
    }
    for t in net.bn_state_list() {
        args.push(t.into());
    }
    args
}

/// FQ artifacts additionally take the PACT act betas [3].
pub fn synthnet_fq_args(net: &SynthNet) -> Vec<Arg> {
    let mut args = synthnet_fp_args(net);
    for t in net.act_beta_list() {
        args.push(t.into());
    }
    args
}

/// ID artifact argument list (python model.id_spec order):
/// per conv: wq, kappa_q, lambda_q, m, d, act_hi; then fc.wq, fc.bq.
///
/// Extracted from the IntegerDeployable graph produced by
/// `Network::<FakeQuantized>::deploy` — validates that the graph has the
/// SynthNet topology (3x [ConvInt, IntBn, RequantAct], AvgPool, Flatten,
/// LinearInt).
pub fn synthnet_id_args(dep: &Deployed) -> Result<Vec<Arg>> {
    let mut args: Vec<Arg> = Vec::new();
    let nodes = &dep.id.nodes;
    let mut i = 0usize;
    ensure!(
        matches!(nodes[i].op, IntOp::Input { .. }),
        "node 0 must be Input"
    );
    i += 1;
    for conv in 0..3 {
        let IntOp::ConvInt { wq, .. } = &nodes[i].op else {
            bail!("expected ConvInt at node {i} (conv {conv})");
        };
        let IntOp::IntBn { bn } = &nodes[i + 1].op else {
            bail!(
                "expected IntBn at node {} (use_thresholds graphs have no \
                 id_fwd artifact)",
                i + 1
            );
        };
        let IntOp::RequantAct { rq } = &nodes[i + 2].op else {
            bail!("expected RequantAct at node {}", i + 2);
        };
        args.push(wq.widen().into());
        args.push(Tensor::from_vec(&[bn.kappa_q.len()], bn.kappa_q.clone()).into());
        args.push(Tensor::from_vec(&[bn.lambda_q.len()], bn.lambda_q.clone()).into());
        args.push(Tensor::scalar(rq.m as i32).into());
        args.push(Tensor::scalar(rq.d as i32).into());
        args.push(Tensor::scalar(rq.hi as i32).into());
        i += 3;
    }
    ensure!(matches!(nodes[i].op, IntOp::AvgPoolInt { .. }), "expected AvgPoolInt");
    ensure!(matches!(nodes[i + 1].op, IntOp::Flatten), "expected Flatten");
    i += 2;
    let IntOp::LinearInt { wq, bias_q } = &nodes[i].op else {
        bail!("expected LinearInt at node {i}");
    };
    args.push(wq.widen().into());
    let bq: Vec<i32> = match bias_q {
        Some(b) => b.iter().map(|v| *v as i32).collect(),
        None => vec![0; wq.shape()[1]],
    };
    args.push(Tensor::from_vec(&[bq.len()], bq).into());
    Ok(args)
}
