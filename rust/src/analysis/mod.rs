//! Static soundness verification of IntegerDeployable graphs
//! (`nemo check`, DESIGN.md §Static-verification).
//!
//! [`check_graph`] runs an interval abstract interpretation
//! ([`interval`]) over an [`IntGraph`] and proves — or refutes, with a
//! node-attributed diagnostic — that the paper's integer-only pipeline
//! claim holds for the *actual* weights and grids in the model, not
//! just the worst case of each precision class:
//!
//! * every GEMM/BN/add accumulator fits the i32 datapath
//!   ([`rules::ACC_OVERFLOW`]);
//! * every requant respects the 1/η bound `d <= D_MAX`, `m >= 1`
//!   (Eq. 13-14, [`rules::REQUANT_PARAMS`]) and pure-rescale requants
//!   never reach their clamp ([`rules::REQUANT_SATURATION`]);
//! * every `Precision` stamp contains its node's inferred interval
//!   ([`rules::PRECISION_UNSOUND`]), with provably-loose stamps flagged
//!   as missed packing ([`rules::PRECISION_LOOSE`]);
//! * structural hygiene: dead nodes, unused weight tensors, and
//!   bit-serial-eligible GEMMs left on the MAC path
//!   ([`rules::DEAD_NODE`], [`rules::UNUSED_WEIGHTS`],
//!   [`rules::BITSERIAL_MISSED`]).
//!
//! The verifier is wired in at three layers: `transform::deploy` hard-
//! errors on unsound graphs it would otherwise emit, the artifact
//! loaders re-check untrusted files under [`CheckMode`], and the
//! `nemo check` CLI verb renders [`CheckReport`] for operators (human
//! or `--json`).

pub mod interval;

use crate::engine::plan::IntPlan;
use crate::graph::int::{IntGraph, IntOp};
use crate::graph::NodeId;
use crate::quant::requant::{Requant, D_MAX};
use crate::quant::Precision;
use crate::util::json::{obj, Value};

pub use interval::{infer_intervals, Interval};

/// Stable rule identifiers, in report order. The `check --json` schema
/// emits a count per rule — every id, every time — so downstream
/// tooling can key on them.
pub mod rules {
    /// Graph fails structural validation or plan compilation.
    pub const GRAPH_STRUCTURE: &str = "graph-structure";
    /// An accumulator/result interval escapes the i32 datapath.
    pub const ACC_OVERFLOW: &str = "acc-overflow";
    /// Requant shift/multiplier outside the paper's legal range.
    pub const REQUANT_PARAMS: &str = "requant-params";
    /// A pure-rescale requant can reach its saturating clamp.
    pub const REQUANT_SATURATION: &str = "requant-saturation";
    /// A precision stamp does not contain the inferred interval.
    pub const PRECISION_UNSOUND: &str = "precision-unsound";
    /// A stamp is provably wider than the interval needs (missed packing).
    pub const PRECISION_LOOSE: &str = "precision-loose";
    /// A node is unreachable from the graph output.
    pub const DEAD_NODE: &str = "dead-node";
    /// A dead GEMM node carries a weight tensor that is never read.
    pub const UNUSED_WEIGHTS: &str = "unused-weights";
    /// A bit-serial-eligible GEMM is routed to the MAC kernels.
    pub const BITSERIAL_MISSED: &str = "bitserial-missed";

    pub const ALL: [&str; 9] = [
        GRAPH_STRUCTURE,
        ACC_OVERFLOW,
        REQUANT_PARAMS,
        REQUANT_SATURATION,
        PRECISION_UNSOUND,
        PRECISION_LOOSE,
        DEAD_NODE,
        UNUSED_WEIGHTS,
        BITSERIAL_MISSED,
    ];
}

/// How much the artifact loaders trust a checksum-valid file.
///
/// * `Off` — structural decode + precision re-proof only (the historic
///   contract).
/// * `Warn` — run the verifier, print findings to stderr, load anyway.
/// * `Strict` — any `Error`-severity finding rejects the artifact; a
///   checksum-valid file with adversarial weights must not load.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum CheckMode {
    Off,
    #[default]
    Warn,
    Strict,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    Warning,
    Error,
}

impl Severity {
    pub fn name(self) -> &'static str {
        match self {
            Severity::Warning => "warning",
            Severity::Error => "error",
        }
    }
}

/// One rule violation, attributed to a node where one exists.
#[derive(Clone, Debug)]
pub struct Finding {
    pub rule: &'static str,
    pub severity: Severity,
    pub node: Option<NodeId>,
    /// Node name (or a structural location) for human rendering.
    pub name: String,
    pub message: String,
}

/// The verifier's structured result: findings plus the per-node
/// intervals the proofs rest on (indexed by node id; empty when the
/// graph failed structural validation before inference ran).
#[derive(Clone, Debug, Default)]
pub struct CheckReport {
    pub findings: Vec<Finding>,
    pub intervals: Vec<Interval>,
    pub nodes_checked: usize,
}

impl CheckReport {
    pub fn errors(&self) -> usize {
        self.findings.iter().filter(|f| f.severity == Severity::Error).count()
    }

    pub fn warnings(&self) -> usize {
        self.findings.iter().filter(|f| f.severity == Severity::Warning).count()
    }

    /// No `Error`-severity finding — warnings do not affect soundness.
    pub fn is_sound(&self) -> bool {
        self.errors() == 0
    }

    pub fn first_error(&self) -> Option<&Finding> {
        self.findings.iter().find(|f| f.severity == Severity::Error)
    }

    fn rule_count(&self, rule: &str) -> usize {
        self.findings.iter().filter(|f| f.rule == rule).count()
    }

    /// One-line operator summary: rule pass count + finding totals
    /// (`nemo info`, end of `nemo check` output).
    pub fn summary_line(&self) -> String {
        let violated = rules::ALL.iter().filter(|r| self.rule_count(r) > 0).count();
        let verdict = if self.is_sound() { "sound" } else { "UNSOUND" };
        format!(
            "{verdict} — {}/{} rules pass, {} errors, {} warnings, {} nodes",
            rules::ALL.len() - violated,
            rules::ALL.len(),
            self.errors(),
            self.warnings(),
            self.nodes_checked
        )
    }

    /// Multi-line human rendering: one line per finding, errors first.
    pub fn render_human(&self) -> String {
        let mut lines: Vec<String> = Vec::new();
        for sev in [Severity::Error, Severity::Warning] {
            for f in self.findings.iter().filter(|f| f.severity == sev) {
                let loc = match f.node {
                    Some(id) => format!("node {id} '{}'", f.name),
                    None => f.name.clone(),
                };
                lines.push(format!("{} [{}] {}: {}", sev.name(), f.rule, loc, f.message));
            }
        }
        lines.join("\n")
    }

    /// Stable JSON rendering (`nemo check --json`). Schema:
    /// `format`/`version` tags, finding list, a count for *every* rule
    /// id, and the per-node intervals. Keys serialize alphabetically
    /// (BTreeMap), so the byte layout is deterministic.
    pub fn to_json(&self, source: &str) -> String {
        let findings: Vec<Value> = self
            .findings
            .iter()
            .map(|f| {
                obj(vec![
                    ("message", Value::Str(f.message.clone())),
                    ("name", Value::Str(f.name.clone())),
                    (
                        "node",
                        match f.node {
                            Some(id) => Value::Int(id as i64),
                            None => Value::Null,
                        },
                    ),
                    ("rule", Value::Str(f.rule.to_string())),
                    ("severity", Value::Str(f.severity.name().to_string())),
                ])
            })
            .collect();
        let rule_counts: Vec<Value> = rules::ALL
            .iter()
            .map(|r| {
                obj(vec![
                    ("id", Value::Str(r.to_string())),
                    ("violations", Value::Int(self.rule_count(r) as i64)),
                ])
            })
            .collect();
        let intervals: Vec<Value> = self
            .intervals
            .iter()
            .map(|iv| Value::Arr(vec![Value::Int(iv.lo), Value::Int(iv.hi)]))
            .collect();
        let doc = obj(vec![
            ("errors", Value::Int(self.errors() as i64)),
            ("findings", Value::Arr(findings)),
            ("format", Value::Str("nemo-check-report".to_string())),
            ("intervals", Value::Arr(intervals)),
            ("nodes", Value::Int(self.nodes_checked as i64)),
            ("rules", Value::Arr(rule_counts)),
            ("source", Value::Str(source.to_string())),
            ("version", Value::Int(1)),
        ]);
        crate::util::json::write(&doc)
    }
}

/// Is this requant a pure rescale — a clip so wide (the full i32
/// datapath or beyond) that the paper's semantics say the clamp must
/// never engage? Activation requants clip *by design* ([0, 2^Q-1]) and
/// are exempt from the saturation rule.
fn is_pure_rescale(rq: &Requant) -> bool {
    rq.lo <= i32::MIN as i64 && rq.hi >= i32::MAX as i64
}

fn check_requant_params(
    findings: &mut Vec<Finding>,
    node: NodeId,
    name: &str,
    what: &str,
    rq: &Requant,
) {
    if rq.d > D_MAX {
        findings.push(Finding {
            rule: rules::REQUANT_PARAMS,
            severity: Severity::Error,
            node: Some(node),
            name: name.to_string(),
            message: format!(
                "{what} shift d={} exceeds D_MAX={D_MAX} (paper 1/\u{3b7} bound, Eq. 14)",
                rq.d
            ),
        });
    }
    if rq.m < 1 {
        findings.push(Finding {
            rule: rules::REQUANT_PARAMS,
            severity: Severity::Error,
            node: Some(node),
            name: name.to_string(),
            message: format!("{what} multiplier m={} < 1 collapses the grid (Eq. 13)", rq.m),
        });
    }
}

fn check_requant_saturation(
    findings: &mut Vec<Finding>,
    node: NodeId,
    name: &str,
    what: &str,
    rq: &Requant,
    x: Interval,
) {
    if !is_pure_rescale(rq) {
        return;
    }
    let (lo, hi) = interval::requant_preclip(rq, x);
    if lo < rq.lo as i128 || hi > rq.hi as i128 {
        findings.push(Finding {
            rule: rules::REQUANT_SATURATION,
            severity: Severity::Error,
            node: Some(node),
            name: name.to_string(),
            message: format!(
                "{what} pre-clip product spans [{lo}, {hi}] — saturation at \
                 [{}, {}] is reachable (Eq. 11)",
                rq.lo, rq.hi
            ),
        });
    }
}

/// Node ids reachable backward from the output.
fn reachable_set(g: &IntGraph) -> Vec<bool> {
    let mut seen = vec![false; g.nodes.len()];
    let mut stack = vec![g.output];
    while let Some(id) = stack.pop() {
        if std::mem::replace(&mut seen[id], true) {
            continue;
        }
        stack.extend(g.nodes[id].inputs.iter().copied());
    }
    seen
}

/// Run every rule over `g` and return the structured report. Never
/// panics on malformed graphs: structural validation failures become a
/// single [`rules::GRAPH_STRUCTURE`] error and inference is skipped.
pub fn check_graph(g: &IntGraph) -> CheckReport {
    if let Err(e) = g.validate() {
        return CheckReport {
            findings: vec![Finding {
                rule: rules::GRAPH_STRUCTURE,
                severity: Severity::Error,
                node: None,
                name: "graph".to_string(),
                message: format!("structural validation failed: {e}"),
            }],
            intervals: Vec::new(),
            nodes_checked: g.nodes.len(),
        };
    }

    let intervals = infer_intervals(g);
    let reachable = reachable_set(g);
    let mut findings: Vec<Finding> = Vec::new();
    let i32_cap = i32::MAX as i64;

    for nd in &g.nodes {
        let iv = intervals[nd.id];
        let in0 = nd.inputs.first().map(|&i| intervals[i]);
        let mut overflowed = false;
        let overflow = |findings: &mut Vec<Finding>, detail: String| {
            findings.push(Finding {
                rule: rules::ACC_OVERFLOW,
                severity: Severity::Error,
                node: Some(nd.id),
                name: nd.name.clone(),
                message: detail,
            });
        };

        match &nd.op {
            IntOp::Input { .. } => {
                if !iv.fits_i32() {
                    overflowed = true;
                    overflow(
                        &mut findings,
                        format!(
                            "input grid [{}, {}] does not fit the i32 datapath",
                            iv.lo, iv.hi
                        ),
                    );
                }
            }
            IntOp::ConvInt { .. } | IntOp::LinearInt { .. } | IntOp::IntBn { .. } => {
                if !iv.fits_i32() {
                    overflowed = true;
                    overflow(
                        &mut findings,
                        format!(
                            "{} accumulator interval [{}, {}] exceeds i32 for the \
                             actual weight magnitudes",
                            nd.op.name(),
                            iv.lo,
                            iv.hi
                        ),
                    );
                }
            }
            IntOp::RequantAct { rq } => {
                let x = in0.expect("requant has an input");
                check_requant_params(&mut findings, nd.id, &nd.name, "requant", rq);
                check_requant_saturation(&mut findings, nd.id, &nd.name, "requant", rq, x);
                if !iv.fits_i32() {
                    // the interpreter casts rq.apply() straight to i32
                    overflowed = true;
                    overflow(
                        &mut findings,
                        format!("requant output [{}, {}] escapes i32", iv.lo, iv.hi),
                    );
                }
            }
            IntOp::ThreshAct { th } => {
                if th.n_levels > i32_cap {
                    overflowed = true;
                    overflow(
                        &mut findings,
                        format!("{} threshold levels exceed i32", th.n_levels),
                    );
                }
            }
            IntOp::AvgPoolInt { k, d } => {
                let x = in0.expect("pool has an input");
                let acc = (x.max_abs() as i128) * (*k as i128) * (*k as i128);
                if acc > i32_cap as i128 || !iv.fits_i32() {
                    overflowed = true;
                    overflow(
                        &mut findings,
                        format!(
                            "avg-pool accumulator reaches {acc} over k={k} window \
                             (input [{}, {}])",
                            x.lo, x.hi
                        ),
                    );
                }
                if *d > D_MAX {
                    findings.push(Finding {
                        rule: rules::REQUANT_PARAMS,
                        severity: Severity::Error,
                        node: Some(nd.id),
                        name: nd.name.clone(),
                        message: format!(
                            "avg-pool shift d={d} exceeds D_MAX={D_MAX} (Eq. 25)"
                        ),
                    });
                }
            }
            IntOp::AddRequant { rqs } => {
                // The engine narrows the running sum to i32 after every
                // branch, so each partial-sum interval must fit — not
                // just the final one.
                let rf = intervals[nd.inputs[0]];
                let (mut lo, mut hi) = (rf.lo as i128, rf.hi as i128);
                let (mut env_lo, mut env_hi) = (lo, hi);
                for (i, rq) in rqs.iter().enumerate() {
                    let bx = intervals[nd.inputs[i + 1]];
                    let what = format!("add branch {}", i + 1);
                    check_requant_params(&mut findings, nd.id, &nd.name, &what, rq);
                    check_requant_saturation(&mut findings, nd.id, &nd.name, &what, rq, bx);
                    let b = interval::requant_range(rq, bx);
                    lo += b.lo as i128;
                    hi += b.hi as i128;
                    env_lo = env_lo.min(lo);
                    env_hi = env_hi.max(hi);
                }
                if env_lo < i32::MIN as i128 || env_hi > i32_cap as i128 {
                    overflowed = true;
                    overflow(
                        &mut findings,
                        format!(
                            "add partial-sum envelope [{env_lo}, {env_hi}] escapes \
                             the per-branch i32 narrowing"
                        ),
                    );
                }
            }
            IntOp::MaxPoolInt { .. } | IntOp::Flatten => {}
        }

        // Precision stamps: the stamp must contain the inferred
        // interval (skip nodes already reported as overflowing — the
        // stamp is the least of their problems), and clipped ops whose
        // interval provably fits a narrower class are missed packing.
        if !overflowed && !nd.precision.contains(iv.lo, iv.hi) {
            findings.push(Finding {
                rule: rules::PRECISION_UNSOUND,
                severity: Severity::Error,
                node: Some(nd.id),
                name: nd.name.clone(),
                message: format!(
                    "stamped {} but inferred interval [{}, {}] escapes it",
                    nd.precision.name(),
                    iv.lo,
                    iv.hi
                ),
            });
        } else if matches!(
            nd.op,
            IntOp::Input { .. } | IntOp::RequantAct { .. } | IntOp::ThreshAct { .. }
        ) {
            let tight = Precision::for_range(iv.lo, iv.hi);
            if tight.bits() < nd.precision.bits() {
                findings.push(Finding {
                    rule: rules::PRECISION_LOOSE,
                    severity: Severity::Warning,
                    node: Some(nd.id),
                    name: nd.name.clone(),
                    message: format!(
                        "stamped {} but interval [{}, {}] fits {} — missed packing",
                        nd.precision.name(),
                        iv.lo,
                        iv.hi,
                        tight.name()
                    ),
                });
            }
        }

        if !reachable[nd.id] {
            let gemm = matches!(nd.op, IntOp::ConvInt { .. } | IntOp::LinearInt { .. });
            findings.push(Finding {
                rule: if gemm { rules::UNUSED_WEIGHTS } else { rules::DEAD_NODE },
                severity: Severity::Warning,
                node: Some(nd.id),
                name: nd.name.clone(),
                message: if gemm {
                    format!(
                        "{} is unreachable from the output — its weight tensor is \
                         never read",
                        nd.op.name()
                    )
                } else {
                    format!("{} is unreachable from the output", nd.op.name())
                },
            });
        }
    }

    // Routing facts come from the compiled plan: a GEMM whose weights
    // fit a few-bit grid and whose *interval* fits 1-2 unsigned bits
    // should be on the bit-serial AND+popcount path.
    match IntPlan::compile(g) {
        Ok(plan) => {
            for r in plan.gemm_routing() {
                if r.bitserial {
                    continue;
                }
                let Some(bits) = r.weight_bits else { continue };
                if bits > 4 {
                    continue;
                }
                let x = intervals[r.input_node];
                if x.lo >= 0 && x.hi <= 3 {
                    let nd = g.node(r.node);
                    findings.push(Finding {
                        rule: rules::BITSERIAL_MISSED,
                        severity: Severity::Warning,
                        node: Some(r.node),
                        name: nd.name.clone(),
                        message: format!(
                            "weights fit {bits} bits and input interval [{}, {}] \
                             fits {}, but the GEMM is routed to the MAC kernels \
                             (input stamped {})",
                            x.lo,
                            x.hi,
                            Precision::for_range(x.lo, x.hi).name(),
                            r.input_precision.name()
                        ),
                    });
                }
            }
        }
        Err(e) => findings.push(Finding {
            rule: rules::GRAPH_STRUCTURE,
            severity: Severity::Error,
            node: None,
            name: "plan".to_string(),
            message: format!("plan compilation failed: {e}"),
        }),
    }

    CheckReport { findings, intervals, nodes_checked: g.nodes.len() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::int::IntNode;
    use crate::quant::QuantSpec;
    use crate::tensor::{QTensor, TensorI};

    fn input_node(bits: u32) -> IntNode {
        let spec = QuantSpec::activation(1.0, bits);
        IntNode {
            id: 0,
            op: IntOp::Input { shape: vec![4], spec },
            inputs: vec![],
            name: "in".into(),
            precision: Precision::of_spec(&spec),
        }
    }

    fn linear(id: usize, input: usize, w: Vec<i32>, co: usize, prec: Precision) -> IntNode {
        let rows = w.len() / co;
        IntNode {
            id,
            op: IntOp::LinearInt {
                wq: QTensor::I32(TensorI::from_vec(&[rows, co], w)),
                bias_q: None,
            },
            inputs: vec![input],
            name: format!("fc{id}"),
            precision: prec,
        }
    }

    fn graph(nodes: Vec<IntNode>, output: usize) -> IntGraph {
        IntGraph { nodes, output, eps_out: 1.0 }
    }

    #[test]
    fn clean_two_layer_graph_is_sound() {
        let g = graph(
            vec![
                input_node(8),
                linear(1, 0, vec![1, -2, 3, -4], 1, Precision::I32),
                IntNode {
                    id: 2,
                    op: IntOp::RequantAct { rq: Requant { m: 128, d: 8, lo: 0, hi: 255 } },
                    inputs: vec![1],
                    name: "act".into(),
                    precision: Precision::U8,
                },
            ],
            2,
        );
        let r = check_graph(&g);
        assert!(r.is_sound(), "unexpected findings: {}", r.render_human());
        assert_eq!(r.nodes_checked, 3);
        // intervals: fc in [-6*255, 4*255], act clipped into [0, 255]
        assert_eq!(r.intervals[0], Interval::new(0, 255));
        assert!(r.intervals[2].lo >= 0 && r.intervals[2].hi <= 255);
    }

    #[test]
    fn huge_weights_trip_acc_overflow() {
        let g = graph(
            vec![input_node(8), linear(1, 0, vec![100_000_000; 4], 1, Precision::I32)],
            1,
        );
        let r = check_graph(&g);
        assert!(!r.is_sound());
        assert_eq!(r.first_error().unwrap().rule, rules::ACC_OVERFLOW);
        assert_eq!(r.first_error().unwrap().node, Some(1));
    }

    #[test]
    fn oversized_shift_trips_requant_params() {
        let g = graph(
            vec![
                input_node(8),
                linear(1, 0, vec![1, 1, 1, 1], 1, Precision::I32),
                IntNode {
                    id: 2,
                    op: IntOp::RequantAct {
                        rq: Requant { m: 1 << 41, d: D_MAX + 10, lo: 0, hi: 255 },
                    },
                    inputs: vec![1],
                    name: "act".into(),
                    precision: Precision::U8,
                },
            ],
            2,
        );
        let r = check_graph(&g);
        assert_eq!(r.first_error().unwrap().rule, rules::REQUANT_PARAMS);
    }

    #[test]
    fn reachable_wide_rescale_trips_saturation() {
        // pure-rescale requant (full-i32 clip) whose product escapes i32
        let g = graph(
            vec![
                input_node(8),
                IntNode {
                    id: 1,
                    op: IntOp::AddRequant {
                        rqs: vec![Requant {
                            m: 1 << 30,
                            d: 0,
                            lo: i32::MIN as i64,
                            hi: i32::MAX as i64,
                        }],
                    },
                    inputs: vec![0, 0],
                    name: "add".into(),
                    precision: Precision::I32,
                },
            ],
            1,
        );
        let r = check_graph(&g);
        let saturation =
            r.findings.iter().any(|f| f.rule == rules::REQUANT_SATURATION);
        assert!(saturation, "findings: {}", r.render_human());
    }

    #[test]
    fn activation_clips_are_exempt_from_saturation() {
        let rq = Requant { m: 1 << 20, d: 4, lo: 0, hi: 255 };
        assert!(!super::is_pure_rescale(&rq));
    }

    #[test]
    fn dead_gemm_reports_unused_weights() {
        let g = graph(
            vec![
                input_node(4),
                linear(1, 0, vec![1, 2, -1, 2], 1, Precision::I32),
                linear(2, 0, vec![3, 4, -3, 4], 1, Precision::I32),
            ],
            2,
        );
        let r = check_graph(&g);
        assert!(r.is_sound());
        let f = r.findings.iter().find(|f| f.rule == rules::UNUSED_WEIGHTS).unwrap();
        assert_eq!(f.node, Some(1));
        assert_eq!(f.severity, Severity::Warning);
    }

    #[test]
    fn loose_requant_stamp_warns_missed_packing() {
        // clip [0, 3] fits U2 but the node is stamped I32
        let g = graph(
            vec![
                input_node(8),
                linear(1, 0, vec![1, -1, 1, -1], 1, Precision::I32),
                IntNode {
                    id: 2,
                    op: IntOp::RequantAct { rq: Requant { m: 1, d: 8, lo: 0, hi: 3 } },
                    inputs: vec![1],
                    name: "act".into(),
                    precision: Precision::I32,
                },
            ],
            2,
        );
        let r = check_graph(&g);
        assert!(r.is_sound());
        let f = r.findings.iter().find(|f| f.rule == rules::PRECISION_LOOSE).unwrap();
        assert_eq!(f.node, Some(2));
    }

    #[test]
    fn structural_failure_short_circuits() {
        let g = graph(vec![], 0);
        let r = check_graph(&g);
        assert_eq!(r.findings.len(), 1);
        assert_eq!(r.findings[0].rule, rules::GRAPH_STRUCTURE);
        assert!(r.intervals.is_empty());
    }

    #[test]
    fn json_schema_is_stable() {
        let g = graph(
            vec![input_node(8), linear(1, 0, vec![1, 2, 3, 4], 1, Precision::I32)],
            1,
        );
        let text = check_graph(&g).to_json("m.nemo.json");
        let v = crate::util::json::parse(&text).unwrap();
        assert_eq!(v.get("format").unwrap().as_str().unwrap(), "nemo-check-report");
        assert_eq!(v.get("version").unwrap().as_i64().unwrap(), 1);
        assert_eq!(v.get("nodes").unwrap().as_i64().unwrap(), 2);
        assert_eq!(v.get("errors").unwrap().as_i64().unwrap(), 0);
        let rules_arr = v.get("rules").unwrap().as_arr().unwrap();
        assert_eq!(rules_arr.len(), rules::ALL.len());
        for (rv, id) in rules_arr.iter().zip(rules::ALL) {
            assert_eq!(rv.get("id").unwrap().as_str().unwrap(), id);
        }
        assert_eq!(v.get("intervals").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn summary_line_counts_rules() {
        let g = graph(
            vec![input_node(8), linear(1, 0, vec![100_000_000; 4], 1, Precision::I32)],
            1,
        );
        let line = check_graph(&g).summary_line();
        assert!(line.starts_with("UNSOUND"), "{line}");
        assert!(line.contains("8/9 rules pass"), "{line}");
    }
}
