"""L2: the "SynthNet" model in all four NEMO representations, plus QAT
train steps.

SynthNet is the paper-scale CNN used throughout the repo (the Rust model
zoo mirrors this config exactly, see rust/src/model/synthnet.rs):

    input  1 x 16 x 16, 8-bit (eps_in = 1/255, alpha = 0)        sec. 3.7
    conv1  3x3 s1 p1   1 ->  8   + BN + PACT act
    conv2  3x3 s2 p1   8 -> 16   + BN + PACT act
    conv3  3x3 s2 p1  16 -> 32   + BN + PACT act
    avgpool 4x4 (global)                                         Eq. 25
    fc     32 -> 10 (+ bias)

Representations (paper sec. 1-3):
  * fp_fwd  — FullPrecision float forward.
  * fq_fwd  — FakeQuantized: PACT weight/act fake-quantization with
              static (wbits, abits); BN stays float (sec. 2, "In NEMO").
  * qd_fwd  — QuantizedDeployable: hardened weights, quantized BN
              (kappa_hat, lambda_hat), Eq. 10 activations — float tensors
              but every value lies on its quantized grid.
  * id_fwd  — IntegerDeployable: int32 integer images only; every linear
              operator routes through the Pallas qgemm (+ fused BN/requant
              epilogue), pooling through the Pallas avgpool kernel.

Train steps (SGD, BN batch statistics with running-stat update):
  * fp_train_step — FullPrecision.
  * fq_train_step — FakeQuantized QAT with STE; PACT act clipping bounds
                    (beta) are trained by backprop (sec. 2.2).

All functions take flat *lists* of arrays in the orders given by the
*_spec() functions; aot.py records those orders in the artifact manifest
so the Rust runtime can assemble buffers by name.
"""

from __future__ import annotations

import functools
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp

from . import quantlib as ql
from .kernels.avgpool import avgpool as k_avgpool
from .kernels.qgemm import qgemm, qgemm_bn_requant
from .kernels.ref import im2col_ref

# --------------------------------------------------------------------------
# Architecture config (single source of truth; exported in manifest.json)
# --------------------------------------------------------------------------

CONVS = [
    dict(name="conv1", cin=1, cout=8, k=3, stride=1, pad=1, oh=16, ow=16),
    dict(name="conv2", cin=8, cout=16, k=3, stride=2, pad=1, oh=8, ow=8),
    dict(name="conv3", cin=16, cout=32, k=3, stride=2, pad=1, oh=4, ow=4),
]
IN_SHAPE = (1, 16, 16)
N_CLASSES = 10
FC_IN = 32
POOL_K = 4
POOL_D = 12          # static d of Eq. 25; mirrored by rust transform
EPS_IN = 1.0 / 255.0  # 8-bit input, sec. 3.7
BN_EPS = 1e-5

ARCH = dict(convs=CONVS, in_shape=IN_SHAPE, n_classes=N_CLASSES,
            fc_in=FC_IN, pool_k=POOL_K, pool_d=POOL_D, eps_in=EPS_IN,
            bn_eps=BN_EPS)


def param_spec() -> List[Tuple[str, Tuple[int, ...]]]:
    """Trainable FP/FQ parameters, in flattened artifact order."""
    spec = []
    for c in CONVS:
        spec.append((f"{c['name']}.w", (c["cout"], c["cin"], c["k"], c["k"])))
        spec.append((f"{c['name']}.bn_gamma", (c["cout"],)))
        spec.append((f"{c['name']}.bn_beta", (c["cout"],)))
    spec.append(("fc.w", (FC_IN, N_CLASSES)))
    spec.append(("fc.b", (N_CLASSES,)))
    return spec


def bn_state_spec() -> List[Tuple[str, Tuple[int, ...]]]:
    """Running BN statistics (state, not trained by the optimizer)."""
    spec = []
    for c in CONVS:
        spec.append((f"{c['name']}.bn_mu", (c["cout"],)))
        spec.append((f"{c['name']}.bn_var", (c["cout"],)))
    return spec


def act_beta_spec() -> List[Tuple[str, Tuple[int, ...]]]:
    """PACT activation clipping bounds, one scalar per activation."""
    return [(f"act{i+1}.beta", ()) for i in range(len(CONVS))]


N_PARAMS = len(param_spec())
N_BN_STATE = len(bn_state_spec())
N_ACT = len(CONVS)


# --------------------------------------------------------------------------
# Shared pieces
# --------------------------------------------------------------------------


def _conv(x, w, stride, pad):
    return jax.lax.conv_general_dilated(
        x, w, window_strides=(stride, stride),
        padding=((pad, pad), (pad, pad)),
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )


def _bn_inference(phi, gamma, beta, mu, var):
    sigma = jnp.sqrt(var + BN_EPS)
    shape = (1, -1, 1, 1)
    return (gamma / sigma).reshape(shape) * (phi - mu.reshape(shape)) + beta.reshape(shape)


def _softmax_xent(logits, labels):
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return jnp.mean(logz - gold)


# --------------------------------------------------------------------------
# FullPrecision (sec. 1)
# --------------------------------------------------------------------------


def fp_fwd(params: Sequence[jax.Array], bn_state: Sequence[jax.Array],
           x: jax.Array) -> jax.Array:
    """FullPrecision inference forward: x [B,1,16,16] f32 -> logits [B,10]."""
    p = list(params)
    s = list(bn_state)
    h = x
    for i, c in enumerate(CONVS):
        w, gamma, beta = p[3 * i], p[3 * i + 1], p[3 * i + 2]
        mu, var = s[2 * i], s[2 * i + 1]
        phi = _conv(h, w, c["stride"], c["pad"])
        phi = _bn_inference(phi, gamma, beta, mu, var)
        h = jax.nn.relu(phi)
    h = jnp.mean(h, axis=(2, 3))  # global average pool
    wf, bf = p[-2], p[-1]
    return h @ wf + bf


def _fp_loss(params, bn_state_in, x, y):
    """Training-mode forward (batch BN stats) -> (loss, new_bn_state)."""
    p = list(params)
    s = list(bn_state_in)
    new_state = []
    h = x
    for i, c in enumerate(CONVS):
        w, gamma, beta = p[3 * i], p[3 * i + 1], p[3 * i + 2]
        phi = _conv(h, w, c["stride"], c["pad"])
        mu_b = jnp.mean(phi, axis=(0, 2, 3))
        var_b = jnp.var(phi, axis=(0, 2, 3))
        mu_r, var_r = s[2 * i], s[2 * i + 1]
        momentum = 0.1
        new_state.append((1 - momentum) * mu_r + momentum * mu_b)
        new_state.append((1 - momentum) * var_r + momentum * var_b)
        phi = _bn_inference(phi, gamma, beta, jax.lax.stop_gradient(mu_b),
                            jax.lax.stop_gradient(var_b))
        h = jax.nn.relu(phi)
    h = jnp.mean(h, axis=(2, 3))
    logits = h @ p[-2] + p[-1]
    return _softmax_xent(logits, y), new_state


def fp_train_step(params, bn_state, x, y, lr):
    """One SGD step. Returns (params', bn_state', loss)."""
    (loss, new_state), grads = jax.value_and_grad(_fp_loss, has_aux=True)(
        list(params), list(bn_state), x, y)
    new_params = [p - lr * g for p, g in zip(params, grads)]
    return new_params, new_state, loss


# --------------------------------------------------------------------------
# FakeQuantized (sec. 2)
# --------------------------------------------------------------------------


def _fq_body(params, bn_state, act_betas, x, wbits, abits, train_bn):
    p = list(params)
    s = list(bn_state)
    n_levels = (1 << abits) - 1
    new_state = []
    h = x
    for i, c in enumerate(CONVS):
        w, gamma, beta = p[3 * i], p[3 * i + 1], p[3 * i + 2]
        # Weight fake-quantization: symmetric PACT grid, beta_w from the
        # current weight statistics (NEMO's reset_alpha_weights policy).
        beta_w = jax.lax.stop_gradient(jnp.max(jnp.abs(w)))
        wq = ql.pact_weight(w, beta_w, wbits)
        phi = _conv(h, wq, c["stride"], c["pad"])
        if train_bn:
            mu_b = jnp.mean(phi, axis=(0, 2, 3))
            var_b = jnp.var(phi, axis=(0, 2, 3))
            mu_r, var_r = s[2 * i], s[2 * i + 1]
            momentum = 0.1
            new_state.append((1 - momentum) * mu_r + momentum * mu_b)
            new_state.append((1 - momentum) * var_r + momentum * var_b)
            phi = _bn_inference(phi, gamma, beta, jax.lax.stop_gradient(mu_b),
                                jax.lax.stop_gradient(var_b))
        else:
            phi = _bn_inference(phi, gamma, beta, s[2 * i], s[2 * i + 1])
        ab = act_betas[i]
        eps_y = ab / n_levels
        h = ql.pact_act(phi, ab, eps_y)
    h = jnp.mean(h, axis=(2, 3))
    logits = h @ p[-2] + p[-1]
    return logits, new_state


def fq_fwd(params, bn_state, act_betas, x, *, wbits=8, abits=8):
    """FakeQuantized inference forward."""
    logits, _ = _fq_body(params, bn_state, act_betas, x, wbits, abits,
                         train_bn=False)
    return logits


def fq_train_step(params, bn_state, act_betas, x, y, lr, *, wbits=8, abits=8):
    """One QAT SGD step (STE). Trains params AND the PACT act betas.

    Returns (params', bn_state', act_betas', loss).
    """

    def loss_fn(p, ab):
        logits, new_state = _fq_body(p, bn_state, ab, x, wbits, abits,
                                     train_bn=True)
        return _softmax_xent(logits, y), new_state

    (loss, new_state), (gp, gab) = jax.value_and_grad(
        loss_fn, argnums=(0, 1), has_aux=True)(list(params), list(act_betas))
    new_params = [p - lr * g for p, g in zip(params, gp)]
    # Small decay pulls unused clipping headroom down (PACT sec. 3).
    new_betas = [b - lr * (g + 1e-4 * b) for b, g in zip(act_betas, gab)]
    return new_params, new_state, new_betas, loss


# --------------------------------------------------------------------------
# QuantizedDeployable (sec. 3): float tensors, all on quantized grids
# --------------------------------------------------------------------------


def qd_spec() -> List[Tuple[str, Tuple[int, ...]]]:
    """Flattened QD argument order (per layer, then fc, then input)."""
    spec = []
    for c in CONVS:
        spec.append((f"{c['name']}.w_hat", (c["cout"], c["cin"], c["k"], c["k"])))
        spec.append((f"{c['name']}.kappa_hat", (c["cout"],)))
        spec.append((f"{c['name']}.lambda_hat", (c["cout"],)))
        spec.append((f"act.beta_y", ()))
        spec.append((f"act.eps_y", ()))
    spec.append(("fc.w_hat", (FC_IN, N_CLASSES)))
    spec.append(("fc.b_hat", (N_CLASSES,)))
    return spec


def qd_fwd(args: Sequence[jax.Array], x: jax.Array) -> jax.Array:
    """QuantizedDeployable forward (Eq. 10 activations, quantized BN).

    args order per conv layer: w_hat, kappa_hat, lambda_hat, beta_y, eps_y;
    then fc.w_hat, fc.b_hat. x is the quantized input (multiple of eps_in).
    """
    a = list(args)
    h = x
    idx = 0
    for c in CONVS:
        w_hat, kappa_hat, lambda_hat, beta_y, eps_y = a[idx:idx + 5]
        idx += 5
        phi = _conv(h, w_hat, c["stride"], c["pad"])
        shape = (1, -1, 1, 1)
        phi = kappa_hat.reshape(shape) * phi + lambda_hat.reshape(shape)
        # Eq. 10: linear quantization as clipped floor.
        h = jnp.floor(jnp.clip(phi, 0.0, beta_y) / eps_y) * eps_y
    h = jnp.mean(h, axis=(2, 3))
    return h @ a[idx] + a[idx + 1]


# --------------------------------------------------------------------------
# IntegerDeployable (sec. 3): int32 integer images only
# --------------------------------------------------------------------------


def id_spec() -> List[Tuple[str, Tuple[int, ...]]]:
    """Flattened ID argument order (integer images + requant params)."""
    spec = []
    for c in CONVS:
        spec.append((f"{c['name']}.wq", (c["cin"] * c["k"] * c["k"], c["cout"])))
        spec.append((f"{c['name']}.kappa_q", (c["cout"],)))
        spec.append((f"{c['name']}.lambda_q", (c["cout"],)))
        spec.append((f"{c['name']}.m", ()))
        spec.append((f"{c['name']}.d", ()))
        spec.append((f"{c['name']}.act_hi", ()))
    spec.append(("fc.wq", (FC_IN, N_CLASSES)))
    spec.append(("fc.bq", (N_CLASSES,)))
    return spec


def id_fwd(args: Sequence[jax.Array], qx: jax.Array) -> jax.Array:
    """IntegerDeployable forward: qx [B,1,16,16] i32 -> qlogits [B,10] i32.

    Every linear operator routes through the Pallas fused kernel
    (qgemm + integer BN + requantization, Eq. 16/22/11); pooling through
    the Pallas integer avgpool (Eq. 25). No float ops anywhere.

    Block sizes are tuned per layer (#Perf): bm covers all rows of a
    batch<=16 lowering in few grid steps, bk spans the whole reduction,
    bn the whole channel dim — interpret-mode grids lower to XLA while
    loops, so fewer/fatter steps dominate wall-clock on CPU (on TPU the
    same shapes keep the working set under ~1.5 MiB VMEM).
    """
    a = list(args)
    h = qx
    idx = 0
    zero = jnp.int32(0)
    for c in CONVS:
        wq, kappa_q, lambda_q, m, d, act_hi = a[idx:idx + 6]
        idx += 6
        cols, (b, oh, ow) = im2col_ref(h, c["k"], c["k"], c["stride"], c["pad"])
        kdim = c["cin"] * c["k"] * c["k"]
        y = qgemm_bn_requant(
            cols, wq, kappa_q, lambda_q, m, d, zero, act_hi,
            bm=min(1024, _ceil_mult(cols.shape[0], 128)),
            bk=_ceil_mult(kdim, 8),
            bn=_ceil_mult(c["cout"], 8),
        )
        h = y.reshape(b, oh, ow, c["cout"]).transpose(0, 3, 1, 2)
    h = k_avgpool(h, POOL_K, POOL_K, POOL_D)
    b = h.shape[0]
    h = h.reshape(b, FC_IN)
    wq_fc, bq_fc = a[idx], a[idx + 1]
    return qgemm(h, wq_fc, bm=_ceil_mult(b, 8), bk=FC_IN, bn=16) + bq_fc[None, :]


def _ceil_mult(x: int, m: int) -> int:
    return -(-x // m) * m


def id_fwd_xla(args: Sequence[jax.Array], qx: jax.Array) -> jax.Array:
    """IntegerDeployable forward on native XLA integer ops (no Pallas).

    Same argument spec and bit-exact same function as id_fwd; this is the
    deployment variant for hardware whose compiler has first-class integer
    support (the serving fast path on CPU), and the honest comparator for
    E9's "ID on general-purpose hardware" overhead measurement.
    """
    a = list(args)
    h = qx
    idx = 0
    for c in CONVS:
        wq, kappa_q, lambda_q, m, d, act_hi = a[idx:idx + 6]
        idx += 6
        # wq is [cin*k*k, cout]; rebuild OIHW for lax.conv.
        w = wq.reshape(c["cin"], c["k"], c["k"], c["cout"]).transpose(3, 0, 1, 2)
        phi = jax.lax.conv_general_dilated(
            h, w, (c["stride"], c["stride"]),
            ((c["pad"], c["pad"]), (c["pad"], c["pad"])),
            dimension_numbers=("NCHW", "OIHW", "NCHW"),
            preferred_element_type=jnp.int32)
        bn = phi.astype(jnp.int64) * kappa_q.astype(jnp.int64)[None, :, None, None] \
            + lambda_q.astype(jnp.int64)[None, :, None, None]
        y = jnp.right_shift(bn * m.astype(jnp.int64), d.astype(jnp.int64))
        h = jnp.clip(y, 0, act_hi.astype(jnp.int64)).astype(jnp.int32)
    b, cc, hh, ww = h.shape
    r = h.reshape(b, cc, hh // POOL_K, POOL_K, ww // POOL_K, POOL_K)
    acc = jnp.sum(r.astype(jnp.int64), axis=(3, 5))
    mp = (1 << POOL_D) // (POOL_K * POOL_K)
    h = jnp.right_shift(acc * jnp.int64(mp), jnp.int64(POOL_D)).astype(jnp.int32)
    h = h.reshape(b, FC_IN)
    wq_fc, bq_fc = a[idx], a[idx + 1]
    out = jnp.matmul(h.astype(jnp.int64), wq_fc.astype(jnp.int64)).astype(jnp.int32)
    return out + bq_fc[None, :]
