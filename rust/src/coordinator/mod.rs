//! Serving coordinator (S7): request router + dynamic batcher + worker
//! pool over AOT-compiled IntegerDeployable executables.
//!
//! Deployment shape (vLLM-router-like, scaled to this paper): callers
//! submit single-sample integer images; the batcher coalesces them up to
//! `max_batch` or `batch_timeout`, picks the smallest compiled batch
//! variant that fits (artifacts are lowered at batch sizes 1/2/4/8/16),
//! pads, executes on a worker thread, and scatters the per-sample
//! results. Python is never involved; the executables were compiled once
//! from the JAX/Pallas graphs.

pub mod metrics;

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{self, Receiver, Sender, SyncSender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::runtime::{Arg, Executable, Runtime};
use crate::tensor::{Tensor, TensorI};

pub use metrics::Metrics;

/// A deployable model: shared deployment parameters + per-batch-size
/// compiled variants.
pub struct ModelVariant {
    pub name: String,
    /// (batch, executable), ascending by batch
    pub variants: Vec<(usize, Arc<Executable>)>,
    /// the non-input arguments (integer deployment params)
    pub base_args: Vec<Arg>,
    /// per-sample input shape (e.g. [1, 16, 16])
    pub input_shape: Vec<usize>,
}

impl ModelVariant {
    /// Load every `kind` artifact (e.g. "id_fwd") from the runtime.
    pub fn load(
        rt: &Runtime,
        name: &str,
        kind: &str,
        base_args: Vec<Arg>,
    ) -> Result<Self> {
        let specs = rt.manifest.by_kind(kind);
        if specs.is_empty() {
            bail!("no artifacts of kind '{kind}' in manifest");
        }
        let mut variants = Vec::new();
        let mut input_shape = Vec::new();
        for s in specs {
            let b = s.batch.context("artifact missing batch")?;
            input_shape = s.args.last().unwrap().shape[1..].to_vec();
            variants.push((b, rt.load(&s.name)?));
        }
        variants.sort_by_key(|(b, _)| *b);
        Ok(ModelVariant { name: name.to_string(), variants, base_args, input_shape })
    }

    fn pick(&self, n: usize) -> &(usize, Arc<Executable>) {
        self.variants
            .iter()
            .find(|(b, _)| *b >= n)
            .unwrap_or_else(|| self.variants.last().unwrap())
    }

    pub fn max_batch(&self) -> usize {
        self.variants.last().map(|(b, _)| *b).unwrap_or(1)
    }
}

struct Request {
    model: String,
    qx: TensorI, // [1, ...]
    reply: SyncSender<Result<TensorI>>,
    enqueued: Instant,
}

/// Coordinator configuration.
#[derive(Clone, Copy, Debug)]
pub struct ServerConfig {
    pub max_batch: usize,
    pub batch_timeout: Duration,
    pub n_workers: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            max_batch: 16,
            batch_timeout: Duration::from_micros(500),
            n_workers: 2,
        }
    }
}

/// Clonable client handle.
#[derive(Clone)]
pub struct ServerHandle {
    tx: Sender<Request>,
}

impl ServerHandle {
    /// Blocking single-sample inference; returns the [1, C_out] integer
    /// logits image.
    pub fn infer(&self, model: &str, qx: TensorI) -> Result<TensorI> {
        let (rtx, rrx) = mpsc::sync_channel(1);
        self.tx
            .send(Request {
                model: model.to_string(),
                qx,
                reply: rtx,
                enqueued: Instant::now(),
            })
            .map_err(|_| anyhow!("server stopped"))?;
        rrx.recv().map_err(|_| anyhow!("server dropped request"))?
    }
}

/// The running server; dropping it (after all handles) stops the threads.
pub struct Server {
    handle: ServerHandle,
    stop: Arc<AtomicBool>,
    pub metrics: Arc<Mutex<Metrics>>,
    threads: Vec<std::thread::JoinHandle<()>>,
}

struct Job {
    exec: Arc<Executable>,
    args: Vec<Arg>,
    waiters: Vec<(SyncSender<Result<TensorI>>, Instant)>,
    n_real: usize,
    batch: usize,
}

impl Server {
    pub fn start(models: Vec<ModelVariant>, cfg: ServerConfig) -> Server {
        let (tx, rx) = mpsc::channel::<Request>();
        let (jtx, jrx) = mpsc::channel::<Job>();
        let jrx = Arc::new(Mutex::new(jrx));
        let metrics = Arc::new(Mutex::new(Metrics::new()));
        let stop = Arc::new(AtomicBool::new(false));
        let registry: Arc<HashMap<String, ModelVariant>> = Arc::new(
            models.into_iter().map(|m| (m.name.clone(), m)).collect(),
        );

        let mut threads = Vec::new();
        // Batcher thread
        {
            let registry = registry.clone();
            let metrics = metrics.clone();
            let stop = stop.clone();
            threads.push(std::thread::spawn(move || {
                batcher_loop(rx, jtx, registry, metrics, stop, cfg);
            }));
        }
        // Worker pool
        for wid in 0..cfg.n_workers {
            let jrx = jrx.clone();
            let metrics = metrics.clone();
            threads.push(std::thread::spawn(move || {
                worker_loop(wid, jrx, metrics);
            }));
        }
        Server { handle: ServerHandle { tx }, stop, metrics, threads }
    }

    pub fn handle(&self) -> ServerHandle {
        self.handle.clone()
    }

    pub fn stop(self) -> Metrics {
        self.stop.store(true, Ordering::SeqCst);
        let Server { handle, metrics, threads, .. } = self;
        drop(handle); // close the request channel so the batcher exits
        for t in threads {
            let _ = t.join();
        }
        let m = metrics.lock().unwrap().clone();
        m
    }
}

fn batcher_loop(
    rx: Receiver<Request>,
    jtx: Sender<Job>,
    registry: Arc<HashMap<String, ModelVariant>>,
    metrics: Arc<Mutex<Metrics>>,
    stop: Arc<AtomicBool>,
    cfg: ServerConfig,
) {
    loop {
        // Block for the first request (or exit when all senders dropped).
        let first = match rx.recv_timeout(Duration::from_millis(50)) {
            Ok(r) => r,
            Err(mpsc::RecvTimeoutError::Timeout) => {
                if stop.load(Ordering::SeqCst) {
                    return;
                }
                continue;
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => return,
        };
        let deadline = Instant::now() + cfg.batch_timeout;
        let mut bucket: HashMap<String, Vec<Request>> = HashMap::new();
        let cap = cfg.max_batch;
        bucket.entry(first.model.clone()).or_default().push(first);
        // Coalesce until the timeout or the cap for some model.
        loop {
            let full = bucket.values().any(|v| v.len() >= cap);
            let now = Instant::now();
            if full || now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(r) => bucket.entry(r.model.clone()).or_default().push(r),
                Err(mpsc::RecvTimeoutError::Timeout) => break,
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
        }
        for (model, reqs) in bucket {
            let Some(mv) = registry.get(&model) else {
                for r in reqs {
                    let _ = r
                        .reply
                        .send(Err(anyhow!("unknown model '{model}'")));
                }
                continue;
            };
            // Split into chunks of at most the largest compiled batch.
            for chunk in reqs.chunks(mv.max_batch().min(cap)) {
                dispatch(mv, chunk, &jtx, &metrics);
            }
        }
    }
}

fn dispatch(
    mv: &ModelVariant,
    reqs: &[Request],
    jtx: &Sender<Job>,
    metrics: &Arc<Mutex<Metrics>>,
) {
    let n = reqs.len();
    let (batch, exec) = mv.pick(n);
    // Gather: [n, ...] + zero padding to the variant batch.
    let mut sample_len = 1usize;
    for d in &mv.input_shape {
        sample_len *= d;
    }
    let mut data = Vec::with_capacity(batch * sample_len);
    for r in reqs {
        debug_assert_eq!(&r.qx.shape()[1..], &mv.input_shape[..]);
        data.extend_from_slice(r.qx.data());
    }
    data.resize(batch * sample_len, 0);
    let mut shape = vec![*batch];
    shape.extend_from_slice(&mv.input_shape);
    let qx = Tensor::from_vec(&shape, data);

    let mut args = mv.base_args.clone();
    args.push(qx.into());

    {
        let mut m = metrics.lock().unwrap();
        m.batch_sizes.push(n as f64);
        let now = Instant::now();
        for r in reqs {
            m.queue_wait
                .push(now.duration_since(r.enqueued).as_secs_f64());
        }
    }
    let job = Job {
        exec: exec.clone(),
        args,
        waiters: reqs.iter().map(|r| (r.reply.clone(), r.enqueued)).collect(),
        n_real: n,
        batch: *batch,
    };
    let _ = jtx.send(job);
}

fn worker_loop(
    _wid: usize,
    jrx: Arc<Mutex<Receiver<Job>>>,
    metrics: Arc<Mutex<Metrics>>,
) {
    loop {
        let job = {
            let guard = jrx.lock().unwrap();
            match guard.recv() {
                Ok(j) => j,
                Err(_) => return,
            }
        };
        let t0 = Instant::now();
        let result = job.exec.run(&job.args);
        let exec_s = t0.elapsed().as_secs_f64();
        match result {
            Ok(outs) => {
                let logits = outs.into_iter().next().unwrap();
                let t = match logits {
                    Arg::I32(t) => t,
                    Arg::F32(t) => t.map(|v| v as i32),
                };
                let done = Instant::now();
                let mut m = metrics.lock().unwrap();
                m.exec_time.push(exec_s);
                m.completed += job.n_real as u64;
                m.padded += (job.batch - job.n_real) as u64;
                drop(m);
                for (i, (reply, enq)) in job.waiters.iter().enumerate() {
                    let row = t.slice_batch(i, i + 1);
                    let _ = reply.send(Ok(row));
                    metrics
                        .lock()
                        .unwrap()
                        .e2e_latency
                        .push(done.duration_since(*enq).as_secs_f64());
                }
            }
            Err(e) => {
                let msg = format!("execution failed: {e:#}");
                let mut m = metrics.lock().unwrap();
                m.failed += job.n_real as u64;
                drop(m);
                for (reply, _) in &job.waiters {
                    let _ = reply.send(Err(anyhow!(msg.clone())));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pick_selects_smallest_fitting_variant() {
        // Synthetic ModelVariant sans executables is hard to build (needs
        // a runtime); pick() logic is exercised via serving integration
        // tests. Here: config defaults sanity.
        let cfg = ServerConfig::default();
        assert!(cfg.max_batch >= 1);
        assert!(cfg.n_workers >= 1);
    }
}
