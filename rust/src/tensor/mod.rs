//! Dense row-major tensor substrate (S1 in DESIGN.md).
//!
//! The offline vendor set has no `ndarray`, so the engines run on this
//! small, fully-tested implementation. Element types used across the
//! crate: `f32` for FullPrecision/FakeQuantized/QuantizedDeployable
//! values and `i32` for IntegerDeployable integer images (with `i64`
//! widening inside the ops that need it, mirroring the Pallas kernels).
//! Sub-word integer images additionally pack to `u8`/`i8` storage behind
//! [`QTensor`] when the deployment pipeline proves the value range fits
//! (DESIGN.md §Precision propagation) — 1 byte/element instead of 4 on
//! the bandwidth-bound GEMM hot path.

pub mod ops;

use std::fmt;
use std::sync::Arc;

use crate::quant::Precision;

// -- borrowed-or-owned element storage --------------------------------
//
// Cold-loading a binary artifact (DESIGN.md §Artifact-format v3) maps
// the file and hands tensors *views* into the mapping instead of
// copying every weight byte. `Storage<T>` is the enabling layer: the
// owned variant is exactly the old `Vec<T>`, the view variant borrows
// a byte range of a shared [`ByteSource`] allocation. Everything above
// `data()` is unchanged — kernels cannot tell the variants apart.

/// A stable, immutable byte allocation that zero-copy tensor views
/// borrow from (an mmap'ed artifact file, an aligned read buffer).
/// Contract: `bytes()` must return the same allocation, unchanged, for
/// the source's whole lifetime — view construction validates bounds and
/// alignment against it once and trusts them afterwards.
pub trait ByteSource: Send + Sync {
    fn bytes(&self) -> &[u8];
}

impl ByteSource for Vec<u8> {
    fn bytes(&self) -> &[u8] {
        self
    }
}

mod sealed {
    pub trait Sealed {}
    impl Sealed for u8 {}
    impl Sealed for i8 {}
    impl Sealed for i32 {}
}

/// Element types allowed behind zero-copy views: plain integer types
/// with no invalid bit patterns whose in-memory representation equals
/// the artifact's little-endian payload bytes (`u8`/`i8` trivially;
/// `i32` only on little-endian hosts — [`Tensor::from_view`] enforces
/// that at construction).
pub trait ViewElem: sealed::Sealed + Copy + Default + 'static {}
impl ViewElem for u8 {}
impl ViewElem for i8 {}
impl ViewElem for i32 {}

#[derive(Clone)]
enum Storage<T> {
    Owned(Vec<T>),
    /// `len` elements starting at byte `off` of `src`. Invariants
    /// (checked by the only constructor, [`Tensor::from_view`]):
    /// `T: ViewElem`, the range is in bounds, and `src.bytes() + off`
    /// is aligned for `T`.
    View { src: Arc<dyn ByteSource>, off: usize, len: usize },
}

impl<T: Copy> Storage<T> {
    #[inline]
    fn len(&self) -> usize {
        match self {
            Storage::Owned(v) => v.len(),
            Storage::View { len, .. } => *len,
        }
    }

    #[inline]
    fn as_slice(&self) -> &[T] {
        match self {
            Storage::Owned(v) => v,
            Storage::View { src, off, len } => {
                let bytes = &src.bytes()[*off..*off + *len * std::mem::size_of::<T>()];
                // SAFETY: construction checked bounds and alignment
                // against this same (stable, immutable) allocation, and
                // `T: ViewElem` is a plain integer type with no invalid
                // bit patterns.
                unsafe { std::slice::from_raw_parts(bytes.as_ptr().cast::<T>(), *len) }
            }
        }
    }

    /// Copy-on-write promotion: views become owned before any mutation,
    /// so a mapped artifact's bytes are never written through.
    fn make_owned(&mut self) {
        if let Storage::View { .. } = self {
            *self = Storage::Owned(self.as_slice().to_vec());
        }
    }

    #[inline]
    fn as_mut_slice(&mut self) -> &mut [T] {
        self.make_owned();
        match self {
            Storage::Owned(v) => v,
            Storage::View { .. } => unreachable!("promoted above"),
        }
    }

    fn into_vec(mut self) -> Vec<T> {
        self.make_owned();
        match self {
            Storage::Owned(v) => v,
            Storage::View { .. } => unreachable!("promoted above"),
        }
    }

    fn is_view(&self) -> bool {
        matches!(self, Storage::View { .. })
    }
}

impl<T: Copy + PartialEq> PartialEq for Storage<T> {
    fn eq(&self, other: &Self) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl<T: Copy + fmt::Debug> fmt::Debug for Storage<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.is_view() {
            write!(f, "view:")?;
        }
        f.debug_list().entries(self.as_slice()).finish()
    }
}

#[derive(Clone, PartialEq)]
pub struct Tensor<T> {
    shape: Vec<usize>,
    data: Storage<T>,
}

pub type TensorF = Tensor<f32>;
pub type TensorI = Tensor<i32>;
pub type TensorU8 = Tensor<u8>;
pub type TensorI8 = Tensor<i8>;

impl<T: Copy + Default> Tensor<T> {
    pub fn zeros(shape: &[usize]) -> Self {
        let n: usize = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: Storage::Owned(vec![T::default(); n]) }
    }

    pub fn from_vec(shape: &[usize], data: Vec<T>) -> Self {
        let n: usize = shape.iter().product();
        assert_eq!(n, data.len(), "shape {:?} != data len {}", shape, data.len());
        Tensor { shape: shape.to_vec(), data: Storage::Owned(data) }
    }

    pub fn full(shape: &[usize], v: T) -> Self {
        let n: usize = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: Storage::Owned(vec![v; n]) }
    }

    pub fn scalar(v: T) -> Self {
        Tensor { shape: vec![], data: Storage::Owned(vec![v]) }
    }

    #[inline]
    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    #[inline]
    pub fn ndim(&self) -> usize {
        self.shape.len()
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.len() == 0
    }

    #[inline]
    pub fn data(&self) -> &[T] {
        self.data.as_slice()
    }

    /// Mutable element access. Borrowed (zero-copy view) storage is
    /// promoted to an owned copy first — mapped artifact bytes are
    /// never written through.
    #[inline]
    pub fn data_mut(&mut self) -> &mut [T] {
        self.data.as_mut_slice()
    }

    pub fn into_vec(self) -> Vec<T> {
        self.data.into_vec()
    }

    /// Whether element storage is a borrowed zero-copy view over a
    /// shared [`ByteSource`] (an mmap'ed artifact) rather than owned.
    pub fn is_borrowed(&self) -> bool {
        self.data.is_view()
    }

    /// Reshape without moving data (total size must match).
    pub fn reshape(&self, shape: &[usize]) -> Self {
        let n: usize = shape.iter().product();
        assert_eq!(n, self.data.len(), "reshape {:?} -> {:?}", self.shape, shape);
        Tensor { shape: shape.to_vec(), data: self.data.clone() }
    }

    pub fn into_reshape(mut self, shape: &[usize]) -> Self {
        let n: usize = shape.iter().product();
        assert_eq!(n, self.data.len());
        self.shape = shape.to_vec();
        self
    }

    #[inline]
    pub fn at2(&self, i: usize, j: usize) -> T {
        debug_assert_eq!(self.ndim(), 2);
        self.data.as_slice()[i * self.shape[1] + j]
    }

    #[inline]
    pub fn at4(&self, n: usize, c: usize, h: usize, w: usize) -> T {
        debug_assert_eq!(self.ndim(), 4);
        let (sc, sh, sw) = (self.shape[1], self.shape[2], self.shape[3]);
        self.data.as_slice()[((n * sc + c) * sh + h) * sw + w]
    }

    #[inline]
    pub fn set4(&mut self, n: usize, c: usize, h: usize, w: usize, v: T) {
        let (sc, sh, sw) = (self.shape[1], self.shape[2], self.shape[3]);
        self.data.as_mut_slice()[((n * sc + c) * sh + h) * sw + w] = v;
    }

    pub fn map<U: Copy + Default>(&self, f: impl Fn(T) -> U) -> Tensor<U> {
        Tensor {
            shape: self.shape.clone(),
            data: Storage::Owned(self.data().iter().map(|x| f(*x)).collect()),
        }
    }

    /// Batch-slice of a 4-D (NCHW) or 2-D tensor: rows [lo, hi).
    pub fn slice_batch(&self, lo: usize, hi: usize) -> Self {
        assert!(!self.shape.is_empty() && hi <= self.shape[0] && lo <= hi);
        let row: usize = self.shape[1..].iter().product();
        let mut shape = self.shape.clone();
        shape[0] = hi - lo;
        Tensor { shape, data: Storage::Owned(self.data()[lo * row..hi * row].to_vec()) }
    }

    /// Concatenate along axis 0.
    pub fn cat_batch(parts: &[&Tensor<T>]) -> Self {
        assert!(!parts.is_empty());
        let inner = &parts[0].shape[1..];
        let mut shape = parts[0].shape.clone();
        shape[0] = parts.iter().map(|p| p.shape[0]).sum();
        let mut data = Vec::with_capacity(shape.iter().product());
        for p in parts {
            assert_eq!(&p.shape[1..], inner, "cat_batch shape mismatch");
            data.extend_from_slice(p.data());
        }
        Tensor { shape, data: Storage::Owned(data) }
    }

    /// Zero-copy view of `shape.iter().product()` elements starting at
    /// byte offset `off` of `src`. Fails loudly when the byte range is
    /// out of bounds, the address is misaligned for `T`, or the host is
    /// big-endian while `T` is wider than a byte (artifact payload
    /// bytes are little-endian) — callers fall back to an owned copy.
    pub fn from_view(
        shape: &[usize],
        src: Arc<dyn ByteSource>,
        off: usize,
    ) -> Result<Self, String>
    where
        T: ViewElem,
    {
        let len: usize = shape.iter().product();
        let size = std::mem::size_of::<T>();
        if size > 1 && cfg!(target_endian = "big") {
            return Err("multi-byte zero-copy views need a little-endian host".into());
        }
        let end = off
            .checked_add(len * size)
            .ok_or_else(|| "view range overflows".to_string())?;
        let b = src.bytes();
        if end > b.len() {
            return Err(format!(
                "view [{off}, {end}) out of bounds of {}-byte source",
                b.len()
            ));
        }
        if (b.as_ptr() as usize + off) % std::mem::align_of::<T>() != 0 {
            return Err(format!(
                "view at byte offset {off} misaligned for a {size}-byte element"
            ));
        }
        Ok(Tensor { shape: shape.to_vec(), data: Storage::View { src, off, len } })
    }
}

impl Tensor<f32> {
    pub fn from_f64(shape: &[usize], data: &[f64]) -> Self {
        Tensor::from_vec(shape, data.iter().map(|x| *x as f32).collect())
    }

    pub fn allclose(&self, other: &Self, atol: f32, rtol: f32) -> bool {
        self.shape == other.shape
            && self
                .data()
                .iter()
                .zip(other.data())
                .all(|(a, b)| (a - b).abs() <= atol + rtol * b.abs())
    }

    pub fn max_abs_diff(&self, other: &Self) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data()
            .iter()
            .zip(other.data())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

impl Tensor<i32> {
    /// Per-row argmax of a [N, C] tensor (integer images preserve order,
    /// sec. 3.6, so classification works directly on Q(logits)).
    pub fn argmax_rows(&self) -> Vec<usize> {
        assert_eq!(self.ndim(), 2);
        let c = self.shape[1];
        self.data()
            .chunks(c)
            .map(|row| {
                row.iter()
                    .enumerate()
                    .max_by_key(|(_, v)| **v)
                    .map(|(i, _)| i)
                    .unwrap()
            })
            .collect()
    }
}

impl Tensor<f32> {
    pub fn argmax_rows(&self) -> Vec<usize> {
        assert_eq!(self.ndim(), 2);
        let c = self.shape[1];
        self.data()
            .chunks(c)
            .map(|row| {
                let mut best = 0;
                for (i, v) in row.iter().enumerate() {
                    if *v > row[best] {
                        best = i;
                    }
                }
                best
            })
            .collect()
    }
}

// -- sub-byte bit packing ---------------------------------------------
//
// Layout contract (DESIGN.md §Sub-byte-packing): element `e` of a flat
// buffer occupies bits [e*bits, (e+1)*bits) counted LSB-first within
// each byte. All sub-byte widths (1/2/4) divide 8, so elements never
// straddle byte boundaries: byte `b` holds elements
// [b*8/bits, (b+1)*8/bits), the lowest-indexed element in the lowest
// bits. Signed nibbles (`I4`) store 4-bit two's complement.

/// Bytes needed for `len` elements of `bits` width (`ceil(len*bits/8)`).
#[inline]
pub fn packed_byte_len(len: usize, bits: u32) -> usize {
    (len * bits as usize).div_ceil(8)
}

/// Read element `idx` of a packed buffer as its unsigned bit pattern.
#[inline]
pub fn get_packed_raw(data: &[u8], idx: usize, bits: u32) -> u32 {
    debug_assert!(matches!(bits, 1 | 2 | 4));
    let bit = idx * bits as usize;
    let mask = (1u32 << bits) - 1;
    (data[bit / 8] as u32 >> (bit % 8)) & mask
}

/// Read element `idx` of a packed buffer at precision `p`, sign-extending
/// two's-complement nibbles for `I4`.
#[inline]
pub fn get_packed(data: &[u8], idx: usize, p: Precision) -> i32 {
    let raw = get_packed_raw(data, idx, p.bits());
    if p == Precision::I4 && raw >= 8 {
        raw as i32 - 16
    } else {
        raw as i32
    }
}

/// Write element `idx` of a packed buffer at precision `p`. The value
/// must be in `p`'s range (debug-asserted — callers range-check first).
#[inline]
pub fn set_packed(data: &mut [u8], idx: usize, p: Precision, v: i32) {
    let bits = p.bits();
    debug_assert!(
        (p.min_val()..=p.max_val()).contains(&(v as i64)),
        "value {v} outside {} range",
        p.name()
    );
    let bit = idx * bits as usize;
    let mask = ((1u32 << bits) - 1) as u8;
    let raw = (v as u32 & mask as u32) as u8;
    let b = &mut data[bit / 8];
    let shift = bit % 8;
    *b = (*b & !(mask << shift)) | (raw << shift);
}

/// A bit-packed sub-byte integer image: `len` elements of a sub-byte
/// [`Precision`] in `storage_bytes` bytes, LSB-first (see the layout
/// contract above). Trailing pad bits of the final byte are always zero,
/// so equal images have equal bytes and payload checksums are stable.
#[derive(Clone, Debug, PartialEq)]
pub struct PackedTensor {
    prec: Precision,
    shape: Vec<usize>,
    len: usize,
    data: Storage<u8>,
}

/// Shared validation for packed payloads, owned or viewed: sub-byte
/// precision, exact byte length, zero trailing pad bits. Returns the
/// element count.
fn check_packed_payload(
    shape: &[usize],
    p: Precision,
    data: &[u8],
) -> Result<usize, String> {
    if !p.is_sub_byte() {
        return Err(format!("{} is not a sub-byte precision", p.name()));
    }
    let len: usize = shape.iter().product();
    let want = p.storage_bytes(len);
    if data.len() != want {
        return Err(format!(
            "packed {} payload of {} bytes, shape {shape:?} wants {want}",
            p.name(),
            data.len()
        ));
    }
    let used_bits = len * p.bits() as usize;
    if used_bits % 8 != 0 {
        let last = data[want - 1];
        let pad_mask = !((1u16 << (used_bits % 8)) as u8).wrapping_sub(1);
        if last & pad_mask != 0 {
            return Err(format!(
                "packed {} payload has non-zero trailing pad bits",
                p.name()
            ));
        }
    }
    Ok(len)
}

impl PackedTensor {
    /// Wrap raw packed bytes. Fails loudly when the byte length does not
    /// match `p.storage_bytes(len)`, when `p` is not sub-byte, or when a
    /// trailing pad bit is set (a corrupt or non-canonical payload).
    pub fn from_bytes(
        shape: &[usize],
        p: Precision,
        data: Vec<u8>,
    ) -> Result<Self, String> {
        let len = check_packed_payload(shape, p, &data)?;
        Ok(PackedTensor { prec: p, shape: shape.to_vec(), len, data: Storage::Owned(data) })
    }

    /// Zero-copy packed payload: the `p.storage_bytes(len)` bytes at
    /// byte offset `off` of `src`, validated exactly like
    /// [`Self::from_bytes`] (length, sub-byte precision, pad bits).
    pub fn from_view(
        shape: &[usize],
        p: Precision,
        src: Arc<dyn ByteSource>,
        off: usize,
    ) -> Result<Self, String> {
        let t = Tensor::<u8>::from_view(&[p.storage_bytes(shape.iter().product())], src, off)?;
        let len = check_packed_payload(shape, p, t.data())?;
        let Tensor { data, .. } = t;
        Ok(PackedTensor { prec: p, shape: shape.to_vec(), len, data })
    }

    /// Whether the payload is a borrowed zero-copy view (see
    /// [`Tensor::is_borrowed`]).
    pub fn is_borrowed(&self) -> bool {
        self.data.is_view()
    }

    pub fn precision(&self) -> Precision {
        self.prec
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// The packed payload bytes.
    pub fn bytes(&self) -> &[u8] {
        self.data.as_slice()
    }

    /// Element `idx`, sign-extended for `I4`.
    #[inline]
    pub fn get(&self, idx: usize) -> i32 {
        get_packed(self.data.as_slice(), idx, self.prec)
    }
}

/// A precision-tagged integer image: the packed counterpart of
/// [`TensorI`]. Sub-word variants store 1 byte/element and the sub-byte
/// classes pack 2-8 elements per byte; every variant widens losslessly
/// back to `i32`, and narrowing is checked against the target precision's
/// range — the conversion fails loudly instead of wrapping, because a
/// value outside the stamped range means the deploy-time range proof was
/// violated.
#[derive(Clone, Debug, PartialEq)]
pub enum QTensor {
    U8(TensorU8),
    I8(TensorI8),
    I32(TensorI),
    /// Any sub-byte precision (`U1`/`U2`/`U4`/`I4`), bit-packed.
    Packed(PackedTensor),
}

impl QTensor {
    /// Storage precision of this image.
    pub fn precision(&self) -> Precision {
        match self {
            QTensor::U8(_) => Precision::U8,
            QTensor::I8(_) => Precision::I8,
            QTensor::I32(_) => Precision::I32,
            QTensor::Packed(t) => t.precision(),
        }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            QTensor::U8(t) => t.shape(),
            QTensor::I8(t) => t.shape(),
            QTensor::I32(t) => t.shape(),
            QTensor::Packed(t) => t.shape(),
        }
    }

    pub fn len(&self) -> usize {
        match self {
            QTensor::U8(t) => t.len(),
            QTensor::I8(t) => t.len(),
            QTensor::I32(t) => t.len(),
            QTensor::Packed(t) => t.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Bytes of element storage (the bandwidth this image costs).
    pub fn storage_bytes(&self) -> usize {
        self.precision().storage_bytes(self.len())
    }

    /// Whether element storage is a borrowed zero-copy view over a
    /// shared [`ByteSource`] (an mmap'ed artifact) rather than owned —
    /// the loader's zero-copy accounting reads this.
    pub fn is_borrowed(&self) -> bool {
        match self {
            QTensor::U8(t) => t.is_borrowed(),
            QTensor::I8(t) => t.is_borrowed(),
            QTensor::I32(t) => t.is_borrowed(),
            QTensor::Packed(t) => t.is_borrowed(),
        }
    }

    /// (min, max) of the stored values widened to i64; (0, 0) when
    /// empty. The artifact writer stamps weight dtypes from this.
    pub fn min_max(&self) -> (i64, i64) {
        fn fold<T: Copy + Into<i64>>(d: &[T]) -> (i64, i64) {
            d.iter().fold((i64::MAX, i64::MIN), |(lo, hi), v| {
                let v: i64 = (*v).into();
                (lo.min(v), hi.max(v))
            })
        }
        if self.is_empty() {
            return (0, 0);
        }
        match self {
            QTensor::U8(t) => fold(t.data()),
            QTensor::I8(t) => fold(t.data()),
            QTensor::I32(t) => fold(t.data()),
            QTensor::Packed(t) => (0..t.len())
                .map(|i| t.get(i) as i64)
                .fold((i64::MAX, i64::MIN), |(lo, hi), v| (lo.min(v), hi.max(v))),
        }
    }

    /// Lossless widening to the full-width i32 image.
    pub fn widen(&self) -> TensorI {
        match self {
            QTensor::U8(t) => t.map(|v| v as i32),
            QTensor::I8(t) => t.map(|v| v as i32),
            QTensor::I32(t) => t.clone(),
            QTensor::Packed(t) => Tensor::from_vec(
                t.shape(),
                (0..t.len()).map(|i| t.get(i)).collect(),
            ),
        }
    }

    /// Checked narrowing of an i32 image into packed storage. Returns an
    /// error naming the offending value when any element falls outside
    /// `p`'s range (the range proof failed) instead of silently wrapping.
    pub fn narrow_from(t: &TensorI, p: Precision) -> Result<QTensor, String> {
        let check = |v: i32| -> Result<(), String> {
            let v = v as i64;
            if !(p.min_val()..=p.max_val()).contains(&v) {
                return Err(format!(
                    "value {v} outside {} range [{}, {}]",
                    p.name(),
                    p.min_val(),
                    p.max_val()
                ));
            }
            Ok(())
        };
        match p {
            Precision::U8 => {
                let mut data = Vec::with_capacity(t.len());
                for &v in t.data() {
                    check(v)?;
                    data.push(v as u8);
                }
                Ok(QTensor::U8(Tensor::from_vec(t.shape(), data)))
            }
            Precision::I8 => {
                let mut data = Vec::with_capacity(t.len());
                for &v in t.data() {
                    check(v)?;
                    data.push(v as i8);
                }
                Ok(QTensor::I8(Tensor::from_vec(t.shape(), data)))
            }
            Precision::I32 => Ok(QTensor::I32(t.clone())),
            _ => {
                let mut data = vec![0u8; p.storage_bytes(t.len())];
                for (i, &v) in t.data().iter().enumerate() {
                    check(v)?;
                    set_packed(&mut data, i, p, v);
                }
                Ok(QTensor::Packed(PackedTensor {
                    prec: p,
                    shape: t.shape().to_vec(),
                    len: t.len(),
                    data: Storage::Owned(data),
                }))
            }
        }
    }
}

impl From<TensorI> for QTensor {
    fn from(t: TensorI) -> Self {
        QTensor::I32(t)
    }
}

impl<T: fmt::Debug + Copy + Default> fmt::Debug for Tensor<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Tensor{:?}", self.shape)?;
        if self.len() <= 16 {
            write!(f, " {:?}", self.data())
        } else {
            write!(f, " [{:?}, {:?}, ...]", self.data()[0], self.data()[1])
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construct_and_index() {
        let t = Tensor::from_vec(&[2, 3], vec![1, 2, 3, 4, 5, 6]);
        assert_eq!(t.at2(1, 2), 6);
        assert_eq!(t.shape(), &[2, 3]);
    }

    #[test]
    fn at4_layout_is_nchw() {
        let mut t = Tensor::<i32>::zeros(&[2, 3, 4, 5]);
        t.set4(1, 2, 3, 4, 99);
        assert_eq!(t.at4(1, 2, 3, 4), 99);
        assert_eq!(t.data()[t.len() - 1], 99); // last element
    }

    #[test]
    #[should_panic]
    fn bad_shape_panics() {
        Tensor::from_vec(&[2, 2], vec![1, 2, 3]);
    }

    #[test]
    fn slice_and_cat_roundtrip() {
        let t = Tensor::from_vec(&[4, 2], (0..8).collect());
        let a = t.slice_batch(0, 1);
        let b = t.slice_batch(1, 4);
        let back = Tensor::cat_batch(&[&a, &b]);
        assert_eq!(back, t);
    }

    #[test]
    fn argmax_rows_int() {
        let t = Tensor::from_vec(&[2, 3], vec![1, 5, 2, -7, -3, -9]);
        assert_eq!(t.argmax_rows(), vec![1, 1]);
    }

    #[test]
    fn allclose() {
        let a = Tensor::from_vec(&[2], vec![1.0f32, 2.0]);
        let b = Tensor::from_vec(&[2], vec![1.0f32, 2.0 + 1e-6]);
        assert!(a.allclose(&b, 1e-5, 0.0));
        assert!(!a.allclose(&b, 1e-8, 0.0));
    }

    #[test]
    fn qtensor_narrow_widen_roundtrip() {
        let t = Tensor::from_vec(&[2, 2], vec![0, 1, 254, 255]);
        let q = QTensor::narrow_from(&t, Precision::U8).unwrap();
        assert_eq!(q.precision(), Precision::U8);
        assert_eq!(q.shape(), &[2, 2]);
        assert_eq!(q.storage_bytes(), 4);
        assert_eq!(q.widen(), t);

        let s = Tensor::from_vec(&[3], vec![-128, 0, 127]);
        let q = QTensor::narrow_from(&s, Precision::I8).unwrap();
        assert_eq!(q.precision(), Precision::I8);
        assert_eq!(q.storage_bytes(), 3);
        assert_eq!(q.widen(), s);

        let w = Tensor::from_vec(&[2], vec![-70000, 70000]);
        let q = QTensor::narrow_from(&w, Precision::I32).unwrap();
        assert_eq!(q.precision(), Precision::I32);
        assert_eq!(q.storage_bytes(), 8);
        assert_eq!(q.widen(), w);
    }

    #[test]
    fn qtensor_narrow_rejects_out_of_range() {
        let t = Tensor::from_vec(&[2], vec![0, 256]);
        let err = QTensor::narrow_from(&t, Precision::U8).unwrap_err();
        assert!(err.contains("256"), "{err}");
        let t = Tensor::from_vec(&[1], vec![-1]);
        assert!(QTensor::narrow_from(&t, Precision::U8).is_err());
        let t = Tensor::from_vec(&[1], vec![128]);
        assert!(QTensor::narrow_from(&t, Precision::I8).is_err());
        // sub-byte classes reject out-of-range values too
        let t = Tensor::from_vec(&[1], vec![2]);
        assert!(QTensor::narrow_from(&t, Precision::U1).is_err());
        let t = Tensor::from_vec(&[1], vec![4]);
        assert!(QTensor::narrow_from(&t, Precision::U2).is_err());
        let t = Tensor::from_vec(&[1], vec![16]);
        assert!(QTensor::narrow_from(&t, Precision::U4).is_err());
        let t = Tensor::from_vec(&[1], vec![-9]);
        assert!(QTensor::narrow_from(&t, Precision::I4).is_err());
    }

    #[test]
    fn subbyte_narrow_widen_roundtrip_and_sizing() {
        // U1: 9 elements -> 2 bytes, LSB-first.
        let t = Tensor::from_vec(&[9], vec![1, 0, 1, 1, 0, 0, 1, 0, 1]);
        let q = QTensor::narrow_from(&t, Precision::U1).unwrap();
        assert_eq!(q.precision(), Precision::U1);
        assert_eq!(q.storage_bytes(), 2);
        assert_eq!(q.widen(), t);
        if let QTensor::Packed(p) = &q {
            assert_eq!(p.bytes(), &[0b0100_1101, 0b0000_0001]);
        } else {
            panic!("expected packed storage");
        }

        // U2: 5 elements -> 2 bytes.
        let t = Tensor::from_vec(&[5], vec![0, 1, 2, 3, 2]);
        let q = QTensor::narrow_from(&t, Precision::U2).unwrap();
        assert_eq!(q.storage_bytes(), 2);
        assert_eq!(q.widen(), t);

        // U4 + I4: 2 elements per byte, I4 sign-extends.
        let t = Tensor::from_vec(&[3], vec![0, 15, 7]);
        let q = QTensor::narrow_from(&t, Precision::U4).unwrap();
        assert_eq!(q.storage_bytes(), 2);
        assert_eq!(q.widen(), t);
        let t = Tensor::from_vec(&[4], vec![-8, -1, 0, 7]);
        let q = QTensor::narrow_from(&t, Precision::I4).unwrap();
        assert_eq!(q.storage_bytes(), 2);
        assert_eq!(q.widen(), t);
    }

    /// 8-byte-aligned test source (a plain `Vec<u8>` allocation is only
    /// guaranteed 1-aligned, so i32-view tests need this).
    struct AlignedSrc {
        buf: Vec<u64>,
        len: usize,
    }

    impl AlignedSrc {
        fn new(bytes: &[u8]) -> Self {
            let mut buf = vec![0u64; bytes.len().div_ceil(8)];
            // SAFETY: `buf` holds at least `bytes.len()` bytes (rounded
            // up to whole u64 words) and the two allocations are
            // disjoint, so the nonoverlapping copy stays in bounds.
            unsafe {
                std::ptr::copy_nonoverlapping(
                    bytes.as_ptr(),
                    buf.as_mut_ptr().cast::<u8>(),
                    bytes.len(),
                );
            }
            AlignedSrc { buf, len: bytes.len() }
        }
    }

    impl ByteSource for AlignedSrc {
        fn bytes(&self) -> &[u8] {
            // SAFETY: the u64 buffer is fully initialized and `len` is
            // no larger than its byte size by construction in `new`.
            unsafe { std::slice::from_raw_parts(self.buf.as_ptr().cast(), self.len) }
        }
    }

    #[test]
    fn views_are_zero_copy_and_promote_on_write() {
        let src: Arc<dyn ByteSource> = Arc::new(vec![1u8, 2, 3, 4, 5, 6, 7, 8]);
        let t = Tensor::<u8>::from_view(&[2, 2], src.clone(), 4).unwrap();
        assert!(t.is_borrowed());
        assert_eq!(t.data(), &[5, 6, 7, 8]);
        // Equality is by value, not by storage flavour.
        assert_eq!(t, Tensor::from_vec(&[2, 2], vec![5, 6, 7, 8]));
        // Reshape keeps the borrow; mutation promotes to an owned copy
        // without touching the source.
        let r = t.reshape(&[4]);
        assert!(r.is_borrowed());
        let mut m = t.clone();
        m.data_mut()[0] = 9;
        assert!(!m.is_borrowed());
        assert_eq!(m.data(), &[9, 6, 7, 8]);
        assert_eq!(t.data(), &[5, 6, 7, 8]);
        assert_eq!(src.bytes()[4], 5);
        // Out-of-bounds ranges fail loudly.
        assert!(Tensor::<u8>::from_view(&[9], src.clone(), 0).is_err());
        assert!(Tensor::<u8>::from_view(&[4], src.clone(), 5).is_err());
        assert!(Tensor::<u8>::from_view(&[1], src, usize::MAX).is_err());
    }

    #[test]
    fn i32_views_check_alignment() {
        let vals = [3i32, -7, 1 << 20, -1];
        let mut bytes = Vec::new();
        for v in vals {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        bytes.extend_from_slice(&[0, 0]); // slack for the misaligned case
        let src: Arc<dyn ByteSource> = Arc::new(AlignedSrc::new(&bytes));
        if cfg!(target_endian = "little") {
            let t = Tensor::<i32>::from_view(&[2, 2], src.clone(), 0).unwrap();
            assert!(t.is_borrowed());
            assert_eq!(t.data(), &vals);
            assert_eq!(t.at2(0, 1), -7);
            // into_vec promotes the view to an owned copy.
            assert_eq!(t.into_vec(), vals.to_vec());
        }
        // Offset 2 is not 4-aligned for i32.
        assert!(Tensor::<i32>::from_view(&[1], src, 2).is_err());
    }

    #[test]
    fn packed_views_validate_like_from_bytes() {
        // 3 x U2 uses bits 0-5 of one byte: 0b__10_01_00 = elements 0,1,2.
        let src: Arc<dyn ByteSource> = Arc::new(vec![0b10_01_00u8, 0x40]);
        let p = PackedTensor::from_view(&[3], Precision::U2, src.clone(), 0).unwrap();
        assert!(p.is_borrowed());
        assert_eq!((p.get(0), p.get(1), p.get(2)), (0, 1, 2));
        assert_eq!(QTensor::Packed(p).widen().data(), &[0, 1, 2]);
        // Byte 1 has a pad bit set for a 3 x U2 payload.
        assert!(PackedTensor::from_view(&[3], Precision::U2, src.clone(), 1).is_err());
        // Out of bounds.
        assert!(PackedTensor::from_view(&[9], Precision::U2, src, 0).is_err());
    }

    #[test]
    fn qtensor_min_max_and_borrow_accounting() {
        let t = Tensor::from_vec(&[4], vec![-3, 7, 0, 2]);
        let q = QTensor::narrow_from(&t, Precision::I8).unwrap();
        assert_eq!(q.min_max(), (-3, 7));
        assert!(!q.is_borrowed());
        let sub = QTensor::narrow_from(
            &Tensor::from_vec(&[3], vec![-8, 7, -1]),
            Precision::I4,
        )
        .unwrap();
        assert_eq!(sub.min_max(), (-8, 7));
        let empty = QTensor::I32(Tensor::from_vec(&[0], vec![]));
        assert_eq!(empty.min_max(), (0, 0));
    }

    #[test]
    fn packed_tensor_from_bytes_is_validated() {
        // Wrong byte length.
        assert!(PackedTensor::from_bytes(&[5], Precision::U2, vec![0]).is_err());
        // Non-sub-byte precision.
        assert!(PackedTensor::from_bytes(&[4], Precision::U8, vec![0]).is_err());
        // Set trailing pad bit (3 x 2 bits use bits 0-5 of one byte).
        assert!(PackedTensor::from_bytes(&[3], Precision::U2, vec![0x40]).is_err());
        // Canonical payload round-trips.
        let p = PackedTensor::from_bytes(&[3], Precision::U2, vec![0b10_01_00]).unwrap();
        assert_eq!((p.get(0), p.get(1), p.get(2)), (0, 1, 2));
        assert_eq!(QTensor::Packed(p.clone()).widen().data(), &[0, 1, 2]);
    }
}
