//! Backward-plan compiler (DESIGN.md §Training): reverse-mode gradients
//! built from the same machinery the forward plans use — `graph::shape`
//! for shape inference, [`assign_slots`] for liveness-planned arena
//! reuse, and the `tensor::ops` `_into` backward kernels.
//!
//! Formulation: one reverse step per graph node, in reverse id order
//! (graph construction is topological, so descending id is a valid
//! reverse-topological schedule). The step for node `n` *pulls* its
//! output gradient: it zeroes d_n's arena slot and accumulates one
//! contribution per consuming edge — every backward kernel is
//! `_acc_into` — then turns d_n plus the checkpointed forward
//! activations (the tape) into n's parameter gradients. Consumer
//! gradients were produced by earlier reverse steps, so each step has
//! exactly one output buffer and the forward plan's liveness allocator
//! applies unchanged.
//!
//! The plan compiles against graph *structure* only and reads weights
//! live from the graph at execute time: one compiled [`BackwardPlan`]
//! serves every SGD step, while the (weight-baking) forward `FloatPlan`
//! is recompiled per step.
//!
//! PACT (paper Eq. 10, y = ε·clip(⌊t/ε⌋, 0, 2^bits−1) with ε = β/(2^bits−1))
//! differentiates with the straight-through estimator: ∂y/∂x = 1 on the
//! pass-through region 0 ≤ x < β and 0 outside; the learned clip gets
//! ∂y/∂β = 1 exactly where the STE passes nothing, x ≥ β.

use super::plan::{
    assign_slots, channel_stride, FloatArena, PlanError, StepId, StepSpec,
};
use crate::graph::grad::Gradients;
use crate::graph::{shape, Graph, NodeId, Op};
use crate::quant::Precision;
use crate::tensor::{ops, TensorF};

/// One reverse step: the node whose output gradient it materializes and
/// the consumers whose contributions it accumulates.
struct BwdStep {
    node: NodeId,
    /// One entry per consuming edge (a node reading `node` through two of
    /// its inputs contributes twice, as the chain rule demands).
    consumers: Vec<NodeId>,
    is_input: bool,
}

/// Per-batch-size backward layout (the gradient arena's counterpart of
/// `PlanLayout`).
pub struct BwdLayout {
    pub batch: usize,
    /// Full activation/gradient shape of every node (batch prepended).
    shapes: Vec<Vec<usize>>,
    /// Arena slot holding node n's output gradient d_n (by NodeId).
    grad_slot: Vec<usize>,
    /// Scratch slots per reverse step (conv gather/GEMM buffers).
    scratch: Vec<Vec<usize>>,
    /// Required length of each arena slot.
    pub slot_lens: Vec<usize>,
}

impl BwdLayout {
    /// Total gradient-arena elements (peak-memory introspection; the
    /// train bench reports this).
    pub fn arena_len(&self) -> usize {
        self.slot_lens.iter().sum()
    }

    pub fn arena_bytes(&self) -> usize {
        self.arena_len() * std::mem::size_of::<f32>()
    }

    pub fn arena_slots(&self) -> usize {
        self.slot_lens.len()
    }
}

/// A compiled backward pass over a float [`Graph`].
pub struct BackwardPlan {
    steps: Vec<BwdStep>,
    /// Graph output node; its reverse step is seeded with dL/d(output).
    output: NodeId,
    /// Nodes whose forward activation the backward kernels read.
    needed: Vec<bool>,
    /// Per-node sample shapes (no batch dim), from shape inference.
    sample_shapes: Vec<Vec<usize>>,
}

impl BackwardPlan {
    /// Compile the reverse schedule for `g`'s structure. Pair with
    /// [`FloatPlan::compile_unfused`](super::plan::FloatPlan::compile_unfused)
    /// for the forward tape: unfused plans keep step id == node id, so
    /// the tape and this plan index activations identically.
    pub fn compile(g: &Graph) -> Result<BackwardPlan, PlanError> {
        let shapes1 = shape::infer_float(g, 1)?;
        let n = g.nodes.len();
        let mut consumers: Vec<Vec<NodeId>> = vec![Vec::new(); n];
        for nd in &g.nodes {
            for &i in &nd.inputs {
                consumers[i].push(nd.id);
            }
        }
        // The tape mask: activations some backward rule reads. Conv and
        // Linear read their input for the weight gradient, BatchNorm for
        // dγ, ReLU/PACT for the pass-through mask, MaxPool for the
        // argmax re-scan.
        let mut needed = vec![false; n];
        for nd in &g.nodes {
            let reads_input = matches!(
                nd.op,
                Op::Conv2d { .. }
                    | Op::Linear { .. }
                    | Op::BatchNorm { .. }
                    | Op::ReLU
                    | Op::PactAct { .. }
                    | Op::MaxPool { .. }
            );
            if reads_input {
                for &i in &nd.inputs {
                    needed[i] = true;
                }
            }
        }
        let steps = (0..n)
            .rev()
            .map(|node| BwdStep {
                node,
                consumers: consumers[node].clone(),
                is_input: matches!(g.nodes[node].op, Op::Input { .. }),
            })
            .collect();
        Ok(BackwardPlan {
            steps,
            output: g.output,
            needed,
            sample_shapes: shapes1.iter().map(|s| s[1..].to_vec()).collect(),
        })
    }

    /// Which node activations the backward pass reads — the `keep` mask
    /// for `FloatPlan::execute_checkpointed` over the unfused forward
    /// plan. Activations outside this mask are never cloned out of the
    /// forward arena.
    pub fn tape_mask(&self) -> &[bool] {
        &self.needed
    }

    /// Build the per-batch layout: reverse-step [`StepSpec`]s fed through
    /// the same liveness allocator as the forward plans. `g` must be the
    /// graph this plan was compiled from (weight shapes size the conv
    /// scratch buffers).
    pub fn layout(&self, g: &Graph, batch: usize) -> Result<BwdLayout, PlanError> {
        if batch == 0 {
            return Err(PlanError::Invalid("batch size must be >= 1".into()));
        }
        let n = self.steps.len();
        let shapes: Vec<Vec<usize>> = self
            .sample_shapes
            .iter()
            .map(|ss| {
                let mut s = Vec::with_capacity(ss.len() + 1);
                s.push(batch);
                s.extend_from_slice(ss);
                s
            })
            .collect();
        let numel = |node: NodeId| -> usize { shapes[node].iter().product() };
        let conv_dims = |node: NodeId| -> (usize, usize) {
            match &g.nodes[node].op {
                // (rows of the im2col GEMM, C_in*KH*KW patch dim)
                Op::Conv2d { w, .. } => (
                    numel(node) / w.shape()[0],
                    w.shape()[1] * w.shape()[2] * w.shape()[3],
                ),
                _ => unreachable!("conv_dims on non-conv node"),
            }
        };
        let specs: Vec<StepSpec> = self
            .steps
            .iter()
            .map(|st| {
                let mut scratch: Vec<(usize, Precision)> = Vec::new();
                let mut inputs: Vec<StepId> = Vec::new();
                if !st.is_input {
                    for &c in &st.consumers {
                        // This step reads each consumer's gradient,
                        // produced by the (earlier) reverse step n-1-c.
                        inputs.push(n - 1 - c);
                        if let Op::Conv2d { .. } = &g.nodes[c].op {
                            // d_c gathered to GEMM rows, then the
                            // patch-gradient matrix gCols = dRows·wmatᵀ.
                            let (rows, kdim) = conv_dims(c);
                            scratch.push((numel(c), Precision::I32));
                            scratch.push((rows * kdim, Precision::I32));
                        }
                    }
                    if let Op::Conv2d { .. } = &g.nodes[st.node].op {
                        // Weight gradient: im2col of the input activation
                        // plus d_n gathered to GEMM rows.
                        let (rows, kdim) = conv_dims(st.node);
                        scratch.push((rows * kdim, Precision::I32));
                        scratch.push((numel(st.node), Precision::I32));
                    }
                }
                StepSpec {
                    inputs,
                    out_len: numel(st.node),
                    // Gradients live in the one-width float arena;
                    // precision tags only matter for packed layouts.
                    out_prec: Precision::I32,
                    scratch,
                    is_input: st.is_input,
                }
            })
            .collect();
        // Pin the seed slot (reverse step of the graph output) exactly
        // like the forward plans pin their output slot.
        let (out_slot, scratch, slot_lens, _prec) =
            assign_slots(&specs, n - 1 - self.output);
        let mut grad_slot = vec![0usize; n];
        for (r, st) in self.steps.iter().enumerate() {
            grad_slot[st.node] = out_slot[r];
        }
        Ok(BwdLayout { batch, shapes, grad_slot, scratch, slot_lens })
    }

    /// Run the backward pass. `tape[node]` must hold every activation in
    /// [`Self::tape_mask`] (from `execute_checkpointed` over the unfused
    /// forward plan; the Input node's entry is the input batch itself)
    /// and `seed` is dL/d(network output), shaped like the forward
    /// output. Reads weights/BN/PACT parameters live from `g`.
    pub fn execute(
        &self,
        g: &Graph,
        layout: &BwdLayout,
        arena: &mut FloatArena,
        tape: &[Option<TensorF>],
        seed: &TensorF,
    ) -> Gradients {
        let n = self.steps.len();
        let mut grads = Gradients::zeros(n);
        arena.prepare_lens(&layout.slot_lens);
        let out_numel: usize = layout.shapes[self.output].iter().product();
        assert_eq!(seed.len(), out_numel, "seed shape != output shape");
        let act = |node: NodeId| {
            tape[node]
                .as_ref()
                .expect("tape is missing an activation the backward pass reads")
        };
        for (r, st) in self.steps.iter().enumerate() {
            if st.is_input {
                continue;
            }
            let node = st.node;
            let numel: usize = layout.shapes[node].iter().product();
            let d_slot = layout.grad_slot[node];
            let mut d = std::mem::take(&mut arena.bufs[d_slot]);
            if node == self.output {
                d[..numel].copy_from_slice(seed.data());
            } else {
                d[..numel].fill(0.0);
            }

            // Accumulate each consumer's contribution to d_n.
            let mut si = 0usize; // scratch cursor; order matches layout()
            for &c in &st.consumers {
                match &g.nodes[c].op {
                    Op::Conv2d { w, stride, pad, .. } => {
                        let (kh, kw) = (w.shape()[2], w.shape()[3]);
                        let (bi, ci, hi, wi) = {
                            let s = &layout.shapes[node];
                            (s[0], s[1], s[2], s[3])
                        };
                        let (co, oh, ow) = {
                            let s = &layout.shapes[c];
                            (s[1], s[2], s[3])
                        };
                        let m = bi * oh * ow;
                        let kdim = ci * kh * kw;
                        let rows_slot = layout.scratch[r][si];
                        let gcols_slot = layout.scratch[r][si + 1];
                        si += 2;
                        let wmat = ops::oihw_to_wmat(w);
                        let mut rows = std::mem::take(&mut arena.bufs[rows_slot]);
                        let mut gcols = std::mem::take(&mut arena.bufs[gcols_slot]);
                        {
                            let dc = &arena.bufs[layout.grad_slot[c]];
                            ops::nchw_to_rows_into(dc, bi, co, oh, ow, &mut rows);
                        }
                        gcols[..m * kdim].fill(0.0);
                        ops::matmul_f32_abt_acc_into(
                            &rows[..m * co],
                            wmat.data(),
                            m,
                            co,
                            kdim,
                            &mut gcols,
                        );
                        ops::col2im_acc_into(
                            &gcols,
                            bi,
                            ci,
                            hi,
                            wi,
                            kh,
                            kw,
                            *stride,
                            *pad,
                            &mut d[..numel],
                        );
                        arena.bufs[rows_slot] = rows;
                        arena.bufs[gcols_slot] = gcols;
                    }
                    Op::Linear { w, .. } => {
                        let (bsz, fo) = (layout.shapes[c][0], layout.shapes[c][1]);
                        let fi = layout.shapes[node][1];
                        let dc = &arena.bufs[layout.grad_slot[c]];
                        // dX += dY·wᵀ with w stored [in, out].
                        ops::matmul_f32_abt_acc_into(
                            &dc[..bsz * fo],
                            w.data(),
                            bsz,
                            fo,
                            fi,
                            &mut d[..numel],
                        );
                    }
                    Op::BatchNorm { bn } => {
                        let (kappa, _) = bn.affine();
                        let (ch, hw) = channel_stride(&layout.shapes[c]);
                        let dc = &arena.bufs[layout.grad_slot[c]];
                        for (i, (dv, &cv)) in
                            d[..numel].iter_mut().zip(&dc[..numel]).enumerate()
                        {
                            *dv += kappa[(i / hw) % ch] as f32 * cv;
                        }
                    }
                    Op::QuantBn { kappa_hat, .. } => {
                        let (ch, hw) = channel_stride(&layout.shapes[c]);
                        let dc = &arena.bufs[layout.grad_slot[c]];
                        for (i, (dv, &cv)) in
                            d[..numel].iter_mut().zip(&dc[..numel]).enumerate()
                        {
                            *dv += kappa_hat[(i / hw) % ch] as f32 * cv;
                        }
                    }
                    Op::ReLU => {
                        let x = act(node);
                        let dc = &arena.bufs[layout.grad_slot[c]];
                        for ((dv, &cv), &xv) in
                            d[..numel].iter_mut().zip(&dc[..numel]).zip(x.data())
                        {
                            if xv > 0.0 {
                                *dv += cv;
                            }
                        }
                    }
                    Op::PactAct { beta, .. } => {
                        let x = act(node);
                        let dc = &arena.bufs[layout.grad_slot[c]];
                        let b = *beta as f32;
                        for ((dv, &cv), &xv) in
                            d[..numel].iter_mut().zip(&dc[..numel]).zip(x.data())
                        {
                            if (0.0..b).contains(&xv) {
                                *dv += cv;
                            }
                        }
                    }
                    Op::MaxPool { k } => {
                        let s = &layout.shapes[node];
                        let x = act(node);
                        let dc = &arena.bufs[layout.grad_slot[c]];
                        ops::maxpool_backward_acc_into(
                            x.data(),
                            dc,
                            s[0],
                            s[1],
                            s[2],
                            s[3],
                            *k,
                            &mut d[..numel],
                        );
                    }
                    Op::AvgPool { k } => {
                        let s = &layout.shapes[node];
                        let dc = &arena.bufs[layout.grad_slot[c]];
                        ops::avgpool_backward_acc_into(
                            dc,
                            s[0],
                            s[1],
                            s[2],
                            s[3],
                            *k,
                            &mut d[..numel],
                        );
                    }
                    Op::GlobalAvgPool => {
                        let s = &layout.shapes[node];
                        let dc = &arena.bufs[layout.grad_slot[c]];
                        ops::global_mean_backward_acc_into(
                            dc,
                            s[0],
                            s[1],
                            s[2],
                            s[3],
                            &mut d[..numel],
                        );
                    }
                    Op::Flatten | Op::Add => {
                        let dc = &arena.bufs[layout.grad_slot[c]];
                        for (dv, &cv) in d[..numel].iter_mut().zip(&dc[..numel]) {
                            *dv += cv;
                        }
                    }
                    Op::Input { .. } => unreachable!("Input cannot consume"),
                }
            }

            // Parameter gradients of this node from d_n and the tape.
            match &g.nodes[node].op {
                Op::Conv2d { w, bias, stride, pad } => {
                    let inp = g.nodes[node].inputs[0];
                    let x = act(inp);
                    let (co, ci, kh, kw) =
                        (w.shape()[0], w.shape()[1], w.shape()[2], w.shape()[3]);
                    let (bi, hi, wi) = {
                        let s = &layout.shapes[inp];
                        (s[0], s[2], s[3])
                    };
                    let (oh, ow) = (layout.shapes[node][2], layout.shapes[node][3]);
                    let m = bi * oh * ow;
                    let kdim = ci * kh * kw;
                    // Weight-grad scratch is always the last two entries.
                    let sc = &layout.scratch[r];
                    let cols_slot = sc[sc.len() - 2];
                    let rows_slot = sc[sc.len() - 1];
                    let mut cols = std::mem::take(&mut arena.bufs[cols_slot]);
                    let mut rows = std::mem::take(&mut arena.bufs[rows_slot]);
                    ops::im2col_into(
                        x.data(),
                        bi,
                        ci,
                        hi,
                        wi,
                        kh,
                        kw,
                        *stride,
                        *pad,
                        &mut cols,
                    );
                    ops::nchw_to_rows_into(&d[..numel], bi, co, oh, ow, &mut rows);
                    // dWmat = colsᵀ·dRows, then back to OIHW order.
                    let mut gw = vec![0f32; kdim * co];
                    ops::matmul_f32_atb_into(
                        &cols[..m * kdim],
                        &rows[..m * co],
                        m,
                        kdim,
                        co,
                        &mut gw,
                    );
                    grads.nodes[node].w = ops::wmat_to_oihw(&gw, co, ci, kh, kw);
                    if bias.is_some() {
                        let mut gb = vec![0f32; co];
                        for row in rows[..m * co].chunks_exact(co) {
                            for (gv, &v) in gb.iter_mut().zip(row) {
                                *gv += v;
                            }
                        }
                        grads.nodes[node].bias = gb;
                    }
                    arena.bufs[cols_slot] = cols;
                    arena.bufs[rows_slot] = rows;
                }
                Op::Linear { w, bias } => {
                    let inp = g.nodes[node].inputs[0];
                    let x = act(inp);
                    let (bsz, fi) = (layout.shapes[inp][0], layout.shapes[inp][1]);
                    let fo = w.shape()[1];
                    // dW = xᵀ·dY, stored [in, out] like the weights.
                    let mut gw = vec![0f32; fi * fo];
                    ops::matmul_f32_atb_into(
                        x.data(),
                        &d[..bsz * fo],
                        bsz,
                        fi,
                        fo,
                        &mut gw,
                    );
                    grads.nodes[node].w = gw;
                    if bias.is_some() {
                        let mut gb = vec![0f32; fo];
                        for row in d[..bsz * fo].chunks_exact(fo) {
                            for (gv, &v) in gb.iter_mut().zip(row) {
                                *gv += v;
                            }
                        }
                        grads.nodes[node].bias = gb;
                    }
                }
                Op::BatchNorm { bn } => {
                    let inp = g.nodes[node].inputs[0];
                    let x = act(inp);
                    let (ch, hw) = channel_stride(&layout.shapes[node]);
                    // Frozen-statistics training: y = γ·(x−μ)/σ + β with
                    // μ/σ constant, so dγ_c = Σ d·(x−μ_c)/σ_c, dβ_c = Σ d.
                    let mut ggamma = vec![0f32; ch];
                    let mut gbeta = vec![0f32; ch];
                    for (i, (&dv, &xv)) in
                        d[..numel].iter().zip(x.data()).enumerate()
                    {
                        let c = (i / hw) % ch;
                        gbeta[c] += dv;
                        ggamma[c] += dv * ((xv as f64 - bn.mu[c]) / bn.sigma[c]) as f32;
                    }
                    grads.nodes[node].gamma = ggamma;
                    grads.nodes[node].beta = gbeta;
                }
                Op::PactAct { beta, .. } => {
                    let inp = g.nodes[node].inputs[0];
                    let x = act(inp);
                    let b = *beta as f32;
                    // ∂y/∂β = 1 exactly on the saturated region x ≥ β —
                    // the complement of the STE pass-through band.
                    let mut gb = 0f64;
                    for (&dv, &xv) in d[..numel].iter().zip(x.data()) {
                        if xv >= b {
                            gb += dv as f64;
                        }
                    }
                    grads.nodes[node].pact_beta = gb;
                }
                _ => {}
            }
            arena.bufs[d_slot] = d;
        }
        grads
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::plan::FloatPlan;
    use crate::quant::bn::BnParams;
    use crate::tensor::Tensor;
    use crate::util::rng::Rng;

    fn run_grads(g: &Graph, x: &TensorF, seed: &TensorF) -> Gradients {
        let fwd = FloatPlan::compile_unfused(g).unwrap();
        let bwd = BackwardPlan::compile(g).unwrap();
        let fl = fwd.layout(x.shape()[0]).unwrap();
        let bl = bwd.layout(g, x.shape()[0]).unwrap();
        let mut arena = FloatArena::new();
        let (_, tape) = fwd.execute_checkpointed(&fl, &mut arena, x, bwd.tape_mask());
        bwd.execute(g, &bl, &mut arena, &tape, seed)
    }

    #[test]
    fn linear_grads_match_analytic() {
        let mut g = Graph::new(1.0);
        let xin = g.push("in", Op::Input { shape: vec![4] }, &[]);
        let w = Tensor::from_vec(
            &[4, 2],
            vec![0.5, -0.25, 0.125, 1.0, -0.75, 0.3, 0.2, -0.1],
        );
        g.push("fc", Op::Linear { w, bias: Some(vec![0.1, -0.2]) }, &[xin]);

        let mut rng = Rng::new(7);
        let x = Tensor::from_vec(
            &[3, 4],
            (0..12).map(|_| rng.normal(0.0, 1.0) as f32).collect(),
        );
        let seed = Tensor::from_vec(
            &[3, 2],
            (0..6).map(|_| rng.normal(0.0, 1.0) as f32).collect(),
        );
        let grads = run_grads(&g, &x, &seed);

        // dW[i,j] = Σ_b x[b,i]·seed[b,j]; db[j] = Σ_b seed[b,j].
        for i in 0..4 {
            for j in 0..2 {
                let mut want = 0f32;
                for b in 0..3 {
                    want += x.data()[b * 4 + i] * seed.data()[b * 2 + j];
                }
                let got = grads.nodes[1].w[i * 2 + j];
                assert!((got - want).abs() < 1e-5, "dW[{i},{j}]: {got} vs {want}");
            }
        }
        for j in 0..2 {
            let want: f32 = (0..3).map(|b| seed.data()[b * 2 + j]).sum();
            let got = grads.nodes[1].bias[j];
            assert!((got - want).abs() < 1e-5, "db[{j}]: {got} vs {want}");
        }
    }

    #[test]
    fn bn_param_grads_match_analytic() {
        let mut g = Graph::new(1.0);
        let xin = g.push("in", Op::Input { shape: vec![2, 2, 2] }, &[]);
        let bn = BnParams {
            gamma: vec![1.5, 0.5],
            sigma: vec![2.0, 0.8],
            beta: vec![0.3, -0.3],
            mu: vec![0.1, -0.2],
        };
        g.push("bn", Op::BatchNorm { bn: bn.clone() }, &[xin]);

        let mut rng = Rng::new(11);
        let x = Tensor::from_vec(
            &[1, 2, 2, 2],
            (0..8).map(|_| rng.normal(0.0, 1.0) as f32).collect(),
        );
        let seed = Tensor::from_vec(
            &[1, 2, 2, 2],
            (0..8).map(|_| rng.normal(0.0, 1.0) as f32).collect(),
        );
        let grads = run_grads(&g, &x, &seed);
        for c in 0..2 {
            let (mut wg, mut wb) = (0f64, 0f64);
            for i in 0..4 {
                let d = seed.data()[c * 4 + i] as f64;
                let xv = x.data()[c * 4 + i] as f64;
                wb += d;
                wg += d * (xv - bn.mu[c]) / bn.sigma[c];
            }
            assert!((grads.nodes[1].gamma[c] as f64 - wg).abs() < 1e-5);
            assert!((grads.nodes[1].beta[c] as f64 - wb).abs() < 1e-5);
        }
    }

    #[test]
    fn pact_clip_grad_sums_saturated_region() {
        let mut g = Graph::new(1.0);
        let xin = g.push("in", Op::Input { shape: vec![4] }, &[]);
        g.push("act", Op::PactAct { beta: 1.0, bits: 4 }, &[xin]);
        // Two saturated (≥ β), one pass-through, one negative.
        let x = Tensor::from_vec(&[1, 4], vec![1.5, 0.5, -0.5, 2.5]);
        let seed = Tensor::from_vec(&[1, 4], vec![1.0, 10.0, 100.0, 7.0]);
        let grads = run_grads(&g, &x, &seed);
        assert!((grads.nodes[1].pact_beta - 8.0).abs() < 1e-6);
    }

    #[test]
    fn tape_mask_marks_exactly_the_read_activations() {
        // in -> conv -> bn -> relu -> gap -> fc: conv/bn/relu inputs and
        // the fc input are on the tape; the relu output (gap input) and
        // network output are not.
        let mut g = Graph::new(1.0 / 255.0);
        let x = g.push("in", Op::Input { shape: vec![1, 4, 4] }, &[]);
        let w = Tensor::from_vec(
            &[2, 1, 3, 3],
            (0..18).map(|i| (i as f32 - 9.0) * 0.05).collect(),
        );
        let c = g.push("conv", Op::Conv2d { w, bias: None, stride: 1, pad: 1 }, &[x]);
        let b = g.push("bn", Op::BatchNorm { bn: BnParams::identity(2) }, &[c]);
        let a = g.push("act", Op::ReLU, &[b]);
        let p = g.push("gap", Op::GlobalAvgPool, &[a]);
        let w2 = Tensor::from_vec(&[2, 3], (0..6).map(|i| i as f32 * 0.1).collect());
        let f = g.push("fc", Op::Linear { w: w2, bias: None }, &[p]);
        let plan = BackwardPlan::compile(&g).unwrap();
        let mask = plan.tape_mask();
        assert!(mask[x]); // conv reads it
        assert!(mask[c]); // bn reads it
        assert!(mask[b]); // relu reads it
        assert!(!mask[a]); // gap needs no activation
        assert!(mask[p]); // fc reads it
        assert!(!mask[f]); // nothing consumes the output
    }
}
