//! Precision assignment across deployment bit widths (DESIGN.md
//! §Precision propagation): deploying the synthnet at Q in {2, 4, 7, 8,
//! 9} bits must stamp every IntegerDeployable node with exactly the
//! precision its QuantSpec/clip range proves — the sub-byte classes
//! (U2/U4) for few-bit activation spaces, U8 up to 8 bits, I32 for the
//! accumulating ops and for the 9-bit fallback — and the packed
//! execution built on those stamps must be bit-identical to the i32
//! interpreter while costing strictly fewer arena bytes.

use nemo::data::SynthDigits;
use nemo::engine::{IntPlan, IntegerEngine, PackedArena};
use nemo::graph::int::{IntGraph, IntOp};
use nemo::model::synthnet::{SynthNet, EPS_IN};
use nemo::quant::{quantize_input, Precision};
use nemo::transform::DeployOptions;
use nemo::util::rng::Rng;

/// Recompute the expected stamp for every node straight from its op's
/// quantized range (spec / clip bounds / threshold levels) plus the
/// pool/flatten inheritance rule — the independent oracle the stamped
/// values are checked against.
fn expected_precisions(g: &IntGraph) -> Vec<Precision> {
    let mut out: Vec<Precision> = Vec::new();
    for n in &g.nodes {
        let p = match &n.op {
            IntOp::Input { spec, .. } => Precision::for_range(spec.lo, spec.hi),
            IntOp::RequantAct { rq } => Precision::for_range(rq.lo, rq.hi),
            IntOp::ThreshAct { th } => Precision::for_range(0, th.n_levels),
            IntOp::MaxPoolInt { .. } | IntOp::AvgPoolInt { .. } | IntOp::Flatten => {
                out[n.inputs[0]]
            }
            IntOp::ConvInt { .. }
            | IntOp::LinearInt { .. }
            | IntOp::IntBn { .. }
            | IntOp::AddRequant { .. } => Precision::I32,
        };
        out.push(p);
    }
    out
}

#[test]
fn synthnet_precision_stamps_match_quant_spec_ranges() {
    let mut rng = Rng::new(55);
    let net = SynthNet::init(&mut rng);
    for q in [2u32, 4, 7, 8, 9] {
        let nid = net
            .to_network(q)
            .unwrap()
            .deploy(DeployOptions { wbits: q, abits: q, ..DeployOptions::default() })
            .unwrap()
            .integerize();
        let g = nid.int_graph();
        let got = nid.node_precisions();
        assert_eq!(got, expected_precisions(g), "Q={q}: stamps != spec ranges");

        for (n, p) in g.nodes.iter().zip(&got) {
            match &n.op {
                // 8-bit camera input stays U8 at every Q.
                IntOp::Input { .. } => {
                    assert_eq!(*p, Precision::U8, "Q={q} input")
                }
                // Activations: [0, 2^Q - 1] -> the tightest storage
                // class (sub-byte below 8 bits), I32 at 9.
                IntOp::RequantAct { .. } => {
                    let want = match q {
                        2 => Precision::U2,
                        4 => Precision::U4,
                        7 | 8 => Precision::U8,
                        _ => Precision::I32,
                    };
                    assert_eq!(*p, want, "Q={q} activation '{}'", n.name);
                }
                // Accumulating ops are always full-width.
                IntOp::ConvInt { .. }
                | IntOp::LinearInt { .. }
                | IntOp::IntBn { .. }
                | IntOp::AddRequant { .. } => {
                    assert_eq!(*p, Precision::I32, "Q={q} '{}'", n.name)
                }
                _ => {}
            }
        }
        if q == 9 {
            // The 9-bit fallback: beyond the 8-bit input image, nothing
            // packs.
            assert!(
                got.iter().skip(1).all(|p| *p == Precision::I32),
                "Q=9 must fall back to I32 everywhere past the input"
            );
        }
    }
}

#[test]
fn synthnet_thresholds_pack_like_requants() {
    let mut rng = Rng::new(58);
    let net = SynthNet::init(&mut rng);
    for q in [4u32, 8, 9] {
        let nid = net
            .to_network(q)
            .unwrap()
            .deploy(DeployOptions {
                wbits: q,
                abits: q,
                use_thresholds: true,
                ..DeployOptions::default()
            })
            .unwrap()
            .integerize();
        let g = nid.int_graph();
        assert_eq!(
            nid.node_precisions(),
            expected_precisions(g),
            "Q={q} thresholds"
        );
        for n in &g.nodes {
            if let IntOp::ThreshAct { .. } = n.op {
                let want = match q {
                    4 => Precision::U4,
                    8 => Precision::U8,
                    _ => Precision::I32,
                };
                assert_eq!(n.precision, want, "Q={q} threshold '{}'", n.name);
            }
        }
    }
}

#[test]
fn synthnet_packed_arena_is_smaller_and_bit_identical() {
    let mut rng = Rng::new(56);
    let net = SynthNet::init(&mut rng);
    let nid = net
        .to_network(8)
        .unwrap()
        .deploy(DeployOptions::default())
        .unwrap()
        .integerize();
    let plan = IntPlan::compile(nid.int_graph()).unwrap();
    assert!(plan.has_packed_steps());
    let wide = plan.layout(8).unwrap();
    let packed = plan.packed_layout(8).unwrap();
    assert!(
        packed.arena_bytes() < wide.arena_bytes(),
        "packed arena {} B must beat i32 arena {} B on the deployed synthnet",
        packed.arena_bytes(),
        wide.arena_bytes()
    );

    let (x, _) = SynthDigits::eval_set(57, 8);
    let qx = quantize_input(&x, EPS_IN);
    let mut arena = PackedArena::new();
    let got = plan.execute_packed(&packed, &mut arena, &qx);
    let want = IntegerEngine::new().run_interpreted(nid.int_graph(), &qx);
    assert_eq!(got, want, "packed execution diverged from the interpreter");

    // The serving executor compiles the packed path for this graph.
    let exec = nid.to_executor(8).unwrap();
    assert!(exec.packed(), "deployed synthnet must serve packed");
}
