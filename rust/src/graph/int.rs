//! IntegerDeployable graph: integer-image operators only (paper sec. 3).
//!
//! Produced by `transform::integerize`; executed by
//! `engine::IntegerEngine` (the MCU-datapath simulator) and — through the
//! equivalent HLO artifact — by the PJRT runtime.

use crate::quant::bn::{BnQuant, Thresholds};
use crate::quant::requant::Requant;
use crate::quant::{Precision, QuantSpec};
use crate::tensor::QTensor;

pub type NodeId = usize;

/// Integer-domain operator.
///
/// GEMM weights are precision-tagged [`QTensor`] images stored at their
/// packed precision (i8 for byte grids, bit-packed for sub-byte grids,
/// i32 only when the values genuinely need it) — the representation a
/// binary artifact's zero-copy weight views load straight into. Widening
/// to `TensorI` (`wq.widen()`) is always available for full-width
/// consumers like the interpreter.
#[derive(Clone, Debug)]
pub enum IntOp {
    /// Integer input image, NCHW shape (without batch).
    Input { shape: Vec<usize>, spec: QuantSpec },
    /// Convolution with weights in matrix layout [C_in*KH*KW, C_out]
    /// (Eq. 16). Bias (if any) is already in the eps_phi space.
    ConvInt {
        wq: QTensor,
        bias_q: Option<Vec<i64>>,
        cin: usize,
        kh: usize,
        kw: usize,
        stride: usize,
        pad: usize,
    },
    /// Fully-connected: weights [in, out] (Eq. 16).
    LinearInt { wq: QTensor, bias_q: Option<Vec<i64>> },
    /// Integer batch-norm (Eq. 22).
    IntBn { bn: BnQuant },
    /// Requantizing activation (Eq. 11): clip((m*q) >> d, 0, 2^Q-1).
    RequantAct { rq: Requant },
    /// Threshold activation (Eq. 19-20) — the exact BN+act merge.
    ThreshAct { th: Thresholds },
    /// Integer average pooling (Eq. 25).
    AvgPoolInt { k: usize, d: u32 },
    /// Max pooling (untouched by quantization, sec. 3.6).
    MaxPoolInt { k: usize },
    Flatten,
    /// Add with per-branch requantization (Eq. 24): branch 0 is the
    /// reference space; rqs[i] requantizes branch i+1 into it.
    AddRequant { rqs: Vec<Requant> },
}

impl IntOp {
    /// Storage precision this op's output provably fits, given the
    /// precision of its (first) input — the op-local half of the
    /// `QuantSpec.bits -> Precision -> kernel` map (DESIGN.md §Precision
    /// propagation):
    ///
    /// * clipped ops carry their provable range directly (Input: the
    ///   quant spec; RequantAct: the clip bounds; ThreshAct: [0, levels]);
    /// * pooling/Flatten never widen the range, so they inherit;
    /// * GEMM/BN/Add accumulate and stay full-width `I32` (the deploy
    ///   range analysis proves they fit i32, nothing narrower).
    pub fn natural_precision(&self, input: Option<Precision>) -> Precision {
        match self {
            IntOp::Input { spec, .. } => Precision::of_spec(spec),
            IntOp::RequantAct { rq } => rq.output_precision(),
            IntOp::ThreshAct { th } => Precision::for_range(0, th.n_levels),
            IntOp::AvgPoolInt { .. } | IntOp::MaxPoolInt { .. } | IntOp::Flatten => {
                input.unwrap_or(Precision::I32)
            }
            IntOp::ConvInt { .. }
            | IntOp::LinearInt { .. }
            | IntOp::IntBn { .. }
            | IntOp::AddRequant { .. } => Precision::I32,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            IntOp::Input { .. } => "Input",
            IntOp::ConvInt { .. } => "ConvInt",
            IntOp::LinearInt { .. } => "LinearInt",
            IntOp::IntBn { .. } => "IntBn",
            IntOp::RequantAct { .. } => "RequantAct",
            IntOp::ThreshAct { .. } => "ThreshAct",
            IntOp::AvgPoolInt { .. } => "AvgPoolInt",
            IntOp::MaxPoolInt { .. } => "MaxPoolInt",
            IntOp::Flatten => "Flatten",
            IntOp::AddRequant { .. } => "AddRequant",
        }
    }
}

#[derive(Clone, Debug)]
pub struct IntNode {
    pub id: NodeId,
    pub op: IntOp,
    pub inputs: Vec<NodeId>,
    pub name: String,
    /// Storage precision of this node's output integer image, stamped at
    /// construction from [`IntOp::natural_precision`] and range-proved by
    /// the deployment transform. The plan compiler dispatches packed vs.
    /// full-width kernels on it.
    pub precision: Precision,
}

/// IntegerDeployable graph plus the eps bookkeeping needed to interpret
/// its (integer) output in the real domain.
#[derive(Clone, Debug, Default)]
pub struct IntGraph {
    pub nodes: Vec<IntNode>,
    pub output: NodeId,
    /// Quantum of the output integer image: logits_real ~ eps_out * Q.
    pub eps_out: f64,
}

impl IntGraph {
    pub fn push(&mut self, name: &str, op: IntOp, inputs: &[NodeId]) -> NodeId {
        let id = self.nodes.len();
        for &i in inputs {
            assert!(i < id, "forward reference");
        }
        let input_prec = inputs.first().map(|&i| self.nodes[i].precision);
        let precision = op.natural_precision(input_prec);
        self.nodes.push(IntNode {
            id,
            op,
            inputs: inputs.to_vec(),
            name: name.into(),
            precision,
        });
        self.output = id;
        id
    }

    pub fn node(&self, id: NodeId) -> &IntNode {
        &self.nodes[id]
    }

    /// Override a node's stamped storage precision. The assignment must
    /// still be proved sound (see `graph::shape::infer_precision`) — plan
    /// compilation rejects unsound stamps.
    pub fn stamp_precision(&mut self, id: NodeId, p: Precision) {
        self.nodes[id].precision = p;
    }

    /// Stamped output precision of every node, in id order.
    pub fn precisions(&self) -> Vec<Precision> {
        self.nodes.iter().map(|n| n.precision).collect()
    }

    /// Structural validation for graphs assembled outside [`Self::push`]
    /// (e.g. reconstructed from a deserialized deployment artifact,
    /// where a corrupt file must yield an error rather than trip push's
    /// forward-reference assertion): ids must be dense and in order,
    /// inputs must point strictly backwards, and the output must exist.
    pub fn validate(&self) -> Result<(), String> {
        if self.nodes.is_empty() {
            return Err("integer graph has no nodes".into());
        }
        for (i, n) in self.nodes.iter().enumerate() {
            if n.id != i {
                return Err(format!("node at position {i} carries id {}", n.id));
            }
            for &inp in &n.inputs {
                if inp >= i {
                    return Err(format!(
                        "node {i} ('{}') references input {inp} (forward or self)",
                        n.name
                    ));
                }
            }
        }
        if self.output >= self.nodes.len() {
            return Err(format!(
                "output id {} out of bounds ({} nodes)",
                self.output,
                self.nodes.len()
            ));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    #[test]
    fn push_stamps_natural_precisions() {
        let mut g = IntGraph::default();
        let spec = QuantSpec { eps: 1.0 / 255.0, lo: 0, hi: 255 };
        let x = g.push("in", IntOp::Input { shape: vec![1, 4, 4], spec }, &[]);
        let wq = Tensor::from_vec(&[9, 2], vec![1; 18]).into();
        let c = g.push(
            "conv",
            IntOp::ConvInt { wq, bias_q: None, cin: 1, kh: 3, kw: 3, stride: 1, pad: 1 },
            &[x],
        );
        let rq = Requant { m: 3, d: 2, lo: 0, hi: 255 };
        let a = g.push("act", IntOp::RequantAct { rq }, &[c]);
        let p = g.push("mp", IntOp::MaxPoolInt { k: 2 }, &[a]);
        let f = g.push("fl", IntOp::Flatten, &[p]);
        assert_eq!(g.node(x).precision, Precision::U8);
        assert_eq!(g.node(c).precision, Precision::I32);
        assert_eq!(g.node(a).precision, Precision::U8);
        assert_eq!(g.node(p).precision, Precision::U8); // maxpool inherits
        assert_eq!(g.node(f).precision, Precision::U8); // flatten inherits
        assert_eq!(g.precisions().len(), 5);
    }

    #[test]
    fn wide_requant_stays_full_width() {
        let mut g = IntGraph::default();
        let spec = QuantSpec { eps: 1.0, lo: 0, hi: 511 }; // 9-bit input
        let x = g.push("in", IntOp::Input { shape: vec![4], spec }, &[]);
        assert_eq!(g.node(x).precision, Precision::I32);
        let rq = Requant { m: 1, d: 0, lo: 0, hi: 511 }; // 9-bit clip
        let a = g.push("act", IntOp::RequantAct { rq }, &[x]);
        assert_eq!(g.node(a).precision, Precision::I32);
    }
}
