//! Remote serving integration tests: wire-protocol conformance over
//! real loopback sockets, bit-identity with in-process inference, and
//! the registry invariants (swap atomicity, metrics ledgers) observed
//! remotely.
//!
//! Contract under test (DESIGN.md §Network-protocol): every detectable
//! failure is answered with a typed `ReplyErr` — never a silently torn
//! connection; fatal framing errors close only *after* the reply;
//! payload-level errors keep the connection usable; and a loopback
//! round-trip is bit-identical to `ServerHandle::infer` because
//! integer inference is deterministic and tensors cross the wire
//! losslessly.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use nemo::coordinator::{Server, ServerConfig, ServerHandle};
use nemo::exec::{Arg, ExecInput, ExecOutput, Executor, NativeIntExecutor};
use nemo::graph::int::{IntGraph, IntOp};
use nemo::model::mlp;
use nemo::net::protocol::{
    decode_error, read_frame, Frame, Opcode, HEADER_LEN, MAGIC, WIRE_VERSION,
};
use nemo::net::{
    ClientConfig, NemoClient, NetConfig, NetServer, WireCode, WireError, MAX_PAYLOAD,
};
use nemo::network::{IntegerDeployable, Network};
use nemo::quant::{quantize_input, QuantSpec};
use nemo::tensor::{Tensor, TensorF, TensorI};
use nemo::transform::DeployOptions;
use nemo::util::rng::Rng;

// -- fixtures (shared idiom with tests/registry.rs) ---------------------

/// Deterministic stub: logits = input + offset.
struct OffsetExec {
    offset: i32,
}

impl Executor for OffsetExec {
    fn name(&self) -> &str {
        "offset-stub"
    }

    fn input_shape(&self) -> &[usize] {
        &[2]
    }

    fn max_batch(&self) -> usize {
        8
    }

    fn run_batch(&self, input: &ExecInput) -> anyhow::Result<ExecOutput> {
        let t = input.batch.as_i32()?;
        Ok(ExecOutput { logits: Arg::I32(t.map(|v| v + self.offset)) })
    }
}

/// Stub slow enough for a deadline to expire first.
struct SlowExec;

impl Executor for SlowExec {
    fn name(&self) -> &str {
        "slow-stub"
    }

    fn input_shape(&self) -> &[usize] {
        &[2]
    }

    fn max_batch(&self) -> usize {
        8
    }

    fn run_batch(&self, input: &ExecInput) -> anyhow::Result<ExecOutput> {
        std::thread::sleep(Duration::from_millis(150));
        Ok(ExecOutput { logits: input.batch.clone() })
    }
}

fn qx2(a: i32, b: i32) -> TensorI {
    Tensor::from_vec(&[1, 2], vec![a, b])
}

fn fast_cfg() -> ServerConfig {
    ServerConfig {
        max_batch: 8,
        batch_timeout: Duration::from_micros(200),
        n_workers: 2,
    }
}

fn deployed_mlp(seed: u64) -> Network<IntegerDeployable> {
    let mut rng = Rng::new(seed);
    let g = mlp(&mut rng, 12, 10, 4, 1.0 / 255.0);
    let x = TensorF::from_vec(
        &[8, 12],
        (0..96).map(|_| rng.uniform(0.0, 1.0) as f32).collect(),
    );
    let fp = Network::from_graph(g).unwrap();
    let betas = fp.calibrate(&[x]);
    fp.quantize_pact(8, 8, &betas)
        .unwrap()
        .deploy(DeployOptions::default())
        .unwrap()
        .integerize()
}

/// Identity graph whose input spec exceeds 8 bits, forcing the wide
/// (i32) executor path.
fn wide_identity_exec() -> Arc<dyn Executor> {
    let mut g = IntGraph::default();
    let spec = QuantSpec { eps: 1.0, lo: 0, hi: 1 << 16 };
    let x = g.push("in", IntOp::Input { shape: vec![2], spec }, &[]);
    let wq = Tensor::from_vec(&[2, 2], vec![1, 0, 0, 1]).into();
    g.push("fc", IntOp::LinearInt { wq, bias_q: None }, &[x]);
    g.eps_out = 1.0;
    let exec = NativeIntExecutor::new(g, 8).unwrap();
    assert!(!exec.packed(), "this fixture must exercise the wide path");
    Arc::new(exec)
}

/// Boot a coordinator + socket front-end; returns (net server, server,
/// handle) — callers stop the net layer first, then the coordinator.
fn boot(builder_models: Vec<(&str, Arc<dyn Executor>)>, net_cfg: NetConfig)
    -> (NetServer, Server, ServerHandle) {
    let mut b = Server::builder().default_config(fast_cfg());
    for (name, exec) in builder_models {
        b = b.model(name, exec);
    }
    let server = b.start().unwrap();
    let h = server.handle();
    let ns = NetServer::bind("127.0.0.1:0", server.handle(), net_cfg).unwrap();
    (ns, server, h)
}

fn connect(ns: &NetServer) -> NemoClient {
    NemoClient::connect_with(
        ns.local_addr(),
        ClientConfig { read_timeout: Duration::from_secs(5), ..Default::default() },
    )
    .unwrap()
}

fn wire_code(err: &anyhow::Error) -> Option<WireCode> {
    err.downcast_ref::<WireError>().map(|w| w.code)
}

/// Raw socket speaking hand-built frames — for protocol-violation tests
/// the well-behaved client cannot produce.
fn raw_socket(ns: &NetServer) -> TcpStream {
    let s = TcpStream::connect(ns.local_addr()).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    s.set_write_timeout(Some(Duration::from_secs(5))).unwrap();
    s
}

/// Read one reply and expect a typed error with `code`.
fn expect_err_reply(s: &mut TcpStream, code: WireCode) -> WireError {
    let frame = read_frame(s, MAX_PAYLOAD).unwrap();
    assert_eq!(frame.opcode, Opcode::ReplyErr, "expected a typed error reply");
    let err = decode_error(&frame.payload);
    assert_eq!(err.code, code, "{err}");
    err
}

/// After a fatal error the server must close: the next read sees EOF.
fn expect_eof(s: &mut TcpStream) {
    let mut buf = [0u8; 1];
    loop {
        match s.read(&mut buf) {
            Ok(0) => return,
            Ok(_) => panic!("server sent bytes after a fatal error"),
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                panic!("connection still open after a fatal error")
            }
            // Reset is also a close on some platforms.
            Err(_) => return,
        }
    }
}

// -- bit-identity (acceptance criterion) --------------------------------

#[test]
fn loopback_is_bit_identical_to_in_process_packed_path() {
    let net = deployed_mlp(71);
    let exec = net.to_shared_executor(8).unwrap();
    let (ns, server, h) = boot(vec![("m", exec)], NetConfig::default());
    let mut client = connect(&ns);

    let mut rng = Rng::new(710);
    for _ in 0..16 {
        let x = TensorF::from_vec(
            &[1, 12],
            (0..12).map(|_| rng.uniform(0.0, 1.0) as f32).collect(),
        );
        let qx = quantize_input(&x, 1.0 / 255.0);
        let remote = client.infer("m", &qx).unwrap();
        let local = h.infer("m", qx.clone()).unwrap();
        let engine = net.run(&qx);
        // remote == in-process served == raw engine, bit for bit
        assert_eq!(remote.data(), local.data());
        assert_eq!(remote.shape(), local.shape());
        assert_eq!(remote.data(), engine.data());
    }
    ns.stop();
    server.stop();
}

#[test]
fn loopback_is_bit_identical_on_the_wide_path() {
    let (ns, server, h) = boot(vec![("w", wide_identity_exec())], NetConfig::default());
    let mut client = connect(&ns);
    // 40000 does not fit u8/i8, so it crosses the wire as i32 both ways.
    let qx = qx2(40000, 2);
    let remote = client.infer("w", &qx).unwrap();
    let local = h.infer("w", qx).unwrap();
    assert_eq!(remote.data(), &[40000, 2]);
    assert_eq!(remote.data(), local.data());
    ns.stop();
    server.stop();
}

// -- swap atomicity under concurrent remote traffic (acceptance) --------

#[test]
fn concurrent_remote_swap_loses_zero_replies() {
    let net1 = deployed_mlp(81);
    let net2 = deployed_mlp(82);
    let path = std::env::temp_dir()
        .join(format!("nemo_net_swap_{}.nemo.json", std::process::id()));
    net2.save_deployed(&path).unwrap();

    let exec = net1.to_shared_executor(8).unwrap();
    let (ns, server, h) = boot(vec![("m", exec)], NetConfig::default());

    let net1 = Arc::new(net1);
    let net2 = Arc::new(net2);
    let swapped = Arc::new(AtomicBool::new(false));
    let per_client = 40usize;
    let n_clients = 4usize;

    let mut joins = Vec::new();
    for c in 0..n_clients as u64 {
        let addr = ns.local_addr();
        let (net1, net2) = (net1.clone(), net2.clone());
        let swapped = swapped.clone();
        joins.push(std::thread::spawn(move || {
            let mut client = NemoClient::connect(addr).unwrap();
            let mut rng = Rng::new(8100 + c);
            for _ in 0..per_client {
                let x = TensorF::from_vec(
                    &[1, 12],
                    (0..12).map(|_| rng.uniform(0.0, 1.0) as f32).collect(),
                );
                let qx = quantize_input(&x, 1.0 / 255.0);
                let was_swapped = swapped.load(Ordering::SeqCst);
                // Zero lost replies: every request gets an Ok, mid-swap
                // included.
                let served = client.infer("m", &qx).unwrap();
                let e1 = net1.run(&qx);
                let e2 = net2.run(&qx);
                // Every reply is bit-identical to exactly one version.
                assert!(
                    served.data() == e1.data() || served.data() == e2.data(),
                    "reply matches neither executor version"
                );
                if was_swapped {
                    // Submitted strictly after the swap returned: must
                    // run on the new executor.
                    assert_eq!(served.data(), e2.data());
                }
            }
        }));
    }

    // Remote hot swap from its own connection, mid-traffic.
    let swap_version = {
        let mut admin = connect(&ns);
        std::thread::sleep(Duration::from_millis(10));
        let v = admin.swap_model("m", path.to_str().unwrap()).unwrap();
        swapped.store(true, Ordering::SeqCst);
        v
    };
    assert_eq!(swap_version, 2);

    for j in joins {
        j.join().unwrap();
    }

    // Ledger spans both versions and lost nothing. Metrics are recorded
    // after replies scatter, so poll briefly for the last batch.
    let total = (per_client * n_clients) as u64;
    let mut admin = connect(&ns);
    let t0 = Instant::now();
    loop {
        let m = admin.model_metrics("m").unwrap();
        if m.completed == total {
            assert_eq!(m.failed, 0);
            break;
        }
        assert!(
            t0.elapsed() < Duration::from_secs(5),
            "ledger stuck at {} of {total}",
            m.completed
        );
        std::thread::sleep(Duration::from_millis(5));
    }
    ns.stop();
    server.stop();
    let _ = std::fs::remove_file(path);
}

// -- wire admin ops -----------------------------------------------------

#[test]
fn wire_list_is_sorted_and_complete() {
    let (ns, server, _h) = boot(
        vec![
            ("zebra", Arc::new(OffsetExec { offset: 1 }) as Arc<dyn Executor>),
            ("alpha", Arc::new(OffsetExec { offset: 2 }) as Arc<dyn Executor>),
            ("mid", Arc::new(OffsetExec { offset: 3 }) as Arc<dyn Executor>),
        ],
        NetConfig::default(),
    );
    let mut client = connect(&ns);
    let infos = client.list_models().unwrap();
    let names: Vec<&str> = infos.iter().map(|m| m.name.as_str()).collect();
    // Deterministic order, wire-guaranteed: sorted by name.
    assert_eq!(names, ["alpha", "mid", "zebra"]);
    for m in &infos {
        assert_eq!(m.version, 1);
        assert_eq!(m.backend, "offset-stub");
        assert_eq!(m.input_shape, vec![2]);
        assert_eq!(m.max_batch, 8);
        assert_eq!(m.provenance, "in-memory");
    }
    ns.stop();
    server.stop();
}

#[test]
fn remote_load_metrics_unload_lifecycle() {
    let net = deployed_mlp(91);
    let path = std::env::temp_dir()
        .join(format!("nemo_net_load_{}.nemo.json", std::process::id()));
    net.save_deployed(&path).unwrap();

    let (ns, server, _h) = boot(
        vec![("seed", Arc::new(OffsetExec { offset: 5 }) as Arc<dyn Executor>)],
        NetConfig::default(),
    );
    let mut client = connect(&ns);

    // load a second model from a server-side artifact path
    let v = client.load_model("fresh", path.to_str().unwrap()).unwrap();
    assert_eq!(v, 1);
    let names: Vec<String> =
        client.list_models().unwrap().into_iter().map(|m| m.name).collect();
    assert_eq!(names, ["fresh", "seed"]);

    // traffic lands in the new model's ledger
    let mut rng = Rng::new(910);
    let x = TensorF::from_vec(
        &[1, 12],
        (0..12).map(|_| rng.uniform(0.0, 1.0) as f32).collect(),
    );
    let qx = quantize_input(&x, 1.0 / 255.0);
    let remote = client.infer("fresh", &qx).unwrap();
    assert_eq!(remote.data(), net.run(&qx).data());

    // metrics are recorded after the reply is scattered — poll briefly
    let t0 = Instant::now();
    loop {
        let m = client.model_metrics("fresh").unwrap();
        if m.completed == 1 {
            assert_eq!(m.failed, 0);
            assert_eq!(m.e2e_latency.count, 1);
            break;
        }
        assert!(t0.elapsed() < Duration::from_secs(5), "metrics never caught up");
        std::thread::sleep(Duration::from_millis(5));
    }

    // unload: subsequent inference is a typed unknown-model error
    client.unload_model("fresh").unwrap();
    let err = client.infer("fresh", &qx).unwrap_err();
    assert_eq!(wire_code(&err), Some(WireCode::UnknownModel), "{err:#}");
    // the connection survived the typed error
    client.ping().unwrap();
    ns.stop();
    server.stop();
    let _ = std::fs::remove_file(path);
}

// -- typed wire errors (satellite: protocol conformance) ----------------

#[test]
fn unknown_model_is_a_typed_wire_error() {
    let (ns, server, _h) = boot(
        vec![("m", Arc::new(OffsetExec { offset: 1 }) as Arc<dyn Executor>)],
        NetConfig::default(),
    );
    let mut client = connect(&ns);
    let err = client.infer("nope", &qx2(1, 2)).unwrap_err();
    assert_eq!(wire_code(&err), Some(WireCode::UnknownModel), "{err:#}");
    // non-fatal: same connection keeps serving
    assert_eq!(client.infer("m", &qx2(1, 2)).unwrap().data(), &[2, 3]);
    ns.stop();
    server.stop();
}

#[test]
fn deadline_propagates_client_to_server_to_batcher() {
    let (ns, server, _h) = boot(
        vec![("slow", Arc::new(SlowExec) as Arc<dyn Executor>)],
        NetConfig::default(),
    );
    let mut client = connect(&ns);
    let t0 = Instant::now();
    let err = client
        .infer_deadline("slow", &qx2(1, 2), Duration::from_millis(10))
        .unwrap_err();
    // typed, and from the server's deadline logic — not a socket timeout
    assert_eq!(wire_code(&err), Some(WireCode::DeadlineExceeded), "{err:#}");
    assert!(
        t0.elapsed() < Duration::from_millis(150),
        "deadline reply must not wait for the slow executor"
    );
    // connection stays usable after the typed error
    client.ping().unwrap();
    ns.stop();
    server.stop();
}

#[test]
fn malformed_magic_is_typed_then_fatal() {
    let (ns, server, _h) = boot(
        vec![("m", Arc::new(OffsetExec { offset: 1 }) as Arc<dyn Executor>)],
        NetConfig::default(),
    );
    let mut s = raw_socket(&ns);
    let mut bytes = Frame::new(Opcode::Ping, 7, Vec::new()).encode();
    bytes[..4].copy_from_slice(b"XENO");
    s.write_all(&bytes).unwrap();
    expect_err_reply(&mut s, WireCode::MalformedFrame);
    expect_eof(&mut s);
    ns.stop();
    server.stop();
}

#[test]
fn protocol_version_mismatch_is_typed_then_fatal() {
    let (ns, server, _h) = boot(
        vec![("m", Arc::new(OffsetExec { offset: 1 }) as Arc<dyn Executor>)],
        NetConfig::default(),
    );
    let mut s = raw_socket(&ns);
    let mut bytes = Frame::new(Opcode::Ping, 9, Vec::new()).encode();
    bytes[4..6].copy_from_slice(&99u16.to_le_bytes());
    assert_ne!(WIRE_VERSION, 99);
    s.write_all(&bytes).unwrap();
    let err = expect_err_reply(&mut s, WireCode::VersionMismatch);
    assert!(err.message.contains("v99"), "{err}");
    expect_eof(&mut s);
    ns.stop();
    server.stop();
}

#[test]
fn oversized_frame_is_rejected_without_reading_the_payload() {
    let (ns, server, _h) = boot(
        vec![("m", Arc::new(OffsetExec { offset: 1 }) as Arc<dyn Executor>)],
        NetConfig { max_payload: 1024, ..NetConfig::default() },
    );
    let mut s = raw_socket(&ns);
    // header declaring a 1 MiB payload; the payload itself never sent
    let mut hdr = Vec::with_capacity(HEADER_LEN);
    hdr.extend_from_slice(&MAGIC.to_le_bytes());
    hdr.extend_from_slice(&WIRE_VERSION.to_le_bytes());
    hdr.push(Opcode::Ping as u8);
    hdr.push(0);
    hdr.extend_from_slice(&11u64.to_le_bytes());
    hdr.extend_from_slice(&(1u32 << 20).to_le_bytes());
    s.write_all(&hdr).unwrap();
    expect_err_reply(&mut s, WireCode::FrameTooLarge);
    expect_eof(&mut s);
    ns.stop();
    server.stop();
}

#[test]
fn truncated_frame_is_typed_then_fatal_not_a_hang() {
    let (ns, server, _h) = boot(
        vec![("m", Arc::new(OffsetExec { offset: 1 }) as Arc<dyn Executor>)],
        // short stall limit so the test is quick
        NetConfig { read_timeout: Duration::from_millis(100), ..NetConfig::default() },
    );
    let mut s = raw_socket(&ns);
    // header promises 64 payload bytes; only 10 ever arrive
    let mut hdr = Vec::with_capacity(HEADER_LEN + 10);
    hdr.extend_from_slice(&MAGIC.to_le_bytes());
    hdr.extend_from_slice(&WIRE_VERSION.to_le_bytes());
    hdr.push(Opcode::Ping as u8);
    hdr.push(0);
    hdr.extend_from_slice(&13u64.to_le_bytes());
    hdr.extend_from_slice(&64u32.to_le_bytes());
    hdr.extend_from_slice(&[0u8; 10]);
    s.write_all(&hdr).unwrap();
    let t0 = Instant::now();
    let err = expect_err_reply(&mut s, WireCode::MalformedFrame);
    assert!(err.message.contains("truncated"), "{err}");
    assert!(t0.elapsed() < Duration::from_secs(3), "stall must not hang");
    expect_eof(&mut s);
    ns.stop();
    server.stop();
}

#[test]
fn checksum_corruption_is_typed_and_the_connection_survives() {
    let (ns, server, _h) = boot(
        vec![("m", Arc::new(OffsetExec { offset: 1 }) as Arc<dyn Executor>)],
        NetConfig::default(),
    );
    let mut s = raw_socket(&ns);
    // ping with a non-empty payload (so the checksum covers something),
    // trailer flipped
    let mut bytes = Frame::new(Opcode::Ping, 21, vec![1, 2, 3]).encode();
    let last = bytes.len() - 1;
    bytes[last] ^= 0xff;
    s.write_all(&bytes).unwrap();
    expect_err_reply(&mut s, WireCode::ChecksumMismatch);
    // framing stayed in sync: a valid frame on the same connection works
    // (ping rejects non-empty payloads as BadRequest, so send empty)
    let ping = Frame::new(Opcode::Ping, 22, Vec::new());
    s.write_all(&ping.encode()).unwrap();
    let reply = read_frame(&mut s, MAX_PAYLOAD).unwrap();
    assert_eq!(reply.opcode, Opcode::ReplyOk);
    assert_eq!(reply.req_id, 22);
    ns.stop();
    server.stop();
}

#[test]
fn reply_opcodes_and_garbage_payloads_are_bad_requests() {
    let (ns, server, _h) = boot(
        vec![("m", Arc::new(OffsetExec { offset: 1 }) as Arc<dyn Executor>)],
        NetConfig::default(),
    );
    let mut s = raw_socket(&ns);
    // a reply opcode as a request
    s.write_all(&Frame::new(Opcode::ReplyOk, 31, Vec::new()).encode()).unwrap();
    expect_err_reply(&mut s, WireCode::BadRequest);
    // unknown opcode byte
    let mut bytes = Frame::new(Opcode::Ping, 32, Vec::new()).encode();
    bytes[6] = 0x7f;
    s.write_all(&bytes).unwrap();
    expect_err_reply(&mut s, WireCode::BadRequest);
    // a structurally broken infer payload (truncated string)
    s.write_all(&Frame::new(Opcode::Infer, 33, vec![255, 0, 0, 0]).encode())
        .unwrap();
    expect_err_reply(&mut s, WireCode::MalformedFrame);
    ns.stop();
    server.stop();
}

// -- pipelining, idle reaping, graceful drain ---------------------------

#[test]
fn pipelined_requests_reply_in_order() {
    let (ns, server, _h) = boot(
        vec![("m", Arc::new(OffsetExec { offset: 7 }) as Arc<dyn Executor>)],
        NetConfig::default(),
    );
    let mut client = connect(&ns);
    let inputs: Vec<TensorI> = (0..10).map(|i| qx2(i, i * 10)).collect();
    let outs = client.infer_pipelined("m", &inputs).unwrap();
    assert_eq!(outs.len(), 10);
    for (i, out) in outs.iter().enumerate() {
        let i = i as i32;
        assert_eq!(out.data(), &[i + 7, i * 10 + 7], "reply {i} out of order");
    }
    ns.stop();
    server.stop();
}

#[test]
fn idle_connections_are_reaped() {
    let (ns, server, _h) = boot(
        vec![("m", Arc::new(OffsetExec { offset: 1 }) as Arc<dyn Executor>)],
        NetConfig { idle_timeout: Duration::from_millis(100), ..NetConfig::default() },
    );
    let mut client = connect(&ns);
    client.ping().unwrap();
    std::thread::sleep(Duration::from_millis(400));
    // the server closed the idle connection; the next call fails instead
    // of hanging
    assert!(client.ping().is_err());
    // fresh connections still serve
    let mut fresh = connect(&ns);
    fresh.ping().unwrap();
    ns.stop();
    server.stop();
}

#[test]
fn graceful_drain_completes_the_in_flight_reply() {
    let (ns, server, _h) = boot(
        vec![("slow", Arc::new(SlowExec) as Arc<dyn Executor>)],
        NetConfig::default(),
    );
    let addr = ns.local_addr();
    let worker = std::thread::spawn(move || {
        let mut client = NemoClient::connect(addr).unwrap();
        client.infer("slow", &qx2(3, 4)).unwrap()
    });
    // let the request reach the handler, then stop the socket layer:
    // the in-flight request must still complete and reply before the
    // handler joins.
    std::thread::sleep(Duration::from_millis(60));
    ns.stop();
    let out = worker.join().unwrap();
    assert_eq!(out.data(), &[3, 4]);
    server.stop();
}
