//! `nemo` — the L3 leader binary.
//!
//! Subcommands:
//!   train     train SynthNet (FP, then optional FQ fine-tune) and write
//!             a checkpoint; `--backend native` (the default) runs the
//!             in-process backward-plan engine, `--backend pjrt` the
//!             AOT-compiled PJRT train steps (requires the `pjrt`
//!             feature); `--resume ck.json` continues an earlier run
//!             (model + optimizer state)
//!   deploy    run the typestate quantization pipeline on a checkpoint;
//!             prints the per-layer quantization table and validates
//!             QD/ID agreement
//!   infer     classify synthetic samples with the IntegerDeployable
//!             network from a checkpoint
//!   serve     start the serving coordinator; `--listen ADDR` exposes it
//!             over the framed-TCP wire protocol until SIGINT/SIGTERM
//!             (graceful drain), otherwise a self-driving load test
//!             runs; `--backend native` serves the in-process integer
//!             engine (no artifacts needed), `--backend pjrt` the
//!             compiled executables
//!   client    talk to a remote `nemo serve --listen` server:
//!             ping / list / metrics / infer / swap / load / unload
//!   validate  re-run the cross-language golden checks
//!   info      list artifacts and platform info
//!   check     statically verify deployment artifacts: interval
//!             abstract interpretation proves accumulators fit the i32
//!             datapath, requants cannot saturate and precision stamps
//!             hold (`--json` for the machine-readable report,
//!             `--strict` to fail on warnings too)
//!
//! `nemo <sub> --help-less`: flags are documented in README.md.

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use nemo::cli::{model_spec, Args};
use nemo::coordinator::{Server, ServerConfig};
use nemo::data::SynthDigits;
use nemo::exec::Executor;
use nemo::io::{artifacts_dir, Checkpoint, DeployedArtifact, Goldens};
use nemo::model::synthnet::{SynthNet, EPS_IN};
use nemo::network::{IntegerDeployable, Network};
use nemo::quant::quantize_input;
use nemo::train::{eval_float, eval_integer};
use nemo::transform::DeployOptions;
use nemo::util::rng::Rng;

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e:#}\n{USAGE}");
            std::process::exit(2);
        }
    };
    // Only `client` takes a positional action word (`nemo client ping`).
    if let Some(a) = &args.action {
        if args.subcommand != "client" {
            eprintln!(
                "error: unexpected positional argument '{a}' after \
                 '{}'\n{USAGE}",
                args.subcommand
            );
            std::process::exit(2);
        }
    }
    let r = match args.subcommand.as_str() {
        "train" => cmd_train(&args),
        "deploy" => cmd_deploy(&args),
        "infer" => cmd_infer(&args),
        "serve" => cmd_serve(&args),
        "client" => cmd_client(&args),
        "validate" => cmd_validate(&args),
        "info" => cmd_info(&args),
        "check" => cmd_check(&args),
        "" => {
            eprintln!("{USAGE}");
            std::process::exit(2);
        }
        s => {
            eprintln!("unknown subcommand '{s}'\n{USAGE}");
            std::process::exit(2);
        }
    };
    if let Err(e) = r {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

const USAGE: &str = "usage: nemo <train|deploy|infer|serve|client|validate|info|check> [--flags]
  train    --steps N --fq-steps N --bits B --lr F --batch B --seed N --out ck.json
           --backend native|pjrt (native needs no artifacts) --resume ck.json (continue a run)
  deploy   --ckpt ck.json --bits B --thresholds --save m.nemo.json --save-bin m.nemob
  infer    --ckpt ck.json --n N --bits B
  serve    --ckpt ck.json --backend native|pjrt --requests N --clients C --max-batch B --timeout-us T
           --model [name=]m.nemo.json  (repeatable: serve saved deployment artifacts by name —
                                        JSON or binary .nemob; name defaults to the file stem)
           --swap name=m.nemo.json     (hot-swap an artifact onto the running server mid-load-test)
           --listen ADDR               (serve remotely over the wire protocol until SIGINT/SIGTERM
                                        drains in-flight batches; --port-file F writes the bound port)
  client   <ping|list|metrics|infer|swap|load|unload> --addr HOST:PORT
           infer --model NAME --n N --seed S [--input qx.json] [--deadline-us T] [--pipeline]
           swap/load --model name=m.nemo.json   metrics/unload --model NAME
  validate
  info     --model m.nemo.json|m.nemob  (repeatable: inspect artifacts without serving them;
                                         .nemob additionally prints the weight section table)
  check    --model m.nemo.json|m.nemob  (repeatable: run the static soundness verifier; exits
                                         nonzero on any error finding)
           --json     (machine-readable nemo-check-report v1, one document per artifact)
           --strict   (warnings also fail the check)";

fn load_or_init_net(args: &Args, rng: &mut Rng) -> Result<SynthNet> {
    match args.str_opt("ckpt") {
        Some(p) if std::path::Path::new(p).exists() => {
            let ck = Checkpoint::load(p)?;
            SynthNet::from_checkpoint(&ck)
        }
        Some(p) => bail!("checkpoint {p} not found (run `nemo train` first)"),
        None => Ok(SynthNet::init(rng)),
    }
}

fn cmd_train(args: &Args) -> Result<()> {
    match args.str_or("backend", "native").as_str() {
        "native" => cmd_train_native(args),
        "pjrt" => cmd_train_pjrt(args),
        b => bail!("unknown train backend '{b}' (expected native|pjrt)"),
    }
}

fn train_config_from_args(args: &Args, seed: u64) -> Result<nemo::train::TrainConfig> {
    Ok(nemo::train::TrainConfig {
        steps: args.usize_or("steps", 300)?,
        lr: args.f64_or("lr", 0.05)?,
        lr_decay: true,
        seed,
        log_every: if args.bool("quiet") { 0 } else { 25 },
        batch: args.usize_or("batch", nemo::train::TRAIN_BATCH)?,
        ..nemo::train::TrainConfig::default()
    })
}

/// Calibrate PACT betas from the trained FP net (paper sec. 2: beta =
/// max of y in the FullPrecision stage). Always done — deployment reads
/// them from the checkpoint even without QAT fine-tuning.
fn calibrate_betas(args: &Args, net: &mut SynthNet, data: &mut SynthDigits) -> Result<()> {
    let (cal_x, _) = data.batch(64);
    let pctl = args.f64_or("calib-pctl", 0.995)?;
    let fp = Network::from_graph(net.to_fp_graph())?;
    net.act_betas = fp.calibrate_percentile(&[cal_x], pctl);
    println!("calibrated act betas: {:?}", net.act_betas);
    Ok(())
}

/// Native training: the backward-plan engine in this binary — no PJRT
/// runtime, no artifacts, works in the default build.
fn cmd_train_native(args: &Args) -> Result<()> {
    use nemo::train::native::{train_fp, train_fq, OptState};

    let seed = args.usize_or("seed", 1)? as u64;
    let mut rng = Rng::new(seed);
    let (mut net, mut opt) = match args.str_opt("resume") {
        Some(p) => {
            let ck = Checkpoint::load(p).with_context(|| format!("resume checkpoint {p}"))?;
            println!("resuming from {p}");
            (SynthNet::from_checkpoint(&ck)?, OptState::load(&ck))
        }
        None => (SynthNet::init(&mut rng), OptState::default()),
    };
    let mut data = SynthDigits::new(seed);
    let fq_steps = args.usize_or("fq-steps", 150)?;
    let bits = args.u32_or("bits", 8)?;
    let cfg = train_config_from_args(args, seed)?;

    println!("== FullPrecision training ({} steps, native) ==", cfg.steps);
    let rep = train_fp(&mut net, &mut data, &cfg, &mut opt)?;
    let (h, t) = rep.head_tail(10);
    println!("loss: first10 {h:.4} -> last10 {t:.4}");

    calibrate_betas(args, &mut net, &mut data)?;

    if fq_steps > 0 {
        println!("== FakeQuantized fine-tune w{bits}a{bits} ({fq_steps} steps, native) ==");
        let cfg2 = nemo::train::TrainConfig { steps: fq_steps, lr: cfg.lr * 0.2, ..cfg };
        let rep2 = train_fq(&mut net, &mut data, bits, bits, &cfg2, &mut opt)?;
        let (h2, t2) = rep2.head_tail(10);
        println!("loss: first10 {h2:.4} -> last10 {t2:.4}");
    }

    let (ex, el) = SynthDigits::eval_set(seed, 512);
    let acc = eval_float(&net.to_fp_graph(), &ex, &el);
    println!("FP eval accuracy: {:.1}%", acc * 100.0);

    let out = args.str_or("out", "synthnet_ck.json");
    let mut ck = net.to_checkpoint();
    opt.save(&mut ck);
    ck.save(&out)?;
    println!("checkpoint -> {out}");
    Ok(())
}

#[cfg(feature = "pjrt")]
fn cmd_train_pjrt(args: &Args) -> Result<()> {
    use nemo::train::{train_fp, train_fq, TrainConfig};

    let rt = nemo::runtime::Runtime::new(artifacts_dir())?;
    let seed = args.usize_or("seed", 1)? as u64;
    let mut rng = Rng::new(seed);
    let mut net = SynthNet::init(&mut rng);
    let mut data = SynthDigits::new(seed);
    let steps = args.usize_or("steps", 300)?;
    let fq_steps = args.usize_or("fq-steps", 150)?;
    let bits = args.u32_or("bits", 8)?;
    let cfg = train_config_from_args(args, seed)?;

    println!("== FullPrecision training ({steps} steps) ==");
    let rep = train_fp(&rt, &mut net, &mut data, &cfg)?;
    let (h, t) = rep.head_tail(10);
    println!("loss: first10 {h:.4} -> last10 {t:.4}");

    calibrate_betas(args, &mut net, &mut data)?;

    if fq_steps > 0 {
        println!("== FakeQuantized fine-tune w{bits}a{bits} ({fq_steps} steps) ==");
        let cfg2 = TrainConfig { steps: fq_steps, lr: cfg.lr * 0.2, ..cfg };
        let rep2 = train_fq(&rt, &mut net, &mut data, bits, bits, &cfg2)?;
        let (h2, t2) = rep2.head_tail(10);
        println!("loss: first10 {h2:.4} -> last10 {t2:.4}");
    }

    let (ex, el) = SynthDigits::eval_set(seed, 512);
    let acc = eval_float(&net.to_fp_graph(), &ex, &el);
    println!("FP eval accuracy: {:.1}%", acc * 100.0);

    let out = args.str_or("out", "synthnet_ck.json");
    net.to_checkpoint().save(&out)?;
    println!("checkpoint -> {out}");
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_train_pjrt(_args: &Args) -> Result<()> {
    bail!(
        "`--backend pjrt` runs the AOT-compiled PJRT train steps; this \
         binary was built without the `pjrt` feature (rebuild with \
         `--features pjrt`, or drop the flag to train natively)"
    )
}

/// Run the typestate pipeline FakeQuantized -> QD -> ID on a net.
fn deploy_from_args(args: &Args, net: &SynthNet) -> Result<Network<IntegerDeployable>> {
    let bits = args.u32_or("bits", 8)?;
    let opts = DeployOptions {
        wbits: bits,
        abits: bits,
        use_thresholds: args.bool("thresholds"),
        ..DeployOptions::default()
    };
    Ok(net.to_network(opts.abits)?.deploy(opts)?.integerize())
}

fn cmd_deploy(args: &Args) -> Result<()> {
    let mut rng = Rng::new(7);
    let net = load_or_init_net(args, &mut rng)?;
    let nid = deploy_from_args(args, &net)?;
    println!("per-layer quantization (paper sec. 3 pipeline):");
    println!(
        "{:<8} {:>12} {:>12} {:>12} {:>12} {:>4} {:>8}",
        "layer", "eps_w", "eps_phi", "eps_phi_out", "eps_y", "d", "m"
    );
    for l in nid.layers() {
        println!(
            "{:<8} {:>12.3e} {:>12.3e} {:>12.3e} {:>12.3e} {:>4} {:>8}",
            l.name, l.eps_w, l.eps_phi, l.eps_phi_out, l.eps_y, l.d, l.m
        );
    }
    println!("eps_out = {:.6e}", nid.eps_out());
    let worst = *nid.deployed().worst_case.iter().max().unwrap();
    println!(
        "worst-case integer magnitude: {} (i32 headroom {:.1}%)",
        worst,
        100.0 * worst as f64 / i32::MAX as f64
    );

    // quick QD vs ID agreement check on synthetic data
    let (x, labels) = SynthDigits::eval_set(11, 256);
    let fp_acc = eval_float(&net.to_fp_graph(), &x, &labels);
    let qd_acc = eval_float(&nid.deployed().qd, &x, &labels);
    let id_acc = eval_integer(nid.int_graph(), &x, &labels, EPS_IN);
    println!(
        "FP accuracy {:.1}%  QD accuracy {:.1}%  ID accuracy {:.1}%",
        fp_acc * 100.0,
        qd_acc * 100.0,
        id_acc * 100.0
    );

    if args.bool("debug") {
        debug_layerwise(nid.deployed(), &x);
    }

    // Freeze the deployed model as a native artifact: `nemo serve
    // --model <path>` then serves it with no training or transform work.
    if let Some(path) = args.str_opt("save") {
        nid.save_deployed(path)
            .with_context(|| format!("saving deployment artifact {path}"))?;
        let bytes = std::fs::metadata(path).map(|m| m.len()).unwrap_or(0);
        println!("deployment artifact -> {path} ({bytes} bytes)");
    }
    // The v3 binary container: same frozen program, 64-byte-aligned
    // weight sections the loader mmaps into zero-copy views.
    if let Some(path) = args.str_opt("save-bin") {
        nid.save_deployed_bin(path)
            .with_context(|| format!("saving binary deployment artifact {path}"))?;
        let bytes = std::fs::metadata(path).map(|m| m.len()).unwrap_or(0);
        println!("binary deployment artifact -> {path} ({bytes} bytes)");
    }
    Ok(())
}

/// Per-node QD (float, on-grid) vs ID (integer image * eps) comparison —
/// pinpoints which operator introduces requantization error.
fn debug_layerwise(dep: &nemo::transform::Deployed, x: &nemo::tensor::TensorF) {
    use nemo::engine::{FloatEngine, IntegerEngine};
    let x = x.slice_batch(0, 8.min(x.shape()[0]));
    let qx = quantize_input(&x, EPS_IN);
    let x_grid = qx.map(|q| q as f32 / 255.0);
    let qd_trace = FloatEngine::new().run_traced(&dep.qd, &x_grid);
    let id_trace = IntegerEngine::new().run_traced(&dep.id, &qx);
    let qd_by_name: std::collections::HashMap<&str, usize> = dep
        .qd
        .nodes
        .iter()
        .map(|n| (n.name.as_str(), n.id))
        .collect();
    println!("\nper-node QD vs ID (max |qd - eps*Q|, and scale):");
    for (i, n) in dep.id.nodes.iter().enumerate() {
        let Some(&qi) = qd_by_name.get(n.name.as_str()) else { continue };
        let qd_t = &qd_trace[qi];
        let id_t = &id_trace[i];
        if qd_t.len() != id_t.len() {
            continue;
        }
        let eps = dep.node_eps[i];
        let mut max_diff = 0f64;
        let mut max_mag = 0f64;
        for (a, b) in qd_t.data().iter().zip(id_t.data()) {
            let real = *b as f64 * eps;
            max_diff = max_diff.max((*a as f64 - real).abs());
            max_mag = max_mag.max((*a as f64).abs());
        }
        println!(
            "  {:<14} {:<12} eps={:.3e}  max|diff|={:.4e}  max|qd|={:.3e}  rel={:.3}%",
            n.name,
            n.op.name(),
            eps,
            max_diff,
            max_mag,
            100.0 * max_diff / max_mag.max(1e-12)
        );
    }
}

fn cmd_infer(args: &Args) -> Result<()> {
    let mut rng = Rng::new(3);
    let net = load_or_init_net(args, &mut rng)?;
    let nid = deploy_from_args(args, &net)?;
    let n = args.usize_or("n", 8)?;
    let mut data = SynthDigits::new(args.usize_or("seed", 5)? as u64);
    let mut correct = 0;
    for _ in 0..n {
        let (x, labels) = data.batch(1);
        let qx = quantize_input(&x, EPS_IN);
        let out = nid.run(&qx);
        let pred = out.argmax_rows()[0];
        if pred == labels[0] {
            correct += 1;
        }
        println!("label {} -> pred {} {}", labels[0], pred,
                 if pred == labels[0] { "ok" } else { "MISS" });
    }
    println!("{correct}/{n} correct");
    Ok(())
}

#[cfg(feature = "pjrt")]
fn pjrt_exec(
    args: &Args,
    nid: &Network<IntegerDeployable>,
) -> Result<Arc<dyn Executor>> {
    use nemo::model::artifact_args::synthnet_id_args;
    let rt = nemo::runtime::Runtime::new(artifacts_dir())?;
    let base_args = synthnet_id_args(nid.deployed())?;
    let kind = args.str_or("kind", "id_fwd_xla");
    Ok(Arc::new(nemo::exec::PjrtExecutor::load(&rt, &kind, base_args)?))
}

#[cfg(not(feature = "pjrt"))]
fn pjrt_exec(
    _args: &Args,
    _nid: &Network<IntegerDeployable>,
) -> Result<Arc<dyn Executor>> {
    bail!(
        "this binary was built without the `pjrt` feature; rebuild with \
         `--features pjrt` or use `--backend native`"
    )
}

fn cmd_serve(args: &Args) -> Result<()> {
    let cfg = ServerConfig {
        max_batch: args.usize_or("max-batch", 16)?,
        batch_timeout: Duration::from_micros(args.usize_or("timeout-us", 500)? as u64),
        n_workers: args.usize_or("workers", 2)?,
    };
    let backend = args.str_or("backend", "native");

    // `--model [name=]m.nemo.json` (repeatable) serves saved deployment
    // artifacts directly: no checkpoint, no training, no transform
    // pipeline — the artifacts ARE the models. Otherwise deploy from a
    // checkpoint (or a fresh init) and serve it as "synthnet".
    let model_flags = args.str_all("model");
    let mut builder = Server::builder().default_config(cfg);
    let mut names: Vec<String> = Vec::new();
    if !model_flags.is_empty() {
        if backend != "native" {
            bail!(
                "serve --model serves the native integer engine; drop \
                 --backend or use --backend native"
            );
        }
        for spec in model_flags {
            let (name, path) = model_spec(spec);
            println!("loading deployment artifact {path} as '{name}'");
            builder = builder.model_from_artifact(&name, &path);
            names.push(name);
        }
    } else {
        let mut rng = Rng::new(7);
        let net = load_or_init_net(args, &mut rng)?;
        let nid = deploy_from_args(args, &net)?;
        let exec: Arc<dyn Executor> = match backend.as_str() {
            // The in-process integer engine: no artifacts, no FFI.
            "native" => nid.to_shared_executor(cfg.max_batch)?,
            "pjrt" => pjrt_exec(args, &nid)?,
            b => bail!("unknown backend '{b}' (expected native|pjrt)"),
        };
        builder = builder.model("synthnet", exec);
        names.push("synthnet".to_string());
    }

    let server = builder.start()?;
    let h = server.handle();
    for info in h.list_models() {
        println!(
            "model '{}' v{}  backend={}  input={:?}  max_batch={}  [{}]",
            info.name,
            info.version,
            info.backend,
            info.input_shape,
            info.max_batch,
            info.provenance
        );
    }

    // `--listen ADDR`: expose the coordinator over the wire protocol
    // and stay up until a signal, instead of the self-driving load test.
    if let Some(listen) = args.str_opt("listen") {
        return serve_remote(args, server, listen);
    }

    let shutdown = nemo::net::shutdown_flag();
    let n_requests = args.usize_or("requests", 512)?;
    let n_clients = args.usize_or("clients", 8)?.max(1);
    // Integer truncation: each client issues `per` requests, so the
    // reachable total is per * n_clients, not n_requests — the swap
    // trigger below must wait on the former or it would never fire.
    let per = n_requests / n_clients;
    println!(
        "serving {} model(s): {} requests, {n_clients} clients, {:?}",
        names.len(),
        per * n_clients,
        cfg
    );

    let t0 = Instant::now();
    // Optional hot swap mid-run: `--swap name=path.nemo.json` re-deploys
    // an artifact onto the *running* server once roughly half the
    // traffic has completed — the zero-downtime rollout the registry
    // exists for.
    let swap_join = args.str_opt("swap").map(|spec| {
        let spec = spec.to_string();
        let h = server.handle();
        let names = names.clone();
        let half = ((per * n_clients) / 2) as u64;
        let shutdown = shutdown.clone();
        std::thread::spawn(move || -> Result<()> {
            let Some((name, path)) = spec.split_once('=') else {
                bail!("--swap expects name=path.nemo.json, got '{spec}'");
            };
            loop {
                // An interrupted load test may never reach the halfway
                // trigger — bail out instead of spinning forever.
                if shutdown.is_set() {
                    return Ok(());
                }
                let done: u64 = names
                    .iter()
                    .map(|n| {
                        h.model_metrics(n).map(|m| m.completed + m.failed).unwrap_or(0)
                    })
                    .sum();
                if done >= half {
                    break;
                }
                std::thread::sleep(Duration::from_millis(1));
            }
            let t = Instant::now();
            let version = h.swap_model_from_artifact(name, path)?;
            println!(
                "hot swap: '{name}' <- {path} now v{version} \
                 (swap took {:.2} ms; in-flight batches finished on the old executor)",
                t.elapsed().as_secs_f64() * 1e3
            );
            Ok(())
        })
    });

    let mut joins = Vec::new();
    for c in 0..n_clients {
        let h = server.handle();
        let model = names[c % names.len()].clone();
        let shutdown = shutdown.clone();
        joins.push(std::thread::spawn(move || -> Result<usize> {
            let mut data = SynthDigits::new(1000 + c as u64);
            let mut ok = 0;
            for _ in 0..per {
                // SIGINT/SIGTERM: stop submitting; in-flight batches
                // drain through Server::stop() below and the aggregate
                // metrics still print instead of dying mid-batch.
                if shutdown.is_set() {
                    break;
                }
                let (x, labels) = data.batch(1);
                let qx = quantize_input(&x, EPS_IN);
                let out = h.infer(&model, qx)?;
                if out.argmax_rows()[0] == labels[0] {
                    ok += 1;
                }
            }
            Ok(ok)
        }));
    }
    let mut correct = 0;
    for j in joins {
        correct += j.join().unwrap()?;
    }
    if let Some(j) = swap_join {
        j.join().unwrap()?;
    }
    let wall = t0.elapsed().as_secs_f64();

    // Stop first: joining the workers makes the ledgers final, so the
    // per-model reports below cannot race the last batch's accounting.
    // (The handle stays usable for registry reads after stop.)
    let mut metrics = server.stop();
    if names.len() > 1 {
        for name in &names {
            let mut m = h.model_metrics(name)?;
            println!("-- model '{name}'\n{}", m.report());
        }
    }
    println!("{}", metrics.report());
    println!(
        "wall {:.3}s  throughput {:.0} req/s  argmax-vs-label agreement {:.1}%",
        wall,
        metrics.throughput(wall),
        100.0 * correct as f64 / (per * n_clients).max(1) as f64
    );
    Ok(())
}

/// `nemo serve --listen ADDR`: expose the running coordinator over the
/// wire protocol and block until SIGINT/SIGTERM, then drain — the
/// socket layer stops accepting and finishes in-flight frames, the
/// coordinator finishes in-flight batches, and the aggregate metrics
/// print on the way out.
fn serve_remote(args: &Args, server: Server, listen: &str) -> Result<()> {
    use nemo::net::{shutdown_flag, NetConfig, NetServer};

    let shutdown = shutdown_flag();
    let net_cfg = NetConfig {
        handler_threads: args.usize_or("net-threads", 8)?.max(1),
        ..NetConfig::default()
    };
    let ns = NetServer::bind(listen, server.handle(), net_cfg)
        .with_context(|| format!("binding wire-protocol listener on {listen}"))?;
    let addr = ns.local_addr();
    println!("listening on {addr} (wire protocol v{})", nemo::net::WIRE_VERSION);
    // `--listen 127.0.0.1:0` binds an OS-assigned port; `--port-file F`
    // publishes it so scripts (CI's e2e step) can find the server.
    if let Some(pf) = args.str_opt("port-file") {
        std::fs::write(pf, addr.port().to_string())
            .with_context(|| format!("writing port file {pf}"))?;
        println!("port -> {pf}");
    }
    println!("serving until SIGINT/SIGTERM ...");
    while !shutdown.is_set() {
        std::thread::sleep(Duration::from_millis(50));
    }
    println!("signal received: draining in-flight requests ...");
    ns.stop(); // socket layer first: replies for in-flight frames go out
    let mut metrics = server.stop(); // then the coordinator's batches
    println!("{}", metrics.report());
    println!("shutdown complete");
    Ok(())
}

/// `nemo client <action>`: drive a remote `nemo serve --listen` server.
fn cmd_client(args: &Args) -> Result<()> {
    use nemo::net::{ClientConfig, NemoClient};

    let addr = args.str_or("addr", "127.0.0.1:7070");
    let action = args.action.as_deref().unwrap_or("");
    if action.is_empty() {
        bail!("client needs an action: nemo client <ping|list|metrics|infer|swap|load|unload>");
    }
    let mut client = NemoClient::connect_with(&addr, ClientConfig::default())
        .with_context(|| format!("connecting to {addr}"))?;
    match action {
        "ping" => {
            let t = Instant::now();
            client.ping()?;
            println!("pong from {addr} in {:.3} ms", t.elapsed().as_secs_f64() * 1e3);
        }
        "list" => {
            for m in client.list_models()? {
                println!(
                    "model '{}' v{}  backend={}  input={:?}  max_batch={}  [{}]",
                    m.name, m.version, m.backend, m.input_shape, m.max_batch, m.provenance
                );
            }
        }
        "metrics" => {
            let name = require_model(args, "metrics")?;
            println!("{}", client.model_metrics(&name)?.report());
        }
        "infer" => {
            let name = require_model(args, "infer")?;
            let inputs = client_inputs(args)?;
            let deadline = args.usize_or("deadline-us", 0)?;
            let outs: Vec<nemo::tensor::TensorI> = if args.bool("pipeline") {
                client.infer_pipelined(&name, &inputs)?
            } else {
                inputs
                    .iter()
                    .map(|qx| match deadline {
                        0 => client.infer(&name, qx),
                        us => client.infer_deadline(
                            &name,
                            qx,
                            Duration::from_micros(us as u64),
                        ),
                    })
                    .collect::<Result<_>>()?
            };
            // Deterministic, diff-able output: CI asserts these lines
            // are bit-identical across a hot swap of the same artifact.
            for (i, out) in outs.iter().enumerate() {
                println!("logits[{i}] = {:?}", out.data());
                println!("pred[{i}] = {}", out.argmax_rows()[0]);
            }
        }
        "swap" => {
            let (name, path) = model_spec(&require_model(args, "swap")?);
            let version = client.swap_model(&name, &path)?;
            println!("swapped '{name}' <- {path}: now v{version}");
        }
        "load" => {
            let (name, path) = model_spec(&require_model(args, "load")?);
            let version = client.load_model(&name, &path)?;
            println!("loaded '{name}' <- {path}: v{version}");
        }
        "unload" => {
            let name = require_model(args, "unload")?;
            client.unload_model(&name)?;
            println!("unloaded '{name}'");
        }
        other => bail!(
            "unknown client action '{other}' \
             (expected ping|list|metrics|infer|swap|load|unload)"
        ),
    }
    Ok(())
}

fn require_model(args: &Args, action: &str) -> Result<String> {
    args.str_opt("model")
        .map(|s| s.to_string())
        .ok_or_else(|| anyhow::anyhow!("client {action} needs --model"))
}

/// Inputs for `client infer`: either `--input qx.json` (a JSON nested
/// array holding one `[1, ...]` integer image, as produced by
/// quantizing with `eps_in`) or `--n` synthetic samples from the
/// deterministic SynthDigits stream at `--seed`.
fn client_inputs(args: &Args) -> Result<Vec<nemo::tensor::TensorI>> {
    if let Some(path) = args.str_opt("input") {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading --input {path}"))?;
        let v = nemo::util::json::parse(&text)
            .with_context(|| format!("parsing --input {path}"))?;
        let (data, shape) = v
            .as_i32_tensor()
            .with_context(|| format!("--input {path}: expected a nested integer array"))?;
        if shape.first() != Some(&1) {
            bail!(
                "--input {path}: expected a [1, ...] single-sample image, \
                 got shape {shape:?}"
            );
        }
        return Ok(vec![nemo::tensor::Tensor::from_vec(&shape, data)]);
    }
    let n = args.usize_or("n", 1)?.max(1);
    let mut data = SynthDigits::new(args.usize_or("seed", 5)? as u64);
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        let (x, _labels) = data.batch(1);
        out.push(quantize_input(&x, EPS_IN));
    }
    Ok(out)
}

fn cmd_validate(_args: &Args) -> Result<()> {
    let dir = artifacts_dir();
    let g = Goldens::load(&dir).context("goldens")?;
    // spot-check the cross-language contract (full suite: cargo test)
    let qx = g.tensor_i32(&["model_case", "qx"])?;
    let want = g.tensor_i32(&["model_case", "id_qlogits"])?;
    // rebuild the net from goldens and deploy through the typed pipeline
    let ck_net = {
        let p = |name: &str| g.tensor_f32(&["model_case", "params", name]).unwrap();
        let v = |name: &str| {
            g.walk(&["model_case", "params", name])
                .unwrap()
                .as_f64_tensor()
                .unwrap()
                .0
        };
        let s = |name: &str| {
            g.walk(&["model_case", "bn_state", name])
                .unwrap()
                .as_f64_tensor()
                .unwrap()
                .0
        };
        SynthNet {
            convs: vec![
                (p("conv1.w"), v("conv1.bn_gamma"), v("conv1.bn_beta")),
                (p("conv2.w"), v("conv2.bn_gamma"), v("conv2.bn_beta")),
                (p("conv3.w"), v("conv3.bn_gamma"), v("conv3.bn_beta")),
            ],
            bn_state: vec![
                (s("conv1.bn_mu"), s("conv1.bn_var")),
                (s("conv2.bn_mu"), s("conv2.bn_var")),
                (s("conv3.bn_mu"), s("conv3.bn_var")),
            ],
            fc_w: p("fc.w"),
            fc_b: v("fc.b"),
            act_betas: g.walk(&["model_case", "act_betas"])?.as_f64_tensor()?.0,
        }
    };
    let nid = ck_net
        .to_network(8)?
        .deploy(DeployOptions::default())?
        .integerize();
    let got = nid.run(&qx);
    if got.data() != want.data() {
        bail!("integer engine diverges from python golden");
    }
    println!("integer engine vs python golden: bit-exact ✓");

    #[cfg(feature = "pjrt")]
    {
        use nemo::model::artifact_args::synthnet_id_args;
        let rt = nemo::runtime::Runtime::new(&dir)?;
        let exe = rt.load("synthnet_id_fwd_b2")?;
        let mut a = synthnet_id_args(nid.deployed())?;
        a.push(qx.into());
        let outs = exe.run(&a)?;
        if outs[0].as_i32()?.data() != want.data() {
            bail!("PJRT artifact diverges from python golden");
        }
        println!("PJRT (Pallas) vs python golden:  bit-exact ✓");
    }
    #[cfg(not(feature = "pjrt"))]
    println!("PJRT check skipped (built without the `pjrt` feature)");
    println!("validation OK");
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    // `nemo info --model m.nemo.json` (repeatable): inspect native
    // deployment artifacts without serving them — format/version,
    // checksum, layer/precision tables, requant params, input shape.
    let models = args.str_all("model");
    if !models.is_empty() {
        for (i, path) in models.iter().enumerate() {
            if i > 0 {
                println!();
            }
            info_artifact(path)?;
        }
        return Ok(());
    }
    #[cfg(feature = "pjrt")]
    {
        let rt = nemo::runtime::Runtime::new(artifacts_dir())?;
        println!("platform: {}", rt.platform());
    }
    #[cfg(not(feature = "pjrt"))]
    println!("platform: native (built without the `pjrt` feature)");
    let manifest = nemo::io::Manifest::load(artifacts_dir())?;
    println!("artifacts ({}):", manifest.artifacts.len());
    for a in &manifest.artifacts {
        println!(
            "  {:<36} kind={:<9} args={:<2} outs={}",
            a.name,
            a.kind,
            a.args.len(),
            a.n_outputs
        );
    }
    Ok(())
}

fn cmd_check(args: &Args) -> Result<()> {
    // `nemo check --model m.nemo.json [--model m.nemob ...]`: run the
    // static soundness verifier (interval abstract interpretation,
    // DESIGN.md §Static-verification) over each artifact. Exit status
    // is the gate: nonzero when any artifact has an error finding (or,
    // under --strict, any finding at all).
    let models = args.str_all("model");
    if models.is_empty() {
        bail!("check: pass at least one --model m.nemo.json|m.nemob");
    }
    let as_json = args.bool("json");
    let strict = args.bool("strict");
    let mut errors = 0usize;
    let mut warnings = 0usize;
    for (i, spec) in models.iter().enumerate() {
        let (_, path) = model_spec(spec);
        let art = DeployedArtifact::load(&path)
            .with_context(|| format!("loading deployment artifact {path}"))?;
        let report = nemo::analysis::check_graph(&art.graph);
        errors += report.errors();
        warnings += report.warnings();
        if as_json {
            println!("{}", report.to_json(&path));
        } else {
            if i > 0 {
                println!();
            }
            println!("check {path}");
            let human = report.render_human();
            if !human.is_empty() {
                println!("{human}");
            }
            println!("  {}", report.summary_line());
        }
    }
    if errors > 0 {
        bail!("check failed: {errors} error(s), {warnings} warning(s)");
    }
    if strict && warnings > 0 {
        bail!("check failed under --strict: {warnings} warning(s)");
    }
    Ok(())
}

/// Print everything an operator needs to know about a deployment
/// artifact before routing traffic at it (ROADMAP "Artifact tooling").
fn info_artifact(path: &str) -> Result<()> {
    use nemo::graph::int::IntOp;

    let (art, prov) = DeployedArtifact::load_with_provenance(path)
        .with_context(|| format!("loading deployment artifact {path}"))?;
    println!("artifact {}", prov.path);
    println!(
        "  format v{}  checksum {} (verified)  {} bytes",
        prov.format_version, prov.checksum, prov.bytes
    );
    // Binary containers additionally expose their section table and how
    // the on-disk weight bytes compare to the JSON-equivalent encoding.
    if prov.format_version == nemo::io::artifact::BIN_VERSION as i64 {
        let info = nemo::io::binary_info(path)
            .with_context(|| format!("reading binary section table of {path}"))?;
        println!(
            "  binary container: header {} B, payload base {} B, \
             weight sections {} B raw / {} B aligned",
            info.header_bytes,
            info.payload_base,
            info.weight_bytes,
            info.aligned_weight_bytes
        );
        let json_bytes = nemo::util::json::write(&art.to_json()).len();
        println!(
            "  weight bytes on disk vs JSON-equivalent artifact: {} / {} ({:.2}x smaller file)",
            info.weight_bytes,
            json_bytes,
            json_bytes as f64 / info.file_bytes.max(1) as f64
        );
        println!("  sections ({}):", info.sections.len());
        println!(
            "    {:<4} {:<16} {:>6} {:>10} {:>10}  checksum",
            "idx", "name", "dtype", "offset", "bytes"
        );
        for (i, s) in info.sections.iter().enumerate() {
            println!(
                "    {:<4} {:<16} {:>6} {:>10} {:>10}  {}",
                i, s.name, s.dtype, s.off, s.bytes, s.checksum
            );
        }
    }
    println!(
        "  wbits={} abits={} bn_folded={}  eps_in={:.6e}  eps_out={:.6e}",
        art.meta.wbits,
        art.meta.abits,
        art.meta.bn_folded,
        art.eps_in(),
        art.graph.eps_out
    );
    let input_shape = art.graph.nodes.iter().find_map(|n| match &n.op {
        IntOp::Input { shape, .. } => Some(shape.clone()),
        _ => None,
    });
    match input_shape {
        Some(s) => println!("  input shape (per sample): {s:?}"),
        None => println!("  input shape: <no Input node>"),
    }
    println!("  nodes ({}):", art.graph.nodes.len());
    println!("    {:<16} {:<12} {:>9}", "name", "op", "precision");
    for n in &art.graph.nodes {
        println!("    {:<16} {:<12} {:>9}", n.name, n.op.name(), n.precision.name());
    }
    // One-line soundness verdict next to the section table — the full
    // findings live under `nemo check --model`.
    println!("  check: {}", nemo::analysis::check_graph(&art.graph).summary_line());
    if !art.layers.is_empty() {
        println!("  layers (requant params, paper sec. 3):");
        println!(
            "    {:<10} {:>12} {:>12} {:>4} {:>10} {:>8}",
            "layer", "eps_w", "eps_y", "d", "m", "act_hi"
        );
        for l in &art.layers {
            println!(
                "    {:<10} {:>12.3e} {:>12.3e} {:>4} {:>10} {:>8}",
                l.name, l.eps_w, l.eps_y, l.d, l.m, l.act_hi
            );
        }
    }
    Ok(())
}
