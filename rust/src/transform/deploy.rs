//! The QuantizedDeployable / IntegerDeployable transform (paper sec. 3).
//!
//! Takes a FakeQuantized graph (PACT activations everywhere) and produces
//! BOTH deployment representations in one walk:
//!
//! * a QD float graph — hardened weights, quantized BN (`QuantBn`),
//!   Eq. 10 activations; every tensor value lies on its quantized grid;
//! * an ID integer graph — integer images only, with per-layer
//!   requantization parameters (Eq. 11/13/14), integer BN (Eq. 22) or
//!   exact thresholds (Eq. 19-20), integer AvgPool (Eq. 25) and
//!   requantizing Adds (Eq. 24).
//!
//! The walk also performs the paper's `set_deployment` eps propagation
//! and an integer *range analysis*: worst-case accumulator magnitudes are
//! tracked per node and any i32 overflow aborts the transform — this is
//! the safety contract the Pallas kernels and the integer engine rely on
//! for their checked narrowing.

use super::TransformError;
use crate::graph::int::{IntGraph, IntOp};
use crate::graph::{Graph, NodeId, Op};
use crate::quant::bn::{BnQuant, Thresholds};
use crate::quant::requant::Requant;
use crate::quant::{harden_tensor, max_abs, quantize_tensor, QuantSpec};
use crate::tensor::{Tensor, TensorI};

#[derive(Clone, Copy, Debug)]
pub struct DeployOptions {
    pub wbits: u32,
    pub abits: u32,
    /// BN kappa quantizer bits (sec. 3.4; 8 keeps kappa*phi inside i32).
    pub kappa_bits: u32,
    /// 1/eta for activations (NEMO PACT_IntegerAct default: 16).
    pub requant_factor: u32,
    /// 1/eta for Add branches (NEMO PACT_IntegerAdd default: 256).
    pub add_requant_factor: u32,
    /// Merge BN+act into exact integer thresholds (Eq. 19-20) instead of
    /// IntBn+RequantAct. Paper: best when 2^abits is small.
    pub use_thresholds: bool,
    /// Static d of the integer AvgPool (Eq. 25).
    pub pool_d: u32,
}

impl Default for DeployOptions {
    fn default() -> Self {
        DeployOptions {
            wbits: 8,
            abits: 8,
            kappa_bits: 8,
            requant_factor: 16,
            add_requant_factor: 256,
            use_thresholds: false,
            pool_d: 12,
        }
    }
}

/// Per-layer quantization record (mirrors python deploy.LayerQuant; used
/// for reporting and for assembling PJRT artifact arguments).
#[derive(Clone, Debug)]
pub struct LayerQuant {
    pub name: String,
    pub beta_w: f64,
    pub eps_w: f64,
    pub eps_phi: f64,
    pub eps_kappa: f64,
    pub eps_phi_out: f64,
    pub beta_y: f64,
    pub eps_y: f64,
    pub d: u32,
    pub m: i64,
    pub act_hi: i64,
}

/// Result of the deployment transform.
#[derive(Clone, Debug)]
pub struct Deployed {
    pub qd: Graph,
    pub id: IntGraph,
    pub layers: Vec<LayerQuant>,
    pub eps_out: f64,
    /// Worst-case integer magnitude seen at each ID node (range analysis).
    pub worst_case: Vec<i64>,
    /// Quantum of each ID node's output (diagnostics: real ~ eps * Q).
    pub node_eps: Vec<f64>,
}

#[derive(Clone, Copy, Debug)]
enum ShapeInfo {
    Chw(usize, usize, usize),
    #[allow(dead_code)]
    Flat(usize),
}

/// Node state carried through the eps-propagation walk.
#[derive(Clone, Debug)]
struct NodeState {
    /// quantum of this node's output integer image
    eps: f64,
    /// integer image value bounds (inclusive)
    qmin: i64,
    qmax: i64,
    shape: ShapeInfo,
    id_node: NodeId,
    /// BN parameters still pending a threshold merge
    pending_bn: Option<(crate::quant::bn::BnParams, f64)>,
}

/// The QD/ID transform walk. Crate-private: the public entry point is
/// `network::Network::<FakeQuantized>::deploy`, which makes an
/// un-fake-quantized input graph unrepresentable.
pub(crate) fn deploy_impl(
    g: &Graph,
    opts: DeployOptions,
) -> Result<Deployed, TransformError> {
    g.validate()?;
    let mut qd = Graph::new(g.eps_in);
    let mut id = IntGraph::default();
    let mut layers = Vec::new();
    let mut states: Vec<Option<NodeState>> = vec![None; g.nodes.len()];
    let mut qd_map: Vec<NodeId> = vec![usize::MAX; g.nodes.len()];
    let mut worst_case: Vec<i64> = Vec::new();
    let n_act = (1i64 << opts.abits) - 1;

    for n in &g.nodes {
        let st = match &n.op {
            Op::Input { shape } => {
                let spec = g.input_spec();
                qd_map[n.id] = qd.push(&n.name, n.op.clone(), &[]);
                let id_node = id.push(
                    &n.name,
                    IntOp::Input { shape: shape.clone(), spec },
                    &[],
                );
                let sh = match shape.len() {
                    3 => ShapeInfo::Chw(shape[0], shape[1], shape[2]),
                    1 => ShapeInfo::Flat(shape[0]),
                    d => {
                        let _ = d;
                        return Err(TransformError::Unsupported("deploy", "input rank"));
                    }
                };
                NodeState {
                    eps: spec.eps,
                    qmin: spec.lo,
                    qmax: spec.hi,
                    shape: sh,
                    id_node,
                    pending_bn: None,
                }
            }
            Op::Conv2d { w, bias, stride, pad } => {
                let prev = states[n.inputs[0]].as_ref().unwrap().clone();
                let spec = QuantSpec::weight(max_abs(w), opts.wbits);
                let w_hat = harden_tensor(w, &spec);
                let wq_oihw = quantize_tensor(w, &spec);
                let eps_phi = spec.eps * prev.eps;
                let (co, ci, kh, kw) =
                    (w.shape()[0], w.shape()[1], w.shape()[2], w.shape()[3]);
                // OIHW -> [C_in*KH*KW, C_out] (artifact layout)
                let mut wmat = vec![0i32; ci * kh * kw * co];
                for o in 0..co {
                    for i in 0..ci {
                        for y in 0..kh {
                            for z in 0..kw {
                                wmat[(i * kh * kw + y * kw + z) * co + o] =
                                    wq_oihw.data()[((o * ci + i) * kh + y) * kw + z];
                            }
                        }
                    }
                }
                let wq = Tensor::from_vec(&[ci * kh * kw, co], wmat);
                let bias_q: Option<Vec<i64>> = bias.as_ref().map(|b| {
                    b.iter().map(|v| (v / eps_phi).floor() as i64).collect()
                });
                let b_hat: Option<Vec<f64>> = bias_q
                    .as_ref()
                    .map(|bq| bq.iter().map(|q| *q as f64 * eps_phi).collect());
                // range analysis per output channel
                let (qmin, qmax) =
                    conv_range(&wq, prev.qmin, prev.qmax, bias_q.as_deref());
                check_range(&n.name, qmin, qmax)?;
                let (h, wd) = match prev.shape {
                    ShapeInfo::Chw(_, h, w) => (h, w),
                    _ => return Err(TransformError::Unsupported("deploy", "conv on flat")),
                };
                let oh = (h + 2 * pad - kh) / stride + 1;
                let ow = (wd + 2 * pad - kw) / stride + 1;
                qd_map[n.id] = qd.push(
                    &n.name,
                    Op::Conv2d {
                        w: w_hat,
                        bias: b_hat,
                        stride: *stride,
                        pad: *pad,
                    },
                    &[qd_map[n.inputs[0]]],
                );
                let id_node = id.push(
                    &n.name,
                    IntOp::ConvInt {
                        wq: wq.into(),
                        bias_q,
                        cin: ci,
                        kh,
                        kw,
                        stride: *stride,
                        pad: *pad,
                    },
                    &[prev.id_node],
                );
                layers.push(LayerQuant {
                    name: n.name.clone(),
                    beta_w: max_abs(w),
                    eps_w: spec.eps,
                    eps_phi,
                    eps_kappa: 1.0,
                    eps_phi_out: eps_phi,
                    beta_y: 0.0,
                    eps_y: 0.0,
                    d: 0,
                    m: 0,
                    act_hi: n_act,
                });
                NodeState {
                    eps: eps_phi,
                    qmin,
                    qmax,
                    shape: ShapeInfo::Chw(co, oh, ow),
                    id_node,
                    pending_bn: None,
                }
            }
            Op::Linear { w, bias } => {
                let prev = states[n.inputs[0]].as_ref().unwrap().clone();
                let spec = QuantSpec::weight(max_abs(w), opts.wbits);
                let w_hat = harden_tensor(w, &spec);
                let wq = quantize_tensor(w, &spec);
                let eps_phi = spec.eps * prev.eps;
                let bias_q: Option<Vec<i64>> = bias.as_ref().map(|b| {
                    b.iter().map(|v| (v / eps_phi).floor() as i64).collect()
                });
                let b_hat: Option<Vec<f64>> = bias_q
                    .as_ref()
                    .map(|bq| bq.iter().map(|q| *q as f64 * eps_phi).collect());
                let (qmin, qmax) =
                    linear_range(&wq, prev.qmin, prev.qmax, bias_q.as_deref());
                check_range(&n.name, qmin, qmax)?;
                let fo = w.shape()[1];
                qd_map[n.id] = qd.push(
                    &n.name,
                    Op::Linear { w: w_hat, bias: b_hat },
                    &[qd_map[n.inputs[0]]],
                );
                let id_node = id.push(
                    &n.name,
                    IntOp::LinearInt { wq: wq.into(), bias_q },
                    &[prev.id_node],
                );
                layers.push(LayerQuant {
                    name: n.name.clone(),
                    beta_w: max_abs(w),
                    eps_w: spec.eps,
                    eps_phi,
                    eps_kappa: 1.0,
                    eps_phi_out: eps_phi,
                    beta_y: 0.0,
                    eps_y: 0.0,
                    d: 0,
                    m: 0,
                    act_hi: n_act,
                });
                NodeState {
                    eps: eps_phi,
                    qmin,
                    qmax,
                    shape: ShapeInfo::Flat(fo),
                    id_node,
                    pending_bn: None,
                }
            }
            Op::BatchNorm { bn } => {
                let prev = states[n.inputs[0]].as_ref().unwrap().clone();
                let bq = BnQuant::derive(bn, prev.eps, opts.kappa_bits);
                let kappa_hat: Vec<f64> =
                    bq.kappa_q.iter().map(|q| *q as f64 * bq.eps_kappa).collect();
                let lambda_hat: Vec<f64> = bq
                    .lambda_q
                    .iter()
                    .map(|q| *q as f64 * bq.eps_phi_out)
                    .collect();
                qd_map[n.id] = qd.push(
                    &n.name,
                    Op::QuantBn { kappa_hat, lambda_hat },
                    &[qd_map[n.inputs[0]]],
                );
                // range: kappa*q + lambda, per channel extremes
                let kmax = bq.kappa_q.iter().map(|k| (*k as i64).abs()).max().unwrap_or(0);
                let lmax = bq.lambda_q.iter().map(|l| (*l as i64).abs()).max().unwrap_or(0);
                let w = kmax * prev.qmax.abs().max(prev.qmin.abs()) + lmax;
                check_range(&n.name, -w, w)?;
                if let Some(l) = layers.last_mut() {
                    l.eps_kappa = bq.eps_kappa;
                    l.eps_phi_out = bq.eps_phi_out;
                }
                if opts.use_thresholds {
                    // Defer: the following PactAct will absorb this BN into
                    // exact integer thresholds (Eq. 19-20). ID graph gets
                    // no node here.
                    NodeState {
                        eps: bq.eps_phi_out,
                        qmin: -w,
                        qmax: w,
                        shape: prev.shape,
                        id_node: prev.id_node,
                        pending_bn: Some((bn.clone(), prev.eps)),
                    }
                } else {
                    let eps_phi_out = bq.eps_phi_out;
                    let id_node =
                        id.push(&n.name, IntOp::IntBn { bn: bq }, &[prev.id_node]);
                    NodeState {
                        eps: eps_phi_out,
                        qmin: -w,
                        qmax: w,
                        shape: prev.shape,
                        id_node,
                        pending_bn: None,
                    }
                }
            }
            Op::PactAct { beta, bits } => {
                let prev = states[n.inputs[0]].as_ref().unwrap().clone();
                let bits = if *bits == 0 { opts.abits } else { *bits };
                let hi = (1i64 << bits) - 1;
                let eps_y = beta / hi as f64;
                qd_map[n.id] = qd.push(
                    &n.name,
                    Op::PactAct { beta: *beta, bits },
                    &[qd_map[n.inputs[0]]],
                );
        let mut requant_md: Option<(i64, u32)> = None;
                let id_node = if let Some((bn, eps_phi)) = &prev.pending_bn {
                    let th = Thresholds::derive(bn, *eps_phi, eps_y, hi);
                    id.push(&n.name, IntOp::ThreshAct { th }, &[prev.id_node])
                } else {
                    let rq = Requant::derive(prev.eps, eps_y, opts.requant_factor, 0, hi)
                        .map_err(|source| TransformError::RequantSaturated {
                            node: n.name.clone(),
                            source,
                        })?;
                    requant_md = Some((rq.m, rq.d));
                    // The requant product m*q is computed in i128 by
                    // Requant::apply, so no product-width check is needed
                    // here — choose_d saturation (above) is the only way
                    // a requant can go wrong at deploy time.
                    if let Some(l) = layers.last_mut() {
                        l.beta_y = *beta;
                        l.eps_y = eps_y;
                        l.d = rq.d;
                        l.m = rq.m;
                        l.act_hi = hi;
                    }
                    id.push(&n.name, IntOp::RequantAct { rq }, &[prev.id_node])
                };
                // Propagate the REALIZED output quantum. The requant
                // multiplier approximates eps_a/eps_y by m/2^d, so the
                // integer image actually carries eps_eff = eps_a*2^d/m,
                // not the nominal eps_y (equal when thresholds are used —
                // they are exact). Propagating eps_eff removes the
                // systematic per-layer scale error (up to eta) that would
                // otherwise compound; the paper leaves this bookkeeping
                // to the deployment backend (sec. 3.2/3.4 notes).
                let eps_eff = match requant_md {
                    None => eps_y, // thresholds are exact
                    Some((m, d)) => prev.eps * (1u64 << d) as f64 / m as f64,
                };
                if let Some(l) = layers.last_mut() {
                    if prev.pending_bn.is_some() {
                        l.beta_y = *beta;
                        l.eps_y = eps_y;
                        l.act_hi = hi;
                    }
                }
                NodeState {
                    eps: eps_eff,
                    qmin: 0,
                    qmax: hi,
                    shape: prev.shape,
                    id_node,
                    pending_bn: None,
                }
            }
            Op::ReLU => return Err(TransformError::NeedsFakeQuant("ReLU")),
            Op::QuantBn { .. } => {
                return Err(TransformError::Unsupported("deploy", "QuantBn input"))
            }
            Op::MaxPool { k } => {
                let prev = states[n.inputs[0]].as_ref().unwrap().clone();
                qd_map[n.id] =
                    qd.push(&n.name, Op::MaxPool { k: *k }, &[qd_map[n.inputs[0]]]);
                let id_node =
                    id.push(&n.name, IntOp::MaxPoolInt { k: *k }, &[prev.id_node]);
                let shape = pool_shape(prev.shape, *k)?;
                NodeState { shape, id_node, pending_bn: None, ..prev }
            }
            Op::AvgPool { .. } | Op::GlobalAvgPool => {
                let prev = states[n.inputs[0]].as_ref().unwrap().clone();
                let k = match &n.op {
                    Op::AvgPool { k } => *k,
                    _ => match prev.shape {
                        ShapeInfo::Chw(_, h, w) => {
                            if h != w {
                                return Err(TransformError::Unsupported(
                                    "deploy",
                                    "global pool on non-square",
                                ));
                            }
                            h
                        }
                        _ => {
                            return Err(TransformError::Unsupported(
                                "deploy",
                                "global pool on flat",
                            ))
                        }
                    },
                };
                qd_map[n.id] = qd.push(&n.name, n.op.clone(), &[qd_map[n.inputs[0]]]);
                let mut id_node = id.push(
                    &n.name,
                    IntOp::AvgPoolInt { k, d: opts.pool_d },
                    &[prev.id_node],
                );
                // sum of k*k values then ~/k^2: range preserved (slightly
                // shrunk by the floor); worst case during accumulation:
                let acc = prev.qmax.abs().max(prev.qmin.abs()) * (k * k) as i64;
                check_range(&n.name, -acc, acc)?;
                // Realized quantum after the Eq. 25 scaling: the ideal
                // 1/K^2 is approximated by m/2^d, so eps scales by
                // m*K^2/2^d (exactly 1 when K^2 divides 2^d).
                let m_pool = (1i64 << opts.pool_d) / (k * k) as i64;
                let eps_eff = prev.eps * (m_pool * (k * k) as i64) as f64
                    / (1i64 << opts.pool_d) as f64;
                let shape = if matches!(n.op, Op::GlobalAvgPool) {
                    // Global pooling flattens [B,C,1,1] -> [B,C]; the float
                    // engine's GlobalAvgPool does this implicitly, so the
                    // ID graph needs an explicit Flatten to match.
                    id_node = id.push(
                        &format!("{}_flatten", n.name),
                        IntOp::Flatten,
                        &[id_node],
                    );
                    match prev.shape {
                        ShapeInfo::Chw(c, _, _) => ShapeInfo::Flat(c),
                        f => f,
                    }
                } else {
                    pool_shape(prev.shape, k)?
                };
                NodeState { shape, id_node, pending_bn: None, eps: eps_eff, ..prev }
            }
            Op::Flatten => {
                let prev = states[n.inputs[0]].as_ref().unwrap().clone();
                qd_map[n.id] = qd.push(&n.name, Op::Flatten, &[qd_map[n.inputs[0]]]);
                let id_node = id.push(&n.name, IntOp::Flatten, &[prev.id_node]);
                let shape = match prev.shape {
                    ShapeInfo::Chw(c, h, w) => ShapeInfo::Flat(c * h * w),
                    f => f,
                };
                NodeState { shape, id_node, pending_bn: None, ..prev }
            }
            Op::Add => {
                // Branch 0 is the reference space (Eq. 24).
                let ref_st = states[n.inputs[0]].as_ref().unwrap().clone();
                let mut rqs = Vec::new();
                let mut qmin = ref_st.qmin;
                let mut qmax = ref_st.qmax;
                let mut id_inputs = vec![ref_st.id_node];
                for &i in &n.inputs[1..] {
                    let bst = states[i].as_ref().unwrap();
                    let rq = Requant::derive(
                        bst.eps,
                        ref_st.eps,
                        opts.add_requant_factor,
                        i32::MIN as i64,
                        i32::MAX as i64,
                    )
                    .map_err(|source| TransformError::RequantSaturated {
                        node: n.name.clone(),
                        source,
                    })?;
                    qmin += rq.apply(bst.qmin).min(rq.apply(bst.qmax));
                    qmax += rq.apply(bst.qmax).max(rq.apply(bst.qmin));
                    rqs.push(rq);
                    id_inputs.push(bst.id_node);
                }
                check_range(&n.name, qmin, qmax)?;
                let qd_inputs: Vec<NodeId> =
                    n.inputs.iter().map(|&i| qd_map[i]).collect();
                qd_map[n.id] = qd.push(&n.name, Op::Add, &qd_inputs);
                let id_node = id.push(&n.name, IntOp::AddRequant { rqs }, &id_inputs);
                NodeState {
                    eps: ref_st.eps,
                    qmin,
                    qmax,
                    shape: ref_st.shape,
                    id_node,
                    pending_bn: None,
                }
            }
        };
        worst_case.push(st.qmax.abs().max(st.qmin.abs()));
        states[n.id] = Some(st);
    }

    // Precision range proof (DESIGN.md §Precision propagation): every ID
    // node was stamped a storage precision at construction (clip bounds,
    // input spec, inheritance, or the I32 accumulator fallback); the
    // analyzed worst-case range must fit the stamp, or the packed kernels
    // would narrow out-of-range values. Natural stamps are sound by
    // construction — this check pins that contract at deploy time.
    for st in states.iter().flatten() {
        let nd = id.node(st.id_node);
        if !nd.precision.contains(st.qmin, st.qmax) {
            return Err(TransformError::PrecisionProof {
                node: nd.name.clone(),
                precision: nd.precision.name(),
                qmin: st.qmin,
                qmax: st.qmax,
            });
        }
    }

    let out_state = states[g.output].as_ref().unwrap();
    qd.output = qd_map[g.output];
    id.output = out_state.id_node;
    id.eps_out = out_state.eps;
    // Per-ID-node eps (diagnostics): fill from node states, then forward-
    // fill helper nodes (e.g. the Flatten inserted after global pooling).
    let mut node_eps = vec![f64::NAN; id.nodes.len()];
    for st in states.iter().flatten() {
        node_eps[st.id_node] = st.eps;
    }
    for i in 1..node_eps.len() {
        if node_eps[i].is_nan() {
            node_eps[i] = node_eps[i - 1];
        }
    }
    // Static soundness gate (DESIGN.md §Static-verification): the
    // abstract interpreter re-proves from the emitted graph what the
    // walk above derived incrementally. Its analysis is at least as
    // tight as deploy's per-node ranges, so a clean deploy never trips
    // it — but any future transform bug that emits an overflowing
    // accumulator or a saturating requant becomes a hard error here
    // instead of a silent wrap on the MCU datapath.
    let report = crate::analysis::check_graph(&id);
    if let Some(f) = report.first_error() {
        return Err(TransformError::Unsound {
            node: f.name.clone(),
            rule: f.rule,
            detail: f.message.clone(),
        });
    }

    Ok(Deployed {
        qd,
        id,
        layers,
        eps_out: out_state.eps,
        worst_case,
        node_eps,
    })
}

fn pool_shape(s: ShapeInfo, k: usize) -> Result<ShapeInfo, TransformError> {
    match s {
        ShapeInfo::Chw(c, h, w) => Ok(ShapeInfo::Chw(c, h / k, w / k)),
        _ => Err(TransformError::Unsupported("deploy", "pool on flat")),
    }
}

fn check_range(node: &str, qmin: i64, qmax: i64) -> Result<(), TransformError> {
    let worst = qmax.abs().max(qmin.abs());
    if worst > i32::MAX as i64 {
        return Err(TransformError::RangeOverflow { node: node.to_string(), worst });
    }
    Ok(())
}

/// Worst-case output range of an integer conv/linear over input range
/// [xlo, xhi]: per output channel, sum per-weight extremes.
fn conv_range(
    wq: &TensorI,
    xlo: i64,
    xhi: i64,
    bias: Option<&[i64]>,
) -> (i64, i64) {
    let (rows, co) = (wq.shape()[0], wq.shape()[1]);
    let mut worst_min = 0i64;
    let mut worst_max = 0i64;
    for oc in 0..co {
        let mut lo = 0i64;
        let mut hi = 0i64;
        for r in 0..rows {
            let w = wq.at2(r, oc) as i64;
            let a = w * xlo;
            let b = w * xhi;
            lo += a.min(b);
            hi += a.max(b);
        }
        if let Some(bq) = bias {
            lo += bq[oc];
            hi += bq[oc];
        }
        worst_min = worst_min.min(lo);
        worst_max = worst_max.max(hi);
    }
    (worst_min, worst_max)
}

fn linear_range(wq: &TensorI, xlo: i64, xhi: i64, bias: Option<&[i64]>) -> (i64, i64) {
    conv_range(wq, xlo, xhi, bias)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{FloatEngine, IntegerEngine};
    use crate::quant::bn::BnParams;
    use crate::quant::quantize_input;
    use crate::tensor::TensorF;
    use crate::transform::{calibrate, quantize_pact_impl};
    use crate::util::rng::Rng;

    /// conv-bn-act -> conv-bn-act -> gap -> flatten -> fc test net.
    fn small_net(rng: &mut Rng) -> Graph {
        let mut g = Graph::new(1.0 / 255.0);
        let x = g.push("in", Op::Input { shape: vec![1, 8, 8] }, &[]);
        let w1 = TensorF::from_vec(
            &[4, 1, 3, 3],
            (0..36).map(|_| rng.normal(0.0, 0.5) as f32).collect(),
        );
        let c1 = g.push("c1", Op::Conv2d { w: w1, bias: None, stride: 1, pad: 1 }, &[x]);
        let bn1 = BnParams {
            gamma: (0..4).map(|_| rng.uniform(0.3, 1.5)).collect(),
            sigma: (0..4).map(|_| rng.uniform(0.3, 1.5)).collect(),
            beta: (0..4).map(|_| rng.normal(0.0, 0.2)).collect(),
            mu: (0..4).map(|_| rng.normal(0.0, 0.2)).collect(),
        };
        let b1 = g.push("bn1", Op::BatchNorm { bn: bn1 }, &[c1]);
        let a1 = g.push("a1", Op::ReLU, &[b1]);
        let w2 = TensorF::from_vec(
            &[8, 4, 3, 3],
            (0..288).map(|_| rng.normal(0.0, 0.3) as f32).collect(),
        );
        let c2 = g.push("c2", Op::Conv2d { w: w2, bias: None, stride: 2, pad: 1 }, &[a1]);
        let bn2 = BnParams {
            gamma: (0..8).map(|_| rng.uniform(0.3, 1.5)).collect(),
            sigma: (0..8).map(|_| rng.uniform(0.3, 1.5)).collect(),
            beta: (0..8).map(|_| rng.normal(0.0, 0.2)).collect(),
            mu: (0..8).map(|_| rng.normal(0.0, 0.2)).collect(),
        };
        let b2 = g.push("bn2", Op::BatchNorm { bn: bn2 }, &[c2]);
        let a2 = g.push("a2", Op::ReLU, &[b2]);
        let p = g.push("gap", Op::GlobalAvgPool, &[a2]);
        let wf = TensorF::from_vec(
            &[8, 5],
            (0..40).map(|_| rng.normal(0.0, 0.5) as f32).collect(),
        );
        g.push("fc", Op::Linear { w: wf, bias: Some(vec![0.1, -0.1, 0.0, 0.2, 0.05]) }, &[p]);
        g
    }

    fn rand_batch(rng: &mut Rng, b: usize) -> TensorF {
        TensorF::from_vec(
            &[b, 1, 8, 8],
            (0..b * 64).map(|_| rng.uniform(0.0, 1.0) as f32).collect(),
        )
    }

    fn pipeline(use_thresholds: bool) -> (Graph, Deployed, TensorF) {
        let mut rng = Rng::new(99);
        let g = small_net(&mut rng);
        let cal = rand_batch(&mut rng, 16);
        let betas = calibrate(&g, &[cal]);
        let fq = quantize_pact_impl(&g, 8, 8, &betas);
        let dep = deploy_impl(
            &fq,
            DeployOptions { use_thresholds, ..DeployOptions::default() },
        )
        .unwrap();
        let x = rand_batch(&mut rng, 4);
        (fq, dep, x)
    }

    #[test]
    fn deploy_rejects_relu() {
        let mut rng = Rng::new(1);
        let g = small_net(&mut rng);
        assert!(matches!(
            deploy_impl(&g, DeployOptions::default()),
            Err(TransformError::NeedsFakeQuant(_))
        ));
    }

    #[test]
    fn qd_close_to_fq_and_id_matches_qd() {
        let (fq, dep, x) = pipeline(false);
        let fe = FloatEngine::new();
        let qx = quantize_input(&x, 1.0 / 255.0);
        let x_grid = qx.map(|q| q as f32 / 255.0);
        let fq_out = fe.run(&fq, &x_grid);
        let qd_out = fe.run(&dep.qd, &x_grid);
        // QD == FQ up to BN quantization (kappa_bits=8) error
        assert!(
            fq_out.max_abs_diff(&qd_out) < 0.25,
            "FQ vs QD diff {}",
            fq_out.max_abs_diff(&qd_out)
        );
        // ID integer output * eps_out tracks QD within requant error
        let ie = IntegerEngine::new();
        let id_out = ie.run(&dep.id, &qx);
        let id_real = id_out.map(|q| (q as f64 * dep.eps_out) as f32);
        assert!(
            qd_out.max_abs_diff(&id_real) < 0.25,
            "QD vs ID diff {}",
            qd_out.max_abs_diff(&id_real)
        );
    }

    #[test]
    fn threshold_variant_agrees_with_requant_variant() {
        let (_, dep_rq, x) = pipeline(false);
        let (_, dep_th, _) = pipeline(true);
        let qx = quantize_input(&x, 1.0 / 255.0);
        let ie = IntegerEngine::new();
        let a = ie.run(&dep_rq.id, &qx);
        let b = ie.run(&dep_th.id, &qx);
        // Thresholds are EXACT; requant has eta<=1/16 error. Outputs are
        // close but not identical; argmax must agree.
        assert_eq!(a.shape(), b.shape());
        assert_eq!(a.argmax_rows(), b.argmax_rows());
        // threshold path drops IntBn nodes
        assert!(dep_th.id.nodes.len() < dep_rq.id.nodes.len());
    }

    #[test]
    fn eps_out_is_product_of_quanta() {
        let (_, dep, _) = pipeline(false);
        let last = dep.layers.last().unwrap();
        // fc: eps_out = eps_w_fc * eps_x(last act)
        assert!((dep.eps_out - last.eps_phi).abs() < 1e-15);
    }

    #[test]
    fn requant_saturation_is_a_deploy_error() {
        // eps_phi ~ 3e-7 against eps_y ~ 4e6: Eq. 14 needs d > 40, so the
        // requant cannot meet the 1/16 error guarantee. The old choose_d
        // silently returned d = 40 and baked the wrong (m, d) into the
        // graph; deploy must reject the network instead.
        let mut g = Graph::new(1.0 / 255.0);
        let x = g.push("in", Op::Input { shape: vec![4] }, &[]);
        let w = TensorF::full(&[4, 4], 0.01);
        let l = g.push("fc", Op::Linear { w, bias: None }, &[x]);
        g.push("act", Op::PactAct { beta: 1e9, bits: 8 }, &[l]);
        match deploy_impl(&g, DeployOptions::default()) {
            Err(TransformError::RequantSaturated { node, .. }) => {
                assert_eq!(node, "act");
            }
            other => panic!("expected RequantSaturated, got {:?}", other.err()),
        }
    }

    #[test]
    fn range_analysis_flags_overflow() {
        // A pathological net: huge weights * deep accumulation at 8 bits
        // input 255 -> conv with 2^20-ish integer weights would overflow.
        let mut g = Graph::new(1.0 / 255.0);
        let x = g.push("in", Op::Input { shape: vec![64, 8, 8] }, &[]);
        // Weight values all at the max grid point with huge fan-in.
        let w = TensorF::full(&[8, 64, 3, 3], 100.0);
        let c = g.push("c", Op::Conv2d { w, bias: None, stride: 1, pad: 1 }, &[x]);
        g.push("a", Op::PactAct { beta: 1.0, bits: 8 }, &[c]);
        // 64*9 * 127 * 255 = 18.6M fits; make it not fit via 32x scale:
        // use wbits=16 -> |Q_w| up to 32767, acc ~ 4.8e9 > 2^31.
        let err = deploy_impl(&g, DeployOptions { wbits: 16, ..Default::default() });
        assert!(matches!(err, Err(TransformError::RangeOverflow { .. })));
    }

    #[test]
    fn deployed_graphs_pass_the_static_checker() {
        // The deploy-time soundness gate must be a no-op on graphs
        // deploy itself emits — the checker's analysis is tighter than
        // the walk's, so a clean deploy implies a clean report (both
        // requant and threshold variants).
        for use_thresholds in [false, true] {
            let (_, dep, _) = pipeline(use_thresholds);
            let report = crate::analysis::check_graph(&dep.id);
            assert!(
                report.is_sound(),
                "deploy emitted an unsound graph: {}",
                report.render_human()
            );
        }
    }
}
