//! Deployment pipeline study (experiment E3): accuracy of the four
//! representations across weight/activation bit widths, plus the
//! threshold-merge variant (E2's deployment form). Everything goes
//! through the typestate pipeline (`Network<Stage>`).
//!
//!     cargo run --release --example deploy_pipeline [-- --ckpt ck.json]
//!
//! Without a checkpoint this trains nothing — it uses a fixed seed net
//! whose accuracy is low; pass a `nemo train` checkpoint for the real
//! Table-1 analog (examples/e2e_qat.rs automates the whole flow).

use nemo::cli::Args;
use nemo::data::SynthDigits;
use nemo::io::Checkpoint;
use nemo::model::synthnet::{SynthNet, EPS_IN};
use nemo::network::Network;
use nemo::train::{eval_float, eval_integer};
use nemo::transform::DeployOptions;
use nemo::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&std::iter::once("deploy_pipeline".to_string())
        .chain(argv)
        .collect::<Vec<_>>())?;

    let mut rng = Rng::new(9);
    let mut net = match args.str_opt("ckpt") {
        Some(p) => SynthNet::from_checkpoint(&Checkpoint::load(p)?)?,
        None => {
            eprintln!("note: no --ckpt given; using an untrained net");
            SynthNet::init(&mut rng)
        }
    };

    let (eval_x, eval_l) = SynthDigits::eval_set(123, 512);
    let mut cal = SynthDigits::new(77);
    let (cal_x, _) = cal.batch(64);
    let fp = Network::from_graph(net.to_fp_graph())?;
    net.act_betas = fp.calibrate_percentile(&[cal_x], 0.995);

    let fp_acc = eval_float(fp.graph(), &eval_x, &eval_l);
    println!("\nE3: accuracy across representations (512 eval samples)");
    println!("{:<18} {:>8} {:>8} {:>8} {:>8}", "bits (W/A)", "FP", "FQ", "QD", "ID");
    for bits in [8u32, 4, 2] {
        // FQ with weights hardened up front (the QAT-style forward pass).
        let fq_h = Network::from_graph(net.to_fp_graph())?
            .quantize_pact(bits, bits, &net.act_betas)?;
        let fq_acc = eval_float(fq_h.graph(), &eval_x, &eval_l);
        // Deployment path: FQ (unhardened, bit-exact with the Python
        // reference) -> QD -> ID.
        let qd = net.to_network(bits)?.deploy(DeployOptions {
            wbits: bits,
            abits: bits,
            ..DeployOptions::default()
        })?;
        let qd_acc = eval_float(qd.graph(), &eval_x, &eval_l);
        let id = qd.integerize();
        let id_acc = eval_integer(id.int_graph(), &eval_x, &eval_l, EPS_IN);
        println!(
            "{:<18} {:>7.1}% {:>7.1}% {:>7.1}% {:>7.1}%",
            format!("{bits}/{bits}"),
            fp_acc * 100.0,
            fq_acc * 100.0,
            qd_acc * 100.0,
            id_acc * 100.0
        );
    }

    // Threshold-merge deployment (sec. 3.4): exact BN+act, no IntBn.
    println!("\nE2 deployment form: threshold-merged BN+activation");
    for bits in [4u32, 2] {
        let id = net
            .to_network(bits)?
            .deploy(DeployOptions {
                wbits: bits,
                abits: bits,
                use_thresholds: true,
                ..DeployOptions::default()
            })?
            .integerize();
        let id_acc = eval_integer(id.int_graph(), &eval_x, &eval_l, EPS_IN);
        let n_th: usize = id
            .int_graph()
            .nodes
            .iter()
            .filter(|n| matches!(n.op, nemo::graph::int::IntOp::ThreshAct { .. }))
            .count();
        println!(
            "  {bits}/{bits} bits: ID-thresholds accuracy {:>5.1}%  ({n_th} threshold acts, {} thresholds/channel)",
            id_acc * 100.0,
            (1u32 << bits) - 1
        );
    }
    Ok(())
}
