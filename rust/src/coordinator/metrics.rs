//! Serving metrics: latency distributions, batch-size mix, counters.

use crate::util::stats::Samples;

#[derive(Clone, Debug, Default)]
pub struct Metrics {
    /// seconds each request waited in the batcher queue
    pub queue_wait: Samples,
    /// seconds per executable invocation
    pub exec_time: Samples,
    /// request end-to-end seconds (enqueue -> reply)
    pub e2e_latency: Samples,
    /// real (unpadded) samples per dispatched batch
    pub batch_sizes: Samples,
    pub completed: u64,
    pub failed: u64,
    /// padding waste (samples executed but discarded)
    pub padded: u64,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// Human-readable multi-line report.
    pub fn report(&mut self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "completed={} failed={} padded={}\n",
            self.completed, self.failed, self.padded
        ));
        out.push_str(&format!("queue_wait  (s): {}\n", self.queue_wait.summary()));
        out.push_str(&format!("exec_time   (s): {}\n", self.exec_time.summary()));
        out.push_str(&format!("e2e_latency (s): {}\n", self.e2e_latency.summary()));
        out.push_str(&format!(
            "batch size: mean={:.2} p50={:.0}\n",
            self.batch_sizes.mean(),
            self.batch_sizes.percentile(0.5)
        ));
        out
    }

    /// Throughput given a wall-clock window.
    pub fn throughput(&self, wall_secs: f64) -> f64 {
        self.completed as f64 / wall_secs.max(1e-9)
    }

    /// Fold another model's metrics into this one — the aggregate view a
    /// multi-model [`crate::coordinator::Server`] reports at `stop()`.
    /// Counters add; latency/batch distributions concatenate.
    pub fn merge(&mut self, other: &Metrics) {
        self.completed += other.completed;
        self.failed += other.failed;
        self.padded += other.padded;
        self.queue_wait.extend_from(&other.queue_wait);
        self.exec_time.extend_from(&other.exec_time);
        self.e2e_latency.extend_from(&other.e2e_latency);
        self.batch_sizes.extend_from(&other.batch_sizes);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn merge_adds_counters_and_concatenates_samples() {
        let mut a = Metrics::new();
        a.completed = 3;
        a.failed = 1;
        a.e2e_latency.push(0.5);
        let mut b = Metrics::new();
        b.completed = 7;
        b.padded = 2;
        b.e2e_latency.push(1.5);
        a.merge(&b);
        assert_eq!(a.completed, 10);
        assert_eq!(a.failed, 1);
        assert_eq!(a.padded, 2);
        assert_eq!(a.e2e_latency.len(), 2);
        assert!((a.e2e_latency.mean() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn report_renders() {
        let mut m = Metrics::new();
        m.completed = 10;
        m.e2e_latency.push(0.001);
        m.exec_time.push(0.0005);
        m.queue_wait.push(0.0001);
        m.batch_sizes.push(4.0);
        let r = m.report();
        assert!(r.contains("completed=10"));
        assert!(m.throughput(2.0) == 5.0);
    }
}
