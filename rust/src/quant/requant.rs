//! Requantization (Def. 3.1, Eq. 12-14): moving an integer image from one
//! quantized space to another using only an integer multiply and an
//! arithmetic right shift.

use super::Precision;
use crate::tensor::TensorI;

/// Smallest d with eps_a * 2^d >= factor * eps_b (Eq. 14 with
/// eta = 1/factor). Exact doubling loop — identical to
/// quantlib.choose_d so both languages derive the same d.
pub fn choose_d(eps_a: f64, eps_b: f64, requantization_factor: u32) -> u32 {
    assert!(eps_a > 0.0 && eps_b > 0.0, "quanta must be positive");
    const D_MAX: u32 = 40;
    let target = requantization_factor as f64 * eps_b;
    let mut d = 0u32;
    let mut p = eps_a;
    while p < target && d < D_MAX {
        p *= 2.0;
        d += 1;
    }
    d
}

/// m = floor(eps_a * 2^d / eps_b) (Eq. 13).
pub fn multiplier(eps_a: f64, eps_b: f64, d: u32) -> i64 {
    (eps_a * (1u64 << d) as f64 / eps_b).floor() as i64
}

/// Requantization parameters for one space transition.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Requant {
    pub m: i64,
    pub d: u32,
    pub lo: i64,
    pub hi: i64,
}

impl Requant {
    /// Derive (m, d) from the source/target quanta and clip bounds
    /// (Eq. 13-14). `factor` is NEMO's requantization_factor (1/eta):
    /// 16 for activations, 256 for Adds.
    pub fn derive(eps_a: f64, eps_b: f64, factor: u32, lo: i64, hi: i64) -> Self {
        let d = choose_d(eps_a, eps_b, factor);
        Requant { m: multiplier(eps_a, eps_b, d), d, lo, hi }
    }

    /// clip((m * q) >> d, lo, hi). The shift is arithmetic (floor toward
    /// -inf), matching Eq. 13's floor for negative values.
    #[inline]
    pub fn apply(&self, q: i64) -> i64 {
        (((self.m * q) >> self.d) as i64).clamp(self.lo, self.hi)
    }

    /// Requantize a whole integer tensor.
    pub fn apply_tensor(&self, q: &TensorI) -> TensorI {
        q.map(|v| self.apply(v as i64) as i32)
    }

    /// The real-valued ratio this requant approximates.
    pub fn approx_ratio(&self) -> f64 {
        self.m as f64 / (1u64 << self.d) as f64
    }

    /// Storage precision of the requantized output — the clip bounds
    /// [lo, hi] *are* the output's provable value range, so an 8-bit
    /// activation requant ([0, 255]) packs to `U8` while an unclipped
    /// Add-branch requant stays `I32`.
    pub fn output_precision(&self) -> Precision {
        Precision::for_range(self.lo, self.hi)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::prop_check;

    #[test]
    fn eq14_bound_and_minimality() {
        prop_check(500, |rng| {
            let eps_a = (-rng.uniform(2.0, 14.0)).exp2();
            let eps_b = (-rng.uniform(1.0, 10.0)).exp2();
            let factor = [16u32, 64, 256][rng.int(0, 3) as usize];
            let d = choose_d(eps_a, eps_b, factor);
            if d >= 40 {
                return Ok(()); // saturated
            }
            if eps_a * ((1u64 << d) as f64) < factor as f64 * eps_b {
                return Err(format!("bound violated: d={d}"));
            }
            if d > 0 && eps_a * ((1u64 << (d - 1)) as f64) >= factor as f64 * eps_b {
                return Err(format!("not minimal: d={d}"));
            }
            Ok(())
        });
    }

    #[test]
    fn relative_error_bounded_by_eta() {
        // |eps_a/eps_b - m/2^d| / (eps_a/eps_b) <= 1/factor (sec. 3.2)
        prop_check(500, |rng| {
            let eps_a = rng.uniform(1e-7, 1e-1);
            let eps_b = rng.uniform(1e-7, 1e-1);
            let factor = 16u32;
            let d = choose_d(eps_a, eps_b, factor);
            if d >= 40 {
                return Ok(());
            }
            let m = multiplier(eps_a, eps_b, d);
            let ratio = eps_a / eps_b;
            let approx = m as f64 / (1u64 << d) as f64;
            let rel = (ratio - approx).abs() / ratio;
            if rel > 1.0 / factor as f64 + 1e-12 {
                return Err(format!("rel err {rel} > 1/{factor}"));
            }
            Ok(())
        });
    }

    #[test]
    fn arithmetic_shift_floors_negatives() {
        let rq = Requant { m: 1, d: 8, lo: -100, hi: 100 };
        assert_eq!(rq.apply(-1), -1);
        assert_eq!(rq.apply(-256), -1);
        assert_eq!(rq.apply(-257), -2);
        assert_eq!(rq.apply(255), 0);
        assert_eq!(rq.apply(256), 1);
    }

    #[test]
    fn requant_approximates_ideal_scaling() {
        // RQ(q) ~ q * eps_a/eps_b within |q|/D + 1 (sec. 3.2 error bound).
        prop_check(300, |rng| {
            let eps_a = rng.uniform(1e-6, 1e-2);
            let eps_b = rng.uniform(1e-4, 1e-1);
            let rq = Requant::derive(eps_a, eps_b, 16, i64::MIN, i64::MAX);
            let q = rng.int(-(1 << 24), 1 << 24);
            let got = rq.apply(q) as f64;
            let ideal = q as f64 * eps_a / eps_b;
            let bound = (q.abs() as f64) / (1u64 << rq.d) as f64 + 1.0;
            if (got - ideal).abs() > bound {
                return Err(format!(
                    "ideal {ideal} got {got} bound {bound} (m={} d={})",
                    rq.m, rq.d
                ));
            }
            Ok(())
        });
    }

    #[test]
    fn derive_matches_python_constants() {
        // One pinned case also present in goldens (belt and braces).
        let d = choose_d(3.1e-5, 0.02, 16);
        let m = multiplier(3.1e-5, 0.02, d);
        // 0.02*16/3.1e-5 = 10322.6 -> 2^14 = 16384 -> d = 14
        assert_eq!(d, 14);
        assert_eq!(m, (3.1e-5 * 16384.0f64 / 0.02) as i64);
    }
}
