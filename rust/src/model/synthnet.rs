//! SynthNet: the Rust twin of `python/compile/model.py`.
//!
//! Layer hyper-parameters are duplicated as constants and asserted
//! against the artifact manifest at load time (io::Manifest carries the
//! Python-side arch dict). Parameter *order* matters: the flat lists fed
//! to the PJRT artifacts follow `param_spec()` exactly.

use anyhow::{ensure, Result};

use crate::graph::{Graph, Op};
use crate::io::Checkpoint;
use crate::network::{FakeQuantized, Network};
use crate::quant::bn::BnParams;
use crate::transform::TransformError;
use crate::tensor::{Tensor, TensorF};
use crate::util::rng::Rng;

pub const BN_EPS: f64 = 1e-5;
pub const EPS_IN: f64 = 1.0 / 255.0;
pub const POOL_K: usize = 4;
pub const POOL_D: u32 = 12;
pub const N_CLASSES: usize = 10;
pub const FC_IN: usize = 32;
pub const IN_SHAPE: [usize; 3] = [1, 16, 16];

#[derive(Clone, Copy, Debug)]
pub struct ConvCfg {
    pub name: &'static str,
    pub cin: usize,
    pub cout: usize,
    pub k: usize,
    pub stride: usize,
    pub pad: usize,
}

pub const SYNTHNET_CONVS: [ConvCfg; 3] = [
    ConvCfg { name: "conv1", cin: 1, cout: 8, k: 3, stride: 1, pad: 1 },
    ConvCfg { name: "conv2", cin: 8, cout: 16, k: 3, stride: 2, pad: 1 },
    ConvCfg { name: "conv3", cin: 16, cout: 32, k: 3, stride: 2, pad: 1 },
];

/// Trainable parameters + BN running stats + PACT act betas.
#[derive(Clone, Debug)]
pub struct SynthNet {
    /// per conv: (w OIHW, gamma, beta)
    pub convs: Vec<(TensorF, Vec<f64>, Vec<f64>)>,
    /// per conv: (mu, var) running statistics
    pub bn_state: Vec<(Vec<f64>, Vec<f64>)>,
    pub fc_w: TensorF,
    pub fc_b: Vec<f64>,
    /// PACT clipping bounds, one per activation (trained in FQ mode)
    pub act_betas: Vec<f64>,
}

impl SynthNet {
    /// Random initialization (He-style, gamma ~ 1, var = 1).
    pub fn init(rng: &mut Rng) -> Self {
        let mut convs = Vec::new();
        let mut bn_state = Vec::new();
        for c in SYNTHNET_CONVS {
            convs.push((
                super::rand_w(rng, &[c.cout, c.cin, c.k, c.k]),
                (0..c.cout).map(|_| (rng.normal(1.0, 0.1) as f64).abs()).collect(),
                (0..c.cout).map(|_| rng.normal(0.0, 0.1)).collect(),
            ));
            bn_state.push((vec![0.0; c.cout], vec![1.0; c.cout]));
        }
        SynthNet {
            convs,
            bn_state,
            fc_w: super::rand_w(rng, &[FC_IN, N_CLASSES]),
            fc_b: vec![0.0; N_CLASSES],
            act_betas: vec![4.0; SYNTHNET_CONVS.len()],
        }
    }

    /// Build the FullPrecision inference graph (BN from running stats,
    /// plain ReLU).
    pub fn to_fp_graph(&self) -> Graph {
        self.to_graph(false)
    }

    /// Build the FakeQuantized-style graph with PACT activations at the
    /// stored act_betas (weights are NOT hardened here; `Network::deploy`
    /// derives the weight grids itself).
    pub fn to_pact_graph(&self, abits: u32) -> Graph {
        let mut g = self.to_graph(true);
        let mut i = 0;
        for n in &mut g.nodes {
            if let Op::PactAct { beta, bits } = &mut n.op {
                *beta = self.act_betas[i];
                *bits = abits;
                i += 1;
            }
        }
        g
    }

    /// Enter the typestate pipeline at the FakeQuantized stage: the PACT
    /// graph at the stored (possibly QAT-trained) act betas, ready for
    /// `.deploy(opts)`. Weights are not pre-hardened — deploy derives the
    /// weight grids itself, keeping this path bit-exact with the Python
    /// reference deployment.
    pub fn to_network(&self, abits: u32) -> Result<Network<FakeQuantized>, TransformError> {
        Network::from_pact_graph(self.to_pact_graph(abits))
    }

    fn to_graph(&self, pact: bool) -> Graph {
        let mut g = Graph::new(EPS_IN);
        let mut prev = g.push("in", Op::Input { shape: IN_SHAPE.to_vec() }, &[]);
        for (i, c) in SYNTHNET_CONVS.iter().enumerate() {
            let (w, gamma, beta) = &self.convs[i];
            let (mu, var) = &self.bn_state[i];
            let conv = g.push(
                c.name,
                Op::Conv2d { w: w.clone(), bias: None, stride: c.stride, pad: c.pad },
                &[prev],
            );
            let sigma: Vec<f64> = var.iter().map(|v| (v + BN_EPS).sqrt()).collect();
            let bn = BnParams {
                gamma: gamma.clone(),
                sigma,
                beta: beta.clone(),
                mu: mu.clone(),
            };
            let bnn = g.push(&format!("bn{}", i + 1), Op::BatchNorm { bn }, &[conv]);
            prev = if pact {
                g.push(
                    &format!("act{}", i + 1),
                    Op::PactAct { beta: self.act_betas[i], bits: 8 },
                    &[bnn],
                )
            } else {
                g.push(&format!("act{}", i + 1), Op::ReLU, &[bnn])
            };
        }
        let p = g.push("gap", Op::GlobalAvgPool, &[prev]);
        g.push(
            "fc",
            Op::Linear { w: self.fc_w.clone(), bias: Some(self.fc_b.clone()) },
            &[p],
        );
        g
    }

    /// Flat parameter list in artifact order (python model.param_spec):
    /// conv{i}.w, conv{i}.bn_gamma, conv{i}.bn_beta, ..., fc.w, fc.b.
    pub fn param_list(&self) -> Vec<TensorF> {
        let mut out = Vec::new();
        for (w, gamma, beta) in &self.convs {
            out.push(w.clone());
            out.push(vec_to_tensor(gamma));
            out.push(vec_to_tensor(beta));
        }
        out.push(self.fc_w.clone());
        out.push(vec_to_tensor(&self.fc_b));
        out
    }

    /// Flat BN running-state list (python model.bn_state_spec order).
    pub fn bn_state_list(&self) -> Vec<TensorF> {
        let mut out = Vec::new();
        for (mu, var) in &self.bn_state {
            out.push(vec_to_tensor(mu));
            out.push(vec_to_tensor(var));
        }
        out
    }

    pub fn act_beta_list(&self) -> Vec<TensorF> {
        self.act_betas.iter().map(|b| Tensor::scalar(*b as f32)).collect()
    }

    /// Rebuild from flat lists (the outputs of a PJRT train step).
    pub fn update_from_flat(
        &mut self,
        params: &[TensorF],
        bn_state: &[TensorF],
        act_betas: Option<&[TensorF]>,
    ) -> Result<()> {
        ensure!(params.len() == 3 * self.convs.len() + 2, "param count");
        ensure!(bn_state.len() == 2 * self.convs.len(), "bn state count");
        for (i, c) in self.convs.iter_mut().enumerate() {
            c.0 = params[3 * i].clone();
            c.1 = params[3 * i + 1].data().iter().map(|v| *v as f64).collect();
            c.2 = params[3 * i + 2].data().iter().map(|v| *v as f64).collect();
        }
        self.fc_w = params[params.len() - 2].clone();
        self.fc_b = params[params.len() - 1].data().iter().map(|v| *v as f64).collect();
        for (i, s) in self.bn_state.iter_mut().enumerate() {
            s.0 = bn_state[2 * i].data().iter().map(|v| *v as f64).collect();
            s.1 = bn_state[2 * i + 1].data().iter().map(|v| *v as f64).collect();
        }
        if let Some(betas) = act_betas {
            ensure!(betas.len() == self.act_betas.len(), "beta count");
            for (i, b) in betas.iter().enumerate() {
                self.act_betas[i] = b.data()[0] as f64;
            }
        }
        Ok(())
    }

    // -- checkpointing --------------------------------------------------

    pub fn to_checkpoint(&self) -> Checkpoint {
        let mut ck = Checkpoint::default();
        for (i, c) in SYNTHNET_CONVS.iter().enumerate() {
            let (w, gamma, beta) = &self.convs[i];
            ck.insert_f32(&format!("{}.w", c.name), w);
            ck.insert_f64(&format!("{}.bn_gamma", c.name), &[c.cout], gamma.clone());
            ck.insert_f64(&format!("{}.bn_beta", c.name), &[c.cout], beta.clone());
            let (mu, var) = &self.bn_state[i];
            ck.insert_f64(&format!("{}.bn_mu", c.name), &[c.cout], mu.clone());
            ck.insert_f64(&format!("{}.bn_var", c.name), &[c.cout], var.clone());
        }
        ck.insert_f32("fc.w", &self.fc_w);
        ck.insert_f64("fc.b", &[N_CLASSES], self.fc_b.clone());
        ck.insert_f64(
            "act_betas",
            &[self.act_betas.len()],
            self.act_betas.clone(),
        );
        ck
    }

    pub fn from_checkpoint(ck: &Checkpoint) -> Result<Self> {
        let mut convs = Vec::new();
        let mut bn_state = Vec::new();
        for c in SYNTHNET_CONVS {
            let w = ck.get_f32(&format!("{}.w", c.name))?;
            let (_, gamma) = ck.get_f64(&format!("{}.bn_gamma", c.name))?;
            let (_, beta) = ck.get_f64(&format!("{}.bn_beta", c.name))?;
            convs.push((w, gamma.to_vec(), beta.to_vec()));
            let (_, mu) = ck.get_f64(&format!("{}.bn_mu", c.name))?;
            let (_, var) = ck.get_f64(&format!("{}.bn_var", c.name))?;
            bn_state.push((mu.to_vec(), var.to_vec()));
        }
        let fc_w = ck.get_f32("fc.w")?;
        let (_, fc_b) = ck.get_f64("fc.b")?;
        let (_, act_betas) = ck.get_f64("act_betas")?;
        Ok(SynthNet {
            convs,
            bn_state,
            fc_w,
            fc_b: fc_b.to_vec(),
            act_betas: act_betas.to_vec(),
        })
    }
}

fn vec_to_tensor(v: &[f64]) -> TensorF {
    TensorF::from_f64(&[v.len()], v)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::FloatEngine;

    #[test]
    fn init_and_run() {
        let mut rng = Rng::new(7);
        let net = SynthNet::init(&mut rng);
        let g = net.to_fp_graph();
        g.validate().unwrap();
        let x = Tensor::from_vec(&[2, 1, 16, 16], vec![0.5f32; 512]);
        let out = FloatEngine::new().run(&g, &x);
        assert_eq!(out.shape(), &[2, N_CLASSES]);
    }

    #[test]
    fn checkpoint_roundtrip() {
        let mut rng = Rng::new(8);
        let net = SynthNet::init(&mut rng);
        let ck = net.to_checkpoint();
        let back = SynthNet::from_checkpoint(&ck).unwrap();
        assert_eq!(net.fc_w.data(), back.fc_w.data());
        assert_eq!(net.act_betas, back.act_betas);
        // graphs produce identical outputs
        let x = Tensor::from_vec(&[1, 1, 16, 16], vec![0.3f32; 256]);
        let e = FloatEngine::new();
        let a = e.run(&net.to_fp_graph(), &x);
        let b = e.run(&back.to_fp_graph(), &x);
        assert_eq!(a.data(), b.data());
    }

    #[test]
    fn param_list_order_matches_spec() {
        let mut rng = Rng::new(9);
        let net = SynthNet::init(&mut rng);
        let p = net.param_list();
        assert_eq!(p.len(), 11); // 3 convs x 3 + fc.w + fc.b
        assert_eq!(p[0].shape(), &[8, 1, 3, 3]);
        assert_eq!(p[9].shape(), &[FC_IN, N_CLASSES]);
        assert_eq!(net.bn_state_list().len(), 6);
        assert_eq!(net.act_beta_list().len(), 3);
    }
}
