//! Registry lifecycle integration tests: multi-model serving, runtime
//! load / hot-swap / unload under concurrent traffic, typed errors, and
//! per-model metrics accounting.
//!
//! The atomicity contract under test (DESIGN.md §Serving-registry):
//! requests already batched against the old executor complete on it, new
//! requests route to the replacement, no reply is lost or mis-routed,
//! and the per-model metrics ledger accounts for every request across
//! executor versions.

use std::sync::Arc;
use std::time::{Duration, Instant};

use nemo::coordinator::{
    InferError, Provenance, RegistryError, Server, ServerConfig,
};
use nemo::exec::{Arg, ExecInput, ExecOutput, Executor};
use nemo::model::mlp;
use nemo::network::{IntegerDeployable, Network};
use nemo::quant::quantize_input;
use nemo::tensor::{Tensor, TensorF, TensorI};
use nemo::transform::DeployOptions;
use nemo::util::rng::Rng;

/// Deterministic stub: logits = input + offset. Distinct offsets make
/// mis-routed and torn replies detectable from the reply value alone.
struct OffsetExec {
    offset: i32,
}

impl Executor for OffsetExec {
    fn name(&self) -> &str {
        "offset-stub"
    }

    fn input_shape(&self) -> &[usize] {
        &[2]
    }

    fn max_batch(&self) -> usize {
        8
    }

    fn run_batch(&self, input: &ExecInput) -> anyhow::Result<ExecOutput> {
        let t = input.batch.as_i32()?;
        Ok(ExecOutput { logits: Arg::I32(t.map(|v| v + self.offset)) })
    }
}

/// Stub that takes long enough for a deadline to expire first.
struct SlowExec;

impl Executor for SlowExec {
    fn name(&self) -> &str {
        "slow-stub"
    }

    fn input_shape(&self) -> &[usize] {
        &[2]
    }

    fn max_batch(&self) -> usize {
        8
    }

    fn run_batch(&self, input: &ExecInput) -> anyhow::Result<ExecOutput> {
        std::thread::sleep(Duration::from_millis(150));
        Ok(ExecOutput { logits: input.batch.clone() })
    }
}

fn qx2(a: i32, b: i32) -> TensorI {
    Tensor::from_vec(&[1, 2], vec![a, b])
}

fn fast_cfg() -> ServerConfig {
    ServerConfig {
        max_batch: 8,
        batch_timeout: Duration::from_micros(200),
        n_workers: 2,
    }
}

#[test]
fn duplicate_names_are_typed_at_build_and_at_runtime() {
    // Build time: the old Vec<ModelVariant> API last-wins silently on a
    // HashMap insert; the registry must refuse with a typed error.
    let err = Server::builder()
        .model("m", Arc::new(OffsetExec { offset: 1 }))
        .model("m", Arc::new(OffsetExec { offset: 2 }))
        .start()
        .unwrap_err();
    assert!(matches!(
        err.downcast_ref::<RegistryError>(),
        Some(RegistryError::DuplicateName(n)) if n == "m"
    ));

    // Runtime: load_model on a taken name is the same typed error, and
    // the running model is untouched.
    let server = Server::builder()
        .default_config(fast_cfg())
        .model("m", Arc::new(OffsetExec { offset: 10 }))
        .start()
        .unwrap();
    let h = server.handle();
    let err = h.load_model("m", Arc::new(OffsetExec { offset: 20 })).unwrap_err();
    assert!(matches!(
        err.downcast_ref::<RegistryError>(),
        Some(RegistryError::DuplicateName(_))
    ));
    assert_eq!(h.infer("m", qx2(1, 2)).unwrap().data(), &[11, 12]);
    server.stop();
}

#[test]
fn unknown_and_post_unload_inference_are_typed_errors() {
    let server = Server::builder()
        .default_config(fast_cfg())
        .model("m", Arc::new(OffsetExec { offset: 100 }))
        .start()
        .unwrap();
    let h = server.handle();

    // never registered
    let err = h.infer("ghost", qx2(0, 0)).unwrap_err();
    assert!(matches!(
        err.downcast_ref::<RegistryError>(),
        Some(RegistryError::UnknownModel(n)) if n == "ghost"
    ));

    // load at runtime, serve, unload, serve again
    h.load_model("late", Arc::new(OffsetExec { offset: 7 })).unwrap();
    assert_eq!(h.infer("late", qx2(1, 1)).unwrap().data(), &[8, 8]);
    let names: Vec<String> = h.list_models().into_iter().map(|i| i.name).collect();
    assert_eq!(names, vec!["late", "m"]);

    h.unload_model("late").unwrap();
    let err = h.infer("late", qx2(1, 1)).unwrap_err();
    assert!(matches!(
        err.downcast_ref::<RegistryError>(),
        Some(RegistryError::UnknownModel(n)) if n == "late"
    ));
    // unloading twice is typed too
    let err = h.unload_model("late").unwrap_err();
    assert!(matches!(
        err.downcast_ref::<RegistryError>(),
        Some(RegistryError::UnknownModel(_))
    ));
    // metrics of an unloaded model are gone with the entry
    assert!(h.model_metrics("late").is_err());
    server.stop();
}

#[test]
fn swap_under_concurrent_load_loses_and_misroutes_nothing() {
    // Two models, distinct offsets; "a" hot-swaps 1000 -> 3000 mid-run.
    // Every reply must decode to a legal (model, version) offset, every
    // request must be answered, and the per-model ledgers must account
    // for every request.
    let server = Server::builder()
        .default_config(fast_cfg())
        .model("a", Arc::new(OffsetExec { offset: 1000 }))
        .model("b", Arc::new(OffsetExec { offset: 2000 }))
        .start()
        .unwrap();
    let h = server.handle();

    let per_client = 50usize;
    let mut joins = Vec::new();
    for c in 0..8i32 {
        let h = server.handle();
        let model = if c % 2 == 0 { "a" } else { "b" };
        joins.push(std::thread::spawn(move || -> Result<(), String> {
            for i in 0..per_client as i32 {
                let v = c * 1000 + i;
                let out = h
                    .infer(model, qx2(v, v + 1))
                    .map_err(|e| format!("lost reply on '{model}': {e}"))?;
                let off = out.data()[0] - v;
                let legal: &[i32] =
                    if model == "a" { &[1000, 3000] } else { &[2000] };
                if !legal.contains(&off) || out.data()[1] - (v + 1) != off {
                    return Err(format!(
                        "mis-routed/torn reply on '{model}': input {v} -> {:?}",
                        out.data()
                    ));
                }
            }
            Ok(())
        }));
    }

    // Let traffic flow, then swap "a" under load.
    std::thread::sleep(Duration::from_millis(2));
    let version = h.swap_model("a", Arc::new(OffsetExec { offset: 3000 })).unwrap();
    assert_eq!(version, 2);
    // A request submitted after the swap returned must run on the new
    // executor — the registry routes new requests to the replacement.
    let post = h.infer("a", qx2(5, 6)).unwrap();
    assert_eq!(post.data(), &[3005, 3006], "post-swap requests must hit v2");

    for j in joins {
        j.join().unwrap().unwrap();
    }

    // Versions visible; per-model ledgers account for every request
    // (including across the swap: the name keeps one ledger). Stop the
    // server first — workers record metrics *after* scattering replies,
    // so only joining them (stop) makes the exact counts race-free; the
    // handle's registry reads still work afterwards.
    let infos = h.list_models();
    let a = infos.iter().find(|i| i.name == "a").unwrap();
    let b = infos.iter().find(|i| i.name == "b").unwrap();
    assert_eq!(a.version, 2);
    assert_eq!(b.version, 1);
    let total = server.stop();
    let ma = h.model_metrics("a").unwrap();
    let mb = h.model_metrics("b").unwrap();
    assert_eq!(ma.completed, 4 * per_client as u64 + 1);
    assert_eq!(mb.completed, 4 * per_client as u64);
    assert_eq!(ma.failed + mb.failed, 0);
    assert_eq!(total.completed, 8 * per_client as u64 + 1);
}

fn deployed_mlp(seed: u64) -> Network<IntegerDeployable> {
    let mut rng = Rng::new(seed);
    let g = mlp(&mut rng, 12, 10, 4, 1.0 / 255.0);
    let x = TensorF::from_vec(
        &[8, 12],
        (0..96).map(|_| rng.uniform(0.0, 1.0) as f32).collect(),
    );
    let fp = Network::from_graph(g).unwrap();
    let betas = fp.calibrate(&[x]);
    fp.quantize_pact(8, 8, &betas)
        .unwrap()
        .deploy(DeployOptions::default())
        .unwrap()
        .integerize()
}

#[test]
fn artifact_hot_swap_is_bit_identical_per_version() {
    // Serve net1 in-memory as "m"; mid-traffic, hot-swap "m" to net2's
    // saved artifact. Every reply must be bit-identical to exactly one
    // of the two versions' in-memory networks, and post-swap replies to
    // the new one.
    let net1 = deployed_mlp(51);
    let net2 = deployed_mlp(52);
    let path = std::env::temp_dir()
        .join(format!("nemo_registry_swap_{}.nemo.json", std::process::id()));
    net2.save_deployed(&path).unwrap();

    let server = Server::builder()
        .default_config(fast_cfg())
        .model("m", net1.to_shared_executor(8).unwrap())
        .start()
        .unwrap();
    let h = server.handle();

    let net1 = Arc::new(net1);
    let net2 = Arc::new(net2);
    let mut joins = Vec::new();
    for c in 0..4u64 {
        let h = server.handle();
        let (net1, net2) = (net1.clone(), net2.clone());
        joins.push(std::thread::spawn(move || {
            let mut rng = Rng::new(900 + c);
            for _ in 0..40 {
                let x = TensorF::from_vec(
                    &[1, 12],
                    (0..12).map(|_| rng.uniform(0.0, 1.0) as f32).collect(),
                );
                let qx = quantize_input(&x, 1.0 / 255.0);
                let served = h.infer("m", qx.clone()).unwrap();
                let e1 = net1.run(&qx);
                let e2 = net2.run(&qx);
                assert!(
                    served.data() == e1.data() || served.data() == e2.data(),
                    "reply matches neither version: {:?}",
                    served.data()
                );
            }
        }));
    }

    std::thread::sleep(Duration::from_millis(2));
    let version = h.swap_model_from_artifact("m", &path).unwrap();
    assert_eq!(version, 2);

    for j in joins {
        j.join().unwrap();
    }

    // Post-swap: strictly the new program, bit-identical to net2.
    let mut rng = Rng::new(999);
    for _ in 0..8 {
        let x = TensorF::from_vec(
            &[1, 12],
            (0..12).map(|_| rng.uniform(0.0, 1.0) as f32).collect(),
        );
        let qx = quantize_input(&x, 1.0 / 255.0);
        assert_eq!(h.infer("m", qx.clone()).unwrap().data(), net2.run(&qx).data());
    }

    // Provenance now names the artifact file.
    let info = h.list_models().into_iter().find(|i| i.name == "m").unwrap();
    assert_eq!(info.version, 2);
    match &info.provenance {
        Provenance::Artifact(a) => {
            assert!(a.path.contains("nemo_registry_swap_"), "{}", a.path);
            assert!(a.checksum.starts_with("fnv1a64:"), "{}", a.checksum);
        }
        other => panic!("expected artifact provenance, got {other}"),
    }
    let m = server.stop();
    assert_eq!(m.failed, 0);
    assert_eq!(m.completed, 4 * 40 + 8);
    let _ = std::fs::remove_file(&path);
}

#[test]
fn infer_deadline_and_try_infer_semantics() {
    let server = Server::builder()
        .default_config(ServerConfig {
            max_batch: 2,
            batch_timeout: Duration::from_micros(100),
            n_workers: 1,
        })
        .model("slow", Arc::new(SlowExec))
        .model("fast", Arc::new(OffsetExec { offset: 40 }))
        .start()
        .unwrap();
    let h = server.handle();

    // Deadline shorter than the executor's latency: typed timeout; the
    // request still completes server-side (visible in the ledger later).
    let err = h
        .infer_deadline("slow", qx2(1, 2), Duration::from_millis(5))
        .unwrap_err();
    assert!(matches!(
        err.downcast_ref::<InferError>(),
        Some(InferError::DeadlineExceeded(_))
    ));

    // Generous deadline: normal reply.
    let out = h
        .infer_deadline("fast", qx2(1, 2), Duration::from_secs(10))
        .unwrap();
    assert_eq!(out.data(), &[41, 42]);

    // try_infer returns immediately; the reply arrives via polling.
    let pending = h.try_infer("fast", qx2(7, 8)).unwrap();
    let t0 = Instant::now();
    let out = loop {
        if let Some(r) = pending.try_poll() {
            break r.unwrap();
        }
        assert!(t0.elapsed() < Duration::from_secs(10), "reply never arrived");
        std::thread::sleep(Duration::from_millis(1));
    };
    assert_eq!(out.data(), &[47, 48]);

    // try_infer on an unknown name fails before anything is queued.
    assert!(h.try_infer("ghost", qx2(0, 0)).is_err());

    // The timed-out slow request still executed and was accounted.
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let m = h.model_metrics("slow").unwrap();
        if m.completed == 1 {
            break;
        }
        assert!(Instant::now() < deadline, "slow request never accounted");
        std::thread::sleep(Duration::from_millis(5));
    }
    server.stop();
}

#[test]
fn per_model_config_override_caps_that_models_batches() {
    let server = Server::builder()
        .default_config(fast_cfg())
        .model("tiny", Arc::new(OffsetExec { offset: 5 }))
        .config_for(
            "tiny",
            ServerConfig { max_batch: 2, ..fast_cfg() },
        )
        .start()
        .unwrap();
    let mut joins = Vec::new();
    for c in 0..6i32 {
        let h = server.handle();
        joins.push(std::thread::spawn(move || {
            h.infer("tiny", qx2(c, c)).unwrap()
        }));
    }
    for j in joins {
        j.join().unwrap();
    }
    let m = server.stop();
    assert_eq!(m.completed, 6);
    assert!(
        m.batch_sizes.max() <= 2.0,
        "per-model max_batch override ignored: max gathered batch {}",
        m.batch_sizes.max()
    );
}
