//! Typestate pipeline integration tests: the legal chain FP -> FQ -> QD
//! -> ID across architectures, stage metadata accumulation, and the
//! IntegerDeployable stage plugging into the unified `Executor` backend.
//! Illegal transitions are compile errors — proven by the `compile_fail`
//! doc-tests on `nemo::network`. (The deprecated free-function shims the
//! typed chain was originally diffed against are gone; bit-exactness of
//! the execution paths is now pinned by tests/plan.rs instead.)

use nemo::engine::{FloatEngine, IntegerEngine};
use nemo::exec::{ExecInput, Executor};
use nemo::model::synthnet::{SynthNet, EPS_IN};
use nemo::model::{mlp, residual_net};
use nemo::network::{FakeQuantized, Network};
use nemo::quant::quantize_input;
use nemo::tensor::{Tensor, TensorF};
use nemo::transform::{DeployOptions, TransformError};
use nemo::util::rng::Rng;

fn synth_input(rng: &mut Rng, b: usize) -> TensorF {
    Tensor::from_vec(
        &[b, 1, 16, 16],
        (0..b * 256).map(|_| rng.uniform(0.0, 1.0) as f32).collect(),
    )
}

#[test]
fn typed_chain_reaches_integer_deployable_mlp() {
    let mut rng = Rng::new(51);
    let g = mlp(&mut rng, 32, 24, 10, EPS_IN);
    let x = Tensor::from_vec(
        &[4, 32],
        (0..128).map(|_| rng.uniform(0.0, 1.0) as f32).collect(),
    );

    let fp = Network::from_graph(g).unwrap();
    let betas = fp.calibrate(&[x.clone()]);
    let fq = fp.quantize_pact(8, 8, &betas).unwrap();

    // The FQ stage runs the same graph the engine would.
    let fe = FloatEngine::new();
    assert_eq!(fe.run(fq.graph(), &x).data(), fq.run(&x).data());

    let qd = fq.deploy(DeployOptions::default()).unwrap();
    let id = qd.integerize();

    // QD float twin runs; ID integer output matches a direct engine run.
    let qx = quantize_input(&x, EPS_IN);
    let ie = IntegerEngine::new();
    let direct = ie.run(&id.deployed().id, &qx);
    assert_eq!(direct.data(), id.run(&qx).data());
    assert_eq!(direct.shape(), &[4, 10]);
    assert!(id.eps_out() > 0.0);
}

#[test]
fn typed_chain_records_layer_tables_synthnet() {
    let mut rng = Rng::new(52);
    let net = SynthNet::init(&mut rng);
    let x = synth_input(&mut rng, 8);
    let qx = quantize_input(&x, EPS_IN);

    let nid = net
        .to_network(8)
        .unwrap()
        .deploy(DeployOptions::default())
        .unwrap()
        .integerize();
    let out = nid.run(&qx);
    assert_eq!(out.shape(), &[8, 10]);
    assert!(nid.eps_out() > 0.0);
    // One LayerQuant per Linear operator: 3 convs + 1 fc.
    assert_eq!(nid.layers().len(), 4);
    for l in nid.layers() {
        assert!(l.eps_w > 0.0, "layer {} has no weight quantum", l.name);
        assert!(l.eps_phi > 0.0);
    }
    // eps_out is the quantum of the final (activation-less) fc layer.
    let last = nid.layers().last().unwrap();
    assert_eq!(nid.eps_out().to_bits(), last.eps_phi.to_bits());
}

#[test]
fn typed_fold_bn_preserves_function_and_cannot_repeat() {
    let mut rng = Rng::new(53);
    let net = SynthNet::init(&mut rng);
    let g = net.to_fp_graph();
    let x = synth_input(&mut rng, 4);

    let unfolded_out = FloatEngine::new().run(&g, &x);
    let folded = Network::from_graph(g).unwrap().fold_bn(None).unwrap();
    let folded_out = folded.run(&x);
    assert!(
        unfolded_out.allclose(&folded_out, 1e-4, 1e-4),
        "fold changed the function: max diff {}",
        unfolded_out.max_abs_diff(&folded_out)
    );
    // Folding twice would corrupt weights; the typed pipeline refuses.
    assert!(matches!(
        folded.fold_bn(None),
        Err(TransformError::AlreadyFolded)
    ));
}

#[test]
fn residual_net_flows_through_typed_pipeline() {
    let mut rng = Rng::new(54);
    let g = residual_net(&mut rng, EPS_IN);
    let x = synth_input(&mut rng, 4);
    let fp = Network::from_graph(g).unwrap();
    let betas = fp.calibrate(&[x.clone()]);
    let id = fp
        .quantize_pact(8, 8, &betas)
        .unwrap()
        .deploy(DeployOptions::default())
        .unwrap()
        .integerize();
    let out = id.run(&quantize_input(&x, EPS_IN));
    assert_eq!(out.shape(), &[4, 10]);
}

#[test]
fn from_pact_graph_rejects_full_precision_graphs() {
    let mut rng = Rng::new(55);
    let net = SynthNet::init(&mut rng);
    assert!(matches!(
        Network::<FakeQuantized>::from_pact_graph(net.to_fp_graph()),
        Err(TransformError::NeedsFakeQuant(_))
    ));
}

#[test]
fn native_executor_matches_direct_engine_run() {
    let mut rng = Rng::new(56);
    let net = SynthNet::init(&mut rng);
    let nid = net
        .to_network(8)
        .unwrap()
        .deploy(DeployOptions::default())
        .unwrap()
        .integerize();
    let exec = nid.to_executor(8).unwrap();
    assert_eq!(exec.input_shape(), &[1, 16, 16]);
    // The executor's compiled plan fused every conv/linear epilogue.
    assert!(exec.fused_nodes() > 0);

    let x = synth_input(&mut rng, 4);
    let qx = quantize_input(&x, EPS_IN);
    let out = exec.run_batch(&ExecInput::i32(qx.clone())).unwrap();
    assert_eq!(
        out.int_logits().unwrap().data(),
        nid.run(&qx).data(),
        "Executor and direct engine must agree bit-exactly"
    );
    // Repeated batches reuse pooled arenas; results stay identical.
    let again = exec.run_batch(&ExecInput::i32(qx.clone())).unwrap();
    assert_eq!(
        again.int_logits().unwrap().data(),
        out.int_logits().unwrap().data()
    );
    // Smaller batch variant through the same executor.
    let qx1 = qx.slice_batch(0, 1);
    let one = exec.run_batch(&ExecInput::i32(qx1)).unwrap();
    assert_eq!(
        one.int_logits().unwrap().data(),
        &out.int_logits().unwrap().data()[..10]
    );
}
