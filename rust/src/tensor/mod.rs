//! Dense row-major tensor substrate (S1 in DESIGN.md).
//!
//! The offline vendor set has no `ndarray`, so the engines run on this
//! small, fully-tested implementation. Two element types are used across
//! the crate: `f32` for FullPrecision/FakeQuantized/QuantizedDeployable
//! values and `i32` for IntegerDeployable integer images (with `i64`
//! widening inside the ops that need it, mirroring the Pallas kernels).

pub mod ops;

use std::fmt;

#[derive(Clone, PartialEq)]
pub struct Tensor<T> {
    shape: Vec<usize>,
    data: Vec<T>,
}

pub type TensorF = Tensor<f32>;
pub type TensorI = Tensor<i32>;

impl<T: Copy + Default> Tensor<T> {
    pub fn zeros(shape: &[usize]) -> Self {
        let n: usize = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![T::default(); n] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<T>) -> Self {
        let n: usize = shape.iter().product();
        assert_eq!(n, data.len(), "shape {:?} != data len {}", shape, data.len());
        Tensor { shape: shape.to_vec(), data }
    }

    pub fn full(shape: &[usize], v: T) -> Self {
        let n: usize = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![v; n] }
    }

    pub fn scalar(v: T) -> Self {
        Tensor { shape: vec![], data: vec![v] }
    }

    #[inline]
    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    #[inline]
    pub fn ndim(&self) -> usize {
        self.shape.len()
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    #[inline]
    pub fn data(&self) -> &[T] {
        &self.data
    }

    #[inline]
    pub fn data_mut(&mut self) -> &mut [T] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<T> {
        self.data
    }

    /// Reshape without moving data (total size must match).
    pub fn reshape(&self, shape: &[usize]) -> Self {
        let n: usize = shape.iter().product();
        assert_eq!(n, self.data.len(), "reshape {:?} -> {:?}", self.shape, shape);
        Tensor { shape: shape.to_vec(), data: self.data.clone() }
    }

    pub fn into_reshape(mut self, shape: &[usize]) -> Self {
        let n: usize = shape.iter().product();
        assert_eq!(n, self.data.len());
        self.shape = shape.to_vec();
        self
    }

    #[inline]
    pub fn at2(&self, i: usize, j: usize) -> T {
        debug_assert_eq!(self.ndim(), 2);
        self.data[i * self.shape[1] + j]
    }

    #[inline]
    pub fn at4(&self, n: usize, c: usize, h: usize, w: usize) -> T {
        debug_assert_eq!(self.ndim(), 4);
        let (sc, sh, sw) = (self.shape[1], self.shape[2], self.shape[3]);
        self.data[((n * sc + c) * sh + h) * sw + w]
    }

    #[inline]
    pub fn set4(&mut self, n: usize, c: usize, h: usize, w: usize, v: T) {
        let (sc, sh, sw) = (self.shape[1], self.shape[2], self.shape[3]);
        self.data[((n * sc + c) * sh + h) * sw + w] = v;
    }

    pub fn map<U: Copy + Default>(&self, f: impl Fn(T) -> U) -> Tensor<U> {
        Tensor {
            shape: self.shape.clone(),
            data: self.data.iter().map(|x| f(*x)).collect(),
        }
    }

    /// Batch-slice of a 4-D (NCHW) or 2-D tensor: rows [lo, hi).
    pub fn slice_batch(&self, lo: usize, hi: usize) -> Self {
        assert!(!self.shape.is_empty() && hi <= self.shape[0] && lo <= hi);
        let row: usize = self.shape[1..].iter().product();
        let mut shape = self.shape.clone();
        shape[0] = hi - lo;
        Tensor { shape, data: self.data[lo * row..hi * row].to_vec() }
    }

    /// Concatenate along axis 0.
    pub fn cat_batch(parts: &[&Tensor<T>]) -> Self {
        assert!(!parts.is_empty());
        let inner = &parts[0].shape[1..];
        let mut shape = parts[0].shape.clone();
        shape[0] = parts.iter().map(|p| p.shape[0]).sum();
        let mut data = Vec::with_capacity(shape.iter().product());
        for p in parts {
            assert_eq!(&p.shape[1..], inner, "cat_batch shape mismatch");
            data.extend_from_slice(&p.data);
        }
        Tensor { shape, data }
    }
}

impl Tensor<f32> {
    pub fn from_f64(shape: &[usize], data: &[f64]) -> Self {
        Tensor::from_vec(shape, data.iter().map(|x| *x as f32).collect())
    }

    pub fn allclose(&self, other: &Self, atol: f32, rtol: f32) -> bool {
        self.shape == other.shape
            && self
                .data
                .iter()
                .zip(&other.data)
                .all(|(a, b)| (a - b).abs() <= atol + rtol * b.abs())
    }

    pub fn max_abs_diff(&self, other: &Self) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

impl Tensor<i32> {
    /// Per-row argmax of a [N, C] tensor (integer images preserve order,
    /// sec. 3.6, so classification works directly on Q(logits)).
    pub fn argmax_rows(&self) -> Vec<usize> {
        assert_eq!(self.ndim(), 2);
        let c = self.shape[1];
        self.data
            .chunks(c)
            .map(|row| {
                row.iter()
                    .enumerate()
                    .max_by_key(|(_, v)| **v)
                    .map(|(i, _)| i)
                    .unwrap()
            })
            .collect()
    }
}

impl Tensor<f32> {
    pub fn argmax_rows(&self) -> Vec<usize> {
        assert_eq!(self.ndim(), 2);
        let c = self.shape[1];
        self.data
            .chunks(c)
            .map(|row| {
                let mut best = 0;
                for (i, v) in row.iter().enumerate() {
                    if *v > row[best] {
                        best = i;
                    }
                }
                best
            })
            .collect()
    }
}

impl<T: fmt::Debug + Copy + Default> fmt::Debug for Tensor<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Tensor{:?}", self.shape)?;
        if self.len() <= 16 {
            write!(f, " {:?}", self.data)
        } else {
            write!(f, " [{:?}, {:?}, ...]", self.data[0], self.data[1])
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construct_and_index() {
        let t = Tensor::from_vec(&[2, 3], vec![1, 2, 3, 4, 5, 6]);
        assert_eq!(t.at2(1, 2), 6);
        assert_eq!(t.shape(), &[2, 3]);
    }

    #[test]
    fn at4_layout_is_nchw() {
        let mut t = Tensor::<i32>::zeros(&[2, 3, 4, 5]);
        t.set4(1, 2, 3, 4, 99);
        assert_eq!(t.at4(1, 2, 3, 4), 99);
        assert_eq!(t.data()[t.len() - 1], 99); // last element
    }

    #[test]
    #[should_panic]
    fn bad_shape_panics() {
        Tensor::from_vec(&[2, 2], vec![1, 2, 3]);
    }

    #[test]
    fn slice_and_cat_roundtrip() {
        let t = Tensor::from_vec(&[4, 2], (0..8).collect());
        let a = t.slice_batch(0, 1);
        let b = t.slice_batch(1, 4);
        let back = Tensor::cat_batch(&[&a, &b]);
        assert_eq!(back, t);
    }

    #[test]
    fn argmax_rows_int() {
        let t = Tensor::from_vec(&[2, 3], vec![1, 5, 2, -7, -3, -9]);
        assert_eq!(t.argmax_rows(), vec![1, 1]);
    }

    #[test]
    fn allclose() {
        let a = Tensor::from_vec(&[2], vec![1.0f32, 2.0]);
        let b = Tensor::from_vec(&[2], vec![1.0f32, 2.0 + 1e-6]);
        assert!(a.allclose(&b, 1e-5, 0.0));
        assert!(!a.allclose(&b, 1e-8, 0.0));
    }
}
