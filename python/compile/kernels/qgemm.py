"""Integer-image GEMM Pallas kernels (Eq. 16) with fused ID-layer epilogues.

The hot path of every IntegerDeployable layer is

    Q(varphi) = sum_n Q_w(w_n) * Q_x(x_n)                      (Eq. 16)
    Q(phi)    = Q(kappa) * Q(varphi) + Q(lambda)               (Eq. 22)
    Q(y)      = clip((m * Q(phi)) >> d, 0, 2^Q - 1)            (Eq. 11)

`qgemm` computes the first line; `qgemm_bn_requant` fuses all three so the
int32 accumulator tile never leaves VMEM between the matmul and the
epilogue — this is the TPU re-think of the paper's MCU inner loop (see
DESIGN.md #Hardware-Adaptation).

Tiling: grid (M/bm, N/bn, K/bk) with the K axis innermost; the output tile
is accumulated across K steps and the epilogue fires on the last K step.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import INT, WIDE, INTERPRET, cdiv, pad_to


def _qgemm_kernel(a_ref, b_ref, o_ref, *, nk: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jax.lax.dot_general(
        a_ref[...], b_ref[...],
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=INT,
    )


def qgemm(a: jnp.ndarray, b: jnp.ndarray, *, bm: int = 64, bk: int = 64,
          bn: int = 64) -> jnp.ndarray:
    """C[M,N] = A[M,K] @ B[K,N] over int32 integer images.

    The int32 accumulator is safe by the range analysis the deployment
    pipeline performs (rust/src/transform/range.rs): |A| < 2^8, |B| < 2^8,
    K <= 2^14 keeps |C| < 2^31.
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"qgemm: inner dims {k} != {k2}"
    ap = pad_to(pad_to(a, 0, bm), 1, bk)
    bp = pad_to(pad_to(b, 0, bk), 1, bn)
    nk = cdiv(k, bk)
    out = pl.pallas_call(
        functools.partial(_qgemm_kernel, nk=nk),
        grid=(cdiv(m, bm), cdiv(n, bn), nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((ap.shape[0], bp.shape[1]), INT),
        interpret=INTERPRET,
    )(ap, bp)
    return out[:m, :n]


def _qgemm_bn_requant_kernel(a_ref, b_ref, kappa_ref, lambda_ref, mdlh_ref,
                             o_ref, *, nk: int):
    # The int32 output tile itself is the accumulator: it stays resident
    # across the K grid steps, and the epilogue rewrites it in place on the
    # last step, so the partial sums never travel back to HBM.
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jax.lax.dot_general(
        a_ref[...], b_ref[...],
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=INT,
    )

    @pl.when(pl.program_id(2) == nk - 1)
    def _epilogue():
        acc = o_ref[...].astype(WIDE)
        kq = kappa_ref[...].astype(WIDE)[None, :]
        lq = lambda_ref[...].astype(WIDE)[None, :]
        bn = acc * kq + lq
        m = mdlh_ref[0].astype(WIDE)
        d = mdlh_ref[1].astype(WIDE)
        lo = mdlh_ref[2].astype(WIDE)
        hi = mdlh_ref[3].astype(WIDE)
        y = jnp.clip(jnp.right_shift(bn * m, d), lo, hi)
        o_ref[...] = y.astype(INT)


def qgemm_bn_requant(a: jnp.ndarray, b: jnp.ndarray, kappa_q: jnp.ndarray,
                     lambda_q: jnp.ndarray, m: jnp.ndarray, d: jnp.ndarray,
                     lo: jnp.ndarray, hi: jnp.ndarray, *, bm: int = 64,
                     bk: int = 64, bn: int = 64) -> jnp.ndarray:
    """Fused ID layer: requant(intbn(A @ B)) (Eq. 16 + 22 + 11).

    kappa_q/lambda_q: [N] per-output-channel int32; m,d,lo,hi: int32
    scalars (m,d chosen by the deployment pipeline per Eq. 13-14).
    """
    mm, k = a.shape
    k2, n = b.shape
    assert k == k2
    ap = pad_to(pad_to(a, 0, bm), 1, bk)
    bp = pad_to(pad_to(b, 0, bk), 1, bn)
    kp = pad_to(kappa_q, 0, bn)
    lp = pad_to(lambda_q, 0, bn)
    mdlh = jnp.stack([m, d, lo, hi]).astype(INT)
    nk = cdiv(k, bk)
    out = pl.pallas_call(
        functools.partial(_qgemm_bn_requant_kernel, nk=nk),
        grid=(cdiv(mm, bm), cdiv(n, bn), nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
            pl.BlockSpec((4,), lambda i, j, kk: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((ap.shape[0], bp.shape[1]), INT),
        interpret=INTERPRET,
    )(ap, bp, kp, lp, mdlh)
    return out[:mm, :n]
