//! Dense row-major tensor substrate (S1 in DESIGN.md).
//!
//! The offline vendor set has no `ndarray`, so the engines run on this
//! small, fully-tested implementation. Element types used across the
//! crate: `f32` for FullPrecision/FakeQuantized/QuantizedDeployable
//! values and `i32` for IntegerDeployable integer images (with `i64`
//! widening inside the ops that need it, mirroring the Pallas kernels).
//! Sub-word integer images additionally pack to `u8`/`i8` storage behind
//! [`QTensor`] when the deployment pipeline proves the value range fits
//! (DESIGN.md §Precision propagation) — 1 byte/element instead of 4 on
//! the bandwidth-bound GEMM hot path.

pub mod ops;

use std::fmt;

use crate::quant::Precision;

#[derive(Clone, PartialEq)]
pub struct Tensor<T> {
    shape: Vec<usize>,
    data: Vec<T>,
}

pub type TensorF = Tensor<f32>;
pub type TensorI = Tensor<i32>;
pub type TensorU8 = Tensor<u8>;
pub type TensorI8 = Tensor<i8>;

impl<T: Copy + Default> Tensor<T> {
    pub fn zeros(shape: &[usize]) -> Self {
        let n: usize = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![T::default(); n] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<T>) -> Self {
        let n: usize = shape.iter().product();
        assert_eq!(n, data.len(), "shape {:?} != data len {}", shape, data.len());
        Tensor { shape: shape.to_vec(), data }
    }

    pub fn full(shape: &[usize], v: T) -> Self {
        let n: usize = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![v; n] }
    }

    pub fn scalar(v: T) -> Self {
        Tensor { shape: vec![], data: vec![v] }
    }

    #[inline]
    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    #[inline]
    pub fn ndim(&self) -> usize {
        self.shape.len()
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    #[inline]
    pub fn data(&self) -> &[T] {
        &self.data
    }

    #[inline]
    pub fn data_mut(&mut self) -> &mut [T] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<T> {
        self.data
    }

    /// Reshape without moving data (total size must match).
    pub fn reshape(&self, shape: &[usize]) -> Self {
        let n: usize = shape.iter().product();
        assert_eq!(n, self.data.len(), "reshape {:?} -> {:?}", self.shape, shape);
        Tensor { shape: shape.to_vec(), data: self.data.clone() }
    }

    pub fn into_reshape(mut self, shape: &[usize]) -> Self {
        let n: usize = shape.iter().product();
        assert_eq!(n, self.data.len());
        self.shape = shape.to_vec();
        self
    }

    #[inline]
    pub fn at2(&self, i: usize, j: usize) -> T {
        debug_assert_eq!(self.ndim(), 2);
        self.data[i * self.shape[1] + j]
    }

    #[inline]
    pub fn at4(&self, n: usize, c: usize, h: usize, w: usize) -> T {
        debug_assert_eq!(self.ndim(), 4);
        let (sc, sh, sw) = (self.shape[1], self.shape[2], self.shape[3]);
        self.data[((n * sc + c) * sh + h) * sw + w]
    }

    #[inline]
    pub fn set4(&mut self, n: usize, c: usize, h: usize, w: usize, v: T) {
        let (sc, sh, sw) = (self.shape[1], self.shape[2], self.shape[3]);
        self.data[((n * sc + c) * sh + h) * sw + w] = v;
    }

    pub fn map<U: Copy + Default>(&self, f: impl Fn(T) -> U) -> Tensor<U> {
        Tensor {
            shape: self.shape.clone(),
            data: self.data.iter().map(|x| f(*x)).collect(),
        }
    }

    /// Batch-slice of a 4-D (NCHW) or 2-D tensor: rows [lo, hi).
    pub fn slice_batch(&self, lo: usize, hi: usize) -> Self {
        assert!(!self.shape.is_empty() && hi <= self.shape[0] && lo <= hi);
        let row: usize = self.shape[1..].iter().product();
        let mut shape = self.shape.clone();
        shape[0] = hi - lo;
        Tensor { shape, data: self.data[lo * row..hi * row].to_vec() }
    }

    /// Concatenate along axis 0.
    pub fn cat_batch(parts: &[&Tensor<T>]) -> Self {
        assert!(!parts.is_empty());
        let inner = &parts[0].shape[1..];
        let mut shape = parts[0].shape.clone();
        shape[0] = parts.iter().map(|p| p.shape[0]).sum();
        let mut data = Vec::with_capacity(shape.iter().product());
        for p in parts {
            assert_eq!(&p.shape[1..], inner, "cat_batch shape mismatch");
            data.extend_from_slice(&p.data);
        }
        Tensor { shape, data }
    }
}

impl Tensor<f32> {
    pub fn from_f64(shape: &[usize], data: &[f64]) -> Self {
        Tensor::from_vec(shape, data.iter().map(|x| *x as f32).collect())
    }

    pub fn allclose(&self, other: &Self, atol: f32, rtol: f32) -> bool {
        self.shape == other.shape
            && self
                .data
                .iter()
                .zip(&other.data)
                .all(|(a, b)| (a - b).abs() <= atol + rtol * b.abs())
    }

    pub fn max_abs_diff(&self, other: &Self) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

impl Tensor<i32> {
    /// Per-row argmax of a [N, C] tensor (integer images preserve order,
    /// sec. 3.6, so classification works directly on Q(logits)).
    pub fn argmax_rows(&self) -> Vec<usize> {
        assert_eq!(self.ndim(), 2);
        let c = self.shape[1];
        self.data
            .chunks(c)
            .map(|row| {
                row.iter()
                    .enumerate()
                    .max_by_key(|(_, v)| **v)
                    .map(|(i, _)| i)
                    .unwrap()
            })
            .collect()
    }
}

impl Tensor<f32> {
    pub fn argmax_rows(&self) -> Vec<usize> {
        assert_eq!(self.ndim(), 2);
        let c = self.shape[1];
        self.data
            .chunks(c)
            .map(|row| {
                let mut best = 0;
                for (i, v) in row.iter().enumerate() {
                    if *v > row[best] {
                        best = i;
                    }
                }
                best
            })
            .collect()
    }
}

// -- sub-byte bit packing ---------------------------------------------
//
// Layout contract (DESIGN.md §Sub-byte-packing): element `e` of a flat
// buffer occupies bits [e*bits, (e+1)*bits) counted LSB-first within
// each byte. All sub-byte widths (1/2/4) divide 8, so elements never
// straddle byte boundaries: byte `b` holds elements
// [b*8/bits, (b+1)*8/bits), the lowest-indexed element in the lowest
// bits. Signed nibbles (`I4`) store 4-bit two's complement.

/// Bytes needed for `len` elements of `bits` width (`ceil(len*bits/8)`).
#[inline]
pub fn packed_byte_len(len: usize, bits: u32) -> usize {
    (len * bits as usize).div_ceil(8)
}

/// Read element `idx` of a packed buffer as its unsigned bit pattern.
#[inline]
pub fn get_packed_raw(data: &[u8], idx: usize, bits: u32) -> u32 {
    debug_assert!(matches!(bits, 1 | 2 | 4));
    let bit = idx * bits as usize;
    let mask = (1u32 << bits) - 1;
    (data[bit / 8] as u32 >> (bit % 8)) & mask
}

/// Read element `idx` of a packed buffer at precision `p`, sign-extending
/// two's-complement nibbles for `I4`.
#[inline]
pub fn get_packed(data: &[u8], idx: usize, p: Precision) -> i32 {
    let raw = get_packed_raw(data, idx, p.bits());
    if p == Precision::I4 && raw >= 8 {
        raw as i32 - 16
    } else {
        raw as i32
    }
}

/// Write element `idx` of a packed buffer at precision `p`. The value
/// must be in `p`'s range (debug-asserted — callers range-check first).
#[inline]
pub fn set_packed(data: &mut [u8], idx: usize, p: Precision, v: i32) {
    let bits = p.bits();
    debug_assert!(
        (p.min_val()..=p.max_val()).contains(&(v as i64)),
        "value {v} outside {} range",
        p.name()
    );
    let bit = idx * bits as usize;
    let mask = ((1u32 << bits) - 1) as u8;
    let raw = (v as u32 & mask as u32) as u8;
    let b = &mut data[bit / 8];
    let shift = bit % 8;
    *b = (*b & !(mask << shift)) | (raw << shift);
}

/// A bit-packed sub-byte integer image: `len` elements of a sub-byte
/// [`Precision`] in `storage_bytes` bytes, LSB-first (see the layout
/// contract above). Trailing pad bits of the final byte are always zero,
/// so equal images have equal bytes and payload checksums are stable.
#[derive(Clone, Debug, PartialEq)]
pub struct PackedTensor {
    prec: Precision,
    shape: Vec<usize>,
    len: usize,
    data: Vec<u8>,
}

impl PackedTensor {
    /// Wrap raw packed bytes. Fails loudly when the byte length does not
    /// match `p.storage_bytes(len)`, when `p` is not sub-byte, or when a
    /// trailing pad bit is set (a corrupt or non-canonical payload).
    pub fn from_bytes(
        shape: &[usize],
        p: Precision,
        data: Vec<u8>,
    ) -> Result<Self, String> {
        if !p.is_sub_byte() {
            return Err(format!("{} is not a sub-byte precision", p.name()));
        }
        let len: usize = shape.iter().product();
        let want = p.storage_bytes(len);
        if data.len() != want {
            return Err(format!(
                "packed {} payload of {} bytes, shape {shape:?} wants {want}",
                p.name(),
                data.len()
            ));
        }
        let used_bits = len * p.bits() as usize;
        if used_bits % 8 != 0 {
            let last = data[want - 1];
            let pad_mask = !((1u16 << (used_bits % 8)) as u8).wrapping_sub(1);
            if last & pad_mask != 0 {
                return Err(format!(
                    "packed {} payload has non-zero trailing pad bits",
                    p.name()
                ));
            }
        }
        Ok(PackedTensor { prec: p, shape: shape.to_vec(), len, data })
    }

    pub fn precision(&self) -> Precision {
        self.prec
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// The packed payload bytes.
    pub fn bytes(&self) -> &[u8] {
        &self.data
    }

    /// Element `idx`, sign-extended for `I4`.
    #[inline]
    pub fn get(&self, idx: usize) -> i32 {
        get_packed(&self.data, idx, self.prec)
    }
}

/// A precision-tagged integer image: the packed counterpart of
/// [`TensorI`]. Sub-word variants store 1 byte/element and the sub-byte
/// classes pack 2-8 elements per byte; every variant widens losslessly
/// back to `i32`, and narrowing is checked against the target precision's
/// range — the conversion fails loudly instead of wrapping, because a
/// value outside the stamped range means the deploy-time range proof was
/// violated.
#[derive(Clone, Debug, PartialEq)]
pub enum QTensor {
    U8(TensorU8),
    I8(TensorI8),
    I32(TensorI),
    /// Any sub-byte precision (`U1`/`U2`/`U4`/`I4`), bit-packed.
    Packed(PackedTensor),
}

impl QTensor {
    /// Storage precision of this image.
    pub fn precision(&self) -> Precision {
        match self {
            QTensor::U8(_) => Precision::U8,
            QTensor::I8(_) => Precision::I8,
            QTensor::I32(_) => Precision::I32,
            QTensor::Packed(t) => t.precision(),
        }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            QTensor::U8(t) => t.shape(),
            QTensor::I8(t) => t.shape(),
            QTensor::I32(t) => t.shape(),
            QTensor::Packed(t) => t.shape(),
        }
    }

    pub fn len(&self) -> usize {
        match self {
            QTensor::U8(t) => t.len(),
            QTensor::I8(t) => t.len(),
            QTensor::I32(t) => t.len(),
            QTensor::Packed(t) => t.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Bytes of element storage (the bandwidth this image costs).
    pub fn storage_bytes(&self) -> usize {
        self.precision().storage_bytes(self.len())
    }

    /// Lossless widening to the full-width i32 image.
    pub fn widen(&self) -> TensorI {
        match self {
            QTensor::U8(t) => t.map(|v| v as i32),
            QTensor::I8(t) => t.map(|v| v as i32),
            QTensor::I32(t) => t.clone(),
            QTensor::Packed(t) => Tensor::from_vec(
                t.shape(),
                (0..t.len()).map(|i| t.get(i)).collect(),
            ),
        }
    }

    /// Checked narrowing of an i32 image into packed storage. Returns an
    /// error naming the offending value when any element falls outside
    /// `p`'s range (the range proof failed) instead of silently wrapping.
    pub fn narrow_from(t: &TensorI, p: Precision) -> Result<QTensor, String> {
        let check = |v: i32| -> Result<(), String> {
            let v = v as i64;
            if !(p.min_val()..=p.max_val()).contains(&v) {
                return Err(format!(
                    "value {v} outside {} range [{}, {}]",
                    p.name(),
                    p.min_val(),
                    p.max_val()
                ));
            }
            Ok(())
        };
        match p {
            Precision::U8 => {
                let mut data = Vec::with_capacity(t.len());
                for &v in t.data() {
                    check(v)?;
                    data.push(v as u8);
                }
                Ok(QTensor::U8(Tensor::from_vec(t.shape(), data)))
            }
            Precision::I8 => {
                let mut data = Vec::with_capacity(t.len());
                for &v in t.data() {
                    check(v)?;
                    data.push(v as i8);
                }
                Ok(QTensor::I8(Tensor::from_vec(t.shape(), data)))
            }
            Precision::I32 => Ok(QTensor::I32(t.clone())),
            _ => {
                let mut data = vec![0u8; p.storage_bytes(t.len())];
                for (i, &v) in t.data().iter().enumerate() {
                    check(v)?;
                    set_packed(&mut data, i, p, v);
                }
                Ok(QTensor::Packed(PackedTensor {
                    prec: p,
                    shape: t.shape().to_vec(),
                    len: t.len(),
                    data,
                }))
            }
        }
    }
}

impl From<TensorI> for QTensor {
    fn from(t: TensorI) -> Self {
        QTensor::I32(t)
    }
}

impl<T: fmt::Debug + Copy + Default> fmt::Debug for Tensor<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Tensor{:?}", self.shape)?;
        if self.len() <= 16 {
            write!(f, " {:?}", self.data)
        } else {
            write!(f, " [{:?}, {:?}, ...]", self.data[0], self.data[1])
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construct_and_index() {
        let t = Tensor::from_vec(&[2, 3], vec![1, 2, 3, 4, 5, 6]);
        assert_eq!(t.at2(1, 2), 6);
        assert_eq!(t.shape(), &[2, 3]);
    }

    #[test]
    fn at4_layout_is_nchw() {
        let mut t = Tensor::<i32>::zeros(&[2, 3, 4, 5]);
        t.set4(1, 2, 3, 4, 99);
        assert_eq!(t.at4(1, 2, 3, 4), 99);
        assert_eq!(t.data()[t.len() - 1], 99); // last element
    }

    #[test]
    #[should_panic]
    fn bad_shape_panics() {
        Tensor::from_vec(&[2, 2], vec![1, 2, 3]);
    }

    #[test]
    fn slice_and_cat_roundtrip() {
        let t = Tensor::from_vec(&[4, 2], (0..8).collect());
        let a = t.slice_batch(0, 1);
        let b = t.slice_batch(1, 4);
        let back = Tensor::cat_batch(&[&a, &b]);
        assert_eq!(back, t);
    }

    #[test]
    fn argmax_rows_int() {
        let t = Tensor::from_vec(&[2, 3], vec![1, 5, 2, -7, -3, -9]);
        assert_eq!(t.argmax_rows(), vec![1, 1]);
    }

    #[test]
    fn allclose() {
        let a = Tensor::from_vec(&[2], vec![1.0f32, 2.0]);
        let b = Tensor::from_vec(&[2], vec![1.0f32, 2.0 + 1e-6]);
        assert!(a.allclose(&b, 1e-5, 0.0));
        assert!(!a.allclose(&b, 1e-8, 0.0));
    }

    #[test]
    fn qtensor_narrow_widen_roundtrip() {
        let t = Tensor::from_vec(&[2, 2], vec![0, 1, 254, 255]);
        let q = QTensor::narrow_from(&t, Precision::U8).unwrap();
        assert_eq!(q.precision(), Precision::U8);
        assert_eq!(q.shape(), &[2, 2]);
        assert_eq!(q.storage_bytes(), 4);
        assert_eq!(q.widen(), t);

        let s = Tensor::from_vec(&[3], vec![-128, 0, 127]);
        let q = QTensor::narrow_from(&s, Precision::I8).unwrap();
        assert_eq!(q.precision(), Precision::I8);
        assert_eq!(q.storage_bytes(), 3);
        assert_eq!(q.widen(), s);

        let w = Tensor::from_vec(&[2], vec![-70000, 70000]);
        let q = QTensor::narrow_from(&w, Precision::I32).unwrap();
        assert_eq!(q.precision(), Precision::I32);
        assert_eq!(q.storage_bytes(), 8);
        assert_eq!(q.widen(), w);
    }

    #[test]
    fn qtensor_narrow_rejects_out_of_range() {
        let t = Tensor::from_vec(&[2], vec![0, 256]);
        let err = QTensor::narrow_from(&t, Precision::U8).unwrap_err();
        assert!(err.contains("256"), "{err}");
        let t = Tensor::from_vec(&[1], vec![-1]);
        assert!(QTensor::narrow_from(&t, Precision::U8).is_err());
        let t = Tensor::from_vec(&[1], vec![128]);
        assert!(QTensor::narrow_from(&t, Precision::I8).is_err());
        // sub-byte classes reject out-of-range values too
        let t = Tensor::from_vec(&[1], vec![2]);
        assert!(QTensor::narrow_from(&t, Precision::U1).is_err());
        let t = Tensor::from_vec(&[1], vec![4]);
        assert!(QTensor::narrow_from(&t, Precision::U2).is_err());
        let t = Tensor::from_vec(&[1], vec![16]);
        assert!(QTensor::narrow_from(&t, Precision::U4).is_err());
        let t = Tensor::from_vec(&[1], vec![-9]);
        assert!(QTensor::narrow_from(&t, Precision::I4).is_err());
    }

    #[test]
    fn subbyte_narrow_widen_roundtrip_and_sizing() {
        // U1: 9 elements -> 2 bytes, LSB-first.
        let t = Tensor::from_vec(&[9], vec![1, 0, 1, 1, 0, 0, 1, 0, 1]);
        let q = QTensor::narrow_from(&t, Precision::U1).unwrap();
        assert_eq!(q.precision(), Precision::U1);
        assert_eq!(q.storage_bytes(), 2);
        assert_eq!(q.widen(), t);
        if let QTensor::Packed(p) = &q {
            assert_eq!(p.bytes(), &[0b0100_1101, 0b0000_0001]);
        } else {
            panic!("expected packed storage");
        }

        // U2: 5 elements -> 2 bytes.
        let t = Tensor::from_vec(&[5], vec![0, 1, 2, 3, 2]);
        let q = QTensor::narrow_from(&t, Precision::U2).unwrap();
        assert_eq!(q.storage_bytes(), 2);
        assert_eq!(q.widen(), t);

        // U4 + I4: 2 elements per byte, I4 sign-extends.
        let t = Tensor::from_vec(&[3], vec![0, 15, 7]);
        let q = QTensor::narrow_from(&t, Precision::U4).unwrap();
        assert_eq!(q.storage_bytes(), 2);
        assert_eq!(q.widen(), t);
        let t = Tensor::from_vec(&[4], vec![-8, -1, 0, 7]);
        let q = QTensor::narrow_from(&t, Precision::I4).unwrap();
        assert_eq!(q.storage_bytes(), 2);
        assert_eq!(q.widen(), t);
    }

    #[test]
    fn packed_tensor_from_bytes_is_validated() {
        // Wrong byte length.
        assert!(PackedTensor::from_bytes(&[5], Precision::U2, vec![0]).is_err());
        // Non-sub-byte precision.
        assert!(PackedTensor::from_bytes(&[4], Precision::U8, vec![0]).is_err());
        // Set trailing pad bit (3 x 2 bits use bits 0-5 of one byte).
        assert!(PackedTensor::from_bytes(&[3], Precision::U2, vec![0x40]).is_err());
        // Canonical payload round-trips.
        let p = PackedTensor::from_bytes(&[3], Precision::U2, vec![0b10_01_00]).unwrap();
        assert_eq!((p.get(0), p.get(1), p.get(2)), (0, 1, 2));
        assert_eq!(QTensor::Packed(p.clone()).widen().data(), &[0, 1, 2]);
    }
}
